"""Elastic scaling: a checkpoint written under one mesh restores onto a
different mesh shape via logical-axis re-sharding (subprocess: needs 8
simulated devices)."""

import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import tempfile
    import numpy as np
    import jax, jax.numpy as jnp
    from repro import configs
    from repro.config import smoke_config
    from repro.checkpoint.checkpointer import Checkpointer
    from repro.distributed.fault_tolerance import elastic_reshard
    from repro.distributed.sharding import SINGLE_POD_RULES, ShardingCtx
    from repro.models import model as M

    import dataclasses
    cfg = smoke_config(configs.get_config("qwen2.5-3b"))
    cfg = dataclasses.replace(cfg, d_model=64, d_ff=128)
    params = M.init_params(jax.random.key(0), cfg, dtype=jnp.float32)
    specs = M.param_specs(cfg)

    def ctx_for(shape):
        mesh = jax.make_mesh(shape, ("data", "model"),
                             devices=jax.devices()[: shape[0] * shape[1]])
        rules = dict(SINGLE_POD_RULES)
        return ShardingCtx(mesh=mesh, rules=rules)

    # place on a (2,4) mesh, checkpoint, restore onto (4,2) and (1,2)
    ctx_a = ctx_for((2, 4))
    placed = elastic_reshard(params, specs, ctx_a)
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        ck.save(1, placed)
        for new_shape in ((4, 2), (1, 2)):
            ctx_b = ctx_for(new_shape)
            restored = ck.restore(1, placed)
            replaced = elastic_reshard(restored, specs, ctx_b)
            a = jax.tree_util.tree_leaves(params)
            b = jax.tree_util.tree_leaves(replaced)
            for x, y in zip(a, b):
                assert np.allclose(np.asarray(x), np.asarray(y)), new_shape
            # sharding really is on the new mesh
            leaf = jax.tree_util.tree_leaves(replaced)[3]
            assert leaf.sharding.mesh.devices.shape == new_shape
    print("ELASTIC_OK")
    """
)


@pytest.mark.slow
def test_checkpoint_restores_across_mesh_shapes():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        env=env, timeout=600,
    )
    assert r.returncode == 0, (r.stdout[-1000:], r.stderr[-3000:])
    assert "ELASTIC_OK" in r.stdout
