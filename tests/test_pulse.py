"""GraphPulse: windowed telemetry, SLO burn rates, exports, load harness.

Guarantee families (DESIGN.md §13):

1. **Windowed histograms** — ``Histogram.reset()``/``state()``/
   ``window_since()`` give logical reset-on-window semantics without
   destroying lifetime data; window percentiles match numpy on exactly
   the window's records.
2. **Time series** — ``TimeSeriesRegistry.tick()`` emits per-window
   counter deltas and histogram windows into a bounded ring; window-delta
   conservation (sum of deltas + mark == live value) holds even when
   ticks race a live fused workload from another thread.
3. **SLO burn rates** — multi-window evaluation fires on genuinely bad
   traffic, stays silent on healthy traffic (no false violations),
   dedups via edge-triggering, and refuses to judge sparse data.
4. **Typed error paths** — ServiceOverloaded and ShardLoadError become
   ``query.rejected`` / ``shard.load_error`` counters; tracer ring
   overflow surfaces as ``trace.dropped_events`` + an export warning.
5. **Load harness** — closed/open-loop replay is schedule-deterministic,
   phase-correct, and every recorded result is bitwise a solo oracle's.
"""

import threading
import time

import numpy as np
import pytest

from repro.core import apps
from repro.core.graph import from_edge_list, rmat_graph
from repro.core.vsw import VSWEngine
from repro.obs import (
    Histogram,
    MetricsRegistry,
    SLOMonitor,
    TimeSeriesRegistry,
    Tracer,
    error_rate_slo,
    jsonl_lines,
    latency_slo,
    parse_prometheus,
    prometheus_text,
    read_jsonl,
    share_slo,
    trace,
    write_jsonl,
)
from repro.serve import (
    GraphService,
    LoadGenerator,
    QueryClass,
    ServiceOverloaded,
    Workload,
    edge_state_at_version,
    oracle_kwargs,
)

pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")


def _norm(v):
    return np.nan_to_num(v, posinf=1e30)


def _mk_service(tmp_path, tag, g, **kw):
    kw.setdefault("num_shards", 6)
    kw.setdefault("window", 128)
    kw.setdefault("k", 16)
    kw.setdefault("backend", "numpy")
    return GraphService.from_graph(g, str(tmp_path / tag), **kw)


MIX = (
    QueryClass("bfs", weight=2.0, max_iters=8),
    QueryClass("sssp", weight=1.0, max_iters=8),
    QueryClass("wcc", weight=1.0, max_iters=8),
    QueryClass("ppr", weight=1.0, max_iters=6, params={"damping": 0.85}),
)


# ------------------------------------------------------ windowed histograms
def test_histogram_reset_clears_everything():
    h = Histogram("h")
    for x in (0.5, 1.0, 2.0, 0.0, -3.0):
        h.record(x)
    assert h.count == 5
    h.reset()
    assert h.count == 0 and h.total == 0.0
    assert h.quantile(0.99) == 0.0
    assert h.percentiles()["max"] == 0.0
    h.record(7.0)  # usable after reset
    assert h.count == 1


def test_window_since_sees_only_new_records():
    rng = np.random.default_rng(3)
    first = rng.lognormal(-6, 1.2, 4000)
    second = rng.lognormal(-4, 0.8, 6000)
    h = Histogram("h")
    for x in first:
        h.record(float(x))
    mark = h.state()
    w0 = h.window_since(None)  # full-lifetime window
    assert w0.count == len(first)
    for x in second:
        h.record(float(x))
    w = h.window_since(mark)
    assert w.count == len(second)
    assert w.mean == pytest.approx(second.mean(), rel=1e-6)
    for q in (0.5, 0.95, 0.99):
        exact = float(np.quantile(second, q))
        assert abs(w.quantile(q) - exact) / exact < 0.10, q
    # the live histogram keeps its lifetime data
    assert h.count == len(first) + len(second)
    # empty diff
    we = h.window_since(h.state())
    assert we.count == 0 and we.quantile(0.99) == 0.0


def test_window_merge_and_fraction_above():
    h = Histogram("h")
    lows, highs = [0.01] * 80, [1.0] * 20
    for x in lows:
        h.record(x)
    mark = h.state()
    w1 = h.window_since(None)
    for x in highs:
        h.record(x)
    w2 = h.window_since(mark)
    m = w1.merge(w2)
    assert m.count == 100
    assert m.total == pytest.approx(sum(lows) + sum(highs), rel=1e-9)
    assert w2.fraction_above(0.1) == pytest.approx(1.0)
    assert m.fraction_above(0.1) == pytest.approx(0.2)
    assert m.fraction_above(10.0) == 0.0
    p = m.percentiles()
    assert p["count"] == 100 and p["p50"] <= p["p99"]


# ------------------------------------------------------------- time series
def test_timeseries_counter_deltas_and_ring_bound():
    reg = MetricsRegistry()
    c = reg.counter("ops")
    h = reg.histogram("lat")
    ts = TimeSeriesRegistry(reg, capacity=4, interval_s=0.01)
    for k in range(6):
        c.add(10)
        h.record(0.1 * (k + 1))
        s = ts.tick()
        assert s.counters["ops"] == pytest.approx(10.0)
        assert s.histograms["lat"].count == 1
    assert ts.num_windows == 6
    assert len(ts.samples()) == 4  # bounded ring
    assert ts.dropped_samples == 2
    # window-delta conservation over the retained + dropped history
    assert c.value == pytest.approx(60.0)
    m = ts.merged(last_s=3600.0)
    assert m.samples == 4
    assert m.counters["ops"] == pytest.approx(40.0)  # 4 retained windows
    assert m.histograms["lat"].count == 4
    assert ts.series("ops") == [(s.wall_ts, 10.0) for s in ts.samples()]


def test_timeseries_background_ticker():
    reg = MetricsRegistry()
    reg.counter("x").add(1)
    ts = TimeSeriesRegistry(reg, interval_s=0.02)
    ts.start()
    with pytest.raises(RuntimeError):
        ts.start()
    time.sleep(0.15)
    ts.stop()
    ts.stop()  # idempotent
    assert ts.num_windows >= 3
    assert sum(s.counters.get("x", 0.0) for s in ts.samples()) == 1.0


# ---------------------------------------------------------------- SLO gates
def _fill(reg, ts, *, n, bad_frac, lat=0.01, bad_lat=1.0, ticks=4):
    for _ in range(ticks):
        for i in range(n // ticks):
            is_bad = (i / max(n // ticks, 1)) < bad_frac
            reg.histogram("query.latency_s").record(
                bad_lat if is_bad else lat
            )
            reg.counter("query.completed").add(1)
        ts.tick()


def test_slo_no_false_violations_on_healthy_traffic():
    reg = MetricsRegistry()
    ts = TimeSeriesRegistry(reg, interval_s=0.05)
    mon = SLOMonitor(ts, [
        latency_slo("lat", threshold_s=0.5, budget=0.01),
        error_rate_slo("err", budget=0.01,
                       total=("query.completed",)),
        share_slo("qw", budget=0.9),
    ])
    _fill(reg, ts, n=400, bad_frac=0.0)
    for _ in range(3):
        assert mon.evaluate() == []
    assert mon.violations == []
    snap = mon.snapshot()
    assert snap["active"] == [] and len(snap["objectives"]) == 3


def test_slo_fires_on_sustained_burn_and_dedups():
    reg = MetricsRegistry()
    ts = TimeSeriesRegistry(reg, interval_s=0.05)
    mon = SLOMonitor(
        ts,
        [latency_slo("lat", threshold_s=0.5, budget=0.01)],
        windows=((10.0, 2.0, 2.0),),
    )
    # 20% of queries blow the threshold: burn = 0.2/0.01 = 20 >> 2
    _fill(reg, ts, n=400, bad_frac=0.2)
    new = mon.evaluate()
    assert len(new) == 1
    v = new[0]
    assert v.slo == "lat" and v.kind == "latency"
    assert v.burn_long >= 2.0 and v.burn_short >= 2.0
    assert v.bad_fraction == pytest.approx(0.2, abs=0.05)
    assert reg.counter("slo.violations").value == 1
    # still bad: edge-triggered, no second record
    assert mon.evaluate() == []
    assert len(mon.violations) == 1
    d = v.to_dict()
    assert d["slo"] == "lat" and d["long_s"] == 10.0


def test_slo_min_events_guard_and_recovery():
    reg = MetricsRegistry()
    ts = TimeSeriesRegistry(reg, interval_s=0.05)
    slo = latency_slo("lat", threshold_s=0.5, budget=0.01, min_events=50)
    mon = SLOMonitor(ts, [slo], windows=((0.4, 0.4, 2.0),))
    # only 10 (all-bad) events: below min_events -> never a violation
    for _ in range(10):
        reg.histogram("query.latency_s").record(1.0)
    ts.tick()
    assert mon.evaluate() == []
    # plenty of bad events -> trips; then healthy windows age it out
    _fill(reg, ts, n=200, bad_frac=1.0, ticks=2)
    assert len(mon.evaluate()) == 1
    time.sleep(0.5)  # the 0.4 s window now holds only what comes next
    _fill(reg, ts, n=200, bad_frac=0.0, ticks=2)
    assert mon.evaluate() == []  # recovered, _active cleared
    _fill(reg, ts, n=200, bad_frac=1.0, ticks=2)
    assert len(mon.evaluate()) == 1  # re-trips after recovery


def test_slo_validation():
    reg = MetricsRegistry()
    ts = TimeSeriesRegistry(reg)
    with pytest.raises(ValueError):
        latency_slo("x", threshold_s=1.0, budget=0.0)
    with pytest.raises(ValueError):
        SLOMonitor(ts, [latency_slo("a", threshold_s=1.0),
                        latency_slo("a", threshold_s=2.0)])
    with pytest.raises(ValueError):
        SLOMonitor(ts, [latency_slo("a", threshold_s=1.0)],
                   windows=((5.0, 10.0, 2.0),))


# ------------------------------------------------------------------ exports
def test_prometheus_roundtrip_registry_and_snapshot():
    reg = MetricsRegistry()
    reg.counter("query.completed").add(7)
    reg.gauge("queue.depth").set(3.0)
    h = reg.histogram("query.latency_s")
    for x in (0.01, 0.02, 0.05):
        h.record(x)
    text = prometheus_text(reg)
    parsed = parse_prometheus(text)
    assert parsed["graphmp_query_completed"] == 7.0
    assert parsed["graphmp_queue_depth"] == 3.0
    assert parsed["graphmp_query_latency_s_count"] == 3.0
    assert parsed['graphmp_query_latency_s{quantile="0.99"}'] == \
        pytest.approx(0.05, rel=0.10)
    # snapshot-dict form (histograms as percentile blocks)
    snap = {"lat": h.percentiles(), "done": 7.0}
    parsed2 = parse_prometheus(prometheus_text(snap, namespace="svc"))
    assert parsed2["svc_done"] == 7.0
    assert parsed2['svc_lat{quantile="0.5"}'] == \
        pytest.approx(h.quantile(0.5))
    with pytest.raises(ValueError):
        parse_prometheus("this is { not a sample\n")


def test_jsonl_roundtrip(tmp_path):
    reg = MetricsRegistry()
    ts = TimeSeriesRegistry(reg, interval_s=0.01)
    for k in range(3):
        reg.counter("ops").add(k + 1)
        reg.histogram("lat").record(0.01 * (k + 1))
        ts.tick()
    path = str(tmp_path / "pulse.jsonl")
    assert write_jsonl(path, ts) == 3
    docs = read_jsonl(path)
    assert [d["index"] for d in docs] == [0, 1, 2]
    assert docs[1]["counters"]["ops"] == 2.0
    assert docs[2]["histograms"]["lat"]["count"] == 1
    assert write_jsonl(path, ts, append=True) == 3
    assert len(read_jsonl(path)) == 6
    assert len(list(jsonl_lines(ts.samples()))) == 3
    (tmp_path / "bad.jsonl").write_text('{"index": 0}\n')
    with pytest.raises(ValueError):
        read_jsonl(str(tmp_path / "bad.jsonl"))


# ------------------------------------------------- typed errors + trace drops
def test_tracer_ring_overflow_is_loud():
    t = Tracer(capacity=8)
    with trace.tracing(t):
        for i in range(50):
            trace.instant("tick", i=i)
        assert trace.dropped_events() == 42
        reg = MetricsRegistry()
        assert trace.publish_drops(reg) == 42
        assert reg.counter("trace.dropped_events").value == 42
        trace.publish_drops(reg)  # idempotent mirror, not double-count
        assert reg.counter("trace.dropped_events").value == 42
    doc = t.export_chrome()
    assert doc["otherData"]["dropped_events"] == 42
    assert "truncated" in doc["otherData"]["warning"]
    # healthy tracer: no warning key, no counter created
    t2 = Tracer(capacity=64)
    with trace.tracing(t2):
        trace.instant("ok")
        reg2 = MetricsRegistry()
        trace.publish_drops(reg2)
        assert "trace.dropped_events" not in reg2.snapshot()
    assert "warning" not in t2.export_chrome()["otherData"]
    assert trace.dropped_events() == 0  # tracing disabled -> 0


def test_rejection_counts_as_typed_metric(tmp_path):
    g = rmat_graph(400, 4000, seed=2)
    svc = _mk_service(tmp_path, "svc", g, max_pending=1, max_lanes=2,
                      session_entries=0)
    rejected = 0
    with svc.submit_batch():  # worker blocked: queue must overflow
        futs = []
        for s in range(8):
            try:
                futs.append(svc.submit("bfs", s, max_iters=4))
            except ServiceOverloaded:
                rejected += 1
    for f in futs:
        f.result(timeout=60)
    assert rejected > 0
    snap = svc.metrics_snapshot()
    assert snap["errors"]["rejected"] == rejected
    assert snap["errors"]["completed"] == len(futs)
    svc.close()


def test_shard_load_error_counts_as_typed_metric(tmp_path):
    g = rmat_graph(400, 4000, seed=2)
    svc = _mk_service(tmp_path, "svc", g, session_entries=0)
    eng = svc.engine
    orig = eng.store.shard_bytes

    def poisoned(p, fmt="csr"):
        if p == 1:
            raise OSError(f"disk hole at shard {p}")
        return orig(p, fmt)

    eng.store.shard_bytes = poisoned
    eng.pipeline.cache = None
    eng.pipeline.resident = None
    with pytest.raises(Exception):
        svc.query("bfs", 0, max_iters=4)
    snap = svc.metrics_snapshot()
    assert snap["errors"]["shard_load_errors"] >= 1
    eng.store.shard_bytes = orig
    svc.close()


# --------------------------------------------- service telemetry lifecycle
def test_service_telemetry_lifecycle_and_windowed_snapshot(tmp_path):
    g = rmat_graph(500, 5000, seed=5)
    svc = _mk_service(tmp_path, "svc", g)
    ts = svc.start_telemetry(interval_s=0.03)
    assert svc.timeseries is ts and svc.slo_monitor is None
    with pytest.raises(RuntimeError):
        svc.start_telemetry()
    for s in range(6):
        svc.query("bfs", s, max_iters=6)
    time.sleep(0.1)
    w1 = svc.metrics_snapshot(window=True)
    assert w1["query_latency_s"]["count"] >= 6
    svc.query("bfs", 100, max_iters=6)
    w2 = svc.metrics_snapshot(window=True)
    assert w2["query_latency_s"]["count"] == 1  # only the new record
    life = svc.metrics_snapshot()  # lifetime view unaffected by windowing
    assert life["query_latency_s"]["count"] >= 7
    assert "timeseries" in life and life["timeseries"]["windows"] >= 2
    got = svc.stop_telemetry()
    assert got is ts and svc.stop_telemetry() is None  # idempotent
    assert svc.timeseries is None
    svc.start_telemetry(interval_s=0.05)  # restart allowed after stop
    svc.close()  # close stops telemetry
    assert svc.timeseries is None


# ------------------------------------- concurrent snapshotting (no tearing)
_CONCURRENT_VALS = {}  # traced -> stacked result values (cross-param check)


@pytest.mark.parametrize("traced", [False, True])
def test_concurrent_snapshots_mid_sweep(tmp_path, traced):
    """metrics_snapshot() + external ticks from a second thread while a
    fused workload runs: no exceptions, window-delta conservation exact,
    and the traced run's values bitwise-match the untraced run's."""
    g = rmat_graph(800, 12_000, seed=9)
    svc = _mk_service(tmp_path, f"svc{traced}", g, session_entries=0,
                      max_lanes=8)
    # capacity must hold every window of the run: the conservation check
    # below sums ALL deltas, so nothing may fall off the ring
    ts = TimeSeriesRegistry(svc.metrics, capacity=1 << 16,
                            interval_s=0.005)
    stop = threading.Event()
    errors = []

    def hammer():
        while not stop.is_set():
            try:
                ts.tick()
                svc.metrics_snapshot()
                time.sleep(0.001)
            except Exception as exc:  # pragma: no cover
                errors.append(exc)

    th = threading.Thread(target=hammer, daemon=True)
    th.start()
    tracer = Tracer() if traced else None
    sources = list(range(0, 64, 4))
    try:
        if traced:
            trace.install(tracer)
        futs = [svc.submit("sssp", s, max_iters=10) for s in sources]
        vals = {s: f.result(timeout=120).values for s, f in
                zip(sources, futs)}
    finally:
        if traced:
            trace.uninstall()
        stop.set()
        th.join()
    assert not errors
    ts.tick()  # close the final window
    # conservation: all window deltas sum to the live counter, exactly
    done = svc.metrics.counter("query.completed").value
    deltas = sum(s.counters.get("query.completed", 0.0)
                 for s in ts.samples())
    assert ts.dropped_samples == 0
    assert deltas == pytest.approx(done, abs=0)
    assert done == len(sources)
    assert len(svc.metrics_snapshot()["conservation_violations"]) == 0
    svc.close()
    _CONCURRENT_VALS[traced] = np.stack([vals[s] for s in sources])
    if traced and False in _CONCURRENT_VALS:
        # traced == untraced, bitwise: observation changed nothing
        assert np.array_equal(_norm(_CONCURRENT_VALS[False]),
                              _norm(_CONCURRENT_VALS[True]))


# ------------------------------------------------------------- load harness
def test_workload_plan_is_deterministic():
    wl = Workload(classes=MIX, seed=11, update_every=8, update_batch=4)
    p1 = wl.plan(1000, 32)
    p2 = wl.plan(1000, 32)
    assert np.array_equal(p1.cls_idx, p2.cls_idx)
    assert np.array_equal(p1.sources, p2.sources)
    assert len(p1.updates) == 4
    for a, b in zip(p1.updates, p2.updates):
        assert np.array_equal(a, b)
    with pytest.raises(ValueError):
        Workload(classes=())
    with pytest.raises(ValueError):
        QueryClass("bfs", weight=0.0)


def test_closed_loop_phases_and_report(tmp_path):
    g = rmat_graph(600, 8000, seed=8)
    svc = _mk_service(tmp_path, "svc", g, max_lanes=8)
    wl = Workload(classes=MIX, seed=21)
    rep = LoadGenerator(svc, wl, mode="closed", concurrency=3,
                        batch_size=2, total_ops=24, warmup_ops=6).run()
    assert rep.mode == "closed"
    assert rep.warmup_records == 6
    assert rep.submitted == 18  # measure phase only
    assert rep.completed == 18 and rep.errors == 0 and rep.rejected == 0
    assert rep.qps > 0 and rep.latency["count"] == 18
    assert sum(rep.per_class.values()) == 18
    assert 0.0 <= rep.queue_wait_share <= 1.0
    assert len(rep.records) == 24  # warmup kept in the raw records
    summ = rep.summary()
    assert "records" not in summ and summ["qps"] == rep.qps
    svc.close()


def test_open_loop_records_rejections(tmp_path):
    g = rmat_graph(600, 8000, seed=8)
    svc = _mk_service(tmp_path, "svc", g, max_lanes=2, max_pending=1,
                      session_entries=0)
    wl = Workload(classes=(QueryClass("ppr", max_iters=6,
                                      params={"damping": 0.85}),), seed=3)
    rep = LoadGenerator(svc, wl, mode="open", target_qps=500.0,
                        total_ops=30).run()
    assert rep.submitted == 30
    assert rep.completed + rep.rejected == 30
    assert rep.rejected > 0  # the cap must have pushed back
    for r in rep.records:
        if r.rejected:
            assert not r.ok and r.values is None
    # rejections are typed, not silent
    assert svc.metrics_snapshot()["errors"]["rejected"] == rep.rejected
    svc.close()


def test_loadgen_bitwise_oracle_across_versions(tmp_path):
    """The harness's own determinism contract: every completed query,
    closed or open loop, under a live mutation stream, equals a solo
    engine run at exactly its graph version."""
    rng = np.random.default_rng(17)
    n = 500
    edges = rng.integers(0, n, size=(6000, 2)).astype(np.int64)
    g = from_edge_list(edges, n)
    svc = _mk_service(tmp_path, "svc", g, max_lanes=8)
    wl = Workload(classes=MIX, seed=5, update_every=10, update_batch=6)
    rep = LoadGenerator(svc, wl, mode="closed", concurrency=4,
                        total_ops=30).run()
    svc.close()
    assert rep.updates_published >= 1  # the stream actually mutated
    recs = [r for r in rep.records if r.ok]
    assert len(recs) == 30
    versions = sorted({r.graph_version for r in recs})
    assert len(versions) >= 2  # queries spanned a publish
    for v in versions:
        g_v = from_edge_list(
            edge_state_at_version(edges, rep.updates, v), n
        )
        eng = VSWEngine.from_graph(
            g_v, str(tmp_path / f"oracle{v}"), num_shards=6,
            window=128, k=16, backend="numpy",
        )
        for r in recs:
            if r.graph_version != v:
                continue
            solo = eng.run(apps.get_program(r.program, **oracle_kwargs(r)),
                           max_iters=r.max_iters)
            assert np.array_equal(_norm(solo.values), _norm(r.values)), (
                v, r.program, r.source)
        eng.close()


def test_loadgen_validation(tmp_path):
    g = rmat_graph(200, 1000, seed=1)
    svc = _mk_service(tmp_path, "svc", g)
    wl = Workload(classes=MIX)
    with pytest.raises(ValueError):
        LoadGenerator(svc, wl, mode="weird")
    with pytest.raises(ValueError):
        LoadGenerator(svc, wl, mode="open")  # needs target_qps
    with pytest.raises(ValueError):
        LoadGenerator(svc, wl, warmup_ops=9, total_ops=9)
    with pytest.raises(ValueError):
        LoadGenerator(svc, wl, batch_size=0)
    svc.close()
