"""Streamed out-of-core ingestion vs the in-memory oracle (ISSUE 3).

The contract: :func:`repro.core.ingest.ingest_edge_file` must produce
**bitwise-identical** ``GraphMeta`` and per-shard ``row``/``col`` arrays to
the in-memory :func:`repro.core.sharding.preprocess` for every chunk size
(including chunk=1 and chunk > |E|), every spill cadence, both edge-file
formats, empty shards and isolated vertices — while peak memory stays
O(chunk + one shard), never O(|E|).

``hypothesis`` is optional (same convention as ``test_property_graph.py``):
without it each property runs over a deterministic battery of seeded random
graphs.  Tests whose name contains ``e2e`` boot full engines (jax import);
``tests/run_memcapped.py`` runs the rest under a hard RLIMIT_AS cap.
"""

import gc
import os
import tempfile
import tracemalloc

import numpy as np
import pytest

try:
    from hypothesis import HealthCheck, given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core.cache import ShardCache
from repro.core.graph import Graph, rmat_graph, star_graph
from repro.core.ingest import (
    ingest_edge_file,
    iter_edge_chunks,
    kway_merge,
    write_edge_file,
)
from repro.core.sharding import ShardCSR, preprocess
from repro.core.storage import ShardStore

if HAVE_HYPOTHESIS:

    @st.composite
    def graphs(draw, max_v=60, max_e=300):
        n = draw(st.integers(min_value=2, max_value=max_v))
        m = draw(st.integers(min_value=1, max_value=max_e))
        src = draw(st.lists(st.integers(0, n - 1), min_size=m, max_size=m))
        dst = draw(st.lists(st.integers(0, n - 1), min_size=m, max_size=m))
        return Graph(n, np.array(src, np.int32), np.array(dst, np.int32))


def _seeded_graph(seed, max_v=60, max_e=300):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, max_v + 1))
    m = int(rng.integers(1, max_e + 1))
    return Graph(
        n,
        rng.integers(0, n, m).astype(np.int32),
        rng.integers(0, n, m).astype(np.int32),
    )


def _property(arg_fn, n_examples, hyp_decorators):
    """Hypothesis when available, else a seeded parametrize (same checks)."""

    def deco(check):
        if HAVE_HYPOTHESIS:
            f = check
            for d in reversed(hyp_decorators):
                f = d(f)
            return f

        @pytest.mark.parametrize("seed", range(n_examples))
        def wrapper(seed):
            check(*arg_fn(seed))

        wrapper.__name__ = check.__name__
        return wrapper

    return deco


# --------------------------------------------------------------------------
# The oracle comparison
# --------------------------------------------------------------------------


def _ingest_into(d, g, *, fmt, chunk_edges, mem_budget_bytes, **part):
    """Write g's edges to a file, stream-ingest, return (store, meta, stats)."""
    ext = ".txt" if fmt == "text" else ".bin"
    edge_path = os.path.join(d, f"edges{ext}")
    write_edge_file(edge_path, g.src, g.dst, fmt=fmt)
    store = ShardStore(os.path.join(d, "store"))
    meta, stats = ingest_edge_file(
        store,
        edge_path,
        num_vertices=g.num_vertices,
        chunk_edges=chunk_edges,
        mem_budget_bytes=mem_budget_bytes,
        window=64,
        k=8,
        tr=4,
        **part,
    )
    return store, meta, stats


def _assert_bitwise_equal(store, meta, g, **part):
    """meta + every shard from the store vs in-memory preprocess, bitwise."""
    ref_meta, ref_shards = preprocess(g, **part)
    assert meta.num_vertices == ref_meta.num_vertices
    assert meta.num_edges == ref_meta.num_edges
    assert meta.num_shards == ref_meta.num_shards
    assert meta.intervals.dtype == ref_meta.intervals.dtype
    assert np.array_equal(meta.intervals, ref_meta.intervals)
    assert np.array_equal(meta.in_deg, ref_meta.in_deg)
    assert np.array_equal(meta.out_deg, ref_meta.out_deg)
    # the persisted metadata round-trips identically too
    disk_meta = store.read_meta()
    assert np.array_equal(disk_meta.intervals, ref_meta.intervals)
    assert np.array_equal(disk_meta.in_deg, ref_meta.in_deg)
    for s in ref_shards:
        got = store.load_shard(s.shard_id, "csr")
        assert got.v0 == s.v0 and got.v1 == s.v1
        assert got.row.dtype == s.row.dtype and got.col.dtype == s.col.dtype
        assert np.array_equal(got.row, s.row)
        assert np.array_equal(got.col, s.col)


@_property(
    lambda seed: (_seeded_graph(seed), 1 + seed % 6, seed),
    n_examples=25,
    hyp_decorators=[
        settings(max_examples=25, deadline=None,
                 suppress_health_check=[HealthCheck.too_slow]),
        given(graphs(), st.integers(1, 6), st.integers(0, 10**6)),
    ] if HAVE_HYPOTHESIS else [],
)
def test_ingest_bitwise_matches_preprocess(g, p, salt):
    """Across chunk sizes (1, tiny, > |E|), spill cadences and formats."""
    cases = [
        (1, 64, "bin"),  # chunk=1: one edge per read, spill every 8 edges
        (7, 256, "text"),
        (g.num_edges + 5, 1 << 30, "bin"),  # chunk > |E|: single-chunk, no spill
        (max(1, g.num_edges // 3), 512, "bin"),
    ]
    chunk, budget, fmt = cases[salt % len(cases)]
    with tempfile.TemporaryDirectory() as d:
        store, meta, stats = _ingest_into(
            d, g, fmt=fmt, chunk_edges=chunk, mem_budget_bytes=budget,
            num_shards=p,
        )
        _assert_bitwise_equal(store, meta, g, num_shards=p)
        if chunk > g.num_edges:
            assert stats.runs == 0  # everything fit: no spill I/O at all


@_property(
    lambda seed: (_seeded_graph(100 + seed), 4 + (seed * 13) % 60),
    n_examples=15,
    hyp_decorators=[
        settings(max_examples=15, deadline=None,
                 suppress_health_check=[HealthCheck.too_slow]),
        given(graphs(), st.integers(4, 64)),
    ] if HAVE_HYPOTHESIS else [],
)
def test_ingest_edges_per_shard_matches_preprocess(g, eps):
    """The edges_per_shard partitioning path, small chunks + forced spills."""
    with tempfile.TemporaryDirectory() as d:
        store, meta, _ = _ingest_into(
            d, g, fmt="bin", chunk_edges=11, mem_budget_bytes=128,
            edges_per_shard=eps,
        )
        _assert_bitwise_equal(store, meta, g, edges_per_shard=eps)


def test_ingest_empty_graph_and_empty_shards():
    # zero edges, nonzero vertices
    g = Graph(20, np.array([], np.int32), np.array([], np.int32))
    with tempfile.TemporaryDirectory() as d:
        store, meta, stats = _ingest_into(
            d, g, fmt="bin", chunk_edges=4, mem_budget_bytes=64, num_shards=2
        )
        _assert_bitwise_equal(store, meta, g, num_shards=2)
        assert stats.num_edges == 0 and stats.runs == 0
    # isolated vertices: every edge lands on one vertex, the other shards'
    # intervals hold only zero-in-degree vertices (empty shards)
    g = star_graph(50)
    with tempfile.TemporaryDirectory() as d:
        store, meta, _ = _ingest_into(
            d, g, fmt="text", chunk_edges=3, mem_budget_bytes=64, num_shards=4
        )
        _assert_bitwise_equal(store, meta, g, num_shards=4)
    # a trailing block of vertices no edge ever touches
    g = Graph(
        40,
        np.array([0, 1, 2, 3], np.int32),
        np.array([5, 5, 6, 0], np.int32),
    )
    with tempfile.TemporaryDirectory() as d:
        store, meta, _ = _ingest_into(
            d, g, fmt="bin", chunk_edges=2, mem_budget_bytes=32, num_shards=4
        )
        _assert_bitwise_equal(store, meta, g, num_shards=4)


def test_ingest_infers_num_vertices():
    g = _seeded_graph(7)
    n_used = int(max(g.src.max(), g.dst.max())) + 1
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "e.bin")
        write_edge_file(path, g.src, g.dst)
        store = ShardStore(os.path.join(d, "store"))
        meta, _ = store.ingest(path, num_shards=3, chunk_edges=17,
                               mem_budget_bytes=256, window=64, k=8, tr=4)
        assert meta.num_vertices == n_used
        g_trim = Graph(n_used, g.src, g.dst)
        _assert_bitwise_equal(store, meta, g_trim, num_shards=3)


def test_ingest_rejects_out_of_range_ids():
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "e.bin")
        write_edge_file(path, np.array([0, 5], np.int32), np.array([1, 2], np.int32))
        store = ShardStore(os.path.join(d, "store"))
        with pytest.raises(ValueError, match="out of range"):
            store.ingest(path, num_shards=2, num_vertices=4)


def test_invalid_arguments_fail_fast():
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "e.bin")
        with pytest.raises(ValueError, match="chunk_edges"):
            write_edge_file(path, np.array([0], np.int32),
                            np.array([1], np.int32), chunk_edges=0)
        write_edge_file(path, np.array([0], np.int32), np.array([1], np.int32))
        store = ShardStore(os.path.join(d, "store"))
        # exactly-one partitioning arg, checked before any file I/O
        with pytest.raises(ValueError, match="exactly one"):
            store.ingest(path)
        with pytest.raises(ValueError, match="exactly one"):
            store.ingest(path, num_shards=2, edges_per_shard=10)


def test_ingest_removes_orphaned_spill_runs():
    g = _seeded_graph(21)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "e.bin")
        write_edge_file(path, g.src, g.dst)
        store = ShardStore(os.path.join(d, "store"))
        # scratch left behind by a hypothetical crashed previous ingest
        store.write_bytes("ingest_run_00007_00003.bin", b"\x00" * 64)
        meta, stats = store.ingest(path, num_shards=2,
                                   num_vertices=g.num_vertices,
                                   window=64, k=8, tr=4)
        assert stats.orphan_runs_removed == 1
        assert not store.exists("ingest_run_00007_00003.bin")
        _assert_bitwise_equal(store, meta, g, num_shards=2)


def test_text_format_comments_and_blank_lines():
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "e.txt")
        with open(path, "w") as f:
            f.write("# a SNAP-style header\n\n0 1\n1 2   # trailing comment\n\n2 0\n")
        chunks = list(iter_edge_chunks(path, chunk_edges=2))
        src = np.concatenate([c[0] for c in chunks])
        dst = np.concatenate([c[1] for c in chunks])
        assert src.tolist() == [0, 1, 2]
        assert dst.tolist() == [1, 2, 0]
        assert all(len(c[0]) <= 2 for c in chunks)


def test_kway_merge_is_sorted_union():
    rng = np.random.default_rng(0)
    runs = [np.sort(rng.integers(0, 1000, size=rng.integers(0, 50)))
            for _ in range(9)] + [np.empty(0, np.int64)]
    merged = kway_merge([r.astype(np.int64) for r in runs])
    ref = np.sort(np.concatenate(runs)).astype(np.int64)
    assert np.array_equal(merged, ref)
    assert len(kway_merge([])) == 0


# --------------------------------------------------------------------------
# I/O accounting (satellite: spill + final shard bytes identity)
# --------------------------------------------------------------------------


def test_iostats_accounts_every_ingest_byte():
    """On a fresh store, bytes_written == spill runs + final shards + meta,
    and bytes_read == the spill bytes merged back."""
    g = rmat_graph(300, 5000, seed=9)
    with tempfile.TemporaryDirectory() as d:
        store, meta, stats = _ingest_into(
            d, g, fmt="bin", chunk_edges=64, mem_budget_bytes=1024,
            num_shards=5,
        )
        assert stats.spills > 0 and stats.runs > 0  # the cadence forced spills
        assert stats.spill_bytes_written > 0
        assert stats.shard_bytes_written > 0
        assert stats.meta_bytes_written > 0
        assert store.io.bytes_written == (
            stats.spill_bytes_written
            + stats.shard_bytes_written
            + stats.meta_bytes_written
        )
        # every spilled byte is read back exactly once by the merge
        assert stats.spill_bytes_read == stats.spill_bytes_written
        assert store.io.bytes_read == stats.spill_bytes_read
        # spill runs are scratch: none survive in the store directory
        leftovers = [f for f in os.listdir(store.root) if f.startswith("ingest_run_")]
        assert leftovers == []
        # spilled keys are 8 bytes per edge, each edge spilled at most once
        assert stats.spill_bytes_written <= 8 * g.num_edges


def test_ingest_no_spill_when_budget_fits():
    g = rmat_graph(200, 1000, seed=10)
    with tempfile.TemporaryDirectory() as d:
        store, _, stats = _ingest_into(
            d, g, fmt="bin", chunk_edges=10**6, mem_budget_bytes=1 << 30,
            num_shards=3,
        )
        assert stats.spill_bytes_written == 0 and stats.runs == 0
        assert store.io.bytes_written == (
            stats.shard_bytes_written + stats.meta_bytes_written
        )


# --------------------------------------------------------------------------
# Bounded memory (the SEM premise, measured)
# --------------------------------------------------------------------------


_MEM_V = 50_000
_MEM_CHUNK = 20_000
_MEM_BUDGET = 512 << 10  # 512 KiB of buffered spill keys
_MEM_EPS = 60_000  # edges per shard


def _traced_ingest_peak(num_e, seed):
    """Tracemalloc peak of one full streamed ingest of an RMAT graph."""
    g = rmat_graph(_MEM_V, num_e, seed=seed)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "e.bin")
        write_edge_file(path, g.src, g.dst)
        store = ShardStore(os.path.join(d, "store"))
        del g
        gc.collect()
        tracemalloc.start()
        try:
            tracemalloc.reset_peak()
            meta, stats = store.ingest(
                path,
                edges_per_shard=_MEM_EPS,
                num_vertices=_MEM_V,
                chunk_edges=_MEM_CHUNK,
                mem_budget_bytes=_MEM_BUDGET,
                window=256, k=16, tr=8,
            )
            peak = tracemalloc.get_traced_memory()[1]
        finally:
            tracemalloc.stop()
    assert meta.num_edges == num_e
    return peak, stats


def test_ingest_memory_bounded_as_edges_scale():
    """Peak traced allocation must stay O(chunk + budget + one shard) —
    flat as |E| scales 4x past the chunk/budget — the O(|E|) regression
    guard (also run under a hard RLIMIT_AS cap by tests/run_memcapped.py).

    The per-shard constant is dominated by the CSR->ELL conversion's
    working set (~100 B/edge of one shard, transient); with a fixed
    edges_per_shard target that term is independent of |E|.
    """
    small_e, big_e = 600_000, 2_400_000
    peak_small, stats_small = _traced_ingest_peak(small_e, seed=11)
    peak_big, stats_big = _traced_ingest_peak(big_e, seed=12)
    # the budget genuinely forced external spilling at both sizes
    assert stats_small.spills > 1 and stats_big.spills > 4
    assert stats_big.runs > stats_small.runs
    # bookkept scatter-buffer high-water respects budget + one chunk
    for stats in (stats_small, stats_big):
        assert stats.peak_buffered_bytes <= _MEM_BUDGET + 8 * _MEM_CHUNK
    # O(|E|) independence: 4x the edges must not move the peak materially
    assert peak_big < 1.6 * peak_small, (
        f"peak grew with |E|: {peak_small} -> {peak_big} (x4 edges)"
    )
    # absolute sanity: far below even the bare src/dst int64 edge arrays
    assert peak_big < (2 * 8 * big_e) / 2, (
        f"peak {peak_big} not meaningfully below O(|E|) materialization"
    )


# --------------------------------------------------------------------------
# Overwrite invalidation (satellite fix + re-ingest regression)
# --------------------------------------------------------------------------


def test_write_shard_overwrite_invalidates_registered_caches():
    g1 = rmat_graph(100, 600, seed=12)
    g2 = rmat_graph(100, 600, seed=13)
    meta1, shards1 = preprocess(g1, num_shards=2)
    meta2, shards2 = preprocess(g2, num_shards=2)
    with tempfile.TemporaryDirectory() as d:
        store = ShardStore(d)
        cache = ShardCache(1 << 20)
        seen = []
        store.register_invalidation(lambda p: (cache.invalidate(p), seen.append(p)))
        for s in shards1:
            store.write_shard(s, num_vertices=100, window=64, k=8, tr=4)
        assert seen == []  # fresh writes are not overwrites
        cache.put(0, store.shard_bytes(0, "csr"))
        store.write_shard(shards2[0], num_vertices=100, window=64, k=8, tr=4)
        assert seen == [0]  # the hook fired for the replaced id only
        assert cache.get(0) is None  # stale bytes are gone (counts a miss)
        fresh = store.load_shard(0, "csr")
        assert np.array_equal(fresh.col, shards2[0].col)


def test_pipeline_discards_bytes_read_before_concurrent_overwrite():
    """The read->invalidate->put race: a loader that read the OLD shard
    bytes just before an overwrite must not re-cache them after the
    overwrite's invalidation hook already ran (generation guard)."""
    from repro.core.pipeline import ShardPipeline

    g1 = rmat_graph(100, 600, seed=19)
    g2 = rmat_graph(100, 600, seed=20)
    _, shards1 = preprocess(g1, num_shards=2)
    _, shards2 = preprocess(g2, num_shards=2)
    with tempfile.TemporaryDirectory() as d:
        store = ShardStore(d)
        for s in shards1:
            store.write_shard(s, num_vertices=100, window=64, k=8, tr=4)
        cache = ShardCache(1 << 20)
        resident = {}
        store.register_invalidation(
            lambda p: (cache.invalidate(p), resident.pop(p, None))
        )
        pipe = ShardPipeline(store, "csr", cache=cache, depth=0,
                             resident=resident)

        orig_read = store.shard_bytes

        def read_then_lose_race(p, fmt="csr"):
            raw = orig_read(p, fmt)
            # the overwrite (and its invalidation) lands AFTER our read
            # completed but BEFORE our cache/resident inserts
            store.shard_bytes = orig_read
            store.write_shard(shards2[p], num_vertices=100, window=64,
                              k=8, tr=4)
            return raw

        store.shard_bytes = read_then_lose_race
        ls = pipe.load(0)
        # this load legitimately observed the pre-overwrite shard ...
        assert np.array_equal(ls.csr.col, shards1[0].col)
        # ... but neither cache nor resident map may retain it
        cached = cache.get(0)
        if cached is not None:
            assert np.array_equal(
                ShardStore.decode_csr(0, cached).col, shards2[0].col
            )
        assert 0 not in resident
        # the next load must see the replacement
        assert np.array_equal(pipe.load(0).csr.col, shards2[0].col)


def test_shard_cache_invalidate_releases_bytes():
    cache = ShardCache(1 << 16)
    cache.put(3, b"x" * 100)
    before = cache.stored_bytes
    assert cache.invalidate(3) is True
    assert cache.stored_bytes == before - 100
    assert cache.invalidate(3) is False  # idempotent on absent ids
    assert len(cache) == 0


def test_reingest_into_existing_dir_e2e():
    """Re-ingesting a different graph into a live store must drop stale
    cached decodes AND stale extra shard files, and the engine must then
    compute the new graph's answer (regression for the overwrite path)."""
    from repro.core import apps
    from repro.core.vsw import VSWEngine

    g1 = rmat_graph(300, 3000, seed=14)  # 6 shards
    g2 = rmat_graph(250, 1200, seed=15)  # fewer shards after re-ingest
    with tempfile.TemporaryDirectory() as d:
        root = os.path.join(d, "store")
        p1 = os.path.join(d, "g1.bin")
        p2 = os.path.join(d, "g2.bin")
        write_edge_file(p1, g1.src, g1.dst)
        write_edge_file(p2, g2.src, g2.dst)
        store = ShardStore(root)
        meta1, _ = store.ingest(p1, num_shards=6, num_vertices=g1.num_vertices,
                                chunk_edges=128, mem_budget_bytes=2048,
                                window=64, k=8, tr=4)
        eng = VSWEngine(store, backend="numpy", cache_bytes=1 << 20,
                        selective=False)
        eng.run(apps.pagerank(), max_iters=3)  # warm the byte cache
        assert len(eng.cache) > 0
        meta2, stats = store.ingest(p2, num_shards=3,
                                    num_vertices=g2.num_vertices,
                                    chunk_edges=128, mem_budget_bytes=2048,
                                    window=64, k=8, tr=4)
        assert stats.stale_shards_removed == meta1.num_shards - meta2.num_shards
        # no shard files beyond the new count survive
        for p in range(meta2.num_shards, meta1.num_shards):
            assert not store.exists(store.shard_name(p, "csr"))
            assert not store.exists(store.shard_name(p, "ell"))
        # the old engine's cached decodes for overwritten ids are gone;
        # a fresh engine on the same dir computes the new graph's oracle
        eng.close()
        eng2 = VSWEngine.from_store(root, backend="numpy", cache_bytes=1 << 20,
                                    selective=False)
        got = eng2.run(apps.pagerank(), max_iters=5)
        ref_eng = VSWEngine.from_graph(g2, os.path.join(d, "ref"),
                                       num_shards=3, window=64, k=8,
                                       selective=False)
        ref = ref_eng.run(apps.pagerank(), max_iters=5)
        assert np.array_equal(got.values, ref.values)
        eng2.close()
        ref_eng.close()


def test_engine_collectable_without_close_e2e():
    """The store's invalidation hook must not pin a dropped engine (and
    its caches) alive — the re-ingest workflow hands one long-lived store
    to a succession of engines."""
    import weakref

    from repro.core.vsw import VSWEngine

    g = rmat_graph(100, 600, seed=22)
    meta, shards = preprocess(g, num_shards=2)
    with tempfile.TemporaryDirectory() as d:
        store = ShardStore(d)
        store.write_meta(meta)
        for s in shards:
            store.write_shard(s, num_vertices=100, window=64, k=8, tr=4)
        eng = VSWEngine(store, backend="numpy", cache_bytes=1 << 16)
        ref = weakref.ref(eng)
        del eng  # no close(): GC alone must reclaim it
        gc.collect()
        assert ref() is None
        assert store._invalidation_hooks == []  # finalizer unregistered it


# --------------------------------------------------------------------------
# SessionCache across bump_graph_version (satellite)
# --------------------------------------------------------------------------


def test_session_cache_stale_version_misses_e2e():
    from repro.serve import GraphService

    g = rmat_graph(200, 1500, seed=16)
    with tempfile.TemporaryDirectory() as d:
        with GraphService.from_graph(
            g, d, num_shards=3, window=64, k=8, max_lanes=4,
            session_entries=32,
        ) as svc:
            r1 = svc.query("bfs", 5, max_iters=30)
            assert not r1.cached
            r2 = svc.query("bfs", 5, max_iters=30)
            assert r2.cached  # same version: served from the session cache
            assert np.array_equal(r1.values, r2.values)
            misses_before = svc.sessions.misses
            svc.bump_graph_version()
            r3 = svc.query("bfs", 5, max_iters=30)
            assert not r3.cached  # stale-version entry must MISS
            assert svc.sessions.misses > misses_before
            assert np.array_equal(r3.values, r1.values)  # graph unchanged
            r4 = svc.query("bfs", 5, max_iters=30)
            assert r4.cached  # re-cached under the new version key


def test_session_cache_version_keys_unit():
    from repro.serve import SessionCache

    c = SessionCache(capacity=8)
    c.put(("bfs", 5, 0), "v0-result")
    assert c.get(("bfs", 5, 0)) == "v0-result"
    assert c.get(("bfs", 5, 1)) is None  # bumped version: different key
    assert c.hits == 1 and c.misses == 1
    c.put(("bfs", 5, 1), "v1-result")
    assert c.get(("bfs", 5, 1)) == "v1-result"
    # predicate-rejected entries count as misses and are not refreshed
    assert c.get(("bfs", 5, 1), lambda v: False) is None
    assert c.misses == 2


# --------------------------------------------------------------------------
# End-to-end: engines and the service boot from an ingested dir
# --------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["numpy", "jnp", "pallas"])
def test_engine_from_ingested_store_matches_in_memory_e2e(backend):
    from repro.core import apps
    from repro.core.vsw import VSWEngine

    g = rmat_graph(200, 1500, seed=17)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "e.bin")
        write_edge_file(path, g.src, g.dst)
        mem = VSWEngine.from_graph(g, os.path.join(d, "mem"), num_shards=3,
                                   window=64, k=8, backend=backend)
        ing = VSWEngine.from_edge_file(
            path, os.path.join(d, "ing"), num_shards=3,
            num_vertices=g.num_vertices, chunk_edges=100,
            mem_budget_bytes=1024, window=64, k=8, backend=backend,
        )
        for prog, iters in ((apps.pagerank(), 8), (apps.bfs(0), 30)):
            rm = mem.run(prog, max_iters=iters)
            rs = ing.run(prog, max_iters=iters)
            assert np.array_equal(rm.values, rs.values)
            assert rm.converged == rs.converged
        mem.close()
        ing.close()


def test_service_from_ingested_store_matches_in_memory_e2e():
    from repro.serve import GraphService

    g = rmat_graph(250, 2000, seed=18)
    sources = [0, 7, 42]
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "e.bin")
        write_edge_file(path, g.src, g.dst)
        with GraphService.from_graph(
            g, os.path.join(d, "mem"), num_shards=4, window=64, k=8,
            max_lanes=4, session_entries=0,
        ) as svc_mem:
            ref = {
                (prog, s): svc_mem.query(prog, s, max_iters=40).values
                for prog in ("bfs", "ppr") for s in sources
            }
        with GraphService.from_edge_file(
            path, os.path.join(d, "ing"), num_shards=4,
            num_vertices=g.num_vertices, chunk_edges=128,
            mem_budget_bytes=2048, window=64, k=8,
            max_lanes=4, session_entries=0,
        ) as svc_ing:
            for (prog, s), want in ref.items():
                got = svc_ing.query(prog, s, max_iters=40).values
                assert np.array_equal(got, want), (prog, s)
