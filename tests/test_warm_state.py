"""Warm-restart checkpoint tests (DESIGN.md §12).

The contract: a warm boot is a pure TIME optimisation — the store on disk
is always authoritative, and every query served by a warm-booted service
is bitwise what a cold-booted one returns.  The snapshot may be stale,
partially stale, corrupt, or describe a different store entirely; the
worst legal outcome is a cold boot.
"""

import os

import numpy as np
import pytest

from repro.checkpoint.warm_state import (
    WarmStateCheckpointer,
    apply_warm_state,
    capture_warm_state,
)
from repro.core.graph import rmat_graph
from repro.core.storage import ShardStore
from repro.serve import GraphService

N, M, SHARDS = 400, 5000, 4


def _mk_service(tmp_path, tag, g=None, **kw):
    g = g if g is not None else rmat_graph(N, M, seed=9)
    kw.setdefault("num_shards", SHARDS)
    kw.setdefault("window", 128)
    kw.setdefault("k", 16)
    return GraphService.from_graph(g, str(tmp_path / tag), **kw)


# ------------------------------------------------------------ checkpointer
def test_checkpointer_roundtrip_retention_and_integrity(tmp_path):
    svc = _mk_service(tmp_path, "ck", cache_bytes=1 << 20)
    svc.query("bfs", 3)
    ws = capture_warm_state(svc)
    ck = WarmStateCheckpointer(str(tmp_path / "warm"), keep=2)
    for _ in range(3):  # retention: only ``keep`` newest survive
        ck.save(ws)
    assert ck.steps() == [1, 2]
    got = ck.restore()
    assert got.store_version == ws.store_version
    assert got.graph_version == ws.graph_version
    assert np.array_equal(got.intervals, ws.intervals)
    assert got.floors == ws.floors
    assert got.shard_sizes == ws.shard_sizes
    assert got.cache_shards == ws.cache_shards
    assert set(got.bloom_sources) == set(ws.bloom_sources)
    for p in ws.bloom_sources:
        assert np.array_equal(got.bloom_sources[p], ws.bloom_sources[p])
    assert len(got.sessions) == len(ws.sessions)
    for a, b in zip(got.sessions, ws.sessions):
        assert (a.program, a.key, a.source) == (b.program, b.key, b.source)
        assert np.array_equal(a.values, b.values)
    svc.close()

    # integrity: a flipped byte in the payload is detected, not trusted
    step_dir = ck._dir(2)
    with open(os.path.join(step_dir, "state.npz"), "r+b") as f:
        f.seek(10)
        byte = f.read(1)
        f.seek(10)
        f.write(bytes([byte[0] ^ 0xFF]))
    with pytest.raises(IOError, match="corrupt"):
        ck.restore(2)


def test_restore_empty_directory_raises(tmp_path):
    ck = WarmStateCheckpointer(str(tmp_path / "none"))
    assert ck.latest_step() is None
    with pytest.raises(FileNotFoundError):
        ck.restore()


# -------------------------------------------------------------- warm boot
def test_warm_boot_skips_reads_and_is_bitwise_cold(tmp_path):
    g = rmat_graph(N, M, seed=9)
    svc = _mk_service(tmp_path, "wb", g, cache_bytes=1 << 20)
    root = svc.engine.store.root
    svc.apply_updates(inserts=(np.array([1, 2]), np.array([3, 4]))).result()
    r_bfs = svc.query("bfs", 5)
    ckdir = svc.save_warm_state(str(tmp_path / "warm"))
    svc.close()

    warm = GraphService.from_store(root, warm_state=str(tmp_path / "warm"),
                                   cache_bytes=1 << 20)
    rep = warm.warm_restore_report
    assert rep["valid"] and rep["shards_warm"] == SHARDS
    assert rep["sessions_valid"] and rep["sessions_restored"] >= 1
    # the whole point: filter build read NOTHING at boot
    assert warm.engine.loading_io.reads == 0
    assert warm.engine.loading_io.bytes_read == 0
    assert os.path.basename(ckdir).startswith("warm_")

    cold = GraphService.from_store(root, cache_bytes=1 << 20)
    assert cold.engine.loading_io.reads > 0

    # session-cache restoration: the repeat query hits without a sweep
    hit = warm.query("bfs", 5)
    assert hit.cached
    assert np.array_equal(hit.values, r_bfs.values)
    # fresh queries (never cached) are bitwise the cold service's
    for prog, src in (("bfs", 17), ("sssp", 23), ("ppr", 3)):
        a = warm.query(prog, src)
        b = cold.query(prog, src)
        assert np.array_equal(a.values, b.values), (prog, src)
    warm.close()
    cold.close()


def test_warm_boot_accepts_warmstate_object_and_prewarms_cache(tmp_path):
    svc = _mk_service(tmp_path, "obj", cache_bytes=1 << 20)
    root = svc.engine.store.root
    svc.query("bfs", 1)  # populate the byte cache via a sweep
    ws = capture_warm_state(svc)
    assert ws.cache_shards  # the sweep left shards cached
    svc.close()

    warm = GraphService.from_store(root, warm_state=ws, cache_bytes=1 << 20,
                                   prewarm_cache=True)
    rep = warm.warm_restore_report
    assert rep["cache_prewarmed"] == len(ws.cache_shards)
    assert set(warm.engine.cache.keys()) == set(ws.cache_shards)
    warm.close()


# ------------------------------------------------------------- staleness
def test_publish_after_snapshot_invalidates_touched_shards_only(tmp_path):
    svc = _mk_service(tmp_path, "stale", cache_bytes=1 << 20)
    root = svc.engine.store.root
    svc.query("bfs", 2)
    svc.save_warm_state(str(tmp_path / "warm"))
    # mutate AFTER the snapshot: one narrow insert (touches 1 shard)
    svc.apply_updates(inserts=(np.array([0]), np.array([1]))).result()
    svc.close()

    warm = GraphService.from_store(root, warm_state=str(tmp_path / "warm"))
    rep = warm.warm_restore_report
    assert rep["valid"]
    assert rep["shards_stale"] >= 1  # the published shard was rejected
    assert rep["shards_warm"] == SHARDS - rep["shards_stale"]
    assert not rep["sessions_valid"]  # content changed: no cached results
    assert rep["sessions_restored"] == 0

    cold = GraphService.from_store(root)
    a = warm.query("bfs", 2)
    b = cold.query("bfs", 2)
    assert not a.cached  # the stale session entry was NOT restored
    assert np.array_equal(a.values, b.values)
    warm.close()
    cold.close()


def test_compaction_after_snapshot_keeps_sources_valid(tmp_path):
    """Compaction rewrites bytes, not logical content: a snapshot taken
    BEFORE runs were absorbed is still fully valid afterwards — floors
    advanced only to publishes the snapshot already saw."""
    svc = _mk_service(tmp_path, "comp", cache_bytes=1 << 20)
    root = svc.engine.store.root
    svc.apply_updates(inserts=(np.array([5, 6]), np.array([7, 8]))).result()
    r = svc.query("bfs", 5)
    svc.save_warm_state(str(tmp_path / "warm"))
    svc.compact()  # absorbs runs <= snapshot version
    svc.close()

    warm = GraphService.from_store(root, warm_state=str(tmp_path / "warm"))
    rep = warm.warm_restore_report
    assert rep["valid"] and rep["shards_stale"] == 0
    assert rep["sessions_valid"]
    hit = warm.query("bfs", 5)
    assert hit.cached and np.array_equal(hit.values, r.values)
    warm.close()


def test_reingested_store_rejects_snapshot_entirely(tmp_path):
    g1 = rmat_graph(N, M, seed=9)
    g2 = rmat_graph(N, M, seed=10)  # same frame, different edges
    svc = _mk_service(tmp_path, "re", g1, cache_bytes=1 << 20)
    root = svc.engine.store.root
    svc.save_warm_state(str(tmp_path / "warm"))
    svc.close()

    # rebuild the store in place with DIFFERENT edges (same shard count —
    # only the byte sizes betray the re-ingest)
    from repro.core.sharding import preprocess

    meta, shards = preprocess(g2, num_shards=SHARDS)
    store = ShardStore(root)
    store.write_meta(meta, ell_params=store.ell_params())
    for s in shards:
        ep = store.ell_params()
        store.write_shard(s, num_vertices=meta.num_vertices,
                          window=ep["window"], k=ep["k"], tr=ep["tr"])
    ws = WarmStateCheckpointer(str(tmp_path / "warm")).restore()
    rep = apply_warm_state(store, ws)
    assert not rep["valid"]
    assert rep["shards_warm"] == 0

    # a service booted with the rejected snapshot degrades to cold — and
    # answers from the NEW graph
    warm = GraphService.from_store(root, warm_state=ws)
    assert not warm.warm_restore_report["valid"]
    cold = GraphService.from_store(root)
    assert np.array_equal(warm.query("bfs", 4).values,
                          cold.query("bfs", 4).values)
    warm.close()
    cold.close()


def test_wiped_delta_history_rejects_snapshot(tmp_path):
    """A snapshot taken at version > 0 against a store whose delta history
    was wiped (version rolled back) is rejected wholesale."""
    svc = _mk_service(tmp_path, "wipe", cache_bytes=1 << 20)
    root = svc.engine.store.root
    svc.apply_updates(inserts=(np.array([1]), np.array([2]))).result()
    svc.compact()
    svc.save_warm_state(str(tmp_path / "warm"))
    svc.close()

    # wipe the delta manifest: the store recovers to version 0
    os.remove(os.path.join(root, "delta_manifest.json"))
    store = ShardStore(root)
    ws = WarmStateCheckpointer(str(tmp_path / "warm")).restore()
    rep = apply_warm_state(store, ws)
    assert not rep["valid"] and "behind snapshot" in rep["reason"]
    assert rep["shards_warm"] == 0 and not rep["sessions_valid"]
