"""Unit tests: graph container, sharding, CSR, blocked-ELL conversion."""

import numpy as np
import pytest

from repro.core.csr import csr_to_ell
from repro.core.graph import (
    Graph,
    chain_graph,
    from_edge_list,
    rmat_graph,
    star_graph,
    uniform_graph,
)
from repro.core.sharding import compute_intervals, preprocess


def test_graph_basic():
    g = from_edge_list([(0, 1), (1, 2), (2, 0), (0, 2)])
    assert g.num_vertices == 3 and g.num_edges == 4
    assert g.out_degrees().tolist() == [2, 1, 1]
    assert g.in_degrees().tolist() == [1, 1, 2]
    g.validate()


def test_graph_validate_rejects_out_of_range():
    with pytest.raises(ValueError):
        Graph(2, np.array([0, 5]), np.array([1, 0])).validate()


def test_generators_shapes():
    for g in (
        rmat_graph(100, 1000, seed=1),
        uniform_graph(100, 1000, seed=1),
        chain_graph(50),
        star_graph(50),
    ):
        g.validate()
        assert g.num_edges > 0


def test_rmat_is_skewed():
    g = rmat_graph(1 << 12, 1 << 16, seed=0)
    ind = g.in_degrees()
    # power-law-ish: max degree far above average
    assert ind.max() > 10 * g.avg_degree


def test_intervals_balance_edges():
    g = rmat_graph(2000, 50000, seed=2)
    ind = g.in_degrees()
    iv = compute_intervals(ind, num_shards=8)
    per = [ind[iv[p] : iv[p + 1]].sum() for p in range(len(iv) - 1)]
    assert sum(per) == g.num_edges
    # Every shard within 3x of the mean (power-law hubs can exceed target).
    mean = g.num_edges / (len(iv) - 1)
    assert max(per) < 3 * mean


def test_intervals_edge_cases():
    ind = np.zeros(10, dtype=np.int64)
    iv = compute_intervals(ind, num_shards=3)
    assert iv[0] == 0 and iv[-1] == 10
    iv = compute_intervals(np.array([5, 0, 0], dtype=np.int64), edges_per_shard=2)
    assert iv[0] == 0 and iv[-1] == 3


def test_preprocess_partitions_every_edge():
    g = rmat_graph(500, 8000, seed=3)
    meta, shards = preprocess(g, num_shards=6)
    assert sum(s.nnz for s in shards) == g.num_edges
    assert meta.intervals[0] == 0 and meta.intervals[-1] == g.num_vertices
    # CSR adjacency matches brute force on sampled vertices
    for s in shards[::2]:
        for v in range(s.v0, min(s.v0 + 4, s.v1)):
            ref = np.sort(g.src[g.dst == v])
            assert np.array_equal(np.sort(s.in_neighbors(v)), ref)


@pytest.mark.parametrize("window,k,tr", [(64, 8, 8), (256, 16, 8), (1 << 14, 128, 8)])
def test_ell_roundtrip_exact_multiset(window, k, tr):
    g = rmat_graph(300, 4000, seed=4)
    meta, shards = preprocess(g, num_shards=4)
    for s in shards:
        e = csr_to_ell(s, g.num_vertices, window=window, k=k, tr=tr)
        assert int(e.ell_mask.sum()) == s.nnz
        gi = e.global_idx()
        rows_idx, cols_idx = np.nonzero(e.ell_mask)
        srcs = gi[rows_idx, cols_idx]
        dsts = e.seg[rows_idx] + e.v0
        got = np.sort(srcs.astype(np.int64) * g.num_vertices + dsts)
        m = (g.dst >= s.v0) & (g.dst < s.v1)
        ref = np.sort(g.src[m].astype(np.int64) * g.num_vertices + g.dst[m])
        assert np.array_equal(got, ref)
        # tiles never straddle windows
        assert e.n_ell % tr == 0 and e.n_tiles == e.n_ell // tr


def test_ell_empty_shard():
    g = from_edge_list([(0, 1)], num_vertices=10)
    meta, shards = preprocess(g, num_shards=3)
    for s in shards:
        e = csr_to_ell(s, 10, window=8, k=4, tr=8)
        assert e.n_ell % e.tr == 0
        assert int(e.ell_mask.sum()) == s.nnz


def test_ell_int16_window_bound():
    g = rmat_graph(200, 1000, seed=5)
    meta, shards = preprocess(g, num_shards=2)
    e = csr_to_ell(shards[0], 200, window=1 << 15, k=16, tr=8)
    assert e.ell_idx.dtype == np.int16
    e2 = csr_to_ell(shards[0], 200, window=1 << 16, k=16, tr=8)
    assert e2.ell_idx.dtype == np.int32


def test_ell_high_degree_row_splitting():
    g = star_graph(1000)  # vertex 0 has in-degree 999
    meta, shards = preprocess(g, num_shards=1)
    e = csr_to_ell(shards[0], 1000, window=128, k=8, tr=8)
    # row splitting must produce ceil-per-window rows, all mapping to seg 0
    assert (e.seg[e.ell_mask.any(axis=1)] == 0).all()
    assert int(e.ell_mask.sum()) == 999
