"""GraphServe: lane-batched sweeps must be invisible in the results.

Every lane of a concurrent sweep must be bitwise-equal to the same query
run alone on a single-query engine — across programs (BFS / SSSP / PPR),
backends, shard batching, lane retirement and mid-flight backfill — and
the service must survive concurrent submission.
"""

import tempfile
import threading
import time

import numpy as np
import pytest

from repro.core import apps
from repro.core.executor import (
    make_lane_executor,
    update_shard_numpy,
    update_shard_numpy_lanes,
    update_shard_jnp,
    update_shard_jnp_lanes,
)
from repro.core.graph import chain_graph, rmat_graph
from repro.core.sharding import preprocess
from repro.core.vsw import VSWEngine
from repro.serve import (
    GraphService,
    LaneBatcher,
    LaneSeed,
    LaneSweep,
    ServiceOverloaded,
    SessionCache,
    pad_lanes,
)

PROGRAMS = [("bfs", 0), ("bfs", 7), ("sssp", 3), ("ppr", 5), ("ppr", 11)]


def _norm(v):
    return np.nan_to_num(v, posinf=1e30)


def _mk_service(tmp_path, tag, g, **kw):
    kw.setdefault("num_shards", 6)
    kw.setdefault("window", 128)
    kw.setdefault("k", 16)
    return GraphService.from_graph(g, str(tmp_path / tag), **kw)


def _mk_engine(tmp_path, tag, g, **kw):
    kw.setdefault("num_shards", 6)
    kw.setdefault("window", 128)
    kw.setdefault("k", 16)
    return VSWEngine.from_graph(g, str(tmp_path / tag), **kw)


# --------------------------------------------------- per-shard lane backends
def test_lane_backend_rows_are_bitwise_single_lane():
    g = rmat_graph(300, 4000, seed=40)
    meta, shards = preprocess(g, num_shards=3)
    rng = np.random.default_rng(1)
    msgs = rng.random((4, meta.num_vertices)).astype(np.float32)
    from repro.core.csr import csr_to_ell

    for combine in ("sum", "min", "max"):
        for s in shards:
            lanes_np = update_shard_numpy_lanes(s, None, msgs, combine)
            ell = csr_to_ell(s, meta.num_vertices, window=64, k=8, tr=8)
            lanes_jnp = update_shard_jnp_lanes(s, ell, msgs, combine)
            for l in range(4):
                assert np.array_equal(
                    lanes_np[l], update_shard_numpy(s, None, msgs[l], combine)
                )
                assert np.array_equal(
                    lanes_jnp[l], update_shard_jnp(s, ell, msgs[l], combine)
                )


def test_make_lane_executor_selection():
    from repro.core.executor import BatchedEllExecutor, PerShardExecutor

    assert isinstance(make_lane_executor("numpy", batch_shards=4),
                      PerShardExecutor)
    ex = make_lane_executor("pallas", batch_shards=2)
    assert isinstance(ex, BatchedEllExecutor) and ex.lanes
    with pytest.raises(ValueError):
        make_lane_executor("nope")


# ----------------------------------------------- bitwise oracle equivalence
def test_lane_sweep_bitwise_equals_oracle_every_program(tmp_path):
    """The headline contract: K concurrent lanes == K independent
    single-query numpy-oracle runs, bitwise, for every program."""
    g = rmat_graph(500, 6000, seed=41)
    svc = _mk_service(tmp_path, "svc", g, backend="numpy", max_lanes=8)
    eng = _mk_engine(tmp_path, "eng", g, backend="numpy")
    futs = [svc.submit(p, s, max_iters=25) for p, s in PROGRAMS]
    for (p, s), f in zip(PROGRAMS, futs):
        qr = f.result(timeout=120)
        ref = eng.run(apps.get_program(p, source=s), max_iters=25)
        assert np.array_equal(_norm(qr.values), _norm(ref.values)), (p, s)
        assert qr.iterations == ref.num_iterations
        assert qr.converged == ref.converged
    svc.close()
    eng.close()


@pytest.mark.parametrize("backend,batch_shards", [("jnp", 1), ("pallas", 3)])
def test_lane_sweep_bitwise_matches_single_backend(tmp_path, backend,
                                                   batch_shards):
    """Lane + shard batching must also be invisible on the ELL backends:
    each lane equals the same backend's single-query run bitwise."""
    g = rmat_graph(300, 3500, seed=42)
    svc = _mk_service(tmp_path, f"s{backend}", g, num_shards=5,
                      backend=backend, max_lanes=4, batch_shards=batch_shards)
    eng = _mk_engine(tmp_path, f"e{backend}", g, num_shards=5,
                     backend=backend, batch_shards=batch_shards)
    cases = [("sssp", 2), ("ppr", 3), ("bfs", 0)]
    futs = [svc.submit(p, s, max_iters=12) for p, s in cases]
    for (p, s), f in zip(cases, futs):
        qr = f.result(timeout=240)
        ref = eng.run(apps.get_program(p, source=s), max_iters=12)
        assert np.array_equal(_norm(qr.values), _norm(ref.values)), (p, s)
    svc.close()
    eng.close()


# ------------------------------------------------- retirement and backfill
def test_lane_retirement_and_backfill_mid_flight(tmp_path):
    """Lanes converge at different iterations; freed slots are refilled
    mid-sweep and every result still matches its solo oracle run."""
    n = 64
    g = chain_graph(n)
    eng = _mk_engine(tmp_path, "chain", g, num_shards=4, backend="numpy")
    prog = apps.lane_bfs()
    # sources near the chain end converge fast, source 0 is the long tail
    queue = [LaneSeed(source=s, max_iters=200, token=s) for s in (40, 0)]

    def backfill(n_free):
        out = queue[:n_free]
        del queue[:n_free]
        return out

    sweep = LaneSweep(eng, prog)
    results = sweep.run(
        [LaneSeed(source=60, max_iters=200, token=60),
         LaneSeed(source=55, max_iters=200, token=55)],
        backfill=backfill,
    )
    assert sorted(r.token for r in results) == [0, 40, 55, 60]
    assert sum(s.backfilled for s in sweep.iter_stats) == 2
    assert sum(s.retired for s in sweep.iter_stats) == 4
    # retirement is strictly before the sweep's end for the fast lanes
    assert any(s.retired and s.live_lanes > 1 for s in sweep.iter_stats)
    for r in results:
        ref = eng.run(apps.bfs(source=r.token), max_iters=200)
        assert np.array_equal(_norm(r.values), _norm(ref.values)), r.token
        assert r.iterations == ref.num_iterations and r.converged
    eng.close()


def test_service_backfills_within_one_sweep(tmp_path):
    """More compatible queries than lanes: early retirees make room, so one
    sweep serves them all (no second cold start)."""
    g = chain_graph(48)
    svc = _mk_service(tmp_path, "bf", g, num_shards=4, backend="numpy",
                      max_lanes=2)
    futs = [svc.submit("bfs", s, max_iters=100) for s in (44, 40, 20, 1)]
    for f in futs:
        assert f.result(timeout=120).converged
    assert svc.stats()["sweeps"] == 1
    assert svc.stats()["queries_completed"] == 4
    svc.close()


# --------------------------------------------------------------- threading
def test_multithreaded_submit_stress(tmp_path):
    g = rmat_graph(400, 5000, seed=43)
    svc = _mk_service(tmp_path, "mt", g, backend="numpy", max_lanes=8)
    eng = _mk_engine(tmp_path, "mtref", g, backend="numpy")
    refs = {
        (p, s): eng.run(apps.get_program(p, source=s), max_iters=15).values
        for p, s in PROGRAMS
    }
    errors = []

    def client(seed):
        rng = np.random.default_rng(seed)
        try:
            for _ in range(6):
                p, s = PROGRAMS[int(rng.integers(len(PROGRAMS)))]
                qr = svc.submit(p, s, max_iters=15).result(timeout=240)
                if not np.array_equal(_norm(qr.values), _norm(refs[(p, s)])):
                    errors.append((p, s))
        except Exception as e:  # pragma: no cover
            errors.append(repr(e))

    threads = [threading.Thread(target=client, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    st = svc.stats()
    assert st["queries_completed"] + st["session_hits"] == 8 * 6
    svc.close()
    eng.close()


# ------------------------------------------------- sessions, admission, etc
def test_session_cache_and_version_bump(tmp_path):
    g = rmat_graph(300, 3000, seed=44)
    svc = _mk_service(tmp_path, "sess", g, backend="numpy", max_lanes=4)
    a = svc.query("bfs", 3, max_iters=50)
    b = svc.query("bfs", 3, max_iters=50)
    assert not a.cached and b.cached
    assert np.array_equal(_norm(a.values), _norm(b.values))
    assert b.shard_loads == 0.0  # cache hits cost no I/O
    # different static params are a different session key
    c = svc.query("ppr", 3, max_iters=10, damping=0.85)
    d = svc.query("ppr", 3, max_iters=10, damping=0.5)
    assert not c.cached and not d.cached
    svc.bump_graph_version()
    e = svc.query("bfs", 3, max_iters=50)
    assert not e.cached
    assert np.array_equal(_norm(a.values), _norm(e.values))
    svc.close()


def test_zero_iteration_budget_matches_engine(tmp_path):
    """max_iters=0 parity: zero iterations, init values, not converged —
    exactly what ``VSWEngine.run(..., max_iters=0)`` returns."""
    g = rmat_graph(200, 2000, seed=49)
    svc = _mk_service(tmp_path, "zi", g, backend="numpy", max_lanes=2)
    eng = _mk_engine(tmp_path, "ziref", g, backend="numpy")
    qr = svc.query("sssp", 5, max_iters=0)
    ref = eng.run(apps.sssp(5), max_iters=0)
    assert qr.iterations == 0 and not qr.converged
    assert np.array_equal(_norm(qr.values), _norm(ref.values))
    svc.close()
    eng.close()


def test_cached_values_survive_caller_mutation(tmp_path):
    """A caller mutating its result in place must not poison later hits."""
    g = rmat_graph(200, 2000, seed=50)
    svc = _mk_service(tmp_path, "mut", g, backend="numpy", max_lanes=2)
    a = svc.query("bfs", 2, max_iters=30)
    pristine = a.values.copy()
    a.values[:] = -1.0  # caller-side in-place mutation
    b = svc.query("bfs", 2, max_iters=30)
    assert b.cached
    assert np.array_equal(_norm(b.values), _norm(pristine))
    svc.close()


def test_session_cache_predicate_counts_unsuitable_as_miss():
    cache = SessionCache(capacity=4)
    cache.put("k", 10)
    assert cache.get("k", lambda v: v > 50) is None  # present but unsuitable
    assert cache.hits == 0 and cache.misses == 1
    assert cache.get("k", lambda v: v > 5) == 10
    assert cache.hits == 1 and cache.misses == 1


def test_session_cache_lru_eviction():
    cache = SessionCache(capacity=2)
    cache.put("a", 1)
    cache.put("b", 2)
    assert cache.get("a") == 1  # refreshes recency
    cache.put("c", 3)  # evicts b
    assert cache.get("b") is None
    assert cache.get("a") == 1 and cache.get("c") == 3
    assert len(cache) == 2


def test_admission_cap_raises(tmp_path):
    g = rmat_graph(200, 2000, seed=45)
    svc = _mk_service(tmp_path, "cap", g, backend="numpy", max_lanes=2,
                      max_pending=0)
    with pytest.raises(ServiceOverloaded):
        svc.submit("bfs", 0)
    svc.close()


def test_batcher_grouping_and_padding():
    from collections import deque
    import dataclasses

    @dataclasses.dataclass
    class P:
        key: tuple
        n: int

    pending = deque([P(("bfs",), 0), P(("ppr", 0.85), 1), P(("bfs",), 2),
                     P(("ppr", 0.85), 3), P(("bfs",), 4)])
    b = LaneBatcher(max_lanes=2)
    batch = b.form(pending)
    assert [p.n for p in batch] == [0, 2]  # oldest key, FIFO, capped at 2
    assert [p.n for p in pending] == [1, 3, 4]  # others keep order
    assert b.capacity(3) == 4 and b.capacity(1) == 1
    assert [pad_lanes(n) for n in (0, 1, 2, 3, 5, 16)] == [1, 1, 2, 4, 8, 16]


def test_union_plan_is_superset_of_each_lane(tmp_path):
    """Scheduler contract: a shard is skipped only when NO lane needs it."""
    g = rmat_graph(600, 4000, seed=46)
    eng = _mk_engine(tmp_path, "union", g, num_shards=8, backend="numpy",
                     threshold=1.0)
    ids_a = np.array([3], dtype=np.int64)
    ids_b = np.array([577], dtype=np.int64)
    union = np.union1d(ids_a, ids_b)
    pa, pb, pu = (eng.scheduler.plan(i) for i in (ids_a, ids_b, union))
    assert set(pa.shards) | set(pb.shards) <= set(pu.shards)
    eng.close()


# ---------------------------------------------------------------- lifecycle
def test_close_idempotent_and_context_managers(tmp_path):
    g = rmat_graph(200, 2000, seed=47)
    with _mk_engine(tmp_path, "ctx_eng", g, backend="numpy",
                    prefetch_depth=2) as eng:
        eng.run(apps.pagerank(), max_iters=2)
    eng.close()  # second close after __exit__: must be a no-op
    eng.close()
    with _mk_service(tmp_path, "ctx_svc", g, backend="numpy",
                     max_lanes=2) as svc:
        assert svc.query("bfs", 0, max_iters=20).converged
    svc.close()
    svc.close()
    with pytest.raises(RuntimeError):
        svc.submit("bfs", 1)


def test_shard_load_amortization(tmp_path):
    """K lanes share every load: attributed loads/query drop ~K-fold for a
    dense-activity program with a fixed iteration budget."""
    g = rmat_graph(400, 6000, seed=48)
    sources = list(range(8))
    loads = {}
    for k in (1, 8):
        svc = _mk_service(tmp_path, f"amort{k}", g, backend="numpy",
                          max_lanes=k, session_entries=0)
        futs = [svc.submit("ppr", s, max_iters=4) for s in sources]
        for f in futs:
            f.result(timeout=240)
        loads[k] = svc.stats()["loads_per_query"]
        svc.close()
    assert loads[1] >= 4 * loads[8]  # acceptance floor (exact ratio: 8x)


# ------------------- satellite: close() joins in-flight background compaction
def test_close_joins_inflight_compaction(tmp_path):
    """close() must not release the engine while a background compaction
    still holds shard locks: it blocks until the recompactor's maintenance
    thread — including a compaction it is mid-way through — has fully
    exited.  Concurrent closers all observe the same guarantee."""
    from repro.delta.recovery import set_crash_hook

    g = rmat_graph(300, 4000, seed=33)
    svc = _mk_service(tmp_path, "closecomp", g, backend="numpy",
                      num_shards=4, auto_compact_runs=1)
    entered, release = threading.Event(), threading.Event()

    def hook(name):
        if name == "compact.staged":
            entered.set()
            release.wait(10)  # hold the compaction mid-swap

    set_crash_hook(hook)
    try:
        svc.apply_updates(inserts=(np.arange(20) % 300,
                                   (np.arange(20) * 7) % 300)).result()
        assert entered.wait(10), "background compaction never started"

        done = [threading.Event() for _ in range(2)]

        def closer(ev):
            svc.close()
            ev.set()

        threads = [threading.Thread(target=closer, args=(ev,)) for ev in done]
        for t in threads:
            t.start()
        time.sleep(0.2)
        # the compaction is parked inside the hook -> no closer may return
        assert not any(ev.is_set() for ev in done)
        release.set()
        for t in threads:
            t.join(10)
        assert all(ev.is_set() for ev in done)
    finally:
        set_crash_hook(None)
        release.set()
        svc.close()
    # the held compaction ran to completion before close returned
    assert svc.engine.store.delta.dirty_shards() == []
    assert svc._recompactor is None
