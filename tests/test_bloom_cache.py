"""Unit + property tests: Bloom filters and the compressed shard cache."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.bloom import BloomFilter, optimal_num_bits
from repro.core.cache import MODES, ShardCache, select_cache_mode


# ------------------------------------------------------------------- bloom
def test_bloom_no_false_negatives_basic():
    items = np.array([1, 5, 9, 100, 2**31 - 1])
    f = BloomFilter.build(items)
    assert f.contains(items).all()


@settings(max_examples=50, deadline=None)
@given(
    st.lists(st.integers(min_value=0, max_value=2**31 - 1), min_size=1, max_size=500),
    st.lists(st.integers(min_value=0, max_value=2**31 - 1), max_size=200),
)
def test_bloom_no_false_negatives_property(members, queries):
    members = np.unique(np.array(members, dtype=np.int64))
    f = BloomFilter.build(members)
    # every member must test positive
    assert f.contains(members).all()
    # any_member must be True whenever the query overlaps the member set
    q = np.array(queries, dtype=np.int64)
    if len(q) and np.isin(q, members).any():
        assert f.any_member(q)


def test_bloom_false_positive_rate_reasonable():
    rng = np.random.default_rng(0)
    members = rng.choice(10**7, size=20000, replace=False)
    f = BloomFilter.build(members, fp_rate=0.01)
    non_members = np.setdiff1d(rng.choice(10**7, size=30000), members)[:20000]
    fp = f.contains(non_members).mean()
    assert fp < 0.05  # target 0.01, generous bound
    assert f.fp_rate_estimate() < 0.05


def test_bloom_empty():
    f = BloomFilter.build(np.array([], dtype=np.int64))
    assert not f.any_member(np.array([1, 2, 3]))
    assert not f.any_member(np.array([], dtype=np.int64))


def test_optimal_bits_monotone():
    assert optimal_num_bits(1000, 0.01) > optimal_num_bits(100, 0.01)
    assert optimal_num_bits(1000, 0.001) > optimal_num_bits(1000, 0.01)
    assert optimal_num_bits(64, 0.01) % 64 == 0


def test_bloom_device_words_roundtrip():
    f = BloomFilter.build(np.arange(100))
    w = f.device_words()
    assert w.dtype == np.uint32 and w.nbytes == f.bits.nbytes


# ------------------------------------------------------------------- cache
@pytest.mark.parametrize("mode", sorted(MODES))
def test_cache_roundtrip(mode):
    c = ShardCache(1 << 20, mode=mode)
    blob = np.arange(1000, dtype=np.int32).tobytes() * 3
    assert c.put(7, blob)
    assert c.get(7) == blob
    assert c.get(8) is None
    assert c.stats.hits == 1 and c.stats.misses == 1


def test_cache_lru_eviction_respects_capacity():
    c = ShardCache(10_000, mode=1)
    blobs = {i: bytes(np.random.default_rng(i).integers(0, 255, 4000, np.uint8)) for i in range(5)}
    for i, b in blobs.items():
        c.put(i, b)
    assert c.stored_bytes <= 10_000
    assert c.stats.evictions > 0
    # most recently inserted survives
    assert c.get(4) == blobs[4]


def test_cache_compression_saves_space():
    # compressible payload
    blob = b"abcd" * 50_000
    raw = ShardCache(1 << 22, mode=1)
    zl = ShardCache(1 << 22, mode=3)
    raw.put(0, blob)
    zl.put(0, blob)
    assert zl.stored_bytes < raw.stored_bytes // 5
    assert zl.get(0) == blob
    assert zl.stats.compression_ratio > 5


def test_cache_mode_selection():
    compressible = b"xy" * 100_000
    # capacity far below raw size -> compressed mode should win
    m = select_cache_mode(compressible, capacity_bytes=60_000,
                          total_raw_bytes=200_000)
    assert m in (2, 3, 4)
    # infinite capacity -> raw wins (no decompress cost)
    m2 = select_cache_mode(compressible, capacity_bytes=1 << 30,
                           total_raw_bytes=200_000)
    assert m2 == 1


@settings(max_examples=20, deadline=None)
@given(st.binary(min_size=0, max_size=10_000), st.sampled_from([1, 2, 3, 4]))
def test_cache_roundtrip_property(blob, mode):
    c = ShardCache(1 << 20, mode=mode)
    if c.put(0, blob):
        assert c.get(0) == blob
