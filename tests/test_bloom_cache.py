"""Unit + property tests: Bloom filters and the compressed shard cache.

``hypothesis`` is an optional dependency (requirements.txt): when absent
the property tests run against deterministic seeded samples instead of
being collection errors.
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core.bloom import BloomFilter, optimal_num_bits
from repro.core.cache import MODES, ShardCache, select_cache_mode


# ------------------------------------------------------------------- bloom
def test_bloom_no_false_negatives_basic():
    items = np.array([1, 5, 9, 100, 2**31 - 1])
    f = BloomFilter.build(items)
    assert f.contains(items).all()


def _check_bloom_no_false_negatives(members, queries):
    members = np.unique(np.array(members, dtype=np.int64))
    f = BloomFilter.build(members)
    # every member must test positive
    assert f.contains(members).all()
    # any_member must be True whenever the query overlaps the member set
    q = np.array(queries, dtype=np.int64)
    if len(q) and np.isin(q, members).any():
        assert f.any_member(q)


if HAVE_HYPOTHESIS:

    @settings(max_examples=50, deadline=None)
    @given(
        st.lists(st.integers(min_value=0, max_value=2**31 - 1),
                 min_size=1, max_size=500),
        st.lists(st.integers(min_value=0, max_value=2**31 - 1), max_size=200),
    )
    def test_bloom_no_false_negatives_property(members, queries):
        _check_bloom_no_false_negatives(members, queries)

else:  # deterministic fallback sampling

    @pytest.mark.parametrize("seed", range(25))
    def test_bloom_no_false_negatives_property(seed):
        rng = np.random.default_rng(seed)
        members = rng.integers(0, 2**31 - 1, size=rng.integers(1, 500)).tolist()
        queries = rng.integers(0, 2**31 - 1, size=rng.integers(0, 200)).tolist()
        if seed % 3 == 0 and members:  # force overlap in a third of cases
            queries += members[: max(1, len(members) // 4)]
        _check_bloom_no_false_negatives(members, queries)


def test_bloom_false_positive_rate_reasonable():
    rng = np.random.default_rng(0)
    members = rng.choice(10**7, size=20000, replace=False)
    f = BloomFilter.build(members, fp_rate=0.01)
    non_members = np.setdiff1d(rng.choice(10**7, size=30000), members)[:20000]
    fp = f.contains(non_members).mean()
    assert fp < 0.05  # target 0.01, generous bound
    assert f.fp_rate_estimate() < 0.05


def test_bloom_empty():
    f = BloomFilter.build(np.array([], dtype=np.int64))
    assert not f.any_member(np.array([1, 2, 3]))
    assert not f.any_member(np.array([], dtype=np.int64))


def test_optimal_bits_monotone():
    assert optimal_num_bits(1000, 0.01) > optimal_num_bits(100, 0.01)
    assert optimal_num_bits(1000, 0.001) > optimal_num_bits(1000, 0.01)
    assert optimal_num_bits(64, 0.01) % 64 == 0


def test_bloom_device_words_roundtrip():
    f = BloomFilter.build(np.arange(100))
    w = f.device_words()
    assert w.dtype == np.uint32 and w.nbytes == f.bits.nbytes


# ------------------------------------------------------------------- cache
@pytest.mark.parametrize("mode", sorted(MODES))
def test_cache_roundtrip(mode):
    c = ShardCache(1 << 20, mode=mode)
    blob = np.arange(1000, dtype=np.int32).tobytes() * 3
    assert c.put(7, blob)
    assert c.get(7) == blob
    assert c.get(8) is None
    assert c.stats.hits == 1 and c.stats.misses == 1


def test_cache_lru_eviction_respects_capacity():
    c = ShardCache(10_000, mode=1)
    blobs = {i: bytes(np.random.default_rng(i).integers(0, 255, 4000, np.uint8)) for i in range(5)}
    for i, b in blobs.items():
        c.put(i, b)
    assert c.stored_bytes <= 10_000
    assert c.stats.evictions > 0
    # most recently inserted survives
    assert c.get(4) == blobs[4]


def test_cache_compression_saves_space():
    # compressible payload
    blob = b"abcd" * 50_000
    raw = ShardCache(1 << 22, mode=1)
    zl = ShardCache(1 << 22, mode=3)
    raw.put(0, blob)
    zl.put(0, blob)
    assert zl.stored_bytes < raw.stored_bytes // 5
    assert zl.get(0) == blob
    assert zl.stats.compression_ratio > 5


def test_cache_mode_selection():
    compressible = b"xy" * 100_000
    # capacity far below raw size -> compressed mode should win
    m = select_cache_mode(compressible, capacity_bytes=60_000,
                          total_raw_bytes=200_000)
    assert m in (2, 3, 4)
    # infinite capacity -> raw wins (no decompress cost)
    m2 = select_cache_mode(compressible, capacity_bytes=1 << 30,
                           total_raw_bytes=200_000)
    assert m2 == 1


def _check_cache_roundtrip(blob, mode):
    c = ShardCache(1 << 20, mode=mode)
    if c.put(0, blob):
        assert c.get(0) == blob


if HAVE_HYPOTHESIS:

    @settings(max_examples=20, deadline=None)
    @given(st.binary(min_size=0, max_size=10_000), st.sampled_from([1, 2, 3, 4]))
    def test_cache_roundtrip_property(blob, mode):
        _check_cache_roundtrip(blob, mode)

else:

    @pytest.mark.parametrize("mode", [1, 2, 3, 4])
    @pytest.mark.parametrize("seed", range(5))
    def test_cache_roundtrip_property(seed, mode):
        rng = np.random.default_rng(seed)
        blob = bytes(rng.integers(0, 255, rng.integers(0, 10_000), np.uint8))
        _check_cache_roundtrip(blob, mode)


def test_cache_reput_refreshes_lru_recency():
    """Regression: re-inserting a resident shard must move it to the MRU
    end, or a hot shard that keeps getting re-put (every cache-miss path
    does) is evicted as if it were cold."""
    blob = b"x" * 400
    c = ShardCache(1000, mode=1)
    assert c.put(0, blob) and c.put(1, blob)
    assert c.put(0, blob)  # re-put: must refresh recency, not no-op
    c.put(2, blob)  # capacity forces one eviction -> must be 1, not 0
    assert c.get(0) is not None
    assert c.get(1) is None
    assert c.get(2) is not None
