"""Run the ingestion + delta + crash-recovery + fusion tests under a hard
AS cap (CI).

The streamed ingestion pipeline promises O(chunk + one shard) peak memory,
the delta subsystem promises O(affected shard + pending runs) per
publish/decode, the fused serving layer's lane tables are O(groups x
lanes x V) regardless of |E|, and the mesh layer's numpy emulation adds
only O(D) partition metadata on top.  ``test_ingest.py`` asserts the first
with
tracemalloc (precise, catches any O(|E|) regression); this runner adds
defense in depth: the whole pytest process runs under ``RLIMIT_AS``, so a
regression that dodges tracemalloc (native allocations, mmap-backed
arrays) still dies loudly with ``MemoryError`` instead of quietly passing
on a big-RAM CI host.

jax-touching tests (``e2e`` in the name) are excluded — XLA's
address-space reservations are unrelated to what this cap guards.

Usage (CI)::

    PYTHONPATH=src python tests/run_memcapped.py

``MEMCAP_BYTES`` overrides the default 2 GiB cap.
"""

import os
import sys

DEFAULT_CAP = 2 << 30  # 2 GiB: interpreter + numpy + headroom, << big-RAM CI


def main() -> int:
    cap = int(os.environ.get("MEMCAP_BYTES", DEFAULT_CAP))
    try:
        import resource

        resource.setrlimit(resource.RLIMIT_AS, (cap, cap))
        print(f"run_memcapped: RLIMIT_AS = {cap} bytes", flush=True)
    except (ImportError, ValueError, OSError) as exc:  # non-POSIX fallback
        print(f"run_memcapped: could not set RLIMIT_AS ({exc}); "
              "running uncapped", flush=True)

    import pytest

    here = os.path.dirname(os.path.abspath(__file__))
    return pytest.main(
        [
            "-x",
            "-q",
            os.path.join(here, "test_ingest.py"),
            os.path.join(here, "test_delta.py"),
            os.path.join(here, "test_crash_recovery.py"),
            os.path.join(here, "test_warm_state.py"),
            os.path.join(here, "test_fusion.py"),
            os.path.join(here, "test_mesh_sweep.py"),
            "-k",
            "not e2e",
        ]
    )


if __name__ == "__main__":
    sys.exit(main())
