"""Unit tests: HLO collective parser, roofline terms, scan correction,
io-model consistency, data pipeline shapes for every arch."""

import numpy as np
import pytest

from repro.roofline import hw
from repro.roofline.analysis import (
    COLLECTIVES,
    CollectiveStats,
    RooflineTerms,
    _shape_bytes,
    attention_analytic,
    corrected_terms,
    model_flops,
    parse_collectives,
)


def test_shape_bytes():
    assert _shape_bytes("bf16[4,8]") == 64
    assert _shape_bytes("f32[10]") == 40
    assert _shape_bytes("(bf16[2,2], f32[2])") == 16
    assert _shape_bytes("pred[8]") == 8
    assert _shape_bytes("token[]") == 0  # non-numeric types ignored


HLO = """\
ENTRY %main (a: f32[16]) -> f32[16] {
  %ag = f32[64]{0} all-gather(%a), replica_groups={{0,1,2,3}}
  %w = f32[16]{0} while(%init), condition=%cond_1, body=%body_1
  ROOT %r = f32[16]{0} add(%x, %y)
}
%body_1 (p: f32[16]) -> f32[16] {
  %ar = f32[16]{0} all-reduce(%p), to_apply=%sum
  ROOT %out = f32[16]{0} add(%ar, %p)
}
%cond_1 (p: f32[16]) -> pred[] {
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}
"""


def test_parse_collectives_loop_multiplier():
    c1 = parse_collectives(HLO, loop_trips=1)
    c5 = parse_collectives(HLO, loop_trips=5)
    assert c1.bytes_by_kind["all-gather"] == 64 * 4
    assert c1.bytes_by_kind["all-reduce"] == 16 * 4
    # the all-reduce lives in the while body: x5; the all-gather doesn't
    assert c5.bytes_by_kind["all-reduce"] == 5 * 16 * 4
    assert c5.bytes_by_kind["all-gather"] == 64 * 4
    assert c1.count_by_kind["all-reduce"] == 1


def test_roofline_terms_dominant():
    t = RooflineTerms(
        flops_per_dev=197e12,  # exactly 1s of compute
        bytes_per_dev=819e9 * 2,  # 2s of memory
        collective_bytes_per_dev=50e9 * 4 * 0.5,  # 0.5s of collective
        n_chips=256,
    )
    assert abs(t.compute_s - 1.0) < 1e-6
    assert abs(t.memory_s - 2.0) < 1e-6
    assert abs(t.collective_s - 0.5) < 1e-6
    assert t.dominant == "memory"
    assert t.step_time_s == pytest.approx(3.5)
    assert t.step_time_overlap_s == pytest.approx(2.0)


def test_corrected_terms_scan_correction():
    full = {"flops": 100.0, "bytes accessed": 1000.0}
    outer = {"flops": 10.0, "bytes accessed": 100.0}
    t = corrected_terms(full, outer, HLO, trips=5, n_chips=4)
    assert t.flops_per_dev == (100 - 10) * 5 + 10
    assert t.bytes_per_dev == (1000 - 100) * 5 + 100


def test_model_flops_modes():
    from repro import configs
    from repro.config import SHAPES

    cfg = configs.get_config("yi-6b")
    tr = model_flops(cfg, SHAPES["train_4k"], "train")
    pf = model_flops(cfg, SHAPES["prefill_32k"], "prefill")
    dc = model_flops(cfg, SHAPES["decode_32k"], "decode")
    # train = 6ND on ~1M tokens; prefill = 2ND on ~1M tokens
    assert tr / pf == pytest.approx(3.0, rel=1e-6)
    assert dc < pf / 1000  # decode processes batch-many tokens, not seq*batch
    # MoE uses active params
    moe = configs.get_config("moonshot-v1-16b-a3b")
    assert model_flops(moe, SHAPES["train_4k"], "train") < 6 * moe.param_count * 4096 * 256


def test_attention_analytic_train_multiplier():
    from repro import configs
    from repro.config import SHAPES

    cfg = configs.get_config("gemma-7b")
    ftrain, _ = attention_analytic(cfg, SHAPES["train_4k"], "train")
    fpre, _ = attention_analytic(cfg, SHAPES["train_4k"], "prefill")
    assert ftrain / fpre == pytest.approx(4.0)
    # hybrid arch counts only its attention layers
    jam = configs.get_config("jamba-1.5-large-398b")
    fj, _ = attention_analytic(jam, SHAPES["train_4k"], "prefill")
    n_attn = sum(1 for i in range(jam.num_layers)
                 if jam.layer_kind(i)[0] == "attn")
    assert n_attn == 9
    per_layer = fj / n_attn
    full_layer = 4 * 256 * jam.num_heads * (4096 * 4097 / 2) * jam.head_dim
    assert per_layer == pytest.approx(full_layer)


def test_applicable_shapes_skip_rules():
    from repro import configs

    assert "long_500k" in configs.applicable_shapes("jamba-1.5-large-398b")
    assert "long_500k" in configs.applicable_shapes("xlstm-350m")
    for arch in ("yi-6b", "gemma-7b", "whisper-large-v3", "paligemma-3b"):
        assert "long_500k" not in configs.applicable_shapes(arch)
    assert len(configs.list_archs()) == 10


def test_group_periods():
    from repro import configs

    assert configs.get_config("jamba-1.5-large-398b").group_period == 8
    assert configs.get_config("xlstm-350m").group_period == 4
    assert configs.get_config("yi-6b").group_period == 1
    assert configs.get_config("moonshot-v1-16b-a3b").group_period == 1
    for a in configs.list_archs():
        cfg = configs.get_config(a)
        assert cfg.num_layers % cfg.group_period == 0
        # every layer kind is well-defined
        for i in range(cfg.group_period):
            mixer, mlp = cfg.layer_kind(i)
            assert mixer in ("attn", "ssd", "mlstm", "slstm")
            assert mlp in ("dense", "moe", "none")
