"""RaggedFuse: one ragged kernel launch per shard batch covering ALL
fusion groups (DESIGN.md §14).

The ragged contract, tested four ways:

1. **Padding algebra** — :func:`ragged_lane_pad` never wastes more lanes
   than the per-group power-of-two padding the multi-launch path pays,
   and :func:`ragged_lane_concat` lays groups out contiguously with
   per-lane combine-arm ids (padding lanes carry an id matching NO arm).
2. **Bitwise kernels** — ``ell_update_lanes_ragged`` equals
   ``ell_update_lanes_multi`` bit-for-bit per group across combine mixes
   (including duplicated monoids sharing one arm and inf-heavy min
   inputs), and the mesh variant equals the mesh multi path at D ∈
   {1, 2, 8} (numpy emulation inline; jnp/pallas in a subprocess under
   ``XLA_FLAGS=--xla_force_host_platform_device_count=8``).
3. **Bitwise sweeps** — a ``FusedSweep(ragged=True)`` reproduces the
   ``ragged=False`` multi-path results exactly through masked groups
   (lane-selective scheduling), mid-sweep retirement and backfill.
4. **Conserved accounting** — a ragged sweep books exactly ONE dispatch
   per flushed batch (``dispatches == batches``) where the multi path
   pays ``groups`` per batch, and the declared identities
   (``ragged_dispatches <= batches <= dispatches``,
   ``sum(group_lanes) == ragged_lanes``) replay clean through
   ``MetricsRegistry.verify_conservation``.

jax-touching tests carry ``e2e`` in their names so the RLIMIT_AS runner
(run_memcapped.py) can exclude them.
"""

import os
import subprocess
import sys
import textwrap
import time

import numpy as np
import pytest

from repro.core import apps
from repro.core.csr import (
    csr_to_ell,
    next_pow2,
    ragged_lane_concat,
    ragged_lane_pad,
)
from repro.core.graph import chain_graph, rmat_graph
from repro.core.sharding import preprocess
from repro.core.vsw import VSWEngine
from repro.serve import FusedSweep, GraphService, LaneSeed

MIXED = [("bfs", 0), ("ppr", 5), ("sssp", 3), ("ppr", 11), ("wcc", 1)]


def _norm(v):
    return np.nan_to_num(v, posinf=1e30, neginf=-1e30)


def _mk_engine(tmp_path, tag, g, **kw):
    kw.setdefault("num_shards", 6)
    kw.setdefault("window", 128)
    kw.setdefault("k", 16)
    return VSWEngine.from_graph(g, str(tmp_path / tag), **kw)


def _mk_service(tmp_path, tag, g, **kw):
    kw.setdefault("num_shards", 6)
    kw.setdefault("window", 128)
    kw.setdefault("k", 16)
    return GraphService.from_graph(g, str(tmp_path / tag), **kw)


def _solo(eng, program, source, max_iters):
    kw = {} if program == "wcc" else {"source": source}
    return eng.run(apps.get_program(program, **kw), max_iters=max_iters)


# ------------------------------------------------------- padding algebra
def test_ragged_lane_pad_never_worse_than_per_group_pow2():
    """Property (seeded): for ANY group lane counts, the single ragged
    launch's padding waste <= the multi path's per-group pow2 waste."""
    rng = np.random.default_rng(140)
    for _ in range(300):
        counts = rng.integers(0, 33, size=rng.integers(1, 7)).tolist()
        k_total = sum(counts)
        pad = ragged_lane_pad(counts)
        per_group = sum(next_pow2(max(k, 1)) for k in counts)
        assert pad >= max(k_total, 1)
        assert pad <= per_group, (counts, pad, per_group)
        # ragged waste <= per-group waste (the acceptance inequality)
        assert pad - k_total <= per_group - k_total
    # the two interesting corners from DESIGN.md §14
    assert ragged_lane_pad([1, 1, 1]) == 3  # beats next_pow2(3) == 4
    assert ragged_lane_pad([3, 2, 5]) == 14  # == 4+2+8, beats pow2(10)=16


def test_ragged_lane_concat_layout_and_arm_dedup():
    rng = np.random.default_rng(141)
    groups = [rng.random((k, 10)).astype(np.float32) for k in (3, 1, 2)]
    msgs_all, cids, combines_set, slices = ragged_lane_concat(
        groups, ["sum", "min", "sum"]
    )
    # duplicate monoids share ONE kernel arm, first-seen order
    assert combines_set == ("sum", "min")
    assert msgs_all.shape[0] == ragged_lane_pad([3, 1, 2])
    # every group's lane block round-trips bitwise through its slice
    for m, sl in zip(groups, slices):
        assert np.array_equal(msgs_all[sl], m)
    assert np.asarray(cids)[slices[0]].tolist() == [0, 0, 0]
    assert np.asarray(cids)[slices[1]].tolist() == [1]
    assert np.asarray(cids)[slices[2]].tolist() == [0, 0]
    # padding lanes: zero rows, arm id out of range (matches no arm)
    n_live = sum(m.shape[0] for m in groups)
    assert np.all(msgs_all[n_live:] == 0.0)
    assert np.all(np.asarray(cids)[n_live:] == len(combines_set))
    with pytest.raises(ValueError):
        ragged_lane_concat(groups, ["sum", "min"])
    with pytest.raises(ValueError):
        ragged_lane_concat([], [])


# ------------------------------------------------------- kernel bitwise
@pytest.mark.parametrize("combines", [
    ("sum", "min", "max"),
    ("min", "sum"),
    ("sum", "min", "sum"),   # duplicated monoid -> shared arm
    ("min",),                # single group: ragged degenerates to multi
])
def test_ragged_ops_bitwise_vs_multi_e2e(combines):
    from repro.kernels.spmv_ell import ops as spmv_ops

    g = rmat_graph(600, 7000, seed=142)
    meta, shards = preprocess(g, num_shards=3)
    ells = [csr_to_ell(s, g.num_vertices, window=128, k=16, tr=8)
            for s in shards]
    rng = np.random.default_rng(142)
    msgs_by_group = []
    for gi, c in enumerate(combines):
        m = rng.random((gi + 1, g.num_vertices)).astype(np.float32)
        if c in ("min", "max"):
            # inf-heavy lanes: the min/max identity must survive the
            # in-kernel arm selection exactly as it does solo
            m[m > 0.6] = np.inf if c == "min" else -np.inf
        msgs_by_group.append(m)
    ref = spmv_ops.ell_update_lanes_multi(ells, msgs_by_group, list(combines))
    out = spmv_ops.ell_update_lanes_ragged(ells, msgs_by_group, list(combines))
    assert len(out) == len(ref) == len(combines)
    for gi, (accs_r, accs_m) in enumerate(zip(out, ref)):
        assert len(accs_r) == len(accs_m) == len(ells)
        for si, (a, b) in enumerate(zip(accs_r, accs_m)):
            assert a.shape == b.shape
            assert np.array_equal(_norm(a), _norm(b)), (gi, si)
    # empty shard list: shape-compatible empty result
    assert spmv_ops.ell_update_lanes_ragged([], msgs_by_group,
                                            list(combines)) == \
        [[] for _ in combines]


# -------------------------------------------------------- sweep bitwise
@pytest.mark.parametrize("backend,batch_shards,lane_selective", [
    ("jnp", 1, True), ("jnp", 3, True), ("pallas", 2, True),
    ("jnp", 2, False),
])
def test_ragged_sweep_bitwise_vs_multi_e2e(tmp_path, backend, batch_shards,
                                           lane_selective):
    """FusedSweep(ragged=True) == FusedSweep(ragged=False) bitwise per
    lane through masked groups and mid-sweep retirement/backfill — and
    the ragged run books ONE dispatch per batch where multi pays G."""
    g = rmat_graph(400, 4500, seed=143)
    eng = _mk_engine(tmp_path, f"e{backend}{batch_shards}", g, num_shards=5,
                     backend=backend, batch_shards=batch_shards)
    bfs, sssp, ppr = apps.lane_bfs(), apps.lane_sssp(), apps.lane_ppr()
    # varied max_iters force mid-sweep retirement; the backfill queue
    # re-admits into freed lanes while the other group is still live
    queue = [LaneSeed(source=9, max_iters=12, token="b2", program=bfs)]

    def mk_seeds():
        return [
            [LaneSeed(source=0, max_iters=3, token="b0", program=bfs),
             LaneSeed(source=3, max_iters=12, token="s0", program=sssp)],
            [LaneSeed(source=5, max_iters=8, token="p0", program=ppr),
             LaneSeed(source=11, max_iters=2, token="p1", program=ppr)],
        ]

    def mk_backfill(q):
        def backfill(group, n_free):
            if group != 0:
                return []
            out = q[:n_free]
            del q[:n_free]
            return out
        return backfill

    runs = {}
    for ragged in (True, False):
        sweep = FusedSweep(eng, batch_shards=batch_shards,
                           lane_selective=lane_selective, ragged=ragged)
        q = list(queue)
        res = sweep.run(mk_seeds(), backfill=mk_backfill(q))
        runs[ragged] = ({r.token: r for r in res}, sweep.iter_stats)
    by_r, stats_r = runs[True]
    by_m, stats_m = runs[False]
    assert set(by_r) == set(by_m) == {"b0", "s0", "p0", "p1", "b2"}
    for tok in by_m:
        assert np.array_equal(_norm(by_r[tok].values),
                              _norm(by_m[tok].values)), tok
        assert by_r[tok].iterations == by_m[tok].iterations
        assert by_r[tok].converged == by_m[tok].converged
    # accounting: ragged == one launch per flushed batch, every iteration
    assert sum(s.dispatches for s in stats_r) > 0
    for s in stats_r:
        assert s.dispatches == s.batches, s
        assert s.overlap_s >= 0.0
    # the multi path pays per live group: strictly more launches overall
    assert sum(s.dispatches for s in stats_m) > \
        sum(s.dispatches for s in stats_r)
    if batch_shards > 1:  # batch_shards=1 multi runs per-shard (no batches)
        assert sum(s.batches for s in stats_m) == \
            sum(s.batches for s in stats_r)
    eng.close()


def test_ragged_service_mixed_workload_bitwise_e2e(tmp_path):
    """Service-level: ragged on (default) vs off, mixed-algebra workload
    with lane retirement — every query bitwise-equal to its solo run."""
    g = rmat_graph(300, 3500, seed=144)
    eng = _mk_engine(tmp_path, "ref", g, num_shards=5, backend="jnp")
    refs = {c: _solo(eng, *c, 12) for c in MIXED}
    eng.close()
    for ragged in (True, False):
        svc = _mk_service(tmp_path, f"svc{ragged}", g, num_shards=5,
                          backend="jnp", max_lanes=8, max_groups=2,
                          batch_shards=2, ragged=ragged)
        with svc.submit_batch():
            futs = [svc.submit(p, s, max_iters=12) for p, s in MIXED]
        for c, f in zip(MIXED, futs):
            qr = f.result(timeout=240)
            assert np.array_equal(_norm(qr.values),
                                  _norm(refs[c].values)), (ragged, c)
        # futures resolve inside the sweep; the counter bumps at sweep end
        deadline = time.monotonic() + 30
        while svc.stats()["sweeps"] < 1 and time.monotonic() < deadline:
            time.sleep(0.02)
        assert svc.stats()["sweeps"] == 1
        svc.close()


# ------------------------------------------------------- mesh emulation
@pytest.mark.parametrize("D", [1, 2, 8])
def test_ragged_mesh_numpy_emulation_bitwise(tmp_path, D):
    """The jax-free mesh emulation books ragged accounting (one dispatch
    per flush) while staying bitwise vs the single-device numpy oracle."""
    g = rmat_graph(300, 3000, seed=145)
    eng = _mk_engine(tmp_path, f"m{D}", g, backend="numpy", mesh=D)
    ref = _mk_engine(tmp_path, "mref", g, backend="numpy")
    bfs, ppr = apps.lane_bfs(), apps.lane_ppr()
    sweep = FusedSweep(eng, ragged=True)
    res = sweep.run([
        [LaneSeed(source=2, max_iters=10, token="b", program=bfs)],
        [LaneSeed(source=7, max_iters=6, token="p", program=ppr)],
    ])
    by_tok = {r.token: r for r in res}
    for tok, src, prog, iters in (("b", 2, "bfs", 10), ("p", 7, "ppr", 6)):
        sr = _solo(ref, prog, src, iters)
        assert np.array_equal(_norm(by_tok[tok].values), _norm(sr.values))
    for s in sweep.iter_stats:
        assert s.dispatches == s.batches
        if s.device_dispatches:
            assert sum(s.device_dispatches) >= s.dispatches
    eng.close()
    ref.close()


# --------------------------------------------------- jax mesh subprocess
_MESH_RAGGED_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import tempfile
    from repro.core.graph import rmat_graph
    from repro.serve import GraphService

    g = rmat_graph(300, 3500, seed=146)
    cases = [("bfs", 2), ("ppr", 3), ("sssp", 1), ("ppr", 9)]
    norm = lambda v: np.nan_to_num(v, posinf=1e30)
    with tempfile.TemporaryDirectory() as d:
        for backend in ("jnp", "pallas"):
            solo = GraphService.from_graph(
                g, d + f"/solo{backend}", num_shards=6, window=128, k=16,
                backend=backend, max_lanes=8, max_groups=2, batch_shards=2,
                ragged=False)
            refs = {c: solo.query(*c, max_iters=12).values for c in cases}
            solo.close()
            for D in (1, 2, 8):
                svc = GraphService.from_graph(
                    g, d + f"/{backend}{D}", num_shards=6, window=128,
                    k=16, backend=backend, max_lanes=8, max_groups=2,
                    batch_shards=2, mesh=D, ragged=True)
                with svc.submit_batch():
                    futs = [svc.submit(p, s, max_iters=12)
                            for p, s in cases]
                for c, f in zip(cases, futs):
                    qr = f.result(timeout=240)
                    assert np.array_equal(norm(qr.values),
                                          norm(refs[c])), (backend, D, c)
                svc.close()
                print(backend, "D", D, "ragged-bitwise-ok", flush=True)
    print("MESH_RAGGED_OK")
    """
)


def _run_sub(script):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    return subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        env=env, timeout=600,
    )


@pytest.mark.slow
def test_ragged_mesh_jax_bitwise_e2e():
    r = _run_sub(_MESH_RAGGED_SCRIPT)
    assert r.returncode == 0, (r.stdout[-1500:], r.stderr[-3000:])
    assert "MESH_RAGGED_OK" in r.stdout


# ---------------------------------------------------------- conservation
def test_ragged_metrics_conservation_e2e(tmp_path):
    """The declared RaggedFuse identities replay clean on a real ragged
    sweep's iteration stats, and a violated identity is caught."""
    from repro.obs.metrics import ConservationError, MetricsRegistry

    g = rmat_graph(250, 2500, seed=147)
    eng = _mk_engine(tmp_path, "cons", g, backend="jnp", batch_shards=2)
    bfs, ppr = apps.lane_bfs(), apps.lane_ppr()
    sweep = FusedSweep(eng, batch_shards=2, ragged=True)
    sweep.run([
        [LaneSeed(source=0, max_iters=8, token="b", program=bfs)],
        [LaneSeed(source=1, max_iters=8, token="p", program=ppr)],
    ])
    reg = MetricsRegistry()
    for s in sweep.iter_stats:
        reg.ingest(s)
    assert reg.verify_conservation() == []
    assert reg.snapshot()["sweep.batches"] == \
        reg.snapshot()["sweep.dispatches"]
    eng.close()

    # a stats row claiming more batches than dispatches must be flagged
    bad = MetricsRegistry()
    s = sweep.iter_stats[0].__class__(
        iteration=0, live_lanes=2, shards_processed=1, shards_skipped=0,
        bytes_read=0, selective_on=False, retired=0, backfilled=0,
        time_s=0.0, dispatches=1, batches=2,
    )
    bad.ingest(s)
    with pytest.raises(ConservationError):
        bad.verify_conservation()


def test_ragged_exec_stats_identities():
    """ExecStats-level identities: ragged_dispatches <= batches <=
    dispatches and sum(group_lanes) == ragged_lanes."""
    from repro.core.executor import ExecStats
    from repro.obs.metrics import ConservationError, MetricsRegistry

    reg = MetricsRegistry()
    reg.ingest(ExecStats(
        dispatches=4, batches=4, ragged_dispatches=4, ragged_lanes=20,
        group_lanes={0: 12, 1: 8}, shards_executed=8, overlap_s=0.01,
    ))
    assert reg.verify_conservation() == []
    snap = reg.snapshot()
    assert snap["exec.ragged_dispatches"] == 4
    assert snap["exec.ragged_lanes"] == 20

    bad = MetricsRegistry()
    bad.ingest(ExecStats(
        dispatches=2, batches=2, ragged_dispatches=2, ragged_lanes=9,
        group_lanes={0: 4, 1: 4}, shards_executed=4,
    ))
    with pytest.raises(ConservationError):
        bad.verify_conservation()
