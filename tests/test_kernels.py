"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracles,
swept over shapes, dtypes and combine monoids (spec requirement)."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.bloom import BloomFilter32
from repro.core.csr import csr_to_ell
from repro.core.graph import rmat_graph, star_graph
from repro.core.sharding import preprocess
from repro.core.vsw import update_shard_numpy
from repro.kernels.bloom import ops as bloom_ops
from repro.kernels.bloom.ref import bloom_contains_ref
from repro.kernels.flash_attention import ops as attn_ops
from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import mha_ref
from repro.kernels.spmv_ell import ops as spmv_ops


# ----------------------------------------------------------------- spmv_ell
@pytest.mark.parametrize("window,k,tr", [(256, 8, 8), (512, 32, 8), (1024, 128, 8)])
@pytest.mark.parametrize("combine", ["sum", "min", "max"])
@pytest.mark.parametrize("variant", ["masked", "sentinel"])
def test_spmv_ell_matches_oracle(window, k, tr, combine, variant):
    g = rmat_graph(1500, 20000, seed=42)
    meta, shards = preprocess(g, num_shards=3)
    msgs = np.random.default_rng(0).random(g.num_vertices).astype(np.float32)
    for s in shards:
        e = csr_to_ell(s, g.num_vertices, window=window, k=k, tr=tr)
        oracle = update_shard_numpy(s, None, msgs.astype(np.float64), combine)
        acc = np.asarray(spmv_ops.ell_update(e, msgs, combine, variant=variant))
        a = np.nan_to_num(acc, posinf=1e30, neginf=-1e30)
        b = np.nan_to_num(oracle, posinf=1e30, neginf=-1e30)
        assert np.allclose(a, b, rtol=1e-4, atol=1e-5), (s.shard_id, combine)


@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_spmv_ell_dtypes(dtype):
    g = rmat_graph(400, 3000, seed=1)
    meta, shards = preprocess(g, num_shards=2)
    msgs = np.random.default_rng(1).random(g.num_vertices).astype(np.float32)
    e = csr_to_ell(shards[0], g.num_vertices, window=256, k=16, tr=8)
    acc = np.asarray(
        spmv_ops.ell_update(e, np.asarray(msgs, dtype=np.float32), "sum")
    ).astype(np.float32)
    oracle = update_shard_numpy(shards[0], None, msgs.astype(np.float64), "sum")
    tol = 1e-4 if dtype == np.float32 else 5e-2
    assert np.allclose(acc, oracle, rtol=tol, atol=tol)


def test_spmv_ell_hub_vertex_row_split():
    """A 10k-in-degree hub exercises row splitting across many ELL rows."""
    g = star_graph(10_000)
    meta, shards = preprocess(g, num_shards=1)
    e = csr_to_ell(shards[0], g.num_vertices, window=2048, k=64, tr=8)
    msgs = np.ones(g.num_vertices, np.float32)
    acc = np.asarray(spmv_ops.ell_update(e, msgs, "sum"))
    assert np.isclose(acc[0], 9999.0)  # all spokes point at vertex 0
    assert np.allclose(acc[1:], 0.0)


def test_spmv_ell_empty_shard():
    from repro.core.graph import from_edge_list

    g = from_edge_list([(0, 1)], num_vertices=64)
    meta, shards = preprocess(g, num_shards=2)
    msgs = np.ones(64, np.float32)
    for s in shards:
        e = csr_to_ell(s, 64, window=32, k=8, tr=8)
        acc = np.asarray(spmv_ops.ell_update(e, msgs, "sum"))
        assert acc.shape == (s.rows,)


# -------------------------------------------------------------------- bloom
@pytest.mark.parametrize("n_items,num_hashes", [(100, 2), (5000, 4), (200, 8)])
def test_bloom_kernel_bitexact_vs_host(n_items, num_hashes):
    rng = np.random.default_rng(3)
    items = rng.choice(1 << 22, size=n_items, replace=False).astype(np.int32)
    f = BloomFilter32.build(items, num_hashes=num_hashes)
    queries = rng.integers(0, 1 << 22, size=4096).astype(np.int32)
    host = f.contains(queries)
    dev = bloom_ops.contains(f, queries)
    refv = np.asarray(
        bloom_contains_ref(
            jnp.asarray(f.words), jnp.asarray(queries),
            num_bits=f.num_bits, num_hashes=f.num_hashes,
        )
    )
    assert np.array_equal(dev, host)
    assert np.array_equal(refv, host)
    # no false negatives ever
    assert bloom_ops.contains(f, items).all()


def test_bloom_any_active_shards():
    rng = np.random.default_rng(4)
    sets = [rng.choice(10**6, 300, replace=False) for _ in range(5)]
    filters = [BloomFilter32.build(s) for s in sets]
    active = sets[2][:3].astype(np.int32)  # only shard 2 truly active
    out = bloom_ops.any_active_shards(filters, active)
    assert out[2]
    out_empty = bloom_ops.any_active_shards(filters, np.array([], np.int32))
    assert not out_empty.any()


# ---------------------------------------------------------- flash attention
@pytest.mark.parametrize("B,Hq,Hkv,S,D", [
    (1, 4, 4, 256, 64),     # MHA
    (2, 8, 2, 128, 64),     # GQA 4:1
    (1, 2, 1, 384, 128),    # MQA, odd-ish seq
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_ref(B, Hq, Hkv, S, D, causal):
    rng = np.random.default_rng(5)
    q = rng.standard_normal((B, Hq, S, D), dtype=np.float32)
    k = rng.standard_normal((B, Hkv, S, D), dtype=np.float32)
    v = rng.standard_normal((B, Hkv, S, D), dtype=np.float32)
    ref = mha_ref(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), causal=causal)
    out = attn_ops.attention(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
        causal=causal, impl="pallas", block_q=128, block_k=128,
    )
    assert np.allclose(np.asarray(out), np.asarray(ref), rtol=2e-3, atol=2e-3)


def test_flash_attention_bf16():
    rng = np.random.default_rng(6)
    mk = lambda *s: jnp.asarray(rng.standard_normal(s), jnp.bfloat16)
    q, k, v = mk(1, 2, 256, 64), mk(1, 2, 256, 64), mk(1, 2, 256, 64)
    ref = mha_ref(q, k, v, causal=True)
    out = attn_ops.attention(q, k, v, causal=True, impl="pallas")
    assert out.dtype == jnp.bfloat16
    a = np.asarray(out, np.float32)
    b = np.asarray(ref, np.float32)
    assert np.allclose(a, b, rtol=5e-2, atol=5e-2)


def test_flash_attention_decode_suffix_alignment():
    """Sq < Skv: queries are the suffix (KV-cache decode convention)."""
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.standard_normal((1, 2, 128, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2, 512, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 2, 512, 64)), jnp.float32)
    ref = mha_ref(q, k, v, causal=True)
    out = attn_ops.attention(q, k, v, causal=True, impl="pallas")
    assert np.allclose(np.asarray(out), np.asarray(ref), rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("BH,G,S,D,bk", [
    (4, 8, 1024, 64, 256),
    (2, 1, 512, 128, 128),   # MHA-style group of 1
    (3, 4, 384, 64, 512),    # S < block_k (single padded block)
])
def test_flash_decode_matches_oracle(BH, G, S, D, bk):
    from repro.kernels.flash_attention.kernel import (
        decode_partials_ref, flash_decode,
    )

    rng = np.random.default_rng(11)
    q = jnp.asarray(rng.standard_normal((BH, G, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((BH, S, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((BH, S, D)), jnp.float32)
    lens = rng.integers(1, S + 1, BH)
    valid = jnp.asarray(np.arange(S)[None, :] < lens[:, None])
    out = flash_decode(q, k, v, valid, block_k=bk)
    o, m, l = decode_partials_ref(q, k, v, valid)
    ref = np.asarray(o) / np.maximum(np.asarray(l), 1e-30)[..., None]
    assert np.allclose(np.asarray(out), ref, rtol=2e-3, atol=2e-3)


def test_flash_decode_shard_combine_exact():
    """Partial-softmax merge over KV shards == full softmax — the property
    that makes seq-sharded decode a psum of stats instead of a score
    re-gather (EXPERIMENTS.md §Perf, whisper)."""
    from repro.kernels.flash_attention.kernel import (
        decode_partials_ref, flash_decode_combine,
    )

    rng = np.random.default_rng(12)
    BH, G, S, D, N = 4, 8, 1024, 64, 4
    q = jnp.asarray(rng.standard_normal((BH, G, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((BH, S, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((BH, S, D)), jnp.float32)
    valid = jnp.asarray(np.arange(S)[None, :] < np.array([700, S, 1, 512])[:, None])
    o, m, l = decode_partials_ref(q, k, v, valid)
    full = np.asarray(o) / np.maximum(np.asarray(l), 1e-30)[..., None]
    parts = [decode_partials_ref(q, k[:, i*S//N:(i+1)*S//N],
                                 v[:, i*S//N:(i+1)*S//N],
                                 valid[:, i*S//N:(i+1)*S//N])
             for i in range(N)]
    comb = flash_decode_combine(
        jnp.stack([p[0] for p in parts]),
        jnp.stack([p[1] for p in parts]),
        jnp.stack([p[2] for p in parts]),
    )
    assert np.allclose(np.asarray(comb), full, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("block_q,block_k", [(64, 64), (128, 256)])
def test_flash_attention_block_sweep(block_q, block_k):
    rng = np.random.default_rng(8)
    q = jnp.asarray(rng.standard_normal((1, 2, 256, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2, 256, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 2, 256, 64)), jnp.float32)
    ref = mha_ref(q, k, v, causal=True)
    out = flash_attention(
        q.reshape(2, 256, 64), k.reshape(2, 256, 64), v.reshape(2, 256, 64),
        causal=True, block_q=block_q, block_k=block_k,
    )
    assert np.allclose(
        np.asarray(out), np.asarray(ref.reshape(2, 256, 64)),
        rtol=2e-3, atol=2e-3,
    )
