"""Integration tests: the VSW engine vs dense references (paper Alg. 1+2)."""

import numpy as np
import pytest

from repro.core import apps
from repro.core.graph import chain_graph, from_edge_list, rmat_graph
from repro.core.vsw import VSWEngine


# ---------------------------------------------------------------- references
def dense_pagerank(g, iters, d=0.85):
    n = g.num_vertices
    outd = np.maximum(g.out_degrees(), 1).astype(np.float64)
    v = np.full(n, 1.0 / n)
    for _ in range(iters):
        msgs = v / outd
        acc = np.zeros(n)
        np.add.at(acc, g.dst, msgs[g.src])
        v = (1 - d) / n + d * acc
    return v


def dense_sssp(g, src=0):
    dist = np.full(g.num_vertices, np.inf)
    dist[src] = 0
    for _ in range(g.num_vertices):
        nd = dist.copy()
        np.minimum.at(nd, g.dst, dist[g.src] + 1)
        if np.array_equal(nd, dist):
            break
        dist = nd
    return dist


def dense_wcc_labels(g):
    """Min-label propagation fixed point along in-edges (directed semantics)."""
    lab = np.arange(g.num_vertices, dtype=np.float64)
    for _ in range(g.num_vertices):
        nl = lab.copy()
        np.minimum.at(nl, g.dst, lab[g.src])
        if np.array_equal(nl, lab):
            break
        lab = nl
    return lab


@pytest.fixture(params=["numpy", "jnp", "pallas"])
def backend(request):
    return request.param


@pytest.fixture
def engine_factory(tmp_path, backend):
    def make(g, **kw):
        kw.setdefault("num_shards", 5)
        kw.setdefault("window", 128)
        kw.setdefault("k", 16)
        return VSWEngine.from_graph(
            g, str(tmp_path / "store"), backend=backend, **kw
        )

    return make


def test_pagerank_matches_dense(engine_factory):
    g = rmat_graph(500, 6000, seed=3)
    eng = engine_factory(g)
    r = eng.run(apps.pagerank(), max_iters=30)
    assert np.abs(r.values - dense_pagerank(g, 30)).max() < 1e-5


def test_sssp_matches_dense(engine_factory):
    g = rmat_graph(500, 6000, seed=4)
    eng = engine_factory(g)
    r = eng.run(apps.sssp(0), max_iters=100)
    assert r.converged
    ref = dense_sssp(g, 0)
    finite = np.isfinite(ref)
    assert np.array_equal(r.values[finite], ref[finite].astype(np.float32))
    assert np.isinf(r.values[~finite]).all()


def test_wcc_matches_dense(engine_factory):
    g = rmat_graph(400, 3000, seed=5)
    eng = engine_factory(g)
    r = eng.run(apps.wcc(), max_iters=200)
    assert r.converged
    assert np.array_equal(r.values, dense_wcc_labels(g).astype(np.float32))


def test_bfs_levels_on_chain(engine_factory):
    g = chain_graph(64)
    eng = engine_factory(g, num_shards=4)
    r = eng.run(apps.bfs(0), max_iters=100)
    assert r.converged
    assert np.array_equal(r.values, np.arange(64, dtype=np.float32))


def test_vertex_values_never_hit_disk(engine_factory):
    """The SEM contract: per-iteration writes must be zero (Table II, VSW row)."""
    g = rmat_graph(300, 3000, seed=6)
    eng = engine_factory(g)
    w0 = eng.store.io.bytes_written
    eng.run(apps.pagerank(), max_iters=5)
    assert eng.store.io.bytes_written == w0  # nothing written during compute


def test_selective_scheduling_preserves_results(tmp_path):
    g = rmat_graph(600, 5000, seed=7)
    e1 = VSWEngine.from_graph(
        g, str(tmp_path / "a"), num_shards=6, window=128, k=16,
        backend="numpy", selective=False,
    )
    e2 = VSWEngine.from_graph(
        g, str(tmp_path / "b"), num_shards=6, window=128, k=16,
        backend="numpy", selective=True, threshold=0.5,
    )
    for prog in (apps.sssp(0), apps.wcc()):
        r1 = e1.run(prog, max_iters=100)
        r2 = e2.run(prog, max_iters=100)
        a = np.nan_to_num(r1.values, posinf=1e30)
        b = np.nan_to_num(r2.values, posinf=1e30)
        assert np.array_equal(a, b), prog.name
        assert sum(i.shards_skipped for i in r2.iterations) > 0  # it did skip


def test_selective_bloom_never_skips_more_than_exact(tmp_path):
    g = rmat_graph(600, 4000, seed=8)
    kw = dict(num_shards=8, window=128, k=16, backend="numpy",
              selective=True, threshold=0.5)
    e_bloom = VSWEngine.from_graph(g, str(tmp_path / "a"), **kw)
    e_exact = VSWEngine.from_graph(
        g, str(tmp_path / "b"), exact_selective=True, **kw
    )
    rb = e_bloom.run(apps.sssp(0), max_iters=50)
    re = e_exact.run(apps.sssp(0), max_iters=50)
    # identical values, and per-iteration the Bloom engine may process MORE
    # shards (false positives) but never fewer.
    a = np.nan_to_num(rb.values, posinf=1e30)
    b = np.nan_to_num(re.values, posinf=1e30)
    assert np.array_equal(a, b)
    for ib, ie in zip(rb.iterations, re.iterations):
        assert ib.shards_processed >= ie.shards_processed


def test_cache_eliminates_disk_reads(tmp_path):
    g = rmat_graph(500, 8000, seed=9)
    eng = VSWEngine.from_graph(
        g, str(tmp_path / "s"), num_shards=5, window=128, k=16,
        backend="numpy", selective=False, cache_bytes=1 << 24, cache_mode=3,
    )
    r = eng.run(apps.pagerank(), max_iters=5)
    # Cache was warmed during the loading scan; compute reads zero bytes.
    assert r.total_bytes_read == 0
    assert eng.cache.stats.hits >= 5 * 5


def test_cache_partial_capacity_reduces_reads(tmp_path):
    g = rmat_graph(500, 8000, seed=10)
    sizes = {}
    for cap in (0, 1 << 14, 1 << 26):
        eng = VSWEngine.from_graph(
            g, str(tmp_path / f"s{cap}"), num_shards=6, window=128, k=16,
            backend="numpy", selective=False,
            cache_bytes=cap, cache_mode=2,
        )
        r = eng.run(apps.pagerank(), max_iters=4)
        sizes[cap] = r.total_bytes_read
    assert sizes[0] > sizes[1 << 14] or sizes[1 << 14] > sizes[1 << 26]
    assert sizes[1 << 26] == 0


def test_backends_agree(tmp_path):
    g = rmat_graph(400, 5000, seed=11)
    results = {}
    for backend in ("numpy", "jnp"):
        eng = VSWEngine.from_graph(
            g, str(tmp_path / backend), num_shards=4, window=256, k=16,
            backend=backend, selective=False,
        )
        results[backend] = eng.run(apps.pagerank(), max_iters=10).values
    assert np.allclose(results["numpy"], results["jnp"], rtol=1e-5, atol=1e-9)


def test_convergence_termination():
    g = from_edge_list([(0, 1), (1, 2)], num_vertices=3)
    import tempfile

    with tempfile.TemporaryDirectory() as d:
        eng = VSWEngine.from_graph(g, d, num_shards=1, window=8, k=4,
                                   backend="numpy")
        r = eng.run(apps.bfs(0), max_iters=100)
        assert r.converged and r.num_iterations <= 4


def test_device_resident_cache_matches_and_skips_decode(tmp_path):
    """Beyond-paper: decoded device-format shards stay resident — identical
    results, no repeated host decode (EXPERIMENTS.md §Perf notes)."""
    g = rmat_graph(2000, 30000, seed=13)
    res = {}
    for dr in (False, True):
        eng = VSWEngine.from_graph(
            g, str(tmp_path / f"dr{dr}"), num_shards=4, window=256, k=16,
            backend="jnp", selective=False, device_resident=dr,
        )
        res[dr] = eng.run(apps.pagerank(), max_iters=8).values
        if dr:
            assert len(eng._device_shards) == 4  # all shards resident
    assert np.allclose(res[False], res[True], rtol=1e-6, atol=1e-9)


def test_auto_cache_mode_selection(tmp_path):
    """cache_mode=0 runs the GraphH-style selector (paper §II-D-2)."""
    g = rmat_graph(1000, 20000, seed=14)
    eng = VSWEngine.from_graph(
        g, str(tmp_path / "s"), num_shards=4, window=128, k=16,
        backend="numpy", cache_bytes=1 << 22, cache_mode=0,
    )
    assert eng.cache.mode_id in (1, 2, 3, 4)
    r = eng.run(apps.pagerank(), max_iters=5)
    assert np.isfinite(r.values).all()
