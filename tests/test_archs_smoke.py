"""Per-architecture smoke tests (spec requirement): a REDUCED config of the
same family runs one forward/train step on CPU, asserting output shapes and
no NaNs; plus prefill+decode consistency against the full forward."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import configs
from repro.config import smoke_config
from repro.distributed.sharding import LOCAL_CTX
from repro.models import common as C
from repro.models import model as M

ARCHS = configs.list_archs()


def _smoke_batch(cfg, rng, B=2, S=32):
    tokens = rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32)
    labels = rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32)
    batch = {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels)}
    if cfg.frontend == "vision_stub":
        batch["patch_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.prefix_len, cfg.d_model)), jnp.float32
        )
        batch["labels"] = jnp.asarray(labels)
    if cfg.encdec:
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, cfg.encoder_seq, cfg.d_model)), jnp.float32
        )
    return batch


@pytest.fixture(scope="module")
def rng():
    return np.random.default_rng(0)


@pytest.mark.parametrize("arch", ARCHS)
def test_params_match_specs_structure(arch):
    cfg = smoke_config(configs.get_config(arch))
    params = M.init_params(jax.random.key(0), cfg)
    specs = M.param_specs(cfg)
    assert C.tree_congruent(params, specs), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch, rng):
    cfg = smoke_config(configs.get_config(arch))
    params = M.init_params(jax.random.key(1), cfg, dtype=jnp.float32)
    batch = _smoke_batch(cfg, rng)

    logits, _, aux = M.forward(params, batch, cfg, LOCAL_CTX, mode="train")
    S_out = batch["tokens"].shape[1] + (
        cfg.prefix_len if cfg.frontend == "vision_stub" else 0
    )
    assert logits.shape == (2, S_out, cfg.vocab_size), arch
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch

    # one real train step: loss + grads, all finite
    def loss_fn(p):
        l, m = M.train_loss(p, batch, cfg, LOCAL_CTX)
        return l

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss)), arch
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in leaves), arch
    # gradients actually flow to the embedding and deep layers
    gnorm = sum(float(jnp.abs(g).sum()) for g in leaves)
    assert gnorm > 0, arch


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch, rng):
    """decode_step(t) logits must match the full-forward logits at t."""
    cfg = smoke_config(configs.get_config(arch))
    params = M.init_params(jax.random.key(2), cfg, dtype=jnp.float32)
    B, S = 2, 16
    batch = _smoke_batch(cfg, rng, B=B, S=S)
    batch.pop("labels")

    full_logits, _, _ = M.forward(params, batch, cfg, LOCAL_CTX, mode="train")

    # prefill on the first S-4 tokens, then decode 4 tokens one by one
    P0 = S - 4
    pre_batch = dict(batch, tokens=batch["tokens"][:, :P0])
    _, caches = M.prefill(params, pre_batch, cfg, LOCAL_CTX)
    caches = M.pad_caches(caches, cfg, max_seq=S + (
        cfg.prefix_len if cfg.frontend == "vision_stub" else 0
    ))

    prefix = cfg.prefix_len if cfg.frontend == "vision_stub" else 0
    for t in range(P0, S):
        tok = batch["tokens"][:, t : t + 1]
        logits, caches = M.decode_step(
            params, tok, caches, jnp.int32(t + prefix), cfg, LOCAL_CTX
        )
        ref = full_logits[:, t + prefix]
        got = np.asarray(logits, np.float32)
        refn = np.asarray(ref, np.float32)
        assert np.allclose(got, refn, rtol=2e-2, atol=2e-2), (
            arch, t, np.abs(got - refn).max(),
        )


def test_param_counts_in_expected_range():
    """Published param counts (rough): sanity-check our config wiring."""
    expect = {
        "yi-6b": (5.5e9, 7.5e9),
        "qwen2.5-32b": (28e9, 36e9),
        "gemma-7b": (7.0e9, 10e9),
        # assigned spec (48L x 64e x ff1408) works out to ~28B total; the hf
        # model is 27L — we implement the ASSIGNED numbers verbatim.
        "moonshot-v1-16b-a3b": (26e9, 31e9),
        "phi3.5-moe-42b-a6.6b": (38e9, 46e9),
        "jamba-1.5-large-398b": (320e9, 440e9),
        "xlstm-350m": (0.25e9, 0.55e9),
        "paligemma-3b": (2.0e9, 3.5e9),  # language tower only (vision stubbed)
        "whisper-large-v3": (1.2e9, 2.0e9),
        "qwen2.5-3b": (2.5e9, 4.0e9),
    }
    for arch, (lo, hi) in expect.items():
        n = configs.get_config(arch).param_count
        assert lo < n < hi, (arch, f"{n:.3e}", lo, hi)
