"""Distributed VSW over 8 simulated devices must match the single-device
engine.  Runs in a subprocess because XLA's host device count must be fixed
before jax initialises (the main test process keeps 1 device, per spec)."""

import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax, tempfile
    from repro.core.graph import rmat_graph
    from repro.core import apps
    from repro.core.distributed import run_distributed
    from repro.core.vsw import VSWEngine

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    g = rmat_graph(700, 9000, seed=11)
    with tempfile.TemporaryDirectory() as d:
        eng = VSWEngine.from_graph(g, d, num_shards=4, window=4096, k=32,
                                   backend="numpy", selective=False)
        for prog, iters in [(apps.pagerank(), 15), (apps.sssp(0), 40),
                            (apps.wcc(), 60)]:
            ref = eng.run(prog, max_iters=iters).values
            got, it = run_distributed(g, prog, mesh, max_iters=iters)
            a = np.nan_to_num(got, posinf=1e30)
            b = np.nan_to_num(ref, posinf=1e30)
            assert np.allclose(a, b, rtol=1e-4, atol=1e-8), prog.name
    print("DISTRIBUTED_OK")
    """
)


@pytest.mark.slow
def test_distributed_matches_single_device():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        env=env, timeout=600,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    assert "DISTRIBUTED_OK" in r.stdout
