"""GraphScope observability: trace well-formedness + metric conservation.

Three families of guarantees (DESIGN.md §11):

1. **Trace well-formedness** — a traced ``GraphService`` run on a mixed
   fused workload exports valid Chrome-trace JSON: every span closed,
   per-thread timestamps monotonic, durations non-negative, and the
   admit → plan → load → decode → dispatch → retire story visible across
   at least three thread lanes (service worker, prefetchers, recompactor).
2. **Conservation** — ``MetricsRegistry.ingest`` declares each stats
   class's identities and one shared ``verify_conservation()`` replays
   them, including the mesh device splits, across a fused mesh sweep with
   live updates.
3. **Zero-cost disabled path** — with no tracer installed every call site
   returns the shared no-op span; results and stats are unchanged.
"""

import json
import threading
import time

import numpy as np
import pytest

from repro.core.cache import CacheStats
from repro.core.executor import ExecStats
from repro.core.graph import rmat_graph
from repro.core.pipeline import PipelineStats, ShardLoadError
from repro.core.storage import IOStats
from repro.core.vsw import IterStats, VSWEngine
from repro.obs import (
    ConservationError,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    NULL_SPAN,
    Tracer,
    trace,
)
from repro.serve import GraphService
from repro.serve.sweep import SweepIterStats

pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")


def _mk_service(tmp_path, tag, g, **kw):
    kw.setdefault("num_shards", 6)
    kw.setdefault("window", 128)
    kw.setdefault("k", 16)
    return GraphService.from_graph(g, str(tmp_path / tag), **kw)


def _mk_engine(tmp_path, tag, g, **kw):
    kw.setdefault("num_shards", 6)
    kw.setdefault("window", 128)
    kw.setdefault("k", 16)
    return VSWEngine.from_graph(g, str(tmp_path / tag), **kw)


# ---------------------------------------------------------------- histogram
def test_histogram_quantiles_match_numpy():
    rng = np.random.default_rng(0)
    xs = rng.lognormal(mean=-6.0, sigma=1.5, size=20_000)
    h = Histogram("lat")
    for x in xs:
        h.record(float(x))
    for q in (0.5, 0.95, 0.99):
        exact = float(np.quantile(xs, q))
        est = h.quantile(q)
        assert abs(est - exact) / exact < 0.10, (q, est, exact)
    p = h.percentiles()
    assert p["count"] == len(xs)
    assert p["min"] == pytest.approx(xs.min())
    assert p["max"] == pytest.approx(xs.max())
    assert p["p50"] <= p["p95"] <= p["p99"] <= p["max"]


def test_histogram_edge_cases():
    h = Histogram("h")
    assert h.quantile(0.5) == 0.0  # empty
    h.record(0.0)  # zero-duration sample must not blow up log()
    h.record(-1.0)
    h.record(5.0)
    assert h.count == 3
    assert h.quantile(1.0) == pytest.approx(5.0, rel=0.07)  # bucket width
    with pytest.raises(ValueError):
        h.quantile(1.5)
    h2 = Histogram("h2")
    h2.record(10.0)
    h.merge(h2)
    assert h.count == 4 and h.max == 10.0


# ----------------------------------------------------------------- registry
def test_registry_typed_instruments():
    reg = MetricsRegistry()
    c = reg.counter("a")
    assert isinstance(c, Counter) and reg.counter("a") is c
    c.add(3)
    assert reg.value("a") == 3
    with pytest.raises(ValueError):
        c.add(-1)
    g = reg.gauge("g")
    assert isinstance(g, Gauge)
    g.set(7)
    assert reg.value("g") == 7
    with pytest.raises(TypeError):
        reg.histogram("a")  # name already bound to a Counter


def test_registry_ingests_all_nine_stats_classes():
    from repro.core.ingest import IngestStats
    from repro.delta.recompact import CompactionStats
    from repro.roofline.analysis import CollectiveStats

    reg = MetricsRegistry()
    reg.ingest(IOStats(bytes_read=10, reads=1))
    reg.ingest(CacheStats(hits=2, misses=3))
    reg.ingest(PipelineStats(shards_loaded=5, cache_hits=2))
    reg.ingest(
        ExecStats(
            dispatches=2,
            shards_executed=4,
            device_shards={0: 3, 1: 1},
            device_dispatches={0: 1, 1: 1},
        )
    )
    reg.ingest(
        IterStats(
            iteration=0, time_s=0.1, shards_processed=4, shards_skipped=2,
            bytes_read=100, cache_hits=1, cache_misses=3, active_count=7,
            active_ratio=0.5, selective_on=True, dispatches=2,
            device_shards=(3, 1), device_bytes=(75.0, 25.0),
            device_dispatches=(1, 1),
        )
    )
    reg.ingest(
        SweepIterStats(
            iteration=0, live_lanes=4, shards_processed=4, shards_skipped=0,
            bytes_read=64, selective_on=False, retired=1, backfilled=0,
            time_s=0.05, device_shards=(2, 2), device_bytes=(32.0, 32.0),
        )
    )
    reg.ingest(
        IngestStats(
            num_edges=10, spill_bytes_written=8, spill_bytes_read=8,
            shard_bytes_written=100, meta_bytes_written=20,
        )
    )
    reg.ingest(CompactionStats(shards_compacted=1, runs_absorbed=2))
    reg.ingest(CollectiveStats(bytes_by_kind={"all-gather": 64},
                               count_by_kind={"all-gather": 1}))
    assert reg.verify_conservation() == []
    assert reg.num_checks > 0
    snap = reg.snapshot()
    assert snap["io.bytes_read"] == 10
    assert snap["cache.hits"] == 2
    assert isinstance(snap["iter.time_s"], dict)
    with pytest.raises(TypeError):
        reg.ingest(object())


def test_verify_conservation_catches_violation():
    reg = MetricsRegistry()
    # sum(device_shards) != shards_executed: a mis-attributed mesh split.
    reg.ingest(ExecStats(dispatches=1, shards_executed=5,
                         device_shards={0: 2, 1: 2}))
    with pytest.raises(ConservationError, match="device_shards"):
        reg.verify_conservation()
    assert len(reg.verify_conservation(strict=False)) == 1
    # identities can also be declared directly
    reg2 = MetricsRegistry()
    reg2.check("bytes split", 99.9999999, 100.0, tol=1e-6)
    assert reg2.verify_conservation() == []
    reg2.check("bad", 1.0, 2.0)
    with pytest.raises(ConservationError, match="bad"):
        reg2.verify_conservation()


# ------------------------------------------------------------- tracer basics
def test_disabled_tracing_is_noop():
    assert trace.active() is None
    sp = trace.span("anything", shard=3)
    assert sp is NULL_SPAN
    with sp:
        pass  # no state, no error
    trace.counter("c", 1.0)
    trace.instant("i")


def test_span_nesting_and_wellformedness():
    tr = Tracer()
    with trace.tracing(tr):
        with trace.span("outer", a=1):
            with trace.span("inner"):
                pass
            with trace.span("inner"):
                pass
        assert tr.open_span_count() == 0
    assert trace.active() is None
    out = tr.export_chrome()
    xs = [e for e in out["traceEvents"] if e["ph"] == "X"]
    assert [e["name"] for e in xs] == ["inner", "inner", "outer"]
    outer = xs[-1]
    for inner in xs[:2]:
        assert outer["ts"] <= inner["ts"]
        assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-3
    assert outer["args"] == {"a": 1}


def test_span_error_attribute_and_propagation():
    tr = Tracer()
    with trace.tracing(tr):
        with pytest.raises(RuntimeError, match="boom"):
            with trace.span("fail", shard=9):
                raise RuntimeError("boom")
    assert tr.open_span_count() == 0
    ev = [e for e in tr.export_chrome()["traceEvents"] if e["ph"] == "X"][0]
    assert ev["args"]["shard"] == 9
    assert "boom" in ev["args"]["error"]


def test_ring_overflow_keeps_newest_and_counts_dropped():
    tr = Tracer(capacity=16)
    with trace.tracing(tr):
        for i in range(50):
            with trace.span("s", i=i):
                pass
    out = tr.export_chrome()
    xs = [e for e in out["traceEvents"] if e["ph"] == "X"]
    assert len(xs) == 16
    assert [e["args"]["i"] for e in xs] == list(range(34, 50))
    assert out["otherData"]["dropped_events"] == 34


def test_tracer_thread_rings_are_per_thread():
    tr = Tracer()

    def work():
        # _ACTIVE is a module global: the installed tracer is visible from
        # every thread without per-thread setup.
        with trace.span("t"):
            pass

    th = threading.Thread(target=work, name="obs-test-thread")
    with trace.tracing(tr):
        with trace.span("main"):
            th.start()
            th.join()
    names = tr.thread_names()
    assert "obs-test-thread" in names and len(names) == 2


# ------------------------------------------- end-to-end trace of the service
def _chrome_wellformed(doc, tr):
    """Shared schema assertions for an exported Chrome trace."""
    text = json.dumps(doc)  # must be JSON-serializable as produced
    doc = json.loads(text)
    assert tr.open_span_count() == 0  # every span closed
    evs = doc["traceEvents"]
    assert isinstance(evs, list) and evs
    by_tid = {}
    for e in evs:
        assert e["ph"] in ("M", "X", "C", "i")
        assert isinstance(e["pid"], int) and isinstance(e["tid"], int)
        assert isinstance(e["name"], str) and e["name"]
        if e["ph"] == "M":
            continue
        assert e["ts"] >= 0.0
        if e["ph"] == "X":
            assert e["dur"] >= 0.0
            by_tid.setdefault(e["tid"], []).append(e)
    for tid, xs in by_tid.items():
        # Ring order is record (= close) order per thread: end timestamps
        # are monotonic within a lane.
        ends = [e["ts"] + e["dur"] for e in xs]
        assert all(a <= b + 1e-3 for a, b in zip(ends, ends[1:])), tid
    return doc


def test_traced_mixed_fused_service_run(tmp_path):
    g = rmat_graph(800, 12000, seed=7)
    tr = Tracer()
    with trace.tracing(tr):
        with _mk_service(
            tmp_path, "traced", g,
            max_lanes=4, max_groups=2, auto_compact_runs=1, prefetch_depth=2,
        ) as svc:
            with svc.submit_batch():
                futs = [
                    svc.submit("bfs", 0),
                    svc.submit("sssp", 3),
                    svc.submit("ppr", 5, max_iters=8),
                    svc.submit("bfs", 7),
                ]
            for f in futs:
                f.result()
            svc.apply_updates(inserts=[(1, 2), (3, 4)]).result()
            svc.submit("bfs", 1).result()
            snap = svc.metrics_snapshot()
    doc = _chrome_wellformed(tr.export_chrome(str(tmp_path / "t.json")), tr)
    evs = doc["traceEvents"]
    span_names = {e["name"] for e in evs if e["ph"] == "X"}
    # the admit -> plan -> load -> decode -> dispatch -> retire story
    for required in (
        "service.admit", "sweep.plan", "shard.load", "shard.decode",
        "exec.dispatch", "service.retire", "service.fusion_set",
        "service.publish", "overlay.merge", "store.read",
    ):
        assert required in span_names, required
    # >= 3 thread lanes actually carrying spans
    lanes = {e["tid"] for e in evs if e["ph"] == "X"}
    assert len(lanes) >= 3
    tnames = {e["args"]["name"] for e in evs if e["ph"] == "M"}
    assert "graphserve-worker" in tnames
    assert any(n.startswith("shard-prefetch") for n in tnames)
    # the file on disk is the same valid JSON
    on_disk = json.load(open(tmp_path / "t.json"))
    assert on_disk["traceEvents"]
    # metrics snapshot carries the latency decomposition
    assert snap["query_latency_s"]["count"] == 5
    assert snap["query_latency_s"]["p99"] > 0
    assert snap["conservation_violations"] == []


def test_engine_run_traced_matches_untraced(tmp_path):
    """Tracing must not perturb results: same sweep, bitwise outputs."""
    from repro.core.apps import bfs

    g = rmat_graph(500, 7000, seed=11)
    with _mk_engine(tmp_path, "a", g, prefetch_depth=2) as eng:
        base = eng.run(bfs(0), max_iters=20)
    tr = Tracer()
    with trace.tracing(tr):
        with _mk_engine(tmp_path, "b", g, prefetch_depth=2) as eng:
            traced = eng.run(bfs(0), max_iters=20)
    assert np.array_equal(base.values, traced.values)
    assert base.converged == traced.converged
    assert tr.event_count() > 0


# ----------------------------------------------- satellite: queue-wait split
def test_query_latency_decomposition(tmp_path):
    g = rmat_graph(600, 9000, seed=3)
    # max_lanes=1: later queries MUST wait for a slot, so queue_wait > 0.
    with _mk_service(tmp_path, "lat", g, max_lanes=1, max_groups=1,
                     session_entries=0) as svc:
        with svc.submit_batch():
            futs = [svc.submit("bfs", s) for s in (0, 3, 9)]
        rs = [f.result() for f in futs]
    for r in rs:
        assert r.queue_wait_s >= 0.0 and r.sweep_s >= 0.0
        assert r.latency_s == pytest.approx(
            r.queue_wait_s + r.sweep_s, rel=1e-6, abs=1e-6
        )
    # the last-served query waited for earlier sweeps/backfills
    assert max(r.queue_wait_s for r in rs) > 0.0


def test_cached_hit_reports_zero_queue_wait(tmp_path):
    g = rmat_graph(400, 5000, seed=5)
    with _mk_service(tmp_path, "cache", g) as svc:
        first = svc.query("bfs", 2)
        assert not first.cached
        hit = svc.query("bfs", 2)
    assert hit.cached
    assert hit.queue_wait_s == 0.0 and hit.sweep_s == 0.0
    assert hit.latency_s >= 0.0


# ------------------------------- satellite: prefetch exception propagation
def _poison(eng, bad_shard):
    """Make one shard unreadable, forcing every load through the store."""
    orig = eng.store.shard_bytes

    def poisoned(p, fmt="csr"):
        if p == bad_shard:
            raise OSError(f"disk hole at shard {p}")
        return orig(p, fmt)

    eng.store.shard_bytes = poisoned
    eng.pipeline.cache = None  # no warm-cache bypass of the store
    eng.pipeline.resident = None


@pytest.mark.parametrize("depth", [0, 2])
def test_shard_load_error_carries_shard_id(tmp_path, depth):
    from repro.core.apps import bfs

    g = rmat_graph(500, 7000, seed=13)
    with _mk_engine(tmp_path, f"err{depth}", g, prefetch_depth=depth,
                    selective=False) as eng:
        _poison(eng, bad_shard=4)
        with pytest.raises(ShardLoadError) as ei:
            eng.run(bfs(0), max_iters=3)
    assert ei.value.shard_id == 4
    assert isinstance(ei.value.__cause__, OSError)
    assert "shard 4" in str(ei.value)


def test_shard_load_error_span_recorded(tmp_path):
    from repro.core.apps import bfs

    g = rmat_graph(500, 7000, seed=13)
    tr = Tracer()
    with trace.tracing(tr):
        with _mk_engine(tmp_path, "errspan", g, prefetch_depth=2,
                        selective=False) as eng:
            _poison(eng, bad_shard=2)
            with pytest.raises(ShardLoadError):
                eng.run(bfs(0), max_iters=3)
    # close() shuts the prefetch pool down without waiting; give in-flight
    # loads (whose shard.load spans are open on the prefetch threads) a
    # moment to drain before asserting everything closed.
    deadline = time.monotonic() + 5.0
    while tr.open_span_count() and time.monotonic() < deadline:
        time.sleep(0.01)
    assert tr.open_span_count() == 0  # error paths still close spans
    evs = tr.export_chrome()["traceEvents"]
    errs = [
        e for e in evs
        if e["ph"] == "X" and e["name"] == "shard.load"
        and "error" in e.get("args", {})
    ]
    assert errs and any(e["args"]["shard"] == 2 for e in errs)


# ------------------- satellite: conservation on fused mesh sweep + updates
def test_conservation_fused_mesh_sweep_with_updates(tmp_path):
    g = rmat_graph(900, 14000, seed=21)
    with _mk_service(
        tmp_path, "mesh", g,
        backend="numpy", mesh=4, max_lanes=4, max_groups=2,
        session_entries=0,
    ) as svc:
        with svc.submit_batch():
            futs = [
                svc.submit("bfs", 0),
                svc.submit("sssp", 5),
                svc.submit("ppr", 9, max_iters=6),
            ]
        for f in futs:
            f.result()
        svc.apply_updates(inserts=[(10, 11), (12, 13)],
                          deletes=[(0, 1)]).result()
        with svc.submit_batch():
            futs = [svc.submit("bfs", 2), svc.submit("wcc", 0)]
        for f in futs:
            f.result()
        snap = svc.metrics_snapshot()
        # mesh sweeps declared per-iteration device identities; replaying
        # them is THE shared conservation check (no per-test ad-hoc sums)
        assert svc.metrics.num_checks > 0
        assert svc.metrics.verify_conservation() == []
    assert snap["conservation_violations"] == []
    assert snap["stages"]["iter_s"]["count"] > 0
    assert snap["query_latency_s"]["count"] == 5
    assert snap["queue_wait_s"]["count"] == 5
