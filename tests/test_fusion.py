"""Cross-query fusion: heterogeneous programs on one shard stream.

The fusion contract extends PR 2's (test_serve.py): mixing programs in a
sweep — same-algebra programs FUSED into one lane table, different algebra
groups INTERLEAVED on one stream — must be invisible in the results.
Every query must be bitwise-equal to the same query run alone on a
single-query engine, across programs (BFS / SSSP / WCC / PPR), backends,
mid-sweep retirement and cross-group backfill, and graph updates between
sweeps.  Cost attribution must be mask-aware AND conserved: the per-lane
bytes/loads of a sweep sum to exactly what the sweep read.

jax-backend tests carry ``e2e`` in their names so the RLIMIT_AS runner
(run_memcapped.py) can exclude them.
"""

import dataclasses
import math
from collections import deque

import numpy as np
import pytest

from repro.core import apps
from repro.core.cache import CacheStats, mode_iteration_cost
from repro.core.graph import Graph, chain_graph, rmat_graph
from repro.core.sharding import preprocess
from repro.core.vsw import VSWEngine
from repro.serve import FusedSweep, GraphService, LaneBatcher, LaneSeed

# (program, source) workloads mixing all three min-algebra programs + PPR
MIXED = [("bfs", 0), ("sssp", 3), ("wcc", 1), ("ppr", 5), ("bfs", 7),
         ("ppr", 11), ("sssp", 2), ("wcc", 9)]


def _norm(v):
    return np.nan_to_num(v, posinf=1e30)


def _mk_service(tmp_path, tag, g, **kw):
    kw.setdefault("num_shards", 6)
    kw.setdefault("window", 128)
    kw.setdefault("k", 16)
    return GraphService.from_graph(g, str(tmp_path / tag), **kw)


def _mk_engine(tmp_path, tag, g, **kw):
    kw.setdefault("num_shards", 6)
    kw.setdefault("window", 128)
    kw.setdefault("k", 16)
    return VSWEngine.from_graph(g, str(tmp_path / tag), **kw)


def _solo(eng, program, source, max_iters):
    kw = {} if program == "wcc" else {"source": source}
    return eng.run(apps.get_program(program, **kw), max_iters=max_iters)


# ------------------------------------------------------------ program keys
def test_combine_key_splits_from_program_key():
    bfs, sssp, wcc = apps.lane_bfs(), apps.lane_sssp(), apps.lane_wcc()
    ppr1, ppr2 = apps.lane_ppr(0.85), apps.lane_ppr(0.5)
    # same algebra -> same fusion identity, distinct full keys
    assert bfs.combine_key == sssp.combine_key == wcc.combine_key == ("min",)
    assert len({bfs.key, sssp.key, wcc.key}) == 3
    # PPR variants fuse with each other but never with the min programs
    assert ppr1.combine_key == ppr2.combine_key == ("sum",)
    assert ppr1.key != ppr2.key
    assert ppr1.combine_key != bfs.combine_key


def test_lane_wcc_matches_vertex_program_oracle(tmp_path):
    g = rmat_graph(300, 3000, seed=60)
    eng = _mk_engine(tmp_path, "wccref", g, backend="numpy")
    svc = _mk_service(tmp_path, "wccsvc", g, backend="numpy", max_lanes=4)
    qr = svc.query("wcc", 0, max_iters=50)
    ref = eng.run(apps.wcc(), max_iters=50)
    assert np.array_equal(_norm(qr.values), _norm(ref.values))
    assert qr.converged == ref.converged
    svc.close()
    eng.close()


# -------------------------------------------------------- batcher formation
def test_batcher_forms_fusion_sets():
    @dataclasses.dataclass
    class P:
        key: tuple
        combine_key: tuple
        n: int

    def mk(name, ck, n):
        return P((name,), ck, n)

    pending = deque([
        mk("bfs", ("min",), 0), mk("ppr", ("sum",), 1),
        mk("sssp", ("min",), 2), mk("wcc", ("min",), 3),
        mk("ppr", ("sum",), 4), mk("bfs", ("min",), 5),
    ])
    b = LaneBatcher(max_lanes=3, max_groups=2)
    groups = b.form_fused(pending)
    # group 0: oldest request's algebra (min), capped at max_lanes;
    # group 1: the next algebra in FIFO order (sum)
    assert [p.n for p in groups[0]] == [0, 2, 3]
    assert [p.n for p in groups[1]] == [1, 4]
    assert [p.n for p in pending] == [5]  # leftover keeps order

    # key-only mode restores PR 2 batching: identical program keys only
    pending = deque([
        mk("bfs", ("min",), 0), mk("sssp", ("min",), 1),
        mk("bfs", ("min",), 2),
    ])
    b = LaneBatcher(max_lanes=4, max_groups=1, fuse_programs=False)
    groups = b.form_fused(pending)
    assert [p.n for p in groups[0]] == [0, 2]
    assert [p.n for p in pending] == [1]


# ------------------------------------------------- fused same-algebra sweeps
def test_fused_min_programs_single_sweep_bitwise(tmp_path):
    """BFS + SSSP + WCC share one lane table and ONE sweep; every result
    bitwise-equals its solo single-query run."""
    g = rmat_graph(500, 6000, seed=61)
    svc = _mk_service(tmp_path, "svc", g, backend="numpy", max_lanes=8,
                      max_groups=1)
    eng = _mk_engine(tmp_path, "eng", g, backend="numpy")
    cases = [(p, s) for p, s in MIXED if p != "ppr"]
    with svc.submit_batch():
        futs = [svc.submit(p, s, max_iters=25) for p, s in cases]
    for (p, s), f in zip(cases, futs):
        qr = f.result(timeout=120)
        ref = _solo(eng, p, s, 25)
        assert np.array_equal(_norm(qr.values), _norm(ref.values)), (p, s)
        assert qr.iterations == ref.num_iterations
        assert qr.converged == ref.converged
    assert svc.stats()["sweeps"] == 1  # all three programs fused
    svc.close()
    eng.close()


def test_interleaved_groups_single_sweep_bitwise(tmp_path):
    """min-algebra and PPR groups interleave on ONE shard stream."""
    g = rmat_graph(500, 6000, seed=62)
    svc = _mk_service(tmp_path, "svc", g, backend="numpy", max_lanes=8,
                      max_groups=2)
    eng = _mk_engine(tmp_path, "eng", g, backend="numpy")
    with svc.submit_batch():
        futs = [svc.submit(p, s, max_iters=20) for p, s in MIXED]
    for (p, s), f in zip(MIXED, futs):
        qr = f.result(timeout=120)
        ref = _solo(eng, p, s, 20)
        assert np.array_equal(_norm(qr.values), _norm(ref.values)), (p, s)
        assert qr.groups == 2
    st = svc.stats()
    assert st["sweeps"] == 1 and st["multi_group_sweeps"] == 1
    svc.close()
    eng.close()


@pytest.mark.parametrize("backend,batch_shards", [("jnp", 1), ("jnp", 3),
                                                  ("pallas", 2)])
def test_interleaved_groups_bitwise_e2e(tmp_path, backend, batch_shards):
    """Fusion + interleaving + shard batching on the ELL backends: each
    query equals the same backend's single-query run bitwise."""
    g = rmat_graph(300, 3500, seed=63)
    svc = _mk_service(tmp_path, f"s{backend}{batch_shards}", g, num_shards=5,
                      backend=backend, max_lanes=8, max_groups=2,
                      batch_shards=batch_shards)
    eng = _mk_engine(tmp_path, f"e{backend}{batch_shards}", g, num_shards=5,
                     backend=backend, batch_shards=batch_shards)
    cases = [("bfs", 2), ("wcc", 0), ("ppr", 3), ("sssp", 1), ("ppr", 9)]
    with svc.submit_batch():
        futs = [svc.submit(p, s, max_iters=12) for p, s in cases]
    for (p, s), f in zip(cases, futs):
        qr = f.result(timeout=240)
        ref = _solo(eng, p, s, 12)
        assert np.array_equal(_norm(qr.values), _norm(ref.values)), (p, s)
    assert svc.stats()["sweeps"] == 1
    svc.close()
    eng.close()


# -------------------------------------------- retirement / cross-group fill
def test_retirement_and_backfill_across_groups(tmp_path):
    """Early-retiring lanes in each group are backfilled from the queue
    mid-sweep — min-algebra and PPR queues drain through ONE sweep."""
    n = 64
    g = chain_graph(n)
    svc = _mk_service(tmp_path, "bf", g, num_shards=4, backend="numpy",
                      max_lanes=3, max_groups=2)
    # 4 min-algebra queries (chain sources converge at wildly different
    # iterations) interleaved with 3 PPR queries, on 3 lanes per group:
    # bfs source 0 overflows group 0 and must be backfilled mid-sweep.
    cases = [("bfs", 60), ("ppr", 0), ("bfs", 55), ("ppr", 1),
             ("bfs", 40), ("ppr", 2), ("bfs", 0)]
    with svc.submit_batch():
        futs = [svc.submit(p, s, max_iters=200 if p == "bfs" else 6)
                for p, s in cases]
    eng = _mk_engine(tmp_path, "bfref", g, num_shards=4, backend="numpy")
    for (p, s), f in zip(cases, futs):
        qr = f.result(timeout=240)
        ref = _solo(eng, p, s, 200 if p == "bfs" else 6)
        assert np.array_equal(_norm(qr.values), _norm(ref.values)), (p, s)
    st = svc.stats()
    assert st["sweeps"] == 1 and st["queries_completed"] == 7
    svc.close()
    eng.close()


def test_fused_sweep_direct_backfill_and_zero_budget(tmp_path):
    """FusedSweep API: per-group backfill callbacks, zero-budget seeds
    finished at admission (initial AND backfilled) without taking lanes."""
    g = chain_graph(48)
    eng = _mk_engine(tmp_path, "direct", g, num_shards=4, backend="numpy")
    bfs, ppr = apps.lane_bfs(), apps.lane_ppr()
    queues = {
        0: [LaneSeed(source=20, max_iters=0, token="z1", program=bfs),
            LaneSeed(source=1, max_iters=200, token="b1", program=bfs)],
        1: [LaneSeed(source=3, max_iters=0, token="z2", program=ppr)],
    }

    def backfill(group, n_free):
        out = queues[group][:n_free]
        del queues[group][:n_free]
        return out

    sweep = FusedSweep(eng)
    results = sweep.run(
        [[LaneSeed(source=44, max_iters=200, token="b0", program=bfs),
          LaneSeed(source=40, max_iters=0, token="z0", program=bfs)],
         [LaneSeed(source=0, max_iters=4, token="p0", program=ppr)]],
        backfill=backfill,
    )
    by_token = {r.token: r for r in results}
    assert set(by_token) == {"b0", "b1", "p0", "z0", "z1", "z2"}
    # zero-budget parity: init values, zero iterations, not converged
    for tok, src, prog in (("z0", 40, "bfs"), ("z1", 20, "bfs"),
                           ("z2", 3, "ppr")):
        r = by_token[tok]
        assert r.iterations == 0 and not r.converged
        assert r.bytes_read == 0.0 and r.shard_loads == 0.0
        ref = _solo(eng, prog, src, 0)
        assert np.array_equal(_norm(r.values), _norm(ref.values))
    # live lanes still bitwise vs solo
    for tok, src, prog, iters in (("b0", 44, "bfs", 200),
                                  ("b1", 1, "bfs", 200), ("p0", 0, "ppr", 4)):
        ref = _solo(eng, prog, src, iters)
        assert np.array_equal(_norm(by_token[tok].values), _norm(ref.values))
    assert sum(s.backfilled for s in sweep.iter_stats) == 1  # only b1
    eng.close()


def test_service_zero_budget_matches_engine(tmp_path):
    g = rmat_graph(200, 2000, seed=64)
    svc = _mk_service(tmp_path, "zb", g, backend="numpy", max_lanes=2)
    eng = _mk_engine(tmp_path, "zbref", g, backend="numpy")
    qr = svc.query("wcc", 5, max_iters=0)
    ref = eng.run(apps.wcc(), max_iters=0)
    assert qr.iterations == 0 and not qr.converged
    assert np.array_equal(_norm(qr.values), _norm(ref.values))
    svc.close()
    eng.close()


# ----------------------------------------------------- cost attribution
def test_cost_attribution_conserved_and_mask_aware(tmp_path):
    """Per-lane bytes/loads sum to the sweep totals exactly, and a lane
    masked out of most of the stream is charged less than an always-on
    lane (ROADMAP mask-aware cost attribution follow-on)."""
    n = 96
    g = chain_graph(n)
    eng = _mk_engine(tmp_path, "cost", g, num_shards=6, backend="numpy",
                     threshold=1.0, cache_bytes=0)
    bfs, wcc = apps.lane_bfs(), apps.lane_wcc()
    sweep = FusedSweep(eng)
    results = sweep.run(
        [[LaneSeed(source=90, max_iters=300, token="fast", program=bfs),
          LaneSeed(source=0, max_iters=300, token="slow", program=bfs),
          LaneSeed(source=1, max_iters=300, token="dense", program=wcc)]],
    )
    total_loads = sum(s.shards_processed for s in sweep.iter_stats)
    total_bytes = sum(s.bytes_read for s in sweep.iter_stats)
    got_loads = sum(r.shard_loads for r in results)
    got_bytes = sum(r.bytes_read for r in results)
    assert math.isclose(got_loads, total_loads, rel_tol=1e-9)
    assert math.isclose(got_bytes, total_bytes, rel_tol=1e-9)
    # mask-awareness: the BFS frontier near the chain end touches one
    # shard per iteration while WCC's dense frontier needs all of them —
    # even-split attribution would charge both lanes identically.
    by = {r.token: r for r in results}
    assert by["fast"].shard_loads < by["dense"].shard_loads
    assert sum(s.lane_rows_skipped for s in sweep.iter_stats) > 0
    eng.close()


def test_plan_lane_shares_sum_to_planned(tmp_path):
    g = rmat_graph(600, 4000, seed=65)
    eng = _mk_engine(tmp_path, "shares", g, num_shards=8, backend="numpy",
                     threshold=1.0)
    lane_active = [np.array([3], dtype=np.int64),
                   np.array([577], dtype=np.int64),
                   np.arange(0, 600, 7, dtype=np.int64)]
    union = np.unique(np.concatenate(lane_active))
    plan = eng.scheduler.plan(union, lane_active=lane_active)
    shares = plan.lane_shares(3)
    assert shares.shape == (3,)
    assert math.isclose(shares.sum(), plan.num_planned, rel_tol=1e-9)
    # unmasked plans split evenly
    full = eng.scheduler.plan(np.arange(600, dtype=np.int64))
    assert np.allclose(full.lane_shares(4), full.num_planned / 4)
    assert full.lane_shares(0).shape == (0,)
    eng.close()


# ------------------------------------------------- updates between sweeps
def test_apply_updates_between_fused_sweeps_per_version_oracle(tmp_path):
    """Mixed-program serving across a live mutation: every result must
    match a from-scratch engine built at exactly its graph_version."""
    rng = np.random.default_rng(66)
    num_v, num_e = 300, 3000
    g = rmat_graph(num_v, num_e, seed=66)
    svc = _mk_service(tmp_path, "upd", g, backend="numpy", max_lanes=4,
                      max_groups=2, session_entries=0)

    cases = [("bfs", 3), ("wcc", 0), ("ppr", 7), ("sssp", 11)]
    # resolved BEFORE the update is even staged: deterministically version 0
    with svc.submit_batch():
        futs_pre = [svc.submit(p, s, max_iters=15) for p, s in cases]
    res_pre = [f.result(timeout=240) for f in futs_pre]

    # stage a mutation while a fresh batch may or may not have formed: the
    # version TAG on each result decides which oracle it must match
    with svc.submit_batch():
        futs0 = [svc.submit(p, s + 20, max_iters=15) for p, s in cases]
    take = rng.choice(num_e, 200, replace=False)
    dels = (g.src[take], g.dst[take])
    ins = (rng.integers(0, num_v, 150).astype(np.int32),
           rng.integers(0, num_v, 150).astype(np.int32))
    upd = svc.apply_updates(inserts=ins, deletes=dels).result(timeout=240)
    assert upd.graph_version == 1

    # submitted after the publish resolved: deterministically version 1
    with svc.submit_batch():
        futs1 = [svc.submit(p, s, max_iters=15) for p, s in cases]
    res0 = [f.result(timeout=240) for f in futs0]
    res1 = [f.result(timeout=240) for f in futs1]

    # version-1 edge state (delete = all copies, deletes before inserts)
    tomb = np.unique((dels[1].astype(np.int64) << 32)
                     | dels[0].astype(np.int64))
    keys = (g.dst.astype(np.int64) << 32) | g.src.astype(np.int64)
    pos = np.minimum(np.searchsorted(tomb, keys), len(tomb) - 1)
    keep = tomb[pos] != keys
    g1 = Graph(num_v,
               np.concatenate([g.src[keep], ins[0]]).astype(np.int32),
               np.concatenate([g.dst[keep], ins[1]]).astype(np.int32))
    oracles = {0: _mk_engine(tmp_path, "v0", g, backend="numpy"),
               1: _mk_engine(tmp_path, "v1", g1, backend="numpy")}
    checks = (
        [(p, s, qr) for (p, s), qr in zip(cases, res_pre)]
        + [(p, s + 20, qr) for (p, s), qr in zip(cases, res0)]
        + [(p, s, qr) for (p, s), qr in zip(cases, res1)]
    )
    for p, s, qr in checks:
        eng = oracles[qr.graph_version]
        ref = _solo(eng, p, s, 15)
        assert np.array_equal(_norm(qr.values), _norm(ref.values)), (
            p, s, qr.graph_version)
    assert all(q.graph_version == 0 for q in res_pre)
    assert all(q.graph_version == 1 for q in res1)
    for eng in oracles.values():
        eng.close()
    svc.close()


# ------------------------------------------------------- property stress
def test_property_mixed_workload_stress(tmp_path):
    """Seeded random mixed workloads: any combination of programs, sources
    and budgets, with more queries than lanes (forcing retirement +
    backfill across groups), stays bitwise vs solo."""
    g = rmat_graph(400, 5000, seed=67)
    eng = _mk_engine(tmp_path, "stressref", g, backend="numpy")
    refs = {}
    for trial in range(3):
        rng = np.random.default_rng(100 + trial)
        svc = _mk_service(tmp_path, f"stress{trial}", g, backend="numpy",
                          max_lanes=4, max_groups=2, session_entries=0)
        progs = ["bfs", "sssp", "wcc", "ppr"]
        cases = []
        for _ in range(12):
            p = progs[int(rng.integers(len(progs)))]
            s = int(rng.integers(g.num_vertices))
            iters = int(rng.integers(0, 18))
            cases.append((p, s, iters))
        with svc.submit_batch():
            futs = [svc.submit(p, s, max_iters=it) for p, s, it in cases]
        for (p, s, it), f in zip(cases, futs):
            qr = f.result(timeout=240)
            ck = (p, s, it)
            if ck not in refs:
                refs[ck] = _solo(eng, p, s, it)
            ref = refs[ck]
            assert np.array_equal(_norm(qr.values), _norm(ref.values)), ck
            assert qr.iterations == ref.num_iterations
        svc.close()
    eng.close()


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_property_mixed_workload_stress_backends_e2e(tmp_path, backend):
    g = rmat_graph(250, 2500, seed=68)
    eng = _mk_engine(tmp_path, f"pref{backend}", g, num_shards=4,
                     backend=backend, batch_shards=2)
    svc = _mk_service(tmp_path, f"p{backend}", g, num_shards=4,
                      backend=backend, batch_shards=2, max_lanes=4,
                      max_groups=2, session_entries=0)
    rng = np.random.default_rng(69)
    progs = ["bfs", "sssp", "wcc", "ppr"]
    cases = [(progs[int(rng.integers(len(progs)))],
              int(rng.integers(g.num_vertices)), int(rng.integers(1, 10)))
             for _ in range(8)]
    with svc.submit_batch():
        futs = [svc.submit(p, s, max_iters=it) for p, s, it in cases]
    for (p, s, it), f in zip(cases, futs):
        qr = f.result(timeout=300)
        ref = _solo(eng, p, s, it)
        assert np.array_equal(_norm(qr.values), _norm(ref.values)), (p, s, it)
    svc.close()
    eng.close()


# ------------------------------------------------------- executor layer
def test_run_groups_matches_per_group_run():
    """PerShardExecutor.run_groups == one run() per group, bitwise; None
    entries produce no dispatch."""
    from repro.core.executor import ExecStats, make_lane_executor
    from repro.core.pipeline import LoadedShard

    g = rmat_graph(300, 4000, seed=70)
    meta, shards = preprocess(g, num_shards=3)
    rng = np.random.default_rng(2)
    msgs_a = rng.random((4, meta.num_vertices)).astype(np.float32)
    msgs_b = rng.random((2, meta.num_vertices)).astype(np.float32)
    loaded = [LoadedShard(s.shard_id, s, None) for s in shards]
    ex = make_lane_executor("numpy")
    stats = ExecStats()
    got = {}
    for gi, res in ex.run_groups(
        loaded, [(msgs_a, "min"), None, (msgs_b, "sum")], stats
    ):
        got.setdefault(gi, []).append(res)
    assert set(got) == {0, 2}
    assert stats.dispatches == 2 * len(shards)
    for gi, msgs, combine in ((0, msgs_a, "min"), (2, msgs_b, "sum")):
        solo = list(ex.run(loaded, msgs, combine))
        for a, b in zip(got[gi], solo):
            assert a.shard_id == b.shard_id
            assert np.array_equal(a.acc, b.acc)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_batched_run_groups_matches_per_group_run_e2e(backend):
    """BatchedEllExecutor.run_groups (one concat, G dispatches) must be
    bitwise the per-group batched dispatch."""
    from repro.core.csr import csr_to_ell
    from repro.core.executor import make_lane_executor
    from repro.core.pipeline import LoadedShard

    g = rmat_graph(250, 3000, seed=71)
    meta, shards = preprocess(g, num_shards=4)
    ells = [csr_to_ell(s, meta.num_vertices, window=64, k=8, tr=8)
            for s in shards]
    loaded = [LoadedShard(s.shard_id, None, e) for s, e in zip(shards, ells)]
    rng = np.random.default_rng(3)
    msgs_a = rng.random((2, meta.num_vertices)).astype(np.float32)
    msgs_b = rng.random((4, meta.num_vertices)).astype(np.float32)
    ex = make_lane_executor(backend, batch_shards=3)
    got = {}
    for gi, res in ex.run_groups(loaded, [(msgs_a, "sum"), (msgs_b, "min")]):
        got.setdefault(gi, []).append(res)
    for gi, msgs, combine in ((0, msgs_a, "sum"), (1, msgs_b, "min")):
        solo = list(ex.run(loaded, msgs, combine))
        for a, b in zip(got[gi], solo):
            assert a.shard_id == b.shard_id
            assert np.array_equal(a.acc, b.acc)


# ----------------------------------------------------- cache-model fixes
def test_mode_iteration_cost_amortizes_compression():
    """The one-time compression cost must count, amortized over the cache
    lifetime — the pre-fix model dropped it entirely."""
    # everything fits cached either way; raw has zero codec cost
    base = dict(capacity_bytes=1 << 30, total_raw_bytes=1 << 20,
                disk_bw=100e6)
    raw = mode_iteration_cost(1.0, 0.0, 0.0, **base)
    # a codec with heavy compression cost and cheap decompression: with a
    # short lifetime the compression dominates; amortized over a long
    # lifetime it fades
    slow_short = mode_iteration_cost(4.0, 1e-6, 1e-9, lifetime_iters=1,
                                     **base)
    slow_long = mode_iteration_cost(4.0, 1e-6, 1e-9, lifetime_iters=1000,
                                    **base)
    assert raw < slow_short  # compression cost now visible
    assert slow_long < slow_short  # and amortized by lifetime
    # when compression unlocks hit rate, it still wins despite its cost
    tight = dict(capacity_bytes=1 << 18, total_raw_bytes=1 << 20,
                 disk_bw=100e6)
    assert (mode_iteration_cost(4.0, 1e-8, 1e-9, **tight)
            < mode_iteration_cost(1.0, 0.0, 0.0, **tight))


def test_select_cache_mode_still_prefers_raw_when_everything_fits():
    from repro.core.cache import select_cache_mode

    compressible = b"xy" * 100_000
    assert select_cache_mode(compressible, capacity_bytes=1 << 30,
                             total_raw_bytes=200_000) == 1


def test_cache_stats_reset_clears_eviction_and_time_counters():
    st = CacheStats(hits=3, misses=4, evictions=5,
                    inserted_bytes_raw=100, inserted_bytes_stored=50,
                    compress_time_s=1.5, decompress_time_s=2.5)
    st.reset_counters()
    assert st.hits == st.misses == st.evictions == 0
    assert st.compress_time_s == 0.0 and st.decompress_time_s == 0.0
    # capacity-describing fields survive a counter reset
    assert st.inserted_bytes_raw == 100 and st.inserted_bytes_stored == 50


# ------------------------------------------------------------- amortization
def test_fused_sweep_reads_less_than_per_group_sweeps(tmp_path):
    """The acceptance direction of fig_fusion at test scale: a mixed
    workload served fused+interleaved reads fewer bytes per query than
    PR 2 key-equality batching (per-group sweeps)."""
    g = rmat_graph(400, 6000, seed=72)
    workload = [("bfs", 0), ("sssp", 1), ("ppr", 2), ("bfs", 3),
                ("ppr", 4), ("sssp", 5), ("wcc", 6), ("ppr", 7)]
    bytes_per_query = {}
    for mode, kw in (
        ("baseline", dict(fuse_programs=False, max_groups=1)),
        ("fused", dict(fuse_programs=True, max_groups=1)),
        ("interleaved", dict(fuse_programs=True, max_groups=2)),
    ):
        svc = _mk_service(tmp_path, mode, g, backend="numpy", max_lanes=8,
                          session_entries=0, cache_bytes=0, **kw)
        with svc.submit_batch():
            futs = [svc.submit(p, s, max_iters=6) for p, s in workload]
        for f in futs:
            f.result(timeout=240)
        st = svc.stats()
        bytes_per_query[mode] = st["bytes_read_total"] / len(workload)
        svc.close()
    assert bytes_per_query["fused"] < bytes_per_query["baseline"]
    assert bytes_per_query["interleaved"] < bytes_per_query["baseline"]
    assert bytes_per_query["interleaved"] < bytes_per_query["fused"]
