"""Baseline engines (PSW/ESG/DSW) must equal VSW numerically, and their
measured I/O must follow the Table II ordering (PSW > ESG > DSW > VSW)."""

import numpy as np
import pytest

from repro.core import apps
from repro.core.baselines.engines import (
    DSWEngine,
    ESGEngine,
    PSWEngine,
    prepare_baseline_store,
)
from repro.core.baselines.io_model import IOParams, MODELS, io_table
from repro.core.graph import rmat_graph
from repro.core.vsw import VSWEngine


@pytest.fixture(scope="module")
def setup(tmp_path_factory):
    g = rmat_graph(400, 5000, seed=7)
    d1 = tmp_path_factory.mktemp("vsw")
    d2 = tmp_path_factory.mktemp("base")
    vsw = VSWEngine.from_graph(
        g, str(d1), num_shards=6, window=128, k=16,
        backend="numpy", selective=False,
    )
    store = prepare_baseline_store(g, str(d2), num_shards=6)
    return g, vsw, store


@pytest.mark.parametrize("prog_name,iters", [
    ("pagerank", 10), ("sssp", 25), ("wcc", 40),
])
@pytest.mark.parametrize("engine_cls", [PSWEngine, ESGEngine, DSWEngine])
def test_baseline_matches_vsw(setup, prog_name, iters, engine_cls):
    g, vsw, store = setup
    prog = apps.get_program(prog_name) if prog_name != "sssp" else apps.sssp(0)
    ref = vsw.run(prog, max_iters=iters).values
    got = engine_cls(store).run(prog, max_iters=iters).values
    a = np.nan_to_num(got, posinf=1e30)
    b = np.nan_to_num(ref, posinf=1e30)
    assert np.allclose(a, b, atol=1e-6)


def test_io_ordering_matches_table2(setup):
    """Measured per-iteration read volume must order PSW > ESG > DSW > VSW=0
    (with cold cache VSW reads only edges; baselines read edges + values)."""
    g, vsw, store = setup
    prog = apps.pagerank()
    reads = {}
    for name, cls in (("psw", PSWEngine), ("esg", ESGEngine), ("dsw", DSWEngine)):
        io0 = store.io.snapshot()
        r = cls(store).run(prog, max_iters=3)
        d = store.io - io0
        reads[name] = d.bytes_read / r.num_iterations
        if name == "psw":
            writes_psw = d.bytes_written / r.num_iterations
    rv = vsw.run(prog, max_iters=3)
    reads["vsw"] = rv.total_bytes_read / rv.num_iterations

    assert reads["psw"] > reads["esg"] > reads["dsw"] > 0
    assert reads["vsw"] < reads["dsw"]  # SEM: no vertex traffic
    assert writes_psw > 0  # PSW rewrites edges; VSW writes nothing
    w0 = vsw.store.io.bytes_written
    vsw.run(prog, max_iters=2)
    assert vsw.store.io.bytes_written == w0


def test_analytic_model_rows():
    p = IOParams(C=4, D=8, V=1.1e9, E=91.8e9, P=4096, N=24, theta=0.3)
    t = io_table(p)
    # paper Table II qualitative claims:
    assert t["vsw"]["write"] == 0
    assert t["vsw"]["read"] < t["dsw"]["read"] < t["esg"]["read"] < t["psw"]["read"]
    assert t["vsw"]["memory"] > t["esg"]["memory"]  # SEM trades memory for I/O
    # VSW read = theta * D * E exactly
    assert np.isclose(t["vsw"]["read"], 0.3 * 8 * 91.8e9)


def test_analytic_vs_measured_edge_term(setup):
    """The D|E| edge-stream term must dominate measured DSW/ESG reads and be
    within 2x of the analytic prediction (container overheads allowed)."""
    g, vsw, store = setup
    prog = apps.pagerank()
    P = store.read_meta().num_shards
    params = IOParams(C=4, D=8, V=g.num_vertices, E=g.num_edges, P=P)
    io0 = store.io.snapshot()
    r = ESGEngine(store).run(prog, max_iters=3)
    measured = (store.io - io0).bytes_read / r.num_iterations
    predicted = MODELS["esg"].read(params)
    assert 0.5 < measured / predicted < 2.5
