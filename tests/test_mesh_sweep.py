"""Mesh-sharded VSW sweeps: one host read, D device slices (DESIGN.md §10).

The mesh contract, tested three ways:

1. **Partition algebra** — :func:`equal_device_bounds` /
   :class:`MeshPartition` put every destination interval on exactly one
   device (the paper's lock-free property lifted to SPMD), and the
   device-layout builders (legacy ``build_device_graph`` vs the PR 3-era
   ``build_device_graph_from_store``) agree bitwise.
2. **Bitwise sweeps** — an engine/service booted with ``mesh=D`` produces
   results bitwise-equal to the single-device run of the same backend for
   BFS / SSSP / PPR / WCC at D ∈ {1, 2, 8}, through mid-sweep lane
   retirement/backfill and ``apply_updates`` between sweeps.  The numpy
   mesh EMULATION (no jax — safe under run_memcapped) is compared against
   the numpy oracle directly; jnp/pallas run in a subprocess under
   ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (``e2e`` names,
   like test_distributed_vsw.py).
3. **Conserved attribution** — per-device shard/dispatch/bytes stats sum
   to the sweep totals: the host read each shard ONCE, sliced per device,
   never once per device.

jax-touching tests carry ``e2e`` in their names so the RLIMIT_AS runner
(run_memcapped.py) can exclude them.
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import apps
from repro.core.distributed import (
    MeshPartition,
    build_device_graph,
    build_device_graph_from_store,
    equal_device_bounds,
)
from repro.core.graph import Graph, chain_graph, rmat_graph, uniform_graph
from repro.core.ingest import pack_keys
from repro.core.vsw import VSWEngine
from repro.serve import FusedSweep, GraphService, LaneSeed, MeshSweep

MESH_SIZES = (1, 2, 8)


def _norm(v):
    return np.nan_to_num(v, posinf=1e30)


def _mk_engine(tmp_path, tag, g, **kw):
    kw.setdefault("num_shards", 6)
    kw.setdefault("window", 128)
    kw.setdefault("k", 16)
    return VSWEngine.from_graph(g, str(tmp_path / tag), **kw)


def _mk_service(tmp_path, tag, g, **kw):
    kw.setdefault("num_shards", 6)
    kw.setdefault("window", 128)
    kw.setdefault("k", 16)
    return GraphService.from_graph(g, str(tmp_path / tag), **kw)


def _mutated(src, dst, ins, dels):
    """Reference edge-list semantics of apply_updates: delete ALL copies of
    the named edges, then append inserts (same as test_delta's oracle)."""
    tomb = np.unique(pack_keys(
        np.asarray(dels[0], np.int64), np.asarray(dels[1], np.int64)))
    keys = pack_keys(src.astype(np.int64), dst.astype(np.int64))
    pos = np.minimum(np.searchsorted(tomb, keys), len(tomb) - 1)
    keep = tomb[pos] != keys
    src, dst = src[keep], dst[keep]
    src = np.concatenate([src, np.asarray(ins[0], np.int32)])
    dst = np.concatenate([dst, np.asarray(ins[1], np.int32)])
    return src.astype(np.int32), dst.astype(np.int32)


# ------------------------------------------------------- partition algebra
def test_equal_device_bounds_cover_and_order():
    for nv in (1, 7, 64, 1000):
        for d in (1, 2, 3, 8):
            rows_per_dev, nv_pad, bounds = equal_device_bounds(nv, d)
            assert bounds[0] == 0 and bounds[-1] == nv
            assert np.all(np.diff(bounds) >= 0)
            assert rows_per_dev * d == nv_pad >= nv
    with pytest.raises(ValueError):
        equal_device_bounds(10, 0)


def test_mesh_partition_owns_each_shard_once(tmp_path):
    g = rmat_graph(400, 3000, seed=7)
    eng = _mk_engine(tmp_path, "own", g, num_shards=7, backend="numpy")
    for d in (1, 2, 3, 8):
        part = MeshPartition.from_meta(eng.meta, d)
        assert part.owner.shape == (eng.meta.num_shards,)
        assert part.owner.min() >= 0 and part.owner.max() < d
        # ownership follows interval starts monotonically
        assert np.all(np.diff(part.owner) >= 0)
        # group/interleave round-trip: a permutation preserving per-device
        # interval order
        ids = list(range(eng.meta.num_shards))
        groups = part.group(ids)
        assert sorted(p for gr in groups for p in gr) == ids
        inter = MeshPartition.interleave(groups)
        assert sorted(inter) == ids
        for dd, gr in enumerate(groups):
            assert all(part.device_of(p) == dd for p in gr)
            assert gr == sorted(gr)
    eng.close()


def test_mesh_partition_seeded_stress():
    rng = np.random.default_rng(17)
    for _ in range(50):
        n_shards = int(rng.integers(1, 20))
        n_dev = int(rng.integers(1, 9))
        sub = rng.permutation(n_shards)[: int(rng.integers(0, n_shards + 1))]
        sub = sorted(int(p) for p in sub)
        owner = np.sort(rng.integers(0, n_dev, n_shards)).astype(np.int32)
        part = MeshPartition(n_dev=n_dev, num_shards=n_shards, owner=owner)
        groups = part.group(sub)
        assert len(groups) == n_dev
        assert sorted(p for gr in groups for p in gr) == sub
        inter = MeshPartition.interleave(groups)
        assert sorted(inter) == sub


def test_device_graph_builders_agree(tmp_path):
    """Satellite: the legacy dry-run layout builder and the store-backed
    one (no Graph object, PR 3's contract) produce bitwise-equal device
    graphs at every mesh size."""
    g = uniform_graph(300, 2500, seed=3)
    eng = _mk_engine(tmp_path, "dg", g, num_shards=5, backend="numpy",
                     window=256, k=16)
    store = eng.store
    for d in (1, 3, 4, 8):
        dg1 = build_device_graph(g, d, window=256, k=16, tr=8)
        dg2 = build_device_graph_from_store(store, d)
        for f in ("ell_idx", "ell_valid", "seg", "out_deg"):
            assert np.array_equal(getattr(dg1, f), getattr(dg2, f)), (d, f)
        for f in ("num_vertices", "num_vertices_real", "rows_per_dev",
                  "n_dev", "n_ell_per_dev"):
            assert getattr(dg1, f) == getattr(dg2, f), (d, f)
    eng.close()


# ------------------------------------------- engine sweeps (numpy emulation)
def test_engine_mesh_numpy_bitwise_and_conserved(tmp_path):
    g = uniform_graph(500, 4000, seed=0)
    solo = _mk_engine(tmp_path, "solo", g, num_shards=8, backend="numpy")
    for D in MESH_SIZES:
        meshy = _mk_engine(tmp_path, f"m{D}", g, num_shards=8,
                           backend="numpy", mesh=D)
        for prog, kw in (("pagerank", {}), ("bfs", {"source": 0}),
                         ("sssp", {"source": 0}), ("wcc", {})):
            r1 = solo.run(apps.get_program(prog, **kw), max_iters=20)
            r2 = meshy.run(apps.get_program(prog, **kw), max_iters=20)
            assert np.array_equal(r1.values, r2.values), (D, prog)
            for it in r2.iterations:
                assert len(it.device_shards) == D
                assert sum(it.device_shards) == it.shards_processed
                assert abs(sum(it.device_bytes) - it.bytes_read) < 1e-6
        meshy.close()
    solo.close()


def test_mesh_plans_prune_idle_devices(tmp_path):
    """Selective plans leave devices whose destination intervals are all
    inactive with EMPTY groups — no host read for them."""
    n = 256
    g = chain_graph(n)
    eng = _mk_engine(tmp_path, "prune", g, num_shards=8, backend="numpy",
                     mesh=4, threshold=1.1,  # selective always on
                     exact_selective=True)   # no Bloom false positives
    plan = eng.scheduler.plan(np.asarray([0], dtype=np.int64))
    assert plan.device_shards is not None and len(plan.device_shards) == 4
    # vertex 0's only out-edge targets vertex 1 -> only device 0's shards
    assert all(len(gr) == 0 for gr in plan.device_shards[1:])
    assert sorted(p for gr in plan.device_shards for p in gr) \
        == sorted(plan.shards)
    eng.close()


# ------------------------------------------------- serving sweeps (numpy)
CASES = [("bfs", 2), ("wcc", 0), ("ppr", 3), ("sssp", 1), ("ppr", 9)]


def test_service_mesh_numpy_bitwise(tmp_path):
    g = rmat_graph(300, 3500, seed=63)
    solo = _mk_service(tmp_path, "svsolo", g, backend="numpy", max_lanes=8,
                       max_groups=2)
    refs = {c: solo.query(*c, max_iters=12).values for c in CASES}
    solo.close()
    for D in MESH_SIZES:
        svc = _mk_service(tmp_path, f"svm{D}", g, backend="numpy",
                          max_lanes=8, max_groups=2, mesh=D)
        with svc.submit_batch():
            futs = [svc.submit(p, s, max_iters=12) for p, s in CASES]
        for c, f in zip(CASES, futs):
            qr = f.result(timeout=240)
            assert np.array_equal(_norm(qr.values), _norm(refs[c])), (D, c)
        assert svc.stats()["mesh_devices"] == D
        svc.close()


def test_mesh_sweep_retirement_backfill_bitwise(tmp_path):
    """Mid-sweep retirement + backfill under a mesh: chain BFS sources
    converge at wildly different iterations; every result still equals the
    single-device solo run."""
    n = 64
    g = chain_graph(n)
    cases = [("bfs", 60), ("ppr", 0), ("bfs", 55), ("ppr", 1),
             ("bfs", 40), ("ppr", 2), ("bfs", 0)]
    solo = _mk_service(tmp_path, "bfsolo", g, num_shards=4, backend="numpy",
                       max_lanes=3, max_groups=2)
    refs = {}
    for p, s in cases:
        refs[(p, s)] = solo.query(
            p, s, max_iters=200 if p == "bfs" else 6).values
    solo.close()
    for D in (2, 8):
        svc = _mk_service(tmp_path, f"bf{D}", g, num_shards=4,
                          backend="numpy", max_lanes=3, max_groups=2, mesh=D)
        with svc.submit_batch():
            futs = [svc.submit(p, s, max_iters=200 if p == "bfs" else 6)
                    for p, s in cases]
        for (p, s), f in zip(cases, futs):
            qr = f.result(timeout=240)
            assert np.array_equal(_norm(qr.values), _norm(refs[(p, s)])), \
                (D, p, s)
        svc.close()


def test_mesh_sweep_stats_conserved(tmp_path):
    g = rmat_graph(300, 3500, seed=63)
    eng = _mk_engine(tmp_path, "cons", g, backend="numpy", mesh=4)
    sweep = MeshSweep(eng)
    seeds = [
        [LaneSeed(source=s, max_iters=12,
                  program=apps.get_lane_program("bfs")) for s in (0, 5, 9)],
        [LaneSeed(source=3, max_iters=6,
                  program=apps.get_lane_program("ppr"))],
    ]
    res = sweep.run(seeds)
    assert len(res) == 4
    assert sweep.iter_stats
    for it in sweep.iter_stats:
        assert len(it.device_shards) == 4
        assert sum(it.device_shards) == it.shards_processed
        assert abs(sum(it.device_bytes) - it.bytes_read) < 1e-6
        # dispatch conservation: each device that carried work this
        # iteration launched once per live group, never more
        assert all(d <= it.groups * it.shards_processed
                   for d in it.device_dispatches)
    # lane attribution still sums to the sweep totals under the mesh
    total_bytes = sum(it.bytes_read for it in sweep.iter_stats)
    assert abs(sum(r.bytes_read for r in res) - total_bytes) < 1e-6
    eng.close()


def test_mesh_sweep_rejects_plain_engine(tmp_path):
    g = chain_graph(32)
    eng = _mk_engine(tmp_path, "plain", g, num_shards=2, backend="numpy")
    with pytest.raises(ValueError, match="mesh="):
        MeshSweep(eng)
    assert isinstance(FusedSweep(eng), FusedSweep)  # plain path unaffected
    eng.close()


def test_mesh_apply_updates_between_sweeps(tmp_path):
    """Live edge mutations between mesh sweeps: post-publish queries equal
    a fresh single-device service on the mutated graph (delta overlay +
    version pinning compose with the mesh executor)."""
    rng = np.random.default_rng(29)
    num_v, num_e = 250, 2200
    g = rmat_graph(num_v, num_e, seed=66)
    svc = _mk_service(tmp_path, "upd", g, num_shards=5, backend="numpy",
                      max_lanes=4, max_groups=2, mesh=4, session_entries=0)
    cases = [("bfs", 3), ("wcc", 0), ("ppr", 7), ("sssp", 11)]
    pre = {c: svc.query(*c, max_iters=15) for c in cases}

    take = rng.choice(num_e, 200, replace=False)
    dels = (g.src[take], g.dst[take])
    ins = (rng.integers(0, num_v, 150).astype(np.int32),
           rng.integers(0, num_v, 150).astype(np.int32))
    upd = svc.apply_updates(inserts=ins, deletes=dels).result(timeout=240)
    assert upd.graph_version == 1
    post = {c: svc.query(*c, max_iters=15) for c in cases}
    svc.close()

    msrc, mdst = _mutated(g.src, g.dst, ins, dels)
    mg = Graph(num_v, msrc, mdst)
    ref_pre = _mk_service(tmp_path, "ref0", g, num_shards=5, backend="numpy",
                          max_lanes=4, session_entries=0)
    ref_post = _mk_service(tmp_path, "ref1", mg, num_shards=5,
                           backend="numpy", max_lanes=4, session_entries=0)
    for c in cases:
        assert np.array_equal(
            _norm(pre[c].values),
            _norm(ref_pre.query(*c, max_iters=15).values)), ("pre", c)
        assert np.array_equal(
            _norm(post[c].values),
            _norm(ref_post.query(*c, max_iters=15).values)), ("post", c)
    ref_pre.close()
    ref_post.close()


def test_mesh_seeded_property_stress(tmp_path):
    """Seeded stress: random graphs x random mesh sizes x all four lane
    programs, mesh emulation vs solo, every time bitwise."""
    rng = np.random.default_rng(41)
    for trial in range(4):
        n = int(rng.integers(60, 400))
        m = int(rng.integers(2 * n, 8 * n))
        g = rmat_graph(n, m, seed=int(rng.integers(1 << 30)))
        D = int(rng.choice([2, 3, 5, 8]))
        shards = int(rng.integers(2, 9))
        cases = [(p, int(rng.integers(0, n)))
                 for p in ("bfs", "sssp", "ppr", "wcc")]
        solo = _mk_service(tmp_path, f"st{trial}s", g, num_shards=shards,
                           backend="numpy", max_lanes=4, max_groups=2)
        refs = {c: solo.query(*c, max_iters=10).values for c in cases}
        solo.close()
        svc = _mk_service(tmp_path, f"st{trial}m", g, num_shards=shards,
                          backend="numpy", max_lanes=4, max_groups=2, mesh=D)
        with svc.submit_batch():
            futs = [svc.submit(p, s, max_iters=10) for p, s in cases]
        for c, f in zip(cases, futs):
            assert np.array_equal(
                _norm(f.result(timeout=240).values), _norm(refs[c])), \
                (trial, D, c)
        svc.close()


# ------------------------------------------------ jax paths (subprocess)
_JAX_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import tempfile
    from repro.core.graph import rmat_graph
    from repro.serve import GraphService

    g = rmat_graph(300, 3500, seed=63)
    cases = [("bfs", 2), ("wcc", 0), ("ppr", 3), ("sssp", 1), ("ppr", 9)]
    norm = lambda v: np.nan_to_num(v, posinf=1e30)
    with tempfile.TemporaryDirectory() as d:
        for backend in ("jnp", "pallas"):
            solo = GraphService.from_graph(
                g, d + f"/solo{backend}", num_shards=6, window=128, k=16,
                backend=backend, max_lanes=8, max_groups=2, batch_shards=2)
            refs = {c: solo.query(*c, max_iters=12).values for c in cases}
            solo.close()
            for D in (1, 2, 8):
                svc = GraphService.from_graph(
                    g, d + f"/{backend}{D}", num_shards=6, window=128, k=16,
                    backend=backend, max_lanes=8, max_groups=2,
                    batch_shards=2, mesh=D)
                with svc.submit_batch():
                    futs = [svc.submit(p, s, max_iters=12) for p, s in cases]
                for c, f in zip(cases, futs):
                    qr = f.result(timeout=240)
                    assert np.array_equal(norm(qr.values), norm(refs[c])), \\
                        (backend, D, c)
                assert svc.stats()["mesh_devices"] == D
                svc.close()
                print(backend, "D", D, "bitwise-ok", flush=True)
    print("MESH_JAX_OK")
    """
)

_ERR_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import tempfile
    from repro.launch.mesh import make_host_mesh, make_production_mesh

    # both constructors raise the SAME derived-from-shape error
    for fn, needs in ((lambda: make_host_mesh((4, 4)), 16),
                      (make_production_mesh, 256)):
        try:
            fn()
            raise SystemExit("expected RuntimeError")
        except RuntimeError as e:
            msg = str(e)
            assert f"needs {needs} devices, have 8" in msg, msg
            assert f"device_count={needs}" in msg, msg

    # a 4-device mesh on the 8-device host works (prefix, no truncation)
    m = make_host_mesh((4,), ("dev",))
    assert m.devices.shape == (4,)

    # the engine's mesh= boot path surfaces the same error
    from repro.core.graph import chain_graph
    from repro.core.vsw import VSWEngine
    with tempfile.TemporaryDirectory() as d:
        try:
            VSWEngine.from_graph(chain_graph(64), d + "/x", num_shards=2,
                                 window=128, k=16, backend="jnp", mesh=16)
            raise SystemExit("expected RuntimeError")
        except RuntimeError as e:
            assert "needs 16 devices, have 8" in str(e), str(e)
    print("MESH_ERR_OK")
    """
)


def _run_sub(script):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    return subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        env=env, timeout=600,
    )


@pytest.mark.slow
def test_mesh_jnp_pallas_bitwise_e2e():
    r = _run_sub(_JAX_SCRIPT)
    assert r.returncode == 0, (r.stdout[-1500:], r.stderr[-3000:])
    assert "MESH_JAX_OK" in r.stdout


@pytest.mark.slow
def test_mesh_device_errors_uniform_e2e():
    r = _run_sub(_ERR_SCRIPT)
    assert r.returncode == 0, (r.stdout[-1500:], r.stderr[-3000:])
    assert "MESH_ERR_OK" in r.stdout
