"""Subprocess driver for the crash-recovery matrix (test_crash_recovery.py).

Runs a fixed, fully deterministic mutation script against a pre-built store
and SIGKILLs ITSELF at one named injection point (``repro.delta.recovery.
CRASH_POINTS``) — a real crash, not an exception: no ``finally`` blocks, no
atexit, the files are exactly what the protocol had made durable at that
point.  The parent test imports this module for the SAME scenario
definitions, so it can compute the per-version oracles the recovered store
must match bitwise.

Usage:  python tests/crash_driver.py <store_root> <crash_point|none>
"""

import os
import signal
import sys

import numpy as np

N_VERTICES = 300
N_EDGES = 2500
N_SHARDS = 4
SEED = 7


def base_graph():
    from repro.core.graph import uniform_graph

    return uniform_graph(N_VERTICES, N_EDGES, seed=SEED)


def batches(g):
    """Two deterministic mutation batches (inserts + deletes of existing
    edges), each published separately: versions 1 and 2."""
    rng = np.random.default_rng(42)
    out = []
    for _ in range(2):
        i_src = rng.integers(0, N_VERTICES, 30)
        i_dst = rng.integers(0, N_VERTICES, 30)
        take = rng.choice(g.num_edges, 10, replace=False)
        out.append(((i_src, i_dst), (g.src[take], g.dst[take])))
    return out


def main(root: str, point: str) -> int:
    from repro.core.storage import ShardStore
    from repro.delta import EdgeLog, Recompactor, set_crash_hook

    if point != "none":

        def hook(name: str) -> None:
            if name == point:
                os.kill(os.getpid(), signal.SIGKILL)

        set_crash_hook(hook)

    store = ShardStore(root)
    g = base_graph()
    log = EdgeLog(store)
    for ins, dels in batches(g):
        log.append(inserts=ins, deletes=dels)
        log.publish()
    Recompactor(store, min_runs=1).compact()
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1], sys.argv[2]))
