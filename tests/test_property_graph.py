"""Hypothesis property tests on system invariants (spec requirement).

``hypothesis`` is optional (requirements.txt).  When it is missing, each
property runs over a deterministic battery of seeded random graphs instead
— same checks, fixed sampling — so ``pytest -x -q`` always collects.
"""

import numpy as np
import pytest

try:
    from hypothesis import HealthCheck, given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import apps
from repro.core.csr import csr_to_ell
from repro.core.graph import Graph, from_edge_list
from repro.core.sharding import compute_intervals, preprocess
from repro.core.vsw import VSWEngine, update_shard_numpy

if HAVE_HYPOTHESIS:

    @st.composite
    def graphs(draw, max_v=60, max_e=300):
        n = draw(st.integers(min_value=2, max_value=max_v))
        m = draw(st.integers(min_value=1, max_value=max_e))
        src = draw(st.lists(st.integers(0, n - 1), min_size=m, max_size=m))
        dst = draw(st.lists(st.integers(0, n - 1), min_size=m, max_size=m))
        return Graph(n, np.array(src, np.int32), np.array(dst, np.int32))


def _seeded_graph(seed, max_v=60, max_e=300):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, max_v + 1))
    m = int(rng.integers(1, max_e + 1))
    return Graph(
        n,
        rng.integers(0, n, m).astype(np.int32),
        rng.integers(0, n, m).astype(np.int32),
    )


def _property(arg_fn, n_examples, hyp_decorators):
    """Decorate with hypothesis when available, else a seeded parametrize.

    ``arg_fn(seed) -> tuple`` supplies the fallback example for one seed;
    ``hyp_decorators`` is the (settings, given) pair used otherwise.
    """

    def deco(check):
        if HAVE_HYPOTHESIS:
            f = check
            for d in reversed(hyp_decorators):
                f = d(f)
            return f

        @pytest.mark.parametrize("seed", range(n_examples))
        def wrapper(seed):
            check(*arg_fn(seed))

        wrapper.__name__ = check.__name__
        return wrapper

    return deco


@_property(
    lambda seed: (_seeded_graph(seed), 1 + seed % 6),
    n_examples=30,
    hyp_decorators=[
        settings(max_examples=30, deadline=None,
                 suppress_health_check=[HealthCheck.too_slow]),
        given(graphs(), st.integers(1, 6)),
    ] if HAVE_HYPOTHESIS else [],
)
def test_sharding_partitions_edges_exactly(g, p):
    meta, shards = preprocess(g, num_shards=p)
    assert sum(s.nnz for s in shards) == g.num_edges
    assert meta.intervals[0] == 0 and meta.intervals[-1] == g.num_vertices
    assert (np.diff(meta.intervals) > 0).all()
    # each edge is in exactly the shard of its destination
    for s in shards:
        for v in range(s.v0, s.v1):
            assert np.array_equal(
                np.sort(s.in_neighbors(v)), np.sort(g.src[g.dst == v])
            )


@_property(
    lambda seed: (_seeded_graph(seed), 4 + (seed * 7) % 61, 2 + (seed * 3) % 15),
    n_examples=25,
    hyp_decorators=[
        settings(max_examples=25, deadline=None,
                 suppress_health_check=[HealthCheck.too_slow]),
        given(graphs(), st.integers(4, 64), st.integers(2, 16)),
    ] if HAVE_HYPOTHESIS else [],
)
def test_ell_preserves_edge_multiset(g, window, k):
    meta, shards = preprocess(g, num_shards=2)
    for s in shards:
        e = csr_to_ell(s, g.num_vertices, window=window, k=k, tr=8)
        assert int(e.ell_mask.sum()) == s.nnz
        gi = e.global_idx()
        r, c = np.nonzero(e.ell_mask)
        got = sorted(zip(gi[r, c].tolist(), (e.seg[r] + e.v0).tolist()))
        m = (g.dst >= s.v0) & (g.dst < s.v1)
        ref = sorted(zip(g.src[m].tolist(), g.dst[m].tolist()))
        assert got == ref


@_property(
    lambda seed: (_seeded_graph(seed, max_v=40, max_e=150),),
    n_examples=15,
    hyp_decorators=[
        settings(max_examples=15, deadline=None,
                 suppress_health_check=[HealthCheck.too_slow]),
        given(graphs(max_v=40, max_e=150)),
    ] if HAVE_HYPOTHESIS else [],
)
def test_pagerank_mass_conservation(g):
    """0 < sum(PR) <= 1 (dangling vertices leak mass; none is created)."""
    import tempfile

    with tempfile.TemporaryDirectory() as d:
        eng = VSWEngine.from_graph(g, d, num_shards=2, window=16, k=4,
                                   backend="numpy", selective=False)
        r = eng.run(apps.pagerank(), max_iters=15)
    total = float(r.values.sum())
    assert 0.0 < total <= 1.0 + 1e-4


@_property(
    lambda seed: (_seeded_graph(100 + seed, max_v=40, max_e=150),),
    n_examples=15,
    hyp_decorators=[
        settings(max_examples=15, deadline=None,
                 suppress_health_check=[HealthCheck.too_slow]),
        given(graphs(max_v=40, max_e=150)),
    ] if HAVE_HYPOTHESIS else [],
)
def test_sssp_triangle_inequality(g):
    """After convergence: dist[v] <= dist[u] + 1 for every edge (u, v)."""
    import tempfile

    with tempfile.TemporaryDirectory() as d:
        eng = VSWEngine.from_graph(g, d, num_shards=2, window=16, k=4,
                                   backend="numpy", selective=False)
        r = eng.run(apps.sssp(0), max_iters=g.num_vertices + 2)
    dist = r.values
    assert dist[0] == 0.0
    lhs = dist[g.dst]
    rhs = dist[g.src] + 1
    ok = np.isinf(rhs) | (lhs <= rhs + 1e-6)
    assert ok.all()


@_property(
    lambda seed: (_seeded_graph(200 + seed, max_v=40, max_e=150),),
    n_examples=15,
    hyp_decorators=[
        settings(max_examples=15, deadline=None,
                 suppress_health_check=[HealthCheck.too_slow]),
        given(graphs(max_v=40, max_e=150)),
    ] if HAVE_HYPOTHESIS else [],
)
def test_wcc_labels_are_fixed_point(g):
    """Converged labels: label[v] <= label[u] for every edge (u,v), and
    every label is the id of some vertex with that label (a root)."""
    import tempfile

    with tempfile.TemporaryDirectory() as d:
        eng = VSWEngine.from_graph(g, d, num_shards=2, window=16, k=4,
                                   backend="numpy", selective=False)
        r = eng.run(apps.wcc(), max_iters=g.num_vertices + 2)
    lab = r.values
    assert (lab[g.dst] <= lab[g.src] + 1e-6).all()
    roots = lab[lab.astype(int)]  # label of each label-vertex
    assert np.array_equal(roots, lab[lab.astype(int)])
    assert (lab <= np.arange(g.num_vertices)).all()


@_property(
    lambda seed: (_seeded_graph(300 + seed), ["sum", "min", "max"][seed % 3]),
    n_examples=20,
    hyp_decorators=[
        settings(max_examples=20, deadline=None,
                 suppress_health_check=[HealthCheck.too_slow]),
        given(graphs(), st.sampled_from(["sum", "min", "max"])),
    ] if HAVE_HYPOTHESIS else [],
)
def test_update_shard_matches_dense(g, combine):
    meta, shards = preprocess(g, num_shards=3)
    msgs = np.random.default_rng(0).random(g.num_vertices).astype(np.float32)
    for s in shards:
        acc = update_shard_numpy(s, None, msgs, combine)
        for v in range(s.v0, s.v1):
            nbrs = g.src[g.dst == v]
            if len(nbrs) == 0:
                continue
            ref = {"sum": np.sum, "min": np.min, "max": np.max}[combine](msgs[nbrs])
            assert np.isclose(acc[v - s.v0], ref, rtol=1e-5)
