"""Hypothesis property tests on system invariants (spec requirement)."""

import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core import apps
from repro.core.csr import csr_to_ell
from repro.core.graph import Graph, from_edge_list
from repro.core.sharding import compute_intervals, preprocess
from repro.core.vsw import VSWEngine, update_shard_numpy


@st.composite
def graphs(draw, max_v=60, max_e=300):
    n = draw(st.integers(min_value=2, max_value=max_v))
    m = draw(st.integers(min_value=1, max_value=max_e))
    src = draw(st.lists(st.integers(0, n - 1), min_size=m, max_size=m))
    dst = draw(st.lists(st.integers(0, n - 1), min_size=m, max_size=m))
    return Graph(n, np.array(src, np.int32), np.array(dst, np.int32))


@settings(max_examples=30, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(graphs(), st.integers(1, 6))
def test_sharding_partitions_edges_exactly(g, p):
    meta, shards = preprocess(g, num_shards=p)
    assert sum(s.nnz for s in shards) == g.num_edges
    assert meta.intervals[0] == 0 and meta.intervals[-1] == g.num_vertices
    assert (np.diff(meta.intervals) > 0).all()
    # each edge is in exactly the shard of its destination
    for s in shards:
        for v in range(s.v0, s.v1):
            assert np.array_equal(
                np.sort(s.in_neighbors(v)), np.sort(g.src[g.dst == v])
            )


@settings(max_examples=25, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(graphs(), st.integers(4, 64), st.integers(2, 16))
def test_ell_preserves_edge_multiset(g, window, k):
    meta, shards = preprocess(g, num_shards=2)
    for s in shards:
        e = csr_to_ell(s, g.num_vertices, window=window, k=k, tr=8)
        assert int(e.ell_mask.sum()) == s.nnz
        gi = e.global_idx()
        r, c = np.nonzero(e.ell_mask)
        got = sorted(zip(gi[r, c].tolist(), (e.seg[r] + e.v0).tolist()))
        m = (g.dst >= s.v0) & (g.dst < s.v1)
        ref = sorted(zip(g.src[m].tolist(), g.dst[m].tolist()))
        assert got == ref


@settings(max_examples=15, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(graphs(max_v=40, max_e=150))
def test_pagerank_mass_conservation(g):
    """0 < sum(PR) <= 1 (dangling vertices leak mass; none is created)."""
    import tempfile

    with tempfile.TemporaryDirectory() as d:
        eng = VSWEngine.from_graph(g, d, num_shards=2, window=16, k=4,
                                   backend="numpy", selective=False)
        r = eng.run(apps.pagerank(), max_iters=15)
    total = float(r.values.sum())
    assert 0.0 < total <= 1.0 + 1e-4


@settings(max_examples=15, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(graphs(max_v=40, max_e=150))
def test_sssp_triangle_inequality(g):
    """After convergence: dist[v] <= dist[u] + 1 for every edge (u, v)."""
    import tempfile

    with tempfile.TemporaryDirectory() as d:
        eng = VSWEngine.from_graph(g, d, num_shards=2, window=16, k=4,
                                   backend="numpy", selective=False)
        r = eng.run(apps.sssp(0), max_iters=g.num_vertices + 2)
    dist = r.values
    assert dist[0] == 0.0
    lhs = dist[g.dst]
    rhs = dist[g.src] + 1
    ok = np.isinf(rhs) | (lhs <= rhs + 1e-6)
    assert ok.all()


@settings(max_examples=15, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(graphs(max_v=40, max_e=150))
def test_wcc_labels_are_fixed_point(g):
    """Converged labels: label[v] <= label[u] for every edge (u,v), and
    every label is the id of some vertex with that label (a root)."""
    import tempfile

    with tempfile.TemporaryDirectory() as d:
        eng = VSWEngine.from_graph(g, d, num_shards=2, window=16, k=4,
                                   backend="numpy", selective=False)
        r = eng.run(apps.wcc(), max_iters=g.num_vertices + 2)
    lab = r.values
    assert (lab[g.dst] <= lab[g.src] + 1e-6).all()
    roots = lab[lab.astype(int)]  # label of each label-vertex
    assert np.array_equal(roots, lab[lab.astype(int)])
    assert (lab <= np.arange(g.num_vertices)).all()


@settings(max_examples=20, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(graphs(), st.sampled_from(["sum", "min", "max"]))
def test_update_shard_matches_dense(g, combine):
    meta, shards = preprocess(g, num_shards=3)
    msgs = np.random.default_rng(0).random(g.num_vertices).astype(np.float32)
    for s in shards:
        acc = update_shard_numpy(s, None, msgs, combine)
        for v in range(s.v0, s.v1):
            nbrs = g.src[g.dst == v]
            if len(nbrs) == 0:
                continue
            ref = {"sum": np.sum, "min": np.min, "max": np.max}[combine](msgs[nbrs])
            assert np.isclose(acc[v - s.v0], ref, rtol=1e-5)
