"""GraphDelta tests: live edge mutations stay bitwise-correct.

The contract (ISSUE 4 / DESIGN.md §8): after ANY interleaving of
insert/delete batches,

- overlay-merged decodes (CSR and ELL) of every shard,
- post-recompaction base shards,
- PageRank / BFS / SSSP sweep results on every backend, and
- the persisted degree / edge-count metadata

are bitwise-identical to a from-scratch build of the mutated edge list on
the same intervals, and a live ``GraphService`` never returns a result
mixing two graph versions.

Tests booting engines (jax import) carry ``e2e`` in their name so the
RLIMIT_AS runner (tests/run_memcapped.py) can exclude them.
"""

import os
import threading
import time

import numpy as np
import pytest

from repro.core.graph import Graph, rmat_graph, small_world_graph
from repro.core.ingest import (
    csr_from_keys,
    ingest_edge_file,
    keys_of_csr,
    pack_keys,
    write_edge_file,
)
from repro.core.sharding import build_shards, preprocess
from repro.core.storage import ShardStore
from repro.delta import EdgeLog, Recompactor, apply_run
from repro.delta.edgelog import _norm_edges

WINDOW, K, TR = 64, 8, 4


# --------------------------------------------------------------------------
# Oracle machinery
# --------------------------------------------------------------------------


def _apply_batch_oracle(src, dst, batch):
    """Reference semantics on a plain edge list: deletes (ALL copies of the
    named edges) first, then inserts appended."""
    ins, dels = batch
    if dels is not None:
        tomb = np.unique(pack_keys(
            np.asarray(dels[0], np.int64), np.asarray(dels[1], np.int64)))
        keys = pack_keys(src.astype(np.int64), dst.astype(np.int64))
        pos = np.minimum(np.searchsorted(tomb, keys), len(tomb) - 1)
        keep = tomb[pos] != keys
        src, dst = src[keep], dst[keep]
    if ins is not None:
        src = np.concatenate([src, np.asarray(ins[0], np.int32)])
        dst = np.concatenate([dst, np.asarray(ins[1], np.int32)])
    return src.astype(np.int32), dst.astype(np.int32)


def _mk_store(tmp, g, num_shards, sub="s", via="preprocess"):
    root = os.path.join(tmp, sub)
    if via == "preprocess":
        meta, shards = preprocess(g, num_shards=num_shards)
        store = ShardStore(root)
        store.write_meta(meta, ell_params={"window": WINDOW, "k": K, "tr": TR})
        for s in shards:
            store.write_shard(s, num_vertices=meta.num_vertices,
                              window=WINDOW, k=K, tr=TR)
    else:  # streamed ingest with a tiny chunk to exercise the spill path
        path = os.path.join(tmp, f"{sub}_edges.bin")
        write_edge_file(path, g.src, g.dst)
        store = ShardStore(root)
        meta, _ = ingest_edge_file(
            store, path, num_shards=num_shards, num_vertices=g.num_vertices,
            chunk_edges=257, mem_budget_bytes=1 << 12,
            window=WINDOW, k=K, tr=TR,
        )
    return store, meta


def _rand_batch(rng, g_src, g_dst, n):
    """Random mutation batch: duplicate inserts, deletes of existing AND
    absent edges, overlapping insert/delete keys."""
    kind = rng.integers(0, 3)
    ins = dels = None
    if kind in (0, 2):
        i_src = rng.integers(0, n, rng.integers(1, 40))
        i_dst = rng.integers(0, n, len(i_src))
        if len(g_src) and rng.integers(0, 2):  # duplicate an existing edge
            j = rng.integers(0, len(g_src))
            i_src = np.append(i_src, g_src[j])
            i_dst = np.append(i_dst, g_dst[j])
        ins = (i_src, i_dst)
    if kind in (1, 2):
        d_src = rng.integers(0, n, rng.integers(1, 20))
        d_dst = rng.integers(0, n, len(d_src))
        if len(g_src):
            take = rng.choice(len(g_src), min(15, len(g_src)), replace=False)
            d_src = np.concatenate([d_src, g_src[take]])
            d_dst = np.concatenate([d_dst, g_dst[take]])
        dels = (d_src, d_dst)
    return ins, dels


def _assert_logical_equal(store, meta, mg):
    """Every logical shard (CSR + ELL) and the metadata vs a from-scratch
    build of the mutated graph on the SAME intervals."""
    from repro.core.csr import csr_to_ell

    ref_shards = build_shards(mg, meta.intervals)
    for p in range(meta.num_shards):
        got = store.load_shard(p, "csr")
        ref = ref_shards[p]
        assert np.array_equal(got.row, ref.row), f"shard {p} row"
        assert np.array_equal(got.col, ref.col), f"shard {p} col"
        got_e = store.load_shard(p, "ell")
        ref_e = csr_to_ell(ref, mg.num_vertices, window=WINDOW, k=K, tr=TR)
        assert np.array_equal(got_e.ell_idx, ref_e.ell_idx), f"shard {p} ell"
        assert np.array_equal(got_e.ell_mask, ref_e.ell_mask)
        assert np.array_equal(got_e.seg, ref_e.seg)
        assert got_e.nnz == ref_e.nnz
    disk = store.read_meta()
    assert disk.num_edges == mg.num_edges
    assert np.array_equal(disk.in_deg, mg.in_degrees())
    assert np.array_equal(disk.out_deg, mg.out_degrees())


# --------------------------------------------------------------------------
# Unit: fold semantics
# --------------------------------------------------------------------------


def test_apply_run_fold_unit():
    keys = np.array([1, 5, 5, 9], dtype=np.int64)
    # tombstone removes ALL copies; insert adds one; both sorted in
    out = apply_run(keys, tombs=np.array([5], np.int64),
                    ins=np.array([2, 9], np.int64))
    assert out.tolist() == [1, 2, 9, 9]
    # tombstone of an absent key is a no-op
    out = apply_run(out, tombs=np.array([4], np.int64),
                    ins=np.empty(0, np.int64))
    assert out.tolist() == [1, 2, 9, 9]
    # empty base
    out = apply_run(np.empty(0, np.int64), np.array([1], np.int64),
                    np.array([3], np.int64))
    assert out.tolist() == [3]


def test_keys_roundtrip_unit():
    g = rmat_graph(100, 400, seed=7)
    meta, shards = preprocess(g, num_shards=3)
    for s in shards:
        keys = keys_of_csr(s)
        assert np.all(np.diff(keys) >= 0)
        back = csr_from_keys(s.shard_id, s.v0, s.v1, keys)
        assert np.array_equal(back.row, s.row)
        assert np.array_equal(back.col, s.col)


def test_norm_edges_validation_unit():
    assert _norm_edges(None, 10, "x") is None
    assert _norm_edges((np.array([]), np.array([])), 10, "x") is None
    with pytest.raises(ValueError, match="out of range"):
        _norm_edges((np.array([0]), np.array([10])), 10, "x")
    with pytest.raises(ValueError, match="out of range"):
        _norm_edges((np.array([-1]), np.array([0])), 10, "x")
    with pytest.raises(ValueError, match="mismatch"):
        _norm_edges((np.array([1, 2]), np.array([1])), 10, "x")
    s, d = _norm_edges(np.array([[1, 2], [3, 4]]), 10, "x")
    assert s.tolist() == [1, 3] and d.tolist() == [2, 4]


def test_edgelog_rejects_out_of_range():
    import tempfile

    with tempfile.TemporaryDirectory() as tmp:
        store, meta = _mk_store(tmp, rmat_graph(50, 200, seed=1), 2)
        log = EdgeLog(store)
        with pytest.raises(ValueError):
            log.append(inserts=(np.array([0]), np.array([50])))
        assert log.staged_batches == 0


# --------------------------------------------------------------------------
# Property: overlay + recompaction bitwise vs from-scratch build
# --------------------------------------------------------------------------


@pytest.mark.parametrize("via", ["preprocess", "ingest"])
@pytest.mark.parametrize("seed", range(6))
def test_overlay_and_compaction_bitwise(tmp_path, seed, via):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(40, 300))
    m = int(rng.integers(0, 900))
    g = rmat_graph(n, m, seed=seed + 100)
    num_shards = int(rng.integers(1, 7))
    store, meta = _mk_store(str(tmp_path), g, num_shards, via=via)

    src, dst = g.src, g.dst
    log = EdgeLog(store, chunk_edges=int(rng.integers(1, 64)))
    for round_ in range(3):
        # 1-2 batches staged per publish
        for _ in range(int(rng.integers(1, 3))):
            batch = _rand_batch(rng, src, dst, n)
            log.append(inserts=batch[0], deletes=batch[1])
            src, dst = _apply_batch_oracle(src, dst, batch)
        pub = log.publish()
        mg = Graph(n, src, dst)
        assert store.read_meta().num_edges == mg.num_edges, pub
        _assert_logical_equal(store, meta, mg)
        if round_ == 1:
            # mid-sequence recompaction, then keep mutating on the new base
            Recompactor(store).compact()
            assert store.delta.dirty_shards() == []
            _assert_logical_equal(store, meta, mg)
    # final recompaction
    Recompactor(store).compact()
    _assert_logical_equal(store, meta, Graph(n, src, dst))
    # base containers now carry everything: no pending state anywhere
    assert store.delta.dirty_shards() == []


def test_publish_sequencing_semantics(tmp_path):
    g = Graph(10, np.array([1, 1, 2], np.int32), np.array([3, 3, 4], np.int32))
    store, meta = _mk_store(str(tmp_path), g, 1)
    log = EdgeLog(store)
    # same batch: delete (1,3) [all copies] THEN insert one copy back
    log.append(inserts=(np.array([1]), np.array([3])),
               deletes=(np.array([1]), np.array([3])))
    log.publish()
    got = store.load_shard(0, "csr")
    keys = keys_of_csr(got)
    assert keys.tolist() == pack_keys(
        np.array([1, 2], np.int64), np.array([3, 4], np.int64)).tolist()
    # across batches: insert (5,6) then delete it -> absent
    log.append(inserts=(np.array([5]), np.array([6])))
    log.append(deletes=(np.array([5]), np.array([6])))
    log.publish()
    keys = keys_of_csr(store.load_shard(0, "csr"))
    assert pack_keys(np.array([5], np.int64), np.array([6], np.int64))[0] \
        not in keys
    # degrees follow
    m2 = store.read_meta()
    ref = Graph(10, np.array([1, 2], np.int32), np.array([3, 4], np.int32))
    assert np.array_equal(m2.in_deg, ref.in_degrees())
    assert np.array_equal(m2.out_deg, ref.out_degrees())
    assert m2.num_edges == 2


def test_empty_publish_and_noop_batches(tmp_path):
    g = rmat_graph(30, 100, seed=2)
    store, meta = _mk_store(str(tmp_path), g, 2)
    log = EdgeLog(store)
    assert log.publish().version == 0  # nothing staged
    log.append()  # empty batch is dropped at staging
    assert log.staged_batches == 0
    # insert then delete the same edge across batches: the insert cancels,
    # the tombstone still removes any base copies of (1,2)
    log.append(inserts=(np.array([1]), np.array([2])))
    log.append(deletes=(np.array([1]), np.array([2])))
    pub = log.publish()
    src, dst = _apply_batch_oracle(g.src, g.dst,
                                   ((np.array([1]), np.array([2])), None))
    src, dst = _apply_batch_oracle(src, dst,
                                   (None, (np.array([1]), np.array([2]))))
    _assert_logical_equal(store, meta, Graph(30, src, dst))
    assert pub.version == 1  # a tombstone run was published


def test_manifest_recovery_dirty_reopen(tmp_path):
    g = rmat_graph(80, 400, seed=3)
    store, meta = _mk_store(str(tmp_path), g, 3)
    log = EdgeLog(store)
    ins = (np.array([1, 2, 3]), np.array([4, 5, 6]))
    log.append(inserts=ins)
    pub = log.publish()
    # an UNPUBLISHED orphan run (seq beyond the manifest) must be discarded
    orphan = os.path.join(store.root, "delta_run_00000_0000099.npz")
    with open(orphan, "wb") as f:
        f.write(b"garbage")
    store2 = ShardStore(store.root)
    assert store2.delta is not None
    assert store2.delta.version == pub.version
    assert not os.path.exists(orphan)
    src, dst = _apply_batch_oracle(g.src, g.dst, (ins, None))
    _assert_logical_equal(store2, meta, Graph(80, src, dst))


def test_reingest_clears_stale_delta_state(tmp_path):
    g = rmat_graph(60, 300, seed=4)
    store, meta = _mk_store(str(tmp_path), g, 2, via="ingest")
    log = EdgeLog(store)
    log.append(inserts=(np.array([1]), np.array([2])))
    log.publish()
    assert store.delta is not None and store.delta.version == 1
    # full re-ingest of a DIFFERENT graph replaces the logical store
    g2 = rmat_graph(60, 300, seed=5)
    path = os.path.join(str(tmp_path), "re.bin")
    write_edge_file(path, g2.src, g2.dst)
    meta2, stats = ingest_edge_file(
        store, path, num_shards=2, num_vertices=60,
        window=WINDOW, k=K, tr=TR,
    )
    assert stats.stale_delta_runs_removed >= 1
    assert store.delta is None
    _assert_logical_equal(store, meta2, g2)


def test_compaction_trigger_batches_runs(tmp_path):
    """min_runs is a real batching knob: below it (and with the byte
    trigger disabled at its 0.0 default) nothing compacts."""
    store, _ = _mk_store(str(tmp_path), rmat_graph(60, 300, seed=20), 2)
    log = EdgeLog(store)
    log.append(inserts=(np.array([1]), np.array([2])))
    log.publish()
    rc = Recompactor(store, min_runs=3)
    assert not any(rc.should_compact(p) for p in rc.dirty_shards())
    assert rc.compact().shards_compacted == 0
    for _ in range(2):
        log.append(inserts=(np.array([1]), np.array([2])))
        log.publish()
    assert any(rc.should_compact(p) for p in rc.dirty_shards())
    assert rc.compact().shards_compacted >= 1
    # byte-fraction trigger, when enabled, can fire below min_runs
    log.append(inserts=(np.array([1, 2, 3]), np.array([2, 3, 4])))
    log.publish()
    rc2 = Recompactor(store, min_runs=100, min_delta_frac=1e-9)
    assert any(rc2.should_compact(p) for p in rc2.dirty_shards())


def test_write_meta_preserves_ell_block_fresh_process(tmp_path):
    """A fresh ShardStore handle rewriting metadata (the first publish of
    a new process) must not drop the persisted (window, k, tr) block."""
    import json

    g = rmat_graph(40, 200, seed=21)
    store, meta = _mk_store(str(tmp_path), g, 2, via="ingest")
    fresh = ShardStore(store.root)  # no in-memory _ell_params
    fresh.write_meta(fresh.read_meta())
    prop = json.loads(fresh.read_bytes("property.json"))
    assert prop["ell"] == {"window": WINDOW, "k": K, "tr": TR}
    # and a publish from the fresh handle keeps ELL overlay decode working
    log = EdgeLog(fresh)
    log.append(inserts=(np.array([1]), np.array([2])))
    log.publish()
    assert fresh.ell_params()["window"] == WINDOW
    fresh.load_shard(fresh.read_meta().shard_of_vertex(2), "ell")


def test_failed_publish_leaves_no_orphan_runs(tmp_path, monkeypatch):
    """If publish dies mid-way through writing run files, the files it
    already wrote are removed — a later publish reuses the same sequence
    number, and recovery must not resurrect the failed batch."""
    g = rmat_graph(80, 500, seed=22)
    store, meta = _mk_store(str(tmp_path), g, 4)
    log = EdgeLog(store)
    # touch several shards so the per-shard write loop has multiple steps
    log.append(inserts=(np.arange(20) % 80, (np.arange(20) * 7) % 80))
    real_write = store.write_bytes
    writes = {"n": 0}

    def failing_write(name, raw):
        if name.startswith("delta_run_"):
            writes["n"] += 1
            if writes["n"] == 2:
                raise OSError("disk full")
        return real_write(name, raw)

    monkeypatch.setattr(store, "write_bytes", failing_write)
    with pytest.raises(OSError):
        log.publish()
    monkeypatch.setattr(store, "write_bytes", real_write)
    leftover = [f for f in os.listdir(store.root)
                if f.startswith("delta_run_")]
    assert leftover == []
    assert store.delta.version == 0
    # a subsequent publish at the same seq commits cleanly
    log.append(inserts=(np.array([3]), np.array([4])))
    assert log.publish().version == 1
    src, dst = _apply_batch_oracle(g.src, g.dst,
                                   ((np.array([3]), np.array([4])), None))
    _assert_logical_equal(store, meta, Graph(80, src, dst))


def test_pin_blocks_compaction_until_release(tmp_path):
    store, _ = _mk_store(str(tmp_path), rmat_graph(50, 300, seed=6), 2)
    log = EdgeLog(store)
    log.append(inserts=(np.array([1, 2]), np.array([3, 4])))
    log.publish()
    overlay = store.delta
    pin = overlay.acquire_pin()  # pinned BELOW the version a compaction needs?
    # pin == version here, so compaction need not wait; take a pin at an
    # older version by publishing after pinning
    log.append(inserts=(np.array([5]), np.array([6])))
    log.publish()
    done = threading.Event()

    def compact():
        Recompactor(store).compact()
        done.set()

    t = threading.Thread(target=compact)
    t.start()
    # the sweep pinned at the OLD version blocks absorption
    assert not done.wait(0.3)
    overlay.release_pin(pin)
    assert done.wait(5.0)
    t.join()
    assert overlay.dirty_shards() == []


# --------------------------------------------------------------------------
# Satellite: parallel finalize + ingest-time warmup
# --------------------------------------------------------------------------


def _ingest_with(tmp, g, sub, **kw):
    path = os.path.join(tmp, f"{sub}.bin")
    write_edge_file(path, g.src, g.dst)
    store = ShardStore(os.path.join(tmp, sub))
    meta, stats = ingest_edge_file(
        store, path, num_shards=5, num_vertices=g.num_vertices,
        chunk_edges=313, mem_budget_bytes=1 << 12,
        window=WINDOW, k=K, tr=TR, **kw,
    )
    return store, meta, stats


def test_parallel_finalize_bitwise_and_stats(tmp_path):
    g = rmat_graph(300, 4000, seed=8)
    s1, m1, st1 = _ingest_with(str(tmp_path), g, "w1", finalize_workers=1)
    s4, m4, st4 = _ingest_with(str(tmp_path), g, "w4", finalize_workers=4)
    assert st4.finalize_workers == 4
    for p in range(m1.num_shards):
        a, b = s1.load_shard(p, "csr"), s4.load_shard(p, "csr")
        assert np.array_equal(a.row, b.row) and np.array_equal(a.col, b.col)
        ea, eb = s1.load_shard(p, "ell"), s4.load_shard(p, "ell")
        assert np.array_equal(ea.ell_idx, eb.ell_idx)
    # byte-accounting identity holds under parallelism, and both paths
    # measured the same shard/spill volumes
    for st, store in ((st1, s1), (st4, s4)):
        assert store.io.bytes_written == st.bytes_written_total
    assert st1.shard_bytes_written == st4.shard_bytes_written
    assert st1.spill_bytes_written == st4.spill_bytes_written
    # auto worker count
    _, _, st0 = _ingest_with(str(tmp_path), g, "w0", finalize_workers=0)
    assert st0.finalize_workers >= 1


def test_ingest_warmup_sources_deposited(tmp_path):
    g = rmat_graph(200, 2000, seed=9)
    store, meta, stats = _ingest_with(str(tmp_path), g, "warm")
    assert stats.warm_sources_built == meta.num_shards
    _, shards = preprocess(g, num_shards=5)
    for s in shards:
        warm = store.warm_sources(s.shard_id)
        assert warm is not None
        assert np.array_equal(warm, np.unique(s.col))
    # warm_bytes keeps container bytes under the budget
    store2, meta2, st2 = _ingest_with(
        str(tmp_path), g, "warmraw", warm_bytes=1 << 30)
    assert st2.warm_raw_bytes > 0
    raw = store2.warm_raw(0, "csr")
    assert raw == store2.shard_bytes(0, "csr")
    # disabled -> nothing deposited
    store3, _, st3 = _ingest_with(
        str(tmp_path), g, "cold", warm_sources=False)
    assert st3.warm_sources_built == 0 and store3.warm_sources(0) is None


def test_ingest_warmup_skips_boot_reads_e2e(tmp_path):
    from repro.core.vsw import VSWEngine

    g = rmat_graph(200, 2000, seed=10)
    store, meta, _ = _ingest_with(
        str(tmp_path), g, "boot", warm_bytes=1 << 30)
    io0 = store.io.snapshot()
    eng = VSWEngine(store, cache_bytes=1 << 22)
    warm_reads = (store.io - io0).reads
    # Bloom inputs came from warm sources; cache seeded from warm bytes —
    # boot did not re-read every shard (a cold boot reads all of them)
    assert warm_reads < meta.num_shards
    cold = ShardStore(store.root)
    io1 = cold.io.snapshot()
    eng_cold = VSWEngine(cold, cache_bytes=1 << 22)
    assert (cold.io - io1).reads >= meta.num_shards
    # identical filters -> identical plans -> identical results
    from repro.core import apps

    a = eng.run(apps.pagerank(), max_iters=5)
    b = eng_cold.run(apps.pagerank(), max_iters=5)
    assert np.array_equal(a.values, b.values)
    eng.close()
    eng_cold.close()


def test_session_cache_drop_stale_versions_unit():
    from repro.serve.session import SessionCache

    c = SessionCache(16)
    c.put(("k", 1, 0), "a")
    c.put(("k", 2, 0), "b")
    c.put(("k", 1, 1), "c")
    assert c.drop_stale_versions(1) == 2
    assert c.get(("k", 1, 1)) == "c"
    assert c.get(("k", 1, 0)) is None


# --------------------------------------------------------------------------
# Engine-level sweeps on mutated stores (e2e: boots jax backends)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["numpy", "jnp", "pallas"])
def test_engine_sweep_matches_fresh_preprocess_e2e(tmp_path, backend):
    from repro.core import apps
    from repro.core.vsw import VSWEngine

    rng = np.random.default_rng(11)
    g = rmat_graph(250, 1500, seed=11)
    store, meta = _mk_store(str(tmp_path), g, 5)
    src, dst = g.src, g.dst
    log = EdgeLog(store)
    for _ in range(2):
        batch = _rand_batch(rng, src, dst, 250)
        log.append(inserts=batch[0], deletes=batch[1])
        src, dst = _apply_batch_oracle(src, dst, batch)
    log.publish()
    mg = Graph(250, src, dst)

    fresh = VSWEngine.from_graph(
        mg, os.path.join(str(tmp_path), f"fresh_{backend}"),
        num_shards=5, window=WINDOW, k=K, tr=TR, backend=backend,
    )
    live = VSWEngine(store, backend=backend, cache_bytes=1 << 20,
                     batch_shards=2 if backend != "numpy" else 1)
    for prog in ("pagerank", "bfs", "sssp"):
        ref = fresh.run(apps.get_program(prog), max_iters=12)
        got = live.run(apps.get_program(prog), max_iters=12)
        assert np.array_equal(got.values, ref.values), (backend, prog)
    # recompact under the open engine, then sweep again
    Recompactor(store).compact()
    for prog in ("pagerank", "bfs"):
        ref = fresh.run(apps.get_program(prog), max_iters=12)
        got = live.run(apps.get_program(prog), max_iters=12)
        assert np.array_equal(got.values, ref.values), (backend, prog, "compacted")
    fresh.close()
    live.close()


@pytest.mark.parametrize("backend,batch_shards", [
    ("numpy", 1), ("jnp", 1), ("jnp", 3), ("pallas", 2),
])
def test_lane_mask_bitwise_vs_solo_e2e(tmp_path, backend, batch_shards):
    from repro.core import apps
    from repro.core.vsw import VSWEngine
    from repro.serve.sweep import LaneSeed, LaneSweep

    g = small_world_graph(600, k=2, shortcuts=0.01, seed=12)
    root = os.path.join(str(tmp_path), f"lm_{backend}{batch_shards}")
    # high threshold so selective scheduling (and with it lane masking)
    # engages on a test-sized graph
    eng = VSWEngine.from_graph(g, root, num_shards=8, window=WINDOW, k=K,
                               tr=TR, threshold=0.5, backend=backend)
    sources = [3, 150, 300, 450]
    sweep = LaneSweep(eng, apps.lane_bfs(), lane_selective=True,
                      batch_shards=batch_shards)
    results = sweep.run([LaneSeed(source=s) for s in sources])
    assert sum(it.lane_rows_skipped for it in sweep.iter_stats) > 0, \
        "distant BFS frontiers should skip per-lane dispatch rows"
    by_src = {r.source: r for r in results}
    for s in sources:
        ref = eng.run(apps.bfs(s), max_iters=100)
        assert np.array_equal(by_src[s].values, ref.values), s
    # masking OFF agrees too
    sweep_off = LaneSweep(eng, apps.lane_bfs(), lane_selective=False,
                          batch_shards=batch_shards)
    for r in sweep_off.run([LaneSeed(source=s) for s in sources]):
        assert np.array_equal(r.values, by_src[r.source].values)
    eng.close()


# --------------------------------------------------------------------------
# Serving: update-during-serve (e2e)
# --------------------------------------------------------------------------


def _oracle_values(cache, tmp, states, version, source, max_iters=100):
    """Solo-engine BFS oracle for (version, source), memoized."""
    from repro.core import apps
    from repro.core.vsw import VSWEngine

    key = (version, source)
    if key not in cache:
        src, dst = states[version]
        eng = VSWEngine.from_graph(
            Graph(states["n"], src, dst),
            os.path.join(tmp, f"oracle_v{version}_{source}"),
            num_shards=4, window=WINDOW, k=K, tr=TR,
        )
        cache[key] = eng.run(apps.bfs(source), max_iters=max_iters).values
        eng.close()
    return cache[key]


def test_service_update_during_serve_stress_e2e(tmp_path):
    """Concurrent apply_updates + queries: every result must match a
    from-scratch oracle of the edge state AT ITS REPORTED VERSION — i.e. a
    live service never serves a mixed-version or stale-cache result."""
    from repro.serve import GraphService

    rng = np.random.default_rng(13)
    n = 300
    g = small_world_graph(n, k=2, shortcuts=0.02, seed=13)
    states = {"n": n, 0: (g.src, g.dst)}
    tmp = str(tmp_path)

    svc = GraphService.from_graph(
        g, os.path.join(tmp, "svc"), num_shards=4,
        window=WINDOW, k=K, tr=TR, max_lanes=4, session_entries=64,
    )
    sources = [1, 77, 150, 222]
    results = []
    res_lock = threading.Lock()
    stop = threading.Event()

    def querier():
        while not stop.is_set():
            s = sources[rng.integers(0, len(sources))]
            qr = svc.query("bfs", int(s))
            with res_lock:
                results.append(qr)

    threads = [threading.Thread(target=querier) for _ in range(3)]
    for t in threads:
        t.start()
    try:
        src, dst = g.src, g.dst
        for v in range(1, 4):
            time.sleep(0.05)
            batch = _rand_batch(rng, src, dst, n)
            src, dst = _apply_batch_oracle(src, dst, batch)
            upd = svc.apply_updates(inserts=batch[0], deletes=batch[1]).result()
            assert upd.graph_version == v
            states[v] = (src, dst)
        time.sleep(0.15)
    finally:
        stop.set()
        for t in threads:
            t.join()

    final = [svc.query("bfs", s) for s in sources]
    svc.close()
    oracle_cache = {}
    assert len(results) > 0
    for qr in results + final:
        assert qr.graph_version in states, qr.graph_version
        ref = _oracle_values(oracle_cache, tmp, states, qr.graph_version,
                             qr.source)
        assert np.array_equal(qr.values, ref), (
            f"source {qr.source} @ v{qr.graph_version} (cached={qr.cached})"
        )
    # the final queries ran at the final version
    for qr in final:
        assert qr.graph_version == 3


def test_service_auto_compact_during_serve_e2e(tmp_path):
    """Background recompaction while serving: results stay exact and the
    pending runs eventually drain into the base shards."""
    from repro.serve import GraphService

    n = 200
    g = small_world_graph(n, k=2, shortcuts=0.02, seed=14)
    tmp = str(tmp_path)
    svc = GraphService.from_graph(
        g, os.path.join(tmp, "svc"), num_shards=4, window=WINDOW, k=K, tr=TR,
        max_lanes=4, auto_compact_runs=1,
    )
    states = {"n": n, 0: (g.src, g.dst)}
    src, dst = g.src, g.dst
    rng = np.random.default_rng(15)
    for v in range(1, 4):
        batch = _rand_batch(rng, src, dst, n)
        src, dst = _apply_batch_oracle(src, dst, batch)
        svc.apply_updates(inserts=batch[0], deletes=batch[1]).result()
        states[v] = (src, dst)
        qr = svc.query("bfs", 5)
        oracle_cache = {}
        ref = _oracle_values(oracle_cache, tmp, states, qr.graph_version, 5)
        assert np.array_equal(qr.values, ref), f"v{qr.graph_version}"
    deadline = time.time() + 10
    while svc.engine.store.delta.dirty_shards() and time.time() < deadline:
        time.sleep(0.05)
    assert svc.engine.store.delta.dirty_shards() == []
    assert svc.stats()["shards_compacted"] >= 1
    qr = svc.query("bfs", 5)
    ref = _oracle_values({}, tmp, states, 3, 5)
    assert np.array_equal(qr.values, ref)
    svc.close()


def test_service_from_dirty_store_boot_e2e(tmp_path):
    """A service booted on a store with unabsorbed delta runs serves the
    mutated graph."""
    from repro.core import apps
    from repro.core.vsw import VSWEngine
    from repro.serve import GraphService

    g = rmat_graph(150, 900, seed=16)
    store, meta = _mk_store(str(tmp_path), g, 4)
    log = EdgeLog(store)
    ins = (np.array([3, 4, 5]), np.array([10, 11, 12]))
    log.append(inserts=ins)
    log.publish()
    src, dst = _apply_batch_oracle(g.src, g.dst, (ins, None))
    svc = GraphService.from_store(store.root, max_lanes=4)
    qr = svc.query("bfs", 3)
    ref_eng = VSWEngine.from_graph(
        Graph(150, src, dst), os.path.join(str(tmp_path), "oracle"),
        num_shards=4, window=WINDOW, k=K, tr=TR)
    ref = ref_eng.run(apps.bfs(3), max_iters=100)
    assert np.array_equal(qr.values, ref.values)
    ref_eng.close()
    svc.close()


# --------------------------------------------------------------------------
# Crash windows (ISSUE 8): failed-publish cleanup + journaled metadata
# --------------------------------------------------------------------------


def _fail_nth_delta_write(store, nth):
    """Make the ``nth`` delta-file write (run/journal, by prefix) raise —
    the raise-after-first-run-file window the old cleanup path leaked in."""
    orig = store.write_bytes
    seen = {"n": 0}

    def failing(name, data):
        if name.startswith("delta_run_") or name.startswith("delta_journal_"):
            seen["n"] += 1
            if seen["n"] == nth:
                raise OSError(f"injected failure at delta write #{nth}")
        return orig(name, data)

    store.write_bytes = failing
    return lambda: setattr(store, "write_bytes", orig)


@pytest.mark.parametrize("fail_at", ["second_run", "journal"])
def test_failed_publish_scrubs_every_partial_file(tmp_path, fail_at):
    """An aborted publish must leave NO delta files behind — a later
    successful publish reuses the same seq, and recovery would legitimize
    leftover orphans as published runs (phantom edges)."""
    g = rmat_graph(200, 3000, seed=3)
    store, meta = _mk_store(str(tmp_path), g, 4)
    log = EdgeLog(store)
    rng = np.random.default_rng(5)
    # wide batch: touches several shards, so run files exist pre-raise
    ins = (rng.integers(0, 200, 60), rng.integers(0, 200, 60))
    log.append(inserts=ins)
    touched = len({np.searchsorted(meta.intervals[1:], d, side="right")
                   for d in ins[1]})
    assert touched >= 2  # the scenario needs a partial-run window
    nth = 2 if fail_at == "second_run" else touched + 1  # journal write
    restore = _fail_nth_delta_write(store, nth)
    with pytest.raises(OSError, match="injected"):
        log.publish()
    restore()

    assert store.delta.version == 0
    leftovers = [f for f in os.listdir(store.root)
                 if f.startswith(("delta_run_", "delta_journal_"))]
    assert not leftovers, leftovers
    disk = store.read_meta()  # metadata untouched by the failed publish
    assert disk.num_edges == g.num_edges

    # the SAME seq is reused by the retry — it must commit cleanly and the
    # store must be bitwise the oracle (no phantom copies from orphans)
    log.append(inserts=ins)
    pub = log.publish()
    assert pub.version == 1
    src, dst = _apply_batch_oracle(g.src, g.dst, (ins, None))
    _assert_logical_equal(store, meta, Graph(200, src, dst))


def test_publish_meta_write_failure_recovers_on_reopen(tmp_path):
    """A publish whose COMMIT landed but whose metadata write failed is a
    durable publish: the version advances, and the next open replays the
    metadata journal — degrees/edge count converge to the published state
    instead of staying stale (the old stale-degree window)."""
    g = rmat_graph(150, 2000, seed=11)
    store, meta = _mk_store(str(tmp_path), g, 4)
    log = EdgeLog(store)
    ins = (np.array([1, 2, 3, 7]), np.array([4, 5, 6, 9]))
    log.append(inserts=ins)
    orig = store.write_meta

    def failing_meta(m, **kw):
        raise OSError("injected metadata write failure")

    store.write_meta = failing_meta
    with pytest.raises(OSError, match="injected"):
        log.publish()
    store.write_meta = orig

    # committed: the publish is visible despite the metadata failure
    assert store.delta.version == 1
    assert store.delta.pending_runs != {}

    # reopen: recovery replays the journal onto the metadata
    store2 = ShardStore(store.root)
    assert store2.delta.last_recovery.journal_replayed
    src, dst = _apply_batch_oracle(g.src, g.dst, (ins, None))
    _assert_logical_equal(store2, meta, Graph(150, src, dst))
    # and a second open is clean
    store3 = ShardStore(store.root)
    assert not store3.delta.last_recovery.acted
