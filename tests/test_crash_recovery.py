"""Kill-during-commit crash-recovery matrix (DESIGN.md §12).

For every named injection point in ``repro.delta.recovery.CRASH_POINTS``, a
subprocess (tests/crash_driver.py) runs a deterministic publish/publish/
compact script against a copy of a pristine store and SIGKILLs itself at
that point.  The parent then reopens the store — recovery runs inside
``DeltaOverlay.__init__`` — and asserts:

- the recovered store is BITWISE one of the per-version oracles (a
  from-scratch build of the edge list at version 0, 1 or 2 — never a mix,
  never a double-apply, never degrees ahead of edges),
- which oracle is determined by the protocol: a crash before a commit
  point recovers to the pre-operation version, after it to the committed
  one,
- no protocol debris survives recovery (orphan runs, journals, staged
  containers, stage/journal manifest records),
- recovery is idempotent (a second reopen acts on nothing), and
- the recovered store is USABLE: finishing the interrupted script from
  the recovered version converges to the same final state as a run that
  never crashed.

Kept SIGKILL-real on purpose: exception-based "crash" tests leave
``finally`` blocks running and miss exactly the windows this matrix is
for.
"""

import os
import shutil
import subprocess
import sys

import pytest

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import crash_driver
from test_delta import WINDOW, K, TR, _apply_batch_oracle, _assert_logical_equal

from repro.core.graph import Graph
from repro.core.sharding import preprocess
from repro.core.storage import (
    DELTA_JOURNAL_PREFIX,
    DELTA_RUN_PREFIX,
    DELTA_STAGE_DIR,
    ShardStore,
)
from repro.delta import CRASH_POINTS, EdgeLog, Recompactor

_SRC = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"
)

#: Protocol contract: the version a store killed at each point must
#: recover to.  Points strictly before a COMMIT roll back; points at or
#: after it roll forward.  (Publish points fire during the first publish;
#: compact points fire after both publishes committed.)
EXPECTED_VERSION = {
    "publish.first_run": 0,
    "publish.runs_written": 0,
    "publish.journal_written": 0,
    "publish.committed": 1,
    "publish.meta_written": 1,
    "compact.staged": 2,
    "compact.flipped": 2,
    "compact.csr_renamed": 2,
    "compact.renamed": 2,
    "none": 2,
}


@pytest.fixture(scope="module")
def pristine(tmp_path_factory):
    """One pristine store + the per-version oracle graphs, built once."""
    tmp = tmp_path_factory.mktemp("crash")
    root = os.path.join(str(tmp), "pristine")
    g = crash_driver.base_graph()
    meta, shards = preprocess(g, num_shards=crash_driver.N_SHARDS)
    store = ShardStore(root)
    store.write_meta(meta, ell_params={"window": WINDOW, "k": K, "tr": TR})
    for s in shards:
        store.write_shard(s, num_vertices=meta.num_vertices,
                          window=WINDOW, k=K, tr=TR)
    oracles = [g]
    src, dst = g.src, g.dst
    for ins, dels in crash_driver.batches(g):
        src, dst = _apply_batch_oracle(src, dst, ((ins), (dels)))
        oracles.append(Graph(crash_driver.N_VERTICES, src, dst))
    return root, meta, oracles


def _run_driver(root: str, point: str) -> int:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        filter(None, [_SRC, env.get("PYTHONPATH")])
    )
    driver = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          "crash_driver.py")
    proc = subprocess.run(
        [sys.executable, driver, root, point],
        env=env, capture_output=True, text=True, timeout=120,
    )
    if proc.returncode not in (0, -9):
        raise AssertionError(
            f"driver died unexpectedly ({proc.returncode}):\n{proc.stderr}"
        )
    return proc.returncode


def _assert_no_debris(root: str) -> None:
    files = os.listdir(root)
    assert not any(f.startswith(DELTA_JOURNAL_PREFIX) for f in files), files
    stage = os.path.join(root, DELTA_STAGE_DIR)
    assert not (os.path.isdir(stage) and os.listdir(stage))


def _assert_runs_consistent(store: ShardStore) -> None:
    """Every run file on disk is registered, published, and unabsorbed."""
    overlay = store.delta
    version = overlay.version if overlay else 0
    floors = overlay.floors() if overlay else {}
    for f in os.listdir(store.root):
        if not f.startswith(DELTA_RUN_PREFIX):
            continue
        p, seq = (int(x) for x in f[len(DELTA_RUN_PREFIX):-4].split("_"))
        assert seq <= version, f"orphan run past version: {f}"
        assert seq > floors.get(p, 0), f"absorbed run survived: {f}"


@pytest.mark.parametrize("point", list(CRASH_POINTS) + ["none"])
def test_kill_matrix_recovers_bitwise(pristine, tmp_path, point):
    root0, meta, oracles = pristine
    root = os.path.join(str(tmp_path), "store")
    shutil.copytree(root0, root)

    rc = _run_driver(root, point)
    assert (rc == 0) == (point == "none"), f"{point}: returncode {rc}"

    # reopen: DeltaOverlay.__init__ runs recovery before anything reads
    store = ShardStore(root)
    version = store.delta.version if store.delta is not None else 0
    assert version == EXPECTED_VERSION[point], point
    _assert_logical_equal(store, meta, oracles[version])
    _assert_no_debris(root)
    _assert_runs_consistent(store)

    # recovery is idempotent: a fresh open of the recovered store (its
    # DeltaOverlay runs the state machine again) acts on nothing and sees
    # the same state
    store2 = ShardStore(root)
    if store2.delta is not None:
        assert not store2.delta.last_recovery.acted
    _assert_logical_equal(store2, meta, oracles[version])

    # the recovered store is usable: finish the interrupted script and the
    # final state must equal the never-crashed run's
    log = EdgeLog(store2)
    g = crash_driver.base_graph()
    for ins, dels in crash_driver.batches(g)[version:]:
        log.append(inserts=ins, deletes=dels)
        log.publish()
    Recompactor(store2, min_runs=1).compact()
    _assert_logical_equal(store2, meta, oracles[-1])
    assert not store2.delta.dirty_shards()
    _assert_no_debris(root)
