"""Substrate tests: optimizer, compression, checkpointing (incl. crash/
restart + corruption detection), data determinism, straggler monitor,
preemption, end-to-end train loop with resume."""

import os
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import configs
from repro.checkpoint.checkpointer import Checkpointer
from repro.config import smoke_config
from repro.data.tokens import DataConfig, make_batch
from repro.distributed.fault_tolerance import (
    PreemptionGuard,
    StragglerMonitor,
)
from repro.optim import adamw
from repro.optim.compression import (
    CompressionConfig,
    compress_tree,
    init_error_state,
    wire_bytes_ratio,
)
from repro.train.loop import LoopConfig, train


# ---------------------------------------------------------------- optimizer
def test_adamw_reduces_quadratic_loss():
    params = {"w": jnp.asarray([3.0, -2.0, 1.0])}
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                            total_steps=200, schedule="constant")
    state = adamw.init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    for _ in range(150):
        g = jax.grad(loss)(params)
        params, state, _ = adamw.apply_updates(params, g, state, cfg)
    assert float(loss(params)) < 1e-3


def test_adamw_lr_schedule_shapes():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
    lrs = [float(adamw.lr_at(cfg, jnp.asarray(s))) for s in range(101)]
    assert lrs[0] < lrs[9] <= 1.0  # warmup
    assert lrs[100] < lrs[50] < lrs[11]  # cosine decay
    assert lrs[100] >= cfg.lr * cfg.min_lr_ratio - 1e-6


def test_grad_clip_limits_update_norm():
    params = {"w": jnp.zeros(4)}
    cfg = adamw.AdamWConfig(lr=1.0, grad_clip=0.5, weight_decay=0.0)
    state = adamw.init(params)
    huge = {"w": jnp.full(4, 1e6)}
    _, _, metrics = adamw.apply_updates(params, huge, state, cfg)
    assert float(metrics["grad_norm"]) > 1e5  # measured pre-clip


# -------------------------------------------------------------- compression
@pytest.mark.parametrize("kind,rounds,tol", [("topk", 60, 0.25), ("int8", 30, 0.01)])
def test_compression_error_feedback_preserves_signal(kind, rounds, tol):
    """Error feedback: the residual stays bounded by ~(1/ratio)·|g|, so the
    per-round AVERAGE of sent gradients converges to the true gradient at
    rate O(1/rounds) — the property that keeps compressed SGD unbiased."""
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.standard_normal(1000), jnp.float32)}
    err = init_error_state(g)
    cfg = CompressionConfig(kind=kind, topk_ratio=0.1)
    total_sent = jnp.zeros(1000)
    for _ in range(rounds):  # same gradient repeatedly
        sent, err = compress_tree(g, err, cfg)
        total_sent = total_sent + sent["w"]
    rel = float(
        jnp.abs(total_sent / rounds - g["w"]).max() / jnp.abs(g["w"]).max()
    )
    assert rel < tol, rel
    # without error feedback, top-k would permanently drop small entries
    if kind == "topk":
        nef = CompressionConfig(kind=kind, topk_ratio=0.1, error_feedback=False)
        sent0, _ = compress_tree(g, init_error_state(g), nef)
        assert float((sent0["w"] == 0).mean()) > 0.8


def test_wire_bytes_ratio():
    assert wire_bytes_ratio(CompressionConfig("none")) == 1.0
    assert wire_bytes_ratio(CompressionConfig("int8")) == 0.5  # vs bf16
    r = wire_bytes_ratio(CompressionConfig("topk", topk_ratio=0.01))
    assert 0.01 < r < 0.1


# ------------------------------------------------------------- checkpointer
def _tree():
    return {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "b": {"c": jnp.ones((5,), jnp.bfloat16)},
    }


def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    t = _tree()
    ck.save(7, t)
    out = ck.restore(7, t)
    assert np.allclose(np.asarray(out["a"]), np.asarray(t["a"]))
    assert out["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_async_and_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    t = _tree()
    for s in (1, 2, 3, 4):
        ck.save_async(s, t)
        ck.wait()
    steps = sorted(os.listdir(tmp_path))
    assert steps == ["step_00000003", "step_00000004"]
    assert ck.latest_step() == 4


def test_checkpoint_detects_corruption(tmp_path):
    ck = Checkpointer(str(tmp_path))
    t = _tree()
    path = ck.save(1, t)
    shard = os.path.join(path, "shard_00000.npz")
    with open(shard, "r+b") as f:
        f.seek(100)
        f.write(b"\x00" * 8)
    with pytest.raises(IOError, match="corrupt"):
        ck.restore(1, t)


def test_checkpoint_crash_mid_write_keeps_previous(tmp_path):
    """A .tmp dir (simulated crash) must not shadow the committed step."""
    ck = Checkpointer(str(tmp_path))
    t = _tree()
    ck.save(5, t)
    os.makedirs(os.path.join(tmp_path, "step_00000009.tmp"))
    assert ck.latest_step() == 5
    ck.restore(5, t)


# --------------------------------------------------------------------- data
def test_data_deterministic_and_host_sharded():
    cfg = DataConfig(seq_len=32, global_batch=8, vocab_size=100, seed=1)
    b1 = make_batch(cfg, step=3)
    b2 = make_batch(cfg, step=3)
    assert np.array_equal(b1["tokens"], b2["tokens"])  # stateless resume
    b3 = make_batch(cfg, step=4)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # labels are next-token shifted
    h0 = DataConfig(seq_len=32, global_batch=8, vocab_size=100, seed=1,
                    num_hosts=2, host_id=0)
    h1 = DataConfig(seq_len=32, global_batch=8, vocab_size=100, seed=1,
                    num_hosts=2, host_id=1)
    a, b = make_batch(h0, 0), make_batch(h1, 0)
    assert a["tokens"].shape[0] == 4
    assert not np.array_equal(a["tokens"], b["tokens"])  # disjoint streams


# ---------------------------------------------------- fault tolerance units
def test_straggler_monitor_flags_outliers():
    mon = StragglerMonitor(window=20, threshold=2.0)
    for i in range(10):
        mon.start_step()
        time.sleep(0.002)
        mon.end_step(i)
    mon.start_step()
    time.sleep(0.05)  # 25x median
    mon.end_step(10)
    assert len(mon.events) == 1
    assert mon.events[0].ratio > 2


def test_preemption_guard_flag():
    g = PreemptionGuard(signals=())
    assert not g.preempted
    g.trigger()
    assert g.preempted


# ------------------------------------------------------- end-to-end training
def test_train_loop_runs_and_resumes(tmp_path):
    cfg = smoke_config(configs.get_config("qwen2.5-3b"))
    data_cfg = DataConfig(seq_len=32, global_batch=4, vocab_size=cfg.vocab_size)
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=12)

    r1 = train(cfg, data_cfg, LoopConfig(total_steps=6, checkpoint_every=3,
                                         log_every=0),
               opt_cfg, checkpoint_dir=str(tmp_path))
    assert r1.final_step == 6
    assert np.isfinite(r1.losses).all()

    # resume continues from step 6 without re-running earlier steps
    r2 = train(cfg, data_cfg, LoopConfig(total_steps=9, checkpoint_every=3,
                                         log_every=0),
               opt_cfg, checkpoint_dir=str(tmp_path))
    assert r2.resumed_from == 6
    assert r2.final_step == 9
    assert len(r2.losses) == 3


def test_train_loop_preemption_checkpoints_and_stops(tmp_path):
    cfg = smoke_config(configs.get_config("yi-6b"))
    data_cfg = DataConfig(seq_len=16, global_batch=2, vocab_size=cfg.vocab_size)
    opt_cfg = adamw.AdamWConfig(lr=1e-3, total_steps=100)
    guard = PreemptionGuard(signals=())
    guard.trigger()  # preempted before step 1 completes
    r = train(cfg, data_cfg,
              LoopConfig(total_steps=50, checkpoint_every=100, log_every=0),
              opt_cfg, checkpoint_dir=str(tmp_path), preemption=guard)
    assert r.preempted and r.final_step == 1
    ck = Checkpointer(str(tmp_path))
    assert ck.latest_step() == 1  # emergency checkpoint written


def test_train_loss_decreases_on_structured_data():
    cfg = smoke_config(configs.get_config("xlstm-350m"))
    data_cfg = DataConfig(seq_len=64, global_batch=8, vocab_size=cfg.vocab_size,
                          motif_prob=1.0, motif_len=8)
    opt_cfg = adamw.AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=40,
                                weight_decay=0.0)
    r = train(cfg, data_cfg, LoopConfig(total_steps=30, log_every=0), opt_cfg)
    first = np.mean(r.losses[:5])
    last = np.mean(r.losses[-5:])
    assert last < first - 0.1, (first, last)
