"""Tests for the layered engine: scheduler plans, pipelined prefetch, and
batched multi-shard dispatch must be invisible in the results — every layer
combination is bitwise-equal to the synchronous per-shard path."""

import threading

import numpy as np
import pytest

from repro.core import apps
from repro.core.cache import ShardCache
from repro.core.csr import concat_ells, csr_to_ell
from repro.core.executor import (
    BatchedEllExecutor,
    PerShardExecutor,
    make_executor,
    update_shard_jnp,
    update_shard_numpy,
)
from repro.core.graph import rmat_graph
from repro.core.pipeline import PipelineStats, ShardPipeline
from repro.core.scheduler import ShardScheduler
from repro.core.sharding import preprocess
from repro.core.storage import ShardStore
from repro.core.vsw import VSWEngine


def _mk_engine(tmp_path, tag, **kw):
    g = kw.pop("graph", None)
    if g is None:
        g = rmat_graph(500, 6000, seed=21)
    kw.setdefault("num_shards", 6)
    kw.setdefault("window", 128)
    kw.setdefault("k", 16)
    return VSWEngine.from_graph(g, str(tmp_path / tag), **kw)


# ------------------------------------------------------------- equivalence
@pytest.mark.parametrize("backend", ["numpy", "jnp"])
@pytest.mark.parametrize("depth", [0, 1, 4])
def test_prefetch_depth_preserves_values(tmp_path, backend, depth):
    g = rmat_graph(500, 6000, seed=22)
    progs = [(apps.pagerank(), 10), (apps.sssp(0), 60), (apps.wcc(), 60)]
    ref_eng = _mk_engine(tmp_path, f"ref{backend}{depth}", graph=g,
                         backend=backend, prefetch_depth=0)
    eng = _mk_engine(tmp_path, f"d{backend}{depth}", graph=g,
                     backend=backend, prefetch_depth=depth)
    for prog, iters in progs:
        ref = ref_eng.run(prog, max_iters=iters).values
        got = eng.run(prog, max_iters=iters).values
        assert np.array_equal(
            np.nan_to_num(got, posinf=1e30), np.nan_to_num(ref, posinf=1e30)
        ), (prog.name, backend, depth)
    eng.close()
    ref_eng.close()


@pytest.mark.parametrize("backend", ["numpy", "jnp", "pallas"])
def test_batched_executor_bitwise_equals_per_shard(tmp_path, backend):
    g = rmat_graph(400, 5000, seed=23)
    per = _mk_engine(tmp_path, f"per{backend}", graph=g, backend=backend,
                     batch_shards=1, prefetch_depth=0)
    bat = _mk_engine(tmp_path, f"bat{backend}", graph=g, backend=backend,
                     batch_shards=3, prefetch_depth=2)
    for prog, iters in [(apps.pagerank(), 8), (apps.sssp(0), 40)]:
        a = per.run(prog, max_iters=iters).values
        b = bat.run(prog, max_iters=iters).values
        assert np.array_equal(
            np.nan_to_num(a, posinf=1e30), np.nan_to_num(b, posinf=1e30)
        ), (prog.name, backend)
    per.close()
    bat.close()


def test_batched_executor_reports_fewer_dispatches(tmp_path):
    eng = _mk_engine(tmp_path, "disp", backend="jnp", batch_shards=3,
                     prefetch_depth=2, selective=False)
    r = eng.run(apps.pagerank(), max_iters=3)
    for it in r.iterations:
        assert it.shards_processed == 6
        assert it.dispatches == 2  # ceil(6 / 3)
    eng.close()


def test_pipelined_cache_run_matches_and_counts(tmp_path):
    g = rmat_graph(500, 8000, seed=24)
    sync = _mk_engine(tmp_path, "sync", graph=g, backend="numpy",
                      prefetch_depth=0, cache_bytes=1 << 24, cache_mode=3,
                      selective=False)
    pipe = _mk_engine(tmp_path, "pipe", graph=g, backend="numpy",
                      prefetch_depth=4, cache_bytes=1 << 24, cache_mode=3,
                      selective=False)
    rs = sync.run(apps.pagerank(), max_iters=5)
    rp = pipe.run(apps.pagerank(), max_iters=5)
    assert np.array_equal(rs.values, rp.values)
    # warmed cache: both run disk-free with identical hit accounting
    assert rp.total_bytes_read == 0
    for it_s, it_p in zip(rs.iterations, rp.iterations):
        assert it_s.cache_hits == it_p.cache_hits == 6
    pipe.close()
    sync.close()


def test_iterstats_overlap_accounting(tmp_path):
    eng = _mk_engine(tmp_path, "ov", backend="numpy", prefetch_depth=4,
                     selective=False, emulate_bw=20e6)
    r = eng.run(apps.pagerank(), max_iters=3)
    for it in r.iterations:
        assert it.prefetch_depth == 4
        assert it.load_total_s > 0
        assert it.load_wait_s >= 0
        assert abs(it.load_overlap_s -
                   max(0.0, it.load_total_s - it.load_wait_s)) < 1e-9
    # with 4 loader threads over a throttled store, some load latency must
    # be hidden behind compute / other loads
    assert r.total_load_overlap_s > 0
    eng.close()


# --------------------------------------------------------------- scheduler
def test_scheduler_plan_matches_engine_semantics(tmp_path):
    g = rmat_graph(600, 4000, seed=25)
    eng = _mk_engine(tmp_path, "sched", graph=g, backend="numpy",
                     num_shards=8, selective=True, threshold=0.5)
    sched = eng.scheduler
    # selective off above threshold: everything planned
    many = np.arange(400, dtype=np.int64)
    plan = sched.plan(many)
    assert not plan.selective_on and plan.shards == list(range(8))
    # tiny active set: plan == exactly the shards whose filter may match
    few = np.array([3], dtype=np.int64)
    plan = sched.plan(few)
    assert plan.selective_on
    assert plan.shards == [p for p in range(8) if sched.shard_is_active(p, few)]
    assert sorted(plan.shards + plan.skipped) == list(range(8))
    eng.close()


def test_scheduler_bloom_plans_superset_of_exact(tmp_path):
    g = rmat_graph(600, 4000, seed=26)
    meta, shards = preprocess(g, num_shards=8)
    store = ShardStore(str(tmp_path / "s"))
    store.write_meta(meta)
    for s in shards:
        store.write_shard(s, num_vertices=meta.num_vertices, window=128,
                          k=16, tr=8)
    bloom = ShardScheduler(meta, threshold=1.0)
    exact = ShardScheduler(meta, threshold=1.0, exact_selective=True)
    bloom.build_filters(store)
    exact.build_filters(store)
    rng = np.random.default_rng(0)
    for _ in range(10):
        ids = rng.choice(meta.num_vertices, size=3, replace=False).astype(np.int64)
        pb, pe = bloom.plan(ids), exact.plan(ids)
        assert set(pe.shards) <= set(pb.shards)  # no false negatives


# ---------------------------------------------------------------- pipeline
@pytest.mark.parametrize("depth", [0, 2])
def test_pipeline_yields_plan_order_with_stats(tmp_path, depth):
    g = rmat_graph(300, 3000, seed=27)
    meta, shards = preprocess(g, num_shards=5)
    store = ShardStore(str(tmp_path / "s"))
    store.write_meta(meta)
    for s in shards:
        store.write_shard(s, num_vertices=meta.num_vertices, window=128,
                          k=16, tr=8)
    pipe = ShardPipeline(store, "csr", depth=depth)
    stats = PipelineStats()
    order = [3, 0, 4, 1]
    out = [ls.shard_id for ls in pipe.iter_shards(order, stats=stats)]
    assert out == order
    assert stats.shards_loaded == 4
    assert stats.load_total_s > 0
    pipe.close()


def test_shard_cache_thread_safety_hammer():
    cache = ShardCache(1 << 16, mode=2)
    blobs = {i: bytes([i % 251]) * (500 + 37 * i) for i in range(24)}
    errors = []

    def worker(seed):
        rng = np.random.default_rng(seed)
        try:
            for _ in range(300):
                i = int(rng.integers(0, 24))
                got = cache.get(i)
                if got is None:
                    cache.put(i, blobs[i])
                elif got != blobs[i]:
                    errors.append((i, len(got)))
        except Exception as e:  # pragma: no cover
            errors.append(repr(e))

    threads = [threading.Thread(target=worker, args=(s,)) for s in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    assert cache.stored_bytes <= cache.capacity_bytes
    assert cache.stats.hits + cache.stats.misses == 8 * 300


def test_store_bulk_and_async_reads(tmp_path):
    from concurrent.futures import ThreadPoolExecutor

    g = rmat_graph(300, 3000, seed=30)
    meta, shards = preprocess(g, num_shards=4)
    store = ShardStore(str(tmp_path / "s"))
    store.write_meta(meta)
    for s in shards:
        store.write_shard(s, num_vertices=meta.num_vertices, window=128,
                          k=16, tr=8)
    io0 = store.io.snapshot()
    serial = store.shard_bytes_bulk(range(4), "csr")
    d1 = store.io - io0
    assert d1.reads == 4 and d1.bytes_read == sum(len(b) for b in serial.values())
    concurrent = store.shard_bytes_bulk(range(4), "csr", max_workers=4)
    assert concurrent == serial  # same bytes, same accounting channel
    with ThreadPoolExecutor(max_workers=2) as pool:
        fut = store.read_bytes_async(store.shard_name(2, "csr"), pool)
        assert fut.result() == serial[2]
    decoded = store.load_shards([1, 3], "csr")
    assert decoded[1].shard_id == 1 and decoded[3].v1 == shards[3].v1


def test_scheduler_warm_cache_reads_csr_bytes_once(tmp_path):
    """The loading scan reuses the filter-scan bytes for cache warming when
    the cached format IS csr — no double read of every shard."""
    g = rmat_graph(300, 3000, seed=31)
    meta, shards = preprocess(g, num_shards=4)
    store = ShardStore(str(tmp_path / "s"))
    store.write_meta(meta)
    for s in shards:
        store.write_shard(s, num_vertices=meta.num_vertices, window=128,
                          k=16, tr=8)
    cache = ShardCache(1 << 24, mode=1)
    sched = ShardScheduler(meta)
    sched.build_filters(store, warm_cache=cache, cache_fmt="csr")
    assert sched.loading_io.reads == 4  # one accounted read per shard
    assert len(cache) == 4


# ---------------------------------------------------------------- executor
def test_make_executor_selection():
    assert isinstance(make_executor("numpy", batch_shards=4), PerShardExecutor)
    assert isinstance(make_executor("jnp", batch_shards=1), PerShardExecutor)
    assert isinstance(make_executor("pallas", batch_shards=4), BatchedEllExecutor)
    with pytest.raises(ValueError):
        make_executor("nope")


def test_concat_ells_roundtrip():
    g = rmat_graph(300, 4000, seed=28)
    meta, shards = preprocess(g, num_shards=4)
    ells = [csr_to_ell(s, meta.num_vertices, window=64, k=8, tr=8)
            for s in shards]
    batch = concat_ells(ells)
    assert batch.rows_total == meta.num_vertices
    assert batch.n_ell == sum(e.n_ell for e in ells)
    assert batch.tile_window.shape[0] == sum(e.n_tiles for e in ells)
    # globalized seg stays inside each shard's row interval
    off = 0
    r0 = 0
    for e in ells:
        seg = batch.seg[off: off + e.n_ell]
        assert seg.min() >= r0 and seg.max() < r0 + e.rows
        off += e.n_ell
        r0 += e.rows
    # split inverts concatenation
    acc = np.arange(batch.rows_total, dtype=np.float32)
    parts = batch.split(acc)
    assert [len(p) for p in parts] == [e.rows for e in ells]


def test_batched_shapes_are_bucketed():
    """Batched dispatch must hit a bounded set of jit shapes even as the
    batch composition changes (selective scheduling shrinks plans every
    iteration)."""
    from repro.core.csr import bucket_rows, next_pow2

    # many nearby sizes collapse into few buckets
    assert len({bucket_rows(n, 8) for n in range(8, 257, 8)}) <= 6
    assert len({next_pow2(n) for n in range(1, 257)}) == 9
    assert bucket_rows(24, 12) % 12 == 0 and bucket_rows(24, 12) >= 32


def test_pad_ell_non_pow2_tile_rows(tmp_path):
    """Regression: tile_window padding used floor division and broke
    whenever the pow2 row padding wasn't a multiple of ``tr``."""
    g = rmat_graph(200, 2500, seed=29)
    meta, shards = preprocess(g, num_shards=2)
    msgs = np.random.default_rng(0).random(meta.num_vertices).astype(np.float32)
    for s in shards:
        ell = csr_to_ell(s, meta.num_vertices, window=64, k=4, tr=12)
        assert ell.n_ell % 12 == 0
        oracle = update_shard_numpy(s, None, msgs, "sum")
        got = update_shard_jnp(s, ell, msgs, "sum")
        assert np.allclose(got, oracle, rtol=1e-5, atol=1e-9)


# ----------------- satellite: prefetch window drains after a failed sweep
def test_failed_sweep_drains_prefetch_next_sweep_clean(tmp_path):
    """After a ShardLoadError surfaces from a prefetch thread, the
    pipeline's in-flight window is drained — the NEXT sweep on the SAME
    engine must neither hang nor consume stale queue entries, and its
    values are bitwise a fresh engine's."""
    from repro.core.pipeline import ShardLoadError

    g = rmat_graph(500, 6000, seed=21)
    eng = _mk_engine(tmp_path, "drain", graph=g, prefetch_depth=2,
                     selective=False)
    ref = _mk_engine(tmp_path, "drainref", graph=g, prefetch_depth=0,
                     selective=False)
    orig = eng.store.shard_bytes
    failing = {"on": True}

    def flaky(p, fmt="csr"):
        if failing["on"] and p == 3:
            raise OSError(f"transient disk hole at shard {p}")
        return orig(p, fmt)

    eng.store.shard_bytes = flaky
    eng.pipeline.cache = None  # every load goes through the store
    with pytest.raises(ShardLoadError) as ei:
        eng.run(apps.bfs(0), max_iters=4)
    assert ei.value.shard_id == 3

    # two consecutive recovery sweeps: the first would absorb any stale
    # prefetch completions if the window had NOT been drained
    failing["on"] = False
    for _ in range(2):
        got = eng.run(apps.bfs(0), max_iters=50)
        want = ref.run(apps.bfs(0), max_iters=50)
        assert got.converged == want.converged
        assert np.array_equal(got.values, want.values)
    eng.close()
    ref.close()
