"""Integration: the dry-run pipeline end-to-end on a small simulated mesh.

Exercises lower_cell (shardings, microbatch fit, scan correction, collective
parsing) for one dense and one hybrid arch at reduced scale — the same code
path the 512-device production dry-run runs.  Subprocess because the device
count must be set before jax initialises."""

import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import numpy as np
    import jax
    from repro import configs
    from repro.config import ShapeConfig, smoke_config
    from repro.launch import dryrun as DR

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    shape_train = ShapeConfig("train_tiny", 64, 8, "train")
    shape_dec = ShapeConfig("decode_tiny", 128, 8, "decode")

    for arch in ("yi-6b", "jamba-1.5-large-398b"):
        cfg = smoke_config(configs.get_config(arch))
        # widen smoke dims so the 4-way model axis divides them
        cfg = dataclasses.replace(cfg, d_model=128, d_ff=256,
                                  dense_d_ff=256 if cfg.dense_d_ff else 0)
        for shape in (shape_train, shape_dec):
            compiled, info = DR.lower_cell(
                cfg, shape, mesh, verbose=False, microbatches=1,
            )
            t = info["terms"]
            assert t["flops_per_dev"] > 0, (arch, shape.name)
            assert t["bytes_per_dev"] > 0
            assert info["memory"]["peak_bytes_estimate"] > 0
            # scan correction multiplied the body: corrected flops must
            # exceed the raw single-body cost for a multi-group model
            print(arch, shape.name, "OK",
                  f"flops={t['flops_per_dev']:.3e}",
                  f"col={t['collective_bytes_per_dev']:.3e}")
    print("DRYRUN_SMALL_OK")
    """
)


@pytest.mark.slow
def test_dryrun_pipeline_small_mesh():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        env=env, timeout=900,
    )
    assert r.returncode == 0, (r.stdout[-1500:], r.stderr[-3000:])
    assert "DRYRUN_SMALL_OK" in r.stdout
