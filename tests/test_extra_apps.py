"""Extra vertex programs: personalized PageRank and degree centrality —
the paper's claim that the Update API expresses arbitrary pull-mode apps."""

import numpy as np
import pytest

from repro.core import apps
from repro.core.graph import from_edge_list, rmat_graph
from repro.core.vsw import VSWEngine


def test_degree_centrality_counts_in_edges(tmp_path):
    g = rmat_graph(300, 4000, seed=0)
    eng = VSWEngine.from_graph(g, str(tmp_path / "s"), num_shards=4,
                               window=128, k=16, backend="numpy",
                               selective=False)
    r = eng.run(apps.degree_centrality(), max_iters=2)
    assert np.array_equal(r.values, g.in_degrees().astype(np.float32))


def test_ppr_mass_conservation_and_locality(tmp_path):
    # a two-cluster graph: PPR from cluster A should concentrate there
    edges = []
    rng = np.random.default_rng(1)
    for _ in range(600):  # cluster A: 0..19
        a, b = rng.integers(0, 20, 2)
        edges.append((a, b))
    for _ in range(600):  # cluster B: 20..39
        a, b = rng.integers(20, 40, 2)
        edges.append((a, b))
    edges.append((0, 20))  # weak bridge
    edges.append((20, 0))
    g = from_edge_list(edges, num_vertices=40)

    eng = VSWEngine.from_graph(g, str(tmp_path / "s"), num_shards=3,
                               window=16, k=8, backend="numpy",
                               selective=False)
    r = eng.run(apps.personalized_pagerank(source=0), max_iters=60)
    vals = r.values
    # teleport keeps total mass ~1 (dangling leakage aside)
    assert 0.3 < vals.sum() <= 1.0 + 1e-4
    # locality: cluster A holds most of the mass
    assert vals[:20].sum() > 3 * vals[20:].sum()
    # and the source is the top vertex
    assert vals.argmax() == 0


def test_ppr_source_in_any_shard(tmp_path):
    """The teleport indexing must survive interval offsets (v0 != 0)."""
    g = rmat_graph(200, 2000, seed=2)
    for source in (0, 150, 199):
        eng = VSWEngine.from_graph(
            g, str(tmp_path / f"s{source}"), num_shards=5, window=64, k=8,
            backend="numpy", selective=False,
        )
        r = eng.run(apps.personalized_pagerank(source=source), max_iters=40)
        assert r.values[source] >= 0.15 - 1e-3  # at least the teleport share


def test_registry_lists_all_apps():
    for name in ("pagerank", "sssp", "wcc", "bfs", "ppr", "degree"):
        p = apps.get_program(name)
        assert p.combine in ("sum", "min", "max")
    with pytest.raises(KeyError):
        apps.get_program("nope")
