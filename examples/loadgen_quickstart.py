"""GraphPulse quickstart: load-test a live service, watch the SLOs.

Starts an in-process :class:`GraphService` with the telemetry ticker and
an SLO monitor running, then replays a seeded mixed BFS / SSSP / WCC /
PPR workload with a concurrent mutation stream in both load-gen modes:

1. **closed loop** — 4 workers, ``submit_batch`` chunks of 4: sustained
   QPS with exact p50/p99 and the queue-wait vs sweep split;
2. **open loop** — arrival-scheduled at a target QPS with Poisson
   inter-arrivals: offered vs achieved rate, queueing delay measured
   rather than hidden.

Afterwards it prints the SLO burn rates (healthy run: no violations),
writes the telemetry ring as JSONL (``loadgen_quickstart.jsonl``, one
JSON object per closed window) and a Prometheus text exposition
(``loadgen_quickstart.prom`` — feed it to ``promtool check metrics``),
and replays a few completed queries on a solo oracle engine at their
exact graph version to demonstrate the bitwise-reproducibility contract.

    PYTHONPATH=src python examples/loadgen_quickstart.py
"""

import tempfile

import numpy as np

from repro.core import apps
from repro.core.graph import from_edge_list
from repro.core.vsw import VSWEngine
from repro.obs import (
    error_rate_slo,
    latency_slo,
    prometheus_text,
    share_slo,
    write_jsonl,
)
from repro.serve import (
    GraphService,
    LoadGenerator,
    QueryClass,
    Workload,
    edge_state_at_version,
    oracle_kwargs,
)

JSONL_OUT = "loadgen_quickstart.jsonl"
PROM_OUT = "loadgen_quickstart.prom"


def main():
    rng = np.random.default_rng(0)
    n, m = 5_000, 80_000
    edges = rng.integers(0, n, size=(m, 2)).astype(np.int64)
    g = from_edge_list(edges, n)

    workload = Workload(
        classes=(
            QueryClass("bfs", weight=2.0, max_iters=6),
            QueryClass("sssp", weight=1.0, max_iters=6),
            QueryClass("wcc", weight=1.0, max_iters=6),
            QueryClass("ppr", weight=1.0, max_iters=5,
                       params={"damping": 0.85}),
        ),
        seed=42,
        update_every=16,   # one mutation batch every 16 queries
        update_batch=32,   # of 32 random inserted edges
    )

    with tempfile.TemporaryDirectory() as d:
        with GraphService.from_graph(
            g, f"{d}/store", num_shards=6, backend="numpy", max_lanes=16,
        ) as svc:
            svc.start_telemetry(interval_s=0.1, slos=[
                latency_slo("latency_p99", threshold_s=10.0, budget=0.01),
                error_rate_slo("admission_errors", budget=0.05),
                share_slo("queue_wait_share", budget=0.95),
            ])

            print("== closed loop: 4 workers, submit_batch chunks of 4 ==")
            rep = LoadGenerator(
                svc, workload, mode="closed", concurrency=4, batch_size=4,
                total_ops=64, warmup_ops=12,
            ).run()
            print(f"  qps={rep.qps:.1f}  completed={rep.completed}"
                  f"  rejected={rep.rejected}  mix={rep.per_class}")
            print(f"  p50={rep.latency['p50']*1e3:.1f}ms"
                  f"  p99={rep.latency['p99']*1e3:.1f}ms"
                  f"  queue-wait share={rep.queue_wait_share:.0%}"
                  f"  updates published={rep.updates_published}")

            print("== open loop: 150 QPS offered, Poisson arrivals ==")
            rep_o = LoadGenerator(
                svc, workload, mode="open", target_qps=150.0, poisson=True,
                total_ops=32, warmup_ops=6,
            ).run()
            print(f"  offered={rep_o.offered_qps:.1f}"
                  f"  achieved={rep_o.qps:.1f}"
                  f"  p99={rep_o.latency['p99']*1e3:.1f}ms"
                  f"  rejected={rep_o.rejected}")

            snap = svc.metrics_snapshot()
            print("== SLOs ==")
            for obj in snap["slo"]["objectives"]:
                burns = {
                    k: (f"{v['burn_long']:.2f}"
                        if v["burn_long"] is not None else "n/a")
                    for k, v in obj["burn_rates"].items()
                }
                print(f"  {obj['name']} ({obj['kind']},"
                      f" budget={obj['budget']}): burn {burns}")
            print(f"  violations: {len(snap['slo']['violations'])}"
                  f"  errors: {snap['errors']}")

            with open(PROM_OUT, "w") as f:
                f.write(prometheus_text(svc.metrics))
            ts = svc.stop_telemetry()
            n_windows = write_jsonl(JSONL_OUT, ts)
            print(f"wrote {PROM_OUT} and {JSONL_OUT} ({n_windows} windows)")

        # the reproducibility contract: any record replays bitwise on a
        # solo engine built at exactly its graph version
        print("== oracle replay (bitwise) ==")
        done = [r for r in rep.records if r.ok][:4]
        norm = lambda v: np.nan_to_num(v, posinf=1e30)
        for r in done:
            g_v = from_edge_list(
                edge_state_at_version(edges, rep.updates, r.graph_version), n
            )
            eng = VSWEngine.from_graph(
                g_v, f"{d}/oracle{r.index}", num_shards=6, backend="numpy"
            )
            solo = eng.run(apps.get_program(r.program, **oracle_kwargs(r)),
                           max_iters=r.max_iters)
            match = bool(np.array_equal(norm(solo.values), norm(r.values)))
            print(f"  {r.program}@{r.source} v{r.graph_version}: "
                  f"{'bitwise-equal' if match else 'MISMATCH'}")
            assert match
            eng.close()


if __name__ == "__main__":
    main()
