"""GraphScope quickstart: trace a serving run, open it in Perfetto.

Starts an in-process :class:`GraphService` with a :class:`Tracer`
installed, runs a mixed BFS / SSSP / personalized-PageRank workload with
one live edge-update batch in the middle, then:

1. exports a Chrome-trace JSON (``trace_quickstart.json``) — load it at
   https://ui.perfetto.dev (or ``chrome://tracing``) to see the full
   admit -> plan -> prefetch -> load -> decode -> dispatch -> retire
   timeline, with one lane per thread (service worker, shard
   prefetchers, the delta recompactor);
2. prints the service's metrics snapshot: p50/p95/p99 query latency
   split into queue-wait vs sweep time, per-stage sweep timings, and the
   result of replaying every declared conservation identity.

    PYTHONPATH=src python examples/trace_quickstart.py
"""

import json
import tempfile

import numpy as np

from repro.core.graph import rmat_graph
from repro.obs import Tracer, trace
from repro.serve import GraphService

N_QUERIES = 24
OUT = "trace_quickstart.json"


def _mixed_queries(num_vertices, seed=0):
    rng = np.random.default_rng(seed)
    programs = ["bfs", "sssp", "ppr"]
    return [
        (programs[i % len(programs)], int(rng.integers(num_vertices)))
        for i in range(N_QUERIES)
    ]


def _fmt_pct(name, p):
    return (f"  {name:14} n={p['count']:<4d} p50={p['p50'] * 1e3:8.2f}ms  "
            f"p95={p['p95'] * 1e3:8.2f}ms  p99={p['p99'] * 1e3:8.2f}ms")


def main() -> None:
    print("== GraphScope quickstart ==")
    g = rmat_graph(num_vertices=4_000, num_edges=60_000, seed=0)
    queries = _mixed_queries(g.num_vertices)
    tracer = Tracer()

    with trace.tracing(tracer):  # installs the tracer for every thread
        with tempfile.TemporaryDirectory() as root:
            with GraphService.from_graph(
                g, root,
                num_shards=8,
                backend="numpy",
                mesh=2,              # 2-device mesh emulation: device-split
                                     # conservation identities get declared
                max_lanes=8,
                max_groups=2,        # fuse bfs/sssp with ppr on one stream
                auto_compact_runs=1,  # so the recompactor lane shows up
            ) as service:
                half = N_QUERIES // 2
                futs = [service.submit(p, s, max_iters=20)
                        for p, s in queries[:half]]
                for f in futs:
                    f.result()

                # a live update between sweeps: overlay.merge + compact.shard
                # spans appear, later queries run on the new graph version
                service.apply_updates(
                    inserts=[(1, 2), (3, 4), (5, 6)], deletes=[(0, 1)]
                ).result()

                futs = [service.submit(p, s, max_iters=20)
                        for p, s in queries[half:]]
                for f in futs:
                    f.result()

                snap = service.metrics_snapshot()

    doc = tracer.export_chrome(OUT)
    lanes = sorted(tracer.thread_names())
    spans = {e["name"] for e in doc["traceEvents"] if e["ph"] == "X"}
    print(f"\nwrote {OUT}: {len(doc['traceEvents'])} events, "
          f"{len(lanes)} thread lanes "
          f"(dropped={doc['otherData']['dropped_events']})")
    print("  lanes:", ", ".join(lanes))
    print("  spans:", ", ".join(sorted(spans)))
    print("open in https://ui.perfetto.dev or chrome://tracing")

    print("\nquery latency (submit -> result):")
    print(_fmt_pct("total", snap["query_latency_s"]))
    print(_fmt_pct("queue wait", snap["queue_wait_s"]))
    print(_fmt_pct("sweep", snap["sweep_s"]))
    print("per-stage sweep timings:")
    for stage, p in snap["stages"].items():
        print(_fmt_pct(stage, p))
    bad = snap["conservation_violations"]
    print(f"conservation: {'OK' if not bad else bad} "
          f"({service.metrics.num_checks} identities replayed)")

    with open(OUT) as f:
        json.load(f)  # the artifact round-trips as valid JSON
    print("\ndone.")


if __name__ == "__main__":
    main()
