"""Live edge mutations: update a serving graph without downtime.

PR 3 made the store buildable out-of-core; GraphDelta (repro/delta) makes
it UPDATABLE.  This example walks the serving-side update loop:

1. stream-ingest an edge file and start a `GraphService` on it, with
   background recompaction enabled (`auto_compact_runs`),
2. answer a BFS query, then `apply_updates()` — insert a shortcut edge and
   delete one on the query's shortest path — and watch the SAME query
   return a different (correct) answer at the new graph version,
3. show that in-flight/repeat queries are version-tagged
   (`QueryResult.graph_version`) and that the session cache never serves a
   stale version,
4. drive enough updates that the recompactor folds the delta runs back
   into the base shards, then verify the store is clean and still serving.

The same machinery works without a service: `EdgeLog(store).append(...);
publish()` between `VSWEngine.run()` calls, and `Recompactor(store)`
for synchronous maintenance.

Run:  PYTHONPATH=src python examples/update_quickstart.py
"""

import os
import tempfile
import time

import numpy as np

from repro.core.graph import small_world_graph
from repro.core.ingest import write_edge_file
from repro.serve import GraphService


def main() -> None:
    num_v = 20_000
    with tempfile.TemporaryDirectory() as d:
        edge_path = os.path.join(d, "edges.bin")
        root = os.path.join(d, "store")

        # 1. build + serve (high-diameter graph so BFS answers are legible)
        g = small_world_graph(num_v, k=2, shortcuts=0.0002, seed=7)
        write_edge_file(edge_path, g.src, g.dst)
        svc = GraphService.from_edge_file(
            edge_path, root,
            num_shards=8, num_vertices=num_v,
            max_lanes=8, auto_compact_runs=4,
        )
        print(f"serving {num_v} vertices / {g.num_edges} edges from {root}")

        # ``far`` is 50 ring-hops away (k=2 ring): close enough to resolve
        # within the iteration budget, far enough that a shortcut matters
        src, far = 0, 100
        r0 = svc.query("bfs", src)
        print(f"v{r0.graph_version}: dist({src} -> {far}) = "
              f"{r0.values[far]:.0f}  (iters={r0.iterations})")

        # 2. mutate: add a direct shortcut src -> far, remove a ring edge
        upd = svc.apply_updates(
            inserts=(np.array([src]), np.array([far])),
            deletes=(np.array([src]), np.array([1])),
        ).result()
        print(f"published v{upd.graph_version}: +{upd.edges_inserted} "
              f"-{upd.edges_removed} edges, shards {upd.shards_touched}")

        r1 = svc.query("bfs", src)
        assert r1.graph_version == upd.graph_version
        assert r1.values[far] == 1.0, "shortcut must be visible immediately"
        print(f"v{r1.graph_version}: dist({src} -> {far}) = "
              f"{r1.values[far]:.0f}  <- shortcut live, no re-preprocess")

        # 3. repeat query: session-cache hit, same version tag
        r2 = svc.query("bfs", src)
        print(f"repeat query: cached={r2.cached} at v{r2.graph_version}")
        assert r2.cached and r2.graph_version == r1.graph_version

        # 4. churn updates; the background recompactor absorbs a shard's
        # runs once it accumulates auto_compact_runs of them (LSM-style
        # batching — shards below the threshold stay on the overlay path)
        rng = np.random.default_rng(0)
        for _ in range(8):
            svc.apply_updates(
                inserts=(rng.integers(0, num_v, 200),
                         rng.integers(0, num_v, 200)),
            ).result()
        deadline = time.time() + 10
        while (svc.stats().get("shards_compacted", 0) == 0
               and time.time() < deadline):
            time.sleep(0.05)
        st = svc.stats()
        print(f"after churn: graph_version={st['graph_version']} "
              f"dirty_shards={st['dirty_shards']} "
              f"shards_compacted={st.get('shards_compacted')}")
        assert st.get("shards_compacted", 0) >= 1, "background compaction"

        # drain the sub-threshold tail explicitly (e.g. before a snapshot)
        svc.compact()
        assert svc.stats()["dirty_shards"] == 0

        r3 = svc.query("bfs", src)
        assert r3.values[far] == 1.0  # the shortcut survived recompaction
        print(f"v{r3.graph_version}: dist({src} -> {far}) = "
              f"{r3.values[far]:.0f}  (served from compacted base shards)")
        svc.close()
        print("done.")


if __name__ == "__main__":
    main()
