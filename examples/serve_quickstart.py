"""GraphServe quickstart: concurrent multi-query serving on one warm engine.

Builds a small power-law graph, starts an in-process :class:`GraphService`,
submits 32 mixed BFS / SSSP / personalized-PageRank queries, and prints
per-query latency plus the aggregate shard-load amortization — how many
shard fetches each query paid, versus the sequential one-query-at-a-time
baseline the lane batching replaces.

    PYTHONPATH=src python examples/serve_quickstart.py
"""

import tempfile
import time

import numpy as np

from repro.core.graph import rmat_graph
from repro.serve import GraphService

N_QUERIES = 32


def _mixed_queries(num_vertices, seed=0):
    """32 mixed queries: programs interleaved, sources spread over |V|."""
    rng = np.random.default_rng(seed)
    programs = ["bfs", "sssp", "ppr"]
    return [
        (programs[i % len(programs)], int(rng.integers(num_vertices)))
        for i in range(N_QUERIES)
    ]


def _run(service, queries):
    t0 = time.perf_counter()
    futs = [service.submit(p, s, max_iters=20) for p, s in queries]
    results = [f.result() for f in futs]
    return results, time.perf_counter() - t0


def main() -> None:
    print("== GraphServe quickstart ==")
    g = rmat_graph(num_vertices=4_000, num_edges=60_000, seed=0)
    print(f"graph: |V|={g.num_vertices:,} |E|={g.num_edges:,}")
    queries = _mixed_queries(g.num_vertices)

    with tempfile.TemporaryDirectory() as root:
        with GraphService.from_graph(
            g, root,
            num_shards=8,
            backend="numpy",      # numpy | jnp | pallas
            max_lanes=16,         # lane budget: K queries share one sweep
            session_entries=64,   # LRU result cache (program, source, version)
        ) as service:
            results, wall = _run(service, queries)

            print(f"\n{'id':>3} {'program':7} {'source':>6} {'iters':>5} "
                  f"{'conv':>4} {'latency_ms':>10} {'loads':>7} {'read_kb':>8}")
            for r in results:
                print(f"{r.request_id:3d} {r.program:7} {r.source:6d} "
                      f"{r.iterations:5d} {str(r.converged):>4} "
                      f"{r.latency_s * 1e3:10.1f} {r.shard_loads:7.1f} "
                      f"{r.bytes_read / 1e3:8.1f}")

            st = service.stats()
            lat = sorted(r.latency_s for r in results)
            print(f"\nqueries={st['queries_completed']}  "
                  f"sweeps={st['sweeps']}  wall={wall:.2f}s  "
                  f"throughput={len(results) / wall:.1f} q/s")
            print(f"latency p50={lat[len(lat) // 2] * 1e3:.1f}ms  "
                  f"p95={lat[int(len(lat) * 0.95)] * 1e3:.1f}ms")

            # repeat traffic: session-cache hits bypass the lane queue
            again, _ = _run(service, queries[:8])
            print(f"resubmitted 8 queries: "
                  f"{sum(r.cached for r in again)} served from session cache")
            batched_loads = st["loads_per_query"]

        # Sequential baseline: the same queries, one lane (K=1) — every
        # query pays its own full sweep of shard loads.
        with tempfile.TemporaryDirectory() as seq_root:
            with GraphService.from_graph(
                g, seq_root, num_shards=8, backend="numpy",
                max_lanes=1, session_entries=0,
            ) as sequential:
                _run(sequential, queries)
                seq_loads = sequential.stats()["loads_per_query"]

    print(f"\nshard-load amortization: {batched_loads:.1f} loads/query "
          f"batched vs {seq_loads:.1f} sequential "
          f"-> {seq_loads / max(batched_loads, 1e-9):.1f}x fewer loads")


if __name__ == "__main__":
    main()
