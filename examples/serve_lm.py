"""Serving demo: batched prefill + decode with KV caches.

Loads a smoke-scale model, prefills a batch of prompts, then decodes
tokens autoregressively — the same prefill/decode_step functions the
dry-run lowers at 32k/512k scale.

    PYTHONPATH=src python examples/serve_lm.py [--arch qwen2.5-3b]
"""

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro import configs
from repro.config import smoke_config
from repro.distributed.sharding import LOCAL_CTX
from repro.models import model as M


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b", choices=configs.list_archs())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    args = ap.parse_args()

    cfg = smoke_config(configs.get_config(args.arch))
    params = M.init_params(jax.random.key(0), cfg, dtype=jnp.float32)
    rng = np.random.default_rng(0)

    B, P, G = args.batch, args.prompt_len, args.gen_len
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, P)), jnp.int32)}
    if cfg.frontend == "vision_stub":
        batch["patch_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.prefix_len, cfg.d_model)), jnp.float32)
    if cfg.encdec:
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, cfg.encoder_seq, cfg.d_model)), jnp.float32)

    prefill = jax.jit(lambda p, b: M.prefill(p, b, cfg, LOCAL_CTX))
    decode = jax.jit(
        lambda p, t, kv, i: M.decode_step(p, t, kv, i, cfg, LOCAL_CTX))

    t0 = time.perf_counter()
    logits, caches = prefill(params, batch)
    prefix = cfg.prefix_len if cfg.frontend == "vision_stub" else 0
    caches = M.pad_caches(caches, cfg, max_seq=P + G + prefix)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    toks = jnp.argmax(logits, axis=-1)[:, None]
    out = [np.asarray(toks)]
    t0 = time.perf_counter()
    for step in range(G - 1):
        logits, caches = decode(
            params, toks, caches, jnp.int32(P + prefix + step))
        toks = jnp.argmax(logits, axis=-1)[:, None]
        out.append(np.asarray(toks))
    jax.block_until_ready(logits)
    t_decode = time.perf_counter() - t0

    gen = np.concatenate(out, axis=1)
    print(f"arch={cfg.name}  batch={B} prompt={P} gen={G}")
    print(f"prefill: {t_prefill*1e3:.1f} ms "
          f"({B*P/t_prefill:.0f} tok/s)")
    print(f"decode:  {t_decode*1e3:.1f} ms total "
          f"({B*(G-1)/t_decode:.0f} tok/s)")
    print(f"sample generated ids (row 0): {gen[0].tolist()}")


if __name__ == "__main__":
    main()
