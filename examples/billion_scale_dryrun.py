"""The paper at pod scale: distributed VSW on EU-2015 (1.07B vertices,
91.8B edges) — the paper's largest dataset — lowered for a 256-chip pod.

This is the "what would it take" exercise the paper's single-machine
design motivates: the SEM contract (vertices resident, edges streamed)
maps onto the mesh as interval-sharded vertex arrays plus a per-superstep
all-gather of the message array (DESIGN.md §5).

Run standalone (sets the 512-device flag itself):

    PYTHONPATH=src python examples/billion_scale_dryrun.py
"""

import os

os.environ.setdefault(
    "XLA_FLAGS", "--xla_force_host_platform_device_count=512"
)

import numpy as np  # noqa: E402

import jax  # noqa: E402


def main() -> None:
    from repro.configs.graphmp import EU2015
    from repro.core.distributed import device_graph_specs, make_superstep
    from repro.launch.mesh import make_production_mesh
    from repro.roofline import analysis as RA
    from repro.roofline import hw

    mesh = make_production_mesh(multi_pod=False)
    n_dev = int(np.prod(mesh.devices.shape))
    rows_per_dev = -(-EU2015.num_vertices // n_dev)
    print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))}")
    print(f"workload: {EU2015.name} |V|={EU2015.num_vertices:.2e} "
          f"|E|={EU2015.num_edges:.2e}")

    specs = device_graph_specs(EU2015.num_vertices, EU2015.num_edges, n_dev)
    for k, v in specs.items():
        print(f"  input {k}: {v.shape} {v.dtype}")

    step, _, _ = make_superstep(
        mesh, "pagerank", EU2015.num_vertices, rows_per_dev)
    lowered = step.lower(
        specs["src_vals"], specs["ell_idx"], specs["ell_valid"],
        specs["seg"], specs["out_deg"])
    compiled = lowered.compile()
    print(compiled.memory_analysis())
    cost = compiled.cost_analysis()
    col = RA.parse_collectives(compiled.as_text())
    terms = RA.RooflineTerms(
        flops_per_dev=float(cost.get("flops", 0) or 0),
        bytes_per_dev=float(cost.get("bytes accessed", 0) or 0),
        collective_bytes_per_dev=float(col.total_bytes),
        n_chips=n_dev,
    )
    print(f"\nroofline terms per superstep (one PageRank iteration):")
    print(f"  compute:    {terms.compute_s*1e3:9.3f} ms")
    print(f"  memory:     {terms.memory_s*1e3:9.3f} ms")
    print(f"  collective: {terms.collective_s*1e3:9.3f} ms "
          f"({terms.collective_bytes_per_dev/2**30:.2f} GiB/dev — the "
          f"all-gathered SEM working set)")
    print(f"  dominant:   {terms.dominant}")
    eps = EU2015.num_edges / terms.step_time_s
    print(f"  edges/s (no-overlap bound): {eps:.3e} "
          f"(paper's testbed: ~1e9 edges/s)")


if __name__ == "__main__":
    main()
