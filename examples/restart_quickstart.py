"""Warm restarts: kill a serving box, boot the next one in milliseconds.

PR 8 closes the crash windows (journaled publish + staged-rename
compaction, DESIGN.md §12), so a killed service always reopens to a
consistent store.  This example shows the OTHER half of the restart
story: `WarmState` checkpoints make the reopen fast.

A cold `GraphService.from_store` boot re-derives its serving state by
scanning the store — every shard is read once just to build the Bloom
filters.  A warm boot restores that state from a checkpoint instead:

1. ingest + serve, apply an update, answer a query (populating the
   session cache), then `save_warm_state()` and close — simulating a
   planned restart or a periodic snapshot before a crash,
2. cold-boot a fresh service and count its boot reads,
3. warm-boot from the checkpoint: ZERO boot reads, the repeat query is a
   session-cache hit, and fresh queries are bitwise the cold service's,
4. mutate the store BEHIND a snapshot and warm-boot again: the touched
   shard is rejected (store is authoritative), everything else stays
   warm, and answers are still correct.

An `emulate_bw` throttle makes the boot-time difference visible on a
small example; `fig_restart` (benchmarks/bench_graphmp.py) measures the
same story in CI.

Run:  PYTHONPATH=src python examples/restart_quickstart.py
"""

import os
import tempfile
import time

import numpy as np

from repro.core.graph import rmat_graph
from repro.serve import GraphService

BW = 200e6  # emulated disk bandwidth, bytes/s — makes boot reads cost time


def main() -> None:
    num_v, num_e, shards = 20_000, 200_000, 8
    with tempfile.TemporaryDirectory() as d:
        root = os.path.join(d, "store")
        ckdir = os.path.join(d, "warm")

        # 1. serve, mutate, query, snapshot, die
        g = rmat_graph(num_v, num_e, seed=3)
        svc = GraphService.from_graph(
            g, root, num_shards=shards, cache_bytes=64 << 20)
        svc.apply_updates(
            inserts=(np.array([1, 2]), np.array([3, 4]))).result()
        r0 = svc.query("bfs", 0)
        svc.save_warm_state(ckdir)
        svc.close()
        print(f"snapshot saved to {ckdir} at store version "
              f"{svc.engine.store.delta.version}")

        # 2. cold boot: the filter build reads every shard
        t0 = time.perf_counter()
        cold = GraphService.from_store(root, emulate_bw=BW,
                                       cache_bytes=64 << 20)
        cold_wall = time.perf_counter() - t0
        io = cold.engine.loading_io
        print(f"cold boot: {cold_wall*1e3:7.1f} ms  "
              f"({io.reads} reads, {io.bytes_read} bytes)")

        # 3. warm boot: restore Bloom sources + session cache, read nothing
        t0 = time.perf_counter()
        warm = GraphService.from_store(root, warm_state=ckdir,
                                       emulate_bw=BW, cache_bytes=64 << 20)
        warm_wall = time.perf_counter() - t0
        rep = warm.warm_restore_report
        io = warm.engine.loading_io
        print(f"warm boot: {warm_wall*1e3:7.1f} ms  "
              f"({io.reads} reads, {io.bytes_read} bytes)  "
              f"shards_warm={rep['shards_warm']}/{shards} "
              f"sessions={rep['sessions_restored']}")
        assert rep["valid"] and io.reads == 0
        assert warm_wall < cold_wall

        hit = warm.query("bfs", 0)  # restored session entry: no sweep
        assert hit.cached and np.array_equal(hit.values, r0.values)
        print(f"repeat query after warm boot: cached={hit.cached}")
        a, b = warm.query("sssp", 7), cold.query("sssp", 7)
        assert np.array_equal(a.values, b.values)  # warm == cold, bitwise
        warm.close()

        # 4. the store moves on behind the snapshot: publish via the cold
        # service, then warm-boot from the now-stale checkpoint
        cold.apply_updates(
            inserts=(np.array([5]), np.array([6]))).result()
        r_new = cold.query("bfs", 0)
        cold.close()

        stale = GraphService.from_store(root, warm_state=ckdir,
                                        cache_bytes=64 << 20)
        rep = stale.warm_restore_report
        print(f"stale snapshot: shards_warm={rep['shards_warm']} "
              f"shards_stale={rep['shards_stale']} "
              f"sessions={rep['sessions_restored']}")
        assert rep["valid"] and rep["shards_stale"] >= 1
        assert rep["sessions_restored"] == 0  # content changed: no replays
        r = stale.query("bfs", 0)
        assert not r.cached and np.array_equal(r.values, r_new.values)
        print("stale shards rejected, answers still correct — the store "
              "is always authoritative.")
        stale.close()
        print("done.")


if __name__ == "__main__":
    main()
