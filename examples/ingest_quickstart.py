"""Out-of-core boot: stream an on-disk edge file into a store, then serve.

The point of the semi-external-memory model is |E| >> RAM — so the one
thing a serving box must NOT do is materialize the edge list to build its
shards.  This example walks the full out-of-core path:

1. write a raw binary edge file (8 bytes/edge, the interchange format a
   crawler or ETL job would hand us),
2. stream-ingest it with a deliberately tiny chunk/spill budget so the
   two-pass external build actually spills and merges,
3. boot a VSWEngine straight from the store directory — no Graph object —
   and run PageRank,
4. boot a GraphService from the same directory and answer point queries.

Run:  PYTHONPATH=src python examples/ingest_quickstart.py
"""

import os
import tempfile

import numpy as np

from repro.core import apps
from repro.core.graph import rmat_graph
from repro.core.ingest import write_edge_file
from repro.core.storage import ShardStore
from repro.core.vsw import VSWEngine
from repro.serve import GraphService


def main() -> None:
    num_v, num_e = 50_000, 1_000_000
    with tempfile.TemporaryDirectory() as d:
        edge_path = os.path.join(d, "edges.bin")
        root = os.path.join(d, "store")

        # 1. the edge file an upstream job would produce (8 B/edge)
        g = rmat_graph(num_v, num_e, seed=0)
        nbytes = write_edge_file(edge_path, g.src, g.dst)
        del g  # from here on, nothing holds the edge list
        print(f"edge file: {num_e:,} edges, {nbytes / 1e6:.1f} MB")

        # 2. two-pass external build: bounded chunks, spill runs, k-way merge
        store = ShardStore(root)
        meta, stats = store.ingest(
            edge_path,
            edges_per_shard=60_000,
            chunk_edges=25_000,          # pass over the file 25k edges at a time
            mem_budget_bytes=1 << 20,    # spill once 1 MB of keys is buffered
        )
        print(
            f"ingested: {meta.num_shards} shards | "
            f"{stats.spills} spills, {stats.runs} runs, "
            f"{stats.spill_bytes_written / 1e6:.1f} MB spilled | "
            f"peak scatter buffer {stats.peak_buffered_bytes / 1e6:.2f} MB"
        )

        # 3. engine boots from the directory alone
        with VSWEngine.from_store(root, backend="numpy",
                                  cache_bytes=64 << 20) as engine:
            r = engine.run(apps.pagerank(), max_iters=10)
            top = np.argsort(r.values)[-3:][::-1]
            print(f"pagerank top-3 vertices: {top.tolist()}")

        # 4. so does the serving layer
        with GraphService.from_store(root, max_lanes=8,
                                     backend="numpy") as svc:
            futs = [svc.submit("bfs", int(s), max_iters=50)
                    for s in (0, 7, 99)]
            for f in futs:
                q = f.result()
                reached = int(np.isfinite(q.values).sum())
                print(f"bfs from {q.source}: reached {reached:,} vertices "
                      f"in {q.iterations} iterations")


if __name__ == "__main__":
    main()
