"""Quickstart: GraphMP on a synthetic power-law graph.

Builds an RMAT graph, preprocesses it into destination-interval shards,
and runs the paper's three applications through the semi-external-memory
VSW engine with Bloom-filter selective scheduling and a compressed cache.

    PYTHONPATH=src python examples/quickstart.py
"""

import tempfile

import numpy as np

from repro.core import apps
from repro.core.graph import rmat_graph
from repro.core.vsw import VSWEngine


def main() -> None:
    print("== GraphMP quickstart ==")
    g = rmat_graph(num_vertices=50_000, num_edges=1_000_000, seed=0)
    print(f"graph: |V|={g.num_vertices:,} |E|={g.num_edges:,} "
          f"max_in_deg={g.in_degrees().max():,}")

    with tempfile.TemporaryDirectory() as root:
        engine = VSWEngine.from_graph(
            g, root,
            num_shards=16,          # paper: ~18-22M edges/shard at scale
            backend="jnp",          # numpy | jnp | pallas
            selective=True,         # Bloom-filter shard skipping (§II-D-1)
            threshold=1e-3,         # paper's activation-ratio threshold
            cache_bytes=1 << 28,    # compressed edge cache (§II-D-2)
            cache_mode=3,           # zlib mode
        )

        for prog in (apps.pagerank(), apps.sssp(source=0), apps.wcc()):
            r = engine.run(prog, max_iters=100)
            skipped = sum(i.shards_skipped for i in r.iterations)
            print(
                f"{prog.name:9s} iters={r.num_iterations:3d} "
                f"converged={r.converged} "
                f"disk_read={r.total_bytes_read/1e6:7.1f}MB "
                f"shards_skipped={skipped:4d} "
                f"cache_hit_rate={engine.cache.stats.hit_rate:.2f}"
            )
            if prog.name == "pagerank":
                top = np.argsort(-r.values)[:5]
                print(f"          top-5 vertices by rank: {top.tolist()}")


if __name__ == "__main__":
    main()
