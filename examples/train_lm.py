"""End-to-end driver: train a ~100M-param LM for a few hundred steps.

Exercises the full production path at CPU scale: config -> model -> data
pipeline -> fused train step -> async sharded checkpointing -> restart
recovery.  Interrupt it (Ctrl-C -> SIGTERM path) and re-run: it resumes
from the latest checkpoint.

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--arch yi-6b]
"""

import argparse
import dataclasses
import os

from repro import configs
from repro.config import ModelConfig
from repro.data.tokens import DataConfig
from repro.distributed.fault_tolerance import PreemptionGuard
from repro.optim import adamw
from repro.train.loop import LoopConfig, train


def hundred_m_config(base: ModelConfig) -> ModelConfig:
    """Scale the chosen architecture family down to ~100M params."""
    return dataclasses.replace(
        base,
        name=base.name + "-100m",
        num_layers=max(base.group_period * 2, 4 * base.group_period),
        d_model=512,
        num_heads=8,
        num_kv_heads=min(base.num_kv_heads, 4),
        head_dim=64,
        d_ff=1536,
        dense_d_ff=1536 if base.dense_d_ff else 0,
        vocab_size=32_000,
        num_experts=min(base.num_experts, 8) if base.num_experts else 0,
        top_k=min(base.top_k, 2) if base.top_k else 0,
        ssm_state=32 if base.ssm_kind else base.ssm_state,
        ssm_head_dim=64 if base.ssm_kind else base.ssm_head_dim,
        ssm_chunk=64 if base.ssm_kind else base.ssm_chunk,
        num_encoder_layers=4 if base.encdec else 0,
        encoder_seq=128 if base.encdec else 0,
        prefix_len=16 if base.frontend == "vision_stub" else 0,
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b", choices=configs.list_archs())
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = hundred_m_config(configs.get_config(args.arch))
    print(f"arch={cfg.name} params~{cfg.param_count/1e6:.0f}M")

    data_cfg = DataConfig(
        seq_len=args.seq, global_batch=args.batch,
        vocab_size=cfg.vocab_size, motif_prob=0.8,
    )
    opt_cfg = adamw.AdamWConfig(
        lr=3e-4, warmup_steps=20, total_steps=args.steps,
    )
    os.makedirs(args.ckpt_dir, exist_ok=True)

    with PreemptionGuard() as guard:
        result = train(
            cfg, data_cfg,
            LoopConfig(total_steps=args.steps, checkpoint_every=50,
                       log_every=10),
            opt_cfg,
            checkpoint_dir=args.ckpt_dir,
            preemption=guard,
        )

    print(
        f"\ndone: step={result.final_step} "
        f"loss {result.losses[0]:.3f} -> {result.losses[-1]:.3f} "
        f"resumed_from={result.resumed_from} "
        f"stragglers={result.straggler_events} "
        f"preempted={result.preempted}"
    )


if __name__ == "__main__":
    main()
