"""Fault-tolerance runtime pieces: preemption handling, straggler
mitigation, elastic re-meshing.

On a real 1000-node job these hook into the cluster scheduler; here they
are fully implemented against process-local signals and timing so the
training loop's recovery paths are genuinely exercised by tests:

- :class:`PreemptionGuard` — converts SIGTERM/SIGINT into a "checkpoint now
  and exit cleanly" flag the train loop polls each step (the standard TPU
  preemption-notice pattern).
- :class:`StragglerMonitor` — tracks per-step wall times in a rolling
  window; steps slower than ``threshold`` x median are flagged.  At scale
  the same statistic, psum-shared, decides when to fire backup executions
  of the slow host's work (speculative re-execution); here it feeds
  metrics + a callback.
- :func:`elastic_reshard` — moves a (params, opt_state) pytree onto a NEW
  mesh using the logical-axis specs: the restore path when the job shrinks
  or grows.  Checkpoints store logical axes only, so this composes with
  :class:`repro.checkpoint.checkpointer.Checkpointer` for elastic restart.
"""

from __future__ import annotations

import collections
import dataclasses
import signal
import statistics
import threading
import time
from typing import Any, Callable, Deque, Dict, List, Optional

import jax

from .sharding import ShardingCtx


class PreemptionGuard:
    """SIGTERM/SIGINT -> graceful checkpoint-and-exit flag."""

    def __init__(self, signals=(signal.SIGTERM,)):
        self._flag = threading.Event()
        self._prev = {}
        self._signals = signals

    def __enter__(self):
        for s in self._signals:
            self._prev[s] = signal.signal(s, self._handler)
        return self

    def __exit__(self, *exc):
        for s, h in self._prev.items():
            signal.signal(s, h)
        return False

    def _handler(self, signum, frame):
        self._flag.set()

    @property
    def preempted(self) -> bool:
        return self._flag.is_set()

    def trigger(self) -> None:  # for tests
        self._flag.set()


@dataclasses.dataclass
class StragglerEvent:
    step: int
    step_time_s: float
    median_s: float
    ratio: float


class StragglerMonitor:
    """Rolling-window step-time statistics with outlier flagging."""

    def __init__(self, window: int = 50, threshold: float = 2.0,
                 on_straggler: Optional[Callable[[StragglerEvent], None]] = None):
        self.window: Deque[float] = collections.deque(maxlen=window)
        self.threshold = threshold
        self.on_straggler = on_straggler
        self.events: List[StragglerEvent] = []
        self._t0: Optional[float] = None

    def start_step(self) -> None:
        self._t0 = time.perf_counter()

    def end_step(self, step: int) -> float:
        dt = time.perf_counter() - self._t0
        if len(self.window) >= 5:
            med = statistics.median(self.window)
            if dt > self.threshold * med:
                ev = StragglerEvent(step, dt, med, dt / med)
                self.events.append(ev)
                if self.on_straggler:
                    self.on_straggler(ev)
        self.window.append(dt)
        return dt

    @property
    def median(self) -> float:
        return statistics.median(self.window) if self.window else 0.0


def elastic_reshard(tree, specs_tree, new_ctx: ShardingCtx):
    """Re-place a pytree onto a new mesh via logical-axis specs.

    Used on elastic restart: the checkpoint restores host-side, then this
    device_puts with the new mesh's NamedShardings.  Logical specs make the
    operation mesh-shape-agnostic.
    """
    shardings = new_ctx.param_sharding(specs_tree)
    return jax.device_put(tree, shardings)
