"""Logical-axis sharding: rules mapping model-space axes to mesh axes.

Models annotate parameters and activations with *logical* axis names
(common.py).  A :class:`ShardingRules` maps them onto mesh axes; the same
model code runs unsharded (rules=None, smoke tests), single-pod, or
multi-pod by swapping rules — the core mechanism behind elastic re-meshing
(a checkpoint stores logical axes, not mesh axes, so it can be restored
onto any mesh shape).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[str, Tuple[str, ...], None]

#: default rules for the production (pod, data, model) mesh
DEFAULT_RULES: Dict[str, MeshAxes] = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": ("pod", "data"),  # FSDP: shard params' d_model dim
    "qkv": "model",
    "mlp": "model",
    "vocab": "model",
    "expert": "model",
    "inner": "model",
    "layers": None,
    "kvseq": None,
    "heads": "model",  # per-head state/cache dims (SSM states, KV heads)
    "heads_kv": "model",
    "kvshard": None,  # attention scores' key dim (seq-parallel opt-in)
    "embed_expert": ("pod", "data"),  # expert weights' d_model dim (FSDP)
    "mlp_expert": None,  # expert weights' d_ff dim
}

#: single-pod rules (no "pod" axis in the mesh)
SINGLE_POD_RULES: Dict[str, MeshAxes] = {
    **DEFAULT_RULES,
    "batch": "data",
    "embed": "data",
    "embed_expert": "data",
}

#: sequence-sharded variant for long-context cells (activation seq dim over
#: the model axis; params as in the base rules)
def with_seq_sharding(rules: Dict[str, MeshAxes]) -> Dict[str, MeshAxes]:
    return {**rules, "kvseq": "model"}


@dataclasses.dataclass
class ShardingCtx:
    """Runtime sharding context threaded through model code."""

    mesh: Optional[Mesh] = None
    rules: Optional[Dict[str, MeshAxes]] = None
    attn_impl: str = "xla"  # "xla" (dry-run/CPU) | "pallas" (TPU)
    #: kv-block size for the memory-bounded blocked attention path
    #: (0 = full materialization).  Long-sequence prefill cells set this;
    #: the roofline pipeline adds the analytic correction for FLOPs hidden
    #: inside the kv loop (EXPERIMENTS.md §Roofline methodology).
    attn_block_k: int = 0
    #: Megatron-style sequence parallelism for attention intermediates:
    #: constrain the score/prob tensors' KEY dim onto the TP axis — always
    #: divisible, rescues archs whose head count doesn't divide it
    #: (EXPERIMENTS.md §Perf, whisper iteration 1).
    attn_seq_shard: bool = False
    #: store attention probabilities in bf16 (f32 softmax stats kept)
    attn_bf16_probs: bool = False

    def spec(self, *logical: Optional[str]) -> P:
        if self.rules is None:
            return P()
        return P(*(self.rules.get(ax) if ax else None for ax in logical))

    def ac(self, x: jax.Array, *logical: Optional[str]) -> jax.Array:
        """Activation sharding constraint (no-op without a mesh)."""
        if self.mesh is None or self.rules is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self.spec(*logical))
        )

    def param_sharding(self, specs_tree):
        """Map a logical-spec tree to NamedShardings (for in_shardings)."""
        assert self.mesh is not None and self.rules is not None

        def one(spec):
            return NamedSharding(
                self.mesh,
                P(*(self.rules.get(ax) if ax else None for ax in spec)),
            )

        return jax.tree_util.tree_map(
            one, specs_tree, is_leaf=lambda x: isinstance(x, tuple)
        )


LOCAL_CTX = ShardingCtx()  # unsharded (smoke tests, single CPU)


#: logical axes of the GRAPH workload (mesh sweeps, DESIGN.md §10):
#: - "vertex": destination-vertex dim — sharded over every mesh axis (the
#:   per-device resident slice of the vertex / lane matrices),
#: - "device": the stacked per-device ELL block dim — sharded the same way
#:   (device d's block lands on device d),
#: - "lane":  the serving lane (concurrent-query) dim — replicated; lanes
#:   are vmapped, the vertex axis underneath them is what's sharded.
GRAPH_RULES: Dict[str, MeshAxes] = {
    "vertex": (),  # filled per-mesh by graph_ctx (all axes of that mesh)
    "device": (),
    "lane": None,
}


def graph_ctx(mesh: Mesh) -> ShardingCtx:
    """A :class:`ShardingCtx` for graph mesh sweeps: every mesh axis shards
    the vertex/device dims, lanes replicate.  The mesh kernel builds its
    ``shard_map`` specs through :meth:`ShardingCtx.spec`, so the graph path
    shares the model stack's logical-axis mechanism instead of hand-rolled
    PartitionSpecs."""
    axes = tuple(mesh.axis_names)
    rules = {**GRAPH_RULES, "vertex": axes, "device": axes}
    return ShardingCtx(mesh=mesh, rules=rules)
