"""Synthetic token data pipeline: sharded, deterministic, prefetched.

A production loader is storage-bound; this one is a drop-in stand-in with
the same contract: per-host deterministic sharding (host h sees disjoint
data), stateless resume from a step counter (fault tolerance: restart at
step k regenerates exactly the batches k, k+1, ... with no data loss or
duplication), and background prefetch of the next batch.

The token stream is a mixture of Zipf-distributed unigrams and repeated
n-gram motifs so the LM loss actually decreases during the example runs
(pure-uniform tokens would pin loss at log V).
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np

from repro.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    vocab_size: int
    seed: int = 0
    num_hosts: int = 1
    host_id: int = 0
    zipf_a: float = 1.3
    motif_len: int = 16
    motif_prob: float = 0.5


def _batch_rng(cfg: DataConfig, step: int) -> np.random.Generator:
    # independent stream per (seed, host, step) -> stateless resume
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, cfg.host_id, step])
    )


def make_batch(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    """Deterministic batch for ``step`` (host-sharded slice of the global)."""
    rng = _batch_rng(cfg, step)
    per_host = cfg.global_batch // cfg.num_hosts
    S = cfg.seq_len
    # Zipf unigrams, clipped to vocab
    toks = rng.zipf(cfg.zipf_a, size=(per_host, S + 1)) % cfg.vocab_size
    # overlay repeated motifs (predictable structure)
    n_motifs = max(1, S // (4 * cfg.motif_len))
    for b in range(per_host):
        if rng.random() < cfg.motif_prob:
            motif = rng.integers(0, cfg.vocab_size, cfg.motif_len)
            for _ in range(n_motifs):
                at = rng.integers(0, S + 1 - cfg.motif_len)
                toks[b, at : at + cfg.motif_len] = motif
    tokens = toks[:, :-1].astype(np.int32)
    labels = toks[:, 1:].astype(np.int32)
    return {"tokens": tokens, "labels": labels}


def add_frontend_stub(batch: Dict, model_cfg: ModelConfig, step: int) -> Dict:
    """Attach precomputed frame/patch embeddings for [audio]/[vlm] archs."""
    rng = np.random.default_rng(step + 7)
    B = batch["tokens"].shape[0]
    if model_cfg.frontend == "vision_stub":
        batch["patch_embeds"] = rng.standard_normal(
            (B, model_cfg.prefix_len, model_cfg.d_model)
        ).astype(np.float32)
    elif model_cfg.frontend == "audio_stub":
        batch["frames"] = rng.standard_normal(
            (B, model_cfg.encoder_seq, model_cfg.d_model)
        ).astype(np.float32)
    return batch


class PrefetchingLoader:
    """Background-thread prefetch of the next ``depth`` batches."""

    def __init__(self, cfg: DataConfig, model_cfg: Optional[ModelConfig] = None,
                 start_step: int = 0, depth: int = 2):
        self.cfg = cfg
        self.model_cfg = model_cfg
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        step = self._step
        while not self._stop.is_set():
            b = make_batch(self.cfg, step)
            if self.model_cfg is not None and self.model_cfg.frontend != "none":
                b = add_frontend_stub(b, self.model_cfg, step)
            try:
                self._q.put((step, b), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2)
