"""AdamW + schedules + global-norm clipping, pure-pytree (no optax dep).

State layout mirrors params (m, v trees) so the checkpointing and sharding
machinery treats optimizer state exactly like parameters (same logical
axes — optimizer state shards with its parameter).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array  # int32 scalar
    m: Any  # pytree like params
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    schedule: str = "cosine"  # cosine | linear | constant


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(s / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        decay = 1.0
    else:
        frac = jnp.clip(
            (s - cfg.warmup_steps)
            / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
            0.0, 1.0,
        )
        if cfg.schedule == "cosine":
            decay = 0.5 * (1 + jnp.cos(jnp.pi * frac))
        else:
            decay = 1.0 - frac
        decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * decay
    return cfg.lr * warm * decay


def init(params, dtype=jnp.float32) -> AdamWState:
    """dtype: moment dtype — bf16 halves optimizer HBM at >100B scale
    (production trick; update math still runs in f32)."""
    zeros = lambda t: jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, dtype), t
    )
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros(params), v=zeros(params))


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(sum(leaves))


def apply_updates(
    params, grads, state: AdamWState, cfg: AdamWConfig,
) -> Tuple[Any, AdamWState, Dict[str, jax.Array]]:
    """One AdamW step; returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = lr_at(cfg, step)
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32) * scale
        m2 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * gf
        v2 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * gf * gf
        mh = m2 / bc1
        vh = v2 / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, m2.astype(m.dtype), v2.astype(v.dtype)

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v), {
        "grad_norm": gnorm, "lr": lr,
    }
