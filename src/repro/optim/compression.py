"""Gradient compression for the cross-pod (DCN) reduction.

At multi-pod scale the inter-pod links are ~10x slower than in-pod ICI, so
the pod-axis gradient all-reduce is the collective bottleneck (see
EXPERIMENTS.md §Roofline, jamba train cells).  Two standard compressors,
both with error feedback so compression noise accumulates into the next
step instead of biasing the gradient:

- ``topk``: keep the k largest-magnitude entries per tensor (sparsify
  before the pod all-reduce; the in-pod reduction stays dense/exact).
- ``int8``: per-tensor symmetric quantisation (4x fewer bytes on the wire
  at bf16 baseline -> 2x; vs f32 -> 4x).

These run INSIDE the compiled step: compress -> psum over 'pod' ->
decompress, so the dry-run's collective parser sees the reduced wire bytes.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    kind: str = "none"  # none | topk | int8
    topk_ratio: float = 0.01  # fraction of entries kept
    error_feedback: bool = True


def init_error_state(params) -> Any:
    return jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, jnp.float32), params
    )


def _topk_mask(x: jax.Array, ratio: float) -> jax.Array:
    flat = jnp.abs(x.reshape(-1))
    k = max(1, int(flat.shape[0] * ratio))
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return (jnp.abs(x) >= thresh).astype(x.dtype)


def compress_tree(grads, err, cfg: CompressionConfig):
    """Returns (compressed_grads, new_error) — both pytrees like grads.

    The compressed gradients are what crosses the pod axis; `new_error`
    is the residual kept locally for the next step (error feedback).
    """
    if cfg.kind == "none":
        return grads, err

    def one(g, e):
        gf = g.astype(jnp.float32) + (e if cfg.error_feedback else 0.0)
        if cfg.kind == "topk":
            mask = _topk_mask(gf, cfg.topk_ratio)
            sent = gf * mask
            resid = gf - sent
            return sent.astype(g.dtype), resid
        if cfg.kind == "int8":
            scale = jnp.maximum(jnp.abs(gf).max(), 1e-12) / 127.0
            q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
            sent = q.astype(jnp.float32) * scale
            resid = gf - sent
            return sent.astype(g.dtype), resid
        raise ValueError(cfg.kind)

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(err)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (
        treedef.unflatten([o[0] for o in outs]),
        treedef.unflatten([o[1] for o in outs]),
    )


def wire_bytes_ratio(cfg: CompressionConfig, dtype_bytes: int = 2) -> float:
    """Analytic wire-volume multiplier for the roofline collective term."""
    if cfg.kind == "none":
        return 1.0
    if cfg.kind == "int8":
        return 1.0 / dtype_bytes
    if cfg.kind == "topk":
        # index (4B) + value (dtype) per kept entry
        return cfg.topk_ratio * (4 + dtype_bytes) / dtype_bytes
    raise ValueError(cfg.kind)
