"""The Vertex-centric Sliding Window engine (paper Algorithm 1).

Semantics reproduced exactly:

- two resident vertex arrays, ``SrcVertexArray`` (iteration input) and
  ``DstVertexArray`` (iteration output); vertices NEVER touch disk,
- the window slides over destination-interval shards; each shard is loaded
  (cache -> disk), processed by exactly one worker, and its interval of
  ``DstVertexArray`` written by that worker alone (lock-free),
- selective scheduling: when the active ratio drops below the threshold
  (paper: 0.001), shards whose Bloom filter matches no active vertex are
  skipped — no disk read, no compute (§II-D-1),
- compressed edge cache consulted before every disk read (§II-D-2),
- termination when an iteration produces zero active vertices.

Three interchangeable shard-update backends (all must agree; tests enforce):

=========  ==================================================================
numpy      ``np.add.at`` / ``np.minimum.at`` scatter-reduce over CSR — the
           bitwise oracle.
jnp        windowed ELL gather + masked reduce + segment combine under
           ``jax.jit`` (shape-bucketed to bound recompiles) — what XLA
           would run.
pallas     the ``repro.kernels.spmv_ell`` TPU kernel (interpret mode on
           CPU) — the production hot loop.
=========  ==================================================================
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .apps import COMBINE_IDENTITY, VertexProgram
from .bloom import BloomFilter, build_shard_filters
from .cache import ShardCache
from .csr import EllShard
from .graph import Graph
from .sharding import GraphMeta, ShardCSR, preprocess
from .storage import ShardStore

__all__ = [
    "IterStats",
    "RunResult",
    "VSWEngine",
    "update_shard_numpy",
    "update_shard_jnp",
    "BACKENDS",
]

# --------------------------------------------------------------------------
# Shard-update backends: (csr, ell, msgs, combine) -> acc [rows] float32
# --------------------------------------------------------------------------


def update_shard_numpy(
    csr: ShardCSR, ell: Optional[EllShard], msgs: np.ndarray, combine: str
) -> np.ndarray:
    """Scatter-reduce oracle over the CSR shard."""
    rows = csr.rows
    acc = np.full(rows, COMBINE_IDENTITY[combine], dtype=msgs.dtype)
    if csr.nnz == 0:
        return acc
    local_dst = np.repeat(np.arange(rows, dtype=np.int64), np.diff(csr.row))
    vals = msgs[csr.col]
    if combine == "sum":
        np.add.at(acc, local_dst, vals)
    elif combine == "min":
        np.minimum.at(acc, local_dst, vals)
    elif combine == "max":
        np.maximum.at(acc, local_dst, vals)
    else:  # pragma: no cover
        raise ValueError(combine)
    return acc


def _next_pow2(n: int) -> int:
    return 1 << max(int(np.ceil(np.log2(max(n, 1)))), 0)


@functools.lru_cache(maxsize=64)
def _jnp_ell_fn(n_ell: int, k: int, tr: int, rows: int, window: int, combine: str):
    """Build a jit'd ELL update for one padded shape bucket."""
    import jax
    import jax.numpy as jnp

    ident = COMBINE_IDENTITY[combine]

    def fn(ell_idx, ell_mask, seg, tile_window, msgs):
        win = jnp.repeat(tile_window, tr)  # [n_ell]
        gidx = ell_idx.astype(jnp.int32) + win[:, None] * window
        g = jnp.take(msgs, gidx, axis=0, mode="clip")
        g = jnp.where(ell_mask, g, jnp.asarray(ident, g.dtype))
        if combine == "sum":
            part = g.sum(axis=1)
            acc = jax.ops.segment_sum(part, seg, num_segments=rows)
        elif combine == "min":
            part = g.min(axis=1)
            acc = jax.ops.segment_min(part, seg, num_segments=rows)
            acc = jnp.where(jnp.isfinite(acc), acc, jnp.asarray(ident, g.dtype))
        else:
            part = g.max(axis=1)
            acc = jax.ops.segment_max(part, seg, num_segments=rows)
            acc = jnp.where(jnp.isfinite(acc), acc, jnp.asarray(ident, g.dtype))
        return acc

    return jax.jit(fn)


def _pad_ell(ell: EllShard, n_ell_pad: int):
    pad = n_ell_pad - ell.n_ell
    if pad == 0:
        return ell.ell_idx, ell.ell_mask, ell.seg, ell.tile_window
    idx = np.concatenate([ell.ell_idx, np.zeros((pad, ell.k), ell.ell_idx.dtype)])
    mask = np.concatenate([ell.ell_mask, np.zeros((pad, ell.k), bool)])
    seg = np.concatenate([ell.seg, np.zeros(pad, np.int32)])
    tw = np.concatenate(
        [ell.tile_window, np.zeros(pad // ell.tr, np.int32)]
    )
    return idx, mask, seg, tw


def update_shard_jnp(
    csr: ShardCSR, ell: EllShard, msgs: np.ndarray, combine: str
) -> np.ndarray:
    """Windowed-ELL gather/combine under jit (shape-bucketed)."""
    import jax.numpy as jnp

    n_ell_pad = max(_next_pow2(ell.n_ell), ell.tr)
    rows = ell.rows
    idx, mask, seg, tw = _pad_ell(ell, n_ell_pad)
    # Pad msgs to full windows so gather never reads OOB.
    n_pad_v = ell.num_windows * ell.window
    msgs_p = np.pad(msgs, (0, n_pad_v - msgs.shape[0]))
    fn = _jnp_ell_fn(n_ell_pad, ell.k, ell.tr, rows, ell.window, combine)
    acc = fn(jnp.asarray(idx), jnp.asarray(mask), jnp.asarray(seg),
             jnp.asarray(tw), jnp.asarray(msgs_p))
    return np.asarray(acc)


def _update_shard_pallas(
    csr: ShardCSR, ell: EllShard, msgs: np.ndarray, combine: str
) -> np.ndarray:
    from repro.kernels.spmv_ell import ops as spmv_ops

    return np.asarray(spmv_ops.ell_update(ell, msgs, combine))


BACKENDS: Dict[str, Callable] = {
    "numpy": update_shard_numpy,
    "jnp": update_shard_jnp,
    "pallas": _update_shard_pallas,
}


# --------------------------------------------------------------------------
# Engine
# --------------------------------------------------------------------------


@dataclasses.dataclass
class IterStats:
    iteration: int
    time_s: float
    shards_processed: int
    shards_skipped: int
    bytes_read: int
    cache_hits: int
    cache_misses: int
    active_count: int
    active_ratio: float
    selective_on: bool


@dataclasses.dataclass
class RunResult:
    values: np.ndarray
    iterations: List[IterStats]
    converged: bool

    @property
    def num_iterations(self) -> int:
        return len(self.iterations)

    @property
    def total_bytes_read(self) -> int:
        return sum(i.bytes_read for i in self.iterations)

    @property
    def total_time_s(self) -> float:
        return sum(i.time_s for i in self.iterations)


class VSWEngine:
    """GraphMP: semi-external-memory vertex-centric engine."""

    def __init__(
        self,
        store: ShardStore,
        *,
        backend: str = "numpy",
        selective: bool = True,
        threshold: float = 1e-3,
        cache_bytes: int = 0,
        cache_mode: int = 1,  # 1-4, or 0 = GraphH-style auto-select
        bloom_fp: float = 0.01,
        exact_selective: bool = False,
        device_resident: bool = False,
    ):
        if backend not in BACKENDS:
            raise ValueError(f"unknown backend {backend}; have {sorted(BACKENDS)}")
        self.store = store
        self.meta = store.read_meta()
        self.backend_name = backend
        self.backend = BACKENDS[backend]
        self.selective = selective
        self.threshold = threshold
        self.exact_selective = exact_selective
        if cache_bytes > 0 and cache_mode == 0:
            # GraphH-style auto mode selection on a sample shard (§II-D-2)
            from .cache import select_cache_mode

            sample = store.shard_bytes(0, "csr" if backend == "numpy" else "ell")
            total = sum(
                store.file_size(store.shard_name(p, "csr" if backend == "numpy" else "ell"))
                for p in range(self.meta.num_shards)
            )
            cache_mode = select_cache_mode(sample, cache_bytes, total)
        self.cache = ShardCache(cache_bytes, cache_mode) if cache_bytes > 0 else None
        self.bloom_fp = bloom_fp
        # Beyond-paper: keep decoded device-format shards resident (the
        # TPU analogue of "leave it in the cache" — skips host decode AND
        # host->device transfer on every revisit).
        self.device_resident = device_resident and backend in ("jnp", "pallas")
        self._device_shards = {}
        self.filters: Optional[List[BloomFilter]] = None
        self.exact_sources: Optional[List[np.ndarray]] = None
        self._build_filters()

    # ------------------------------------------------------------- factory
    @classmethod
    def from_graph(
        cls,
        graph: Graph,
        root: str,
        *,
        num_shards: Optional[int] = None,
        edges_per_shard: Optional[int] = None,
        window: int = 1 << 14,
        k: int = 128,
        tr: int = 8,
        emulate_bw: Optional[float] = None,
        **engine_kwargs,
    ) -> "VSWEngine":
        """Preprocess ``graph`` into ``root`` then open an engine on it."""
        meta, shards = preprocess(
            graph, num_shards=num_shards, edges_per_shard=edges_per_shard
        )
        store = ShardStore(root, emulate_bw=emulate_bw)
        store.write_meta(meta)
        for s in shards:
            store.write_shard(
                s, num_vertices=meta.num_vertices, window=window, k=k, tr=tr
            )
        return cls(store, **engine_kwargs)

    @property
    def _fmt(self) -> str:
        """Which on-disk representation this backend consumes."""
        return "csr" if self.backend_name == "numpy" else "ell"

    # ------------------------------------------------------------- filters
    def _build_filters(self) -> None:
        """Data-loading phase: scan shards once to build Bloom filters and
        optionally warm the cache (paper §IV-B: 'during the data loading
        phase, GraphMP scans all edges to construct Bloom filters, and
        places processed shards in the cache if possible')."""
        filters: List[BloomFilter] = []
        exact: List[np.ndarray] = []
        io0 = self.store.io.snapshot()  # loading-phase I/O isn't per-iteration
        for p in range(self.meta.num_shards):
            csr = self.store.decode_csr(p, self.store.shard_bytes(p, "csr"))
            srcs = csr.unique_sources()
            filters.append(BloomFilter.build(srcs, fp_rate=self.bloom_fp))
            exact.append(srcs)
            if self.cache is not None:
                raw = self.store.shard_bytes(p, self._fmt) if self._fmt != "csr" \
                    else self.store.shard_bytes(p, "csr")
                self.cache.put(p, raw)
        self.filters = filters
        self.exact_sources = exact
        self.loading_io = self.store.io - io0

    # ---------------------------------------------------------------- load
    def _load_shard(self, p: int):
        """Returns (csr_or_None, ell_or_None) for the backend's format."""
        if self.device_resident and p in self._device_shards:
            return self._device_shards[p]
        raw = self.cache.get(p) if self.cache is not None else None
        if raw is None:
            raw = self.store.shard_bytes(p, self._fmt)
            if self.cache is not None:
                self.cache.put(p, raw)
        if self._fmt == "csr":
            out = (self.store.decode_csr(p, raw), None)
        else:
            out = (None, self.store.decode_ell(p, raw))
        if self.device_resident:
            self._device_shards[p] = out
        return out

    # ----------------------------------------------------------- scheduling
    def _shard_is_active(self, p: int, active_ids: np.ndarray) -> bool:
        if self.exact_selective:
            srcs = self.exact_sources[p]
            return bool(np.isin(active_ids, srcs, assume_unique=False).any())
        return self.filters[p].any_member(active_ids)

    # ------------------------------------------------------------------ run
    def run(
        self,
        program: VertexProgram,
        *,
        max_iters: int = 100,
        record_values_history: bool = False,
    ) -> RunResult:
        meta = self.meta
        src_vals, active_mask = program.init(meta)
        src_vals = src_vals.astype(np.float32)
        active_ids = np.flatnonzero(active_mask).astype(np.int64)
        stats: List[IterStats] = []
        history = []
        converged = False

        for it in range(max_iters):
            t0 = time.perf_counter()
            io0 = self.store.io.snapshot()
            cache_h0 = self.cache.stats.hits if self.cache else 0
            cache_m0 = self.cache.stats.misses if self.cache else 0

            active_ratio = len(active_ids) / max(meta.num_vertices, 1)
            use_selective = self.selective and active_ratio < self.threshold

            msgs = program.pre(src_vals, meta.out_deg).astype(np.float32)
            dst_vals = src_vals.copy()  # carried over for skipped shards
            processed = skipped = 0

            for p in range(meta.num_shards):
                if use_selective and not self._shard_is_active(p, active_ids):
                    skipped += 1
                    continue
                csr, ell = self._load_shard(p)
                ref = csr if csr is not None else ell
                acc = self.backend(csr, ell, msgs, program.combine)
                new = program.apply(
                    np.asarray(acc, dtype=src_vals.dtype),
                    src_vals[ref.v0 : ref.v1],
                    meta,
                    ref.v0,
                )
                dst_vals[ref.v0 : ref.v1] = new
                processed += 1

            new_active = program.is_active(dst_vals, src_vals)
            active_ids = np.flatnonzero(new_active).astype(np.int64)
            src_vals = dst_vals
            dio = self.store.io - io0

            stats.append(
                IterStats(
                    iteration=it,
                    time_s=time.perf_counter() - t0,
                    shards_processed=processed,
                    shards_skipped=skipped,
                    bytes_read=dio.bytes_read,
                    cache_hits=(self.cache.stats.hits - cache_h0) if self.cache else 0,
                    cache_misses=(self.cache.stats.misses - cache_m0)
                    if self.cache
                    else 0,
                    active_count=len(active_ids),
                    active_ratio=len(active_ids) / max(meta.num_vertices, 1),
                    selective_on=use_selective,
                )
            )
            if record_values_history:
                history.append(src_vals.copy())
            if len(active_ids) == 0:
                converged = True
                break

        result = RunResult(values=src_vals, iterations=stats, converged=converged)
        if record_values_history:
            result.history = history  # type: ignore[attr-defined]
        return result
