"""The Vertex-centric Sliding Window engine (paper Algorithm 1).

Semantics reproduced exactly:

- two resident vertex arrays, ``SrcVertexArray`` (iteration input) and
  ``DstVertexArray`` (iteration output); vertices NEVER touch disk,
- the window slides over destination-interval shards; each shard is loaded
  (cache -> disk), processed by exactly one worker, and its interval of
  ``DstVertexArray`` written by that worker alone (lock-free),
- selective scheduling: when the active ratio drops below the threshold
  (paper: 0.001), shards whose Bloom filter matches no active vertex are
  skipped — no disk read, no compute (§II-D-1),
- compressed edge cache consulted before every disk read (§II-D-2),
- termination when an iteration produces zero active vertices.

The engine is a thin orchestrator over three explicit layers (DESIGN.md §3):

==========  ===============================================================
scheduler   :class:`~repro.core.scheduler.ShardScheduler` — owns the Bloom/
            exact filters and emits the per-iteration ordered shard plan.
pipeline    :class:`~repro.core.pipeline.ShardPipeline` — walks the plan
            with ``prefetch_depth`` background loader threads so disk read
            + cache lookup + decode overlap compute (paper §II-C, Fig. 3).
executor    :mod:`repro.core.executor` — backend dispatch; with
            ``batch_shards > 1`` the jnp/pallas backends fuse consecutive
            planned shards into one kernel dispatch.
==========  ===============================================================

All layer combinations produce bit-identical values: the plan fixes the
processing order, only the consumer thread touches the vertex arrays, and
batched dispatch is a pure concatenation (DESIGN.md §5).

The shard-update backends (``update_shard_numpy`` / ``update_shard_jnp`` /
``BACKENDS``) live in :mod:`repro.core.executor` and are re-exported here
for compatibility.
"""

from __future__ import annotations

import contextlib
import dataclasses
import time
import weakref
from typing import List, Optional

import numpy as np

from ..obs import trace
from .apps import VertexProgram
from .cache import ShardCache
from .executor import (
    BACKENDS,
    ExecStats,
    MeshLaneExecutor,
    make_executor,
    update_shard_jnp,
    update_shard_numpy,
)
from .graph import Graph
from .pipeline import PipelineStats, ShardPipeline
from .scheduler import ShardScheduler
from .sharding import preprocess
from .storage import ShardStore

__all__ = [
    "IterStats",
    "RunResult",
    "VSWEngine",
    "update_shard_numpy",
    "update_shard_jnp",
    "BACKENDS",
]


@dataclasses.dataclass
class IterStats:
    iteration: int
    time_s: float
    shards_processed: int
    shards_skipped: int
    bytes_read: int
    cache_hits: int
    cache_misses: int
    active_count: int
    active_ratio: float
    selective_on: bool
    # ---- pipeline/executor decomposition (added with the layered engine;
    # defaults keep older constructors — baselines — source-compatible).
    load_total_s: float = 0.0  # sum of in-thread load+decode durations
    load_wait_s: float = 0.0  # critical-path stall waiting on loads
    load_overlap_s: float = 0.0  # load work hidden behind compute
    exec_s: float = 0.0  # backend dispatch time
    dispatches: int = 0  # kernel dispatches (< processed when batching)
    prefetch_depth: int = 0
    # ---- mesh sweeps (DESIGN.md §10); empty tuples on single-device runs.
    # Conservation: sum(device_shards) == shards_processed and
    # sum(device_bytes) == bytes_read — the host read each shard ONCE and
    # attribution splits it by destination-device ownership, never
    # multiplies it by D.
    device_shards: tuple = ()  # planned shards owned per device
    device_dispatches: tuple = ()  # SPMD launches that carried work per device
    device_bytes: tuple = ()  # bytes_read attributed per device


@dataclasses.dataclass
class RunResult:
    values: np.ndarray
    iterations: List[IterStats]
    converged: bool

    @property
    def num_iterations(self) -> int:
        return len(self.iterations)

    @property
    def total_bytes_read(self) -> int:
        return sum(i.bytes_read for i in self.iterations)

    @property
    def total_time_s(self) -> float:
        return sum(i.time_s for i in self.iterations)

    @property
    def total_load_overlap_s(self) -> float:
        return sum(i.load_overlap_s for i in self.iterations)


class VSWEngine:
    """GraphMP: semi-external-memory vertex-centric engine."""

    def __init__(
        self,
        store: ShardStore,
        *,
        backend: str = "numpy",
        selective: bool = True,
        threshold: float = 1e-3,
        cache_bytes: int = 0,
        cache_mode: int = 1,  # 1-4, or 0 = GraphH-style auto-select
        bloom_fp: float = 0.01,
        exact_selective: bool = False,
        device_resident: bool = False,
        prefetch_depth: int = 2,
        batch_shards: int = 1,
        mesh=None,
    ):
        if backend not in BACKENDS:
            raise ValueError(f"unknown backend {backend}; have {sorted(BACKENDS)}")
        self.store = store
        self.meta = store.read_meta()
        self.backend_name = backend
        # ---- mesh boot path (DESIGN.md §10).  ``mesh`` is an int device
        # count or a ready jax Mesh.  numpy + int is the jax-free mesh
        # EMULATION (same partition/plan/accounting, oracle compute); the
        # ELL backends build a host mesh from the count — raising
        # launch.mesh's uniform error when the process has too few devices.
        self.partition = None
        self.mesh = None
        if mesh is not None:
            from .distributed import MeshPartition

            if isinstance(mesh, (int, np.integer)):
                n_dev = int(mesh)
                if backend != "numpy":
                    from repro.launch.mesh import make_host_mesh

                    self.mesh = make_host_mesh((n_dev,), ("dev",))
            else:
                self.mesh = mesh
                n_dev = int(np.prod(mesh.devices.shape))
            self.partition = MeshPartition.from_meta(self.meta, n_dev)
        if cache_bytes > 0 and cache_mode == 0:
            # GraphH-style auto mode selection on a sample shard (§II-D-2)
            from .cache import select_cache_mode

            sample = store.shard_bytes(0, self._fmt)
            total = sum(
                store.file_size(store.shard_name(p, self._fmt))
                for p in range(self.meta.num_shards)
            )
            cache_mode = select_cache_mode(sample, cache_bytes, total)
        self.cache = ShardCache(cache_bytes, cache_mode) if cache_bytes > 0 else None
        # Beyond-paper: keep decoded device-format shards resident (the
        # TPU analogue of "leave it in the cache" — skips host decode AND
        # host->device transfer on every revisit).
        self.device_resident = device_resident and backend in ("jnp", "pallas")
        self._device_shards = {}
        # Re-ingest / shard overwrite on the live store must not leave
        # stale decodes behind in this engine's byte cache or resident map.
        # The hook holds only a weakref: a long-lived store handed from
        # engine to engine (the re-ingest workflow) must not pin dead
        # engines — and their caches — alive.
        self_ref = weakref.ref(self)

        def _hook(p: int, _ref=self_ref) -> None:
            eng = _ref()
            if eng is not None:
                eng._on_shard_invalidated(p)

        self._invalidation_hook = _hook
        # unregister when the engine is GC'd without close(), so the
        # store's hook list cannot grow without bound either
        self._hook_finalizer = weakref.finalize(
            self, store.unregister_invalidation, _hook
        )
        store.register_invalidation(_hook)

        # ---- the three layers ------------------------------------------
        self.scheduler = ShardScheduler(
            self.meta,
            selective=selective,
            threshold=threshold,
            bloom_fp=bloom_fp,
            exact_selective=exact_selective,
        )
        self.scheduler.partition = self.partition
        self.scheduler.build_filters(
            store, warm_cache=self.cache, cache_fmt=self._fmt
        )
        self.pipeline = ShardPipeline(
            store,
            self._fmt,
            cache=self.cache,
            depth=prefetch_depth,
            resident=self._device_shards if self.device_resident else None,
        )
        if self.partition is not None:
            self.executor = MeshLaneExecutor(
                backend, self.partition, self.mesh,
                batch_shards=batch_shards, lanes=False,
            )
        else:
            self.executor = make_executor(backend, batch_shards=batch_shards)
        # Live-mutation state (repro.delta): last overlay version whose
        # metadata/filter changes this engine has absorbed.  Refreshing at
        # sweep start (never mid-sweep) is what keeps a sweep's degrees,
        # filters and shard decodes on ONE graph version.
        self._delta_seen = -1
        self._refresh_delta_state()

    def _on_shard_invalidated(self, p: int) -> None:
        """Store callback: shard ``p`` was overwritten/removed on disk."""
        if self.cache is not None:
            self.cache.invalidate(p)
        self._device_shards.pop(p, None)

    # ------------------------------------------------------- live mutations
    def _refresh_delta_state(self) -> None:
        """Absorb graph mutations published since this engine's last sweep:
        refresh the resident degree arrays / edge count (``pre`` divides by
        out-degree!) and rebuild the Bloom/exact filters of every shard a
        publish touched — base sources (warm, or one read) plus pending
        insert sources.  Deleted sources are NOT removed until the shard
        recompacts: a superset filter costs a wasted load, never
        correctness.  Called only between sweeps."""
        delta = self.store.delta
        if delta is None:
            return
        v = delta.version
        if v == self._delta_seen:
            return
        m = self.store.read_meta()
        # in-place: the scheduler and any live LaneSweep share this object
        self.meta.in_deg[:] = m.in_deg
        self.meta.out_deg[:] = m.out_deg
        self.meta.num_edges = m.num_edges
        for p in delta.publishes_since(self._delta_seen):
            srcs = self.store.warm_sources(p)
            if srcs is None:
                srcs = self.store.decode_csr(
                    p, self.store.shard_bytes(p, "csr")
                ).unique_sources()
                self.store.set_warm_sources(p, srcs)
            pend = delta.pending_insert_sources(p, v)
            if len(pend):
                srcs = np.union1d(srcs, pend)
            self.scheduler.refresh_shard_sources(p, srcs)
        self._delta_seen = v

    @contextlib.contextmanager
    def _sweep_session(self):
        """One sweep's delta scope: absorb published mutations, then pin the
        overlay version so every shard decode in the sweep — including
        prefetch threads — sees the same snapshot, and background
        recompaction cannot absorb runs this sweep still needs."""
        self._refresh_delta_state()
        delta = self.store.delta
        if delta is None:
            yield None
            return
        pin = delta.acquire_pin()
        self.pipeline.pin = pin
        try:
            yield pin
        finally:
            self.pipeline.pin = None
            delta.release_pin(pin)

    # ------------------------------------------------------------- factory
    @classmethod
    def from_graph(
        cls,
        graph: Graph,
        root: str,
        *,
        num_shards: Optional[int] = None,
        edges_per_shard: Optional[int] = None,
        window: int = 1 << 14,
        k: int = 128,
        tr: int = 8,
        emulate_bw: Optional[float] = None,
        **engine_kwargs,
    ) -> "VSWEngine":
        """Preprocess ``graph`` into ``root`` then open an engine on it."""
        meta, shards = preprocess(
            graph, num_shards=num_shards, edges_per_shard=edges_per_shard
        )
        store = ShardStore(root, emulate_bw=emulate_bw)
        store.write_meta(meta)
        for s in shards:
            store.write_shard(
                s, num_vertices=meta.num_vertices, window=window, k=k, tr=tr
            )
        return cls(store, **engine_kwargs)

    @classmethod
    def from_store(
        cls,
        root: str,
        *,
        emulate_bw: Optional[float] = None,
        **engine_kwargs,
    ) -> "VSWEngine":
        """Open an engine on an already-populated store directory (e.g. one
        built by :meth:`ShardStore.ingest`) — no ``Graph`` object, no edge
        list in memory, ever."""
        return cls(ShardStore(root, emulate_bw=emulate_bw), **engine_kwargs)

    @classmethod
    def from_edge_file(
        cls,
        path: str,
        root: str,
        *,
        edges_per_shard: Optional[int] = None,
        num_shards: Optional[int] = None,
        num_vertices: Optional[int] = None,
        chunk_edges: int = 1 << 20,
        mem_budget_bytes: int = 64 << 20,
        window: int = 1 << 14,
        k: int = 128,
        tr: int = 8,
        fmt: Optional[str] = None,
        emulate_bw: Optional[float] = None,
        **engine_kwargs,
    ) -> "VSWEngine":
        """Stream-ingest an on-disk edge file into ``root`` (bounded-memory
        external build, ``repro.core.ingest``) and open an engine on it.
        The full edge list is never resident."""
        store = ShardStore(root, emulate_bw=emulate_bw)
        store.ingest(
            path,
            edges_per_shard=edges_per_shard,
            num_shards=num_shards,
            num_vertices=num_vertices,
            chunk_edges=chunk_edges,
            mem_budget_bytes=mem_budget_bytes,
            window=window,
            k=k,
            tr=tr,
            fmt=fmt,
        )
        return cls(store, **engine_kwargs)

    @property
    def _fmt(self) -> str:
        """Which on-disk representation this backend consumes."""
        return "csr" if self.backend_name == "numpy" else "ell"

    # ----------------------------------------- compatibility accessors
    @property
    def selective(self) -> bool:
        return self.scheduler.selective

    @property
    def threshold(self) -> float:
        return self.scheduler.threshold

    @property
    def exact_selective(self) -> bool:
        return self.scheduler.exact_selective

    @property
    def bloom_fp(self) -> float:
        return self.scheduler.bloom_fp

    @property
    def filters(self):
        return self.scheduler.filters

    @property
    def exact_sources(self):
        return self.scheduler.exact_sources

    @property
    def loading_io(self):
        return self.scheduler.loading_io

    def close(self) -> None:
        """Shut down the prefetch thread pool.  Idempotent: safe to call
        any number of times, including after a context-manager exit."""
        self.pipeline.close()
        self._hook_finalizer()  # unregisters the invalidation hook once

    def __enter__(self) -> "VSWEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------------ run
    def run(
        self,
        program: VertexProgram,
        *,
        max_iters: int = 100,
        record_values_history: bool = False,
    ) -> RunResult:
        with trace.span(
            "vsw.run", program=type(program).__name__, backend=self.backend_name
        ):
            with self._sweep_session():
                return self._run_pinned(
                    program,
                    max_iters=max_iters,
                    record_values_history=record_values_history,
                )

    def _run_pinned(
        self,
        program: VertexProgram,
        *,
        max_iters: int,
        record_values_history: bool,
    ) -> RunResult:
        meta = self.meta
        src_vals, active_mask = program.init(meta)
        src_vals = src_vals.astype(np.float32)
        active_ids = np.flatnonzero(active_mask).astype(np.int64)
        stats: List[IterStats] = []
        history = []
        converged = False
        pstats = PipelineStats()
        xstats = ExecStats()

        for it in range(max_iters):
            t0 = time.perf_counter()
            io0 = self.store.io.snapshot()
            cache_h0 = self.cache.stats.hits if self.cache else 0
            cache_m0 = self.cache.stats.misses if self.cache else 0
            pstats.reset()
            xstats.reset()

            with trace.span("vsw.iter", iteration=it) as it_sp:
                plan = self.scheduler.plan(active_ids)
                msgs = program.pre(src_vals, meta.out_deg).astype(np.float32)
                dst_vals = src_vals.copy()  # carried over for skipped shards

                loaded = self.pipeline.iter_shards(plan.shards, stats=pstats)
                try:
                    for res in self.executor.run(
                        loaded, msgs, program.combine, xstats
                    ):
                        new = program.apply(
                            np.asarray(res.acc, dtype=src_vals.dtype),
                            src_vals[res.v0: res.v1],
                            meta,
                            res.v0,
                        )
                        dst_vals[res.v0: res.v1] = new
                finally:
                    # Deterministic drain: on an executor/apply failure (or
                    # a ShardLoadError mid-stream) the prefetch window is
                    # cancelled+awaited NOW, not at GC — the next run() on
                    # this engine must not race stale loads.
                    loaded.close()
                it_sp.set(shards=plan.num_planned, skipped=plan.num_skipped)

            new_active = program.is_active(dst_vals, src_vals)
            active_ids = np.flatnonzero(new_active).astype(np.int64)
            src_vals = dst_vals
            dio = self.store.io - io0

            dev_shards = dev_disp = dev_bytes = ()
            if plan.device_shards is not None:
                bpl = (
                    dio.bytes_read / plan.num_planned if plan.num_planned
                    else 0.0
                )
                dev_shards = tuple(len(g) for g in plan.device_shards)
                dev_bytes = tuple(len(g) * bpl for g in plan.device_shards)
                dev_disp = tuple(
                    xstats.device_dispatches.get(d, 0)
                    for d in range(len(plan.device_shards))
                )

            stats.append(
                IterStats(
                    iteration=it,
                    time_s=time.perf_counter() - t0,
                    shards_processed=plan.num_planned,
                    shards_skipped=plan.num_skipped,
                    bytes_read=dio.bytes_read,
                    cache_hits=(self.cache.stats.hits - cache_h0) if self.cache else 0,
                    cache_misses=(self.cache.stats.misses - cache_m0)
                    if self.cache
                    else 0,
                    active_count=len(active_ids),
                    active_ratio=len(active_ids) / max(meta.num_vertices, 1),
                    selective_on=plan.selective_on,
                    load_total_s=pstats.load_total_s,
                    load_wait_s=pstats.wait_s,
                    load_overlap_s=pstats.overlap_s,
                    exec_s=xstats.exec_s,
                    dispatches=xstats.dispatches,
                    prefetch_depth=self.pipeline.depth,
                    device_shards=dev_shards,
                    device_dispatches=dev_disp,
                    device_bytes=dev_bytes,
                )
            )
            if record_values_history:
                history.append(src_vals.copy())
            if len(active_ids) == 0:
                converged = True
                break

        result = RunResult(values=src_vals, iterations=stats, converged=converged)
        if record_values_history:
            result.history = history  # type: ignore[attr-defined]
        return result
