"""Bloom filters for selective scheduling (paper §II-D-1).

Each shard owns a Bloom filter over the *source* vertices of its edges.  At
the start of an iteration, if the active-vertex ratio is below the paper's
threshold (0.001), the engine tests every shard's filter against the active
set: a shard whose filter matches no active vertex is *inactive* — loading
and processing it cannot produce updates, so it is skipped (no disk read,
no compute).  False positives only cost a wasted load, never correctness.

The filter is a bit-packed ``uint64`` array with ``k`` double-hashed probes
(h1 + i*h2, the standard Kirsch-Mitzenmacher construction) using two
Fibonacci/multiplicative hashes — branch-free and fully vectorised with
numpy so membership of a whole active-vertex array is one batched call.
A mirror device representation (``bits`` as ``uint32`` for TPU) feeds the
Pallas membership kernel in ``repro.kernels.bloom``.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

__all__ = ["BloomFilter", "build_shard_filters", "optimal_num_bits"]

_MUL1 = np.uint64(0x9E3779B97F4A7C15)  # 2^64 / golden ratio
_MUL2 = np.uint64(0xC2B2AE3D27D4EB4F)  # xxhash64 prime 2


def _hash2(x: np.ndarray) -> tuple:
    """Two independent 64-bit multiplicative hashes of int vertex ids."""
    x = x.astype(np.uint64)
    with np.errstate(over="ignore"):
        h1 = x * _MUL1
        h1 ^= h1 >> np.uint64(29)
        h2 = (x + np.uint64(0x165667B19E3779F9)) * _MUL2
        h2 ^= h2 >> np.uint64(31)
        h2 |= np.uint64(1)  # odd stride so probes cover the table
    return h1, h2


def optimal_num_bits(n_items: int, fp_rate: float = 0.01) -> int:
    """Textbook m = -n ln(p) / (ln 2)^2, rounded up to a multiple of 64."""
    n_items = max(n_items, 1)
    m = int(np.ceil(-n_items * np.log(fp_rate) / (np.log(2.0) ** 2)))
    return max(64, ((m + 63) // 64) * 64)


@dataclasses.dataclass
class BloomFilter:
    bits: np.ndarray  # uint64 [num_bits // 64]
    num_bits: int
    num_hashes: int
    n_items: int = 0

    @classmethod
    def build(
        cls, items: np.ndarray, *, fp_rate: float = 0.01, num_hashes: int = 4
    ) -> "BloomFilter":
        items = np.asarray(items)
        num_bits = optimal_num_bits(len(items), fp_rate)
        f = cls(
            bits=np.zeros(num_bits // 64, dtype=np.uint64),
            num_bits=num_bits,
            num_hashes=num_hashes,
            n_items=len(items),
        )
        f.add(items)
        return f

    def _positions(self, items: np.ndarray) -> np.ndarray:
        """Bit positions, shape [len(items), num_hashes]."""
        h1, h2 = _hash2(np.asarray(items))
        i = np.arange(self.num_hashes, dtype=np.uint64)
        with np.errstate(over="ignore"):
            pos = h1[:, None] + i[None, :] * h2[:, None]
        return (pos % np.uint64(self.num_bits)).astype(np.int64)

    def add(self, items: np.ndarray) -> None:
        if len(items) == 0:
            return
        pos = self._positions(items).ravel()
        word, bit = pos >> 6, pos & 63
        np.bitwise_or.at(self.bits, word, np.uint64(1) << bit.astype(np.uint64))

    def contains(self, items: np.ndarray) -> np.ndarray:
        """Vectorised membership test -> bool [len(items)]."""
        items = np.asarray(items)
        if len(items) == 0:
            return np.zeros(0, dtype=bool)
        pos = self._positions(items)
        word, bit = pos >> 6, pos & 63
        hits = (self.bits[word] >> bit.astype(np.uint64)) & np.uint64(1)
        return hits.astype(bool).all(axis=1)

    def any_member(self, items: np.ndarray) -> bool:
        """Does the filter (possibly) contain ANY of ``items``?

        This is the paper's ``Bloom_filter[shard.id].has(active_vertices)``
        check — the shard-skip decision.
        """
        if len(items) == 0:
            return False
        # Chunked so huge active sets don't materialise a big position matrix.
        items = np.asarray(items)
        for lo in range(0, len(items), 65536):
            if self.contains(items[lo : lo + 65536]).any():
                return True
        return False

    def fp_rate_estimate(self) -> float:
        """(1 - e^{-kn/m})^k using the actual bit occupancy."""
        load = np.unpackbits(self.bits.view(np.uint8)).mean()
        return float(load**self.num_hashes)

    # ------------------------------------------------------- device mirror
    def device_words(self) -> np.ndarray:
        """uint32 view for the TPU membership kernel (no uint64 on TPU)."""
        return self.bits.view(np.uint32).copy()


def build_shard_filters(
    shards: Sequence, *, fp_rate: float = 0.01, num_hashes: int = 4
) -> list:
    """One filter per shard over the shard's unique source vertices."""
    return [
        BloomFilter.build(s.unique_sources(), fp_rate=fp_rate, num_hashes=num_hashes)
        for s in shards
    ]


# ---------------------------------------------------------------------------
# 32-bit variant: the device (TPU) filter.  TPUs have no 64-bit integer
# vector units, so the on-device membership kernel uses uint32 arithmetic
# with a power-of-two bit count (modulo becomes a mask).  This host class is
# the bit-exact mirror the Pallas kernel is tested against.
# ---------------------------------------------------------------------------

_MUL1_32 = np.uint32(0x9E3779B1)  # 2^32 / golden ratio
_MUL2_32 = np.uint32(0x85EBCA77)  # murmur3 c1-ish
_ADD_32 = np.uint32(0x27D4EB2F)


def _hash2_u32(x: np.ndarray) -> tuple:
    x = x.astype(np.uint32)
    with np.errstate(over="ignore"):
        h1 = x * _MUL1_32
        h1 ^= h1 >> np.uint32(15)
        h2 = (x + _ADD_32) * _MUL2_32
        h2 ^= h2 >> np.uint32(13)
        h2 |= np.uint32(1)
    return h1, h2


@dataclasses.dataclass
class BloomFilter32:
    words: np.ndarray  # uint32 [num_bits // 32]
    num_bits: int  # power of two
    num_hashes: int
    n_items: int = 0

    @classmethod
    def build(
        cls, items: np.ndarray, *, fp_rate: float = 0.01, num_hashes: int = 4
    ) -> "BloomFilter32":
        items = np.asarray(items)
        m = optimal_num_bits(len(items), fp_rate)
        num_bits = 1 << int(np.ceil(np.log2(max(m, 32))))
        f = cls(
            words=np.zeros(num_bits // 32, dtype=np.uint32),
            num_bits=num_bits,
            num_hashes=num_hashes,
            n_items=len(items),
        )
        f.add(items)
        return f

    def _positions(self, items: np.ndarray) -> np.ndarray:
        h1, h2 = _hash2_u32(np.asarray(items))
        i = np.arange(self.num_hashes, dtype=np.uint32)
        with np.errstate(over="ignore"):
            pos = h1[:, None] + i[None, :] * h2[:, None]
        return (pos & np.uint32(self.num_bits - 1)).astype(np.int64)

    def add(self, items: np.ndarray) -> None:
        if len(items) == 0:
            return
        pos = self._positions(items).ravel()
        word, bit = pos >> 5, pos & 31
        np.bitwise_or.at(self.words, word, np.uint32(1) << bit.astype(np.uint32))

    def contains(self, items: np.ndarray) -> np.ndarray:
        items = np.asarray(items)
        if len(items) == 0:
            return np.zeros(0, dtype=bool)
        pos = self._positions(items)
        word, bit = pos >> 5, pos & 31
        hits = (self.words[word] >> bit.astype(np.uint32)) & np.uint32(1)
        return hits.astype(bool).all(axis=1)

    def any_member(self, items: np.ndarray) -> bool:
        if len(items) == 0:
            return False
        items = np.asarray(items)
        for lo in range(0, len(items), 65536):
            if self.contains(items[lo : lo + 65536]).any():
                return True
        return False
