"""CSR -> blocked-ELL conversion: the TPU-native shard format.

TPUs have no efficient scalar row-pointer walk, so the paper's CSR layout is
re-blocked at preprocessing time into a *windowed, row-split ELL* format that
a Pallas kernel can stream tile-by-tile (HBM->VMEM) — the kernel-level
analogue of the paper's vertex-centric sliding window:

- **Source windows**: edges are grouped by ``window = src // W``.  While a
  tile is processed, only the ``W``-wide slice of the source-value array is
  resident in VMEM, so the in-kernel gather hits a small local table.  With
  ``W <= 2**15`` the column indices fit ``int16`` — this *is* the on-device
  variant of the paper's compressed edge cache (half the index bytes).
- **Row splitting**: a destination with in-degree ``d`` inside one window
  becomes ``ceil(d / K)`` ELL rows of width ``K``; a ``seg`` array maps each
  ELL row back to its local destination row.  Partial reductions per ELL row
  are segment-combined afterwards (associative combine: sum/min/max), which
  keeps tiles dense regardless of degree skew — crucial for power-law graphs
  whose max in-degree (e.g. 20M in EU-2015) would otherwise explode padding.
- **Tiling**: ELL rows are padded per-window to a multiple of ``TR`` so a
  ``(TR, K)`` tile never straddles two source windows; ``tile_window[t]``
  drives the scalar-prefetch BlockSpec index map in the Pallas kernel.

Padding rows carry ``valid=False`` masks and ``seg=0``; they contribute the
combine identity and are therefore harmless.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from .sharding import ShardCSR

__all__ = [
    "EllShard",
    "EllBatch",
    "csr_to_ell",
    "concat_ells",
    "next_pow2",
    "bucket_rows",
    "pad_ell_arrays",
    "ragged_lane_pad",
    "ragged_lane_concat",
    "DEFAULT_K",
    "DEFAULT_TR",
    "DEFAULT_WINDOW",
]


def next_pow2(n: int) -> int:
    return 1 << max(int(np.ceil(np.log2(max(n, 1)))), 0)


def bucket_rows(n_ell: int, tr: int) -> int:
    """Shape bucket for jit caching: next power of two, rounded up to a
    tile multiple so a ``(TR, K)`` grid still covers it exactly."""
    n = max(next_pow2(n_ell), tr)
    return -(-n // tr) * tr


def pad_ell_arrays(idx, mask, seg, tw, n_ell: int, tr: int, n_ell_pad: int):
    """Pad ELL arrays to ``n_ell_pad`` rows (``n_ell_pad % tr == 0``).

    Padding rows carry ``mask=False`` / ``seg=0`` / window id 0 — they
    gather the combine identity into the first destination row, a no-op.
    ``tile_window`` is padded with ceil division: floor (``pad // tr``)
    silently truncates whenever the row padding isn't a tile multiple,
    leaving the padded tail without a window id.
    """
    pad = n_ell_pad - n_ell
    if pad == 0:
        return idx, mask, seg, tw
    assert n_ell_pad % tr == 0, (n_ell_pad, tr)
    idx = np.concatenate([idx, np.zeros((pad, idx.shape[1]), idx.dtype)])
    mask = np.concatenate([mask, np.zeros((pad, mask.shape[1]), bool)])
    seg = np.concatenate([seg, np.zeros(pad, np.int32)])
    tw = np.concatenate([tw, np.zeros(n_ell_pad // tr - tw.shape[0], np.int32)])
    assert idx.shape[0] == n_ell_pad and tw.shape[0] * tr == n_ell_pad
    return idx, mask, seg, tw

DEFAULT_K = 128  # ELL width == TPU lane count
DEFAULT_TR = 8  # tile rows == TPU sublane count
DEFAULT_WINDOW = 1 << 14  # 16384 source vertices per window (64KB fp32 table)


@dataclasses.dataclass
class EllShard:
    """Windowed row-split ELL representation of one destination shard."""

    shard_id: int
    v0: int
    v1: int
    num_vertices: int  # of the whole graph (defines window count)
    window: int  # W
    k: int  # ELL width
    tr: int  # tile rows
    ell_idx: np.ndarray  # int16/int32 [n_ell, K] window-local source indices
    ell_mask: np.ndarray  # bool  [n_ell, K]
    seg: np.ndarray  # int32 [n_ell] local destination row (0 for padding)
    tile_window: np.ndarray  # int32 [n_ell // TR] source-window id per tile
    nnz: int

    @property
    def rows(self) -> int:
        return self.v1 - self.v0

    @property
    def n_ell(self) -> int:
        return int(self.ell_idx.shape[0])

    @property
    def n_tiles(self) -> int:
        return int(self.tile_window.shape[0])

    @property
    def num_windows(self) -> int:
        return max(1, -(-self.num_vertices // self.window))

    @property
    def nbytes(self) -> int:
        return int(
            self.ell_idx.nbytes
            + self.ell_mask.nbytes
            + self.seg.nbytes
            + self.tile_window.nbytes
        )

    def global_idx(self) -> np.ndarray:
        """Recover global source ids, [n_ell, K] (undefined where mask=False)."""
        win = np.repeat(self.tile_window, self.tr).astype(np.int64)
        return self.ell_idx.astype(np.int64) + win[:, None] * self.window

    def padding_ratio(self) -> float:
        """Fraction of ELL slots that are padding (wasted bandwidth)."""
        total = self.ell_idx.size
        return 1.0 - (self.nnz / total) if total else 0.0


@dataclasses.dataclass
class EllBatch:
    """N consecutive ELL shards concatenated into one kernel dispatch.

    All constituent shards share ``window``/``k``/``tr``/``num_vertices``
    (one preprocessing run), so their tile->window prefetch maps live in the
    same coordinate system and simply concatenate: a single Pallas grid
    walks every tile of every shard against ONE resident message table,
    amortizing per-shard dispatch overhead (DESIGN.md §4).

    ``seg`` is globalized (shard-local destination row + the shard's row
    offset) so one segment combine with ``rows_total`` segments covers the
    whole batch; ``row_offsets`` splits the combined accumulator back into
    per-shard intervals.
    """

    shard_ids: list
    ell_idx: np.ndarray  # [sum n_ell, K]
    ell_mask: np.ndarray  # bool [sum n_ell, K]
    seg: np.ndarray  # int32 [sum n_ell] globalized destination rows
    tile_window: np.ndarray  # int32 [sum n_tiles]
    row_offsets: np.ndarray  # int64 [N+1] shard row boundaries in the acc
    num_vertices: int
    window: int
    k: int
    tr: int

    @property
    def rows_total(self) -> int:
        return int(self.row_offsets[-1])

    @property
    def n_ell(self) -> int:
        return int(self.ell_idx.shape[0])

    @property
    def num_windows(self) -> int:
        return max(1, -(-self.num_vertices // self.window))

    def split(self, acc: np.ndarray) -> list:
        """Slice a combined accumulator back per shard.

        Rows are the trailing axis so both the single-query ``[rows_total]``
        accumulator and the serving layer's lane-batched
        ``[lanes, rows_total]`` accumulator split the same way.
        """
        return [
            acc[..., self.row_offsets[i]: self.row_offsets[i + 1]]
            for i in range(len(self.shard_ids))
        ]


def concat_ells(ells: Sequence[EllShard]) -> EllBatch:
    """Concatenate ELL shards for one batched dispatch.

    Requires a homogeneous batch (same window/k/tr/num_vertices — true for
    any shards from one store) and tile-aligned shards (``n_ell % tr == 0``,
    guaranteed by :func:`csr_to_ell`'s per-window padding).
    """
    if not ells:
        raise ValueError("empty ELL batch")
    first = ells[0]
    for e in ells[1:]:
        if (e.window, e.k, e.tr, e.num_vertices) != (
            first.window, first.k, first.tr, first.num_vertices
        ):
            raise ValueError("ELL shards in a batch must share window/k/tr/|V|")
    for e in ells:
        if e.n_ell % e.tr:
            raise ValueError(f"shard {e.shard_id}: n_ell not tile-aligned")
    row_offsets = np.zeros(len(ells) + 1, dtype=np.int64)
    np.cumsum([e.rows for e in ells], out=row_offsets[1:])
    seg = np.concatenate(
        [e.seg.astype(np.int32) + np.int32(off)
         for e, off in zip(ells, row_offsets[:-1])]
    )
    return EllBatch(
        shard_ids=[e.shard_id for e in ells],
        ell_idx=np.concatenate([e.ell_idx for e in ells]),
        ell_mask=np.concatenate([e.ell_mask for e in ells]),
        seg=seg,
        tile_window=np.concatenate([e.tile_window for e in ells]),
        row_offsets=row_offsets,
        num_vertices=first.num_vertices,
        window=first.window,
        k=first.k,
        tr=first.tr,
    )


def ragged_lane_pad(lane_counts: Sequence[int]) -> int:
    """Padded lane count for ONE ragged launch covering all fusion groups.

    The multi-launch path pads every group to its own power of two, so its
    total waste is ``sum(next_pow2(k_g)) - sum(k_g)``.  A single ragged
    launch only needs ONE padded lane axis; padding the concatenated count
    to ``next_pow2(K_total)`` keeps the jit shape-bucket behaviour but can
    exceed the per-group waste (e.g. counts ``1,1,1`` -> 4 vs 3), so the
    target is capped at the per-group pow2 total — ragged waste is then
    provably never worse than the G-launch waste.
    """
    k_total = int(sum(int(k) for k in lane_counts))
    per_group = int(sum(next_pow2(max(int(k), 1)) for k in lane_counts))
    return max(1, min(next_pow2(max(k_total, 1)), per_group))


def ragged_lane_concat(msgs_by_group, combines: Sequence[str], *,
                       n_cols: Optional[int] = None):
    """Concatenate per-group lane matrices along the lane axis for one
    ragged launch.

    Returns ``(msgs_all, combine_ids, combines_set, group_slices)``:

    - ``msgs_all``   [k_pad, n_cols] — groups stacked then zero-padded to
      ``ragged_lane_pad`` lanes (and to ``n_cols`` columns when the caller
      passes the window-padded vertex count, saving a second copy).
    - ``combine_ids`` int32 [k_pad] — per lane, the index of its combine op
      in ``combines_set``.  Padding lanes get ``len(combines_set)`` — an id
      that matches NO arm, so every selection pass leaves them at the zero
      init and the results are discarded by ``group_slices`` anyway.
    - ``combines_set`` — deduplicated combine ops in first-seen order (two
      groups sharing a monoid share one kernel arm).
    - ``group_slices`` — per input group, its lane interval in ``msgs_all``.
    """
    if len(msgs_by_group) != len(combines):
        raise ValueError("one combine op per group required")
    if not msgs_by_group:
        raise ValueError("empty ragged lane concat")
    combines_set = tuple(dict.fromkeys(combines))
    counts = [int(m.shape[0]) for m in msgs_by_group]
    k_pad = ragged_lane_pad(counts)
    n = int(msgs_by_group[0].shape[1] if n_cols is None else n_cols)
    msgs_all = np.zeros((k_pad, n), dtype=msgs_by_group[0].dtype)
    combine_ids = np.full(k_pad, len(combines_set), dtype=np.int32)
    group_slices = []
    off = 0
    for m, c in zip(msgs_by_group, combines):
        if m.shape[1] > n:
            raise ValueError("group lane matrix wider than n_cols")
        sl = slice(off, off + int(m.shape[0]))
        msgs_all[sl, : m.shape[1]] = m
        combine_ids[sl] = combines_set.index(c)
        group_slices.append(sl)
        off = sl.stop
    return msgs_all, combine_ids, combines_set, group_slices


def csr_to_ell(
    shard: ShardCSR,
    num_vertices: int,
    *,
    window: int = DEFAULT_WINDOW,
    k: int = DEFAULT_K,
    tr: int = DEFAULT_TR,
    index_dtype: Optional[np.dtype] = None,
) -> EllShard:
    """Convert a CSR destination shard into the windowed row-split ELL format."""
    if window <= 0 or k <= 0 or tr <= 0:
        raise ValueError("window, k, tr must be positive")
    if index_dtype is None:
        index_dtype = np.int16 if window <= (1 << 15) else np.int32

    rows = shard.rows
    nnz = shard.nnz

    if nnz == 0:
        ell_idx = np.zeros((tr, k), dtype=index_dtype)
        ell_mask = np.zeros((tr, k), dtype=bool)
        seg = np.zeros((tr,), dtype=np.int32)
        tile_window = np.zeros((1,), dtype=np.int32)
        return EllShard(
            shard.shard_id, shard.v0, shard.v1, num_vertices, window, k, tr,
            ell_idx, ell_mask, seg, tile_window, nnz=0,
        )

    # Expand CSR to (local_dst, src) pairs, then sort by (window, local_dst, src).
    counts = np.diff(shard.row)
    local_dst = np.repeat(np.arange(rows, dtype=np.int64), counts)
    src = shard.col.astype(np.int64)
    win = src // window
    order = np.lexsort((src, local_dst, win))
    src, local_dst, win = src[order], local_dst[order], win[order]
    local_src = (src - win * window).astype(np.int64)

    # Row splitting: within each (window, local_dst) group, edge j goes to ELL
    # row group_start_ell + j // K, slot j % K.
    grp_change = np.empty(nnz, dtype=bool)
    grp_change[0] = True
    grp_change[1:] = (win[1:] != win[:-1]) | (local_dst[1:] != local_dst[:-1])
    grp_id = np.cumsum(grp_change) - 1  # [nnz]
    grp_start = np.flatnonzero(grp_change)  # first edge index of each group
    pos_in_grp = np.arange(nnz, dtype=np.int64) - grp_start[grp_id]
    rows_per_grp = np.ceil(
        np.diff(np.concatenate([grp_start, [nnz]])) / k
    ).astype(np.int64)

    # ELL row index before per-window tile padding.
    grp_row_start = np.concatenate([[0], np.cumsum(rows_per_grp)])[:-1]
    raw_ell_row = grp_row_start[grp_id] + pos_in_grp // k
    slot = pos_in_grp % k
    n_raw = int(rows_per_grp.sum())

    raw_seg = np.zeros(n_raw, dtype=np.int32)
    raw_win = np.zeros(n_raw, dtype=np.int64)
    raw_seg[grp_row_start] = 0  # filled below via scatter of group attrs
    # Each raw ELL row inherits (window, local_dst) of its group.
    grp_first_edge = grp_start  # [n_groups]
    grp_window = win[grp_first_edge]
    grp_dst = local_dst[grp_first_edge]
    row_grp = np.repeat(np.arange(len(grp_start)), rows_per_grp)
    raw_seg = grp_dst[row_grp].astype(np.int32)
    raw_win = grp_window[row_grp]

    # Pad ELL rows per window to a multiple of TR so tiles are window-pure.
    uniq_wins, win_row_counts = np.unique(raw_win, return_counts=True)
    padded_counts = -(-win_row_counts // tr) * tr
    win_row_offset = np.concatenate([[0], np.cumsum(padded_counts)])[:-1]
    n_ell = int(padded_counts.sum())

    # Map raw rows -> padded positions.
    win_rank = np.searchsorted(uniq_wins, raw_win)
    # position of raw row within its window block:
    row_in_win = np.zeros(n_raw, dtype=np.int64)
    # raw rows are already sorted by window (construction preserves sort order)
    start_of_win = np.concatenate([[0], np.cumsum(win_row_counts)])[:-1]
    row_in_win = np.arange(n_raw) - start_of_win[win_rank]
    padded_row = win_row_offset[win_rank] + row_in_win

    ell_idx = np.zeros((n_ell, k), dtype=index_dtype)
    ell_mask = np.zeros((n_ell, k), dtype=bool)
    seg = np.zeros((n_ell,), dtype=np.int32)
    seg[padded_row] = raw_seg

    # Scatter edges into the padded ELL arrays.
    edge_rows = padded_row[raw_ell_row]
    ell_idx[edge_rows, slot] = local_src.astype(index_dtype)
    ell_mask[edge_rows, slot] = True

    n_tiles = n_ell // tr
    tile_window = np.repeat(uniq_wins, padded_counts // tr).astype(np.int32)
    assert tile_window.shape[0] == n_tiles

    out = EllShard(
        shard.shard_id, shard.v0, shard.v1, num_vertices, window, k, tr,
        ell_idx, ell_mask, seg, tile_window, nnz=nnz,
    )
    assert int(out.ell_mask.sum()) == nnz
    return out
