"""Vertex programs (paper Algorithm 2): PageRank, SSSP, WCC (+ extras).

GraphMP's user API is a pull-mode ``Update(v, SrcVertexArray)`` returning the
new value and an activity bit.  All three of the paper's applications share
one algebraic shape::

    acc(v)  = COMBINE_{u in Γ_in(v)}  pre(val(u))     # gather along in-edges
    new(v)  = apply(acc(v), val(v))                   # vertex update
    active  = new(v) != val(v)

where COMBINE is an associative/commutative monoid (sum for PageRank, min
for SSSP/WCC).  We factor the per-edge message into an O(|V|) elementwise
``pre`` pass over the source array (e.g. PageRank's ``val/out_deg`` division
is hoisted out of the edge loop — same math as Alg. 2 line 3, one divide per
vertex instead of per edge), so the per-shard hot loop is a pure
gather+combine that the Pallas kernel implements.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import numpy as np

from .sharding import GraphMeta

__all__ = ["VertexProgram", "pagerank", "sssp", "wcc", "bfs",
           "personalized_pagerank", "degree_centrality", "get_program",
           "COMBINE_IDENTITY",
           "LaneProgram", "lane_bfs", "lane_sssp", "lane_wcc", "lane_ppr",
           "get_lane_program", "LANE_PROGRAMS"]

COMBINE_IDENTITY = {"sum": 0.0, "min": np.inf, "max": -np.inf}


@dataclasses.dataclass
class VertexProgram:
    """One pull-mode graph application.

    Attributes:
      combine: monoid over in-edge messages ("sum" | "min" | "max").
      pre:     (src_vals, out_deg) -> per-source message values, O(|V|).
      apply:   (acc, old_vals, meta, v0) -> new interval values (v0 = the
               interval's first global vertex id, for index-aware apps).
      init:    meta -> (initial values [|V|], initial active mask [|V|]).
      is_active: (new, old) -> bool mask; the paper uses exact inequality.
    """

    name: str
    combine: str
    pre: Callable[[np.ndarray, np.ndarray], np.ndarray]
    apply: Callable[[np.ndarray, np.ndarray, GraphMeta], np.ndarray]
    init: Callable[[GraphMeta], Tuple[np.ndarray, np.ndarray]]
    is_active: Callable[[np.ndarray, np.ndarray], np.ndarray] = (
        lambda new, old: new != old
    )
    dtype: type = np.float32

    @property
    def identity(self) -> float:
        return COMBINE_IDENTITY[self.combine]


def pagerank(damping: float = 0.85) -> VertexProgram:
    """acc = Σ val(u)/out_deg(u);  new = (1-d)/|V| + d·acc  (Alg. 2 lines 1-5)."""

    def pre(src_vals: np.ndarray, out_deg: np.ndarray) -> np.ndarray:
        return src_vals / np.maximum(out_deg, 1).astype(src_vals.dtype)

    def apply(acc: np.ndarray, old: np.ndarray, meta: GraphMeta, v0: int = 0) -> np.ndarray:
        base = np.asarray((1.0 - damping) / meta.num_vertices, dtype=acc.dtype)
        return (base + damping * acc).astype(old.dtype)

    def init(meta: GraphMeta):
        vals = np.full(meta.num_vertices, 1.0 / meta.num_vertices, dtype=np.float32)
        return vals, np.ones(meta.num_vertices, dtype=bool)

    return VertexProgram("pagerank", "sum", pre, apply, init)


def sssp(source: int = 0) -> VertexProgram:
    """Unit-weight SSSP (paper: val(u,v)=1): new = min(min_u d(u)+1, old)."""

    def pre(src_vals: np.ndarray, out_deg: np.ndarray) -> np.ndarray:
        return src_vals + np.asarray(1.0, dtype=src_vals.dtype)

    def apply(acc: np.ndarray, old: np.ndarray, meta: GraphMeta, v0: int = 0) -> np.ndarray:
        return np.minimum(acc, old).astype(old.dtype)

    def init(meta: GraphMeta):
        vals = np.full(meta.num_vertices, np.inf, dtype=np.float32)
        vals[source] = 0.0
        active = np.zeros(meta.num_vertices, dtype=bool)
        active[source] = True
        return vals, active

    return VertexProgram(f"sssp", "min", pre, apply, init)


def wcc() -> VertexProgram:
    """Weakly-connected components by label propagation of the min id.

    Note: as in the paper's Alg. 2, labels propagate along *in-edges* of the
    (directed) shard layout; run on a symmetrised graph for true WCC.
    """

    def pre(src_vals: np.ndarray, out_deg: np.ndarray) -> np.ndarray:
        return src_vals

    def apply(acc: np.ndarray, old: np.ndarray, meta: GraphMeta, v0: int = 0) -> np.ndarray:
        return np.minimum(acc, old).astype(old.dtype)

    def init(meta: GraphMeta):
        vals = np.arange(meta.num_vertices, dtype=np.float32)
        return vals, np.ones(meta.num_vertices, dtype=bool)

    return VertexProgram("wcc", "min", pre, apply, init)


def bfs(source: int = 0) -> VertexProgram:
    """BFS levels — identical algebra to unit-weight SSSP."""
    p = sssp(source)
    return dataclasses.replace(p, name="bfs")


def personalized_pagerank(
    source: int = 0, damping: float = 0.85
) -> VertexProgram:
    """PPR: the teleport mass returns to ``source`` instead of spreading
    uniformly — exercises the paper's claim that the Update API covers
    arbitrary vertex-centric applications (§II-C-2)."""

    def pre(src_vals: np.ndarray, out_deg: np.ndarray) -> np.ndarray:
        return src_vals / np.maximum(out_deg, 1).astype(src_vals.dtype)

    def apply(acc: np.ndarray, old: np.ndarray, meta: GraphMeta, v0: int = 0) -> np.ndarray:
        return (damping * acc).astype(old.dtype)  # base added at source only

    def init(meta: GraphMeta):
        vals = np.zeros(meta.num_vertices, dtype=np.float32)
        vals[source] = 1.0
        return vals, np.ones(meta.num_vertices, dtype=bool)

    def apply_with_teleport(acc, old, meta, v0=0):
        out = (damping * acc).astype(old.dtype)
        idx = source - v0
        if 0 <= idx < len(out):
            out[idx] = out[idx] + np.float32(1.0 - damping)
        return out

    return VertexProgram("ppr", "sum", pre, apply_with_teleport, init)


def degree_centrality() -> VertexProgram:
    """In-degree counting as a one-iteration pull program (sanity app)."""

    def pre(src_vals: np.ndarray, out_deg: np.ndarray) -> np.ndarray:
        return np.ones_like(src_vals)

    def apply(acc: np.ndarray, old: np.ndarray, meta: GraphMeta, v0: int = 0) -> np.ndarray:
        return acc.astype(old.dtype)

    def init(meta: GraphMeta):
        return (
            np.zeros(meta.num_vertices, dtype=np.float32),
            np.ones(meta.num_vertices, dtype=bool),
        )

    return VertexProgram("degree", "sum", pre, apply, init)


# --------------------------------------------------------------------------
# Multi-lane (multi-query) programs — the serving layer's vertex API
# --------------------------------------------------------------------------
#
# GraphServe (repro/serve/) executes K concurrent per-source queries as
# *lanes* of one VSW sweep: vertex state becomes shape ``(K, n)`` and every
# shard's gather+combine is applied to all K message rows at once.  A
# :class:`LaneProgram` is the lane-dimensional counterpart of
# :class:`VertexProgram`: ``pre``/``apply``/``is_active`` operate on 2-D
# ``(K, n)`` arrays, elementwise-identical per lane to the single-source
# program — which is what makes a lane sweep bitwise-equal to K independent
# single-query runs (tests/test_serve.py).  Per-lane state (the source
# vertex) is carried explicitly through ``apply`` so lanes can retire and be
# backfilled mid-sweep without rebuilding closures.  Lanes of DIFFERENT
# programs sharing a combine algebra (``combine_key``) may share one lane
# matrix — the serving layer's lane table applies each lane's own
# ``pre``/``apply`` (DESIGN.md §9), so BFS, SSSP and WCC queries fuse into
# one sweep.


@dataclasses.dataclass
class LaneProgram:
    """One per-source graph application, vectorized over K query lanes.

    Attributes:
      combine:   monoid over in-edge messages (same as VertexProgram).
      key:       full static identity — program name AND static parameters
                 (e.g. PPR damping).  Two requests with equal keys run the
                 exact same per-lane computation; the session cache and the
                 lane table's vectorized ``pre``/``apply`` grouping key on it.
      combine_key: fusion-compatibility key, coarser than ``key``.  Lanes
                 whose programs share a ``combine_key`` may share ONE lane
                 matrix in one sweep: the shard gather+combine kernel only
                 depends on the monoid, while ``pre``/``apply``/``is_active``
                 are row-wise and are applied per lane (grouped by ``key``).
                 Defaults to ``(combine,)`` — BFS, SSSP and WCC all fuse.
      pre:       (vals [K, n], out_deg [n]) -> messages [K, n].
      apply:     (acc [K, rows], old [K, rows], meta, v0, sources [K]) ->
                 new [K, rows]; ``sources[k]`` is lane k's query source
                 (-1 for free/padding lanes), for source-anchored programs.
      init_lane: (meta, source) -> (vals [n], active [n]) for ONE lane —
                 called at admission and again when a lane is backfilled.
      is_active: (new, old) -> bool [K, n]; exact inequality as the paper.
    """

    name: str
    combine: str
    key: Tuple
    pre: Callable[[np.ndarray, np.ndarray], np.ndarray]
    apply: Callable[..., np.ndarray]
    init_lane: Callable[[GraphMeta, int], Tuple[np.ndarray, np.ndarray]]
    combine_key: Optional[Tuple] = None
    is_active: Callable[[np.ndarray, np.ndarray], np.ndarray] = (
        lambda new, old: new != old
    )

    def __post_init__(self) -> None:
        if self.combine_key is None:
            self.combine_key = (self.combine,)

    @property
    def identity(self) -> float:
        return COMBINE_IDENTITY[self.combine]


def _lane_min_distance(name: str) -> LaneProgram:
    """Shared lane algebra of unit-weight SSSP / BFS levels."""

    def pre(vals: np.ndarray, out_deg: np.ndarray) -> np.ndarray:
        return vals + np.asarray(1.0, dtype=vals.dtype)

    def apply(acc, old, meta, v0=0, sources=None):
        return np.minimum(acc, old).astype(old.dtype)

    def init_lane(meta: GraphMeta, source: int):
        vals = np.full(meta.num_vertices, np.inf, dtype=np.float32)
        vals[source] = 0.0
        active = np.zeros(meta.num_vertices, dtype=bool)
        active[source] = True
        return vals, active

    return LaneProgram(name, "min", (name,), pre, apply, init_lane)


def lane_sssp() -> LaneProgram:
    """Lane-vectorized unit-weight SSSP (one source per lane)."""
    return _lane_min_distance("sssp")


def lane_bfs() -> LaneProgram:
    """Lane-vectorized BFS levels — identical algebra to unit-weight SSSP."""
    return _lane_min_distance("bfs")


def lane_wcc() -> LaneProgram:
    """Lane-vectorized WCC label propagation (min component id).

    The query ``source`` is ignored — every lane computes the full
    labelling; the parameter exists so WCC rides the same submit /
    session-cache / lane-table path as the per-source programs.  Identical
    algebra (``min`` combine, ``min(acc, old)`` apply) and op-for-op the
    same per-lane computation as :func:`wcc`, and the same ``combine_key``
    as BFS/SSSP — so WCC lanes fuse into the same lane table.
    """

    def pre(vals: np.ndarray, out_deg: np.ndarray) -> np.ndarray:
        return vals

    def apply(acc, old, meta, v0=0, sources=None):
        return np.minimum(acc, old).astype(old.dtype)

    def init_lane(meta: GraphMeta, source: int):
        vals = np.arange(meta.num_vertices, dtype=np.float32)
        return vals, np.ones(meta.num_vertices, dtype=bool)

    return LaneProgram("wcc", "min", ("wcc",), pre, apply, init_lane)


def lane_ppr(damping: float = 0.85) -> LaneProgram:
    """Lane-vectorized personalized PageRank: each lane's teleport mass
    returns to that lane's source.  Op-for-op identical per lane to
    :func:`personalized_pagerank` (same multiply, same in-place add at the
    source slot) so lane sweeps stay bitwise-equal to single-query runs."""

    def pre(vals: np.ndarray, out_deg: np.ndarray) -> np.ndarray:
        return vals / np.maximum(out_deg, 1).astype(vals.dtype)

    def apply(acc, old, meta, v0=0, sources=None):
        out = (damping * acc).astype(old.dtype)
        if sources is not None:
            local = np.asarray(sources, dtype=np.int64) - v0
            lanes = np.flatnonzero((local >= 0) & (local < out.shape[1]))
            out[lanes, local[lanes]] += np.float32(1.0 - damping)
        return out

    def init_lane(meta: GraphMeta, source: int):
        vals = np.zeros(meta.num_vertices, dtype=np.float32)
        vals[source] = 1.0
        return vals, np.ones(meta.num_vertices, dtype=bool)

    return LaneProgram("ppr", "sum", ("ppr", float(damping)), pre, apply,
                       init_lane)


LANE_PROGRAMS: Dict[str, Callable[..., LaneProgram]] = {
    "bfs": lane_bfs,
    "sssp": lane_sssp,
    "wcc": lane_wcc,
    "ppr": lane_ppr,
}


def get_lane_program(name: str, **kwargs) -> LaneProgram:
    """Factory for lane-vectorized per-source programs (serving layer)."""
    if name not in LANE_PROGRAMS:
        raise KeyError(
            f"unknown lane program {name!r}; have {sorted(LANE_PROGRAMS)}"
        )
    return LANE_PROGRAMS[name](**kwargs)


_REGISTRY: Dict[str, Callable[..., VertexProgram]] = {
    "pagerank": pagerank,
    "sssp": sssp,
    "wcc": wcc,
    "bfs": bfs,
    "ppr": personalized_pagerank,
    "degree": degree_centrality,
}


def get_program(name: str, **kwargs) -> VertexProgram:
    if name not in _REGISTRY:
        raise KeyError(f"unknown program {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name](**kwargs)
