"""Streamed out-of-core ingestion: the paper's 4-step preprocessing as a
bounded-memory external build (GraphMP §II-B, ROADMAP "Out-of-core
ingestion").

:func:`repro.core.sharding.preprocess` materializes and lexsorts the whole
edge list — O(|E|) memory, which contradicts the SEM premise that
|E| >> RAM.  This module rebuilds the same four steps as a **two-pass
external** pipeline over an on-disk edge file:

pass 1 (scan)
    Stream the file in ``chunk_edges``-sized chunks, accumulating in/out
    degrees (the O(|V|) vertex arrays that SEM keeps resident anyway) and
    optionally inferring ``num_vertices``.  Intervals come from the same
    :func:`~repro.core.sharding.compute_intervals` the in-memory path uses,
    on bitwise-identical degree arrays.

pass 2 (scatter + spill)
    Stream the file again; each chunk's edges are routed to their
    destination shard and buffered as packed ``(dst << 32) | src`` int64
    keys.  When the buffered bytes reach ``mem_budget_bytes`` every
    non-empty buffer is sorted and spilled to a per-shard *run* file
    through the store's accounted write channel.

merge (finalize)
    Shards finalize one at a time, in id order: the shard's sorted runs
    are read back and k-way merged (a binary tournament of vectorized
    two-way merges), the merged keys are unpacked into the CSR ``row`` /
    ``col`` arrays, and the shard is written through
    :meth:`ShardStore.write_shard` (which also derives the device ELL
    format).  Peak memory is O(chunk + one shard), never O(|E|).

Bitwise contract (enforced by ``tests/test_ingest.py``): the in-memory
path orders each shard by ``np.lexsort((src, dst))`` — destination-major,
source-minor.  The packed key sorts by exactly that pair (ids are
non-negative int32, so the key order is the lexicographic (dst, src)
order), runs are individually sorted, and merging sorted runs preserves
the order.  Ties are exact duplicate edges, whose ``col`` entries are
indistinguishable — so ``row``/``col`` come out bitwise-identical to
:func:`preprocess` for every chunk size and spill cadence.

Edge-file formats (auto-detected by extension, overridable via ``fmt``):

``bin``
    Raw little-endian int32 ``(src, dst)`` pairs, no header — the densest
    interchange format (8 bytes/edge, the paper's D=8 term exactly).
``text``
    Whitespace-separated ``src dst`` per line; blank lines and ``#``
    comments skipped (SNAP / WebGraph edge-list convention).
"""

from __future__ import annotations

import dataclasses
import os
import threading
from typing import IO, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from .sharding import GraphMeta, ShardCSR, compute_intervals

__all__ = [
    "IngestStats",
    "detect_format",
    "write_edge_file",
    "iter_edge_chunks",
    "ingest_edge_file",
    "kway_merge",
    "pack_keys",
    "keys_of_csr",
    "csr_from_keys",
    "route_edges",
]

_TEXT_EXTS = (".txt", ".el", ".tsv", ".edges", ".edgelist")
_KEY_DTYPE = np.dtype("<i8")
_PAIR_DTYPE = np.dtype("<i4")


# --------------------------------------------------------------------------
# Edge-file readers / writers
# --------------------------------------------------------------------------


def detect_format(path: str) -> str:
    """``text`` for known edge-list extensions, ``bin`` otherwise."""
    ext = os.path.splitext(path)[1].lower()
    return "text" if ext in _TEXT_EXTS else "bin"


def write_edge_file(
    path: str,
    src: np.ndarray,
    dst: np.ndarray,
    *,
    fmt: Optional[str] = None,
    chunk_edges: int = 1 << 20,
) -> int:
    """Write an edge file in ``chunk_edges`` slices; returns bytes written.

    Exists so tests/benchmarks can materialize inputs without holding an
    interleaved copy of the whole edge list.
    """
    if chunk_edges < 1:
        raise ValueError("chunk_edges must be >= 1")
    fmt = fmt or detect_format(path)
    src = np.asarray(src)
    dst = np.asarray(dst)
    if src.shape != dst.shape:
        raise ValueError("src/dst length mismatch")
    total = 0
    with open(path, "wb") as f:
        for lo in range(0, len(src), chunk_edges):
            s = src[lo: lo + chunk_edges]
            d = dst[lo: lo + chunk_edges]
            if fmt == "bin":
                pairs = np.empty((len(s), 2), dtype=_PAIR_DTYPE)
                pairs[:, 0] = s
                pairs[:, 1] = d
                raw = pairs.tobytes()
            elif fmt == "text":
                raw = "".join(
                    f"{int(a)} {int(b)}\n" for a, b in zip(s, d)
                ).encode()
            else:
                raise ValueError(f"unknown edge-file format {fmt!r}")
            f.write(raw)
            total += len(raw)
        if len(src) == 0:
            # still touch the file so an empty graph is ingestable
            pass
    return total


def _iter_bin_chunks(
    f: IO[bytes], chunk_edges: int
) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    pair_bytes = 2 * _PAIR_DTYPE.itemsize
    while True:
        raw = f.read(chunk_edges * pair_bytes)
        if not raw:
            return
        if len(raw) % pair_bytes:
            raise ValueError(
                f"truncated binary edge file: {len(raw) % pair_bytes} "
                f"trailing bytes (not a whole int32 pair)"
            )
        pairs = np.frombuffer(raw, dtype=_PAIR_DTYPE).reshape(-1, 2)
        yield pairs[:, 0], pairs[:, 1]


def _iter_text_chunks(
    f: IO[bytes], chunk_edges: int
) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    srcs: List[int] = []
    dsts: List[int] = []
    for lineno, line in enumerate(f, 1):
        part = line.partition(b"#")[0].split()
        if not part:
            continue
        if len(part) < 2:
            raise ValueError(f"line {lineno}: expected 'src dst', got {line!r}")
        srcs.append(int(part[0]))
        dsts.append(int(part[1]))
        if len(srcs) >= chunk_edges:
            yield np.asarray(srcs, dtype=np.int64), np.asarray(dsts, dtype=np.int64)
            srcs, dsts = [], []
    if srcs:
        yield np.asarray(srcs, dtype=np.int64), np.asarray(dsts, dtype=np.int64)


def iter_edge_chunks(
    path: str,
    *,
    chunk_edges: int = 1 << 20,
    fmt: Optional[str] = None,
) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    """Yield ``(src, dst)`` arrays of at most ``chunk_edges`` edges each.

    The file is read front-to-back with O(chunk) resident bytes; calling it
    twice is the two-pass discipline of the external build.
    """
    if chunk_edges < 1:
        raise ValueError("chunk_edges must be >= 1")
    fmt = fmt or detect_format(path)
    with open(path, "rb") as f:
        if fmt == "bin":
            yield from _iter_bin_chunks(f, chunk_edges)
        elif fmt == "text":
            yield from _iter_text_chunks(f, chunk_edges)
        else:
            raise ValueError(f"unknown edge-file format {fmt!r}")


# --------------------------------------------------------------------------
# K-way merge of sorted runs
# --------------------------------------------------------------------------


def _merge_two(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Stable vectorized merge of two sorted arrays (a before b on ties)."""
    if len(a) == 0:
        return b
    if len(b) == 0:
        return a
    out = np.empty(len(a) + len(b), dtype=a.dtype)
    # final position of a[i] = i + (# of b strictly before it); of b[j] =
    # j + (# of a at-or-before it).  Disjoint + exhaustive, hence a merge.
    out[np.arange(len(a)) + np.searchsorted(b, a, side="left")] = a
    out[np.arange(len(b)) + np.searchsorted(a, b, side="right")] = b
    return out


def kway_merge(runs: Sequence[np.ndarray]) -> np.ndarray:
    """Merge k sorted arrays via a binary tournament (ceil(log2 k) rounds).

    Each round halves the number of runs with vectorized two-way merges;
    total work is O(n log k) with no per-element Python overhead.  Because
    every input is sorted and two-way merge preserves sortedness, the
    result is the sorted union — this is why spill order (which edges
    landed in which run) cannot affect the final shard layout.
    """
    runs = [r for r in runs if len(r)]
    if not runs:
        return np.empty(0, dtype=_KEY_DTYPE)
    while len(runs) > 1:
        merged = [
            _merge_two(runs[i], runs[i + 1]) if i + 1 < len(runs) else runs[i]
            for i in range(0, len(runs), 2)
        ]
        runs = merged
    return runs[0]


# --------------------------------------------------------------------------
# The two-pass external build
# --------------------------------------------------------------------------


@dataclasses.dataclass
class IngestStats:
    """What the external build did and what it cost.

    The accounting identity ``store.io.bytes_written == spill_bytes_written
    + shard_bytes_written + meta_bytes_written`` holds on a fresh store —
    every byte the build writes goes through the accounted channel
    (asserted by ``tests/test_ingest.py``).
    """

    num_vertices: int = 0
    num_edges: int = 0
    num_shards: int = 0
    chunks_pass1: int = 0
    chunks_pass2: int = 0
    spills: int = 0  # buffer flushes (each may emit many runs)
    runs: int = 0  # spill run files written
    max_runs_per_shard: int = 0  # merge fan-in upper bound
    spill_bytes_written: int = 0
    spill_bytes_read: int = 0
    shard_bytes_written: int = 0  # final CSR + ELL containers
    meta_bytes_written: int = 0  # property.json + vertexinfo.npz
    peak_buffered_bytes: int = 0  # high-water of the pass-2 scatter buffers
    peak_shard_bytes: int = 0  # largest single-shard merge working set
    stale_shards_removed: int = 0  # re-ingest into a dir with more shards
    orphan_runs_removed: int = 0  # scratch left by a crashed prior ingest
    stale_delta_runs_removed: int = 0  # re-ingest replaces pending deltas
    finalize_workers: int = 1  # concurrent per-shard merge+write workers
    warm_sources_built: int = 0  # shards whose Bloom inputs were deposited
    warm_raw_bytes: int = 0  # container bytes left warm for cache prefill

    @property
    def bytes_written_total(self) -> int:
        return (
            self.spill_bytes_written
            + self.shard_bytes_written
            + self.meta_bytes_written
        )


class _DegreeScan:
    """Pass 1 accumulator: degrees + vertex-count inference.

    Capacity grows geometrically (2x) when ids are inferred, so a file
    whose ids trend upward costs amortized O(V) copying, not O(V·chunks).
    """

    def __init__(self, num_vertices: Optional[int]):
        self.explicit_n = num_vertices
        n = num_vertices or 0
        self.in_deg = np.zeros(n, dtype=np.int64)
        self.out_deg = np.zeros(n, dtype=np.int64)
        self.num_edges = 0
        self._max_id = -1

    def _grow(self, n: int) -> None:
        cap = len(self.in_deg)
        if n > cap:
            new_cap = max(n, 2 * cap)
            pad = np.zeros(new_cap - cap, dtype=np.int64)
            self.in_deg = np.concatenate([self.in_deg, pad])
            self.out_deg = np.concatenate([self.out_deg, pad])

    def add(self, src: np.ndarray, dst: np.ndarray) -> None:
        if len(src) == 0:
            return
        lo = min(int(src.min()), int(dst.min()))
        hi = max(int(src.max()), int(dst.max()))
        if lo < 0:
            raise ValueError(f"negative vertex id {lo} in edge file")
        if self.explicit_n is not None and hi >= self.explicit_n:
            raise ValueError(
                f"vertex id {hi} out of range [0, {self.explicit_n})"
            )
        self._grow(hi + 1)
        self._max_id = max(self._max_id, hi)
        self.in_deg += np.bincount(dst, minlength=len(self.in_deg))
        self.out_deg += np.bincount(src, minlength=len(self.out_deg))
        self.num_edges += len(src)

    @property
    def num_vertices(self) -> int:
        return self.explicit_n if self.explicit_n is not None else self._max_id + 1

    def degrees(self) -> Tuple[np.ndarray, np.ndarray]:
        """The exact-length degree arrays (trims growth over-allocation)."""
        n = self.num_vertices
        if n == len(self.in_deg):
            return self.in_deg, self.out_deg
        return self.in_deg[:n].copy(), self.out_deg[:n].copy()


def pack_keys(src: np.ndarray, dst: np.ndarray) -> np.ndarray:
    """(dst << 32) | src — int64 keys whose ascending order is the
    destination-major (dst, src) lexicographic order for int32 ids."""
    return (dst.astype(np.int64) << 32) | src.astype(np.int64)


_pack_keys = pack_keys  # original (private) name, kept for callers


def keys_of_csr(csr) -> np.ndarray:
    """Packed sorted keys of a destination-sorted CSR shard — the exact
    inverse of :func:`csr_from_keys` (shards store edges in ascending key
    order, so expanding rows back to (dst, src) pairs yields sorted keys).
    """
    rows = csr.v1 - csr.v0
    dst_local = np.repeat(np.arange(rows, dtype=np.int64), np.diff(csr.row))
    return ((dst_local + csr.v0) << 32) | csr.col.astype(np.int64)


def csr_from_keys(shard_id: int, v0: int, v1: int, keys: np.ndarray):
    """Build the ShardCSR of interval ``[v0, v1)`` from sorted packed keys.

    Single point of truth for the key→CSR transform: the streamed ingest
    finalize, the delta overlay decode and the recompactor all call it, so
    a logical shard decodes bitwise-identically on every path.
    """
    dst_local = (keys >> 32) - v0
    col = (keys & 0xFFFFFFFF).astype(np.int32)
    counts = np.bincount(dst_local, minlength=v1 - v0)
    row = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
    return ShardCSR(shard_id=shard_id, v0=v0, v1=v1, row=row, col=col)


def route_edges(
    intervals: np.ndarray, src: np.ndarray, dst: np.ndarray
) -> Iterator[Tuple[int, np.ndarray]]:
    """Scatter one edge chunk to destination shards: yields ``(p, keys)``
    per touched shard (keys packed, file order preserved — NOT sorted).
    The pass-2 spill path and the delta EdgeLog share this routing."""
    if len(src) == 0:
        return
    keys = pack_keys(src, dst)
    shard_of = np.searchsorted(intervals, dst, side="right") - 1
    order = np.argsort(shard_of, kind="stable")
    keys = keys[order]
    shard_sorted = shard_of[order]
    touched, starts = np.unique(shard_sorted, return_index=True)
    stops = np.append(starts[1:], len(keys))
    for p, lo, hi in zip(touched, starts, stops):
        yield int(p), keys[lo:hi]


def _run_name(shard_id: int, run: int) -> str:
    return f"ingest_run_{shard_id:05d}_{run:05d}.bin"


def ingest_edge_file(
    store,
    path: str,
    *,
    edges_per_shard: Optional[int] = None,
    num_shards: Optional[int] = None,
    num_vertices: Optional[int] = None,
    chunk_edges: int = 1 << 20,
    mem_budget_bytes: int = 64 << 20,
    window: int = 1 << 14,
    k: int = 128,
    tr: int = 8,
    fmt: Optional[str] = None,
    finalize_workers: int = 1,
    warm_sources: bool = True,
    warm_bytes: int = 0,
) -> Tuple[GraphMeta, IngestStats]:
    """Stream ``path`` into ``store`` with O(chunk + one shard) peak memory.

    ``store`` is a :class:`~repro.core.storage.ShardStore`; spill runs and
    final shards all go through its accounted I/O channel.  Returns the
    same ``GraphMeta`` (bitwise) that in-memory ``preprocess`` would have
    produced, plus the build's :class:`IngestStats`.

    ``finalize_workers``: per-shard k-way merges are independent, so the
    finalize step can run them on a thread pool (0 = one worker per core,
    capped at 4).  Peak memory grows to O(chunk + workers * shard); the
    default of 1 preserves the strict single-shard bound.  Output and byte
    accounting are identical for every worker count — each shard's merge is
    self-contained and its bytes are measured per shard, not per interval
    of the global counters.

    Warmup (PR 3 follow-on): ``warm_sources`` deposits each shard's unique
    source ids on the store while the merged arrays are in memory, so
    engine boot builds Bloom filters without re-reading every shard;
    ``warm_bytes > 0`` additionally keeps up to that many container bytes
    for cache prefill at boot.
    """
    if chunk_edges < 1:
        raise ValueError("chunk_edges must be >= 1")
    if mem_budget_bytes < _KEY_DTYPE.itemsize:
        raise ValueError("mem_budget_bytes must hold at least one edge key")
    if (num_shards is None) == (edges_per_shard is None):
        # fail in milliseconds, not after a full pass over a huge file
        raise ValueError("specify exactly one of num_shards / edges_per_shard")
    if finalize_workers < 0:
        raise ValueError("finalize_workers must be >= 0 (0 = auto)")
    if finalize_workers == 0:
        finalize_workers = min(4, os.cpu_count() or 1)
    fmt = fmt or detect_format(path)
    stats = IngestStats(finalize_workers=finalize_workers)

    # orphaned scratch from a previously crashed/interrupted ingest, and
    # pending delta runs from the store's previous life — a full re-ingest
    # replaces the whole logical graph, so leftover mutations are stale
    for f in os.listdir(store.root):
        if f.startswith("ingest_run_") and f.endswith(".bin"):
            os.remove(store._path(f))
            stats.orphan_runs_removed += 1
        elif (
            f.startswith(("delta_run_", "delta_journal_"))
            or f == "delta_manifest.json"
        ):
            os.remove(store._path(f))
            stats.stale_delta_runs_removed += 1
        elif f == "delta_stage" and os.path.isdir(store._path(f)):
            import shutil

            shutil.rmtree(store._path(f))
            stats.stale_delta_runs_removed += 1
    if getattr(store, "delta", None) is not None:
        store.delta = None  # state referred to the replaced graph

    # ---- pass 1: degree scan -------------------------------------------
    scan = _DegreeScan(num_vertices)
    for src, dst in iter_edge_chunks(path, chunk_edges=chunk_edges, fmt=fmt):
        scan.add(src, dst)
        stats.chunks_pass1 += 1
    n = scan.num_vertices
    in_deg, out_deg = scan.degrees()
    intervals = compute_intervals(
        in_deg, num_shards=num_shards, edges_per_shard=edges_per_shard
    )
    P = len(intervals) - 1
    stats.num_vertices = n
    stats.num_edges = scan.num_edges
    stats.num_shards = P

    # ---- pass 2: scatter + spill ---------------------------------------
    buffers: List[List[np.ndarray]] = [[] for _ in range(P)]
    buffered_bytes = 0
    run_names: List[List[str]] = [[] for _ in range(P)]

    def spill() -> None:
        nonlocal buffered_bytes
        if buffered_bytes == 0:
            return
        stats.spills += 1
        for p in range(P):
            if not buffers[p]:
                continue
            run = np.sort(np.concatenate(buffers[p]))
            name = _run_name(p, len(run_names[p]))
            store.write_bytes(name, run.tobytes())
            run_names[p].append(name)
            stats.runs += 1
            stats.spill_bytes_written += run.nbytes
            buffers[p] = []
        buffered_bytes = 0

    for src, dst in iter_edge_chunks(path, chunk_edges=chunk_edges, fmt=fmt):
        stats.chunks_pass2 += 1
        nbytes_chunk = 0
        for p, keys in route_edges(intervals, src, dst):
            buffers[p].append(keys)
            nbytes_chunk += keys.nbytes
        buffered_bytes += nbytes_chunk
        stats.peak_buffered_bytes = max(stats.peak_buffered_bytes, buffered_bytes)
        if buffered_bytes >= mem_budget_bytes:
            spill()

    # ---- merge + finalize: shards are independent, so ``finalize_workers``
    # of them merge+write concurrently (stats mutated under one lock; byte
    # counts measured per shard so parallelism cannot skew them) ----------
    stats_lock = threading.Lock()

    def _finalize_shard(p: int) -> None:
        v0, v1 = int(intervals[p]), int(intervals[p + 1])
        runs = []
        spill_read = 0
        for name in run_names[p]:
            raw = store.read_bytes(name)
            spill_read += len(raw)
            runs.append(np.frombuffer(raw, dtype=_KEY_DTYPE))
        if buffers[p]:  # tail edges never spilled: one in-memory run
            runs.append(np.sort(np.concatenate(buffers[p])))
            buffers[p] = []
        merged = kway_merge(runs)
        n_runs = len(runs)
        del runs
        shard = csr_from_keys(p, v0, v1, merged)
        working_set = merged.nbytes + shard.nbytes
        del merged
        capture = {} if warm_bytes > 0 else None
        store.write_shard(
            shard, num_vertices=n, window=window, k=k, tr=tr, capture=capture
        )
        written = store.file_size(store.shard_name(p, "csr")) + store.file_size(
            store.shard_name(p, "ell")
        )
        for name in run_names[p]:  # spill runs are scratch, not the store
            os.remove(store._path(name))
        run_names[p] = []
        warmed_srcs = 0
        if warm_sources:
            store.set_warm_sources(p, np.unique(shard.col).astype(np.int64))
            warmed_srcs = 1
        warm_kept = 0
        if capture is not None:
            for (cp, cfmt), raw in sorted(capture.items(), key=lambda kv: kv[0][1]):
                if store.warm_raw_bytes_total() + len(raw) <= warm_bytes:
                    store.add_warm_raw(cp, cfmt, raw)
                    warm_kept += len(raw)
        with stats_lock:
            stats.spill_bytes_read += spill_read
            stats.max_runs_per_shard = max(stats.max_runs_per_shard, n_runs)
            stats.peak_shard_bytes = max(stats.peak_shard_bytes, working_set)
            stats.shard_bytes_written += written
            stats.warm_sources_built += warmed_srcs
            stats.warm_raw_bytes += warm_kept

    if finalize_workers > 1 and P > 1:
        import concurrent.futures

        with concurrent.futures.ThreadPoolExecutor(
            max_workers=min(finalize_workers, P),
            thread_name_prefix="ingest-finalize",
        ) as pool:
            for _ in pool.map(_finalize_shard, range(P)):
                pass  # re-raises worker exceptions
    else:
        for p in range(P):
            _finalize_shard(p)

    # ---- stale shards from a previous (larger) ingest ------------------
    p = P
    while store.exists(store.shard_name(p, "csr")) or store.exists(
        store.shard_name(p, "ell")
    ):
        for f in (store.shard_name(p, "csr"), store.shard_name(p, "ell")):
            if store.exists(f):
                os.remove(store._path(f))
        store.invalidate_shard(p)
        stats.stale_shards_removed += 1
        p += 1

    # ---- metadata last: a dir without property.json is not bootable ----
    meta = GraphMeta(
        num_vertices=n,
        num_edges=scan.num_edges,
        num_shards=P,
        intervals=intervals,
        in_deg=in_deg,
        out_deg=out_deg,
    )
    io0 = store.io.snapshot()
    store.write_meta(meta, ell_params={"window": window, "k": k, "tr": tr})
    stats.meta_bytes_written += (store.io - io0).bytes_written
    return meta, stats
