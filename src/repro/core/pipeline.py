"""Prefetching shard loader: *how shards get loaded* (DESIGN.md §3).

Second layer of the engine stack.  Given an ordered :class:`ShardPlan`, the
pipeline yields decoded shards in plan order while a small thread pool runs
``depth`` loads ahead — disk read (or cache hit) + decompress + decode all
happen off the critical path, so a worker consuming shard ``i`` overlaps
the I/O of shards ``i+1 .. i+depth``.  This is the paper's §II-C discipline
("load graph data from SSD/HDD to the main memory" with dedicated load
threads while "multiple executors process the loaded data in parallel"),
with ``depth >= 1`` giving the double buffering of Fig. 3.

``depth == 0`` degrades to a plain synchronous loop — bit-identical
results either way, since consumption order is always plan order and the
vertex arrays are only touched by the consumer.

The pipeline also owns the decoded-resident dict (the beyond-paper
``device_resident`` mode): decoded device-format shards are kept and reused
without touching cache, disk, or decode again.
"""

from __future__ import annotations

import dataclasses
import threading
import time
import weakref
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Dict, Iterator, Optional, Sequence, Tuple

from ..obs import trace
from .cache import ShardCache
from .csr import EllShard
from .sharding import ShardCSR
from .storage import ShardStore

__all__ = ["LoadedShard", "PipelineStats", "ShardLoadError", "ShardPipeline"]


class ShardLoadError(RuntimeError):
    """A prefetch-thread (or inline) shard load failed.

    Raised at the *consuming* iterator with the failing shard id attached
    (``exc.shard_id``) and the original loader exception chained as
    ``__cause__`` — previously the bare loader exception surfaced from
    ``Future.result()`` with no indication of which shard died.  When
    tracing is enabled the failing ``shard.load`` span carries an ``error``
    attribute, so the failure is visible in the timeline too.
    """

    def __init__(self, shard_id: int, cause: BaseException):
        super().__init__(f"shard {shard_id} failed to load: {cause!r}")
        self.shard_id = shard_id


@dataclasses.dataclass
class LoadedShard:
    """One decoded shard plus where it came from and what it cost."""

    shard_id: int
    csr: Optional[ShardCSR]
    ell: Optional[EllShard]
    load_s: float = 0.0  # in-thread (or inline) load+decode duration
    wait_s: float = 0.0  # critical-path stall until this shard was ready
    from_cache: bool = False
    from_resident: bool = False

    @property
    def ref(self):
        """The backend-facing shard object (csr for numpy, ell otherwise)."""
        return self.csr if self.csr is not None else self.ell


@dataclasses.dataclass
class PipelineStats:
    """Per-iteration load/overlap accounting (reset each iteration)."""

    shards_loaded: int = 0
    load_total_s: float = 0.0  # sum of load durations (hidden + exposed)
    wait_s: float = 0.0  # exposed: consumer stalled on a future
    cache_hits: int = 0
    resident_hits: int = 0

    @property
    def overlap_s(self) -> float:
        """Load work hidden behind compute — the paper's Fig. 3 win."""
        return max(0.0, self.load_total_s - self.wait_s)

    def reset(self) -> None:
        self.shards_loaded = 0
        self.load_total_s = self.wait_s = 0.0
        self.cache_hits = self.resident_hits = 0


class ShardPipeline:
    """Walks a shard plan with depth-configurable background prefetch."""

    def __init__(
        self,
        store: ShardStore,
        fmt: str,
        *,
        cache: Optional[ShardCache] = None,
        depth: int = 2,
        resident: Optional[Dict[int, Tuple]] = None,
    ):
        if depth < 0:
            raise ValueError("prefetch depth must be >= 0")
        self.store = store
        self.fmt = fmt
        self.cache = cache
        self.depth = depth
        self.resident = resident  # shard_id -> (csr, ell), engine-owned
        # Delta snapshot pin (repro.delta): the engine/lane sweep sets this
        # to the overlay version it pinned for the CURRENT sweep, so every
        # load — inline or from a prefetch thread — decodes the same graph
        # version.  None = no overlay, or latest published state.
        self.pin: Optional[int] = None
        self._pool: Optional[ThreadPoolExecutor] = None
        self._finalizer = None

    # ----------------------------------------------------------- lifecycle
    def _ensure_pool(self) -> ThreadPoolExecutor:
        if self._pool is None:
            self._pool = ThreadPoolExecutor(
                max_workers=self.depth, thread_name_prefix="shard-prefetch"
            )
            self._finalizer = weakref.finalize(
                self, ThreadPoolExecutor.shutdown, self._pool, wait=False
            )
        return self._pool

    def close(self) -> None:
        if self._pool is not None:
            self._pool.shutdown(wait=False)
            self._pool = None
            if self._finalizer is not None:
                self._finalizer.detach()
                self._finalizer = None

    # ---------------------------------------------------------------- load
    def _load(self, p: int) -> LoadedShard:
        """Cache lookup -> disk read -> decode, all off the critical path
        when called from a prefetch thread.  Any loader failure is wrapped
        in :class:`ShardLoadError` carrying the shard id, and the
        ``shard.load`` span (running on the prefetch thread's trace lane)
        is marked with the error."""
        with trace.span("shard.load", shard=p) as sp:
            try:
                ls = self._load_impl(p)
            except ShardLoadError:
                raise
            except Exception as exc:
                raise ShardLoadError(p, exc) from exc
            sp.set(
                from_cache=ls.from_cache,
                from_resident=ls.from_resident,
                load_ms=ls.load_s * 1e3,
            )
            return ls

    def _load_impl(self, p: int) -> LoadedShard:
        t0 = time.perf_counter()
        delta = self.store.delta
        if delta is not None and delta.has_pending(p, self.pin):
            # Logical decode: base CSR + pending delta runs at the pinned
            # version, merged under the overlay's per-shard lock (atomic
            # against a recompaction swap).  The byte cache keeps the base
            # CSR container; decoded results are never kept resident while
            # a shard has pending deltas — recompaction restores that path.
            obj, from_cache = delta.load_logical(
                p, self.fmt, pin=self.pin, cache=self.cache
            )
            csr, ell = (obj, None) if self.fmt == "csr" else (None, obj)
            return LoadedShard(p, csr, ell, load_s=time.perf_counter() - t0,
                               from_cache=from_cache)
        if self.resident is not None and p in self.resident:
            csr, ell = self.resident[p]
            return LoadedShard(p, csr, ell, load_s=time.perf_counter() - t0,
                               from_resident=True)
        # Snapshot the shard generation BEFORE the read: if an overwrite
        # (re-ingest) lands between our disk read and our cache insert,
        # the generation moves and we discard what we inserted — the
        # invalidation hook alone cannot catch bytes cached after it ran.
        gen0 = self.store.shard_generation(p)
        from_cache = False
        raw = self.cache.get(p) if self.cache is not None else None
        if raw is not None:
            from_cache = True
        else:
            raw = self.store.shard_bytes(p, self.fmt)
            if self.cache is not None:
                self.cache.put(p, raw)
                if self.store.shard_generation(p) != gen0:
                    self.cache.invalidate(p)  # raced with an overwrite
        with trace.span("shard.decode", shard=p, fmt=self.fmt):
            if self.fmt == "csr":
                csr, ell = self.store.decode_csr(p, raw), None
            else:
                csr, ell = None, self.store.decode_ell(p, raw)
        if self.resident is not None:
            self.resident[p] = (csr, ell)
            if self.store.shard_generation(p) != gen0:
                self.resident.pop(p, None)  # same race, decoded form
        return LoadedShard(p, csr, ell, load_s=time.perf_counter() - t0,
                           from_cache=from_cache)

    def load(self, p: int) -> LoadedShard:
        """Synchronous single-shard load (the depth=0 path, also public)."""
        ls = self._load(p)
        ls.wait_s = ls.load_s  # nothing hidden: full latency is exposed
        return ls

    # ---------------------------------------------------------------- walk
    def iter_shards(
        self,
        shard_ids: Sequence[int],
        stats: Optional[PipelineStats] = None,
    ) -> Iterator[LoadedShard]:
        """Yield decoded shards in plan order, prefetching ``depth`` ahead."""
        if self.depth == 0:
            for p in shard_ids:
                ls = self.load(p)
                self._account(ls, stats)
                yield ls
            return

        pool = self._ensure_pool()
        shard_ids = list(shard_ids)
        pending: Dict[int, Future] = {}
        next_submit = 0

        def top_up():
            nonlocal next_submit
            while (
                next_submit < len(shard_ids)
                and len(pending) < self.depth
            ):
                p = shard_ids[next_submit]
                pending[next_submit] = pool.submit(self._load, p)
                next_submit += 1

        try:
            top_up()
            for i in range(len(shard_ids)):
                fut = pending.pop(i)
                t0 = time.perf_counter()
                with trace.span("shard.wait", shard=shard_ids[i]):
                    # Re-raises loader failures on the consumer as
                    # ShardLoadError(shard_id) with the cause chained.
                    ls = fut.result()
                ls.wait_s = time.perf_counter() - t0
                top_up()  # keep the window full while we still hold the shard
                self._account(ls, stats)
                yield ls
        finally:
            # Abnormal exit (a ShardLoadError above, the consumer closing
            # the generator after its own failure, GC of an abandoned
            # iterator): DRAIN the prefetch window.  In-flight futures are
            # cancelled if still queued and awaited if running, so the next
            # sweep on this pipeline starts with idle prefetch threads and
            # no stale loads completing mid-way through it.
            if pending:
                for fut in pending.values():
                    fut.cancel()
                for fut in pending.values():
                    if not fut.cancelled():
                        try:
                            fut.result()
                        except BaseException:
                            pass  # the primary failure already surfaced
                pending.clear()

    @staticmethod
    def _account(ls: LoadedShard, stats: Optional[PipelineStats]) -> None:
        if stats is None:
            return
        stats.shards_loaded += 1
        stats.load_total_s += ls.load_s
        stats.wait_s += ls.wait_s
        stats.cache_hits += int(ls.from_cache)
        stats.resident_hits += int(ls.from_resident)
