"""On-disk shard storage with byte-accurate I/O accounting.

The paper's performance argument is an I/O argument (Table II): VSW reads
``θ·D·|E|`` bytes per iteration and writes nothing.  To reproduce that claim
honestly the engines must do *real* reads and writes through one accounted
channel.  :class:`ShardStore` persists shards as uncompressed ``.npz``
containers and counts every byte that crosses the disk boundary; the
baseline engines (PSW/ESG/DSW) use the same store so measured I/O volumes
are directly comparable to Table II.

The "slow tier" here is the container filesystem — the TPU-adaptation
analogue of the paper's HDD (DESIGN.md §2).
"""

from __future__ import annotations

import dataclasses
import concurrent.futures
import io
import json
import os
import threading
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..obs import trace
from .csr import EllShard, csr_to_ell
from .sharding import GraphMeta, ShardCSR

__all__ = ["IOStats", "ShardStore"]

#: npz container keys of one delta run file (repro.delta): destination-
#: sorted ``(dst<<32|src)`` insert keys plus unique tombstone keys.
DELTA_RUN_PREFIX = "delta_run_"
DELTA_MANIFEST = "delta_manifest.json"
#: per-publish metadata journal (repro.delta.recovery): ABSOLUTE post-
#: publish degree rows + edge count, written before the manifest commit so
#: recovery can replay the metadata of a committed publish idempotently.
DELTA_JOURNAL_PREFIX = "delta_journal_"
#: staging directory for recompaction's staged-rename swap: new base
#: containers land here first, the manifest flips, then each file is
#: renamed into place (recovery finishes or discards, DESIGN.md §12).
DELTA_STAGE_DIR = "delta_stage"


@dataclasses.dataclass
class IOStats:
    """Byte/operation counters for one storage channel."""

    bytes_read: int = 0
    bytes_written: int = 0
    reads: int = 0
    writes: int = 0

    def reset(self) -> None:
        self.bytes_read = self.bytes_written = self.reads = self.writes = 0

    def snapshot(self) -> "IOStats":
        return IOStats(self.bytes_read, self.bytes_written, self.reads, self.writes)

    def __sub__(self, other: "IOStats") -> "IOStats":
        return IOStats(
            self.bytes_read - other.bytes_read,
            self.bytes_written - other.bytes_written,
            self.reads - other.reads,
            self.writes - other.writes,
        )


def _save_npz_bytes(**arrays) -> bytes:
    buf = io.BytesIO()
    np.savez(buf, **arrays)
    return buf.getvalue()


def _load_npz_bytes(raw: bytes) -> Dict[str, np.ndarray]:
    with np.load(io.BytesIO(raw)) as z:
        return {k: z[k] for k in z.files}


class ShardStore:
    """Persist/load graph shards + metadata with I/O accounting.

    Layout (paper §II-B: edge shards + property file + vertex info file)::

        <root>/property.json        graph-level metadata
        <root>/vertexinfo.npz       in/out degree arrays
        <root>/shard_00042.npz      CSR (row/col/interval) + derived ELL arrays
        <root>/aux_<name>.npz       engine-specific extra data (baselines)
    """

    def __init__(self, root: str, *, emulate_bw: Optional[float] = None):
        """``emulate_bw``: optional bytes/s throttle.  The container's FS is
        RAM-cached NVMe-class; the paper's testbed is HDD RAID (~150 MB/s).
        Benchmarks reproducing the paper's disk-bound regime pass e.g.
        ``emulate_bw=150e6`` so reads/writes cost wall time proportional to
        bytes moved (documented in EXPERIMENTS.md §Benchmarks)."""
        self.root = root
        os.makedirs(root, exist_ok=True)
        self.io = IOStats()
        self.emulate_bw = emulate_bw
        # The prefetching loader issues reads from background threads;
        # counter updates must not tear (snapshot()/__sub__ deltas would
        # drift), so every IOStats mutation holds this lock.
        self._io_lock = threading.Lock()
        # Emulated disk is ONE shared channel: concurrent reads queue for
        # bandwidth rather than each sleeping independently (N loader
        # threads must not emulate N disks — pipelining hides latency
        # behind compute, it does not multiply channel bandwidth).
        self._throttle_lock = threading.Lock()
        self._channel_free_at = 0.0
        # Overwriting a shard that a live engine has cached (byte cache,
        # device-resident decode) must not leave stale decodes behind:
        # consumers register a hook and write_shard/ingest notify it with
        # the shard id whenever an EXISTING shard file is replaced/removed.
        # The generation counter closes the read->invalidate->put race: a
        # loader snapshots shard_generation() before reading and discards
        # its bytes if the generation moved by insertion time.
        self._invalidation_hooks: List[Callable[[int], None]] = []
        self._shard_gen: Dict[int, int] = {}
        self._gen_lock = threading.Lock()
        # Ingest-time warmup (PR 3 follow-on): the finalize step of
        # ``ingest`` already holds each shard's bytes and CSR arrays, so it
        # deposits per-shard unique-source arrays (Bloom filter inputs) and
        # optionally raw container bytes here.  Engine boot consumes them
        # instead of re-reading every shard (scheduler.build_filters).
        # In-memory only — a fresh process re-derives them lazily.
        self._warm_lock = threading.Lock()
        self._warm_sources: Dict[int, "np.ndarray"] = {}
        self._warm_raw: Dict[Tuple[int, str], bytes] = {}
        # Live-mutation state (repro.delta): a DeltaOverlay tracking pending
        # per-shard delta runs.  Attached lazily — on first EdgeLog use, or
        # at open time when delta run files / a manifest are found on disk
        # (a store carrying unabsorbed mutations must boot with them).
        self.delta = None
        self._ell_params: Optional[Dict[str, int]] = None
        if (
            os.path.exists(os.path.join(root, DELTA_MANIFEST))
            or os.path.isdir(os.path.join(root, DELTA_STAGE_DIR))
            or any(
                f.startswith((DELTA_RUN_PREFIX, DELTA_JOURNAL_PREFIX))
                for f in os.listdir(root)
            )
        ):
            self.ensure_delta()

    def ensure_delta(self):
        """Attach (or return) this store's :class:`~repro.delta.DeltaOverlay`,
        recovering any published delta runs already on disk."""
        if self.delta is None:
            from repro.delta.overlay import DeltaOverlay  # lazy: avoid cycle

            self.delta = DeltaOverlay(self)
        return self.delta

    # ------------------------------------------------------- ingest warmup
    def set_warm_sources(self, p: int, srcs) -> None:
        with self._warm_lock:
            self._warm_sources[p] = srcs

    def warm_sources(self, p: int):
        """Unique source ids of shard ``p`` if a producer left them warm."""
        with self._warm_lock:
            return self._warm_sources.get(p)

    def add_warm_raw(self, p: int, fmt: str, raw: bytes) -> None:
        with self._warm_lock:
            self._warm_raw[(p, fmt)] = raw

    def warm_raw(self, p: int, fmt: str) -> Optional[bytes]:
        with self._warm_lock:
            return self._warm_raw.get((p, fmt))

    def warm_raw_bytes_total(self) -> int:
        with self._warm_lock:
            return sum(len(b) for b in self._warm_raw.values())

    def _drop_warm(self, p: int) -> None:
        with self._warm_lock:
            self._warm_sources.pop(p, None)
            self._warm_raw.pop((p, "csr"), None)
            self._warm_raw.pop((p, "ell"), None)

    # ------------------------------------------------------------------ raw
    def _path(self, name: str) -> str:
        return os.path.join(self.root, name)

    def _throttle(self, nbytes: int) -> None:
        if self.emulate_bw:
            import time

            with self._throttle_lock:
                now = time.monotonic()
                start = max(now, self._channel_free_at)
                self._channel_free_at = start + nbytes / self.emulate_bw
                wait = self._channel_free_at - now
            if wait > 0:
                time.sleep(wait)

    def read_bytes(self, name: str) -> bytes:
        with trace.span("store.read", key=name) as sp:
            with open(self._path(name), "rb") as f:
                raw = f.read()
            sp.set(bytes=len(raw))
            with self._io_lock:
                self.io.bytes_read += len(raw)
                self.io.reads += 1
            self._throttle(len(raw))
        return raw

    def write_bytes(self, name: str, raw: bytes) -> None:
        with trace.span("store.write", key=name, bytes=len(raw)):
            tmp = self._path(name) + ".tmp"
            with open(tmp, "wb") as f:
                f.write(raw)
            os.replace(tmp, self._path(name))  # atomic: no torn shard files
            with self._io_lock:
                self.io.bytes_written += len(raw)
                self.io.writes += 1
            self._throttle(len(raw))

    def exists(self, name: str) -> bool:
        return os.path.exists(self._path(name))

    # ------------------------------------------------------- invalidation
    def register_invalidation(self, hook: Callable[[int], None]) -> None:
        """Call ``hook(shard_id)`` whenever an existing shard is replaced
        (re-ingest / overwrite) or removed, so cached raw bytes and
        decoded/device-resident copies can be dropped."""
        self._invalidation_hooks.append(hook)

    def unregister_invalidation(self, hook: Callable[[int], None]) -> None:
        try:
            self._invalidation_hooks.remove(hook)
        except ValueError:
            pass

    def shard_generation(self, p: int) -> int:
        """Monotone per-shard counter, bumped on every overwrite/removal.
        Loaders snapshot it before a read and compare after inserting into
        a cache: a moved generation means the bytes may be stale."""
        with self._gen_lock:
            return self._shard_gen.get(p, 0)

    def invalidate_shard(self, p: int, *, drop_warm: bool = True) -> None:
        """Bump the shard's generation and fire the hooks.  ``drop_warm=False``
        is the delta-publish case: base bytes are unchanged (warm base-source
        arrays stay valid) but decoded/cached copies are stale."""
        if drop_warm:
            self._drop_warm(p)  # producers re-deposit after a rewrite
        with self._gen_lock:
            self._shard_gen[p] = self._shard_gen.get(p, 0) + 1
        for hook in list(self._invalidation_hooks):
            hook(p)

    def file_size(self, name: str) -> int:
        return os.path.getsize(self._path(name))

    # ------------------------------------------------------------- metadata
    def write_meta(
        self, meta: GraphMeta, *, ell_params: Optional[Dict[str, int]] = None
    ) -> None:
        prop = {
            "num_vertices": meta.num_vertices,
            "num_edges": meta.num_edges,
            "num_shards": meta.num_shards,
            "intervals": meta.intervals.tolist(),
        }
        if ell_params is None:
            if self._ell_params is None and self.exists("property.json"):
                # fresh process rewriting the metadata of an existing store
                # (e.g. a delta publish): carry the persisted block forward
                # instead of silently dropping it
                old = json.loads(self.read_bytes("property.json"))
                if "ell" in old:
                    self._ell_params = {
                        k: int(v) for k, v in old["ell"].items()
                    }
            ell_params = self._ell_params
        if ell_params is not None:
            # Persisted so the delta overlay can rebuild the device (ELL)
            # format of a mutated shard without reading the base ELL file.
            prop["ell"] = {k: int(ell_params[k]) for k in ("window", "k", "tr")}
            self._ell_params = prop["ell"]
        self.write_bytes("property.json", json.dumps(prop).encode())
        self.write_bytes(
            "vertexinfo.npz",
            _save_npz_bytes(in_deg=meta.in_deg, out_deg=meta.out_deg),
        )

    def ell_params(self) -> Dict[str, int]:
        """The (window, k, tr) every shard of this store was encoded with.

        Prefers the ``ell`` block of ``property.json``; legacy stores fall
        back to one read of shard 0's ELL container header.
        """
        if self._ell_params is None:
            if self.exists("property.json"):
                prop = json.loads(self.read_bytes("property.json"))
                if "ell" in prop:
                    self._ell_params = {
                        k: int(v) for k, v in prop["ell"].items()
                    }
            if self._ell_params is None:
                ell = self.decode_ell(0, self.shard_bytes(0, "ell"))
                self._ell_params = {
                    "window": ell.window, "k": ell.k, "tr": ell.tr
                }
        return self._ell_params

    def read_meta(self) -> GraphMeta:
        prop = json.loads(self.read_bytes("property.json"))
        vi = _load_npz_bytes(self.read_bytes("vertexinfo.npz"))
        return GraphMeta(
            num_vertices=prop["num_vertices"],
            num_edges=prop["num_edges"],
            num_shards=prop["num_shards"],
            intervals=np.asarray(prop["intervals"], dtype=np.int64),
            in_deg=vi["in_deg"],
            out_deg=vi["out_deg"],
        )

    # --------------------------------------------------------------- shards
    #
    # CSR (the paper's disk format) and ELL (the TPU device format) live in
    # SEPARATE files so an engine reads only the representation its backend
    # consumes — per-iteration disk traffic stays at the Table II D|E| term
    # instead of paying for both formats.  ELL validity masks are bit-packed
    # on disk (8x smaller); unpacking is host decode cost, like decompression.

    @staticmethod
    def shard_name(p: int, fmt: str = "csr") -> str:
        return f"shard_{p:05d}.{fmt}.npz"

    def encode_shard(
        self,
        shard: ShardCSR,
        *,
        num_vertices: int,
        window: int,
        k: int,
        tr: int,
    ) -> Tuple[bytes, bytes, EllShard]:
        """Encode one shard's CSR + derived ELL container bytes without
        touching disk — shared by :meth:`write_shard` and recompaction's
        staged-rename swap (which writes to the staging dir itself)."""
        ell = csr_to_ell(shard, num_vertices, window=window, k=k, tr=tr)
        csr_raw = _save_npz_bytes(
            interval=np.array([shard.v0, shard.v1], dtype=np.int64),
            row=shard.row,
            col=shard.col,
        )
        ell_raw = _save_npz_bytes(
            interval=np.array([shard.v0, shard.v1], dtype=np.int64),
            ell_idx=ell.ell_idx,
            mask_bits=np.packbits(ell.ell_mask, axis=None),
            seg=ell.seg,
            tile_window=ell.tile_window,
            ell_meta=np.array(
                [num_vertices, window, k, tr, ell.nnz, ell.n_ell], dtype=np.int64
            ),
        )
        return csr_raw, ell_raw, ell

    def write_shard(
        self,
        shard: ShardCSR,
        *,
        num_vertices: int,
        window: int,
        k: int,
        tr: int,
        capture: Optional[Dict[Tuple[int, str], bytes]] = None,
    ) -> EllShard:
        """Persist CSR + derived device (ELL) format; returns the EllShard.

        Overwriting an existing shard id bumps the shard's generation and
        notifies every registered invalidation hook AFTER the new bytes
        land.  A loader concurrently holding pre-replacement bytes cannot
        re-cache them either: it snapshots ``shard_generation`` before its
        read and discards the insert when the generation has moved
        (``ShardPipeline._load``).
        """
        overwrite = self.exists(self.shard_name(shard.shard_id, "csr")) or self.exists(
            self.shard_name(shard.shard_id, "ell")
        )
        csr_raw, ell_raw, ell = self.encode_shard(
            shard, num_vertices=num_vertices, window=window, k=k, tr=tr
        )
        self.write_bytes(self.shard_name(shard.shard_id, "csr"), csr_raw)
        self.write_bytes(self.shard_name(shard.shard_id, "ell"), ell_raw)
        if capture is not None:
            # Ingest-time cache warmup: hand the already-encoded container
            # bytes back to the caller so they can seed a cache without a
            # read-back through the accounted channel.
            capture[(shard.shard_id, "csr")] = csr_raw
            capture[(shard.shard_id, "ell")] = ell_raw
        if self._ell_params is None:
            self._ell_params = {"window": window, "k": k, "tr": tr}
        if overwrite:
            self.invalidate_shard(shard.shard_id)
        return ell

    def shard_bytes(self, p: int, fmt: str = "csr") -> bytes:
        """Read the raw (uncompressed) shard container from disk."""
        return self.read_bytes(self.shard_name(p, fmt))

    def shard_bytes_bulk(
        self,
        ps: Sequence[int],
        fmt: str = "csr",
        *,
        max_workers: int = 0,
    ) -> Dict[int, bytes]:
        """Read several shard containers in one call.

        ``max_workers > 1`` issues the reads concurrently — on a spinning
        HDD this lets the OS elevator sort the requests; on the accounted
        throttled channel the per-read sleeps overlap, which is exactly what
        N real loader threads would achieve (paper §II-C's dedicated load
        threads).  I/O accounting is identical either way.
        """
        ps = list(ps)
        if max_workers > 1 and len(ps) > 1:
            with concurrent.futures.ThreadPoolExecutor(
                max_workers=min(max_workers, len(ps))
            ) as pool:
                raws = list(pool.map(lambda p: self.shard_bytes(p, fmt), ps))
            return dict(zip(ps, raws))
        return {p: self.shard_bytes(p, fmt) for p in ps}

    def read_bytes_async(
        self, name: str, pool: concurrent.futures.Executor
    ) -> "concurrent.futures.Future[bytes]":
        """Schedule an accounted read on ``pool``; the future resolves to
        the raw bytes.  For callers that want raw-byte prefetch without the
        shard pipeline's cache/decode stages (which submits whole
        load-and-decode jobs to its own pool instead)."""
        return pool.submit(self.read_bytes, name)

    @staticmethod
    def decode_csr(p: int, raw: bytes) -> ShardCSR:
        z = _load_npz_bytes(raw)
        v0, v1 = (int(x) for x in z["interval"])
        return ShardCSR(shard_id=p, v0=v0, v1=v1, row=z["row"], col=z["col"])

    @staticmethod
    def decode_ell(p: int, raw: bytes) -> EllShard:
        z = _load_npz_bytes(raw)
        v0, v1 = (int(x) for x in z["interval"])
        nv, window, k, tr, nnz, n_ell = (int(x) for x in z["ell_meta"])
        mask = np.unpackbits(z["mask_bits"], count=n_ell * k).astype(bool)
        return EllShard(
            shard_id=p, v0=v0, v1=v1, num_vertices=nv, window=window, k=k, tr=tr,
            ell_idx=z["ell_idx"], ell_mask=mask.reshape(n_ell, k), seg=z["seg"],
            tile_window=z["tile_window"], nnz=nnz,
        )

    def load_shard(self, p: int, fmt: str = "csr", *, pin: Optional[int] = None):
        """Load ONE LOGICAL shard: base container plus any pending delta
        runs merged in (repro.delta).  ``pin`` selects the delta snapshot
        (publish sequence) to decode at; ``None`` means the latest published
        state.  Without an attached overlay (or with none pending for this
        shard) this is a plain base read+decode.
        """
        if self.delta is not None and self.delta.has_pending(p, pin):
            return self.delta.load_logical(p, fmt, pin=pin)[0]
        raw = self.shard_bytes(p, fmt)
        if fmt == "csr":
            return self.decode_csr(p, raw)
        return self.decode_ell(p, raw)

    def load_shards(self, ps: Sequence[int], fmt: str = "csr", *,
                    max_workers: int = 0) -> Dict[int, object]:
        """Bulk read + decode convenience (all raws resident at once —
        callers that need streaming should chunk their own
        :meth:`shard_bytes_bulk` calls instead)."""
        pin = self.delta.version if self.delta is not None else None
        dirty = [
            p for p in ps
            if self.delta is not None and self.delta.has_pending(p, pin)
        ]
        clean = [p for p in ps if p not in set(dirty)]
        out = {p: self.load_shard(p, fmt, pin=pin) for p in dirty}
        raws = self.shard_bytes_bulk(clean, fmt, max_workers=max_workers)
        decode = self.decode_csr if fmt == "csr" else self.decode_ell
        out.update({p: decode(p, raw) for p, raw in raws.items()})
        return out

    # ------------------------------------------------------------ ingestion
    def ingest(
        self,
        path: str,
        *,
        edges_per_shard: Optional[int] = None,
        num_shards: Optional[int] = None,
        num_vertices: Optional[int] = None,
        chunk_edges: int = 1 << 20,
        mem_budget_bytes: int = 64 << 20,
        window: int = 1 << 14,
        k: int = 128,
        tr: int = 8,
        fmt: Optional[str] = None,
        finalize_workers: int = 1,
        warm_sources: bool = True,
        warm_bytes: int = 0,
    ) -> Tuple["GraphMeta", "object"]:
        """Stream an on-disk edge file into this store — the out-of-core
        counterpart of ``preprocess`` + ``write_meta``/``write_shard``.

        Two-pass external build (``repro.core.ingest``): pass 1 streams
        ``chunk_edges``-sized chunks to accumulate degrees and compute
        intervals; pass 2 scatters edges into per-shard sorted spill runs
        (flushed whenever ``mem_budget_bytes`` of keys are buffered) and
        k-way merges each shard's runs into the final destination-sorted
        CSR + ELL containers.  Peak memory is O(chunk + one shard); the
        result is bitwise-identical to the in-memory path.  Returns
        ``(GraphMeta, IngestStats)``.
        """
        from .ingest import ingest_edge_file  # local: avoids import cycle

        return ingest_edge_file(
            self,
            path,
            edges_per_shard=edges_per_shard,
            num_shards=num_shards,
            num_vertices=num_vertices,
            chunk_edges=chunk_edges,
            mem_budget_bytes=mem_budget_bytes,
            window=window,
            k=k,
            tr=tr,
            fmt=fmt,
            finalize_workers=finalize_workers,
            warm_sources=warm_sources,
            warm_bytes=warm_bytes,
        )

    # ------------------------------------------------------ auxiliary blobs
    def write_aux(self, name: str, **arrays) -> None:
        self.write_bytes(f"aux_{name}.npz", _save_npz_bytes(**arrays))

    def read_aux(self, name: str) -> Dict[str, np.ndarray]:
        return _load_npz_bytes(self.read_bytes(f"aux_{name}.npz"))

    def aux_exists(self, name: str) -> bool:
        return self.exists(f"aux_{name}.npz")
