"""Graph sharding and preprocessing (GraphMP paper §II-B).

The input graph's vertices are divided into ``P`` disjoint intervals.  Each
interval is associated with a *shard* holding every edge whose destination
vertex lies in that interval, grouped by destination and stored in CSR
(row-offset + column-index) form.  Intervals are chosen so that

1. any shard can be completely loaded into (V)MEM, and
2. the number of edges per shard is balanced.

The paper's four preprocessing steps map 1:1 onto :func:`preprocess`:

1. scan the graph, record in/out degrees            -> ``Graph.in_degrees`` etc.
2. compute vertex intervals (balance + size cap)    -> :func:`compute_intervals`
3. append each edge to a shard by destination       -> :func:`build_shards`
4. transform shards to CSR, persist metadata        -> :class:`ShardCSR`, stores

On top of the paper's CSR we also derive the TPU device format (blocked-ELL
with source windows, see ``csr.py``) during preprocessing, so the runtime
engine never touches raw edge lists.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from .graph import Graph

__all__ = [
    "ShardCSR",
    "GraphMeta",
    "compute_intervals",
    "build_shards",
    "preprocess",
]


@dataclasses.dataclass
class ShardCSR:
    """One destination-interval shard in CSR form.

    ``row`` has ``(v1 - v0) + 1`` entries; the incoming adjacency list of
    vertex ``v`` (``v0 <= v < v1``) is ``col[row[v - v0] : row[v - v0 + 1]]``
    — exactly the paper's ``Γ_in(v)`` access equation.
    """

    shard_id: int
    v0: int  # interval start (inclusive)
    v1: int  # interval end (exclusive)
    row: np.ndarray  # int64 [rows + 1]
    col: np.ndarray  # int32 [nnz] source vertex ids, grouped by destination

    @property
    def rows(self) -> int:
        return self.v1 - self.v0

    @property
    def nnz(self) -> int:
        return int(self.col.shape[0])

    @property
    def nbytes(self) -> int:
        return int(self.row.nbytes + self.col.nbytes)

    def in_neighbors(self, v: int) -> np.ndarray:
        """Γ_in(v) = col[row[v - v0] : row[v - v0 + 1]]."""
        if not (self.v0 <= v < self.v1):
            raise IndexError(f"vertex {v} outside interval [{self.v0}, {self.v1})")
        lo = int(self.row[v - self.v0])
        hi = int(self.row[v - self.v0 + 1])
        return self.col[lo:hi]

    def unique_sources(self) -> np.ndarray:
        return np.unique(self.col)


@dataclasses.dataclass
class GraphMeta:
    """The paper's 'property file' + 'vertex information file' contents."""

    num_vertices: int
    num_edges: int
    num_shards: int
    intervals: np.ndarray  # int64 [num_shards + 1], interval p = [iv[p], iv[p+1])
    in_deg: np.ndarray  # int64 [num_vertices]
    out_deg: np.ndarray  # int64 [num_vertices]

    def interval_of(self, p: int) -> tuple:
        return int(self.intervals[p]), int(self.intervals[p + 1])

    def shard_of_vertex(self, v: int) -> int:
        return int(np.searchsorted(self.intervals, v, side="right") - 1)


def compute_intervals(
    in_deg: np.ndarray,
    *,
    num_shards: Optional[int] = None,
    edges_per_shard: Optional[int] = None,
) -> np.ndarray:
    """Choose interval boundaries so each shard holds ~equal numbers of edges.

    Exactly one of ``num_shards`` / ``edges_per_shard`` must be given (the
    paper targets 18-22M edges so one shard ~= 80MB; tests use far smaller
    targets).  A single vertex whose in-degree exceeds the target still gets
    its own interval — shards may exceed the target by at most one vertex's
    in-degree, as in GraphChi-style sharding.
    """
    num_vertices = int(in_deg.shape[0])
    num_edges = int(in_deg.sum())
    if (num_shards is None) == (edges_per_shard is None):
        raise ValueError("specify exactly one of num_shards / edges_per_shard")
    if num_shards is None:
        num_shards = max(1, int(np.ceil(num_edges / max(edges_per_shard, 1))))
    num_shards = min(num_shards, max(num_vertices, 1))

    if num_shards == 1 or num_edges == 0:
        # Degenerate: everything in one shard (still balanced vacuously).
        bounds = np.linspace(0, num_vertices, num_shards + 1).astype(np.int64)
        bounds[0], bounds[-1] = 0, num_vertices
        return np.unique(bounds) if len(np.unique(bounds)) == num_shards + 1 else np.array(
            [0, num_vertices], dtype=np.int64
        )

    target = num_edges / num_shards
    cum = np.cumsum(in_deg, dtype=np.int64)
    # boundary p = first vertex where cumulative edges >= p * target
    marks = (np.arange(1, num_shards, dtype=np.float64) * target).astype(np.int64)
    cuts = np.searchsorted(cum, marks, side="left") + 1
    bounds = np.concatenate([[0], cuts, [num_vertices]]).astype(np.int64)
    bounds = np.maximum.accumulate(bounds)  # monotone
    bounds = np.unique(bounds)
    if bounds[0] != 0:
        bounds = np.concatenate([[0], bounds])
    if bounds[-1] != num_vertices:
        bounds = np.concatenate([bounds, [num_vertices]])
    return bounds.astype(np.int64)


def build_shards(graph: Graph, intervals: np.ndarray) -> List[ShardCSR]:
    """Steps 3+4: route edges to shards by destination, emit CSR per shard.

    Edges inside a shard are grouped by destination (GraphMP groups by
    destination, unlike GraphChi's source order) with sources sorted within
    each destination for determinism.
    """
    order = np.lexsort((graph.src, graph.dst))
    dst_sorted = graph.dst[order]
    src_sorted = graph.src[order]
    num_shards = len(intervals) - 1

    # Per-vertex incoming counts -> global row offsets.
    in_deg = np.bincount(graph.dst, minlength=graph.num_vertices).astype(np.int64)
    global_row = np.concatenate([[0], np.cumsum(in_deg)])

    shards: List[ShardCSR] = []
    for p in range(num_shards):
        v0, v1 = int(intervals[p]), int(intervals[p + 1])
        lo, hi = int(global_row[v0]), int(global_row[v1])
        row = (global_row[v0 : v1 + 1] - global_row[v0]).astype(np.int64)
        col = src_sorted[lo:hi].astype(np.int32)
        # dst_sorted[lo:hi] is guaranteed to lie in [v0, v1) by construction.
        assert hi == lo or (dst_sorted[lo] >= v0 and dst_sorted[hi - 1] < v1)
        shards.append(ShardCSR(shard_id=p, v0=v0, v1=v1, row=row, col=col))
    return shards


def preprocess(
    graph: Graph,
    *,
    num_shards: Optional[int] = None,
    edges_per_shard: Optional[int] = None,
) -> tuple:
    """Full preprocessing: returns ``(GraphMeta, [ShardCSR])``."""
    graph.validate()
    in_deg = graph.in_degrees()
    out_deg = graph.out_degrees()
    intervals = compute_intervals(
        in_deg, num_shards=num_shards, edges_per_shard=edges_per_shard
    )
    shards = build_shards(graph, intervals)
    meta = GraphMeta(
        num_vertices=graph.num_vertices,
        num_edges=graph.num_edges,
        num_shards=len(shards),
        intervals=intervals,
        in_deg=in_deg,
        out_deg=out_deg,
    )
    # Invariants the rest of the system relies on.
    assert sum(s.nnz for s in shards) == graph.num_edges
    assert all(shards[p].v1 == shards[p + 1].v0 for p in range(len(shards) - 1))
    return meta, shards
