"""Compressed edge cache (paper §II-D-2).

The VSW model leaves most of a server's memory idle (vertices + the shards
under processing are small), so GraphMP fills it with an in-application
shard cache.  A cache hit skips the disk read entirely; to raise the hit
rate the cached bytes may be compressed, trading decompression CPU for
eliminated I/O.  The paper's four modes:

=======  ==================  =============================================
mode     paper codec         this implementation (snappy is unavailable
                             offline; zlib-1 plays its fast/low-ratio role)
=======  ==================  =============================================
mode-1   uncompressed        raw shard bytes
mode-2   snappy              zlib level 1
mode-3   zlib-1              zlib level 3
mode-4   zlib-3              zlib level 6
=======  ==================  =============================================

Eviction is LRU under a byte budget.  The cache stores the *container
bytes* (what would have been read from disk), so hit/miss accounting lines
up exactly with the I/O model's ``θ·D·|E|`` term: ``θ`` is literally
``misses / lookups`` weighted by shard size.
"""

from __future__ import annotations

import dataclasses
import threading
import time
import zlib
from collections import OrderedDict
from typing import Callable, Dict, Optional

from ..obs import trace

__all__ = ["CacheMode", "CacheStats", "ShardCache", "MODES",
           "mode_iteration_cost", "select_cache_mode"]


@dataclasses.dataclass(frozen=True)
class CacheMode:
    name: str
    compress: Callable[[bytes], bytes]
    decompress: Callable[[bytes], bytes]


MODES: Dict[int, CacheMode] = {
    1: CacheMode("raw", lambda b: b, lambda b: b),
    2: CacheMode("fast(zlib-1)", lambda b: zlib.compress(b, 1), zlib.decompress),
    3: CacheMode("zlib-3", lambda b: zlib.compress(b, 3), zlib.decompress),
    4: CacheMode("zlib-6", lambda b: zlib.compress(b, 6), zlib.decompress),
}


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    inserted_bytes_raw: int = 0
    inserted_bytes_stored: int = 0
    compress_time_s: float = 0.0
    decompress_time_s: float = 0.0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    @property
    def compression_ratio(self) -> float:
        if self.inserted_bytes_stored == 0:
            return 1.0
        return self.inserted_bytes_raw / self.inserted_bytes_stored

    def reset_counters(self) -> None:
        """Zero every running counter (hit/miss, evictions, codec timers) —
        capacity-state fields (`inserted_bytes_*`) describe what is IN the
        cache and are deliberately kept."""
        self.hits = self.misses = self.evictions = 0
        self.compress_time_s = self.decompress_time_s = 0.0


class ShardCache:
    """LRU cache of (optionally compressed) shard container bytes.

    Thread-safe: the prefetching loader (``repro.core.pipeline``) calls
    ``get``/``put`` from background threads, so the LRU book-keeping and the
    stats counters are guarded by one lock.  Compression/decompression run
    outside the lock — they are the expensive part and operate on local data.
    """

    def __init__(self, capacity_bytes: int, mode: int = 1):
        if mode not in MODES:
            raise ValueError(f"unknown cache mode {mode}; valid: {sorted(MODES)}")
        self.capacity_bytes = capacity_bytes
        self.mode = MODES[mode]
        self.mode_id = mode
        self.stats = CacheStats()
        self._data: "OrderedDict[int, bytes]" = OrderedDict()
        self._bytes = 0
        self._lock = threading.RLock()

    def __len__(self) -> int:
        with self._lock:
            return len(self._data)

    def keys(self):
        """Snapshot of cached shard ids in LRU -> MRU order (the warm set a
        restart checkpoint records, ``repro.checkpoint.warm_state``)."""
        with self._lock:
            return list(self._data.keys())

    @property
    def stored_bytes(self) -> int:
        with self._lock:
            return self._bytes

    def get(self, shard_id: int) -> Optional[bytes]:
        """Return the *raw* (decompressed) shard bytes, or None on miss."""
        with trace.span("cache.get", shard=shard_id) as sp:
            with self._lock:
                blob = self._data.get(shard_id)
                if blob is None:
                    self.stats.misses += 1
                    sp.set(hit=False)
                    return None
                self._data.move_to_end(shard_id)
                self.stats.hits += 1
            sp.set(hit=True)
            t0 = time.perf_counter()
            raw = self.mode.decompress(blob)
            with self._lock:
                self.stats.decompress_time_s += time.perf_counter() - t0
            return raw

    def put(self, shard_id: int, raw: bytes) -> bool:
        """Insert if it fits; returns True if cached."""
        with trace.span("cache.put", shard=shard_id, bytes=len(raw)):
            return self._put(shard_id, raw)

    def _put(self, shard_id: int, raw: bytes) -> bool:
        with self._lock:
            if shard_id in self._data:
                # Re-put counts as a touch: refresh recency or the entry
                # ages as if never used and gets evicted first.
                self._data.move_to_end(shard_id)
                return True
        t0 = time.perf_counter()
        blob = self.mode.compress(raw)
        dt = time.perf_counter() - t0
        with self._lock:
            self.stats.compress_time_s += dt
            if len(blob) > self.capacity_bytes:
                return False
            if shard_id in self._data:  # raced with another loader thread
                self._data.move_to_end(shard_id)
                return True
            while self._bytes + len(blob) > self.capacity_bytes and self._data:
                _, old = self._data.popitem(last=False)
                self._bytes -= len(old)
                self.stats.evictions += 1
            self._data[shard_id] = blob
            self._bytes += len(blob)
            self.stats.inserted_bytes_raw += len(raw)
            self.stats.inserted_bytes_stored += len(blob)
            return True

    def invalidate(self, shard_id: int) -> bool:
        """Drop one entry (the shard was overwritten on disk); returns
        whether anything was cached.  Not counted as an eviction — the
        entry did not lose a capacity race, it became wrong."""
        with self._lock:
            blob = self._data.pop(shard_id, None)
            if blob is None:
                return False
            self._bytes -= len(blob)
            return True

    def clear(self) -> None:
        with self._lock:
            self._data.clear()
            self._bytes = 0


def mode_iteration_cost(
    ratio: float,
    comp_s_per_byte: float,
    dec_s_per_byte: float,
    capacity_bytes: int,
    total_raw_bytes: int,
    *,
    disk_bw: float = 150e6,
    lifetime_iters: int = 10,
) -> float:
    """Estimated per-iteration cost of caching under one compression mode.

    Three terms: (1) disk time for the bytes that still miss, (2)
    decompression of the cached fraction on every iteration, (3) the
    ONE-TIME compression of the cached fraction, amortized over the
    cache's expected lifetime of ``lifetime_iters`` iterations — entries
    are compressed once at insert and then hit repeatedly, so charging the
    full compression time per iteration would overstate it by the
    lifetime, and dropping it (the pre-fix behavior) understates slow
    codecs whose compression cost is real.
    """
    stored_total = total_raw_bytes / max(ratio, 1e-12)
    cached_frac = min(1.0, capacity_bytes / max(stored_total, 1))
    miss_bytes = (1.0 - cached_frac) * total_raw_bytes
    cached_raw = cached_frac * total_raw_bytes
    return (
        miss_bytes / disk_bw
        + cached_raw * dec_s_per_byte
        + cached_raw * comp_s_per_byte / max(lifetime_iters, 1)
    )


def select_cache_mode(
    sample_raw: bytes,
    capacity_bytes: int,
    total_raw_bytes: int,
    *,
    disk_bw: float = 150e6,
    lifetime_iters: int = 10,
) -> int:
    """Pick the cheapest mode, GraphH-style (paper §II-D-2 pointer).

    Measures compression ratio and codec times on a sample shard, then
    chooses the mode minimising :func:`mode_iteration_cost`.  If mode-1
    already fits everything, compression is pure overhead and mode-1 wins
    by construction.
    """
    best_mode, best_cost = 1, float("inf")
    for mid, mode in MODES.items():
        t0 = time.perf_counter()
        blob = mode.compress(sample_raw)
        t_comp = time.perf_counter() - t0
        ratio = len(sample_raw) / max(len(blob), 1)
        t0 = time.perf_counter()
        mode.decompress(blob)
        t_dec = time.perf_counter() - t0
        per_byte = 1.0 / max(len(sample_raw), 1)
        cost = mode_iteration_cost(
            ratio, t_comp * per_byte, t_dec * per_byte,
            capacity_bytes, total_raw_bytes,
            disk_bw=disk_bw, lifetime_iters=lifetime_iters,
        )
        if cost < best_cost:
            best_mode, best_cost = mid, cost
    return best_mode
