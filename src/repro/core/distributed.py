"""Distributed VSW: the paper's engine scaled over a TPU mesh.

GraphMP is a single-machine system; its SEM contract ("all vertices resident
in fast memory, edges streamed") maps onto a pod as follows (DESIGN.md §5):

- ``SrcVertexArray`` / ``DstVertexArray`` are **sharded by vertex interval**
  over every device of the mesh (axes flattened) — each device owns
  ``|V| / n_dev`` destination vertices and all edge shards whose destination
  interval falls in its slice.  The paper's lock-free property survives
  verbatim: each destination vertex is updated by exactly one device.
- Per superstep, the per-source messages (``pre(src_vals)``) are computed
  shardwise (elementwise, no comm) and **all-gathered** so every device holds
  the full message array — the distributed analogue of "all vertices in
  memory".  For |V| = 1.1B (EU-2015) that is 4.4 GB fp32 per device: fits
  v5e HBM, and is THE collective-roofline term of the graph workload.
- Each device then runs the same windowed-ELL gather/combine as the
  single-device engine over its local edge tiles (Pallas kernel on TPU).
- The iteration-level activity count is a scalar ``psum``.

Device edge layout: every device gets equal-shaped (padded) ELL arrays so
the whole superstep jits as one SPMD program — required for the multi-pod
dry-run (``launch/dryrun.py --arch graphmp``).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional, Sequence, Tuple

import numpy as np

from ..obs import trace
from .apps import COMBINE_IDENTITY, VertexProgram
from .csr import EllShard, csr_to_ell
from .graph import Graph
from .sharding import preprocess

# jax is imported lazily inside the functions that trace/execute SPMD code:
# the host-side pieces (MeshPartition, the device-layout builders) are used
# by the numpy mesh-emulation path, which must stay importable without
# initialising XLA (run_memcapped runs it under RLIMIT_AS).

__all__ = [
    "DeviceGraph",
    "MeshPartition",
    "equal_device_bounds",
    "build_device_graph",
    "build_device_graph_from_store",
    "device_graph_specs",
    "make_superstep",
    "run_distributed",
]


@dataclasses.dataclass
class DeviceGraph:
    """Per-device-stacked ELL arrays + vertex metadata (all padded/equal)."""

    num_vertices: int  # padded to n_dev * rows_per_dev
    num_vertices_real: int
    rows_per_dev: int
    n_dev: int
    window: int
    k: int
    tr: int
    n_ell_per_dev: int
    ell_idx: np.ndarray  # [n_dev * n_ell_per_dev, K] int32 (global src ids)
    ell_valid: np.ndarray  # [n_dev * n_ell_per_dev, K] bool
    seg: np.ndarray  # [n_dev * n_ell_per_dev] int32 local dst row
    out_deg: np.ndarray  # [num_vertices] int32 (padded with 1)


def equal_device_bounds(num_vertices: int, n_dev: int):
    """THE device vertex layout: ``(rows_per_dev, nv_pad, bounds)``.

    Every mesh consumer — the legacy in-memory builder, the store-backed
    builder, and the engine's :class:`MeshPartition` — derives its
    destination-interval ownership from this one function, so the
    "each destination vertex is updated by exactly one device" contract
    cannot drift between the dry-run and the out-of-core paths.

    Bounds are clipped to the real vertex count; trailing devices own the
    (edge-free) padding rows implicitly via ``rows_per_dev``-sized segments.
    """
    if n_dev < 1:
        raise ValueError("n_dev must be >= 1")
    rows_per_dev = -(-num_vertices // n_dev)
    nv_pad = rows_per_dev * n_dev
    bounds = np.minimum(
        np.arange(n_dev + 1, dtype=np.int64) * rows_per_dev, num_vertices
    )
    return rows_per_dev, nv_pad, bounds


@dataclasses.dataclass(frozen=True)
class MeshPartition:
    """Shard -> device ownership for mesh sweeps over an existing store.

    The store's destination intervals are NOT re-cut: every store shard is
    owned by exactly ONE device (the one whose equal vertex slice contains
    the shard's interval start — intervals are far finer than device slices
    at any realistic shard count, and single-ownership is what lifts the
    paper's lock-free property to SPMD: device ``d`` alone writes the
    destination rows of the shards it owns).  The host therefore reads each
    shard once per sweep and routes it to one device slot — the
    "1 host read, D device slices" invariant (DESIGN.md §10).
    """

    n_dev: int
    num_shards: int
    owner: np.ndarray  # [num_shards] int32 owning device per shard

    @classmethod
    def from_meta(cls, meta, n_dev: int) -> "MeshPartition":
        """Own each shard by the equal device slice holding its interval
        start (:func:`equal_device_bounds` on ``meta.num_vertices``)."""
        rows_per_dev, _, _ = equal_device_bounds(meta.num_vertices, n_dev)
        starts = np.asarray(meta.intervals[:-1], dtype=np.int64)
        owner = np.minimum(starts // rows_per_dev, n_dev - 1).astype(np.int32)
        return cls(n_dev=n_dev, num_shards=int(meta.num_shards), owner=owner)

    def device_of(self, shard_id: int) -> int:
        return int(self.owner[shard_id])

    def group(self, shard_ids: Sequence[int]) -> List[List[int]]:
        """Split an ordered shard list into per-device ordered sublists.
        Devices whose shards were all pruned (or that own none) get an
        empty list — they idle through the SPMD dispatch."""
        out: List[List[int]] = [[] for _ in range(self.n_dev)]
        for p in shard_ids:
            out[int(self.owner[p])].append(p)
        return out

    @staticmethod
    def interleave(device_lists: Sequence[Sequence[int]]) -> List[int]:
        """Round-robin merge (d0[0], d1[0], ..., d0[1], ...) so a streaming
        consumer that buffers one shard per device fills every device's
        slot before dispatching an SPMD round."""
        out: List[int] = []
        longest = max((len(g) for g in device_lists), default=0)
        for i in range(longest):
            for g in device_lists:
                if i < len(g):
                    out.append(g[i])
        return out


def build_device_graph(
    graph: Graph,
    n_dev: int,
    *,
    window: int = 1 << 14,
    k: int = 128,
    tr: int = 8,
) -> DeviceGraph:
    """Partition a real graph into equal per-device ELL blocks."""
    rows_per_dev, nv_pad, bounds = equal_device_bounds(graph.num_vertices, n_dev)

    # Build one destination shard per device, then convert to ELL.
    meta, shards = preprocess_with_bounds(graph, bounds)
    return _device_graph_from_shards(
        shards, graph.num_vertices, rows_per_dev, nv_pad, n_dev,
        graph.out_degrees(), window=window, k=k, tr=tr,
    )


def _device_graph_from_shards(
    shards, num_vertices: int, rows_per_dev: int, nv_pad: int, n_dev: int,
    out_degrees: np.ndarray, *, window: int, k: int, tr: int,
) -> DeviceGraph:
    """Shared tail of both builders: per-device CSR shards -> stacked ELL."""
    ells = [csr_to_ell(s, nv_pad, window=window, k=k, tr=tr) for s in shards]
    n_ell_max = max(e.n_ell for e in ells)
    n_ell_pad = -(-n_ell_max // tr) * tr

    idx = np.zeros((n_dev, n_ell_pad, k), dtype=np.int32)
    valid = np.zeros((n_dev, n_ell_pad, k), dtype=bool)
    seg = np.zeros((n_dev, n_ell_pad), dtype=np.int32)
    for d, e in enumerate(ells):
        gi = e.global_idx().astype(np.int32)
        idx[d, : e.n_ell] = np.where(e.ell_mask, gi, 0)
        valid[d, : e.n_ell] = e.ell_mask
        seg[d, : e.n_ell] = e.seg

    out_deg = np.ones(nv_pad, dtype=np.int32)
    out_deg[:num_vertices] = out_degrees.astype(np.int32)

    return DeviceGraph(
        num_vertices=nv_pad,
        num_vertices_real=num_vertices,
        rows_per_dev=rows_per_dev,
        n_dev=n_dev,
        window=window,
        k=k,
        tr=tr,
        n_ell_per_dev=n_ell_pad,
        ell_idx=idx.reshape(n_dev * n_ell_pad, k),
        ell_valid=valid.reshape(n_dev * n_ell_pad, k),
        seg=seg.reshape(n_dev * n_ell_pad),
        out_deg=out_deg,
    )


def build_device_graph_from_store(
    store,
    n_dev: int,
    *,
    window: Optional[int] = None,
    k: Optional[int] = None,
    tr: Optional[int] = None,
) -> DeviceGraph:
    """Per-device ELL blocks straight from a :class:`ShardStore` — no
    ``Graph`` object, no full edge list in memory, ever (PR 3's contract).

    Store shards are decoded ONE at a time and their destination rows are
    re-cut along :func:`equal_device_bounds`; each store shard's row/col
    slices land in at most two adjacent device shards (intervals are
    ordered), and because every store shard keeps destinations grouped with
    sources sorted, the concatenated per-device CSR is bitwise the one
    :func:`build_device_graph` builds from the same edges.

    ELL parameters default to the store's own (``store.ell_params()``) so
    both representations of the graph share one window coordinate system.
    """
    from .sharding import ShardCSR

    with trace.span("mesh.build_device_graph", devices=n_dev):
        return _build_device_graph_from_store(
            store, n_dev, window=window, k=k, tr=tr, ShardCSR=ShardCSR
        )


def _build_device_graph_from_store(
    store,
    n_dev: int,
    *,
    window: Optional[int],
    k: Optional[int],
    tr: Optional[int],
    ShardCSR,
) -> DeviceGraph:
    meta = store.read_meta()
    if window is None or k is None or tr is None:
        ep = store.ell_params()
        window = ep["window"] if window is None else window
        k = ep["k"] if k is None else k
        tr = ep["tr"] if tr is None else tr
    rows_per_dev, nv_pad, bounds = equal_device_bounds(meta.num_vertices, n_dev)

    # Per-device CSR accumulators (row counts first, then columns).
    dev_counts = [
        np.zeros(int(bounds[d + 1] - bounds[d]), dtype=np.int64)
        for d in range(n_dev)
    ]
    dev_cols: List[List[np.ndarray]] = [[] for _ in range(n_dev)]
    for p in range(meta.num_shards):
        csr = store.load_shard(p, "csr")
        counts = np.diff(csr.row)
        # Destination rows of this store shard, split by device boundary.
        d_lo = int(np.searchsorted(bounds, csr.v0, side="right") - 1)
        d_hi = int(np.searchsorted(bounds, max(csr.v1 - 1, csr.v0), side="right") - 1)
        for d in range(d_lo, min(d_hi, n_dev - 1) + 1):
            lo = max(csr.v0, int(bounds[d]))
            hi = min(csr.v1, int(bounds[d + 1]))
            if hi <= lo:
                continue
            r0, r1 = lo - csr.v0, hi - csr.v0
            dev_counts[d][lo - int(bounds[d]): hi - int(bounds[d])] = counts[r0:r1]
            e0, e1 = int(csr.row[r0]), int(csr.row[r1])
            if e1 > e0:
                dev_cols[d].append(csr.col[e0:e1])

    shards = []
    for d in range(n_dev):
        row = np.zeros(len(dev_counts[d]) + 1, dtype=np.int64)
        np.cumsum(dev_counts[d], out=row[1:])
        col = (
            np.concatenate(dev_cols[d]).astype(np.int32)
            if dev_cols[d] else np.zeros(0, dtype=np.int32)
        )
        shards.append(
            ShardCSR(shard_id=d, v0=int(bounds[d]), v1=int(bounds[d + 1]),
                     row=row, col=col)
        )
    return _device_graph_from_shards(
        shards, meta.num_vertices, rows_per_dev, nv_pad, n_dev,
        meta.out_deg, window=window, k=k, tr=tr,
    )


def preprocess_with_bounds(graph: Graph, bounds: np.ndarray):
    """Preprocess with externally fixed interval bounds (equal vertex slices)."""
    from .sharding import GraphMeta, build_shards

    shards = build_shards(graph, bounds)
    meta = GraphMeta(
        num_vertices=graph.num_vertices,
        num_edges=graph.num_edges,
        num_shards=len(shards),
        intervals=bounds,
        in_deg=graph.in_degrees(),
        out_deg=graph.out_degrees(),
    )
    return meta, shards


def device_graph_specs(
    num_vertices: int,
    num_edges: int,
    n_dev: int,
    *,
    k: int = 128,
    tr: int = 8,
    pad_factor: float = 1.30,
    index_dtype=None,
    sentinel: bool = False,
) -> dict:
    """ShapeDtypeStruct stand-ins for a graph of the given size (dry-run).

    ``pad_factor`` models ELL padding waste (measured ~1.1-1.3 on RMAT).
    ``sentinel`` drops the validity plane (see make_superstep).
    """
    import jax
    import jax.numpy as jnp

    if index_dtype is None:
        index_dtype = jnp.int32
    rows_per_dev = -(-num_vertices // n_dev)
    nv_pad = rows_per_dev * n_dev
    edges_per_dev = -(-num_edges // n_dev)
    n_ell = int(-(-edges_per_dev * pad_factor // k))
    n_ell = max(-(-n_ell // tr) * tr, tr)
    S = jax.ShapeDtypeStruct
    out = dict(
        src_vals=S((nv_pad,), jnp.float32),
        ell_idx=S((n_dev * n_ell, k), index_dtype),
        ell_valid=S((n_dev * n_ell, k), jnp.bool_),
        seg=S((n_dev * n_ell,), jnp.int32),
        out_deg=S((nv_pad,), jnp.int32),
    )
    if sentinel:
        out.pop("ell_valid")
    return out


def _pre_apply_fns(program_name: str, num_vertices: int, damping: float = 0.85):
    """jnp versions of the paper's three applications (Alg. 2)."""
    import jax.numpy as jnp

    if program_name == "pagerank":
        pre = lambda v, od: v / jnp.maximum(od, 1).astype(v.dtype)
        apply = lambda acc, old: (1.0 - damping) / num_vertices + damping * acc
        combine = "sum"
    elif program_name in ("sssp", "bfs"):
        pre = lambda v, od: v + 1.0
        apply = lambda acc, old: jnp.minimum(acc, old)
        combine = "min"
    elif program_name == "wcc":
        pre = lambda v, od: v
        apply = lambda acc, old: jnp.minimum(acc, old)
        combine = "min"
    else:  # pragma: no cover
        raise ValueError(program_name)
    return pre, apply, combine


def make_superstep(
    mesh,
    program_name: str,
    num_vertices: int,
    rows_per_dev: int,
    *,
    damping: float = 0.85,
    use_pallas: bool = False,
    msg_dtype=None,
    sentinel: bool = False,
):
    """Build the jit'd SPMD superstep and its shardings.

    Returns ``(step_fn, in_shardings, out_shardings)`` where ``step_fn`` maps
    ``(src_vals, ell_idx, [ell_valid,] seg, out_deg) -> (new_vals, n_active)``.

    Perf variants (EXPERIMENTS.md §Perf, graphmp cell):
      msg_dtype=bf16  — halves the all-gathered SEM working set on the wire
                        (values re-cast to f32 before accumulation).
      sentinel=True   — no validity plane: padding slots carry an
                        out-of-range index and ``jnp.take(mode='fill')``
                        supplies the combine identity; cuts streamed edge
                        bytes by the whole bool plane.
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    if msg_dtype is None:
        msg_dtype = jnp.float32
    axes = tuple(mesh.axis_names)
    vspec = P(axes)  # vertex dim sharded over every mesh axis
    pre, apply_fn, combine = _pre_apply_fns(program_name, num_vertices, damping)
    ident = COMBINE_IDENTITY[combine]

    def _acc(msgs, idx, valid, seg):
        if sentinel:
            g = jnp.take(msgs, idx, axis=0, mode="fill",
                         fill_value=float(ident))  # static: combine identity
        else:
            g = jnp.take(msgs, idx, axis=0, mode="clip")
            g = jnp.where(valid, g, jnp.asarray(ident, g.dtype))
        g = g.astype(jnp.float32)
        if combine == "sum":
            part = g.sum(axis=1)
            return jax.ops.segment_sum(part, seg, num_segments=rows_per_dev)
        part = g.min(axis=1)
        return jax.ops.segment_min(part, seg, num_segments=rows_per_dev)

    def local_update(src_local, idx, valid, seg, out_deg_local):
        # pre(): elementwise on the local vertex slice (no comm).
        msgs_local = pre(src_local, out_deg_local).astype(msg_dtype)
        # SEM working set: every device needs the full message array.
        msgs = jax.lax.all_gather(msgs_local, axes, tiled=True)
        acc = _acc(msgs, idx, valid, seg)
        new_local = apply_fn(acc, src_local).astype(src_local.dtype)
        changed = (new_local != src_local).sum()
        n_active = jax.lax.psum(changed, axes)
        return new_local, n_active

    from jax.experimental.shard_map import shard_map

    if sentinel:
        fn = lambda s, i, g, o: local_update(s, i, None, g, o)
        n_in = 4
    else:
        fn = local_update
        n_in = 5
    step = shard_map(
        fn,
        mesh=mesh,
        in_specs=(vspec,) * n_in,
        out_specs=(vspec, P()),
        check_rep=False,
    )

    in_shardings = tuple(NamedSharding(mesh, s) for s in (vspec,) * n_in)
    out_shardings = (NamedSharding(mesh, vspec), NamedSharding(mesh, P()))
    step_jit = jax.jit(step, in_shardings=in_shardings, out_shardings=out_shardings)
    return step_jit, in_shardings, out_shardings


def run_distributed(
    graph: Graph,
    program: VertexProgram,
    mesh,
    *,
    max_iters: int = 100,
    window: int = 1 << 12,
    k: int = 32,
    tr: int = 8,
    damping: float = 0.85,
) -> Tuple[np.ndarray, int]:
    """Execute the distributed engine for real (CPU multi-device tests)."""
    import jax
    import jax.numpy as jnp

    n_dev = int(np.prod(mesh.devices.shape))
    dg = build_device_graph(graph, n_dev, window=window, k=k, tr=tr)
    step, in_sh, _ = make_superstep(
        mesh, program.name, dg.num_vertices_real, dg.rows_per_dev, damping=damping
    )

    vals0, _ = program.init_padded(dg) if hasattr(program, "init_padded") else (None, None)
    if vals0 is None:
        from .sharding import GraphMeta

        meta = GraphMeta(
            num_vertices=graph.num_vertices,
            num_edges=graph.num_edges,
            num_shards=n_dev,
            intervals=np.arange(n_dev + 1) * dg.rows_per_dev,
            in_deg=np.zeros(graph.num_vertices, np.int64),
            out_deg=graph.out_degrees(),
        )
        vals0, _ = program.init(meta)
    pad = dg.num_vertices - graph.num_vertices
    # Padding vertices: no in/out edges; init them inert with the identity of
    # is_active (their value never changes).
    vals = np.concatenate([vals0.astype(np.float32),
                           np.zeros(pad, np.float32)])

    args = [
        jax.device_put(jnp.asarray(x), s)
        for x, s in zip(
            (vals, dg.ell_idx, dg.ell_valid, dg.seg, dg.out_deg), in_sh
        )
    ]
    iters = 0
    for it in range(max_iters):
        new_vals, n_active = step(*args)
        iters = it + 1
        args[0] = new_vals
        if int(n_active) == 0:
            break
    out = np.asarray(args[0])[: graph.num_vertices]
    return out, iters
