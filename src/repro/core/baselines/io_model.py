"""Analytic I/O model — paper Table II.

Per-iteration disk read/write volume and memory usage for the five
computation models, as closed-form functions of:

    C  size of a vertex value record (bytes)
    D  size of one edge record (bytes)
    V  number of vertices, E number of edges
    P  number of shards / partitions / grid cells
    N  number of CPU cores (VSW memory term)
    theta  cache miss ratio (VSW read term), 0 <= theta <= 1
    d_avg  average degree (VSP's v-shard duplication factor delta)

``benchmarks/bench_io_model.py`` prints this table for the paper's datasets
and cross-checks the VSW/PSW/ESG/DSW rows against *measured* bytes from the
real engines on synthetic graphs.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict

__all__ = ["IOModel", "MODELS", "io_table"]


@dataclasses.dataclass(frozen=True)
class IOModel:
    name: str
    system: str
    read: object  # callable(params) -> bytes
    write: object
    memory: object


def _delta(d_avg: float, P: int) -> float:
    return (1.0 - math.exp(-d_avg / P)) * P


@dataclasses.dataclass
class IOParams:
    C: float
    D: float
    V: float
    E: float
    P: int
    N: int = 1
    theta: float = 1.0

    @property
    def d_avg(self) -> float:
        return self.E / max(self.V, 1)


MODELS: Dict[str, IOModel] = {
    "psw": IOModel(
        "PSW", "GraphChi",
        read=lambda p: p.C * p.V + 2 * (p.C + p.D) * p.E,
        write=lambda p: p.C * p.V + 2 * (p.C + p.D) * p.E,
        memory=lambda p: (p.C * p.V + 2 * (p.C + p.D) * p.E) / p.P,
    ),
    "esg": IOModel(
        "ESG", "X-Stream",
        read=lambda p: p.C * p.V + (p.C + p.D) * p.E,
        write=lambda p: p.C * p.V + p.C * p.E,
        memory=lambda p: p.C * p.V / p.P,
    ),
    "vsp": IOModel(
        "VSP", "VENUS",
        read=lambda p: p.C * (1 + _delta(p.d_avg, p.P)) * p.V + p.D * p.E,
        write=lambda p: p.C * p.V,
        memory=lambda p: p.C * (2 + _delta(p.d_avg, p.P)) * p.V / p.P,
    ),
    "dsw": IOModel(
        "DSW", "GridGraph",
        read=lambda p: p.C * math.sqrt(p.P) * p.V + p.D * p.E,
        write=lambda p: p.C * math.sqrt(p.P) * p.V,
        memory=lambda p: 2 * p.C * p.V / math.sqrt(p.P),
    ),
    "vsw": IOModel(
        "VSW", "GraphMP (ours)",
        read=lambda p: p.theta * p.D * p.E,
        write=lambda p: 0.0,
        memory=lambda p: 2 * p.C * p.V + p.N * p.D * p.E / p.P,
    ),
}


def io_table(params: IOParams) -> Dict[str, Dict[str, float]]:
    return {
        key: {
            "read": float(m.read(params)),
            "write": float(m.write(params)),
            "memory": float(m.memory(params)),
        }
        for key, m in MODELS.items()
    }
