"""Out-of-core baseline engines: PSW (GraphChi), ESG (X-Stream), DSW (GridGraph).

The paper's headline claim is that VSW needs ``θ·D·|E|`` read + 0 write per
iteration while the baselines move vertices AND edge values through disk
every iteration (Table II).  To reproduce the comparison honestly these
engines perform *real* reads and writes through the same accounted
:class:`~repro.core.storage.ShardStore` channel as VSW, and produce
*identical numerical results* (tests assert so).

They reproduce each system's **I/O schedule** — which files cross the disk
boundary, when, and how large — not its internal thread/buffer machinery.
Two deliberate deviations, both noted in EXPERIMENTS.md:

- GraphChi supports asynchronous (Gauss-Seidel) execution; we run its I/O
  schedule synchronously (Jacobi) so all engines compute identical
  per-iteration values.  I/O volume is unaffected.
- GridGraph uses a √P x √P grid; we derive √P chunks from the same VSW
  intervals so its ``C·√P·|V|`` vertex traffic term is reproduced.

Edge records are D = 8 bytes (src, dst int32), vertex/edge values C = 4
bytes (float32) — matching the paper's unweighted-graph setting.
"""

from __future__ import annotations

import os
import time
from typing import Dict, List

import numpy as np

from ..apps import COMBINE_IDENTITY, VertexProgram
from ..graph import Graph
from ..sharding import GraphMeta, preprocess
from ..storage import ShardStore
from ..vsw import IterStats, RunResult

__all__ = ["PSWEngine", "ESGEngine", "DSWEngine", "prepare_baseline_store"]


def _scatter_reduce(acc: np.ndarray, idx: np.ndarray, vals: np.ndarray, combine: str):
    if combine == "sum":
        np.add.at(acc, idx, vals)
    elif combine == "min":
        np.minimum.at(acc, idx, vals)
    else:
        np.maximum.at(acc, idx, vals)


def _chunk_bounds(intervals: np.ndarray, q: int) -> np.ndarray:
    """Coarsen P interval boundaries into q chunk boundaries."""
    P = len(intervals) - 1
    picks = np.linspace(0, P, q + 1).round().astype(int)
    return intervals[picks]


def prepare_baseline_store(
    graph: Graph, root: str, *, num_shards: int, emulate_bw=None
) -> ShardStore:
    """Preprocess a graph into baseline-format files.

    Per (src-interval p, dst-interval q): ``blk_p_q`` with (src, dst) —
    PSW's shard blocks.  Per (src-chunk i, dst-chunk j) over √P chunks:
    ``dsw_grid_i_j`` — GridGraph's grid cells.  Per interval p:
    ``esg_out_p`` (out-edges of p) — X-Stream's streaming partitions.
    """
    meta, _ = preprocess(graph, num_shards=num_shards)
    store = ShardStore(root, emulate_bw=emulate_bw)
    store.write_meta(meta)
    iv = meta.intervals
    P = meta.num_shards
    Q = max(1, int(np.ceil(np.sqrt(P))))
    chunks = _chunk_bounds(iv, Q)
    store.write_aux("dsw_chunks", bounds=chunks)

    src_iv = np.searchsorted(iv, graph.src, side="right") - 1
    dst_iv = np.searchsorted(iv, graph.dst, side="right") - 1
    src_ch = np.searchsorted(chunks, graph.src, side="right") - 1
    dst_ch = np.searchsorted(chunks, graph.dst, side="right") - 1

    for p in range(P):
        m2 = src_iv == p
        store.write_aux(f"esg_out_{p}", src=graph.src[m2], dst=graph.dst[m2])
        for q in range(P):
            mb = m2 & (dst_iv == q)
            store.write_aux(f"blk_{p}_{q}", src=graph.src[mb], dst=graph.dst[mb])
    for i in range(Q):
        mi = src_ch == i
        for j in range(Q):
            mb = mi & (dst_ch == j)
            store.write_aux(f"dsw_grid_{i}_{j}", src=graph.src[mb], dst=graph.dst[mb])
    return store


class _BaselineBase:
    #: bounds key, vertex-file prefix
    def __init__(self, store: ShardStore):
        self.store = store
        self.meta = store.read_meta()

    # vertex files over arbitrary boundary arrays -------------------------
    def _init_vertex_files(
        self, program: VertexProgram, bounds: np.ndarray, prefix: str
    ) -> np.ndarray:
        vals, _ = program.init(self.meta)
        vals = vals.astype(np.float32)
        for p in range(len(bounds) - 1):
            self.store.write_aux(
                f"{prefix}_{p}", vals=vals[int(bounds[p]) : int(bounds[p + 1])]
            )
        return vals

    def _read_v(self, prefix: str, p: int) -> np.ndarray:
        return self.store.read_aux(f"{prefix}_{p}")["vals"]

    def _write_v(self, prefix: str, p: int, vals: np.ndarray) -> None:
        self.store.write_aux(f"{prefix}_{p}", vals=vals.astype(np.float32))

    def _finish_iter(self, it, t0, io0, old_vals, new_vals, processed) -> IterStats:
        dio = self.store.io - io0
        active = int((new_vals != old_vals).sum())
        return IterStats(
            iteration=it,
            time_s=time.perf_counter() - t0,
            shards_processed=processed,
            shards_skipped=0,
            bytes_read=dio.bytes_read,
            cache_hits=0,
            cache_misses=0,
            active_count=active,
            active_ratio=active / max(self.meta.num_vertices, 1),
            selective_on=False,
        )


class PSWEngine(_BaselineBase):
    """GraphChi's parallel-sliding-window I/O schedule (run synchronously).

    Edge records carry their message value inline (C+D bytes).  Gather pass:
    for each destination interval read its vertices + all column blocks with
    values.  Scatter pass: for each source interval, read-modify-write all
    row blocks with the new messages, and write the interval's vertices.
    Every edge is read twice and written twice per iteration at (C+D) bytes
    -> Table II row 1.
    """

    def run(self, program: VertexProgram, *, max_iters: int = 100) -> RunResult:
        meta, store, P = self.meta, self.store, self.meta.num_shards
        iv = meta.intervals
        vals = self._init_vertex_files(program, iv, "psw_vtx")
        # Data-loading scatter: edge values = pre(init vals) (not counted in iters).
        msgs0 = program.pre(vals, meta.out_deg).astype(np.float32)
        for p in range(P):
            for q in range(P):
                blk = store.read_aux(f"blk_{p}_{q}")
                store.write_aux(
                    f"psw_blk_{p}_{q}",
                    src=blk["src"], dst=blk["dst"], val=msgs0[blk["src"]],
                )
        stats: List[IterStats] = []
        converged = False

        for it in range(max_iters):
            t0, io0 = time.perf_counter(), store.io.snapshot()
            old_vals = vals.copy()
            new_vals = vals.copy()
            # ---- gather + update (reads edges once, with values)
            for q in range(P):
                v0, v1 = int(iv[q]), int(iv[q + 1])
                ivals = self._read_v("psw_vtx", q)
                acc = np.full(v1 - v0, COMBINE_IDENTITY[program.combine], np.float32)
                for p in range(P):
                    blk = store.read_aux(f"psw_blk_{p}_{q}")
                    _scatter_reduce(acc, blk["dst"] - v0, blk["val"], program.combine)
                upd = program.apply(acc, ivals, meta, v0)
                new_vals[v0:v1] = upd
                self._write_v("psw_vtx", q, upd)
            # ---- scatter (read-modify-writes edges once more, with values)
            full_msgs = program.pre(new_vals, meta.out_deg).astype(np.float32)
            for p in range(P):
                for q in range(P):
                    blk = store.read_aux(f"psw_blk_{p}_{q}")
                    store.write_aux(
                        f"psw_blk_{p}_{q}",
                        src=blk["src"], dst=blk["dst"], val=full_msgs[blk["src"]],
                    )
            vals = new_vals
            stats.append(self._finish_iter(it, t0, io0, old_vals, vals, P))
            if stats[-1].active_count == 0:
                converged = True
                break
        return RunResult(values=vals, iterations=stats, converged=converged)


class ESGEngine(_BaselineBase):
    """X-Stream's edge-centric scatter-gather I/O schedule.

    Phase 1 (scatter): per partition, read vertices, stream out-edges,
    spill (dst, msg) updates to each destination partition's update file.
    Phase 2 (gather): per partition, read its updates + vertices, apply,
    write vertices.
    """

    def run(self, program: VertexProgram, *, max_iters: int = 100) -> RunResult:
        meta, store, P = self.meta, self.store, self.meta.num_shards
        iv = meta.intervals
        vals = self._init_vertex_files(program, iv, "esg_vtx")
        stats: List[IterStats] = []
        converged = False

        for it in range(max_iters):
            t0, io0 = time.perf_counter(), store.io.snapshot()
            old_vals = vals.copy()
            # ---- scatter
            pending: Dict[int, list] = {q: [] for q in range(P)}
            for p in range(P):
                v0, v1 = int(iv[p]), int(iv[p + 1])
                pv = self._read_v("esg_vtx", p)
                full = np.zeros(meta.num_vertices, np.float32)
                full[v0:v1] = pv
                out = store.read_aux(f"esg_out_{p}")
                msgs = program.pre(full, meta.out_deg)[out["src"]]
                dst_iv = np.searchsorted(iv, out["dst"], "right") - 1
                for q in range(P):
                    m = dst_iv == q
                    if m.any():
                        pending[q].append((out["dst"][m], msgs[m]))
            for q in range(P):  # updates cross the disk boundary
                if pending[q]:
                    d = np.concatenate([x[0] for x in pending[q]])
                    u = np.concatenate([x[1] for x in pending[q]])
                else:
                    d, u = np.zeros(0, np.int32), np.zeros(0, np.float32)
                store.write_aux(f"esg_upd_{q}", dst=d, msg=u)
            # ---- gather
            new_vals = vals.copy()
            for q in range(P):
                v0, v1 = int(iv[q]), int(iv[q + 1])
                upd = store.read_aux(f"esg_upd_{q}")
                acc = np.full(v1 - v0, COMBINE_IDENTITY[program.combine], np.float32)
                _scatter_reduce(acc, upd["dst"] - v0, upd["msg"], program.combine)
                res = program.apply(acc, self._read_v("esg_vtx", q), meta, v0)
                new_vals[v0:v1] = res
                self._write_v("esg_vtx", q, res)
            vals = new_vals
            stats.append(self._finish_iter(it, t0, io0, old_vals, vals, P))
            if stats[-1].active_count == 0:
                converged = True
                break
        return RunResult(values=vals, iterations=stats, converged=converged)


class DSWEngine(_BaselineBase):
    """GridGraph's dual-sliding-window I/O schedule, column-major over a
    √P x √P grid.  Per destination chunk j: read chunk j, then for each
    source chunk i read vertices(i) and stream grid block (i, j); write
    chunk j once per column (the favourable write order — GridGraph's own;
    Table II's ``C√P|V|`` write is its worst case, see EXPERIMENTS.md)."""

    def run(self, program: VertexProgram, *, max_iters: int = 100) -> RunResult:
        meta, store = self.meta, self.store
        chunks = store.read_aux("dsw_chunks")["bounds"]
        Q = len(chunks) - 1
        vals = self._init_vertex_files(program, chunks, "dsw_vtx")
        stats: List[IterStats] = []
        converged = False

        for it in range(max_iters):
            t0, io0 = time.perf_counter(), store.io.snapshot()
            old_vals = vals.copy()
            new_vals = vals.copy()
            for j in range(Q):
                v0, v1 = int(chunks[j]), int(chunks[j + 1])
                dvals = self._read_v("dsw_vtx", j)
                acc = np.full(v1 - v0, COMBINE_IDENTITY[program.combine], np.float32)
                for i in range(Q):
                    u0, u1 = int(chunks[i]), int(chunks[i + 1])
                    svals = self._read_v("dsw_vtx", i)
                    full = np.zeros(meta.num_vertices, np.float32)
                    full[u0:u1] = svals
                    blk = store.read_aux(f"dsw_grid_{i}_{j}")
                    msgs = program.pre(full, meta.out_deg)[blk["src"]]
                    _scatter_reduce(acc, blk["dst"] - v0, msgs, program.combine)
                res = program.apply(acc, dvals, meta, v0)
                new_vals[v0:v1] = res
                # Double-buffered write: later columns must still read this
                # iteration's *input* values for chunk j (Jacobi semantics).
                self._write_v("dsw_vtx_new", j, res)
            for j in range(Q):  # publish: rename is metadata-only, no data I/O
                os.replace(
                    store._path(f"aux_dsw_vtx_new_{j}.npz"),
                    store._path(f"aux_dsw_vtx_{j}.npz"),
                )
            vals = new_vals
            stats.append(self._finish_iter(it, t0, io0, old_vals, vals, Q * Q))
            if stats[-1].active_count == 0:
                converged = True
                break
        return RunResult(values=vals, iterations=stats, converged=converged)
