"""Shard executors: *how planned shards execute* (DESIGN.md §3-4).

Third layer of the engine stack.  An executor consumes the pipeline's
stream of loaded shards and yields per-shard accumulators; it owns the
backend dispatch the engine used to do inline.

Two strategies:

- :class:`PerShardExecutor` — one backend call per shard (the paper's
  worker model; also the only choice for the numpy oracle, whose
  scatter-reduce has no dispatch overhead to amortize).
- :class:`BatchedEllExecutor` — groups up to ``batch_shards`` consecutive
  planned ELL shards into ONE concatenated kernel dispatch (shared
  ``tile_window`` prefetch map, one ``pallas_call`` / one jit call for N
  shards).  Bitwise-equal to per-shard execution by construction: the
  batch is a pure concatenation, so every tile computes identical partials
  and the globalized segment combine preserves per-segment contribution
  order.

Shard-update backends (moved here from ``vsw.py``); signature
``(csr, ell, msgs, combine) -> acc [rows] float32``:

=========  ==================================================================
numpy      ``np.add.at`` / ``np.minimum.at`` scatter-reduce over CSR — the
           bitwise oracle.
jnp        windowed ELL gather + masked reduce + segment combine under
           ``jax.jit`` (shape-bucketed to bound recompiles) — what XLA
           would run.
pallas     the ``repro.kernels.spmv_ell`` TPU kernel (interpret mode on
           CPU) — the production hot loop.
=========  ==================================================================
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import (
    Callable, Dict, Iterable, Iterator, List, Optional, Sequence, Tuple,
)

import numpy as np

from ..obs import trace
from .apps import COMBINE_IDENTITY
from .csr import (
    EllShard, bucket_rows, concat_ells, next_pow2, pad_ell_arrays,
    ragged_lane_concat,
)
from .pipeline import LoadedShard
from .sharding import ShardCSR

__all__ = [
    "BACKENDS",
    "LANE_BACKENDS",
    "ExecResult",
    "ExecStats",
    "PerShardExecutor",
    "BatchedEllExecutor",
    "make_executor",
    "make_lane_executor",
    "update_shard_numpy",
    "update_shard_jnp",
    "update_shards_jnp_batched",
    "update_shard_numpy_lanes",
    "update_shard_jnp_lanes",
    "update_shards_jnp_lanes_batched",
    "update_shards_jnp_lanes_multi",
    "GroupDispatch",
]


# --------------------------------------------------------------------------
# Shard-update backends
# --------------------------------------------------------------------------


def update_shard_numpy(
    csr: ShardCSR, ell: Optional[EllShard], msgs: np.ndarray, combine: str
) -> np.ndarray:
    """Scatter-reduce oracle over the CSR shard."""
    rows = csr.rows
    acc = np.full(rows, COMBINE_IDENTITY[combine], dtype=msgs.dtype)
    if csr.nnz == 0:
        return acc
    local_dst = np.repeat(np.arange(rows, dtype=np.int64), np.diff(csr.row))
    vals = msgs[csr.col]
    if combine == "sum":
        np.add.at(acc, local_dst, vals)
    elif combine == "min":
        np.minimum.at(acc, local_dst, vals)
    elif combine == "max":
        np.maximum.at(acc, local_dst, vals)
    else:  # pragma: no cover
        raise ValueError(combine)
    return acc


def _ell_fn_impl(tr: int, rows: int, window: int, combine: str):
    """The pure (un-jitted) windowed-ELL update for one padded shape bucket.

    Shared by the single-query path (jitted directly) and the serving
    layer's lane path (jitted under ``vmap`` over the message axis) so both
    trace the exact same per-lane computation.
    """
    import jax
    import jax.numpy as jnp

    ident = COMBINE_IDENTITY[combine]

    def fn(ell_idx, ell_mask, seg, tile_window, msgs):
        win = jnp.repeat(tile_window, tr)  # [n_ell]
        gidx = ell_idx.astype(jnp.int32) + win[:, None] * window
        g = jnp.take(msgs, gidx, axis=0, mode="clip")
        g = jnp.where(ell_mask, g, jnp.asarray(ident, g.dtype))
        if combine == "sum":
            part = g.sum(axis=1)
            acc = jax.ops.segment_sum(part, seg, num_segments=rows)
        elif combine == "min":
            part = g.min(axis=1)
            acc = jax.ops.segment_min(part, seg, num_segments=rows)
            acc = jnp.where(jnp.isfinite(acc), acc, jnp.asarray(ident, g.dtype))
        else:
            part = g.max(axis=1)
            acc = jax.ops.segment_max(part, seg, num_segments=rows)
            acc = jnp.where(jnp.isfinite(acc), acc, jnp.asarray(ident, g.dtype))
        return acc

    return fn


@functools.lru_cache(maxsize=64)
def _jnp_ell_fn(n_ell: int, k: int, tr: int, rows: int, window: int, combine: str):
    """Build a jit'd ELL update for one padded shape bucket."""
    import jax

    return jax.jit(_ell_fn_impl(tr, rows, window, combine))


@functools.lru_cache(maxsize=64)
def _jnp_ell_lanes_fn(
    n_ell: int, k: int, tr: int, rows: int, window: int, combine: str
):
    """Lane-batched variant: one jit dispatch updates ``[lanes, ...]``
    message rows against shared edge structure (lane count is a traced
    shape; the serving batcher pads it to pow2 to bound retraces)."""
    import jax

    return jax.jit(
        jax.vmap(_ell_fn_impl(tr, rows, window, combine),
                 in_axes=(None, None, None, None, 0))
    )


def _padded_shard_inputs(ell: EllShard, msgs: np.ndarray):
    """Shape-bucket one shard's ELL arrays and pad messages to full windows
    (so the gather never reads OOB).  ``msgs`` may be 1-D (single query) or
    2-D ``[lanes, |V|]`` — only the trailing (vertex) axis is padded.
    Shared by the single-query and lane paths so the padding discipline
    can't drift between them."""
    n_ell_pad = bucket_rows(ell.n_ell, ell.tr)
    idx, mask, seg, tw = pad_ell_arrays(
        ell.ell_idx, ell.ell_mask, ell.seg, ell.tile_window,
        ell.n_ell, ell.tr, n_ell_pad,
    )
    n_pad_v = ell.num_windows * ell.window
    pad = [(0, 0)] * (msgs.ndim - 1) + [(0, n_pad_v - msgs.shape[-1])]
    return n_ell_pad, idx, mask, seg, tw, np.pad(msgs, pad)


def _staged_batch(ells: List[EllShard]):
    """Concatenate + shape-bucket a shard batch (the shard-side staging
    every batched lane path shares — single-group and multi-group dispatch
    MUST pad identically or fusion stops being bitwise-invisible)."""
    batch = concat_ells(ells)
    n_ell_pad = bucket_rows(batch.n_ell, batch.tr)
    idx, mask, seg, tw = pad_ell_arrays(
        batch.ell_idx, batch.ell_mask, batch.seg, batch.tile_window,
        batch.n_ell, batch.tr, n_ell_pad,
    )
    return batch, n_ell_pad, idx, mask, seg, tw


def _padded_batch_inputs(ells: List[EllShard], msgs: np.ndarray):
    """Batch-level counterpart of :func:`_padded_shard_inputs`."""
    batch, n_ell_pad, idx, mask, seg, tw = _staged_batch(ells)
    n_pad_v = batch.num_windows * batch.window
    pad = [(0, 0)] * (msgs.ndim - 1) + [(0, n_pad_v - msgs.shape[-1])]
    return batch, n_ell_pad, idx, mask, seg, tw, np.pad(msgs, pad)


def update_shard_jnp(
    csr: ShardCSR, ell: EllShard, msgs: np.ndarray, combine: str
) -> np.ndarray:
    """Windowed-ELL gather/combine under jit (shape-bucketed)."""
    import jax.numpy as jnp

    n_ell_pad, idx, mask, seg, tw, msgs_p = _padded_shard_inputs(ell, msgs)
    fn = _jnp_ell_fn(n_ell_pad, ell.k, ell.tr, ell.rows, ell.window, combine)
    acc = fn(jnp.asarray(idx), jnp.asarray(mask), jnp.asarray(seg),
             jnp.asarray(tw), jnp.asarray(msgs_p))
    return np.asarray(acc)


def update_shards_jnp_batched(
    ells: List[EllShard], msgs: np.ndarray, combine: str
) -> List[np.ndarray]:
    """One jit dispatch for N concatenated shards (jnp backend).

    Both the ELL row count AND the segment count are shape-bucketed
    (pow2): batch composition changes every iteration under selective
    scheduling, and without bucketing each distinct (n_ell, rows_total)
    pair would force a fresh XLA compile.  Padding rows land in the
    batch's first destination row carrying the combine identity, and
    surplus segments are simply never referenced by ``split`` — both
    no-ops, so bucketing never changes results.
    """
    import jax.numpy as jnp

    if not ells:
        return []
    batch, n_ell_pad, idx, mask, seg, tw, msgs_p = _padded_batch_inputs(
        ells, msgs
    )
    rows_pad = next_pow2(batch.rows_total)
    fn = _jnp_ell_fn(n_ell_pad, batch.k, batch.tr, rows_pad, batch.window,
                     combine)
    acc = fn(jnp.asarray(idx), jnp.asarray(mask), jnp.asarray(seg),
             jnp.asarray(tw), jnp.asarray(msgs_p))
    return batch.split(np.asarray(acc))


def _update_shard_pallas(
    csr: ShardCSR, ell: EllShard, msgs: np.ndarray, combine: str
) -> np.ndarray:
    from repro.kernels.spmv_ell import ops as spmv_ops

    return np.asarray(spmv_ops.ell_update(ell, msgs, combine))


def _update_shards_pallas_batched(
    ells: List[EllShard], msgs: np.ndarray, combine: str
) -> List[np.ndarray]:
    from repro.kernels.spmv_ell import ops as spmv_ops

    return [np.asarray(a) for a in spmv_ops.ell_update_batched(ells, msgs, combine)]


# --------------------------------------------------------------------------
# Lane-batched backends (serving layer): msgs is [lanes, |V|], acc is
# [lanes, rows].  One shard load feeds every in-flight query lane.
# --------------------------------------------------------------------------


def update_shard_numpy_lanes(
    csr: ShardCSR, ell: Optional[EllShard], msgs: np.ndarray, combine: str
) -> np.ndarray:
    """Lane-stacked scatter-reduce oracle: runs :func:`update_shard_numpy`
    per lane, so each lane's row is bitwise THE single-query oracle."""
    return np.stack(
        [update_shard_numpy(csr, ell, msgs[l], combine)
         for l in range(msgs.shape[0])]
    )


def update_shard_jnp_lanes(
    csr: ShardCSR, ell: EllShard, msgs: np.ndarray, combine: str
) -> np.ndarray:
    """Windowed-ELL gather/combine for all lanes under ONE jit dispatch."""
    import jax.numpy as jnp

    n_ell_pad, idx, mask, seg, tw, msgs_p = _padded_shard_inputs(ell, msgs)
    fn = _jnp_ell_lanes_fn(n_ell_pad, ell.k, ell.tr, ell.rows, ell.window,
                           combine)
    acc = fn(jnp.asarray(idx), jnp.asarray(mask), jnp.asarray(seg),
             jnp.asarray(tw), jnp.asarray(msgs_p))
    return np.asarray(acc)


def update_shards_jnp_lanes_batched(
    ells: List[EllShard], msgs: np.ndarray, combine: str
) -> List[np.ndarray]:
    """One jit dispatch for N concatenated shards x K lanes (jnp backend) —
    same shape-bucketing discipline as :func:`update_shards_jnp_batched`."""
    import jax.numpy as jnp

    if not ells:
        return []
    batch, n_ell_pad, idx, mask, seg, tw, msgs_p = _padded_batch_inputs(
        ells, msgs
    )
    rows_pad = next_pow2(batch.rows_total)
    fn = _jnp_ell_lanes_fn(n_ell_pad, batch.k, batch.tr, rows_pad,
                           batch.window, combine)
    acc = fn(jnp.asarray(idx), jnp.asarray(mask), jnp.asarray(seg),
             jnp.asarray(tw), jnp.asarray(msgs_p))
    return batch.split(np.asarray(acc))


def update_shards_jnp_lanes_multi(
    ells: List[EllShard],
    msgs_by_group: Sequence[np.ndarray],
    combines: Sequence[str],
) -> List[List[np.ndarray]]:
    """Multi-GROUP lane dispatch (fused sweeps, DESIGN.md §9): N shards are
    concatenated / shape-bucketed / staged ONCE, then dispatched once per
    program group against that group's own ``[K_g, |V|]`` lane matrix and
    combine monoid — G dispatches share one decode+concat.  Each group's
    dispatch is the exact computation
    :func:`update_shards_jnp_lanes_batched` would run for it alone (same
    padded arrays, same jit'd function), so fusion stays bitwise-invisible
    per lane.  Returns one per-shard accumulator list per group.
    """
    import jax.numpy as jnp

    if not ells:
        return [[] for _ in msgs_by_group]
    batch, n_ell_pad, idx, mask, seg, tw = _staged_batch(ells)
    idx_j, mask_j, seg_j, tw_j = (
        jnp.asarray(idx), jnp.asarray(mask), jnp.asarray(seg), jnp.asarray(tw)
    )
    rows_pad = next_pow2(batch.rows_total)
    n_pad_v = batch.num_windows * batch.window
    out: List[List[np.ndarray]] = []
    for msgs, combine in zip(msgs_by_group, combines):
        msgs_p = np.zeros((msgs.shape[0], n_pad_v), msgs.dtype)
        msgs_p[:, : msgs.shape[1]] = msgs
        fn = _jnp_ell_lanes_fn(n_ell_pad, batch.k, batch.tr, rows_pad,
                               batch.window, combine)
        acc = fn(idx_j, mask_j, seg_j, tw_j, jnp.asarray(msgs_p))
        out.append(batch.split(np.asarray(acc)))
    return out


@functools.lru_cache(maxsize=64)
def _jnp_ell_lanes_ragged_fn(
    n_ell: int, k: int, tr: int, rows: int, window: int, combines: tuple
):
    """RaggedFuse jnp variant: ONE jit dispatch updates the concatenated
    lane state of ALL fusion groups, selecting each lane's combine arm via
    its ``combine_ids`` entry.  The per-arm bodies are the exact
    :func:`_ell_fn_impl` closures the per-group multi path vmaps, and
    ``jnp.where`` keeps the selected arm's value bit-for-bit, so each
    lane's row is bitwise :func:`update_shards_jnp_lanes_multi`'s."""
    import jax
    import jax.numpy as jnp

    bodies = [_ell_fn_impl(tr, rows, window, c) for c in combines]

    def fn(ell_idx, ell_mask, seg, tile_window, combine_ids, msgs2d):
        acc = jnp.zeros((msgs2d.shape[0], rows), msgs2d.dtype)
        for ci, body in enumerate(bodies):
            acc_c = jax.vmap(body, in_axes=(None, None, None, None, 0))(
                ell_idx, ell_mask, seg, tile_window, msgs2d
            )
            acc = jnp.where((combine_ids == ci)[:, None], acc_c, acc)
        return acc

    return jax.jit(fn)


def _ragged_stage_lanes(msgs_by_group, combines, n_pad_v: int):
    """Stage the concatenated lane state of ALL groups to device once per
    sweep iteration (reused across every shard batch — ISSUE 10 satellite:
    no re-pad per flush while lane membership is unchanged)."""
    import jax.numpy as jnp

    msgs_all, cids, combines_set, slices = ragged_lane_concat(
        msgs_by_group, combines, n_cols=n_pad_v
    )
    return {
        "msgs": jnp.asarray(msgs_all),
        "cids": jnp.asarray(cids),
        "combines": combines_set,
        "slices": slices,
        "k_total": int(sum(int(m.shape[0]) for m in msgs_by_group)),
        "k_pad": int(msgs_all.shape[0]),
    }


def _ragged_dispatch_jnp(ells: List[EllShard], lane_ctx, *,
                         interpret: bool = True):
    """Launch ONE jnp ragged update; the accumulator is left unforced so
    the caller can overlap the next batch's decode (double buffering)."""
    import jax.numpy as jnp

    batch, n_ell_pad, idx, mask, seg, tw = _staged_batch(ells)
    rows_pad = next_pow2(batch.rows_total)
    fn = _jnp_ell_lanes_ragged_fn(
        n_ell_pad, batch.k, batch.tr, rows_pad, batch.window,
        lane_ctx["combines"],
    )
    acc = fn(jnp.asarray(idx), jnp.asarray(mask), jnp.asarray(seg),
             jnp.asarray(tw), lane_ctx["cids"], lane_ctx["msgs"])
    return batch, acc


def _ragged_dispatch_pallas(ells: List[EllShard], lane_ctx, *,
                            interpret: bool = True):
    from repro.kernels.spmv_ell import ops as spmv_ops

    return spmv_ops.ragged_dispatch(ells, lane_ctx, interpret=interpret)


def _ragged_collect(batch, acc, group_slices) -> List[List[np.ndarray]]:
    """Force a ragged accumulator and slice per group per shard."""
    acc = np.asarray(acc)
    return [batch.split(acc[sl]) for sl in group_slices]


def _update_shard_pallas_lanes(
    csr: ShardCSR, ell: EllShard, msgs: np.ndarray, combine: str
) -> np.ndarray:
    from repro.kernels.spmv_ell import ops as spmv_ops

    return np.asarray(spmv_ops.ell_update_lanes(ell, msgs, combine))


def _update_shards_pallas_lanes_batched(
    ells: List[EllShard], msgs: np.ndarray, combine: str
) -> List[np.ndarray]:
    from repro.kernels.spmv_ell import ops as spmv_ops

    return [np.asarray(a)
            for a in spmv_ops.ell_update_lanes_batched(ells, msgs, combine)]


def _update_shards_pallas_lanes_multi(
    ells: List[EllShard],
    msgs_by_group: Sequence[np.ndarray],
    combines: Sequence[str],
) -> List[List[np.ndarray]]:
    from repro.kernels.spmv_ell import ops as spmv_ops

    return [
        [np.asarray(a) for a in accs]
        for accs in spmv_ops.ell_update_lanes_multi(ells, msgs_by_group,
                                                    combines)
    ]


BACKENDS: Dict[str, Callable] = {
    "numpy": update_shard_numpy,
    "jnp": update_shard_jnp,
    "pallas": _update_shard_pallas,
}

_BATCHED_BACKENDS: Dict[str, Callable] = {
    "jnp": update_shards_jnp_batched,
    "pallas": _update_shards_pallas_batched,
}

LANE_BACKENDS: Dict[str, Callable] = {
    "numpy": update_shard_numpy_lanes,
    "jnp": update_shard_jnp_lanes,
    "pallas": _update_shard_pallas_lanes,
}

_BATCHED_LANE_BACKENDS: Dict[str, Callable] = {
    "jnp": update_shards_jnp_lanes_batched,
    "pallas": _update_shards_pallas_lanes_batched,
}

_MULTI_LANE_BACKENDS: Dict[str, Callable] = {
    "jnp": update_shards_jnp_lanes_multi,
    "pallas": _update_shards_pallas_lanes_multi,
}

_RAGGED_LANE_BACKENDS: Dict[str, Callable] = {
    "jnp": _ragged_dispatch_jnp,
    "pallas": _ragged_dispatch_pallas,
}

#: One program group's dispatch request for ``run_groups``: the group's
#: ``[K_g, |V|]`` message matrix and its combine monoid, or None when the
#: group has nothing to dispatch for these shards (every lane masked off /
#: already retired) — the shard stream is still consumed once.
GroupDispatch = Optional[Tuple[np.ndarray, str]]


# --------------------------------------------------------------------------
# Executors
# --------------------------------------------------------------------------


@dataclasses.dataclass
class ExecResult:
    """One shard's accumulator plus which dispatch produced it."""

    shard_id: int
    v0: int
    v1: int
    acc: np.ndarray
    batch_size: int = 1  # shards sharing the kernel dispatch


@dataclasses.dataclass
class ExecStats:
    """Per-iteration dispatch accounting (reset each iteration)."""

    dispatches: int = 0
    shards_executed: int = 0
    exec_s: float = 0.0
    #: shard batches flushed this iteration (a ragged flush is ONE dispatch
    #: per batch; the multi path pays G — conservation:
    #: ragged_dispatches <= batches <= dispatches, DESIGN.md §14).
    batches: int = 0
    ragged_dispatches: int = 0
    #: live (un-padded) lanes covered by ragged launches, summed per flush;
    #: conservation: sum(group_lanes.values()) == ragged_lanes.
    ragged_lanes: int = 0
    group_lanes: Dict[int, int] = dataclasses.field(default_factory=dict)
    #: wall time a dispatched batch stayed in flight while the host staged
    #: the next one (the double-buffer overlap window).
    overlap_s: float = 0.0
    #: mesh executors only: device id -> shard applications / SPMD launches
    #: routed to that device (empty on single-device executors).
    #: Conservation: sum(device_shards.values()) == shards_executed.
    device_shards: Dict[int, int] = dataclasses.field(default_factory=dict)
    device_dispatches: Dict[int, int] = dataclasses.field(default_factory=dict)

    def reset(self) -> None:
        self.dispatches = self.shards_executed = 0
        self.exec_s = 0.0
        self.batches = self.ragged_dispatches = self.ragged_lanes = 0
        self.group_lanes = {}
        self.overlap_s = 0.0
        self.device_shards = {}
        self.device_dispatches = {}


class PerShardExecutor:
    """One backend call per loaded shard (paper worker model).

    With ``lanes=True`` the call consumes ``[lanes, |V|]`` messages and
    yields ``[lanes, rows]`` accumulators — the serving layer's per-shard
    amortization (one load, K lanes).
    """

    def __init__(self, backend: str, *, lanes: bool = False):
        table = LANE_BACKENDS if lanes else BACKENDS
        if backend not in table:
            raise ValueError(f"unknown backend {backend}; have {sorted(table)}")
        self.backend_name = backend
        self.lanes = lanes
        self._fn = table[backend]

    def run(
        self,
        loaded: Iterable[LoadedShard],
        msgs: np.ndarray,
        combine: str,
        stats: Optional[ExecStats] = None,
    ) -> Iterator[ExecResult]:
        for ls in loaded:
            t0 = time.perf_counter()
            with trace.span(
                "exec.dispatch", shard=ls.shard_id, backend=self.backend_name
            ):
                acc = self._fn(ls.csr, ls.ell, msgs, combine)
            if stats is not None:
                stats.dispatches += 1
                stats.shards_executed += 1
                stats.exec_s += time.perf_counter() - t0
            ref = ls.ref
            yield ExecResult(ls.shard_id, ref.v0, ref.v1, np.asarray(acc))

    def run_groups(
        self,
        loaded: Iterable[LoadedShard],
        groups: Sequence[GroupDispatch],
        stats: Optional[ExecStats] = None,
    ) -> Iterator[Tuple[int, ExecResult]]:
        """Multi-group dispatch (fused sweeps): consume each loaded shard
        ONCE and dispatch it per live program group — one load+decode, G
        backend calls.  Yields ``(group_index, result)``; ``None`` entries
        in ``groups`` are skipped without a dispatch.
        """
        for ls in loaded:
            ref = ls.ref
            for gi, ga in enumerate(groups):
                if ga is None:
                    continue
                msgs, combine = ga
                t0 = time.perf_counter()
                with trace.span(
                    "exec.dispatch",
                    shard=ls.shard_id,
                    group=gi,
                    backend=self.backend_name,
                ):
                    acc = self._fn(ls.csr, ls.ell, msgs, combine)
                if stats is not None:
                    stats.dispatches += 1
                    stats.shards_executed += 1
                    stats.exec_s += time.perf_counter() - t0
                yield gi, ExecResult(ls.shard_id, ref.v0, ref.v1,
                                     np.asarray(acc))


class BatchedEllExecutor:
    """Batch consecutive planned ELL shards into one kernel dispatch.

    With ``lanes=True`` each dispatch covers N shards x K query lanes —
    the serving hot loop's maximal amortization point.
    """

    def __init__(self, backend: str, batch_shards: int = 4, *,
                 lanes: bool = False, ragged: bool = True):
        table = _BATCHED_LANE_BACKENDS if lanes else _BATCHED_BACKENDS
        if backend not in table:
            raise ValueError(
                f"batched execution needs an ELL backend, got {backend!r}"
            )
        if batch_shards < 1:
            raise ValueError("batch_shards must be >= 1")
        self.backend_name = backend
        self.batch_shards = batch_shards
        self.lanes = lanes
        #: RaggedFuse (DESIGN.md §14): run_groups concatenates every live
        #: group along the lane axis and launches ONE ragged kernel per
        #: shard batch instead of G, double-buffering collection against
        #: the next batch's host decode.
        self.ragged = bool(ragged) and lanes and backend in _RAGGED_LANE_BACKENDS
        self._fn = table[backend]
        self._multi_fn = _MULTI_LANE_BACKENDS[backend] if lanes else None
        self._ragged_fn = _RAGGED_LANE_BACKENDS.get(backend) if lanes else None

    def run(
        self,
        loaded: Iterable[LoadedShard],
        msgs: np.ndarray,
        combine: str,
        stats: Optional[ExecStats] = None,
    ) -> Iterator[ExecResult]:
        buf: List[LoadedShard] = []
        for ls in loaded:
            buf.append(ls)
            if len(buf) >= self.batch_shards:
                yield from self._flush(buf, msgs, combine, stats)
                buf = []
        if buf:
            yield from self._flush(buf, msgs, combine, stats)

    def _flush(self, buf, msgs, combine, stats) -> Iterator[ExecResult]:
        t0 = time.perf_counter()
        with trace.span(
            "exec.dispatch", shards=len(buf), backend=self.backend_name
        ):
            accs = self._fn([ls.ell for ls in buf], msgs, combine)
        if stats is not None:
            stats.dispatches += 1
            stats.shards_executed += len(buf)
            stats.exec_s += time.perf_counter() - t0
        for ls, acc in zip(buf, accs):
            yield ExecResult(
                ls.shard_id, ls.ell.v0, ls.ell.v1, np.asarray(acc),
                batch_size=len(buf),
            )

    def run_groups(
        self,
        loaded: Iterable[LoadedShard],
        groups: Sequence[GroupDispatch],
        stats: Optional[ExecStats] = None,
    ) -> Iterator[Tuple[int, ExecResult]]:
        """Multi-group batched dispatch: up to ``batch_shards`` consecutive
        shards are concatenated ONCE (shared decode + concat + pad staging)
        and dispatched once per live program group — the fused serving hot
        loop's cost shape: 1 load, 1 concat, G kernel launches per batch.
        """
        if not self.lanes:
            raise RuntimeError("run_groups needs a lane executor")
        if self.ragged:
            yield from self._run_groups_ragged(loaded, groups, stats)
            return
        buf: List[LoadedShard] = []
        for ls in loaded:
            buf.append(ls)
            if len(buf) >= self.batch_shards:
                yield from self._flush_groups(buf, groups, stats)
                buf = []
        if buf:
            yield from self._flush_groups(buf, groups, stats)

    def _flush_groups(self, buf, groups, stats):
        live = [(gi, ga) for gi, ga in enumerate(groups) if ga is not None]
        if not live:
            return
        t0 = time.perf_counter()
        with trace.span(
            "exec.dispatch",
            shards=len(buf),
            groups=len(live),
            backend=self.backend_name,
        ):
            accs_by_group = self._multi_fn(
                [ls.ell for ls in buf],
                [ga[0] for _, ga in live],
                [ga[1] for _, ga in live],
            )
        if stats is not None:
            stats.dispatches += len(live)
            stats.batches += 1
            stats.shards_executed += len(buf) * len(live)
            stats.exec_s += time.perf_counter() - t0
        for (gi, _), accs in zip(live, accs_by_group):
            for ls, acc in zip(buf, accs):
                yield gi, ExecResult(
                    ls.shard_id, ls.ell.v0, ls.ell.v1, np.asarray(acc),
                    batch_size=len(buf),
                )

    def _run_groups_ragged(self, loaded, groups, stats):
        """RaggedFuse hot loop: 1 load, 1 concat, ONE kernel launch per
        batch covering every live group, with the collect of batch ``i``
        deferred until batch ``i+1`` has been dispatched — the launch stays
        in flight while the host stages the next batch (double buffering;
        the pipeline's prefetch threads fill the ``loaded`` iterator
        concurrently, so the pull below overlaps device compute too).
        """
        live = [(gi, ga) for gi, ga in enumerate(groups) if ga is not None]
        if not live:
            for _ in loaded:  # consume the stream exactly like the G-path
                pass
            return
        lane_ctx = None  # staged on first flush, reused across batches
        k_total = sum(int(ga[0].shape[0]) for _, ga in live)

        def dispatch(buf):
            nonlocal lane_ctx
            t0 = time.perf_counter()
            with trace.span(
                "exec.dispatch",
                shards=len(buf),
                groups=len(live),
                backend=self.backend_name,
                ragged=True,
            ):
                if lane_ctx is None:
                    ell = buf[0].ell
                    lane_ctx = _ragged_stage_lanes(
                        [ga[0] for _, ga in live],
                        [ga[1] for _, ga in live],
                        ell.num_windows * ell.window,
                    )
                batch, acc = self._ragged_fn([ls.ell for ls in buf], lane_ctx)
            if stats is not None:
                stats.dispatches += 1
                stats.ragged_dispatches += 1
                stats.batches += 1
                stats.shards_executed += len(buf) * len(live)
                stats.ragged_lanes += k_total
                for gi, ga in live:
                    stats.group_lanes[gi] = (
                        stats.group_lanes.get(gi, 0) + int(ga[0].shape[0])
                    )
                stats.exec_s += time.perf_counter() - t0
            return buf, batch, acc, time.perf_counter()

        def collect(p):
            buf, batch, acc, t_launch = p
            if stats is not None:
                stats.overlap_s += time.perf_counter() - t_launch
            t0 = time.perf_counter()
            accs_by_group = _ragged_collect(batch, acc, lane_ctx["slices"])
            if stats is not None:
                stats.exec_s += time.perf_counter() - t0
            for (gi, _), accs in zip(live, accs_by_group):
                for ls, acc_s in zip(buf, accs):
                    yield gi, ExecResult(
                        ls.shard_id, ls.ell.v0, ls.ell.v1, np.asarray(acc_s),
                        batch_size=len(buf),
                    )

        pending = None
        buf: List[LoadedShard] = []
        for ls in loaded:
            buf.append(ls)
            if len(buf) >= self.batch_shards:
                nxt = dispatch(buf)
                buf = []
                if pending is not None:
                    yield from collect(pending)
                pending = nxt
        if buf:
            nxt = dispatch(buf)
            if pending is not None:
                yield from collect(pending)
            pending = nxt
        if pending is not None:
            yield from collect(pending)


class MeshLaneExecutor:
    """SPMD executor: route each loaded shard to its owning device's batch
    and dispatch every device's batch in ONE ``shard_map`` launch per live
    program group — "1 host read, G x D slices" (DESIGN.md §10).

    Shards buffer per device (by :class:`MeshPartition` ownership) up to
    ``batch_shards`` each; a flush dispatches ALL devices together, so the
    dispatch count is per SPMD program, not per device — each group's
    launch covers every device's slice.  Devices whose buffer is empty this
    round (inactive destination intervals pruned by the scheduler) ride
    along as identity-padded zero blocks inside the same program.

    ``backend="numpy"`` is the mesh EMULATION path: identical routing,
    flush cadence and accounting, but per-shard numpy-oracle calls and no
    jax import — the bitwise reference for the jnp/pallas mesh paths, safe
    under the memory-capped (jax-free) test tier.
    """

    def __init__(self, backend: str, partition, mesh=None, *,
                 batch_shards: int = 1, lanes: bool = False,
                 interpret: bool = True, ragged: bool = True):
        if backend not in LANE_BACKENDS:
            raise ValueError(
                f"unknown backend {backend}; have {sorted(LANE_BACKENDS)}"
            )
        if backend != "numpy" and mesh is None:
            raise ValueError("jnp/pallas mesh execution needs a jax Mesh")
        if batch_shards < 1:
            raise ValueError("batch_shards must be >= 1")
        self.backend_name = backend
        self.partition = partition
        self.mesh = mesh
        self.batch_shards = batch_shards
        self.lanes = lanes
        self.interpret = interpret
        #: RaggedFuse under the mesh: one shard_map step per flush covers
        #: every live group ("1 host read, 1 SPMD step, D slices"); the
        #: numpy emulation books the identical accounting.  Collection is
        #: double-buffered against the next round's host decode (ROADMAP
        #: mesh item (c)).
        self.ragged = bool(ragged)

    def run(
        self,
        loaded: Iterable[LoadedShard],
        msgs: np.ndarray,
        combine: str,
        stats: Optional[ExecStats] = None,
    ) -> Iterator[ExecResult]:
        """Single-program path (``VSWEngine.run``): the message array rides
        as a 1-lane group; the lane backends reduce to the plain ones for a
        single lane, so this is bitwise the single-device engine sweep."""
        groups: Sequence[GroupDispatch] = [(np.asarray(msgs)[None], combine)]
        for _, res in self.run_groups(loaded, groups, stats):
            yield ExecResult(res.shard_id, res.v0, res.v1, res.acc[0],
                             batch_size=res.batch_size)

    def run_groups(
        self,
        loaded: Iterable[LoadedShard],
        groups: Sequence[GroupDispatch],
        stats: Optional[ExecStats] = None,
    ) -> Iterator[Tuple[int, ExecResult]]:
        if self.ragged:
            yield from self._run_groups_ragged(loaded, groups, stats)
            return
        n_dev = self.partition.n_dev
        bufs: List[List[LoadedShard]] = [[] for _ in range(n_dev)]
        for ls in loaded:
            d = self.partition.device_of(ls.shard_id)
            bufs[d].append(ls)
            if len(bufs[d]) >= self.batch_shards:
                yield from self._flush(bufs, groups, stats)
                bufs = [[] for _ in range(n_dev)]
        if any(bufs):
            yield from self._flush(bufs, groups, stats)

    def _run_groups_ragged(self, loaded, groups, stats):
        """One SPMD step (or emulated round) per flush for ALL groups, with
        batch ``i``'s collect deferred until batch ``i+1``'s dispatch is in
        flight — the mesh double-buffer (DESIGN.md §14)."""
        live = [(gi, ga) for gi, ga in enumerate(groups) if ga is not None]
        if not live:
            for _ in loaded:
                pass
            return
        n_dev = self.partition.n_dev
        lane_ctx = None  # staged on first jax flush, reused across rounds
        k_total = sum(int(ga[0].shape[0]) for _, ga in live)
        if self.backend_name != "numpy":
            from repro.kernels.spmv_ell import ops as spmv_ops

        def dispatch(bufs):
            nonlocal lane_ctx
            t0 = time.perf_counter()
            total = sum(len(b) for b in bufs)
            with trace.span(
                "exec.dispatch",
                groups=len(live),
                shards=total,
                devices=sum(1 for b in bufs if b),
                backend=self.backend_name,
                ragged=True,
            ):
                if self.backend_name == "numpy":
                    fn = LANE_BACKENDS["numpy"]
                    results = []
                    for gi, (msgs, combine) in live:
                        for buf in bufs:
                            for ls in buf:
                                acc = np.asarray(
                                    fn(ls.csr, ls.ell, msgs, combine)
                                )
                                results.append((gi, ls, acc, len(buf)))
                    handle = ("numpy", results, None)
                else:
                    if lane_ctx is None:
                        ell = next(ls.ell for b in bufs for ls in b)
                        lane_ctx = spmv_ops.mesh_ragged_stage_lanes(
                            [ga[0] for _, ga in live],
                            [ga[1] for _, ga in live],
                            ell.num_windows * ell.window, n_dev,
                        )
                    h = spmv_ops.mesh_ragged_dispatch(
                        [[ls.ell for ls in buf] for buf in bufs], lane_ctx,
                        mesh=self.mesh, backend=self.backend_name,
                        interpret=self.interpret,
                    )
                    handle = ("mesh", h, list(bufs))
            if stats is not None:
                stats.dispatches += 1
                stats.ragged_dispatches += 1
                stats.batches += 1
                stats.shards_executed += total * len(live)
                stats.ragged_lanes += k_total
                for gi, ga in live:
                    stats.group_lanes[gi] = (
                        stats.group_lanes.get(gi, 0) + int(ga[0].shape[0])
                    )
                for d, buf in enumerate(bufs):
                    if buf:
                        stats.device_shards[d] = (
                            stats.device_shards.get(d, 0)
                            + len(buf) * len(live)
                        )
                        stats.device_dispatches[d] = (
                            stats.device_dispatches.get(d, 0) + 1
                        )
                stats.exec_s += time.perf_counter() - t0
            return handle, time.perf_counter()

        def collect(p):
            handle, t_launch = p
            if stats is not None:
                stats.overlap_s += time.perf_counter() - t_launch
            t0 = time.perf_counter()
            kind, payload, bufs = handle
            if kind == "numpy":
                results = payload
            else:
                results = []
                if payload is not None:
                    accs_by_group, _ = spmv_ops.mesh_ragged_collect(payload)
                    for (gi, _), accs_dev in zip(live, accs_by_group):
                        for buf, accs in zip(bufs, accs_dev):
                            for ls, acc in zip(buf, accs):
                                results.append(
                                    (gi, ls, np.asarray(acc), len(buf))
                                )
            if stats is not None:
                stats.exec_s += time.perf_counter() - t0
            for gi, ls, acc, bs in results:
                ref = ls.ref
                yield gi, ExecResult(ls.shard_id, ref.v0, ref.v1, acc,
                                     batch_size=bs)

        pending = None
        bufs: List[List[LoadedShard]] = [[] for _ in range(n_dev)]
        for ls in loaded:
            d = self.partition.device_of(ls.shard_id)
            bufs[d].append(ls)
            if len(bufs[d]) >= self.batch_shards:
                nxt = dispatch(bufs)
                bufs = [[] for _ in range(n_dev)]
                if pending is not None:
                    yield from collect(pending)
                pending = nxt
        if any(bufs):
            nxt = dispatch(bufs)
            if pending is not None:
                yield from collect(pending)
            pending = nxt
        if pending is not None:
            yield from collect(pending)

    def _flush(self, bufs, groups, stats):
        live = [(gi, ga) for gi, ga in enumerate(groups) if ga is not None]
        if not live:
            return
        t0 = time.perf_counter()
        results = []
        with trace.span(
            "exec.dispatch",
            groups=len(live),
            shards=sum(len(b) for b in bufs),
            devices=sum(1 for b in bufs if b),
            backend=self.backend_name,
        ):
            if self.backend_name == "numpy":
                fn = LANE_BACKENDS["numpy"]
                for gi, (msgs, combine) in live:
                    for buf in bufs:
                        for ls in buf:
                            acc = np.asarray(fn(ls.csr, ls.ell, msgs, combine))
                            results.append((gi, ls, acc, len(buf)))
            else:
                from repro.kernels.spmv_ell import ops as spmv_ops

                accs_by_group, _ = spmv_ops.ell_update_lanes_mesh_multi(
                    [[ls.ell for ls in buf] for buf in bufs],
                    [ga[0] for _, ga in live],
                    [ga[1] for _, ga in live],
                    mesh=self.mesh, backend=self.backend_name,
                    interpret=self.interpret,
                )
                for (gi, _), accs_dev in zip(live, accs_by_group):
                    for buf, accs in zip(bufs, accs_dev):
                        for ls, acc in zip(buf, accs):
                            results.append((gi, ls, np.asarray(acc), len(buf)))
        if stats is not None:
            total = sum(len(b) for b in bufs)
            # One SPMD launch per group covers every device's slice; the
            # numpy emulation books the same way so accounting is
            # backend-invariant (fig_mesh asserts conservation on it).
            stats.dispatches += len(live)
            stats.batches += 1
            stats.shards_executed += total * len(live)
            for d, buf in enumerate(bufs):
                if buf:
                    stats.device_shards[d] = (
                        stats.device_shards.get(d, 0) + len(buf) * len(live)
                    )
                    stats.device_dispatches[d] = (
                        stats.device_dispatches.get(d, 0) + len(live)
                    )
            stats.exec_s += time.perf_counter() - t0
        for gi, ls, acc, bs in results:
            ref = ls.ref
            yield gi, ExecResult(ls.shard_id, ref.v0, ref.v1, acc,
                                 batch_size=bs)


def make_executor(backend: str, *, batch_shards: int = 1):
    """Pick the executor for a backend: batching only exists for the ELL
    (jnp/pallas) backends; the numpy oracle always runs per-shard."""
    if batch_shards < 1:
        raise ValueError("batch_shards must be >= 1")
    if batch_shards > 1 and backend in _BATCHED_BACKENDS:
        return BatchedEllExecutor(backend, batch_shards)
    return PerShardExecutor(backend)


def make_lane_executor(backend: str, *, batch_shards: int = 1,
                       ragged: bool = True):
    """Executor whose dispatches carry a lane (concurrent-query) axis:
    same selection rule as :func:`make_executor`, except that ``ragged``
    (the RaggedFuse one-launch path, on by default) also wants the batched
    executor at ``batch_shards=1`` — a ragged flush is still 1 launch where
    the per-shard path would pay G."""
    if batch_shards < 1:
        raise ValueError("batch_shards must be >= 1")
    if backend in _BATCHED_LANE_BACKENDS and (batch_shards > 1 or ragged):
        return BatchedEllExecutor(backend, batch_shards, lanes=True,
                                  ragged=ragged)
    return PerShardExecutor(backend, lanes=True)
