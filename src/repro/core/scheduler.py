"""Shard scheduling: *what to run this iteration* (DESIGN.md §3).

First layer of the engine stack.  The scheduler owns selective scheduling
(paper §II-D-1): it builds the per-shard Bloom filters (or exact source
sets) during the loading-phase scan and, each iteration, turns the active
vertex set into an ordered :class:`ShardPlan` — the list of shards that can
possibly produce updates.  The pipeline (``repro.core.pipeline``) then
decides *how they get loaded* and the executor (``repro.core.executor``)
*how they execute*; the scheduler never touches shard payloads after the
initial scan.

Keeping the plan an explicit, immutable value (rather than an inline
``continue`` in the engine loop) is what makes prefetching possible at all:
the loader threads need to know the next N shards *before* the current one
finishes computing.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from ..obs import trace
from .bloom import BloomFilter
from .cache import ShardCache
from .sharding import GraphMeta
from .storage import IOStats, ShardStore

__all__ = ["ShardPlan", "ShardScheduler"]


@dataclasses.dataclass(frozen=True)
class ShardPlan:
    """Ordered work list for one iteration.

    ``shards`` preserves interval order (shard p writes ``DstVertexArray``
    interval p; processing in order keeps the paper's sliding-window access
    pattern and makes consecutive ELL shards batchable by the executor).

    ``lane_masks`` (lane-aware selective scheduling, serving layer): when
    the planner was given per-lane active sets, ``lane_masks[p][l]`` says
    whether lane ``l`` may produce updates from shard ``p``.  A planned
    shard always has at least one True lane; lanes masked False carry their
    previous interval values — correct for exactly the reason whole-shard
    skipping is correct, applied per lane (DESIGN.md §6).  ``None`` means
    every lane needs every planned shard (single-query plans, selective
    off, or lane masking disabled).

    For a FUSED sweep (DESIGN.md §9) the lane axis is the concatenation of
    every live lane across all program groups, in group order — the caller
    slices each shard's mask back per group; the plan itself is
    group-agnostic (one union active set, one mask row per live lane).
    """

    shards: List[int]
    skipped: List[int]
    selective_on: bool
    active_ratio: float
    plan_time_s: float
    lane_masks: Optional[Dict[int, np.ndarray]] = None
    #: mesh plans only (scheduler has a partition): planned shards grouped
    #: by owning device, interval order within each device; ``shards`` is
    #: then the round-robin interleave of these groups so the executor's
    #: per-device buffers fill evenly.  Devices whose destination intervals
    #: are all inactive get an EMPTY group — pruned host-side, they ride the
    #: SPMD program as identity blocks without a host read.  ``None`` on
    #: single-device plans.
    device_shards: Optional[List[List[int]]] = None

    @property
    def num_planned(self) -> int:
        return len(self.shards)

    @property
    def num_skipped(self) -> int:
        return len(self.skipped)

    def lane_shares(self, n_lanes: int) -> np.ndarray:
        """Mask-aware per-lane share of this plan's shard loads.

        Each planned shard's single load is split across ONLY the lanes it
        was dispatched for: with lane masks, lane ``l`` earns ``1/|mask_p|``
        for every planned shard ``p`` whose mask includes it; without
        masks, every lane dispatches every shard and earns
        ``num_planned / n_lanes``.  Either way the shares sum to
        ``num_planned`` (one unit per load), so attribution built on top of
        them is conserved — the serving layer multiplies by bytes-per-load
        to split an iteration's read volume (ROADMAP "mask-aware cost
        attribution" follow-on, closed in DESIGN.md §9).
        """
        shares = np.zeros(n_lanes, dtype=np.float64)
        if n_lanes == 0:
            return shares
        if self.lane_masks is None:
            shares[:] = self.num_planned / n_lanes
            return shares
        for p in self.shards:
            mask = self.lane_masks[p]
            shares[mask] += 1.0 / int(mask.sum())
        return shares


class ShardScheduler:
    """Selective scheduling over destination-interval shards."""

    def __init__(
        self,
        meta: GraphMeta,
        *,
        selective: bool = True,
        threshold: float = 1e-3,
        bloom_fp: float = 0.01,
        exact_selective: bool = False,
    ):
        self.meta = meta
        self.selective = selective
        self.threshold = threshold
        self.bloom_fp = bloom_fp
        self.exact_selective = exact_selective
        self.filters: Optional[List[BloomFilter]] = None
        self.exact_sources: Optional[List[np.ndarray]] = None
        self.loading_io: Optional[IOStats] = None
        #: set by the engine's mesh boot path (a
        #: :class:`repro.core.distributed.MeshPartition`); planning stays
        #: host-side — the partition only regroups/reorders the planned list.
        self.partition = None

    # ------------------------------------------------------------- loading
    def build_filters(
        self,
        store: ShardStore,
        *,
        warm_cache: Optional[ShardCache] = None,
        cache_fmt: str = "csr",
    ) -> None:
        """Data-loading phase: scan shards once to build Bloom filters and
        optionally warm the cache (paper §IV-B: 'during the data loading
        phase, GraphMP scans all edges to construct Bloom filters, and
        places processed shards in the cache if possible')."""
        with trace.span("bloom.build", shards=self.meta.num_shards):
            self._build_filters_impl(store, warm_cache=warm_cache, cache_fmt=cache_fmt)

    def _build_filters_impl(
        self,
        store: ShardStore,
        *,
        warm_cache: Optional[ShardCache],
        cache_fmt: str,
    ) -> None:
        io0 = store.io.snapshot()  # loading-phase I/O isn't per-iteration
        ps = list(range(self.meta.num_shards))
        filters: List[BloomFilter] = []
        exact: List[np.ndarray] = []
        delta = getattr(store, "delta", None)
        # Ingest-time warmup (PR 3 follow-on): shards whose unique-source
        # arrays were deposited by the external build (or a recompaction)
        # need no read at all; container bytes left warm seed the cache
        # without a read-back either.  Shards with pending deltas are never
        # cache-warmed here: their cache slot belongs to the overlay's CSR
        # path, and their pending insert sources are patched in by the
        # engine's delta refresh right after construction.
        need_read = [p for p in ps if store.warm_sources(p) is None]
        src_of: Dict[int, np.ndarray] = {}
        # Chunked bulk reads: a handful of shards resident at a time — the
        # SEM contract (the graph may exceed RAM) forbids materializing
        # every shard's bytes at once.
        chunk = 8
        for lo in range(0, len(need_read), chunk):
            part = need_read[lo: lo + chunk]
            csr_raws = store.shard_bytes_bulk(part, "csr")
            if warm_cache is not None and cache_fmt != "csr":
                warm_raws = store.shard_bytes_bulk(part, cache_fmt)
            else:
                warm_raws = csr_raws  # reuse: no second read of same bytes
            for p in part:
                src_of[p] = store.decode_csr(p, csr_raws[p]).unique_sources()
                if warm_cache is not None and not (
                    delta is not None and delta.has_pending(p)
                ):
                    warm_cache.put(p, warm_raws[p])
        for p in ps:
            srcs = src_of.get(p)
            if srcs is None:
                srcs = store.warm_sources(p)
                if warm_cache is not None and not (
                    delta is not None and delta.has_pending(p)
                ):
                    raw = store.warm_raw(p, cache_fmt)
                    if raw is not None:
                        warm_cache.put(p, raw)
            filters.append(BloomFilter.build(srcs, fp_rate=self.bloom_fp))
            exact.append(srcs)
        self.filters = filters
        self.exact_sources = exact
        self.loading_io = store.io - io0

    def refresh_shard_sources(self, p: int, srcs: np.ndarray) -> None:
        """Rebuild one shard's Bloom/exact filter after a delta publish or
        recompaction (``srcs`` = CURRENT unique sources of the logical
        shard, or any superset — supersets cost wasted loads, never
        correctness)."""
        if self.filters is not None:
            self.filters[p] = BloomFilter.build(srcs, fp_rate=self.bloom_fp)
        if self.exact_sources is not None:
            self.exact_sources[p] = srcs

    # ----------------------------------------------------------- decisions
    def shard_is_active(self, p: int, active_ids: np.ndarray) -> bool:
        """May shard ``p`` produce an update given the active set?  Bloom
        false positives cost a wasted load, never correctness."""
        if self.exact_selective:
            srcs = self.exact_sources[p]
            return bool(np.isin(active_ids, srcs, assume_unique=False).any())
        return self.filters[p].any_member(active_ids)

    def plan(
        self,
        active_ids: np.ndarray,
        *,
        lane_active: Optional[Sequence[np.ndarray]] = None,
    ) -> ShardPlan:
        """Emit this iteration's ordered shard plan.

        ``active_ids`` is the (union) active vertex set — for a fused
        multi-group sweep, the union across every live lane of every
        program group.  ``lane_active``
        optionally carries the per-lane active sets of a lane sweep
        (concatenated across groups in group order for fused sweeps); when
        selective scheduling engages, the plan then also computes a
        per-shard LANE MASK so the sweep can skip dispatch rows for lanes
        with no active source in the shard (ROADMAP "lane-aware selective
        scheduling" — compute saving; the shard is loaded once regardless).
        Masks can only be computed when selective is on, which implies every
        individual lane is below the threshold too (each lane's active set
        is a subset of the union).
        """
        with trace.span("sweep.plan") as sp:
            out = self._plan_impl(active_ids, lane_active=lane_active)
            sp.set(
                shards=len(out.shards),
                skipped=len(out.skipped),
                selective=out.selective_on,
            )
            return out

    def _plan_impl(
        self,
        active_ids: np.ndarray,
        *,
        lane_active: Optional[Sequence[np.ndarray]] = None,
    ) -> ShardPlan:
        t0 = time.perf_counter()
        active_ratio = len(active_ids) / max(self.meta.num_vertices, 1)
        use_selective = (
            self.selective
            and active_ratio < self.threshold
            and self.filters is not None
        )
        if not use_selective:
            return self._finalize(
                planned=list(range(self.meta.num_shards)),
                skipped=[],
                selective_on=False,
                active_ratio=active_ratio,
                t0=t0,
                lane_masks=None,
            )
        planned: List[int] = []
        skipped: List[int] = []
        lane_masks: Optional[Dict[int, np.ndarray]] = None
        if lane_active is not None and len(lane_active) > 1:
            lane_masks = {}
            for p in range(self.meta.num_shards):
                mask = np.fromiter(
                    (self.shard_is_active(p, ids) for ids in lane_active),
                    dtype=bool,
                    count=len(lane_active),
                )
                if mask.any():
                    planned.append(p)
                    lane_masks[p] = mask
                else:
                    skipped.append(p)
        else:
            for p in range(self.meta.num_shards):
                (planned if self.shard_is_active(p, active_ids) else skipped).append(p)
        return self._finalize(
            planned=planned,
            skipped=skipped,
            selective_on=True,
            active_ratio=active_ratio,
            t0=t0,
            lane_masks=lane_masks,
        )

    def _finalize(self, *, planned, skipped, selective_on, active_ratio, t0,
                  lane_masks) -> ShardPlan:
        """Shared plan tail: with a mesh partition, group the planned list
        by owning device and interleave round-robin (device-balanced load
        order for the executor's per-device buffers); device pruning falls
        out — a device with no planned shard gets an empty group and no
        host read.  Reordering is safe: per-shard accumulators touch
        disjoint destination intervals and ``lane_shares``/``lane_masks``
        are order-free."""
        device_shards = None
        if self.partition is not None:
            device_shards = self.partition.group(planned)
            planned = type(self.partition).interleave(device_shards)
        return ShardPlan(
            shards=planned,
            skipped=skipped,
            selective_on=selective_on,
            active_ratio=active_ratio,
            plan_time_s=time.perf_counter() - t0,
            lane_masks=lane_masks,
            device_shards=device_shards,
        )
