"""Shard scheduling: *what to run this iteration* (DESIGN.md §3).

First layer of the engine stack.  The scheduler owns selective scheduling
(paper §II-D-1): it builds the per-shard Bloom filters (or exact source
sets) during the loading-phase scan and, each iteration, turns the active
vertex set into an ordered :class:`ShardPlan` — the list of shards that can
possibly produce updates.  The pipeline (``repro.core.pipeline``) then
decides *how they get loaded* and the executor (``repro.core.executor``)
*how they execute*; the scheduler never touches shard payloads after the
initial scan.

Keeping the plan an explicit, immutable value (rather than an inline
``continue`` in the engine loop) is what makes prefetching possible at all:
the loader threads need to know the next N shards *before* the current one
finishes computing.
"""

from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Sequence

import numpy as np

from .bloom import BloomFilter
from .cache import ShardCache
from .sharding import GraphMeta
from .storage import IOStats, ShardStore

__all__ = ["ShardPlan", "ShardScheduler"]


@dataclasses.dataclass(frozen=True)
class ShardPlan:
    """Ordered work list for one iteration.

    ``shards`` preserves interval order (shard p writes ``DstVertexArray``
    interval p; processing in order keeps the paper's sliding-window access
    pattern and makes consecutive ELL shards batchable by the executor).
    """

    shards: List[int]
    skipped: List[int]
    selective_on: bool
    active_ratio: float
    plan_time_s: float

    @property
    def num_planned(self) -> int:
        return len(self.shards)

    @property
    def num_skipped(self) -> int:
        return len(self.skipped)


class ShardScheduler:
    """Selective scheduling over destination-interval shards."""

    def __init__(
        self,
        meta: GraphMeta,
        *,
        selective: bool = True,
        threshold: float = 1e-3,
        bloom_fp: float = 0.01,
        exact_selective: bool = False,
    ):
        self.meta = meta
        self.selective = selective
        self.threshold = threshold
        self.bloom_fp = bloom_fp
        self.exact_selective = exact_selective
        self.filters: Optional[List[BloomFilter]] = None
        self.exact_sources: Optional[List[np.ndarray]] = None
        self.loading_io: Optional[IOStats] = None

    # ------------------------------------------------------------- loading
    def build_filters(
        self,
        store: ShardStore,
        *,
        warm_cache: Optional[ShardCache] = None,
        cache_fmt: str = "csr",
    ) -> None:
        """Data-loading phase: scan shards once to build Bloom filters and
        optionally warm the cache (paper §IV-B: 'during the data loading
        phase, GraphMP scans all edges to construct Bloom filters, and
        places processed shards in the cache if possible')."""
        io0 = store.io.snapshot()  # loading-phase I/O isn't per-iteration
        ps = list(range(self.meta.num_shards))
        filters: List[BloomFilter] = []
        exact: List[np.ndarray] = []
        # Chunked bulk reads: a handful of shards resident at a time — the
        # SEM contract (the graph may exceed RAM) forbids materializing
        # every shard's bytes at once.
        chunk = 8
        for lo in range(0, len(ps), chunk):
            part = ps[lo: lo + chunk]
            csr_raws = store.shard_bytes_bulk(part, "csr")
            if warm_cache is not None and cache_fmt != "csr":
                warm_raws = store.shard_bytes_bulk(part, cache_fmt)
            else:
                warm_raws = csr_raws  # reuse: no second read of same bytes
            for p in part:
                srcs = store.decode_csr(p, csr_raws[p]).unique_sources()
                filters.append(BloomFilter.build(srcs, fp_rate=self.bloom_fp))
                exact.append(srcs)
                if warm_cache is not None:
                    warm_cache.put(p, warm_raws[p])
        self.filters = filters
        self.exact_sources = exact
        self.loading_io = store.io - io0

    # ----------------------------------------------------------- decisions
    def shard_is_active(self, p: int, active_ids: np.ndarray) -> bool:
        """May shard ``p`` produce an update given the active set?  Bloom
        false positives cost a wasted load, never correctness."""
        if self.exact_selective:
            srcs = self.exact_sources[p]
            return bool(np.isin(active_ids, srcs, assume_unique=False).any())
        return self.filters[p].any_member(active_ids)

    def plan(self, active_ids: np.ndarray) -> ShardPlan:
        """Emit this iteration's ordered shard plan."""
        t0 = time.perf_counter()
        active_ratio = len(active_ids) / max(self.meta.num_vertices, 1)
        use_selective = (
            self.selective
            and active_ratio < self.threshold
            and self.filters is not None
        )
        if not use_selective:
            return ShardPlan(
                shards=list(range(self.meta.num_shards)),
                skipped=[],
                selective_on=False,
                active_ratio=active_ratio,
                plan_time_s=time.perf_counter() - t0,
            )
        planned: List[int] = []
        skipped: List[int] = []
        for p in range(self.meta.num_shards):
            (planned if self.shard_is_active(p, active_ids) else skipped).append(p)
        return ShardPlan(
            shards=planned,
            skipped=skipped,
            selective_on=True,
            active_ratio=active_ratio,
            plan_time_s=time.perf_counter() - t0,
        )
