"""Graph container and synthetic graph generators.

A :class:`Graph` is the in-memory edge-list representation used by the
preprocessing phase (GraphMP paper §II-B).  Vertex ids are dense ``int32``
in ``[0, num_vertices)``.  Graphs are unweighted, exactly as in the paper
(``val(u, v) = 1`` for every edge).

Generators produce the power-law graphs the paper evaluates on (Twitter,
UK-2007, ... are power-law web/social graphs); we use RMAT with the standard
(a, b, c, d) = (0.57, 0.19, 0.19, 0.05) parameters plus a uniform generator
for non-skewed baselines.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

__all__ = [
    "Graph",
    "rmat_graph",
    "uniform_graph",
    "chain_graph",
    "star_graph",
    "from_edge_list",
]


@dataclasses.dataclass
class Graph:
    """An unweighted directed graph as parallel ``src``/``dst`` arrays."""

    num_vertices: int
    src: np.ndarray  # int32 [num_edges]
    dst: np.ndarray  # int32 [num_edges]

    def __post_init__(self) -> None:
        self.src = np.asarray(self.src, dtype=np.int32)
        self.dst = np.asarray(self.dst, dtype=np.int32)
        if self.src.shape != self.dst.shape:
            raise ValueError(
                f"src/dst length mismatch: {self.src.shape} vs {self.dst.shape}"
            )

    @property
    def num_edges(self) -> int:
        return int(self.src.shape[0])

    @property
    def avg_degree(self) -> float:
        return self.num_edges / max(self.num_vertices, 1)

    def out_degrees(self) -> np.ndarray:
        return np.bincount(self.src, minlength=self.num_vertices).astype(np.int64)

    def in_degrees(self) -> np.ndarray:
        return np.bincount(self.dst, minlength=self.num_vertices).astype(np.int64)

    def dedup(self) -> "Graph":
        """Remove duplicate edges (and self-loops are kept, as in the paper)."""
        key = self.src.astype(np.int64) * self.num_vertices + self.dst
        _, idx = np.unique(key, return_index=True)
        return Graph(self.num_vertices, self.src[idx], self.dst[idx])

    def reverse(self) -> "Graph":
        return Graph(self.num_vertices, self.dst.copy(), self.src.copy())

    def validate(self) -> None:
        if self.num_edges:
            for name, arr in (("src", self.src), ("dst", self.dst)):
                lo, hi = int(arr.min()), int(arr.max())
                if lo < 0 or hi >= self.num_vertices:
                    raise ValueError(
                        f"{name} ids out of range [0, {self.num_vertices}): "
                        f"min={lo} max={hi}"
                    )


def from_edge_list(edges, num_vertices: Optional[int] = None) -> Graph:
    """Build a graph from an iterable of ``(src, dst)`` pairs."""
    arr = np.asarray(list(edges), dtype=np.int64)
    if arr.size == 0:
        arr = arr.reshape(0, 2)
    n = int(num_vertices if num_vertices is not None else (arr.max() + 1 if arr.size else 0))
    g = Graph(n, arr[:, 0].astype(np.int32), arr[:, 1].astype(np.int32))
    g.validate()
    return g


def rmat_graph(
    num_vertices: int,
    num_edges: int,
    *,
    seed: int = 0,
    a: float = 0.57,
    b: float = 0.19,
    c: float = 0.19,
    dedup: bool = False,
) -> Graph:
    """RMAT power-law generator (Graph500 parameters by default).

    Vertex count is rounded up to a power of two internally; ids above
    ``num_vertices - 1`` are folded back with a modulo so the advertised
    vertex count is exact.
    """
    rng = np.random.default_rng(seed)
    scale = max(int(np.ceil(np.log2(max(num_vertices, 2)))), 1)
    src = np.zeros(num_edges, dtype=np.int64)
    dst = np.zeros(num_edges, dtype=np.int64)
    ab, abc = a + b, a + b + c
    for level in range(scale):
        r = rng.random(num_edges)
        right = r >= ab  # quadrants c or d -> src bit set
        lower = ((r >= a) & (r < ab)) | (r >= abc)  # quadrants b or d -> dst bit set
        src |= right.astype(np.int64) << level
        dst |= lower.astype(np.int64) << level
    src %= num_vertices
    dst %= num_vertices
    g = Graph(num_vertices, src.astype(np.int32), dst.astype(np.int32))
    if dedup:
        g = g.dedup()
    return g


def uniform_graph(num_vertices: int, num_edges: int, *, seed: int = 0) -> Graph:
    rng = np.random.default_rng(seed)
    src = rng.integers(0, num_vertices, size=num_edges, dtype=np.int64)
    dst = rng.integers(0, num_vertices, size=num_edges, dtype=np.int64)
    return Graph(num_vertices, src.astype(np.int32), dst.astype(np.int32))


def chain_graph(num_vertices: int) -> Graph:
    """0 -> 1 -> 2 -> ... — worst case for label-propagation convergence."""
    src = np.arange(num_vertices - 1, dtype=np.int32)
    return Graph(num_vertices, src, src + 1)


def star_graph(num_vertices: int) -> Graph:
    """All vertices point at vertex 0 — a single max-in-degree hub."""
    src = np.arange(1, num_vertices, dtype=np.int32)
    dst = np.zeros(num_vertices - 1, dtype=np.int32)
    return Graph(num_vertices, src, dst)


def small_world_graph(
    num_vertices: int, k: int = 4, shortcuts: float = 0.01, *, seed: int = 0
) -> Graph:
    """Ring + k-nearest + sparse random shortcuts (Watts-Strogatz-ish).

    High diameter (O(n / (n*shortcuts)) hops) makes SSSP/WCC run for many
    iterations with a travelling activity frontier — the regime where the
    paper's selective scheduling shines (Fig. 5b/5c).
    """
    rng = np.random.default_rng(seed)
    base = np.arange(num_vertices, dtype=np.int64)
    srcs, dsts = [], []
    for off in range(1, k + 1):
        srcs.append(base)
        dsts.append((base + off) % num_vertices)
        srcs.append((base + off) % num_vertices)
        dsts.append(base)
    n_short = int(num_vertices * shortcuts)
    if n_short:
        s = rng.integers(0, num_vertices, n_short)
        d = rng.integers(0, num_vertices, n_short)
        srcs.append(s)
        dsts.append(d)
    return Graph(
        num_vertices,
        np.concatenate(srcs).astype(np.int32),
        np.concatenate(dsts).astype(np.int32),
    )
