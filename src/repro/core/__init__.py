"""GraphMP core: the paper's semi-external-memory engine (DESIGN.md §1-2).

Public API::

    from repro.core import apps, VSWEngine, rmat_graph

    engine = VSWEngine.from_graph(rmat_graph(1_000_000, 20_000_000), root,
                                  num_shards=32, cache_bytes=1 << 30)
    result = engine.run(apps.pagerank())
"""

from . import apps
from .graph import (
    Graph,
    chain_graph,
    from_edge_list,
    rmat_graph,
    small_world_graph,
    star_graph,
    uniform_graph,
)
from .executor import BatchedEllExecutor, PerShardExecutor, make_executor
from .ingest import (
    IngestStats,
    csr_from_keys,
    ingest_edge_file,
    iter_edge_chunks,
    keys_of_csr,
    kway_merge,
    pack_keys,
    route_edges,
    write_edge_file,
)
from .pipeline import LoadedShard, PipelineStats, ShardPipeline
from .scheduler import ShardPlan, ShardScheduler
from .vsw import BACKENDS, IterStats, RunResult, VSWEngine

__all__ = [
    "apps",
    "Graph",
    "chain_graph",
    "from_edge_list",
    "rmat_graph",
    "small_world_graph",
    "star_graph",
    "uniform_graph",
    "BACKENDS",
    "IterStats",
    "RunResult",
    "VSWEngine",
    "ShardScheduler",
    "ShardPlan",
    "ShardPipeline",
    "PipelineStats",
    "LoadedShard",
    "PerShardExecutor",
    "BatchedEllExecutor",
    "make_executor",
    "IngestStats",
    "ingest_edge_file",
    "iter_edge_chunks",
    "write_edge_file",
    "pack_keys",
    "keys_of_csr",
    "csr_from_keys",
    "route_edges",
    "kway_merge",
]
