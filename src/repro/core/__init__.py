"""GraphMP core: the paper's semi-external-memory engine (DESIGN.md §1-2).

Public API::

    from repro.core import apps, VSWEngine, rmat_graph

    engine = VSWEngine.from_graph(rmat_graph(1_000_000, 20_000_000), root,
                                  num_shards=32, cache_bytes=1 << 30)
    result = engine.run(apps.pagerank())
"""

from . import apps
from .graph import (
    Graph,
    chain_graph,
    from_edge_list,
    rmat_graph,
    small_world_graph,
    star_graph,
    uniform_graph,
)
from .vsw import BACKENDS, IterStats, RunResult, VSWEngine

__all__ = [
    "apps",
    "Graph",
    "chain_graph",
    "from_edge_list",
    "rmat_graph",
    "small_world_graph",
    "star_graph",
    "uniform_graph",
    "BACKENDS",
    "IterStats",
    "RunResult",
    "VSWEngine",
]
