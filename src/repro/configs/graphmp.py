"""The paper's own workload: graph sizes for the GraphMP engine.

``EU2015`` is the paper's largest dataset (1.07B vertices, 91.8B edges);
used as ShapeDtypeStructs by the distributed dry-run.  ``TESTBED`` sizes
run for real in benchmarks.
"""

import dataclasses


@dataclasses.dataclass(frozen=True)
class GraphWorkload:
    name: str
    num_vertices: int
    num_edges: int


TWITTER = GraphWorkload("twitter", 42_000_000, 1_500_000_000)
UK2007 = GraphWorkload("uk-2007", 134_000_000, 5_500_000_000)
UK2014 = GraphWorkload("uk-2014", 788_000_000, 47_600_000_000)
EU2015 = GraphWorkload("eu-2015", 1_070_000_000, 91_800_000_000)

WORKLOADS = {w.name: w for w in (TWITTER, UK2007, UK2014, EU2015)}
