"""Qwen2.5-3B: GQA with QKV bias. [hf:Qwen/Qwen2.5-0.5B family; hf]
36L d=2048 16H kv=2 hd=128 ff=11008 SwiGLU vocab=151936, tied embeddings."""

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b",
    family="dense",
    num_layers=36,
    d_model=2048,
    num_heads=16,
    num_kv_heads=2,
    head_dim=128,
    d_ff=11008,
    vocab_size=151936,
    mlp_type="swiglu",
    qkv_bias=True,
    tie_embeddings=True,
    rope_theta=1_000_000.0,
)
