"""Architecture registry: ``get_config(arch_id)`` for every assigned arch.

Each module defines ``CONFIG``; ids use dashes (CLI: ``--arch yi-6b``).
"""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.config import ModelConfig, ShapeConfig, SHAPES, smoke_config

_MODULES = {
    "paligemma-3b": "paligemma_3b",
    "yi-6b": "yi_6b",
    "qwen2.5-3b": "qwen2_5_3b",
    "qwen2.5-32b": "qwen2_5_32b",
    "gemma-7b": "gemma_7b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe",
    "whisper-large-v3": "whisper_large_v3",
    "jamba-1.5-large-398b": "jamba_1_5_large",
    "xlstm-350m": "xlstm_350m",
}


def list_archs() -> List[str]:
    return sorted(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; have {list_archs()}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


#: shape cells skipped per arch (reasons in DESIGN.md section 4):
#: long_500k needs a sub-quadratic path - only the SSM/hybrid archs run it.
def applicable_shapes(arch: str) -> List[str]:
    cfg = get_config(arch)
    shapes = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.family in ("hybrid", "ssm"):
        shapes.append("long_500k")
    return shapes
