"""PaliGemma-3B backbone: SigLIP frontend (STUB) + Gemma-2B-class decoder.
[arXiv:2407.07726; hf]  18L d=2048 8H MQA(kv=1) hd=256 ff=16384 GeGLU
vocab=257216; vision patches enter as 256 precomputed prefix embeddings."""

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    mlp_type="geglu",
    tie_embeddings=True,
    frontend="vision_stub",
    prefix_len=256,
)
