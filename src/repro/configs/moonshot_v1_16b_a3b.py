"""Moonlight-16B-A3B (kimi/moonshot): MoE 64 experts top-6.
[hf:moonshotai/Moonlight-16B-A3B; hf]  48L d=2048 16H kv=16 hd=128
expert ff=1408 vocab=163840; every layer MoE."""

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=163840,
    mlp_type="swiglu",
    num_experts=64,
    top_k=6,
    moe_every=1,
)
