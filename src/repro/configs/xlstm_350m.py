"""xLSTM-350M: sLSTM + mLSTM blocks (3:1 mLSTM:sLSTM interleave).
[arXiv:2405.04517]  24L d=1024 4H vocab=50304, d_ff=0 (blocks carry their
own up/down projections), tied embeddings."""

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    head_dim=256,
    d_ff=0,
    vocab_size=50304,
    tie_embeddings=True,
    ssm_kind="xlstm",
    slstm_every=4,
    ssm_expand=2,
    ssm_chunk=128,
)
