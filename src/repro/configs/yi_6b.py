"""Yi-6B: llama-architecture GQA decoder. [arXiv:2403.04652; hf]
32L d=4096 32H kv=4 hd=128 ff=11008 SwiGLU vocab=64000."""

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="yi-6b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=11008,
    vocab_size=64000,
    mlp_type="swiglu",
    rope_theta=5_000_000.0,
)
