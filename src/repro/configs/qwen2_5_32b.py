"""Qwen2.5-32B: GQA with QKV bias. [hf:Qwen/Qwen2.5 family; hf]
64L d=5120 40H kv=8 hd=128 ff=27648 SwiGLU vocab=152064."""

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=27648,
    vocab_size=152064,
    mlp_type="swiglu",
    qkv_bias=True,
    rope_theta=1_000_000.0,
)
