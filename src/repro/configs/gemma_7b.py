"""Gemma-7B: GeGLU, head_dim=256, MHA (kv=16). [arXiv:2403.08295; hf]
28L d=3072 16H kv=16 hd=256 ff=24576 vocab=256000, tied embeddings,
embeddings scaled by sqrt(d_model)."""

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b",
    family="dense",
    num_layers=28,
    d_model=3072,
    num_heads=16,
    num_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab_size=256000,
    mlp_type="geglu",
    tie_embeddings=True,
)
