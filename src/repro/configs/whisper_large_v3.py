"""Whisper-large-v3 backbone: enc-dec transformer; conv audio frontend is a
STUB (input_specs supplies precomputed frame embeddings). [arXiv:2212.04356]
32+32L d=1280 20H kv=20 hd=64 ff=5120 GELU vocab=51866, encoder seq 1500."""

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    num_layers=32,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab_size=51866,
    mlp_type="gelu",
    encdec=True,
    num_encoder_layers=32,
    encoder_seq=1500,
    frontend="audio_stub",
)
