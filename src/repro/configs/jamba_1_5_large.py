"""Jamba-1.5-Large (398B total): Mamba+attention 7:1 interleave, MoE 16e
top-2 on every other layer. [arXiv:2403.19887; hf]
72L d=8192 64H kv=8 hd=128 ff=24576 vocab=65536.
TPU adaptation: Mamba-1 selective scan -> chunked SSD form (DESIGN.md §7).
"""

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    dense_d_ff=24576,
    vocab_size=65536,
    mlp_type="swiglu",
    num_experts=16,
    top_k=2,
    moe_every=2,
    attn_every=8,
    attn_offset=4,
    ssm_kind="ssd",
    ssm_state=128,
    ssm_head_dim=256,
    ssm_expand=2,
    ssm_chunk=128,
)
