"""Session result cache: (program, source, graph-version) -> QueryResult.

Per-source queries repeat heavily in serving workloads (the same handful of
sources dominate traffic), and a finished query's result is immutable until
the graph changes — so results are cached under a key that includes the
service's ``graph_version`` and hits bypass the lane queue entirely.
Bumping the version on a graph update invalidates every cached result
without scanning (stale keys simply age out of the LRU).

Thread-safe: ``submit`` runs on caller threads while the serve worker
populates entries.
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Any, Callable, Hashable, Optional

__all__ = ["SessionCache"]


class SessionCache:
    """Bounded LRU mapping of query keys to finished results."""

    def __init__(self, capacity: int = 256):
        if capacity < 0:
            raise ValueError("capacity must be >= 0")
        self.capacity = capacity
        self._items: "OrderedDict[Hashable, Any]" = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def get(
        self,
        key: Hashable,
        predicate: Optional[Callable[[Any], bool]] = None,
    ) -> Optional[Any]:
        """Look up ``key``; with ``predicate``, a present-but-unsuitable
        entry counts as a MISS (and is not refreshed) so the hit rate
        reflects queries actually served from cache."""
        with self._lock:
            if key in self._items:
                value = self._items[key]
                if predicate is None or predicate(value):
                    self._items.move_to_end(key)
                    self.hits += 1
                    return value
            self.misses += 1
            return None

    def put(self, key: Hashable, value: Any) -> None:
        if self.capacity == 0:
            return
        with self._lock:
            if key in self._items:
                self._items.move_to_end(key)
            self._items[key] = value
            while len(self._items) > self.capacity:
                self._items.popitem(last=False)  # evict LRU

    def drop_stale_versions(self, current_version: int) -> int:
        """Remove entries cached under an older graph version.

        Version keys already make stale entries unreachable (lookups use
        the CURRENT version); this reclaims their capacity eagerly after a
        graph update instead of letting dead entries crowd out live ones.
        Keys are ``(program_key, source, graph_version)`` tuples — finer,
        per-shard invalidation would be unsound without tracking which
        shards each query's result depends on (any edge mutation can move
        any downstream distance/score).  Returns the number dropped.
        """
        with self._lock:
            stale = [
                k for k in self._items
                if isinstance(k, tuple) and k and k[-1] != current_version
            ]
            for k in stale:
                del self._items[k]
            return len(stale)

    def entries(self):
        """Snapshot of (key, value) pairs in LRU -> MRU order — the warm-
        state checkpoint (``repro.checkpoint.warm_state``) persists these
        so a restarted service answers repeat queries from cache again."""
        with self._lock:
            return list(self._items.items())

    def clear(self) -> None:
        with self._lock:
            self._items.clear()

    def __len__(self) -> int:
        with self._lock:
            return len(self._items)
