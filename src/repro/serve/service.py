"""GraphService: a warm, concurrent multi-query serving front end.

One resident :class:`~repro.core.vsw.VSWEngine` (Bloom filters built once,
cache warm, prefetch pool up) answers a stream of per-source queries.
Callers ``submit()`` from any thread and get a ``Future``; a single serve
worker forms *fusion sets* from the pending queue
(:class:`~repro.serve.batcher.LaneBatcher`): requests sharing a combine
algebra — BFS, SSSP and WCC together, PPR at any damping together — fuse
into one lane table, and up to ``max_groups`` algebra groups interleave
on ONE shard stream (:class:`~repro.serve.sweep.FusedSweep`: each shard
loads once and dispatches once per group).  Each future resolves the
moment its lane retires — queries admitted together share every shard
load, and lanes freed by early convergence are backfilled from the queue
mid-sweep, per group.

Admission control is the lane budget: at most ``max_lanes`` queries per
group and ``max_groups`` groups ride one sweep, and (optionally) at most
``max_pending`` may queue — :class:`ServiceOverloaded` is the
back-pressure signal.  Finished results land in a
:class:`~repro.serve.session.SessionCache` keyed by
(program, source, graph-version), so repeat queries bypass the queue.
"""

from __future__ import annotations

import contextlib
import dataclasses
import itertools
import os
import threading
import time
from collections import deque
from concurrent.futures import Future
from typing import Any, Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.core.apps import LaneProgram, get_lane_program
from repro.core.graph import Graph
from repro.core.pipeline import ShardLoadError
from repro.core.vsw import VSWEngine
from repro.obs import trace
from repro.obs.metrics import MetricsRegistry

from .batcher import LaneBatcher
from .session import SessionCache
from .sweep import FusedSweep, LaneResult, LaneSeed

__all__ = ["GraphService", "QueryResult", "ServiceOverloaded"]


class ServiceOverloaded(RuntimeError):
    """Raised by ``submit`` when the pending queue is at its admission cap."""


@dataclasses.dataclass
class QueryResult:
    """One answered query plus its attributed cost."""

    request_id: int
    program: str
    source: int
    values: np.ndarray  # [n] final vertex values
    iterations: int
    converged: bool
    latency_s: float  # submit -> future resolution (queue wait + sweep)
    # Mask-aware cost shares: each planned shard's load (and the bytes
    # behind it) is split over only the lanes it was dispatched for, so a
    # query masked out of most of the stream is billed accordingly.
    bytes_read: float  # this query's share of sweep disk bytes
    shard_loads: float  # this query's share of shard fetches
    lanes: int  # lane capacity of the fusion GROUP that served it
    # Tail-latency decomposition (GraphScope, DESIGN.md §11): time spent
    # queued before a fusion set admitted the query, and time riding the
    # sweep after admission.  ``latency_s == queue_wait_s + sweep_s`` for
    # lane-served results; both are 0.0 for session-cache hits.
    queue_wait_s: float = 0.0
    sweep_s: float = 0.0
    cached: bool = False  # served from the session cache
    groups: int = 1  # program groups interleaved on the serving sweep
    # The graph version this result was computed at.  Every sweep runs
    # pinned to ONE version (updates publish strictly between sweeps), so
    # a result is never a mix of two edge states — tests assert values
    # match a from-scratch build of exactly this version's edge list.
    graph_version: int = 0


@dataclasses.dataclass
class UpdateResult:
    """One applied mutation batch: the version that made it visible.

    ``edges_inserted`` / ``edges_removed`` / ``shards_touched`` describe
    the PUBLISH GROUP the batch rode in: batches staged while the worker
    was busy are folded into one publish (one version bump), and every
    batch's future reports that group's aggregate extent, not a per-batch
    split.
    """

    graph_version: int
    edges_inserted: int
    edges_removed: int
    shards_touched: Tuple[int, ...]
    latency_s: float


@dataclasses.dataclass
class _PendingUpdate:
    """One staged ``apply_updates`` batch awaiting the next publish point."""

    inserts: Optional[Tuple]
    deletes: Optional[Tuple]
    future: "Future[UpdateResult]"
    t_submit: float


@dataclasses.dataclass
class _Pending:
    """Queue entry; doubles as the sweep's lane token."""

    request_id: int
    program: str
    source: int
    max_iters: int
    prog: LaneProgram
    future: "Future[QueryResult]"
    t_submit: float
    t_admit: float = 0.0  # set when a fusion set takes the entry

    @property
    def key(self) -> Tuple:
        return self.prog.key

    @property
    def combine_key(self) -> Tuple:
        return self.prog.combine_key


class GraphService:
    """Serve concurrent BFS / SSSP / WCC / PPR queries from one warm
    engine, fusing and interleaving them onto shared shard streams.

    Mesh serving (DESIGN.md §10): pass ``mesh=`` through any factory — it
    flows to :class:`VSWEngine` with the other engine kwargs, and every
    sweep the worker runs then dispatches per-group per-device slices
    ("1 host read, G x D slices").  Results are bitwise those of the
    single-device service; ``stats()["mesh_devices"]`` reports D."""

    def __init__(
        self,
        engine: VSWEngine,
        *,
        max_lanes: int = 16,
        pad_pow2: bool = True,
        batch_shards: int = 1,
        session_entries: int = 256,
        max_pending: Optional[int] = None,
        graph_version: int = 0,
        lane_selective: bool = True,
        auto_compact_runs: Optional[int] = None,
        max_groups: int = 2,
        fuse_programs: bool = True,
        ragged: bool = True,
    ):
        self.engine = engine
        self.batcher = LaneBatcher(
            max_lanes, pad_pow2=pad_pow2, max_groups=max_groups,
            fuse_programs=fuse_programs,
        )
        self.sessions = SessionCache(session_entries)
        self.batch_shards = batch_shards
        self.max_pending = max_pending
        self.graph_version = graph_version
        self.lane_selective = lane_selective
        # RaggedFuse (DESIGN.md §14): one ragged kernel launch per shard
        # batch covers every fusion group (jnp/pallas lane executors).
        self.ragged = ragged
        # Set by ``from_store(warm_state=...)``: the apply_warm_state
        # report (None = no warm restore was attempted).
        self.warm_restore_report: Optional[Dict[str, Any]] = None

        # GraphScope instruments (DESIGN.md §11): latency histograms fed at
        # retirement, sweep stats ingested after every fusion set so
        # ``metrics_snapshot()`` can report tail latency + stage timings
        # and ``metrics.verify_conservation()`` covers live sweeps.
        self.metrics = MetricsRegistry()
        # Typed error/outcome counters (GraphPulse, DESIGN.md §13), created
        # eagerly so every snapshot carries them even at zero.
        self.metrics.counter("query.completed")
        self.metrics.counter("query.rejected")
        self.metrics.counter("shard.load_error")
        # GraphPulse telemetry (``start_telemetry``): a cadenced ticker
        # closing TimeSeriesRegistry windows + optional SLO evaluation.
        self._telemetry = None  # (ts, monitor, thread, stop_event)
        self._telemetry_lock = threading.Lock()
        # Window marks for ``metrics_snapshot(window=True)``.
        self._window_marks: Dict[str, Any] = {}

        self._pending: Deque[_Pending] = deque()
        self._updates: Deque["_PendingUpdate"] = deque()
        self._edge_log = None  # lazy: most services never mutate
        self._cond = threading.Condition()
        self._closed = False
        self._engine_closed = False
        # Serializes the close body: concurrent/repeated close() calls must
        # each return only after the worker AND any in-flight background
        # compaction have fully stopped (never release the engine while a
        # compaction still holds shard locks).
        self._close_lock = threading.Lock()
        self._ids = itertools.count()
        # aggregate counters (worker-thread writes, snapshot under the lock)
        self._queries_done = 0
        self._sweeps = 0
        self._multi_group_sweeps = 0
        self._updates_done = 0
        self._bytes_read = 0.0
        self._shard_loads = 0.0
        # LSM-style background maintenance: absorb pending delta runs into
        # base shards once a shard accumulates ``auto_compact_runs`` runs.
        # The recompactor coordinates with sweeps via overlay pins, so it is
        # safe to run while queries are in flight.
        self._recompactor = None
        if auto_compact_runs is not None:
            from repro.delta import Recompactor

            self._recompactor = Recompactor(
                engine.store, min_runs=auto_compact_runs
            )
            self._recompactor.start()
        self._worker = threading.Thread(
            target=self._serve_loop, name="graphserve-worker", daemon=True
        )
        self._worker.start()

    # ------------------------------------------------------------- factory
    #
    # All factories split kwargs the same way: service-level options are
    # consumed here, everything else flows to the engine constructor.
    # Only names are listed — ``__init__`` stays the single source of the
    # default values.
    _SERVICE_KWARGS = (
        "max_lanes",
        "pad_pow2",
        "batch_shards",
        "session_entries",
        "max_pending",
        "graph_version",
        "lane_selective",
        "auto_compact_runs",
        "max_groups",
        "fuse_programs",
        "ragged",
    )

    @classmethod
    def _split(cls, kwargs: Dict[str, Any]) -> Dict[str, Any]:
        """Pop the service-level options the caller actually passed."""
        return {k: kwargs.pop(k) for k in cls._SERVICE_KWARGS if k in kwargs}

    @classmethod
    def from_graph(cls, graph: Graph, root: str, **kwargs) -> "GraphService":
        """Preprocess ``graph`` into ``root``, warm an engine, start serving.

        Service options (``max_lanes``, ``pad_pow2``, ``batch_shards``,
        ``session_entries``, ``max_pending``) are consumed here; remaining
        kwargs go to :meth:`VSWEngine.from_graph`.
        """
        service_kw = cls._split(kwargs)
        return cls(VSWEngine.from_graph(graph, root, **kwargs), **service_kw)

    @classmethod
    def from_store(
        cls, root: str, *, warm_state=None, prewarm_cache: bool = False,
        **kwargs,
    ) -> "GraphService":
        """Serve from an already-populated store directory (e.g. built by
        ``ShardStore.ingest``) without ever holding a ``Graph`` object.

        ``warm_state`` (DESIGN.md §12) restores a warm-restart checkpoint:
        pass a :class:`repro.checkpoint.warm_state.WarmState` or a
        checkpoint directory (the latest snapshot is loaded).  Still-valid
        per-shard source arrays are deposited before the engine builds its
        filters — those shards are not read at boot — and, when the store's
        graph content is unchanged since the snapshot, the session cache is
        repopulated so repeat queries hit immediately.  The store on disk
        is ALWAYS authoritative: a stale or mismatched snapshot degrades to
        a cold boot (see ``warm_restore_report`` on the returned service),
        never to wrong answers.  ``prewarm_cache=True`` additionally
        re-reads the snapshot's byte-cache warm set into the new engine's
        cache (boot I/O traded for first-query hits).
        """
        service_kw = cls._split(kwargs)
        if warm_state is None:
            svc = cls(VSWEngine.from_store(root, **kwargs), **service_kw)
            svc.warm_restore_report = None
            return svc
        from repro.checkpoint import warm_state as _ws
        from repro.core.storage import ShardStore

        ws = warm_state
        if isinstance(ws, (str, os.PathLike)):
            ws = _ws.WarmStateCheckpointer(str(ws)).restore()
        store = ShardStore(root, emulate_bw=kwargs.pop("emulate_bw", None))
        report = _ws.apply_warm_state(store, ws)
        engine = VSWEngine(store, **kwargs)
        if prewarm_cache:
            report["cache_prewarmed"] = _ws.prewarm_cache(engine, ws)
        if report["valid"]:
            service_kw.setdefault("graph_version", ws.graph_version)
        svc = cls(engine, **service_kw)
        report["sessions_restored"] = svc._restore_warm_sessions(ws, report)
        svc.warm_restore_report = report
        return svc

    @classmethod
    def from_edge_file(cls, path: str, root: str, **kwargs) -> "GraphService":
        """Stream-ingest an edge file into ``root`` (bounded-memory external
        build) and start serving from it — the serving-scale boot path for
        graphs whose edge list exceeds RAM."""
        service_kw = cls._split(kwargs)
        return cls(VSWEngine.from_edge_file(path, root, **kwargs), **service_kw)

    # -------------------------------------------------------------- submit
    def submit(
        self,
        program: str,
        source: int,
        *,
        max_iters: int = 100,
        **params,
    ) -> "Future[QueryResult]":
        """Queue one query; the future resolves when its lane retires.

        Session-cache hits resolve immediately without occupying a lane.
        Raises :class:`ServiceOverloaded` when ``max_pending`` is reached.
        """
        if self._closed:
            raise RuntimeError("GraphService is closed")
        if not (0 <= source < self.engine.meta.num_vertices):
            raise ValueError(f"source {source} out of range")
        prog = get_lane_program(program, **params)
        t0 = time.perf_counter()
        fut: "Future[QueryResult]" = Future()

        cache_key = (prog.key, int(source), self.graph_version)
        # A cached result answers this request iff it converged within the
        # budget or ran exactly the requested budget; an unsuitable entry
        # counts as a miss (the query re-runs on a lane).
        cached = self.sessions.get(
            cache_key,
            lambda c: (c.converged and c.iterations <= max_iters)
            or c.iterations == max_iters,
        )
        if cached is not None:
            latency = time.perf_counter() - t0
            self.metrics.histogram("query.latency_s").record(latency)
            self.metrics.counter("query.completed").add(1)
            trace.instant("service.cache_hit", program=program, source=source)
            fut.set_result(
                dataclasses.replace(
                    cached,
                    request_id=next(self._ids),
                    values=cached.values.copy(),
                    latency_s=latency,
                    queue_wait_s=0.0,
                    sweep_s=0.0,
                    bytes_read=0.0,
                    shard_loads=0.0,
                    cached=True,
                )
            )
            return fut

        entry = _Pending(
            request_id=next(self._ids),
            program=program,
            source=int(source),
            max_iters=max_iters,
            prog=prog,
            future=fut,
            t_submit=t0,
        )
        with trace.span("service.admit", program=program, source=source):
            with self._cond:
                if self._closed:
                    raise RuntimeError("GraphService is closed")
                if (
                    self.max_pending is not None
                    and len(self._pending) >= self.max_pending
                ):
                    # Typed back-pressure accounting (GraphPulse): the SLO
                    # monitor's error-rate objective reads this counter.
                    self.metrics.counter("query.rejected").add(1)
                    trace.instant(
                        "service.rejected", program=program, source=source
                    )
                    raise ServiceOverloaded(
                        f"pending queue at admission cap ({self.max_pending})"
                    )
                self._pending.append(entry)
                self._cond.notify_all()
        return fut

    def query(
        self, program: str, source: int, *, max_iters: int = 100, **params
    ) -> QueryResult:
        """Blocking convenience wrapper around :meth:`submit`."""
        return self.submit(
            program, source, max_iters=max_iters, **params
        ).result()

    @contextlib.contextmanager
    def submit_batch(self):
        """Admit several queries atomically: while the block is open the
        serve worker cannot pop the queue, so everything submitted inside
        is eligible for ONE fusion set (maximal fusion/interleaving
        instead of whatever prefix the worker races to).  Do not block on
        ``Future.result()`` inside the block — the worker cannot run
        until it closes.
        """
        with self._cond:
            yield self

    # ------------------------------------------------------------- updates
    def apply_updates(
        self, inserts=None, deletes=None
    ) -> "Future[UpdateResult]":
        """Stage one edge-mutation batch; the future resolves once the
        batch is PUBLISHED (durable delta runs + new graph version).

        Updates become visible atomically between sweeps: queries already
        riding a sweep finish on the version they started at; any query
        batch formed after the publish runs on the new version.  Batch
        semantics (deletes before inserts, delete removes all copies) are
        :class:`repro.delta.EdgeLog`'s.  Vertex ids must lie in the store's
        fixed ``[0, num_vertices)`` range.
        """
        if self._closed:
            raise RuntimeError("GraphService is closed")
        from repro.delta.edgelog import _norm_edges  # validate on caller thread

        n = self.engine.meta.num_vertices
        ins = _norm_edges(inserts, n, "inserts")
        dels = _norm_edges(deletes, n, "deletes")
        fut: "Future[UpdateResult]" = Future()
        upd = _PendingUpdate(
            inserts=ins, deletes=dels, future=fut,
            t_submit=time.perf_counter(),
        )
        with self._cond:
            if self._closed:
                raise RuntimeError("GraphService is closed")
            self._updates.append(upd)
            self._cond.notify_all()
        return fut

    def _publish_updates(self, updates: List[_PendingUpdate]) -> None:
        """Publish staged mutation batches (worker thread, between sweeps)."""
        if self._edge_log is None:
            from repro.delta import EdgeLog

            self._edge_log = EdgeLog(self.engine.store)
        try:
            with trace.span("service.publish", batches=len(updates)):
                for u in updates:
                    self._edge_log.append(inserts=u.inserts, deletes=u.deletes)
                pub = self._edge_log.publish()
        except BaseException as exc:
            for u in updates:
                if not u.future.done():
                    u.future.set_exception(exc)
            return
        with self._cond:
            self.graph_version += 1
            version = self.graph_version
            self._updates_done += len(updates)
        self.sessions.drop_stale_versions(version)
        for u in updates:
            u.future.set_result(
                UpdateResult(
                    graph_version=version,
                    edges_inserted=pub.edges_inserted,
                    edges_removed=pub.edges_removed,
                    shards_touched=pub.shards_touched,
                    latency_s=time.perf_counter() - u.t_submit,
                )
            )

    # --------------------------------------------------------- worker loop
    def _serve_loop(self) -> None:
        while True:
            with self._cond:
                while (
                    not self._pending and not self._updates and not self._closed
                ):
                    self._cond.wait()
                if not self._pending and not self._updates and self._closed:
                    return
                updates: List[_PendingUpdate] = list(self._updates)
                self._updates.clear()
                groups = (
                    self.batcher.form_fused(self._pending)
                    if self._pending else []
                )
            if updates:
                # publish BEFORE the next sweep: the fusion set just formed
                # (and everything after it) runs on the new version; in-
                # flight work already finished — sweeps and publishes share
                # this worker thread, so they can never interleave.
                self._publish_updates(updates)
            if groups:
                self._run_fusion_set(groups)

    def _run_fusion_set(self, groups: List[List[_Pending]]) -> None:
        """Run one fusion set — up to ``max_groups`` algebra groups on one
        shared shard stream — resolving each future as its lane retires."""
        capacities = [self.batcher.capacity(len(g)) for g in groups]
        group_keys = [self.batcher.group_key(g[0]) for g in groups]
        n_groups = len(groups)
        resolved: set = set()
        admitted: List[_Pending] = [p for g in groups for p in g]
        t_admit0 = time.perf_counter()
        for p in admitted:
            p.t_admit = t_admit0

        # The whole sweep — including lanes backfilled mid-flight — runs at
        # this version: publishes only happen on this thread between sweeps.
        version = self.graph_version

        def backfill(group: int, n_free: int) -> List[LaneSeed]:
            with self._cond:
                taken = self.batcher.take_fusable(
                    self._pending, group_keys[group], n_free
                )
            t_admit = time.perf_counter()
            for p in taken:
                p.t_admit = t_admit
            admitted.extend(taken)
            return [
                LaneSeed(source=p.source, max_iters=p.max_iters, token=p,
                         program=p.prog)
                for p in taken
            ]

        def on_retire(res: LaneResult) -> None:
            p: _Pending = res.token
            now = time.perf_counter()
            with trace.span(
                "service.retire", program=p.program, source=p.source,
                group=res.group,
            ):
                qr = QueryResult(
                    request_id=p.request_id,
                    program=p.program,
                    source=p.source,
                    values=res.values,
                    iterations=res.iterations,
                    converged=res.converged,
                    latency_s=now - p.t_submit,
                    queue_wait_s=p.t_admit - p.t_submit,
                    sweep_s=now - p.t_admit,
                    bytes_read=res.bytes_read,
                    shard_loads=res.shard_loads,
                    lanes=capacities[res.group],
                    graph_version=version,
                    groups=n_groups,
                )
                self.metrics.histogram("query.latency_s").record(qr.latency_s)
                self.metrics.histogram("query.queue_wait_s").record(
                    qr.queue_wait_s
                )
                self.metrics.histogram("query.sweep_s").record(qr.sweep_s)
                # Cache a private copy: the caller owns ``qr.values`` and may
                # mutate it; later hits must still see the computed result.
                self.sessions.put(
                    (p.prog.key, p.source, version),
                    dataclasses.replace(qr, values=res.values.copy()),
                )
                self.metrics.counter("query.completed").add(1)
                resolved.add(p.request_id)
                with self._cond:
                    self._queries_done += 1
                    self._bytes_read += res.bytes_read
                    self._shard_loads += res.shard_loads
                p.future.set_result(qr)

        seed_groups = [
            [
                LaneSeed(source=p.source, max_iters=p.max_iters, token=p,
                         program=p.prog)
                for p in g
            ]
            for g in groups
        ]
        sweep = FusedSweep(
            self.engine,
            batch_shards=self.batch_shards,
            pad_pow2=self.batcher.pad_pow2,
            lane_selective=self.lane_selective,
            ragged=self.ragged,
        )
        try:
            with trace.span(
                "service.fusion_set",
                groups=n_groups,
                lanes=sum(len(g) for g in groups),
            ):
                sweep.run(seed_groups, backfill=backfill, on_retire=on_retire)
        except BaseException as exc:  # propagate to every unresolved caller
            if isinstance(exc, ShardLoadError):
                # Prefetch failures are a typed, SLO-visible error class.
                self.metrics.counter("shard.load_error").add(1)
            for p in admitted:
                if p.request_id not in resolved and not p.future.done():
                    p.future.set_exception(exc)
        finally:
            # Absorb the sweep's per-iteration stats: conservation
            # identities (incl. the mesh device splits) get declared per
            # iteration and stage-timing histograms feed metrics_snapshot.
            for st in sweep.iter_stats:
                self.metrics.ingest(st)
                self.metrics.histogram("stage.load_s").record(st.load_total_s)
                self.metrics.histogram("stage.load_wait_s").record(
                    st.load_wait_s
                )
                self.metrics.histogram("stage.exec_s").record(st.exec_s)
            with self._cond:
                self._sweeps += 1
                if n_groups > 1:
                    self._multi_group_sweeps += 1

    # --------------------------------------------------------------- stats
    def stats(self) -> Dict[str, Any]:
        """Aggregate serving counters (loads/bytes are lane-attributed)."""
        with self._cond:
            done = self._queries_done
            out = {
                "queries_completed": done,
                "sweeps": self._sweeps,
                "multi_group_sweeps": self._multi_group_sweeps,
                "pending": len(self._pending),
                "bytes_read_total": self._bytes_read,
                "shard_loads_total": self._shard_loads,
                "loads_per_query": self._shard_loads / done if done else 0.0,
                "session_hits": self.sessions.hits,
                "session_misses": self.sessions.misses,
                "updates_published": self._updates_done,
                "updates_pending": len(self._updates),
                "graph_version": self.graph_version,
                # mesh boot path (engine kwargs carry mesh=; DESIGN.md §10):
                # 0 on single-device services.
                "mesh_devices": (
                    self.engine.partition.n_dev
                    if getattr(self.engine, "partition", None) is not None
                    else 0
                ),
            }
        delta = self.engine.store.delta
        out["dirty_shards"] = len(delta.dirty_shards()) if delta else 0
        if self._recompactor is not None:
            out["shards_compacted"] = self._recompactor.total.shards_compacted
        return out

    def metrics_snapshot(self, *, window: bool = False) -> Dict[str, Any]:
        """Tail-latency + stage-timing snapshot (GraphScope, DESIGN.md §11).

        Percentile blocks are log-bucket estimates (≲3.5% relative error):
        per-query latency split into queue wait vs sweep time, per-sweep
        stage timings (load / exposed load wait / dispatch), and the
        outcome of replaying every conservation identity declared by the
        sweeps ingested so far (empty list = all conserved).  The
        benchmark harness writes the latency percentiles into consolidated
        ``BENCH_graphmp.json`` rows.

        ``window=True`` (GraphPulse, DESIGN.md §13) reports each histogram
        block over the records since the PREVIOUS windowed snapshot
        (logical reset-on-window via bucket diffs — the live instruments
        keep their lifetime data) and advances the window marks.

        Every snapshot carries an ``errors`` block (typed outcome
        counters: completions, admission-cap rejections, shard prefetch
        failures, tracer ring drops); when :meth:`start_telemetry` is
        active, ``timeseries`` and ``slo`` blocks report ring occupancy
        and the SLO monitor's burn rates / violation records.
        """
        trace.publish_drops(self.metrics)

        def block(name: str) -> Dict[str, Any]:
            hist = self.metrics.histogram(name)
            if not window:
                return hist.percentiles()
            win = hist.window_since(self._window_marks.get(name))
            self._window_marks[name] = hist.state()
            return win.percentiles()

        out: Dict[str, Any] = {
            "query_latency_s": block("query.latency_s"),
            "queue_wait_s": block("query.queue_wait_s"),
            "sweep_s": block("query.sweep_s"),
            "stages": {
                "iter_s": block("sweep.time_s"),
                "load_s": block("stage.load_s"),
                "load_wait_s": block("stage.load_wait_s"),
                "exec_s": block("stage.exec_s"),
            },
            "errors": {
                "completed": self.metrics.counter("query.completed").value,
                "rejected": self.metrics.counter("query.rejected").value,
                "shard_load_errors": self.metrics.counter(
                    "shard.load_error"
                ).value,
                "trace_dropped_events": trace.dropped_events(),
            },
            "conservation_violations": self.metrics.verify_conservation(
                strict=False
            ),
            "service": self.stats(),
        }
        with self._telemetry_lock:
            tel = self._telemetry
        if tel is not None:
            ts, monitor = tel[0], tel[1]
            out["timeseries"] = {
                "windows": ts.num_windows,
                "retained": len(ts.samples()),
                "dropped_samples": ts.dropped_samples,
                "interval_s": ts.interval_s,
            }
            if monitor is not None:
                out["slo"] = monitor.snapshot()
        return out

    # ----------------------------------------------------------- telemetry
    def start_telemetry(
        self,
        *,
        interval_s: float = 0.25,
        capacity: int = 2048,
        slos=None,
        windows=None,
    ) -> "Any":
        """Start the GraphPulse cadence: a daemon ticker that closes one
        :class:`~repro.obs.timeseries.TimeSeriesRegistry` window every
        ``interval_s`` seconds (and mirrors tracer ring drops into the
        registry).  Pass ``slos`` (a list of :class:`repro.obs.slo.SLO`)
        to also evaluate multi-window burn rates each tick — violations
        then appear in ``metrics_snapshot()["slo"]``.

        Returns the :class:`TimeSeriesRegistry`; the optional monitor is
        at :attr:`slo_monitor`.  Idempotent-hostile by design: starting
        twice raises (stop first) so two tickers can never double-diff
        the counter marks.
        """
        from repro.obs.slo import SLOMonitor
        from repro.obs.timeseries import TimeSeriesRegistry

        with self._telemetry_lock:
            if self._telemetry is not None:
                raise RuntimeError("telemetry already running")
            ts = TimeSeriesRegistry(
                self.metrics, capacity=capacity, interval_s=interval_s
            )
            monitor = None
            if slos:
                kw = {"windows": windows} if windows is not None else {}
                monitor = SLOMonitor(ts, slos, **kw)
            stop = threading.Event()

            def loop() -> None:
                while not stop.wait(interval_s):
                    trace.publish_drops(self.metrics)
                    ts.tick()
                    if monitor is not None:
                        monitor.evaluate()

            th = threading.Thread(
                target=loop, name="graphpulse-ticker", daemon=True
            )
            self._telemetry = (ts, monitor, th, stop)
            th.start()
            return ts

    def stop_telemetry(self, *, final_tick: bool = True):
        """Stop the telemetry ticker (no-op when not running); optionally
        close one last window so the run's tail isn't lost to cadence
        truncation.  Returns the (now-quiescent) TimeSeriesRegistry or
        None."""
        with self._telemetry_lock:
            tel, self._telemetry = self._telemetry, None
        if tel is None:
            return None
        ts, monitor, th, stop = tel
        stop.set()
        th.join()
        if final_tick:
            trace.publish_drops(self.metrics)
            ts.tick()
            if monitor is not None:
                monitor.evaluate()
        return ts

    @property
    def timeseries(self):
        """The live TimeSeriesRegistry, or None when telemetry is off."""
        with self._telemetry_lock:
            return self._telemetry[0] if self._telemetry else None

    @property
    def slo_monitor(self):
        """The live SLOMonitor, or None (telemetry off / no SLOs given)."""
        with self._telemetry_lock:
            return self._telemetry[1] if self._telemetry else None

    def bump_graph_version(self) -> int:
        """Invalidate all cached results (graph changed underneath).
        For actual edge mutations use :meth:`apply_updates`, which bumps
        the version itself at the publish point."""
        with self._cond:
            self.graph_version += 1
            v = self.graph_version
        self.sessions.drop_stale_versions(v)
        return v

    def compact(self):
        """Synchronously absorb every pending delta run into the base
        shards (safe while serving — coordinates with sweeps via overlay
        pins).  Returns :class:`repro.delta.CompactionStats`."""
        from repro.delta import Recompactor

        rc = self._recompactor or Recompactor(self.engine.store)
        return rc.compact(rc.dirty_shards())

    # ---------------------------------------------------------- warm state
    def save_warm_state(
        self, directory: str, *, step: Optional[int] = None, keep: int = 2
    ) -> str:
        """Snapshot this service's warm state (Bloom sources, byte-cache
        warm set, delta coordinates, session-cache results) into an atomic
        on-disk checkpoint (DESIGN.md §12).  Safe while serving; restore
        with ``GraphService.from_store(root, warm_state=directory)``.
        Returns the committed snapshot directory."""
        from repro.checkpoint.warm_state import (
            WarmStateCheckpointer,
            capture_warm_state,
        )

        state = capture_warm_state(self)
        return WarmStateCheckpointer(directory, keep=keep).save(
            state, step=step
        )

    def _restore_warm_sessions(self, ws, report) -> int:
        """Repopulate the session cache from a snapshot whose graph content
        provably matches the store (``report["sessions_valid"]``)."""
        if not report.get("valid") or not report.get("sessions_valid"):
            return 0
        n = 0
        for e in ws.sessions:
            qr = QueryResult(
                request_id=-1,
                program=e.program,
                source=e.source,
                values=np.asarray(e.values),
                iterations=e.iterations,
                converged=e.converged,
                latency_s=0.0,
                bytes_read=0.0,
                shard_loads=0.0,
                lanes=0,
                cached=True,
                graph_version=self.graph_version,
            )
            self.sessions.put(
                (tuple(e.key), int(e.source), self.graph_version), qr
            )
            n += 1
        return n

    # ----------------------------------------------------------- lifecycle
    def close(self, *, close_engine: bool = True) -> None:
        """Drain the queue, stop the worker, release the engine.

        Idempotent AND thread-safe — safe to call repeatedly, concurrently,
        and after ``__exit__``.  Every caller returns only once the serve
        worker has exited and any in-flight background compaction has been
        JOINED: the recompactor holds per-shard overlay locks mid-swap, so
        releasing the engine before it finishes (the old unguarded path,
        where a second closer could race the ``self._recompactor = None``
        hand-off) could tear down state a compaction was still using.
        """
        with self._cond:
            self._closed = True
            self._cond.notify_all()
        self.stop_telemetry(final_tick=False)
        with self._close_lock:
            if self._worker.is_alive():
                self._worker.join()  # drains queued queries AND staged updates
            rc, self._recompactor = self._recompactor, None
            if rc is not None:
                rc.stop()  # joins the maintenance thread mid-compaction too
            if close_engine and not self._engine_closed:
                self._engine_closed = True
                self.engine.close()

    def __enter__(self) -> "GraphService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
