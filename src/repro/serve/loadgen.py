"""GraphPulse load harness: closed- and open-loop query streams against a
:class:`~repro.serve.service.GraphService`.

A :class:`Workload` declares a weighted mix of query classes (BFS / SSSP /
WCC / PPR, each with its own ``max_iters`` and program params) plus an
optional concurrent mutation stream; :class:`LoadGenerator` replays it in
one of two modes:

``closed``
    Fixed concurrency: ``concurrency`` worker threads each submit one
    query, block on its future, record the outcome, repeat.  Offered load
    adapts to service speed — the classic closed-loop benchmark shape,
    immune to coordinated omission *by construction only for what it
    measures* (per-query service latency at a fixed population).
``open``
    Arrival-scheduled: one dispatcher submits at ``target_qps`` (evenly
    spaced, or exponential inter-arrivals with ``poisson=True``) without
    waiting for completions, so queueing delay is *measured*, not hidden
    — the load does not slow down because the service did.  Back-pressure
    (:class:`~repro.serve.service.ServiceOverloaded`) is recorded as a
    rejected operation, never retried silently.

Determinism discipline (the bitwise-oracle contract): the entire operation
schedule — per-op class, source, and every mutation batch's edge list —
is pre-generated from ``Workload.seed`` before any thread starts, so the
*set* of (program, source, params) queries and the exact edge state at
every graph version are reproducible no matter how threads interleave.
Each :class:`OpRecord` carries the answering ``graph_version`` and
(optionally) the result values; ``tests/test_pulse.py`` and the
``fig_qps`` benchmark replay every recorded op on a solo engine built at
exactly that version and assert ``np.array_equal``.

Phases: ops submitted during the first ``warmup_s`` seconds (or the first
``warmup_ops`` operations) are recorded but flagged ``phase="warmup"`` and
excluded from the report's rates/percentiles; submission stops when the
measure budget is exhausted; drain then waits for every in-flight future,
and those completions still land in their submission-time phase.  The
report therefore never truncates a tail latency mid-flight.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .service import GraphService, ServiceOverloaded

__all__ = [
    "LoadGenerator",
    "LoadReport",
    "OpRecord",
    "QueryClass",
    "UpdateRecord",
    "Workload",
]


@dataclasses.dataclass(frozen=True)
class QueryClass:
    """One weighted slice of the query mix."""

    program: str  # "bfs" | "sssp" | "wcc" | "ppr"
    weight: float = 1.0
    max_iters: int = 100
    params: Tuple[Tuple[str, Any], ...] = ()  # e.g. (("damping", 0.85),)

    def __post_init__(self) -> None:
        if self.weight <= 0:
            raise ValueError(f"class {self.program}: weight must be positive")
        if isinstance(self.params, dict):  # ergonomic: accept a dict
            object.__setattr__(
                self, "params", tuple(sorted(self.params.items()))
            )


@dataclasses.dataclass(frozen=True)
class Workload:
    """A declared mix + optional mutation stream, fully seeded."""

    classes: Tuple[QueryClass, ...]
    seed: int = 0
    #: every ``update_every`` queries, one ``apply_updates`` batch of
    #: ``update_batch`` random inserted edges rides along (0 = no stream).
    update_every: int = 0
    update_batch: int = 32

    def __post_init__(self) -> None:
        if not self.classes:
            raise ValueError("workload needs at least one query class")
        if isinstance(self.classes, list):
            object.__setattr__(self, "classes", tuple(self.classes))
        if self.update_every < 0 or self.update_batch <= 0:
            raise ValueError("bad update stream parameters")

    def plan(self, num_vertices: int, total_ops: int) -> "_Plan":
        """Pre-generate the deterministic operation schedule."""
        rng = np.random.default_rng(self.seed)
        w = np.asarray([c.weight for c in self.classes], dtype=np.float64)
        cls_idx = rng.choice(len(self.classes), size=total_ops, p=w / w.sum())
        sources = rng.integers(0, num_vertices, size=total_ops)
        updates: List[np.ndarray] = []
        if self.update_every > 0:
            n_upd = total_ops // self.update_every
            for _ in range(max(n_upd, 0)):
                updates.append(
                    rng.integers(
                        0, num_vertices, size=(self.update_batch, 2)
                    ).astype(np.int64)
                )
        return _Plan(
            cls_idx=cls_idx.astype(np.int64),
            sources=sources.astype(np.int64),
            updates=updates,
        )


@dataclasses.dataclass(frozen=True)
class _Plan:
    """The pre-generated schedule (immutable; shared across workers)."""

    cls_idx: np.ndarray  # [total] index into workload.classes
    sources: np.ndarray  # [total] query source vertices
    updates: List[np.ndarray]  # per-batch [b, 2] inserted edges


@dataclasses.dataclass
class OpRecord:
    """One submitted query and its outcome (success, rejection, or error)."""

    index: int  # position in the pre-generated schedule
    program: str
    source: int
    params: Tuple[Tuple[str, Any], ...]
    max_iters: int
    phase: str  # "warmup" | "measure"
    t_submit: float  # perf_counter at submit
    ok: bool = False
    rejected: bool = False
    error: Optional[str] = None
    latency_s: float = 0.0  # loadgen-observed: submit -> result available
    service_latency_s: float = 0.0  # service-attributed (QueryResult)
    queue_wait_s: float = 0.0
    sweep_s: float = 0.0
    cached: bool = False
    iterations: int = 0
    converged: bool = False
    graph_version: int = -1
    values: Optional[np.ndarray] = None  # kept when keep_values=True


@dataclasses.dataclass
class UpdateRecord:
    """One mutation batch: the edges inserted and the version that shows
    them — enough to rebuild the exact edge state at any version."""

    index: int  # which planned batch
    inserts: np.ndarray  # [b, 2] the actual edges
    t_submit: float
    ok: bool = False
    error: Optional[str] = None
    latency_s: float = 0.0
    graph_version: int = -1


@dataclasses.dataclass
class LoadReport:
    """Aggregates over the MEASURE phase + the full per-op record list."""

    mode: str
    concurrency: int
    target_qps: Optional[float]
    duration_s: float  # measure-phase wall span (first submit -> last done)
    submitted: int
    completed: int
    rejected: int
    errors: int
    cached: int
    qps: float  # completed measure-phase queries / duration_s
    offered_qps: float  # submitted measure-phase queries / submit span
    latency: Dict[str, float]  # exact percentiles over measure completions
    queue_wait: Dict[str, float]
    queue_wait_share: float  # sum(queue_wait) / sum(latency), measure phase
    per_class: Dict[str, int]  # measure-phase completions per program
    updates_submitted: int
    updates_published: int
    records: List[OpRecord]
    updates: List[UpdateRecord]
    warmup_records: int

    def summary(self) -> Dict[str, Any]:
        """The report minus the bulky record lists (export-friendly)."""
        out = dataclasses.asdict(self)
        out.pop("records")
        out.pop("updates")
        return out


def _percentiles(xs: List[float]) -> Dict[str, float]:
    """Exact (sorted-sample) percentiles, same keys as Histogram blocks."""
    if not xs:
        return {"count": 0, "mean": 0.0, "p50": 0.0, "p95": 0.0,
                "p99": 0.0, "max": 0.0}
    arr = np.sort(np.asarray(xs, dtype=np.float64))
    pick = lambda q: float(arr[min(int(q * (len(arr) - 1) + 0.5), len(arr) - 1)])
    return {
        "count": int(len(arr)),
        "mean": float(arr.mean()),
        "p50": pick(0.50),
        "p95": pick(0.95),
        "p99": pick(0.99),
        "max": float(arr[-1]),
    }


class LoadGenerator:
    """Replays a :class:`Workload` against a service; see module docstring.

    Parameters
    ----------
    service:
        The target (already serving).
    workload:
        The seeded mix.  The generator never mutates it.
    mode:
        ``"closed"`` (fixed concurrency) or ``"open"`` (arrival-scheduled).
    concurrency:
        Closed loop: worker-thread population.
    batch_size:
        Closed loop: ops each worker admits atomically per round via
        :meth:`GraphService.submit_batch` (1 = plain ``submit``).  A
        whole chunk is one fusion-set candidate, so this knob trades
        per-query latency for fusion width.
    target_qps:
        Open loop: mean arrival rate (required in open mode).
    poisson:
        Open loop: exponential inter-arrivals instead of even spacing
        (drawn from the workload seed — still deterministic).
    total_ops:
        Length of the pre-generated schedule; submission stops when the
        schedule is exhausted even if time remains.
    warmup_ops:
        Ops at the head of the schedule flagged ``warmup`` (excluded from
        report rates/percentiles, still validated for correctness).
    duration_s:
        Optional wall-clock cap on the submission phase (warmup included).
    keep_values:
        Retain each query's result vector on its record (the oracle
        replay needs them; drop for long memory-bounded soaks).
    """

    def __init__(
        self,
        service: GraphService,
        workload: Workload,
        *,
        mode: str = "closed",
        concurrency: int = 4,
        batch_size: int = 1,
        target_qps: Optional[float] = None,
        poisson: bool = False,
        total_ops: int = 64,
        warmup_ops: int = 0,
        duration_s: Optional[float] = None,
        keep_values: bool = True,
        drain_timeout_s: float = 120.0,
    ):
        if mode not in ("closed", "open"):
            raise ValueError(f"unknown mode {mode!r}")
        if mode == "open" and (target_qps is None or target_qps <= 0):
            raise ValueError("open mode requires a positive target_qps")
        if mode == "closed" and concurrency <= 0:
            raise ValueError("closed mode requires positive concurrency")
        if batch_size <= 0:
            raise ValueError("batch_size must be positive")
        if total_ops <= 0:
            raise ValueError("total_ops must be positive")
        if not 0 <= warmup_ops < total_ops:
            raise ValueError("warmup_ops must be in [0, total_ops)")
        self.service = service
        self.workload = workload
        self.mode = mode
        self.concurrency = int(concurrency)
        self.batch_size = int(batch_size)
        self.target_qps = float(target_qps) if target_qps else None
        self.poisson = bool(poisson)
        self.total_ops = int(total_ops)
        self.warmup_ops = int(warmup_ops)
        self.duration_s = duration_s
        self.keep_values = bool(keep_values)
        self.drain_timeout_s = float(drain_timeout_s)

    # ------------------------------------------------------------------ run
    def run(self) -> LoadReport:
        """Execute the workload; returns the full report after drain."""
        svc = self.service
        plan = self.workload.plan(
            svc.engine.meta.num_vertices, self.total_ops
        )
        records: List[Optional[OpRecord]] = [None] * self.total_ops
        upd_records: List[UpdateRecord] = []
        upd_futs: List[Any] = []
        upd_lock = threading.Lock()
        next_op = iter(range(self.total_ops))
        take_lock = threading.Lock()
        t_begin = time.perf_counter()
        deadline = (
            t_begin + self.duration_s if self.duration_s is not None else None
        )
        pending: List[Tuple["Any", OpRecord]] = []  # (future, record)
        pending_lock = threading.Lock()

        def cutoff() -> bool:
            return deadline is not None and time.perf_counter() >= deadline

        def take() -> Optional[int]:
            with take_lock:
                return next(next_op, None)

        def submit_op(i: int) -> Tuple[OpRecord, Optional[Any]]:
            """Submit schedule slot ``i``; returns (record, future|None)."""
            cls = self.workload.classes[int(plan.cls_idx[i])]
            rec = OpRecord(
                index=i,
                program=cls.program,
                source=int(plan.sources[i]),
                params=cls.params,
                max_iters=cls.max_iters,
                phase="warmup" if i < self.warmup_ops else "measure",
                t_submit=time.perf_counter(),
            )
            records[i] = rec
            fut = None
            try:
                fut = svc.submit(
                    cls.program,
                    rec.source,
                    max_iters=cls.max_iters,
                    **dict(cls.params),
                )
            except ServiceOverloaded:
                rec.rejected = True
                rec.latency_s = time.perf_counter() - rec.t_submit
            except Exception as exc:  # typed in the record, not raised
                rec.error = repr(exc)
                rec.latency_s = time.perf_counter() - rec.t_submit

            # interleaved mutation stream: op i triggers batch i/update_every
            ue = self.workload.update_every
            if ue > 0 and (i + 1) % ue == 0:
                bi = (i + 1) // ue - 1
                if bi < len(plan.updates):
                    _submit_update(bi)
            return rec, fut

        def _submit_update(bi: int) -> None:
            edges = plan.updates[bi]
            urec = UpdateRecord(
                index=bi, inserts=edges, t_submit=time.perf_counter()
            )
            with upd_lock:
                upd_records.append(urec)
            try:
                ufut = svc.apply_updates(inserts=edges)
            except Exception as exc:
                urec.error = repr(exc)
                return
            with upd_lock:
                upd_futs.append(ufut)

            def done(f, urec=urec) -> None:
                try:
                    ur = f.result()
                except Exception as exc:
                    urec.error = repr(exc)
                else:
                    urec.ok = True
                    urec.graph_version = ur.graph_version
                    urec.latency_s = ur.latency_s
                urec.latency_s = urec.latency_s or (
                    time.perf_counter() - urec.t_submit
                )

            ufut.add_done_callback(done)

        def _finish(rec: OpRecord, fut) -> None:
            try:
                qr = fut.result(timeout=self.drain_timeout_s)
            except Exception as exc:
                rec.error = repr(exc)
                rec.latency_s = time.perf_counter() - rec.t_submit
                return
            rec.ok = True
            rec.latency_s = time.perf_counter() - rec.t_submit
            rec.service_latency_s = qr.latency_s
            rec.queue_wait_s = qr.queue_wait_s
            rec.sweep_s = qr.sweep_s
            rec.cached = qr.cached
            rec.iterations = qr.iterations
            rec.converged = qr.converged
            rec.graph_version = qr.graph_version
            if self.keep_values:
                rec.values = qr.values

        if self.mode == "closed":
            def take_chunk() -> List[int]:
                with take_lock:
                    out = []
                    for _ in range(self.batch_size):
                        i = next(next_op, None)
                        if i is None:
                            break
                        out.append(i)
                    return out

            def worker() -> None:
                while not cutoff():
                    chunk = take_chunk()
                    if not chunk:
                        return
                    if len(chunk) == 1:
                        rec, fut = submit_op(chunk[0])
                        if fut is not None:
                            _finish(rec, fut)
                        continue
                    # admit the chunk atomically: one fusion-set candidate
                    batch: List[Tuple[OpRecord, Any]] = []
                    with svc.submit_batch():
                        for i in chunk:
                            rec, fut = submit_op(i)
                            if fut is not None:
                                batch.append((rec, fut))
                    for rec, fut in batch:
                        _finish(rec, fut)

            threads = [
                threading.Thread(
                    target=worker, name=f"loadgen-{k}", daemon=True
                )
                for k in range(self.concurrency)
            ]
            for th in threads:
                th.start()
            for th in threads:
                th.join()
        else:
            # open loop: one dispatcher paced by the arrival schedule
            gaps = self._arrival_gaps()
            t_next = time.perf_counter()
            for i in range(self.total_ops):
                if cutoff():
                    break
                t_next += gaps[i]
                delay = t_next - time.perf_counter()
                if delay > 0:
                    time.sleep(delay)
                rec, fut = submit_op(i)
                if fut is not None:
                    with pending_lock:
                        pending.append((fut, rec))
            # drain: every submitted future must resolve before reporting
            with pending_lock:
                outstanding = list(pending)
            for fut, rec in outstanding:
                _finish(rec, fut)

        # drain the mutation stream too: update records must carry their
        # published graph_version before the report (oracle replay input)
        with upd_lock:
            ufuts = list(upd_futs)
        for uf in ufuts:
            try:
                uf.result(timeout=self.drain_timeout_s)
            except Exception:
                pass  # the done-callback already typed the error

        return self._report([r for r in records if r is not None],
                            upd_records, t_begin)

    def _arrival_gaps(self) -> np.ndarray:
        """Inter-arrival seconds for the open loop (seeded, pre-drawn)."""
        mean_gap = 1.0 / float(self.target_qps)  # type: ignore[arg-type]
        if not self.poisson:
            return np.full(self.total_ops, mean_gap)
        # independent stream: offset seed so the op plan is unchanged
        rng = np.random.default_rng(self.workload.seed + 0x9E3779B9)
        return rng.exponential(mean_gap, size=self.total_ops)

    # --------------------------------------------------------------- report
    def _report(
        self,
        records: List[OpRecord],
        updates: List[UpdateRecord],
        t_begin: float,
    ) -> LoadReport:
        measure = [r for r in records if r.phase == "measure"]
        done = [r for r in measure if r.ok]
        lat = [r.latency_s for r in done]
        qw = [r.queue_wait_s for r in done]
        if done:
            span = max(
                max(r.t_submit + r.latency_s for r in done)
                - min(r.t_submit for r in done),
                1e-9,
            )
            sub_span = max(
                max(r.t_submit for r in measure)
                - min(r.t_submit for r in measure),
                1e-9,
            )
        else:
            span = sub_span = max(time.perf_counter() - t_begin, 1e-9)
        lat_sum = sum(r.latency_s for r in done)
        per_class: Dict[str, int] = {}
        for r in done:
            per_class[r.program] = per_class.get(r.program, 0) + 1
        return LoadReport(
            mode=self.mode,
            concurrency=self.concurrency if self.mode == "closed" else 1,
            target_qps=self.target_qps,
            duration_s=span,
            submitted=len(measure),
            completed=len(done),
            rejected=sum(1 for r in measure if r.rejected),
            errors=sum(1 for r in measure if r.error is not None),
            cached=sum(1 for r in done if r.cached),
            qps=len(done) / span,
            offered_qps=len(measure) / sub_span,
            latency=_percentiles(lat),
            queue_wait=_percentiles(qw),
            queue_wait_share=(sum(qw) / lat_sum) if lat_sum > 0 else 0.0,
            per_class=per_class,
            updates_submitted=len(updates),
            updates_published=sum(1 for u in updates if u.ok),
            records=records,
            updates=updates,
            warmup_records=sum(1 for r in records if r.phase == "warmup"),
        )


def oracle_kwargs(rec: OpRecord) -> Dict[str, Any]:
    """The :func:`repro.core.apps.get_program` kwargs that make a solo
    engine answer exactly this record's query (WCC takes no source)."""
    kw: Dict[str, Any] = dict(rec.params)
    if rec.program != "wcc":
        kw["source"] = rec.source
    return kw


def edge_state_at_version(
    initial_edges: np.ndarray,
    updates: Sequence[UpdateRecord],
    version: int,
) -> np.ndarray:
    """Rebuild the exact edge list visible at ``version``: the initial
    edges plus every published insert batch with ``graph_version <=
    version`` (insert-only streams; order is append, matching
    :class:`repro.delta.EdgeLog` semantics for inserts)."""
    parts = [np.asarray(initial_edges).reshape(-1, 2)]
    pubs = sorted(
        (u for u in updates if u.ok and 0 <= u.graph_version <= version),
        key=lambda u: u.graph_version,
    )
    parts.extend(u.inserts.reshape(-1, 2) for u in pubs)
    return np.concatenate(parts, axis=0)
