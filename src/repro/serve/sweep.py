"""Fused lane sweeps: heterogeneous query programs on ONE shard stream.

GraphMP's whole advantage is that every byte of edge I/O is amortized over
as much compute as possible.  This module pushes that across *programs*:
a :class:`FusedSweep` reuses a warm :class:`~repro.core.vsw.VSWEngine`'s
scheduler, pipeline and store to drive G concurrent **program groups**,
each a :class:`LaneTable` — a ``(capacity, n)`` lane matrix whose lanes
share one combine algebra (:attr:`~repro.core.apps.LaneProgram.combine_key`)
but may run *different programs* (BFS, SSSP and WCC fuse into one table;
``pre``/``apply``/``is_active`` are applied per lane, grouped by full
program key).  Every loaded+decoded shard is dispatched once per live
group (:meth:`run_groups` on the lane executors): G small dispatches, one
load.

Scheduling uses the UNION of the per-lane active sets across every group:
a shard is skipped only when *no* lane's Bloom filter matches.  This
preserves per-lane results bitwise (DESIGN.md §6/§9): the union plan is a
superset of each lane's own plan (``any_member`` over a superset of ids
can only add shards, and above-threshold lanes force the full plan), and
recomputing a shard whose in-messages did not change reproduces the
carried-over value exactly — for monotone ``min`` programs because
``min(acc, old) == old``, and for the ``sum`` programs because ``apply``
is a deterministic function of an unchanged ``acc``.  Fusion adds nothing
to prove: each lane's messages are computed by its own program's ``pre``
on its own row, the kernel is vmapped per lane, and ``apply`` runs per
lane — the per-lane computation is op-for-op the solo run's.

Lanes retire as soon as their own active set empties (or their iteration
budget runs out) and the freed slot is immediately backfilled from the
service queue — per group, so a drained PPR table keeps admitting PPR
queries while a min-algebra table still sweeps.

I/O cost is attributed mask-aware (:meth:`ShardPlan.lane_shares`): each
shard's load is split over only the lanes it was actually dispatched for,
and an iteration's bytes follow the same shares — summed over lanes they
reproduce the sweep totals exactly.

:class:`LaneSweep` (PR 2's single-program API) remains as a thin wrapper:
one program, one group.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.apps import LaneProgram
from repro.core.executor import ExecStats, MeshLaneExecutor, make_lane_executor
from repro.core.pipeline import PipelineStats
from repro.core.scheduler import ShardPlan
from repro.core.vsw import VSWEngine
from repro.obs import trace

from .batcher import pad_lanes

__all__ = ["LaneSeed", "LaneResult", "SweepIterStats", "LaneTable",
           "FusedSweep", "LaneSweep", "MeshSweep"]


@dataclasses.dataclass
class LaneSeed:
    """One admitted query: where it starts, how long it may run, and (for
    fused sweeps) which lane program it runs.  ``program=None`` is only
    valid through :class:`LaneSweep`, which fills in its single program."""

    source: int
    max_iters: int = 100
    token: Any = None  # opaque caller payload (the service's pending entry)
    program: Optional[LaneProgram] = None


@dataclasses.dataclass
class LaneResult:
    """One retired lane: final values plus attributed cost.

    ``bytes_read`` / ``shard_loads`` are the lane's *share* of the sweep's
    I/O, split mask-aware: each planned shard's load (and the bytes behind
    it) is divided over only the lanes that shard was dispatched for —
    the amortization the serving layer exists to create, now attributed to
    the lanes that actually consumed it.
    """

    token: Any
    source: int
    values: np.ndarray  # [n] final vertex values for this query
    iterations: int
    converged: bool
    bytes_read: float
    shard_loads: float
    group: int = 0  # fusion-group index within the sweep
    program: str = ""


@dataclasses.dataclass
class SweepIterStats:
    iteration: int
    live_lanes: int
    shards_processed: int
    shards_skipped: int
    bytes_read: int
    selective_on: bool
    retired: int
    backfilled: int
    time_s: float
    # lane-aware selective scheduling: dispatch rows (shard x lane pairs)
    # skipped because the lane had no active source in the shard
    lane_rows_skipped: int = 0
    # per-stage decomposition (GraphScope, DESIGN.md §11): load work done
    # by prefetch threads, the slice of it exposed on the critical path,
    # and kernel dispatch time — the serving analogue of IterStats'.
    load_total_s: float = 0.0
    load_wait_s: float = 0.0
    exec_s: float = 0.0
    # fusion: program groups live this iteration (1 for plain lane sweeps)
    groups: int = 1
    # RaggedFuse (DESIGN.md §14): kernel dispatches and shard batches this
    # iteration.  Ragged sweeps hold dispatches == batches (one launch per
    # batch covers every group); the multi path pays groups x batches.
    # Conservation: batches <= dispatches.
    dispatches: int = 0
    batches: int = 0
    # double-buffer overlap: wall time launches stayed in flight while the
    # host staged the next batch.
    overlap_s: float = 0.0
    # mesh sweeps (DESIGN.md §10); empty tuples on single-device sweeps.
    # Conserved like IterStats': sum(device_shards) == shards_processed,
    # sum(device_bytes) == bytes_read — one host read per shard, sliced
    # G x D ways, never re-read per device.
    device_shards: tuple = ()
    device_dispatches: tuple = ()
    device_bytes: tuple = ()


class LaneTable:
    """Slot state for ONE fusion group: lanes sharing a combine algebra.

    The table owns everything per-slot — values, active masks, the lane's
    :class:`LaneProgram`, its seed, iteration/cost counters — and the
    admission / retirement lifecycle.  Programs may differ across slots as
    long as every lane's ``combine`` matches the table's (that is what a
    fusion group *is*); row-wise stages (``pre`` / ``apply`` /
    ``is_active``) run per program-key run of slots, so each lane's
    computation is exactly its solo program's.
    """

    def __init__(self, meta, combine: str, capacity: int, *, group: int = 0):
        self.meta = meta
        self.combine = combine
        self.capacity = capacity
        self.group = group
        n = meta.num_vertices
        self.vals = np.zeros((capacity, n), dtype=np.float32)
        self.active = np.zeros((capacity, n), dtype=bool)
        self.live = np.zeros(capacity, dtype=bool)
        self.sources = np.full(capacity, -1, dtype=np.int64)
        self.lane_iters = np.zeros(capacity, dtype=np.int64)
        self.lane_bytes = np.zeros(capacity, dtype=np.float64)
        self.lane_loads = np.zeros(capacity, dtype=np.float64)
        self.progs: List[Optional[LaneProgram]] = [None] * capacity
        self.seeds: List[Optional[LaneSeed]] = [None] * capacity

    # ---------------------------------------------------------- admission
    def admit(self, seed: LaneSeed) -> Optional[LaneResult]:
        """THE admission path — initial seeds and mid-sweep backfill alike.

        Handles ``max_iters <= 0`` here, once (parity with
        ``VSWEngine.run``): zero iterations, init values, not converged —
        the seed never takes a slot and its finished :class:`LaneResult`
        is returned.  Otherwise the seed occupies a free slot and ``None``
        is returned.
        """
        prog = seed.program
        if prog is None:
            raise ValueError("LaneSeed.program is required (fused sweeps)")
        if prog.combine != self.combine:
            raise ValueError(
                f"program {prog.name!r} ({prog.combine}) cannot join a "
                f"{self.combine!r} lane table"
            )
        if seed.max_iters <= 0:
            v, _ = prog.init_lane(self.meta, seed.source)
            return LaneResult(
                token=seed.token, source=seed.source,
                values=v.astype(np.float32), iterations=0, converged=False,
                bytes_read=0.0, shard_loads=0.0,
                group=self.group, program=prog.name,
            )
        free = np.flatnonzero(~self.live)
        if not len(free):
            raise RuntimeError("lane table is full")
        slot = int(free[0])
        v, a = prog.init_lane(self.meta, seed.source)
        self.vals[slot] = v
        self.active[slot] = a
        self.live[slot] = True
        self.sources[slot] = seed.source
        self.lane_iters[slot] = 0
        self.lane_bytes[slot] = 0.0
        self.lane_loads[slot] = 0.0
        self.progs[slot] = prog
        self.seeds[slot] = seed
        return None

    def live_slots(self) -> np.ndarray:
        return np.flatnonzero(self.live)

    def free_count(self) -> int:
        return int((~self.live).sum())

    # ------------------------------------------------- per-program stages
    def _prog_runs(
        self, slots: np.ndarray
    ) -> Iterator[Tuple[np.ndarray, LaneProgram]]:
        """Partition ``slots`` into runs sharing a full program key —
        equal-key lanes run the identical computation, so each run is one
        vectorized call."""
        runs: Dict[Tuple, Tuple[List[int], LaneProgram]] = {}
        for i, k in enumerate(slots):
            prog = self.progs[int(k)]
            runs.setdefault(prog.key, ([], prog))[0].append(i)
        for rows, prog in runs.values():
            yield np.asarray(rows, dtype=np.int64), prog

    def messages(self, out_deg: np.ndarray) -> np.ndarray:
        """Per-lane ``pre`` over the live slots (each lane's own program);
        dead/free rows stay zero — they are never applied."""
        msgs = np.zeros_like(self.vals)
        slots = self.live_slots()
        for rows, prog in self._prog_runs(slots):
            sl = slots[rows]
            msgs[sl] = prog.pre(self.vals[sl], out_deg).astype(np.float32)
        return msgs

    def apply_rows(
        self,
        acc: np.ndarray,
        slots: np.ndarray,
        v0: int,
        v1: int,
        dst: np.ndarray,
    ) -> None:
        """Per-lane ``apply`` for one shard interval: row ``i`` of ``acc``
        belongs to slot ``slots[i]``; results land in ``dst``."""
        for rows, prog in self._prog_runs(slots):
            sl = slots[rows]
            new = prog.apply(
                acc[rows], self.vals[sl, v0:v1], self.meta, v0,
                self.sources[sl],
            )
            dst[sl, v0:v1] = new

    def advance(self, dst: np.ndarray) -> None:
        """Commit one iteration: per-lane ``is_active`` against the old
        values, then swap in ``dst`` and bump live lanes' iteration
        counters."""
        slots = self.live_slots()
        new_active = np.zeros_like(self.active)
        for rows, prog in self._prog_runs(slots):
            sl = slots[rows]
            new_active[sl] = prog.is_active(dst[sl], self.vals[sl])
        self.vals = dst
        self.active = new_active
        self.lane_iters[self.live] += 1

    def attribute(self, shares: np.ndarray, bytes_per_load: float) -> None:
        """Add this iteration's mask-aware cost shares (aligned with
        ``live_slots()``) to the lanes' running totals."""
        slots = self.live_slots()
        self.lane_loads[slots] += shares
        self.lane_bytes[slots] += shares * bytes_per_load

    # --------------------------------------------------------- retirement
    def retire(self, emit: Callable[[LaneResult], None]) -> int:
        """Free every lane that converged or exhausted its budget; ``emit``
        fires per retired lane (the service resolves futures here)."""
        retired = 0
        for k in self.live_slots():
            k = int(k)
            seed = self.seeds[k]
            converged = not self.active[k].any()
            if not converged and self.lane_iters[k] < seed.max_iters:
                continue
            self.live[k] = False
            self.active[k] = False
            retired += 1
            emit(
                LaneResult(
                    token=seed.token,
                    source=seed.source,
                    values=self.vals[k].copy(),
                    iterations=int(self.lane_iters[k]),
                    converged=converged,
                    bytes_read=float(self.lane_bytes[k]),
                    shard_loads=float(self.lane_loads[k]),
                    group=self.group,
                    program=self.progs[k].name,
                )
            )
            self.progs[k] = None
            self.seeds[k] = None
        return retired


class FusedSweep:
    """Drive G program groups over ONE pinned shard stream.

    Each iteration plans the union active set across every group, loads
    each planned shard once, and dispatches it per live group through the
    lane executor's multi-group path — with per-(group, lane) masks under
    lane-aware selective scheduling.
    """

    def __init__(
        self,
        engine: VSWEngine,
        *,
        batch_shards: int = 1,
        pad_pow2: bool = True,
        lane_selective: bool = True,
        ragged: bool = True,
    ):
        self.engine = engine
        self.pad_pow2 = pad_pow2
        # Lane-aware selective scheduling: when the union plan is selective,
        # also skip dispatch ROWS for lanes whose Bloom filter matches no
        # active vertex of the shard — and whole GROUPS whose lanes are all
        # masked (the shard still loads once).  Same bitwise argument as
        # whole-shard skipping, per lane (DESIGN.md §6).
        self.lane_selective = lane_selective
        # RaggedFuse (DESIGN.md §14): the jnp/pallas lane executors
        # concatenate every live group along the lane axis and launch ONE
        # ragged kernel per shard batch (instead of G), double-buffering
        # collection against the next batch's decode.  Bitwise-identical
        # per group; the numpy oracle always runs per-group.
        self.ragged = ragged
        # An engine booted with ``mesh=`` carries a MeshPartition: lane
        # dispatch then routes each decoded shard to its owning device and
        # launches one SPMD program per flush — "1 host read, G x D
        # slices" (DESIGN.md §10).  Same run_groups surface either way.
        if getattr(engine, "partition", None) is not None:
            self.executor = MeshLaneExecutor(
                engine.backend_name, engine.partition, engine.mesh,
                batch_shards=batch_shards, lanes=True, ragged=ragged,
            )
        else:
            self.executor = make_lane_executor(
                engine.backend_name, batch_shards=batch_shards, ragged=ragged
            )
        self.iter_stats: List[SweepIterStats] = []

    # ------------------------------------------------------------------ run
    def run(
        self,
        seed_groups: Sequence[Sequence[LaneSeed]],
        *,
        backfill: Optional[Callable[[int, int], Sequence[LaneSeed]]] = None,
        on_retire: Optional[Callable[[LaneResult], None]] = None,
    ) -> List[LaneResult]:
        """Sweep until every group's lanes have retired and ``backfill``
        is dry.

        ``seed_groups[g]`` seeds group ``g``; every seed carries its own
        program and all programs within a group must share a combine
        algebra.  ``backfill(g, n_free)`` is called whenever group ``g``
        has free slots; it may return up to ``n_free`` new seeds (same
        combine algebra) which start their own iteration 0 mid-sweep.
        ``on_retire`` fires the moment a lane finishes.
        """
        results: List[LaneResult] = []

        def emit(res: LaneResult) -> None:
            results.append(res)
            trace.instant(
                "lane.retire",
                group=res.group,
                source=res.source,
                program=res.program,
                iterations=res.iterations,
            )
            if on_retire is not None:
                on_retire(res)

        engine = self.engine
        meta = engine.meta
        n = meta.num_vertices

        tables: List[LaneTable] = []
        pending_admits: List[Tuple[LaneTable, LaneSeed]] = []
        for gi, seeds in enumerate(seed_groups):
            seeds = list(seeds)
            if not seeds:
                continue
            combine = seeds[0].program.combine
            n_live = sum(1 for s in seeds if s.max_iters > 0)
            capacity = pad_lanes(n_live) if self.pad_pow2 else max(n_live, 1)
            table = LaneTable(meta, combine, capacity, group=gi)
            tables.append(table)
            pending_admits.extend((table, s) for s in seeds)
        for table, seed in pending_admits:
            res = table.admit(seed)
            if res is not None:
                emit(res)  # zero-budget: finished at admission
        if not any(t.live.any() for t in tables):
            return results

        pstats = PipelineStats()
        xstats = ExecStats()
        it = 0
        # One pinned delta session for the WHOLE sweep: mutations published
        # while lanes are in flight become visible to the NEXT sweep, never
        # mid-query — every result is computed at exactly one graph version.
        with engine._sweep_session():
            while any(t.live.any() for t in tables):
                with trace.span("sweep.iter", iteration=it) as it_sp:
                    t0 = time.perf_counter()
                    io0 = engine.store.io.snapshot()
                    pstats.reset()
                    xstats.reset()

                    group_live = [t.live_slots() for t in tables]
                    total_live = int(sum(len(sl) for sl in group_live))
                    n_groups_live = sum(1 for sl in group_live if len(sl))
                    union_any = np.zeros(n, dtype=bool)
                    for t, sl in zip(tables, group_live):
                        if len(sl):
                            union_any |= t.active[sl].any(axis=0)
                    union_ids = np.flatnonzero(union_any).astype(np.int64)
                    lane_active = None
                    if self.lane_selective and total_live > 1:
                        lane_active = [
                            np.flatnonzero(t.active[k]).astype(np.int64)
                            for t, sl in zip(tables, group_live)
                            for k in sl
                        ]
                    plan = engine.scheduler.plan(
                        union_ids, lane_active=lane_active
                    )
                    msgs = [
                        t.messages(meta.out_deg) if len(sl) else None
                        for t, sl in zip(tables, group_live)
                    ]
                    # carried for skipped shards / masked lanes / dead rows
                    dst = [t.vals.copy() for t in tables]

                    loaded = engine.pipeline.iter_shards(
                        plan.shards, stats=pstats
                    )
                    rows_skipped = 0
                    try:
                        if plan.lane_masks is None:
                            groups_args = [
                                (m, t.combine) if m is not None else None
                                for m, t in zip(msgs, tables)
                            ]
                            for gi, res in self.executor.run_groups(
                                loaded, groups_args, xstats
                            ):
                                sl = group_live[gi]
                                acc = np.asarray(res.acc, dtype=np.float32)[sl]
                                tables[gi].apply_rows(
                                    acc, sl, res.v0, res.v1, dst[gi]
                                )
                        else:
                            rows_skipped = self._run_masked(
                                plan, loaded, tables, group_live, msgs, dst,
                                xstats,
                            )
                    finally:
                        # Deterministic drain on failure (ShardLoadError or
                        # executor error): cancel+await the prefetch window
                        # now, so the NEXT sweep on this engine sees idle
                        # loader threads and no stale queue entries.
                        loaded.close()

                    # -------------------------------- commit + attribution
                    dio = engine.store.io - io0
                    shares = plan.lane_shares(total_live)
                    bytes_per_load = (
                        dio.bytes_read / plan.num_planned if plan.num_planned
                        else 0.0
                    )
                    offset = 0
                    for gi, (t, sl) in enumerate(zip(tables, group_live)):
                        if not len(sl):
                            continue
                        t.attribute(
                            shares[offset:offset + len(sl)], bytes_per_load
                        )
                        offset += len(sl)
                        t.advance(dst[gi])

                    # ------------------------------- retirement + backfill
                    retired = sum(t.retire(emit) for t in tables)
                    backfilled = 0
                    if backfill is not None:
                        for t in tables:
                            while True:
                                n_free = t.free_count()
                                if n_free == 0:
                                    break
                                got = list(backfill(t.group, n_free))
                                if not got:
                                    break
                                for seed in got:
                                    res = t.admit(seed)
                                    if res is not None:
                                        emit(res)  # zero-budget, slot free
                                    else:
                                        backfilled += 1

                    dev_shards = dev_disp = dev_bytes = ()
                    if plan.device_shards is not None:
                        dev_shards = tuple(len(g) for g in plan.device_shards)
                        dev_bytes = tuple(
                            len(g) * bytes_per_load
                            for g in plan.device_shards
                        )
                        dev_disp = tuple(
                            xstats.device_dispatches.get(d, 0)
                            for d in range(len(plan.device_shards))
                        )

                    self.iter_stats.append(
                        SweepIterStats(
                            iteration=it,
                            live_lanes=total_live,
                            shards_processed=plan.num_planned,
                            shards_skipped=plan.num_skipped,
                            bytes_read=dio.bytes_read,
                            selective_on=plan.selective_on,
                            retired=retired,
                            backfilled=backfilled,
                            time_s=time.perf_counter() - t0,
                            lane_rows_skipped=rows_skipped,
                            load_total_s=pstats.load_total_s,
                            load_wait_s=pstats.wait_s,
                            exec_s=xstats.exec_s,
                            groups=n_groups_live,
                            dispatches=xstats.dispatches,
                            batches=xstats.batches,
                            overlap_s=xstats.overlap_s,
                            device_shards=dev_shards,
                            device_dispatches=dev_disp,
                            device_bytes=dev_bytes,
                        )
                    )
                    it_sp.set(
                        shards=plan.num_planned,
                        live_lanes=total_live,
                        groups=n_groups_live,
                        retired=retired,
                        backfilled=backfilled,
                    )
                it += 1
        return results

    # ------------------------------------------------- lane-masked dispatch
    def _run_masked(
        self,
        plan: ShardPlan,
        loaded,
        tables: List[LaneTable],
        group_live: List[np.ndarray],
        msgs: List[Optional[np.ndarray]],
        dst: List[np.ndarray],
        xstats: ExecStats,
    ) -> int:
        """Execute the plan with per-shard lane masks: consecutive shards
        sharing a mask are dispatched together (preserving shard batching)
        on ONLY the masked lanes' message rows, per group; a group whose
        lanes are all masked for the run is skipped without a dispatch.
        Unmasked lanes keep their carried values.  Returns skipped
        dispatch rows.

        Message sub-matrices are padded to pow2 lane counts (same shape
        discipline as the batcher) so jit'd lane kernels see bounded
        shapes; padding rows are zeros and their results are discarded.
        Staged sub-matrices are cached per (group, lane mask) for the
        iteration — consecutive flushes with a recurring mask reuse the
        padded copy instead of re-staging it (ISSUE 10 satellite; lane
        values are fixed within the iteration, and the cache dies with the
        call, so retirement/backfill invalidate it for free).
        """
        batch = getattr(self.executor, "batch_shards", 1)
        rows_skipped = 0
        buf: List = []
        buf_mask: Optional[np.ndarray] = None
        staged: Dict[Tuple[int, bytes], np.ndarray] = {}

        def flush() -> None:
            nonlocal buf, buf_mask, rows_skipped
            if not buf:
                return
            groups_args: List[Optional[Tuple[np.ndarray, str]]] = []
            group_slots: List[Optional[np.ndarray]] = []
            offset = 0
            for gi, (t, sl, m) in enumerate(zip(tables, group_live, msgs)):
                sub = buf_mask[offset:offset + len(sl)]
                offset += len(sl)
                dsl = sl[sub] if len(sl) else sl
                rows_skipped += (len(sl) - len(dsl)) * len(buf)
                if not len(dsl):
                    groups_args.append(None)
                    group_slots.append(None)
                    continue
                key = (gi, dsl.tobytes())
                subm = staged.get(key)
                if subm is None:
                    k = len(dsl)
                    cap_sub = pad_lanes(k) if self.pad_pow2 else k
                    subm = np.zeros((cap_sub, m.shape[1]), dtype=m.dtype)
                    subm[:k] = m[dsl]
                    staged[key] = subm
                groups_args.append((subm, t.combine))
                group_slots.append(dsl)
            for gi, res in self.executor.run_groups(
                iter(buf), groups_args, xstats
            ):
                dsl = group_slots[gi]
                acc = np.asarray(res.acc, dtype=np.float32)[: len(dsl)]
                tables[gi].apply_rows(acc, dsl, res.v0, res.v1, dst[gi])
            buf, buf_mask = [], None

        for ls in loaded:
            mask = plan.lane_masks[ls.shard_id]
            if buf and (
                len(buf) >= batch or not np.array_equal(mask, buf_mask)
            ):
                flush()
            buf_mask = mask
            buf.append(ls)
        flush()
        return rows_skipped


class MeshSweep(FusedSweep):
    """A :class:`FusedSweep` whose engine was booted with ``mesh=`` — the
    tentpole API of DESIGN.md §10.

    The partition is the engine's :class:`~repro.core.distributed.
    MeshPartition`: destination-vertex intervals owned per device, so each
    destination vertex is updated by exactly ONE device (the paper's
    lock-free property lifted to SPMD).  Per iteration: one host-side plan,
    one host read per planned shard, one all-gather of each group's lane
    messages, one SPMD dispatch per live group covering every device's
    slice, and a psum'd activity scalar — per-device attribution lands in
    :class:`SweepIterStats`' ``device_*`` fields, conserved against the
    sweep totals.  This class only asserts the partition exists; all
    behavior is the fused sweep's (mesh routing lives in the executor the
    base constructor already selects).
    """

    def __init__(self, engine: VSWEngine, **kwargs):
        if getattr(engine, "partition", None) is None:
            raise ValueError(
                "MeshSweep needs an engine booted with mesh= (an int device "
                "count or a jax Mesh); use FusedSweep for single-device "
                "engines"
            )
        super().__init__(engine, **kwargs)


class LaneSweep:
    """Run per-source queries of ONE program as lanes of one sweep.

    PR 2's single-program API, now a thin wrapper over :class:`FusedSweep`
    with a single fusion group: seeds without an explicit program get this
    sweep's, and ``backfill(n_free)`` keeps its group-less signature.
    """

    def __init__(
        self,
        engine: VSWEngine,
        program: LaneProgram,
        *,
        batch_shards: int = 1,
        pad_pow2: bool = True,
        lane_selective: bool = True,
        ragged: bool = True,
    ):
        self.engine = engine
        self.program = program
        self._fused = FusedSweep(
            engine,
            batch_shards=batch_shards,
            pad_pow2=pad_pow2,
            lane_selective=lane_selective,
            ragged=ragged,
        )

    @property
    def pad_pow2(self) -> bool:
        return self._fused.pad_pow2

    @property
    def lane_selective(self) -> bool:
        return self._fused.lane_selective

    @property
    def executor(self):
        return self._fused.executor

    @property
    def iter_stats(self) -> List[SweepIterStats]:
        return self._fused.iter_stats

    def _with_program(self, seeds: Sequence[LaneSeed]) -> List[LaneSeed]:
        return [
            s if s.program is not None
            else dataclasses.replace(s, program=self.program)
            for s in seeds
        ]

    def run(
        self,
        seeds: Sequence[LaneSeed],
        *,
        backfill: Optional[Callable[[int], Sequence[LaneSeed]]] = None,
        on_retire: Optional[Callable[[LaneResult], None]] = None,
    ) -> List[LaneResult]:
        """Sweep until every lane has retired and ``backfill`` is dry."""
        if not seeds:
            return []
        fused_backfill = None
        if backfill is not None:
            def fused_backfill(_group: int, n_free: int):
                return self._with_program(backfill(n_free))
        return self._fused.run(
            [self._with_program(seeds)],
            backfill=fused_backfill,
            on_retire=on_retire,
        )
