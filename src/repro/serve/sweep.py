"""Lane-batched VSW sweeps: K concurrent queries over one shard stream.

A :class:`LaneSweep` reuses a warm :class:`~repro.core.vsw.VSWEngine`'s
scheduler, pipeline and store, but replaces the single vertex-value array
with a ``(capacity, n)`` lane matrix — one row per in-flight query — and
dispatches each loaded shard through a lane executor
(:func:`repro.core.executor.make_lane_executor`) so every shard load is
amortized across all live lanes.

Scheduling uses the UNION of the per-lane active sets: a shard is skipped
only when *no* lane's Bloom filter matches.  This preserves per-lane
results bitwise (DESIGN.md §6): the union plan is a superset of each lane's
own plan (``any_member`` over a superset of ids can only add shards, and
above-threshold lanes force the full plan), and recomputing a shard whose
in-messages did not change reproduces the carried-over value exactly — for
monotone ``min`` programs because ``min(acc, old) == old``, and for the
``sum`` programs because ``apply`` is a deterministic function of an
unchanged ``acc``.

Lanes retire as soon as their own active set empties (or their iteration
budget runs out) and the freed slot is immediately backfilled from the
service queue, keeping the lane matrix full under load.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, List, Optional, Sequence

import numpy as np

from repro.core.apps import LaneProgram
from repro.core.executor import ExecStats, make_lane_executor
from repro.core.pipeline import PipelineStats
from repro.core.vsw import VSWEngine

from .batcher import pad_lanes

__all__ = ["LaneSeed", "LaneResult", "SweepIterStats", "LaneSweep"]


@dataclasses.dataclass
class LaneSeed:
    """One admitted query: where it starts and how long it may run."""

    source: int
    max_iters: int = 100
    token: Any = None  # opaque caller payload (the service's pending entry)


@dataclasses.dataclass
class LaneResult:
    """One retired lane: final values plus attributed cost.

    ``bytes_read`` / ``shard_loads`` are the lane's *share* of the sweep's
    I/O: each iteration's cost is split evenly over the lanes live in it —
    the amortization the serving layer exists to create.
    """

    token: Any
    source: int
    values: np.ndarray  # [n] final vertex values for this query
    iterations: int
    converged: bool
    bytes_read: float
    shard_loads: float


@dataclasses.dataclass
class SweepIterStats:
    iteration: int
    live_lanes: int
    shards_processed: int
    shards_skipped: int
    bytes_read: int
    selective_on: bool
    retired: int
    backfilled: int
    time_s: float


class LaneSweep:
    """Run per-source queries as lanes of one vertex-centric sweep."""

    def __init__(
        self,
        engine: VSWEngine,
        program: LaneProgram,
        *,
        batch_shards: int = 1,
        pad_pow2: bool = True,
    ):
        self.engine = engine
        self.program = program
        self.pad_pow2 = pad_pow2
        self.executor = make_lane_executor(
            engine.backend_name, batch_shards=batch_shards
        )
        self.iter_stats: List[SweepIterStats] = []

    # ------------------------------------------------------------------ run
    def run(
        self,
        seeds: Sequence[LaneSeed],
        *,
        backfill: Optional[Callable[[int], Sequence[LaneSeed]]] = None,
        on_retire: Optional[Callable[[LaneResult], None]] = None,
    ) -> List[LaneResult]:
        """Sweep until every lane has retired and ``backfill`` is dry.

        ``backfill(n_free)`` is called whenever slots free up; it may return
        up to ``n_free`` new seeds which start their own iteration 0
        mid-sweep.  ``on_retire`` fires the moment a lane finishes — the
        service resolves that query's future immediately rather than at
        sweep end.
        """
        if not seeds:
            return []
        engine, prog = self.engine, self.program
        meta = engine.meta
        n = meta.num_vertices

        results: List[LaneResult] = []

        def finish_zero_budget(seed: LaneSeed) -> None:
            """``max_iters <= 0`` parity with ``VSWEngine.run``: zero
            iterations, init values, not converged — never takes a lane."""
            v, _ = prog.init_lane(meta, seed.source)
            res = LaneResult(
                token=seed.token, source=seed.source,
                values=v.astype(np.float32), iterations=0, converged=False,
                bytes_read=0.0, shard_loads=0.0,
            )
            results.append(res)
            if on_retire is not None:
                on_retire(res)

        live_seeds = []
        for seed in seeds:
            if seed.max_iters > 0:
                live_seeds.append(seed)
            else:
                finish_zero_budget(seed)
        seeds = live_seeds
        if not seeds:
            return results
        capacity = pad_lanes(len(seeds)) if self.pad_pow2 else len(seeds)

        vals = np.zeros((capacity, n), dtype=np.float32)
        active = np.zeros((capacity, n), dtype=bool)
        live = np.zeros(capacity, dtype=bool)
        sources = np.full(capacity, -1, dtype=np.int64)
        lane_iters = np.zeros(capacity, dtype=np.int64)
        lane_bytes = np.zeros(capacity, dtype=np.float64)
        lane_loads = np.zeros(capacity, dtype=np.float64)
        lane_seed: List[Optional[LaneSeed]] = [None] * capacity

        def admit(slot: int, seed: LaneSeed) -> None:
            v, a = prog.init_lane(meta, seed.source)
            vals[slot] = v
            active[slot] = a
            live[slot] = True
            sources[slot] = seed.source
            lane_iters[slot] = 0
            lane_bytes[slot] = 0.0
            lane_loads[slot] = 0.0
            lane_seed[slot] = seed

        for slot, seed in enumerate(seeds):
            admit(slot, seed)

        pstats = PipelineStats()
        xstats = ExecStats()
        it = 0
        while live.any():
            t0 = time.perf_counter()
            io0 = engine.store.io.snapshot()
            pstats.reset()
            xstats.reset()

            union_ids = np.flatnonzero(active[live].any(axis=0)).astype(np.int64)
            plan = engine.scheduler.plan(union_ids)
            msgs = prog.pre(vals, meta.out_deg).astype(np.float32)
            dst = vals.copy()  # carried over for skipped shards

            loaded = engine.pipeline.iter_shards(plan.shards, stats=pstats)
            for res in self.executor.run(loaded, msgs, prog.combine, xstats):
                new = prog.apply(
                    np.asarray(res.acc, dtype=vals.dtype),
                    vals[:, res.v0: res.v1],
                    meta,
                    res.v0,
                    sources,
                )
                dst[:, res.v0: res.v1] = new
            # Retired / free lanes stay frozen at their final values.
            dst[~live] = vals[~live]

            new_active = prog.is_active(dst, vals)
            new_active[~live] = False
            vals, active = dst, new_active
            lane_iters[live] += 1

            # ------------------------------------- per-lane cost attribution
            dio = engine.store.io - io0
            n_live = int(live.sum())
            lane_bytes[live] += dio.bytes_read / n_live
            lane_loads[live] += plan.num_planned / n_live

            # --------------------------------------- retirement + backfill
            retired = 0
            for k in np.flatnonzero(live):
                seed = lane_seed[k]
                converged = not active[k].any()
                if converged or lane_iters[k] >= seed.max_iters:
                    live[k] = False
                    active[k] = False
                    retired += 1
                    res_k = LaneResult(
                        token=seed.token,
                        source=seed.source,
                        values=vals[k].copy(),
                        iterations=int(lane_iters[k]),
                        converged=converged,
                        bytes_read=float(lane_bytes[k]),
                        shard_loads=float(lane_loads[k]),
                    )
                    results.append(res_k)
                    if on_retire is not None:
                        on_retire(res_k)

            backfilled = 0
            if backfill is not None:
                free = list(np.flatnonzero(~live))
                while free:
                    got = list(backfill(len(free)))
                    if not got:
                        break
                    for seed in got:
                        if seed.max_iters <= 0:
                            finish_zero_budget(seed)  # slot stays free
                        else:
                            admit(int(free.pop(0)), seed)
                            backfilled += 1

            self.iter_stats.append(
                SweepIterStats(
                    iteration=it,
                    live_lanes=n_live,
                    shards_processed=plan.num_planned,
                    shards_skipped=plan.num_skipped,
                    bytes_read=dio.bytes_read,
                    selective_on=plan.selective_on,
                    retired=retired,
                    backfilled=backfilled,
                    time_s=time.perf_counter() - t0,
                )
            )
            it += 1
        return results
