"""Lane-batched VSW sweeps: K concurrent queries over one shard stream.

A :class:`LaneSweep` reuses a warm :class:`~repro.core.vsw.VSWEngine`'s
scheduler, pipeline and store, but replaces the single vertex-value array
with a ``(capacity, n)`` lane matrix — one row per in-flight query — and
dispatches each loaded shard through a lane executor
(:func:`repro.core.executor.make_lane_executor`) so every shard load is
amortized across all live lanes.

Scheduling uses the UNION of the per-lane active sets: a shard is skipped
only when *no* lane's Bloom filter matches.  This preserves per-lane
results bitwise (DESIGN.md §6): the union plan is a superset of each lane's
own plan (``any_member`` over a superset of ids can only add shards, and
above-threshold lanes force the full plan), and recomputing a shard whose
in-messages did not change reproduces the carried-over value exactly — for
monotone ``min`` programs because ``min(acc, old) == old``, and for the
``sum`` programs because ``apply`` is a deterministic function of an
unchanged ``acc``.

Lanes retire as soon as their own active set empties (or their iteration
budget runs out) and the freed slot is immediately backfilled from the
service queue, keeping the lane matrix full under load.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, List, Optional, Sequence

import numpy as np

from repro.core.apps import LaneProgram
from repro.core.executor import ExecStats, make_lane_executor
from repro.core.pipeline import PipelineStats
from repro.core.scheduler import ShardPlan
from repro.core.vsw import VSWEngine

from .batcher import pad_lanes

__all__ = ["LaneSeed", "LaneResult", "SweepIterStats", "LaneSweep"]


@dataclasses.dataclass
class LaneSeed:
    """One admitted query: where it starts and how long it may run."""

    source: int
    max_iters: int = 100
    token: Any = None  # opaque caller payload (the service's pending entry)


@dataclasses.dataclass
class LaneResult:
    """One retired lane: final values plus attributed cost.

    ``bytes_read`` / ``shard_loads`` are the lane's *share* of the sweep's
    I/O: each iteration's cost is split evenly over the lanes live in it —
    the amortization the serving layer exists to create.
    """

    token: Any
    source: int
    values: np.ndarray  # [n] final vertex values for this query
    iterations: int
    converged: bool
    bytes_read: float
    shard_loads: float


@dataclasses.dataclass
class SweepIterStats:
    iteration: int
    live_lanes: int
    shards_processed: int
    shards_skipped: int
    bytes_read: int
    selective_on: bool
    retired: int
    backfilled: int
    time_s: float
    # lane-aware selective scheduling: dispatch rows (shard x lane pairs)
    # skipped because the lane had no active source in the shard
    lane_rows_skipped: int = 0


class LaneSweep:
    """Run per-source queries as lanes of one vertex-centric sweep."""

    def __init__(
        self,
        engine: VSWEngine,
        program: LaneProgram,
        *,
        batch_shards: int = 1,
        pad_pow2: bool = True,
        lane_selective: bool = True,
    ):
        self.engine = engine
        self.program = program
        self.pad_pow2 = pad_pow2
        # Lane-aware selective scheduling: when the union plan is selective,
        # also skip dispatch ROWS for lanes whose Bloom filter matches no
        # active vertex of the shard (the shard still loads once).  Same
        # bitwise argument as whole-shard skipping, per lane (DESIGN.md §6).
        self.lane_selective = lane_selective
        self.executor = make_lane_executor(
            engine.backend_name, batch_shards=batch_shards
        )
        self.iter_stats: List[SweepIterStats] = []

    # ------------------------------------------------------------------ run
    def run(
        self,
        seeds: Sequence[LaneSeed],
        *,
        backfill: Optional[Callable[[int], Sequence[LaneSeed]]] = None,
        on_retire: Optional[Callable[[LaneResult], None]] = None,
    ) -> List[LaneResult]:
        """Sweep until every lane has retired and ``backfill`` is dry.

        ``backfill(n_free)`` is called whenever slots free up; it may return
        up to ``n_free`` new seeds which start their own iteration 0
        mid-sweep.  ``on_retire`` fires the moment a lane finishes — the
        service resolves that query's future immediately rather than at
        sweep end.
        """
        if not seeds:
            return []
        engine, prog = self.engine, self.program
        meta = engine.meta
        n = meta.num_vertices

        results: List[LaneResult] = []

        def finish_zero_budget(seed: LaneSeed) -> None:
            """``max_iters <= 0`` parity with ``VSWEngine.run``: zero
            iterations, init values, not converged — never takes a lane."""
            v, _ = prog.init_lane(meta, seed.source)
            res = LaneResult(
                token=seed.token, source=seed.source,
                values=v.astype(np.float32), iterations=0, converged=False,
                bytes_read=0.0, shard_loads=0.0,
            )
            results.append(res)
            if on_retire is not None:
                on_retire(res)

        live_seeds = []
        for seed in seeds:
            if seed.max_iters > 0:
                live_seeds.append(seed)
            else:
                finish_zero_budget(seed)
        seeds = live_seeds
        if not seeds:
            return results
        capacity = pad_lanes(len(seeds)) if self.pad_pow2 else len(seeds)

        vals = np.zeros((capacity, n), dtype=np.float32)
        active = np.zeros((capacity, n), dtype=bool)
        live = np.zeros(capacity, dtype=bool)
        sources = np.full(capacity, -1, dtype=np.int64)
        lane_iters = np.zeros(capacity, dtype=np.int64)
        lane_bytes = np.zeros(capacity, dtype=np.float64)
        lane_loads = np.zeros(capacity, dtype=np.float64)
        lane_seed: List[Optional[LaneSeed]] = [None] * capacity

        def admit(slot: int, seed: LaneSeed) -> None:
            v, a = prog.init_lane(meta, seed.source)
            vals[slot] = v
            active[slot] = a
            live[slot] = True
            sources[slot] = seed.source
            lane_iters[slot] = 0
            lane_bytes[slot] = 0.0
            lane_loads[slot] = 0.0
            lane_seed[slot] = seed

        for slot, seed in enumerate(seeds):
            admit(slot, seed)

        pstats = PipelineStats()
        xstats = ExecStats()
        it = 0
        # One pinned delta session for the WHOLE sweep: mutations published
        # while lanes are in flight become visible to the NEXT sweep, never
        # mid-query — every result is computed at exactly one graph version.
        with engine._sweep_session():
            while live.any():
                t0 = time.perf_counter()
                io0 = engine.store.io.snapshot()
                pstats.reset()
                xstats.reset()

                live_slots = np.flatnonzero(live)
                union_ids = np.flatnonzero(active[live].any(axis=0)).astype(np.int64)
                lane_active = None
                if self.lane_selective and len(live_slots) > 1:
                    lane_active = [
                        np.flatnonzero(active[k]).astype(np.int64)
                        for k in live_slots
                    ]
                plan = engine.scheduler.plan(union_ids, lane_active=lane_active)
                msgs = prog.pre(vals, meta.out_deg).astype(np.float32)
                dst = vals.copy()  # carried over for skipped shards/lanes

                loaded = engine.pipeline.iter_shards(plan.shards, stats=pstats)
                rows_skipped = 0
                if plan.lane_masks is None:
                    for res in self.executor.run(loaded, msgs, prog.combine, xstats):
                        new = prog.apply(
                            np.asarray(res.acc, dtype=vals.dtype),
                            vals[:, res.v0: res.v1],
                            meta,
                            res.v0,
                            sources,
                        )
                        dst[:, res.v0: res.v1] = new
                else:
                    rows_skipped = self._run_masked(
                        plan, loaded, live_slots, msgs, vals, dst,
                        sources, xstats,
                    )
                # Retired / free lanes stay frozen at their final values.
                dst[~live] = vals[~live]

                new_active = prog.is_active(dst, vals)
                new_active[~live] = False
                vals, active = dst, new_active
                lane_iters[live] += 1

                # --------------------------------- per-lane cost attribution
                dio = engine.store.io - io0
                n_live = int(live.sum())
                lane_bytes[live] += dio.bytes_read / n_live
                lane_loads[live] += plan.num_planned / n_live

                # ----------------------------------- retirement + backfill
                retired = 0
                for k in np.flatnonzero(live):
                    seed = lane_seed[k]
                    converged = not active[k].any()
                    if converged or lane_iters[k] >= seed.max_iters:
                        live[k] = False
                        active[k] = False
                        retired += 1
                        res_k = LaneResult(
                            token=seed.token,
                            source=seed.source,
                            values=vals[k].copy(),
                            iterations=int(lane_iters[k]),
                            converged=converged,
                            bytes_read=float(lane_bytes[k]),
                            shard_loads=float(lane_loads[k]),
                        )
                        results.append(res_k)
                        if on_retire is not None:
                            on_retire(res_k)

                backfilled = 0
                if backfill is not None:
                    free = list(np.flatnonzero(~live))
                    while free:
                        got = list(backfill(len(free)))
                        if not got:
                            break
                        for seed in got:
                            if seed.max_iters <= 0:
                                finish_zero_budget(seed)  # slot stays free
                            else:
                                admit(int(free.pop(0)), seed)
                                backfilled += 1

                self.iter_stats.append(
                    SweepIterStats(
                        iteration=it,
                        live_lanes=n_live,
                        shards_processed=plan.num_planned,
                        shards_skipped=plan.num_skipped,
                        bytes_read=dio.bytes_read,
                        selective_on=plan.selective_on,
                        retired=retired,
                        backfilled=backfilled,
                        time_s=time.perf_counter() - t0,
                        lane_rows_skipped=rows_skipped,
                    )
                )
                it += 1
        return results

    # ------------------------------------------------- lane-masked dispatch
    def _run_masked(
        self,
        plan: ShardPlan,
        loaded,
        live_slots: np.ndarray,
        msgs: np.ndarray,
        vals: np.ndarray,
        dst: np.ndarray,
        sources: np.ndarray,
        xstats: ExecStats,
    ) -> int:
        """Execute the plan with per-shard lane masks: consecutive shards
        sharing a mask are dispatched together (preserving shard batching)
        on ONLY the masked lanes' message rows; unmasked lanes keep their
        carried values for that interval.  Returns skipped dispatch rows.

        Message sub-matrices are padded to pow2 lane counts (same shape
        discipline as the batcher) so jit'd lane kernels see bounded
        shapes; padding rows are zeros and their results are discarded.
        """
        prog, meta = self.program, self.engine.meta
        batch = getattr(self.executor, "batch_shards", 1)
        n_live = len(live_slots)
        rows_skipped = 0
        group: List = []
        group_mask: Optional[np.ndarray] = None

        def flush() -> None:
            nonlocal group, group_mask, rows_skipped
            if not group:
                return
            slots = live_slots[group_mask]
            m = len(slots)
            cap_sub = pad_lanes(m) if self.pad_pow2 else m
            sub = np.zeros((cap_sub, msgs.shape[1]), dtype=msgs.dtype)
            sub[:m] = msgs[slots]
            for res in self.executor.run(group, sub, prog.combine, xstats):
                acc = np.asarray(res.acc, dtype=vals.dtype)[:m]
                new = prog.apply(
                    acc,
                    vals[slots, res.v0: res.v1],
                    meta,
                    res.v0,
                    sources[slots],
                )
                dst[slots, res.v0: res.v1] = new
            rows_skipped += (n_live - m) * len(group)
            group, group_mask = [], None

        for ls in loaded:
            mask = plan.lane_masks[ls.shard_id]
            if group and (
                len(group) >= batch or not np.array_equal(mask, group_mask)
            ):
                flush()
            group_mask = mask
            group.append(ls)
        flush()
        return rows_skipped
