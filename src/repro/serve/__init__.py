"""GraphServe: concurrent multi-query serving on a warm VSW engine.

The paper's VSW model makes ONE sweep I/O-optimal; this package amortizes
that sweep across queries.  K concurrent per-source queries (BFS / SSSP /
personalized PageRank) execute as *lanes* of one sweep: vertex state is
``(K, n)``, each shard is loaded and decoded once per iteration and applied
to every lane in a single batched dispatch, so the expected read volume per
query drops from ``θ·D·|E|`` to ``≈ θ·D·|E| / K`` (DESIGN.md §6).

Layers (bottom-up):

Heterogeneous programs share streams two ways (DESIGN.md §9): same-algebra
programs (BFS/SSSP/WCC, or PPR at any damping) FUSE into one lane table,
and different algebra groups INTERLEAVE on one sweep — each loaded shard
is dispatched once per live group.

==========  ===============================================================
sweep       :class:`~repro.serve.sweep.FusedSweep` — drives the engine's
            scheduler/pipeline for G program groups on one pinned shard
            stream; each group is a :class:`~repro.serve.sweep.LaneTable`
            (slot state, admission, retirement, per-group backfill).
            :class:`~repro.serve.sweep.LaneSweep` is the single-program
            wrapper.
batcher     :class:`~repro.serve.batcher.LaneBatcher` — forms fusion sets:
            groups requests by combine algebra (then by group budget),
            padded to pow2 lane counts to bound recompiles.
session     :class:`~repro.serve.session.SessionCache` — LRU result cache
            keyed by (program, source, graph-version).
service     :class:`~repro.serve.service.GraphService` — request queue,
            admission by lane budget, worker thread, mask-aware per-request
            latency / I/O attribution.
loadgen     :class:`~repro.serve.loadgen.LoadGenerator` — closed/open-loop
            workload replay with warmup/measure/drain phases and a seeded,
            bitwise-reproducible operation schedule (GraphPulse,
            DESIGN.md §13).
==========  ===============================================================
"""

from .batcher import LaneBatcher, pad_lanes
from .loadgen import (
    LoadGenerator,
    LoadReport,
    OpRecord,
    QueryClass,
    UpdateRecord,
    Workload,
    edge_state_at_version,
    oracle_kwargs,
)
from .service import GraphService, QueryResult, ServiceOverloaded, UpdateResult
from .session import SessionCache
from .sweep import (
    FusedSweep,
    LaneResult,
    LaneSeed,
    LaneSweep,
    LaneTable,
    MeshSweep,
    SweepIterStats,
)

__all__ = [
    "GraphService",
    "QueryResult",
    "ServiceOverloaded",
    "UpdateResult",
    "LaneBatcher",
    "pad_lanes",
    "SessionCache",
    "FusedSweep",
    "LaneTable",
    "LaneSweep",
    "LaneSeed",
    "LaneResult",
    "MeshSweep",
    "SweepIterStats",
    "LoadGenerator",
    "LoadReport",
    "OpRecord",
    "QueryClass",
    "UpdateRecord",
    "Workload",
    "edge_state_at_version",
    "oracle_kwargs",
]
