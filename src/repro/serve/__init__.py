"""GraphServe: concurrent multi-query serving on a warm VSW engine.

The paper's VSW model makes ONE sweep I/O-optimal; this package amortizes
that sweep across queries.  K concurrent per-source queries (BFS / SSSP /
personalized PageRank) execute as *lanes* of one sweep: vertex state is
``(K, n)``, each shard is loaded and decoded once per iteration and applied
to every lane in a single batched dispatch, so the expected read volume per
query drops from ``θ·D·|E|`` to ``≈ θ·D·|E| / K`` (DESIGN.md §6).

Layers (bottom-up):

==========  ===============================================================
sweep       :class:`~repro.serve.sweep.LaneSweep` — drives the engine's
            scheduler/pipeline with lane-dimensional executors; lanes
            retire on convergence and are backfilled mid-flight.
batcher     :class:`~repro.serve.batcher.LaneBatcher` — groups compatible
            requests (same vertex program + static params) into lane
            batches, padded to pow2 lane counts to bound recompiles.
session     :class:`~repro.serve.session.SessionCache` — LRU result cache
            keyed by (program, source, graph-version).
service     :class:`~repro.serve.service.GraphService` — request queue,
            admission by lane budget, worker thread, per-request
            latency / I/O attribution.
==========  ===============================================================
"""

from .batcher import LaneBatcher, pad_lanes
from .service import GraphService, QueryResult, ServiceOverloaded, UpdateResult
from .session import SessionCache
from .sweep import LaneResult, LaneSeed, LaneSweep, SweepIterStats

__all__ = [
    "GraphService",
    "QueryResult",
    "ServiceOverloaded",
    "UpdateResult",
    "LaneBatcher",
    "pad_lanes",
    "SessionCache",
    "LaneSweep",
    "LaneSeed",
    "LaneResult",
    "SweepIterStats",
]
