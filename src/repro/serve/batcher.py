"""Lane batching: form fusion sets from pending requests, pad lanes pow2.

Two requests may ride the same *lane table* (one lane matrix, one combine
kernel) iff their programs share a
:attr:`~repro.core.apps.LaneProgram.combine_key` — the same combine
algebra.  BFS, SSSP and WCC all carry ``("min",)`` and fuse into one
table even though their per-lane ``pre``/``apply`` differ (the lane table
applies those per lane); two PPR requests with different damping fuse the
same way under ``("sum",)``.  Requests whose algebras differ cannot share
a lane matrix, but they CAN share the shard stream: :meth:`form_fused`
returns up to ``max_groups`` groups — a *fusion set* — that one
:class:`~repro.serve.sweep.FusedSweep` interleaves over a single sweep
(one load per shard, one dispatch per group).

Formation is FIFO from the oldest request: the oldest pending request
defines group 0's combine key and takes up to ``max_lanes`` fusable
followers; the oldest *remaining* request defines group 1; and so on up
to ``max_groups``.  Everything else stays queued in order — no
starvation: the oldest request always rides the next sweep.

``fuse_programs=False`` restores PR 2's key-equality batching (one group,
identical program keys only) — the baseline the fusion benchmarks compare
against.

Lane counts are padded to the next power of two (:func:`pad_lanes`) so
the jit'd lane kernels see a bounded set of shapes — at most
``log2(max_lanes)+1`` lane extents, mirroring the shape-bucketing of the
batched shard dispatch (DESIGN.md §4).

Mesh sweeps change none of this (DESIGN.md §10): the lane axis is
REPLICATED across devices — each device applies every lane to its own
destination-interval slice — so batching, fusion-set formation and the
pow2 padding are device-count-independent: the same ``pad_lanes`` buckets
bound retraces of the shard_map'd lane kernel for every mesh size.
"""

from __future__ import annotations

from typing import Any, Callable, Deque, List

from repro.core.csr import next_pow2
from repro.obs import trace

__all__ = ["pad_lanes", "LaneBatcher"]


def pad_lanes(n: int) -> int:
    """Padded lane capacity for a batch of ``n`` requests (pow2, >= 1)."""
    return next_pow2(max(n, 1))


class LaneBatcher:
    """Forms lane batches / fusion sets from a FIFO of pending requests.

    Pending entries are duck-typed: anything with ``key`` and
    ``combine_key`` attributes (the service uses its internal ``_Pending``
    records).  The caller owns the deque's lock — the batcher only
    mutates, never blocks.
    """

    def __init__(
        self,
        max_lanes: int = 16,
        *,
        pad_pow2: bool = True,
        max_groups: int = 2,
        fuse_programs: bool = True,
    ):
        if max_lanes < 1:
            raise ValueError("max_lanes must be >= 1")
        if max_groups < 1:
            raise ValueError("max_groups must be >= 1")
        self.max_lanes = max_lanes
        self.pad_pow2 = pad_pow2
        self.max_groups = max_groups
        self.fuse_programs = fuse_programs

    def capacity(self, batch_size: int) -> int:
        """Lane-matrix extent allocated for a batch of ``batch_size``."""
        return pad_lanes(batch_size) if self.pad_pow2 else max(batch_size, 1)

    def _take(
        self, pending: Deque[Any], match: Callable[[Any], bool], limit: int
    ) -> List[Any]:
        """Remove and return up to ``limit`` matching entries, preserving
        the relative order of everything left queued."""
        if limit <= 0 or not pending:
            return []
        taken: List[Any] = []
        keep: List[Any] = []
        while pending:
            item = pending.popleft()
            if len(taken) < limit and match(item):
                taken.append(item)
            else:
                keep.append(item)
        pending.extend(keep)
        return taken

    def take_compatible(
        self, pending: Deque[Any], key: Any, limit: int
    ) -> List[Any]:
        """Up to ``limit`` entries with program key EQUAL to ``key`` (PR 2
        compatibility batching — one program, identical static params)."""
        return self._take(pending, lambda item: item.key == key, limit)

    def take_fusable(
        self, pending: Deque[Any], combine_key: Any, limit: int
    ) -> List[Any]:
        """Up to ``limit`` entries whose programs FUSE with ``combine_key``
        — same algebra, any program/params (one lane table)."""
        if not self.fuse_programs:
            # key-only mode: a "fusable" follower must match exactly; the
            # caller passes the group's first key as the combine key.
            return self.take_compatible(pending, combine_key, limit)
        return self._take(
            pending, lambda item: item.combine_key == combine_key, limit
        )

    def group_key(self, entry: Any) -> Any:
        """The fusion identity of ``entry`` under the current policy."""
        return entry.combine_key if self.fuse_programs else entry.key

    def form(self, pending: Deque[Any]) -> List[Any]:
        """PR 2 API: the next single batch — the oldest request plus up to
        ``max_lanes - 1`` followers with the identical program key."""
        if not pending:
            return []
        return self.take_compatible(pending, pending[0].key, self.max_lanes)

    def form_fused(self, pending: Deque[Any]) -> List[List[Any]]:
        """The next fusion set: up to ``max_groups`` groups, each up to
        ``max_lanes`` requests sharing a combine algebra, oldest-first."""
        with trace.span("batch.form") as sp:
            groups: List[List[Any]] = []
            while pending and len(groups) < self.max_groups:
                g = self.take_fusable(
                    pending, self.group_key(pending[0]), self.max_lanes
                )
                if not g:  # pragma: no cover — take of the head never misses
                    break
                groups.append(g)
            sp.set(groups=len(groups), lanes=sum(len(g) for g in groups))
            return groups
