"""Lane batching: group compatible requests, pad lane counts to pow2.

Two requests may ride the same sweep iff their lane programs are
*compatible* — equal :attr:`~repro.core.apps.LaneProgram.key`, i.e. the
same algebra AND the same static parameters (a damping=0.85 PPR cannot
share a lane matrix with damping=0.9).  The batcher scans the pending deque
FIFO, takes up to ``max_lanes`` requests matching the oldest request's key,
and leaves everything else queued in order — no starvation: the oldest
request always defines the next batch.

Lane counts are padded to the next power of two
(:func:`pad_lanes`) so the jit'd lane kernels see a bounded set of shapes
— at most ``log2(max_lanes)+1`` lane extents, mirroring the shape-bucketing
of the batched shard dispatch (DESIGN.md §4).
"""

from __future__ import annotations

from collections import deque
from typing import Any, Deque, List

from repro.core.csr import next_pow2

__all__ = ["pad_lanes", "LaneBatcher"]


def pad_lanes(n: int) -> int:
    """Padded lane capacity for a batch of ``n`` requests (pow2, >= 1)."""
    return next_pow2(max(n, 1))


class LaneBatcher:
    """Forms lane batches from a FIFO of pending requests.

    Pending entries are duck-typed: anything with a ``key`` attribute
    (the service uses its internal ``_Pending`` records).  The caller owns
    the deque's lock — the batcher only mutates, never blocks.
    """

    def __init__(self, max_lanes: int = 16, *, pad_pow2: bool = True):
        if max_lanes < 1:
            raise ValueError("max_lanes must be >= 1")
        self.max_lanes = max_lanes
        self.pad_pow2 = pad_pow2

    def capacity(self, batch_size: int) -> int:
        """Lane-matrix extent allocated for a batch of ``batch_size``."""
        return pad_lanes(batch_size) if self.pad_pow2 else max(batch_size, 1)

    def take_compatible(
        self, pending: Deque[Any], key: Any, limit: int
    ) -> List[Any]:
        """Remove and return up to ``limit`` entries whose key equals
        ``key``, preserving the relative order of everything left queued."""
        if limit <= 0 or not pending:
            return []
        taken: List[Any] = []
        keep: List[Any] = []
        while pending:
            item = pending.popleft()
            if len(taken) < limit and item.key == key:
                taken.append(item)
            else:
                keep.append(item)
        pending.extend(keep)
        return taken

    def form(self, pending: Deque[Any]) -> List[Any]:
        """Take the next batch: the oldest request plus up to
        ``max_lanes - 1`` compatible followers."""
        if not pending:
            return []
        return self.take_compatible(pending, pending[0].key, self.max_lanes)
