"""TPU v5e hardware constants (the TARGET platform; CPU is the dev host)."""

PEAK_FLOPS_BF16 = 197e12  # per chip, bf16
PEAK_FLOPS_INT8 = 394e12
HBM_BW = 819e9  # bytes/s per chip
HBM_BYTES = 16 * 2**30  # 16 GiB per chip
ICI_BW_PER_LINK = 50e9  # bytes/s per link (~) — in-pod torus links
ICI_LINKS = 4  # v5e: 4 links per chip (2D torus x2 dirs)
DCN_BW = 6.25e9  # bytes/s per host cross-pod (conservative 50 Gb/s)
VMEM_BYTES = 128 * 2**20  # ~128MB vector memory per chip

CHIPS_PER_POD = 256  # 16 x 16


def chips(mesh_shape) -> int:
    n = 1
    for s in mesh_shape:
        n *= s
    return n
