"""Render dry-run JSON results into the EXPERIMENTS.md roofline tables.

    PYTHONPATH=src python -m repro.roofline.report reports/dryrun_single.json
"""

from __future__ import annotations

import json
import sys
from typing import Dict, List

from . import hw


def _fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def roofline_fraction(r: Dict) -> float:
    """Useful-compute fraction: MODEL_FLOPS / (chips * peak * bound_time).

    This is the MFU-style score the perf loop drives up: analytic model
    flops divided by what the chips could do in the (no-overlap) roofline
    step time.
    """
    t = r["terms"]
    step = t["compute_s"] + t["memory_s"] + t["collective_s"]
    if step <= 0 or not r.get("model_flops"):
        return 0.0
    return r["model_flops"] / (t["n_chips"] * hw.PEAK_FLOPS_BF16 * step)


def roofline_fraction_overlap(r: Dict) -> float:
    """Same metric against the perfect-overlap bound (max of terms)."""
    t = r["terms"]
    step = max(t["compute_s"], t["memory_s"], t["collective_s"])
    if step <= 0 or not r.get("model_flops"):
        return 0.0
    return r["model_flops"] / (t["n_chips"] * hw.PEAK_FLOPS_BF16 * step)


def render_table(results: List[Dict], mesh: str) -> str:
    rows = [r for r in results if r["mesh"] == mesh and r["ok"] and r.get("terms")]
    out = [
        f"| arch | shape | compute | memory | collective | dominant | "
        f"MFLOPs/HLO | frac (sum) | frac (overlap) |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"])):
        t = r["terms"]
        ratio = r.get("hlo_flops_ratio", 0.0)
        out.append(
            f"| {r['arch']} | {r['shape']} | {_fmt_s(t['compute_s'])} | "
            f"{_fmt_s(t['memory_s'])} | {_fmt_s(t['collective_s'])} | "
            f"**{t['dominant']}** | {ratio:.2f} | {roofline_fraction(r):.3f} | "
            f"{roofline_fraction_overlap(r):.3f} |"
        )
    return "\n".join(out)


def render_memory_table(results: List[Dict], mesh: str) -> str:
    rows = [r for r in results if r["mesh"] == mesh and r["ok"] and r.get("memory")]
    out = [
        "| arch | shape | args/dev | temp/dev (cpu) | peak TPU-est | fits 16GiB |",
        "|---|---|---|---|---|---|",
    ]
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"])):
        m = r["memory"]
        tpu = r.get("peak_tpu_est", m["argument_bytes"] + m["temp_bytes"] // 2)
        fits = "yes" if tpu <= hw.HBM_BYTES else "**NO**"
        out.append(
            f"| {r['arch']} | {r['shape']} | "
            f"{m['argument_bytes']/2**30:.2f}GiB | "
            f"{m['temp_bytes']/2**30:.2f}GiB | "
            f"{tpu/2**30:.2f}GiB | {fits} |"
        )
    return "\n".join(out)


def summarize(path: str) -> None:
    with open(path) as f:
        results = json.load(f)
    meshes = sorted({r["mesh"] for r in results})
    ok = sum(r["ok"] for r in results)
    print(f"# {path}: {ok}/{len(results)} cells ok\n")
    for mesh in meshes:
        print(f"\n## roofline — mesh={mesh}\n")
        print(render_table(results, mesh))
        print(f"\n## memory — mesh={mesh}\n")
        print(render_memory_table(results, mesh))
    bad = [r for r in results if not r["ok"]]
    if bad:
        print("\n## FAILURES\n")
        for r in bad:
            print(f"- {r['arch']} x {r['shape']} ({r['mesh']}): {r['error']}")


if __name__ == "__main__":
    summarize(sys.argv[1])
