"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds (EXPERIMENTS.md §Roofline):

    compute    = HLO_FLOPs_per_device / PEAK_FLOPS_BF16
    memory     = HLO_bytes_per_device / HBM_BW
    collective = collective_bytes_per_device / (ICI_LINKS * ICI_BW_PER_LINK)

Sources:
- ``compiled.cost_analysis()`` provides per-device FLOPs and bytes of the
  PARTITIONED module (measured: GSPMD-partitioned modules report the
  per-participant cost).
- collective bytes come from parsing ``compiled.as_text()``: we sum the
  wire-relevant operand size of every all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute op (shapes in optimized
  HLO are already the per-device shard shapes).

**Scan correction** (methodology): ``lax.scan`` bodies appear ONCE in HLO,
so both cost_analysis and a naive text parse undercount by the trip count.
We correct exactly: compile the model AND an outer-only (0-layer) variant —
``corrected = (full - outer) * trips + outer`` — and multiply collectives
found inside while-loop body computations by the trip count.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

from . import hw

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(text: str) -> int:
    """Sum byte sizes of every dtype[shape] group in a (possibly tuple) type."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int]
    count_by_kind: Dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def _split_computations(hlo: str) -> Dict[str, List[str]]:
    """computation name -> op lines (optimized HLO text)."""
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if not line.startswith(" ") and ("{" in line) and ("(" in line):
            m = re.match(r"(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(", line.strip())
            if m:
                cur = m.group(1)
                comps[cur] = []
                continue
        if cur is not None and stripped and stripped != "}":
            comps[cur].append(stripped)
        if not line.startswith(" ") and stripped == "}":
            cur = None
    return comps


def _while_body_names(comps: Dict[str, List[str]]) -> List[str]:
    bodies = []
    for lines in comps.values():
        for ln in lines:
            if " while(" in ln or "= while(" in ln:
                m = re.search(r"body=%?([\w\.\-]+)", ln)
                if m:
                    bodies.append(m.group(1))
    return bodies


def parse_collectives(hlo: str, loop_trips: int = 1) -> CollectiveStats:
    """Sum collective wire bytes; ops inside while bodies count loop_trips x.

    Wire convention per op kind (documented, consistent across cells):
      all-gather:        output bytes (what lands on each device)
      all-reduce:        output bytes
      reduce-scatter:    input bytes (what leaves each device)
      all-to-all:        output bytes
      collective-permute: output bytes
    """
    comps = _split_computations(hlo)
    bodies = set(_while_body_names(comps))
    bytes_by: Dict[str, int] = {k: 0 for k in COLLECTIVES}
    count_by: Dict[str, int] = {k: 0 for k in COLLECTIVES}

    for name, lines in comps.items():
        mult = loop_trips if name in bodies else 1
        for ln in lines:
            for kind in COLLECTIVES:
                # match the op, not tuple types: " all-gather(" etc.
                if f" {kind}(" in ln or f"{kind}-start(" in ln:
                    if kind == "reduce-scatter":
                        # input operand shapes appear inside the parens;
                        # fall back to output if none parse.
                        m = re.search(r"{}\((.*)\)".format(kind), ln)
                        size = _shape_bytes(ln.split("=")[0])
                        # output of reduce-scatter is 1/N of input: input =
                        # output * group size; approximate with output if
                        # operand text has no shapes (HLO refs are %names).
                        bytes_by[kind] += size * mult
                    else:
                        size = _shape_bytes(ln.split(f" {kind}")[0])
                        bytes_by[kind] += size * mult
                    count_by[kind] += 1
                    break
    return CollectiveStats(bytes_by, count_by)


@dataclasses.dataclass
class RooflineTerms:
    flops_per_dev: float
    bytes_per_dev: float
    collective_bytes_per_dev: float
    n_chips: int

    @property
    def compute_s(self) -> float:
        return self.flops_per_dev / hw.PEAK_FLOPS_BF16

    @property
    def memory_s(self) -> float:
        return self.bytes_per_dev / hw.HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes_per_dev / (hw.ICI_BW_PER_LINK * hw.ICI_LINKS)

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """No-overlap upper bound (sum) — conservative."""
        return self.compute_s + self.memory_s + self.collective_s

    @property
    def step_time_overlap_s(self) -> float:
        """Perfect-overlap lower bound (max)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    def as_dict(self) -> Dict:
        return {
            "flops_per_dev": self.flops_per_dev,
            "bytes_per_dev": self.bytes_per_dev,
            "collective_bytes_per_dev": self.collective_bytes_per_dev,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "n_chips": self.n_chips,
        }


def corrected_terms(
    full_cost: Dict, outer_cost: Dict,
    full_hlo: str, trips: int, n_chips: int,
    extra_scans: Optional[List[Tuple[Dict, int]]] = None,
) -> RooflineTerms:
    """Apply the scan correction to cost_analysis numbers + HLO collectives.

    ``extra_scans``: [(cost_of_variant_without_that_scan, its_trips)]
    handles multi-scan models (whisper enc+dec) by telescoping subtraction;
    for the common single-scan case pass None.
    """
    def get(d, k):
        return float(d.get(k, 0.0) or 0.0)

    f_full, f_outer = get(full_cost, "flops"), get(outer_cost, "flops")
    b_full, b_outer = (
        get(full_cost, "bytes accessed"), get(outer_cost, "bytes accessed"),
    )
    flops = (f_full - f_outer) * trips + f_outer
    byts = (b_full - b_outer) * trips + b_outer
    if extra_scans:
        for mid_cost, mid_trips in extra_scans:
            # contribution already included once at trips x; adjust the
            # difference between full and mid to mid_trips instead.
            df = get(full_cost, "flops") - get(mid_cost, "flops")
            db = get(full_cost, "bytes accessed") - get(mid_cost, "bytes accessed")
            flops += df * (mid_trips - trips)
            byts += db * (mid_trips - trips)

    col = parse_collectives(full_hlo, loop_trips=trips)
    return RooflineTerms(
        flops_per_dev=flops,
        bytes_per_dev=byts,
        collective_bytes_per_dev=float(col.total_bytes),
        n_chips=n_chips,
    )


def attention_analytic(cfg, shape, mode: str) -> Tuple[float, float]:
    """Global (flops, bytes) of causal self-attention einsums.

    Used ONLY when the kv-blocked attention path is active (long-sequence
    prefill): the lax.scan over kv blocks hides (nk-1)/nk of these FLOPs
    from cost_analysis, so the roofline pipeline adds the analytic total
    (and drops the 1/nk double count, which is <4% and conservative).

    fwd flops per layer = 4 * B * H * pairs * head_dim  (QK^T + AV);
    train multiplies by 4 (forward + remat re-forward + 2x backward).
    """
    S, B = shape.seq_len, shape.global_batch
    H, hd = cfg.num_heads, cfg.head_dim
    n_attn = sum(
        1 for i in range(cfg.num_layers) if cfg.layer_kind(i)[0] == "attn"
    )
    pairs = S * (S + 1) / 2  # causal
    mult = 4.0 if mode == "train" else 1.0
    flops = 4.0 * B * H * pairs * hd * n_attn * mult
    # bytes: q/k/v/o streamed once per layer (blocked path keeps q resident)
    byts = B * S * hd * (2 * H + 2 * cfg.num_kv_heads) * 2 * n_attn * mult
    return flops, byts


def model_flops(cfg, shape, mode: str) -> float:
    """Analytic MODEL_FLOPS: 6·N·D train, 2·N·D forward (N = active params).

    For decode, D = tokens processed per step (= global_batch)."""
    n = cfg.active_param_count
    if mode == "train":
        d = shape.seq_len * shape.global_batch
        return 6.0 * n * d
    if mode == "prefill":
        d = shape.seq_len * shape.global_batch
        return 2.0 * n * d
    d = shape.global_batch  # decode: one token per sequence
    return 2.0 * n * d
