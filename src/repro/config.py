"""Config system: model/arch configs, input shapes, and run settings.

One frozen dataclass describes an architecture; ``src/repro/configs/<id>.py``
instantiates it with the exact published numbers.  ``ShapeConfig`` describes
one of the assigned input-shape cells (train_4k / prefill_32k / decode_32k /
long_500k).  ``resolve()`` applies CLI-style ``key=value`` overrides.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "smoke_config"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- block wiring -----------------------------------------------------
    mlp_type: str = "swiglu"  # swiglu | geglu | gelu
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    logit_softcap: float = 0.0

    # --- MoE ---------------------------------------------------------------
    num_experts: int = 0
    top_k: int = 0
    moe_every: int = 1  # MoE MLP on layers with (i % moe_every == moe_every-1)
    capacity_factor: float = 1.25
    dense_d_ff: int = 0  # d_ff of the non-MoE layers in a mixed model

    # --- hybrid (jamba) / ssm (xlstm) ---------------------------------------
    attn_every: int = 0  # attention on layers with (i % attn_every == attn_offset)
    attn_offset: int = 0
    ssm_kind: str = ""  # "ssd" (mamba-2 chunked) | "xlstm"
    ssm_state: int = 128  # N
    ssm_head_dim: int = 64  # P
    ssm_expand: int = 2  # d_inner = expand * d_model
    ssm_chunk: int = 128
    slstm_every: int = 0  # xlstm: sLSTM on layers with (i % slstm_every == slstm_every-1)

    # --- enc-dec (whisper) ---------------------------------------------------
    encdec: bool = False
    num_encoder_layers: int = 0
    encoder_seq: int = 0  # fixed frame count from the (stub) audio frontend

    # --- modality frontend stubs --------------------------------------------
    frontend: str = "none"  # none | audio_stub | vision_stub
    prefix_len: int = 0  # vision: number of patch-embedding positions

    # ------------------------------------------------------------------ props
    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def group_period(self) -> int:
        """Layers per scan-group (1 for homogeneous stacks)."""
        periods = [p for p in (self.attn_every, self.moe_every, self.slstm_every) if p > 1]
        if not periods:
            return 1
        import math

        g = 1
        for p in periods:
            g = g * p // math.gcd(g, p)
        return g

    @property
    def num_groups(self) -> int:
        assert self.num_layers % self.group_period == 0, (
            self.name, self.num_layers, self.group_period)
        return self.num_layers // self.group_period

    def layer_kind(self, i: int) -> Tuple[str, str]:
        """(mixer, mlp) for layer i: mixer in {attn, ssd, mlstm, slstm},
        mlp in {dense, moe, none}."""
        if self.ssm_kind == "xlstm":
            mixer = "slstm" if (
                self.slstm_every and i % self.slstm_every == self.slstm_every - 1
            ) else "mlstm"
            return mixer, "none"  # xlstm blocks carry their own projections
        if self.attn_every:
            mixer = "attn" if i % self.attn_every == self.attn_offset else "ssd"
        else:
            mixer = "attn"
        if self.num_experts:
            mlp = "moe" if i % self.moe_every == self.moe_every - 1 else "dense"
        else:
            mlp = "dense"
        return mixer, mlp

    @property
    def param_count(self) -> int:
        """Approximate parameter count (for 6ND roofline math)."""
        d, ff, L = self.d_model, self.d_ff, self.num_layers
        embed = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        total = embed
        enc_layers = self.num_encoder_layers if self.encdec else 0
        for i in range(L):
            mixer, mlp = self.layer_kind(i)
            if mixer == "attn":
                total += d * (self.q_dim + 2 * self.kv_dim) + self.q_dim * d
                if self.encdec:  # cross attention in decoder
                    total += d * (self.q_dim + 2 * self.kv_dim) + self.q_dim * d
            elif mixer == "ssd":
                di = self.d_inner
                total += d * 2 * di + di * d + di * 4  # in/out proj + conv-ish
            elif mixer in ("mlstm", "slstm"):
                di = self.d_inner
                total += d * 2 * di + di * d + 3 * di * di // max(self.num_heads, 1)
            if mlp == "dense":
                f = self.dense_d_ff or ff
                mult = 3 if self.mlp_type in ("swiglu", "geglu") else 2
                total += mult * d * f
            elif mlp == "moe":
                mult = 3 if self.mlp_type in ("swiglu", "geglu") else 2
                total += self.num_experts * mult * d * ff + d * self.num_experts
        for _ in range(enc_layers):
            total += d * (self.q_dim + 2 * self.kv_dim) + self.q_dim * d
            total += 2 * d * ff  # whisper encoder uses gelu mlp
        return total

    @property
    def active_param_count(self) -> int:
        """Active params per token (MoE: only top_k experts count)."""
        if not self.num_experts:
            return self.param_count
        d, ff = self.d_model, self.d_ff
        mult = 3 if self.mlp_type in ("swiglu", "geglu") else 2
        dead = 0
        for i in range(self.num_layers):
            _, mlp = self.layer_kind(i)
            if mlp == "moe":
                dead += (self.num_experts - self.top_k) * mult * d * ff
        return self.param_count - dead


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # train | prefill | decode


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests (spec requirement)."""
    period = cfg.group_period
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        num_layers=2 * period,
        d_model=64,
        num_heads=4,
        num_kv_heads=max(1, min(cfg.num_kv_heads, 2)),
        head_dim=16,
        d_ff=128,
        dense_d_ff=128 if cfg.dense_d_ff else 0,
        vocab_size=512,
        num_experts=min(cfg.num_experts, 8) if cfg.num_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        # dropless at smoke scale so prefill/decode consistency is exact
        # regardless of sequence-length-dependent capacity
        capacity_factor=16.0 if cfg.num_experts else cfg.capacity_factor,
        ssm_state=16 if cfg.ssm_kind else cfg.ssm_state,
        ssm_head_dim=16 if cfg.ssm_kind else cfg.ssm_head_dim,
        ssm_chunk=16 if cfg.ssm_kind else cfg.ssm_chunk,
        num_encoder_layers=2 if cfg.encdec else 0,
        encoder_seq=32 if cfg.encdec else 0,
        prefix_len=8 if cfg.frontend == "vision_stub" else 0,
    )
