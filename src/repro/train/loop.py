"""The fault-tolerant training loop.

Wires together: data loader (stateless resume), jit'd train step, async
sharded checkpointing, preemption guard, straggler monitor.  Used by
``launch/train.py`` and the end-to-end example; exercised (including the
crash/restart path) by tests/test_train_loop.py.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.config import ModelConfig
from repro.data.tokens import DataConfig, add_frontend_stub, make_batch
from repro.distributed.fault_tolerance import PreemptionGuard, StragglerMonitor
from repro.distributed.sharding import ShardingCtx
from repro.models import model as M
from repro.optim import adamw
from repro.optim.compression import CompressionConfig, init_error_state
from repro.train.step import make_train_step


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    log_every: int = 10
    microbatches: int = 1
    seed: int = 0


@dataclasses.dataclass
class LoopResult:
    final_step: int
    losses: List[float]
    step_times: List[float]
    straggler_events: int
    resumed_from: Optional[int]
    preempted: bool


def train(
    cfg: ModelConfig,
    data_cfg: DataConfig,
    loop_cfg: LoopConfig,
    opt_cfg: adamw.AdamWConfig,
    *,
    ctx: Optional[ShardingCtx] = None,
    checkpoint_dir: Optional[str] = None,
    compression: Optional[CompressionConfig] = None,
    preemption: Optional[PreemptionGuard] = None,
    param_dtype=None,
) -> LoopResult:
    import jax.numpy as jnp

    ctx = ctx or ShardingCtx()
    param_dtype = param_dtype or jnp.float32

    params = M.init_params(jax.random.key(loop_cfg.seed), cfg, dtype=param_dtype)
    opt_state = adamw.init(params)
    err_state = init_error_state(params) if compression else None

    ckpt = Checkpointer(checkpoint_dir) if checkpoint_dir else None
    start_step = 0
    resumed_from = None
    if ckpt is not None:
        latest = ckpt.latest_step()
        if latest is not None:
            state_like = {"params": params, "m": opt_state.m, "v": opt_state.v}
            restored = ckpt.restore(latest, state_like)
            params = restored["params"]
            opt_state = adamw.AdamWState(
                step=jnp.asarray(latest, jnp.int32),
                m=restored["m"], v=restored["v"],
            )
            start_step = latest
            resumed_from = latest

    step_fn = jax.jit(
        make_train_step(
            cfg, ctx, opt_cfg,
            microbatches=loop_cfg.microbatches, compression=compression,
        )
    )

    monitor = StragglerMonitor()
    losses: List[float] = []
    step_times: List[float] = []
    preempted = False
    step = start_step

    while step < loop_cfg.total_steps:
        monitor.start_step()
        batch_np = make_batch(data_cfg, step)
        if cfg.frontend != "none":
            batch_np = add_frontend_stub(batch_np, cfg, step)
        batch = jax.tree_util.tree_map(jnp.asarray, batch_np)
        params, opt_state, err_state, metrics = step_fn(
            params, opt_state, err_state, batch
        )
        loss = float(metrics["loss"])
        losses.append(loss)
        step += 1
        step_times.append(monitor.end_step(step))

        if loop_cfg.log_every and step % loop_cfg.log_every == 0:
            print(
                f"step {step:6d}  loss {loss:.4f}  "
                f"gnorm {float(metrics['grad_norm']):.3f}  "
                f"lr {float(metrics['lr']):.2e}  "
                f"t {step_times[-1]*1e3:.0f}ms"
            )
        want_ckpt = ckpt is not None and (
            step % loop_cfg.checkpoint_every == 0 or step == loop_cfg.total_steps
        )
        if preemption is not None and preemption.preempted:
            want_ckpt = ckpt is not None
            preempted = True
        if want_ckpt:
            ckpt.save_async(
                step,
                {"params": params, "m": opt_state.m, "v": opt_state.v},
                extra={"loss": loss},
            )
        if preempted:
            break

    if ckpt is not None:
        ckpt.wait()
    return LoopResult(
        final_step=step,
        losses=losses,
        step_times=step_times,
        straggler_events=len(monitor.events),
        resumed_from=resumed_from,
        preempted=preempted,
    )
