"""train_step / serve_step factories: the functions the dry-run lowers and
the drivers execute.

``make_train_step`` returns a pure function
    (params, opt_state, err_state, batch) -> (params', opt', err', metrics)
with loss+backward+AdamW fused in one jit, optional microbatch gradient
accumulation (scan over microbatches), and optional gradient compression on
the pod axis.  ``make_serve_steps`` returns (prefill_fn, decode_fn).
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.distributed.sharding import ShardingCtx
from repro.models import model as M
from repro.optim import adamw
from repro.optim.compression import CompressionConfig, compress_tree


def make_train_step(
    cfg: ModelConfig,
    ctx: ShardingCtx,
    opt_cfg: adamw.AdamWConfig,
    *,
    microbatches: int = 1,
    compression: Optional[CompressionConfig] = None,
    pod_axis: Optional[str] = None,
    accum_dtype=jnp.float32,
):
    """Build the jit-able train step.

    ``accum_dtype``: microbatch gradient-accumulator dtype.  f32 is the
    default; bf16 halves the accumulator HBM at >100B scale (acceptable at
    small microbatch counts — EXPERIMENTS.md §Perf jamba note)."""

    def loss_fn(params, batch):
        return M.train_loss(params, batch, cfg, ctx)

    def step(params, opt_state, err_state, batch):
        if microbatches > 1:
            # split the batch on the leading axis and scan, accumulating
            # grads in f32 — memory-bound cells trade HBM for steps.
            def mb_split(x):
                b = x.shape[0]
                return x.reshape(microbatches, b // microbatches, *x.shape[1:])

            mbatch = jax.tree_util.tree_map(mb_split, batch)
            gz = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params
            )

            def one(acc, mb):
                g0, l0 = acc
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                g0 = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(accum_dtype), g0, g
                )
                return (g0, l0 + l), None

            (grads, loss_sum), _ = jax.lax.scan(one, (gz, 0.0), mbatch)
            grads = jax.tree_util.tree_map(
                lambda g: g / microbatches, grads
            )
            loss = loss_sum / microbatches
            metrics = {"loss": loss}
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True
            )(params, batch)

        if compression is not None and compression.kind != "none":
            # Within-pod reduction already happened inside backward (psum
            # over 'data' via GSPMD).  Compress only the cross-pod wire.
            grads, err_state = compress_tree(grads, err_state, compression)

        params, opt_state, opt_metrics = adamw.apply_updates(
            params, grads, opt_state, opt_cfg
        )
        metrics = {**metrics, **opt_metrics, "loss": metrics["loss"]}
        return params, opt_state, err_state, metrics

    return step


def make_serve_steps(cfg: ModelConfig, ctx: ShardingCtx):
    def prefill_fn(params, batch):
        return M.prefill(params, batch, cfg, ctx)

    def decode_fn(params, tokens, caches, cache_index):
        return M.decode_step(params, tokens, caches, cache_index, cfg, ctx)

    return prefill_fn, decode_fn
