"""Sharded, async, fault-tolerant checkpointing.

Design for 1000+ nodes (DESIGN.md §5):

- **Sharded**: every host writes only the param/optimizer shards it owns
  (`<dir>/step_N/shard_<host>.npz`); no gather-to-host-0 (which is O(model)
  memory and a single point of failure).
- **Atomic**: writes go to `step_N.tmp/` then a single `os.replace` commit
  plus a `MANIFEST.json` carrying tree structure, logical axes, mesh-free;
  a crash mid-write never corrupts the newest checkpoint.
- **Mesh-agnostic restore (elastic scaling)**: the manifest records the
  LOGICAL axes of each leaf, not the mesh layout.  `restore()` re-shards
  onto whatever mesh/rules the new job uses — the checkpoint written by a
  512-chip job restores onto 256 or 1024 chips unchanged.
- **Async**: `save_async` snapshots device arrays to host then hands the
  file I/O to a worker thread — training continues during the write.
- **Integrity**: per-shard SHA-256 in the manifest, verified on restore.
"""

from __future__ import annotations

import dataclasses
import hashlib
import io
import json
import os
import shutil
import threading
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

SEP = "/"


def _flatten_with_names(tree) -> List[Tuple[str, Any]]:
    out = []

    def visit(path, leaf):
        name = SEP.join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path
        )
        out.append((name, leaf))
        return leaf

    jax.tree_util.tree_map_with_path(visit, tree)
    return out


class Checkpointer:
    def __init__(self, directory: str, *, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._last_error: Optional[BaseException] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree, *, host_id: int = 0, num_hosts: int = 1,
             extra: Optional[Dict] = None) -> str:
        """Synchronous sharded save of this host's leaves."""
        named = _flatten_with_names(tree)
        tmp = os.path.join(self.directory, f"step_{step:08d}.tmp")
        final = os.path.join(self.directory, f"step_{step:08d}")
        os.makedirs(tmp, exist_ok=True)

        # Host h owns leaves with index % num_hosts == h (simple, balanced;
        # on real multi-host each host instead writes its addressable shards).
        arrays, meta = {}, {}
        for i, (name, leaf) in enumerate(named):
            if i % num_hosts != host_id:
                continue
            arr = np.asarray(leaf)
            if arr.dtype.kind == "V" or str(arr.dtype) == "bfloat16":
                # npz can't hold bf16; upcast to f32 (exact), restore casts
                # back via the target tree's dtypes.
                arr = np.asarray(leaf, dtype=np.float32)
            key = f"a{i}"
            arrays[key] = arr
            meta[key] = {"name": name, "index": i,
                         "shape": list(arr.shape), "dtype": str(arr.dtype)}
        buf = io.BytesIO()
        np.savez(buf, **arrays)
        blob = buf.getvalue()
        digest = hashlib.sha256(blob).hexdigest()
        with open(os.path.join(tmp, f"shard_{host_id:05d}.npz"), "wb") as f:
            f.write(blob)

        manifest = {
            "step": step,
            "num_hosts": num_hosts,
            "num_leaves": len(named),
            "leaf_names": [n for n, _ in named],
            "shard_sha256": {str(host_id): digest},
            "extra": extra or {},
        }
        mpath = os.path.join(tmp, f"manifest_{host_id:05d}.json")
        with open(mpath, "w") as f:
            json.dump(manifest, f)
        # Host 0 commits once all hosts have written (single-host: now).
        if host_id == 0:
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)
            self._gc()
        return final

    def save_async(self, step: int, tree, **kw) -> None:
        """Snapshot to host memory, then write on a worker thread."""
        self.wait()
        host_tree = jax.tree_util.tree_map(np.asarray, tree)

        def work():
            try:
                self.save(step, host_tree, **kw)
            except BaseException as e:  # surfaced on next wait()
                self._last_error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._last_error is not None:
            err, self._last_error = self._last_error, None
            raise err

    # --------------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        steps = []
        for d in os.listdir(self.directory):
            if d.startswith("step_") and not d.endswith(".tmp"):
                try:
                    steps.append(int(d.split("_")[1]))
                except ValueError:
                    pass
        return max(steps) if steps else None

    def restore(
        self, step: int, like, *, shardings=None, verify: bool = True,
    ):
        """Restore into the structure of ``like``; optionally device_put with
        new shardings (elastic re-mesh)."""
        d = os.path.join(self.directory, f"step_{step:08d}")
        named = _flatten_with_names(like)
        leaves: List[Optional[np.ndarray]] = [None] * len(named)
        for fn in sorted(os.listdir(d)):
            if not fn.startswith("shard_"):
                continue
            host_id = int(fn.split("_")[1].split(".")[0])
            with open(os.path.join(d, fn), "rb") as f:
                blob = f.read()
            if verify:
                mpath = os.path.join(d, f"manifest_{host_id:05d}.json")
                with open(mpath) as f:
                    man = json.load(f)
                want = man["shard_sha256"][str(host_id)]
                got = hashlib.sha256(blob).hexdigest()
                if want != got:
                    raise IOError(
                        f"checkpoint shard {fn} corrupt: sha {got} != {want}"
                    )
            with np.load(io.BytesIO(blob)) as z:
                mpath = os.path.join(d, f"manifest_{host_id:05d}.json")
                with open(mpath) as f:
                    man = json.load(f)
                # keys are a<leafindex>
                for key in z.files:
                    idx = int(key[1:])
                    leaves[idx] = z[key]
        missing = [i for i, x in enumerate(leaves) if x is None]
        if missing:
            raise IOError(f"checkpoint step {step} missing leaves {missing[:5]}...")

        treedef = jax.tree_util.tree_structure(like)
        flat_like = jax.tree_util.tree_leaves(like)
        out = []
        for arr, ref in zip(leaves, flat_like):
            a = jnp.asarray(arr).astype(ref.dtype)
            out.append(a)
        tree = jax.tree_util.tree_unflatten(treedef, out)
        if shardings is not None:
            tree = jax.device_put(tree, shardings)
        return tree

    def read_extra(self, step: int) -> Dict:
        d = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(d, "manifest_00000.json")) as f:
            return json.load(f).get("extra", {})

    # ------------------------------------------------------------------- gc
    def _gc(self) -> None:
        steps = sorted(
            int(d.split("_")[1])
            for d in os.listdir(self.directory)
            if d.startswith("step_") and not d.endswith(".tmp")
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"))
