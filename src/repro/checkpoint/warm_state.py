"""Warm-restart checkpoints for the serving stack (DESIGN.md §12).

A cold ``GraphService`` boot pays one full pass over the store: every
shard is read once so the scheduler can build its Bloom/exact source
filters, the byte cache starts empty, and the session cache starts empty —
the first seconds after a restart are the slowest the service will ever
be.  None of that state is precious: all of it can be recomputed from the
store.  What a checkpoint buys is *time*: a snapshot of the warm state
lets a restarted process skip the filter-build read pass entirely and
answer repeat queries from cache immediately.

``WarmState`` captures, per snapshot:

- the per-shard unique-source arrays behind the Bloom/exact filters
  (``ShardScheduler.build_filters`` skips reading any shard whose sources
  were deposited via ``ShardStore.set_warm_sources``),
- the byte-cache warm set (shard ids, LRU -> MRU) — advisory: restoring
  it eagerly re-reads those shards, so it is applied only on request,
- the delta overlay coordinates it was taken at (publish ``version`` and
  per-shard absorbed ``floor``s) — the validity evidence,
- the service's ``graph_version`` and the session-cache entries (finished
  query results) at that version.

Validity is decided per shard at restore time, against the store as
recovered on disk (never the other way round — the checkpoint NEVER
overrides the store):

- the store must describe the same graph frame (``num_vertices``,
  ``num_shards``, intervals) and must not be *behind* the snapshot
  (``version >= snapshot version``; a lower version means the delta
  history was wiped, e.g. a re-ingest — everything is stale);
- a shard's sources are stale iff there is publish evidence past the
  snapshot: its floor or newest registered run seq exceeds the snapshot
  version.  Compaction alone never invalidates (it rewrites bytes, not
  logical content) — unless it absorbed runs the snapshot never saw,
  which is exactly the ``floor > snapshot version`` case;
- when both store and snapshot are at version 0 there is no delta
  history to compare, so the base container byte sizes stand in as the
  re-ingest detector: any mismatch rejects the whole snapshot;
- session entries are only valid when NOTHING changed:
  ``version == snapshot version`` exactly (and the frame checks pass).

Storage follows :mod:`repro.checkpoint.checkpointer`'s orbax-style
protocol — write into ``warm_<step>.tmp/``, fsync-free atomic
``os.replace`` to ``warm_<step>/``, SHA-256 of the payload recorded in
``MANIFEST.json``, bounded retention — but is numpy-only: restoring warm
state must not drag jax into a serving boot.
"""

from __future__ import annotations

import dataclasses
import hashlib
import io
import json
import os
import shutil
from typing import Dict, List, Optional, Tuple

import numpy as np

__all__ = [
    "SessionEntry",
    "WarmState",
    "WarmStateCheckpointer",
    "apply_warm_state",
    "capture_warm_state",
    "prewarm_cache",
]

_PREFIX = "warm_"
_FORMAT = 1


@dataclasses.dataclass
class SessionEntry:
    """One finished query result worth answering from cache after restart."""

    program: str  # program name as submitted
    key: Tuple  # LaneProgram.key (flat tuple of primitives)
    source: int
    values: np.ndarray
    iterations: int
    converged: bool


@dataclasses.dataclass
class WarmState:
    """Everything a restarted service can reuse instead of recompute."""

    store_version: int  # delta publish seq at snapshot (0 = base only)
    graph_version: int  # service-level version counter at snapshot
    num_vertices: int
    num_shards: int
    intervals: np.ndarray  # the store's destination intervals
    floors: Dict[int, int]  # shard -> absorbed watermark at snapshot
    bloom_sources: Dict[int, np.ndarray]  # shard -> unique source ids
    shard_sizes: Dict[int, int]  # shard -> base CSR container bytes
    cache_shards: Tuple[int, ...]  # byte-cache warm set, LRU -> MRU
    sessions: List[SessionEntry]


# --------------------------------------------------------------- capture
def capture_warm_state(service) -> WarmState:
    """Snapshot a live :class:`~repro.serve.service.GraphService`.

    Safe while serving: every piece is either immutable or read through
    its owner's lock, and a publish racing the capture only makes the
    source arrays a *superset* of some consistent state — supersets cost
    wasted loads on the restarted engine, never correctness (the same
    contract ``ShardScheduler.refresh_shard_sources`` documents).
    """
    engine = service.engine
    store = engine.store
    meta = store.read_meta()
    delta = store.delta
    store_version = delta.version if delta is not None else 0
    floors = delta.floors() if delta is not None else {}

    srcs: Dict[int, np.ndarray] = {}
    exact = engine.scheduler.exact_sources or []
    for p, arr in enumerate(exact):
        if arr is not None:
            srcs[p] = np.asarray(arr, dtype=np.int64)
    sizes = {
        p: store.file_size(store.shard_name(p, "csr"))
        for p in range(meta.num_shards)
    }
    cache_shards = tuple(engine.cache.keys()) if engine.cache is not None else ()

    graph_version = service.graph_version
    sessions: List[SessionEntry] = []
    for key, qr in service.sessions.entries():
        # keys are (program_key_tuple, source, graph_version); only
        # current-version entries survive a restore anyway.
        if not (isinstance(key, tuple) and len(key) == 3):
            continue
        if key[2] != graph_version:
            continue
        sessions.append(
            SessionEntry(
                program=qr.program,
                key=tuple(key[0]),
                source=int(key[1]),
                values=np.asarray(qr.values),
                iterations=int(qr.iterations),
                converged=bool(qr.converged),
            )
        )
    return WarmState(
        store_version=store_version,
        graph_version=int(graph_version),
        num_vertices=int(meta.num_vertices),
        num_shards=int(meta.num_shards),
        intervals=np.asarray(meta.intervals, dtype=np.int64),
        floors=floors,
        bloom_sources=srcs,
        shard_sizes=sizes,
        cache_shards=cache_shards,
        sessions=sessions,
    )


# --------------------------------------------------------------- restore
def apply_warm_state(store, ws: WarmState) -> Dict:
    """Deposit the snapshot's still-valid warm sources into ``store``.

    Runs BEFORE the engine is constructed: every shard whose sources are
    deposited is skipped by ``ShardScheduler.build_filters`` — the whole
    point of the exercise.  Returns a report dict:

    ``valid``            whether the snapshot matched the store at all
    ``reason``           why not (when ``valid`` is False)
    ``shards_warm``      shards whose sources were deposited
    ``shards_stale``     shards skipped for publish evidence past the snapshot
    ``sessions_valid``   whether cached query results may be restored
    """
    report = {
        "valid": False,
        "reason": "",
        "shards_warm": 0,
        "shards_stale": 0,
        "sessions_valid": False,
    }
    meta = store.read_meta()
    if (
        int(meta.num_vertices) != ws.num_vertices
        or int(meta.num_shards) != ws.num_shards
        or not np.array_equal(
            np.asarray(meta.intervals, np.int64),
            np.asarray(ws.intervals, np.int64),
        )
    ):
        report["reason"] = "graph frame mismatch (re-ingested store?)"
        return report
    delta = store.delta
    cur_version = delta.version if delta is not None else 0
    if cur_version < ws.store_version:
        report["reason"] = (
            f"store version {cur_version} behind snapshot "
            f"{ws.store_version} (delta history wiped)"
        )
        return report
    if cur_version == 0 and ws.store_version == 0:
        # No delta history on either side: base byte sizes are the only
        # re-ingest evidence left.
        for p, size in ws.shard_sizes.items():
            if store.file_size(store.shard_name(int(p), "csr")) != size:
                report["reason"] = f"shard {p} container size changed"
                return report
    report["valid"] = True
    floors = delta.floors() if delta is not None else {}
    for p, arr in ws.bloom_sources.items():
        p = int(p)
        floor = floors.get(p, 0)
        last = delta.last_publish_seq(p) if delta is not None else 0
        if floor > ws.store_version or last > ws.store_version:
            report["shards_stale"] += 1  # published past the snapshot
            continue
        store.set_warm_sources(p, np.asarray(arr, dtype=np.int64))
        report["shards_warm"] += 1
    report["sessions_valid"] = cur_version == ws.store_version
    return report


def prewarm_cache(engine, ws: WarmState) -> int:
    """Eagerly re-populate the engine's byte cache with the snapshot's warm
    set (clean shards only — dirty shards' slots belong to the overlay's
    CSR path).  This READS those shards: it trades boot-time I/O for
    first-query cache hits, so it is opt-in.  Returns shards inserted."""
    if engine.cache is None:
        return 0
    delta = engine.store.delta
    n = 0
    for p in ws.cache_shards:
        p = int(p)
        if p < 0 or p >= engine.meta.num_shards:
            continue
        if delta is not None and delta.has_pending(p):
            continue
        raw = engine.store.shard_bytes(p, engine._fmt)
        if engine.cache.put(p, raw):
            n += 1
    return n


# --------------------------------------------------------------- on disk
class WarmStateCheckpointer:
    """Atomic, retained, integrity-checked WarmState snapshots on disk.

    Layout (per step)::

        <directory>/warm_00000003/
            state.npz       # every array: sources, intervals, values, ...
            MANIFEST.json   # scalars + session metadata + sha256(state.npz)

    Same commit protocol as :class:`repro.checkpoint.checkpointer.
    Checkpointer`: stage into ``warm_<step>.tmp/``, single ``os.replace``
    to commit, retention GC afterwards.  A crash mid-save leaves a
    ``.tmp`` dir that the next save of the same step overwrites and
    ``latest_step`` never selects.
    """

    def __init__(self, directory: str, *, keep: int = 2):
        if keep < 1:
            raise ValueError("keep must be >= 1")
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------- naming
    def _dir(self, step: int) -> str:
        return os.path.join(self.directory, f"{_PREFIX}{step:08d}")

    def steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith(_PREFIX) and not name.endswith(".tmp"):
                try:
                    out.append(int(name[len(_PREFIX):]))
                except ValueError:
                    continue
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.steps()
        return steps[-1] if steps else None

    # --------------------------------------------------------------- save
    def save(self, state: WarmState, *, step: Optional[int] = None) -> str:
        if step is None:
            latest = self.latest_step()
            step = 0 if latest is None else latest + 1
        arrays = {
            "intervals": np.asarray(state.intervals, np.int64),
            "floors": np.asarray(
                sorted((int(p), int(s)) for p, s in state.floors.items()),
                dtype=np.int64,
            ).reshape(-1, 2),
            "shard_sizes": np.asarray(
                sorted((int(p), int(s)) for p, s in state.shard_sizes.items()),
                dtype=np.int64,
            ).reshape(-1, 2),
            "cache_shards": np.asarray(state.cache_shards, dtype=np.int64),
        }
        for p, arr in state.bloom_sources.items():
            arrays[f"src_{int(p)}"] = np.asarray(arr, np.int64)
        for i, e in enumerate(state.sessions):
            arrays[f"sess_{i}"] = np.asarray(e.values)
        buf = io.BytesIO()
        np.savez_compressed(buf, **arrays)
        payload = buf.getvalue()
        manifest = {
            "format": _FORMAT,
            "step": int(step),
            "store_version": int(state.store_version),
            "graph_version": int(state.graph_version),
            "num_vertices": int(state.num_vertices),
            "num_shards": int(state.num_shards),
            "sessions": [
                {
                    "program": e.program,
                    "key": list(e.key),
                    "source": int(e.source),
                    "iterations": int(e.iterations),
                    "converged": bool(e.converged),
                }
                for e in state.sessions
            ],
            "sha256": hashlib.sha256(payload).hexdigest(),
        }
        final = self._dir(step)
        tmp = final + ".tmp"
        if os.path.isdir(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        with open(os.path.join(tmp, "state.npz"), "wb") as f:
            f.write(payload)
        with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        if os.path.isdir(final):
            shutil.rmtree(final)
        os.replace(tmp, final)  # the commit point
        self._gc()
        return final

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self._dir(s), ignore_errors=True)

    # ------------------------------------------------------------ restore
    def restore(self, step: Optional[int] = None) -> WarmState:
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(
                    f"no warm-state snapshot under {self.directory}"
                )
        d = self._dir(step)
        with open(os.path.join(d, "MANIFEST.json")) as f:
            man = json.load(f)
        with open(os.path.join(d, "state.npz"), "rb") as f:
            payload = f.read()
        digest = hashlib.sha256(payload).hexdigest()
        if digest != man["sha256"]:
            raise IOError(
                f"warm-state payload corrupt at step {step}: "
                f"sha256 {digest} != manifest {man['sha256']}"
            )
        z = np.load(io.BytesIO(payload))
        sessions = [
            SessionEntry(
                program=s["program"],
                key=tuple(s["key"]),
                source=int(s["source"]),
                values=z[f"sess_{i}"],
                iterations=int(s["iterations"]),
                converged=bool(s["converged"]),
            )
            for i, s in enumerate(man["sessions"])
        ]
        return WarmState(
            store_version=int(man["store_version"]),
            graph_version=int(man["graph_version"]),
            num_vertices=int(man["num_vertices"]),
            num_shards=int(man["num_shards"]),
            intervals=z["intervals"],
            floors={int(p): int(s) for p, s in z["floors"]},
            bloom_sources={
                int(k[len("src_"):]): z[k]
                for k in z.files
                if k.startswith("src_")
            },
            shard_sizes={int(p): int(s) for p, s in z["shard_sizes"]},
            cache_shards=tuple(int(p) for p in z["cache_shards"]),
            sessions=sessions,
        )
