"""Production serving launcher: continuous batched prefill + decode.

A miniature serving runtime around the same prefill/decode_step functions
the dry-run lowers at 32k/512k scale: a request queue, batched prefill,
KV caches with buffer donation, and per-request completion.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --smoke \
        --requests 8 --gen-len 24
"""

from __future__ import annotations

import argparse
import time
from typing import List

import numpy as np

import jax
import jax.numpy as jnp

from repro import configs
from repro.config import smoke_config
from repro.distributed.sharding import LOCAL_CTX
from repro.models import model as M


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b", choices=configs.list_archs())
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = smoke_config(configs.get_config(args.arch)) if args.smoke else \
        configs.get_config(args.arch)
    params = M.init_params(jax.random.key(args.seed), cfg, dtype=jnp.float32)
    rng = np.random.default_rng(args.seed)
    prefix = cfg.prefix_len if cfg.frontend == "vision_stub" else 0
    max_seq = args.prompt_len + args.gen_len + prefix

    prefill = jax.jit(lambda p, b: M.prefill(p, b, cfg, LOCAL_CTX))
    decode = jax.jit(
        lambda p, t, kv, i: M.decode_step(p, t, kv, i, cfg, LOCAL_CTX),
        donate_argnums=(2,),
    )

    # request queue -> fixed-size batches (continuous batching at fixed B)
    prompts = [
        rng.integers(0, cfg.vocab_size, args.prompt_len).astype(np.int32)
        for _ in range(args.requests)
    ]
    done: List[np.ndarray] = []
    t_start = time.perf_counter()
    tokens_out = 0
    while prompts:
        batch_prompts = [prompts.pop() for _ in range(min(args.batch, len(prompts)))]
        while len(batch_prompts) < args.batch:  # pad the batch
            batch_prompts.append(batch_prompts[-1])
        batch = {"tokens": jnp.asarray(np.stack(batch_prompts))}
        if cfg.frontend == "vision_stub":
            batch["patch_embeds"] = jnp.asarray(
                rng.standard_normal((args.batch, cfg.prefix_len, cfg.d_model)),
                jnp.float32)
        if cfg.encdec:
            batch["frames"] = jnp.asarray(
                rng.standard_normal((args.batch, cfg.encoder_seq, cfg.d_model)),
                jnp.float32)
        logits, caches = prefill(params, batch)
        caches = M.pad_caches(caches, cfg, max_seq=max_seq)
        toks = jnp.argmax(logits, axis=-1)[:, None]
        outs = [np.asarray(toks)]
        for step in range(args.gen_len - 1):
            logits, caches = decode(
                params, toks, caches,
                jnp.int32(args.prompt_len + prefix + step))
            toks = jnp.argmax(logits, axis=-1)[:, None]
            outs.append(np.asarray(toks))
        gen = np.concatenate(outs, axis=1)
        done.extend(gen[: len(batch_prompts)])
        tokens_out += gen.size
    dt = time.perf_counter() - t_start
    print(f"arch={cfg.name} served {len(done)} requests, "
          f"{tokens_out} tokens in {dt:.2f}s ({tokens_out/dt:.0f} tok/s)")
    print(f"sample: {done[0][:12].tolist()}")


if __name__ == "__main__":
    main()
