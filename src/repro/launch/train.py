"""Production training launcher.

Selects an architecture, builds the mesh + sharding context, and runs the
fault-tolerant training loop.  On the CPU dev host this runs reduced
configs end-to-end; on a real TPU slice the same entry point runs the full
config (the mesh is discovered from the runtime).

    PYTHONPATH=src python -m repro.launch.train --arch yi-6b --smoke \
        --steps 100 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse

import jax

from repro import configs
from repro.config import smoke_config
from repro.data.tokens import DataConfig
from repro.distributed.fault_tolerance import PreemptionGuard
from repro.distributed.sharding import (
    DEFAULT_RULES, SINGLE_POD_RULES, ShardingCtx,
)
from repro.optim import adamw
from repro.optim.compression import CompressionConfig
from repro.train.loop import LoopConfig, train


def build_ctx(args) -> ShardingCtx:
    n = len(jax.devices())
    if n == 1 or args.no_mesh:
        return ShardingCtx()
    if n >= 512:
        from repro.launch.mesh import make_production_mesh

        mesh = make_production_mesh(multi_pod=True)
        return ShardingCtx(mesh=mesh, rules=dict(DEFAULT_RULES))
    # small host meshes: (data, model) as square as possible
    d = 1
    while d * d <= n:
        d *= 2
    d //= 2
    mesh = jax.make_mesh((max(n // d, 1), d), ("data", "model"),
                         devices=jax.devices()[: (n // d) * d])
    return ShardingCtx(mesh=mesh, rules=dict(SINGLE_POD_RULES))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b", choices=configs.list_archs())
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU dev host)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--compress", default="none",
                    choices=["none", "topk", "int8"],
                    help="gradient compression for the cross-pod wire")
    ap.add_argument("--no-mesh", action="store_true")
    args = ap.parse_args()

    cfg = configs.get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    ctx = build_ctx(args)
    print(f"arch={cfg.name} params~{cfg.param_count/1e6:.1f}M "
          f"devices={len(jax.devices())}")

    data_cfg = DataConfig(seq_len=args.seq, global_batch=args.batch,
                          vocab_size=cfg.vocab_size)
    opt_cfg = adamw.AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 1),
                                total_steps=args.steps)
    comp = (CompressionConfig(kind=args.compress)
            if args.compress != "none" else None)

    with PreemptionGuard() as guard:
        result = train(
            cfg, data_cfg,
            LoopConfig(total_steps=args.steps,
                       checkpoint_every=args.checkpoint_every,
                       log_every=10, microbatches=args.microbatches),
            opt_cfg, ctx=ctx, checkpoint_dir=args.ckpt_dir,
            compression=comp, preemption=guard,
        )
    print(f"final: step={result.final_step} loss={result.losses[-1]:.4f} "
          f"resumed_from={result.resumed_from} preempted={result.preempted}")


if __name__ == "__main__":
    main()
