"""Production / host mesh construction.

FUNCTIONS (not module-level constants) so importing this module never
touches jax device state — required because ``dryrun.py`` must set
XLA_FLAGS before any jax initialisation.

Both constructors derive their device requirement from the requested shape
and raise the same :class:`RuntimeError` (``mesh_device_error``) when the
process has too few devices — callers (tests, the engine's ``mesh=`` boot
path) match on one message format instead of two drifting ones.
"""

from __future__ import annotations

import numpy as np


def mesh_device_error(shape, have: int) -> RuntimeError:
    """The uniform too-few-devices error: count derived from ``shape``."""
    need = int(np.prod(shape))
    return RuntimeError(
        f"mesh shape {tuple(shape)} needs {need} devices, have {have} — "
        f"run under XLA_FLAGS=--xla_force_host_platform_device_count={need} "
        "(set BEFORE jax initialises; dryrun.py does this automatically)"
    )


def _take_devices(shape):
    """The first ``prod(shape)`` devices, or raise the uniform error.

    Taking a prefix of ``jax.devices()`` when MORE devices exist is
    deliberate (a (2, 2) test mesh on an 8-device host); having FEWER is
    an error here rather than a confusing failure inside ``make_mesh``.
    """
    import jax

    need = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < need:
        raise mesh_device_error(shape, len(devices))
    return devices[:need]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 (one v5e pod, 256 chips) or 2x16x16 (two pods, 512 chips)."""
    import jax

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, devices=_take_devices(shape))


def make_host_mesh(shape=(1, 1), axes=("data", "model")):
    """Tiny mesh over host devices (tests, examples, the engine's
    ``mesh=int`` boot path).  Raises the uniform error instead of silently
    truncating to however many devices exist."""
    import jax

    return jax.make_mesh(shape, axes, devices=_take_devices(shape))
