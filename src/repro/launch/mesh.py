"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — required because ``dryrun.py`` must set
XLA_FLAGS before any jax initialisation.
"""

from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 (one v5e pod, 256 chips) or 2x16x16 (two pods, 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)} — run under "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 (dryrun.py "
            "sets this automatically)"
        )
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_host_mesh(shape=(1, 1), axes=("data", "model")):
    """Tiny mesh over whatever devices exist (tests, examples)."""
    n = int(np.prod(shape))
    return jax.make_mesh(shape, axes, devices=jax.devices()[:n])
