import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves, without hardware:
  - the sharding config is coherent (GSPMD partitions the step function),
  - it fits HBM (``compiled.memory_analysis()``),
  - and it yields the roofline inputs (``cost_analysis()`` + HLO collective
    parse, scan-corrected per EXPERIMENTS.md §Roofline methodology).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --mesh both \
      --out reports/dryrun.json
  PYTHONPATH=src python -m repro.launch.dryrun --arch graphmp   # the paper
"""

import argparse
import dataclasses
import json
import time
import traceback
from typing import Dict, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.config import ModelConfig, SHAPES, ShapeConfig
from repro.distributed.sharding import (
    DEFAULT_RULES, SINGLE_POD_RULES, ShardingCtx,
)
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.models import transformer as T
from repro.optim import adamw
from repro.roofline import analysis as RA
from repro.roofline import hw
from repro.train.step import make_train_step


def _cost_dict(cost) -> Dict:
    """Normalize ``Compiled.cost_analysis()`` across jax versions: older
    releases return ``[{...}]`` (one dict per computation), newer return
    the flat dict itself."""
    if cost is None:
        return {}
    if isinstance(cost, dict):
        return dict(cost)
    seq = list(cost)
    return dict(seq[0]) if seq else {}


# ----------------------------------------------------------------- sharding
def pick_rules(mesh, shape: ShapeConfig) -> Dict:
    rules = dict(DEFAULT_RULES if "pod" in mesh.axis_names else SINGLE_POD_RULES)
    # batch axes: greedy subset of (pod, data) that divides global_batch
    chosen = []
    rem = shape.global_batch
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            sz = dict(zip(mesh.axis_names, mesh.devices.shape))[a]
            if rem % sz == 0 and rem >= sz:
                chosen.append(a)
                rem //= sz
    rules["batch"] = tuple(chosen) if chosen else None
    if shape.mode == "decode":
        # Flash-decoding-style KV layout: shard the cache SEQUENCE over the
        # model axis (always divisible; kv-head counts often are not) —
        # attention reduces over the sharded axis via partial softmax.
        rules["kvseq"] = "model"
        rules["heads_kv"] = None
    if shape.name == "long_500k":
        # B=1: no data parallelism — spread the 512k cache over data too
        rules["kvseq"] = ("data", "model")
    return rules


def build_shardings(ctx: ShardingCtx, specs_tree, shapes_tree):
    """Logical specs -> NamedShardings, dropping axes that don't divide."""
    mesh = ctx.mesh
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def axis_size(ax) -> int:
        if ax is None:
            return 1
        if isinstance(ax, tuple):
            n = 1
            for a in ax:
                n *= sizes[a]
            return n
        return sizes[ax]

    def one(spec, shape_struct):
        dims = shape_struct.shape
        mesh_axes = []
        for i, logical in enumerate(spec):
            ax = ctx.rules.get(logical) if logical else None
            if ax is not None and dims[i] % axis_size(ax) != 0:
                ax = None  # non-divisible: replicate this dim (e.g. whisper vocab)
            mesh_axes.append(ax)
        return NamedSharding(mesh, P(*mesh_axes))

    return jax.tree_util.tree_map(
        one, specs_tree, shapes_tree,
        is_leaf=lambda x: isinstance(x, tuple),
    )


# -------------------------------------------------------------- input specs
def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    S = jax.ShapeDtypeStruct
    B = shape.global_batch
    if shape.mode == "train":
        batch = {
            "tokens": S((B, shape.seq_len), jnp.int32),
            "labels": S((B, shape.seq_len), jnp.int32),
        }
    elif shape.mode == "prefill":
        batch = {"tokens": S((B, shape.seq_len), jnp.int32)}
    else:  # decode: one new token against a seq_len cache
        batch = {"tokens": S((B, 1), jnp.int32)}
    if cfg.frontend == "vision_stub":
        batch["patch_embeds"] = S((B, cfg.prefix_len, cfg.d_model), jnp.float32)
    if cfg.frontend == "audio_stub":
        batch["frames"] = S((B, cfg.encoder_seq, cfg.d_model), jnp.float32)
    return batch


def batch_specs_logical(cfg: ModelConfig, batch) -> Dict:
    out = {}
    for k, v in batch.items():
        if k in ("tokens", "labels"):
            out[k] = ("batch", None)
        else:
            out[k] = ("batch", None, None)
    return out


# ------------------------------------------------------------- cell lowering
@dataclasses.dataclass
class CellResult:
    arch: str
    shape: str
    mesh: str
    ok: bool
    compile_s: float = 0.0
    error: str = ""
    memory: Optional[Dict] = None
    terms: Optional[Dict] = None
    model_flops: float = 0.0
    hlo_flops_ratio: float = 0.0


def _mem_dict(ma) -> Dict:
    return {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "peak_bytes_estimate": int(
            ma.argument_size_in_bytes + ma.temp_size_in_bytes
        ),
    }


def _zero_layer(cfg: ModelConfig) -> ModelConfig:
    kw = {"num_layers": 0}
    if cfg.encdec:
        kw["num_encoder_layers"] = 0
    return dataclasses.replace(cfg, **kw)


#: kv-block size for long-sequence prefill (memory-bounded attention path)
PREFILL_BLOCK_K = 4096
#: HBM budget for the auto-microbatch fit (leave headroom for XLA slack)
HBM_BUDGET = int(hw.HBM_BYTES * 0.95)


def _batch_shards(shape: ShapeConfig, mesh) -> int:
    n = 1
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    rem = shape.global_batch
    for a in ("pod", "data"):
        if a in sizes and rem % sizes[a] == 0 and rem >= sizes[a]:
            n *= sizes[a]
            rem //= sizes[a]
    return n


def _auto_microbatches(cfg: ModelConfig, shape: ShapeConfig, mesh) -> int:
    """Initial microbatch guess: residual-carry activations <= ~2 GiB.

    mb is capped at local batch size: beyond that each microbatch's batch
    dim no longer spans the batch mesh axes and sharding degrades.
    """
    b_loc = max(shape.global_batch // _batch_shards(shape, mesh), 1)
    carry = cfg.num_groups * b_loc * shape.seq_len * cfg.d_model * 2
    mb = 1
    while carry / mb > 2 * 2**30 and mb < b_loc:
        mb *= 2
    return mb


def lower_cell(
    cfg: ModelConfig, shape: ShapeConfig, mesh, *,
    with_outer_correction: bool = True,
    rules_override: Optional[Dict] = None,
    verbose: bool = True,
    microbatches: Optional[int] = None,  # None = auto-fit
    attn_block_k: Optional[int] = None,
    extra_rules: Optional[Dict] = None,  # perf-iteration rule overrides
    ctx_kwargs: Optional[Dict] = None,  # perf-iteration ShardingCtx flags
) -> Tuple[object, Dict]:
    """Lower + compile one cell.  Returns (compiled, info).

    Two-compile scheme: cost/collectives come from the microbatches=1
    build (same math, exact accounting); memory comes from the build you
    would actually run (auto-fitted microbatch count).
    """
    rules = rules_override or pick_rules(mesh, shape)
    if extra_rules:
        rules = {**rules, **extra_rules}
    if attn_block_k is None:
        attn_block_k = (
            PREFILL_BLOCK_K
            if shape.mode == "prefill" and shape.seq_len > 2 * PREFILL_BLOCK_K
            else 0
        )
    ctx = ShardingCtx(
        mesh=mesh, rules=rules, attn_impl="xla", attn_block_k=attn_block_k,
        **(ctx_kwargs or {}),
    )
    n_chips = int(np.prod(mesh.devices.shape))

    def compile_variant(c: ModelConfig, want_hlo: bool, mb: int = 1):
        params_sh = jax.eval_shape(
            lambda: M.init_params(jax.random.key(0), c, dtype=jnp.bfloat16)
        )
        p_shard = build_shardings(ctx, M.param_specs(c), params_sh)
        batch = input_specs(c, shape)
        b_shard = build_shardings(
            ctx, batch_specs_logical(c, batch), batch
        )

        if shape.mode == "train":
            opt_dtype = jnp.bfloat16 if c.param_count > 100e9 else jnp.float32
            opt_sh = jax.eval_shape(lambda: adamw.init(params_sh, opt_dtype))
            o_shard = adamw.AdamWState(
                step=NamedSharding(mesh, P()), m=p_shard, v=p_shard
            )
            step = make_train_step(
                c, ctx, adamw.AdamWConfig(), microbatches=mb
            )
            fn = jax.jit(
                step,
                in_shardings=(p_shard, o_shard, None, b_shard),
                out_shardings=(p_shard, o_shard, None, None),
                donate_argnums=(0, 1),  # params/opt updated in place
            )
            lowered = fn.lower(params_sh, opt_sh, None, batch)
        elif shape.mode == "prefill":
            fn = jax.jit(
                lambda p, b: M.prefill(p, b, c, ctx),
                in_shardings=(p_shard, b_shard),
            )
            lowered = fn.lower(params_sh, batch)
        else:  # decode
            max_seq = shape.seq_len + (
                c.prefix_len if c.frontend == "vision_stub" else 0
            )
            caches_sh = jax.eval_shape(
                lambda: M.init_decode_caches(c, shape.global_batch, max_seq)
            )
            cache_logical = {
                "stack": T.stacked_cache_specs(c),
                "memory": ("batch", None, None) if c.encdec else None,
            }
            c_shard = build_shardings(ctx, cache_logical, caches_sh)
            fn = jax.jit(
                lambda p, t, kv, i: M.decode_step(p, t, kv, i, c, ctx),
                in_shardings=(
                    p_shard, b_shard["tokens"], c_shard, None,
                ),
                out_shardings=(None, c_shard),
                donate_argnums=(2,),  # KV cache updated in place
            )
            lowered = fn.lower(
                params_sh, batch["tokens"], caches_sh,
                jax.ShapeDtypeStruct((), jnp.int32),
            )
        compiled = lowered.compile()
        hlo = compiled.as_text() if want_hlo else ""
        return compiled, hlo

    # ---- cost build (mb=1: exact accounting)
    t0 = time.time()
    compiled, hlo = compile_variant(cfg, want_hlo=True, mb=1)
    cost = _cost_dict(compiled.cost_analysis())

    # ---- memory build (the config you would run)
    if shape.mode == "train":
        mb = microbatches or _auto_microbatches(cfg, shape, mesh)
        mb_cap = max(shape.global_batch // _batch_shards(shape, mesh), 1)
        while True:
            mem_compiled, _ = (
                (compiled, "") if mb == 1
                else compile_variant(cfg, want_hlo=False, mb=mb)
            )
            mem = mem_compiled.memory_analysis()
            peak = mem.argument_size_in_bytes + mem.temp_size_in_bytes
            tpu_est = mem.argument_size_in_bytes + mem.temp_size_in_bytes // 2
            if tpu_est <= HBM_BUDGET or mb * 2 > mb_cap or microbatches:
                break
            mb *= 2
    else:
        mb = 1
        mem = compiled.memory_analysis()
        peak = mem.argument_size_in_bytes + mem.temp_size_in_bytes
    compile_s = time.time() - t0

    if verbose:
        print(f"    memory_analysis: {mem}")
        print(f"    cost_analysis: flops={cost.get('flops', 0):.4g} "
              f"bytes={cost.get('bytes accessed', 0):.4g}  (mb={mb})")

    # XLA CPU's FloatNormalization upcasts bf16 compute buffers to f32;
    # TPU keeps them bf16.  Arguments retain true dtypes, so a TPU peak
    # estimate halves the temp term (documented in EXPERIMENTS.md).
    peak_tpu_est = mem.argument_size_in_bytes + mem.temp_size_in_bytes // 2
    info: Dict = {
        "compile_s": compile_s,
        "memory": _mem_dict(mem),
        "peak_tpu_est": int(peak_tpu_est),
        "fits_hbm": bool(peak_tpu_est <= hw.HBM_BYTES),
        "microbatches": mb,
        "attn_block_k": attn_block_k,
        "n_chips": n_chips,
        "rules": {k: str(v) for k, v in rules.items()},
    }

    if with_outer_correction:
        outer_compiled, _ = compile_variant(_zero_layer(cfg), want_hlo=False)
        outer_cost = _cost_dict(outer_compiled.cost_analysis())
        trips = cfg.num_groups
        extra = None
        if cfg.encdec:
            mid_cfg = dataclasses.replace(cfg, num_encoder_layers=0)
            mid_compiled, _ = compile_variant(mid_cfg, want_hlo=False)
            # encoder scan trips differ from decoder trips
            extra = [(_cost_dict(mid_compiled.cost_analysis()), cfg.num_encoder_layers)]
        terms = RA.corrected_terms(
            cost, outer_cost, hlo, trips, n_chips,
            extra_scans=extra,
        )
        if attn_block_k:
            # blocked path hides attention flops inside the kv loop: add
            # the analytic total (documented in EXPERIMENTS.md methodology)
            af, ab = RA.attention_analytic(cfg, shape, shape.mode)
            terms = RA.RooflineTerms(
                flops_per_dev=terms.flops_per_dev + af / n_chips,
                bytes_per_dev=terms.bytes_per_dev + ab / n_chips,
                collective_bytes_per_dev=terms.collective_bytes_per_dev,
                n_chips=n_chips,
            )
        info["terms"] = terms.as_dict()
        mf = RA.model_flops(cfg, shape, shape.mode)
        info["model_flops_global"] = mf
        hlo_global = terms.flops_per_dev * n_chips
        info["model_vs_hlo_flops"] = mf / hlo_global if hlo_global else 0.0
    return compiled, info


# ------------------------------------------------------------------- graphmp
def lower_graphmp(mesh, workload: str = "eu-2015", verbose: bool = True) -> Dict:
    """Dry-run the paper's own engine at billion-vertex scale."""
    from repro.configs.graphmp import WORKLOADS
    from repro.core.distributed import device_graph_specs, make_superstep

    w = WORKLOADS[workload]
    n_dev = int(np.prod(mesh.devices.shape))
    rows_per_dev = -(-w.num_vertices // n_dev)
    specs = device_graph_specs(w.num_vertices, w.num_edges, n_dev)
    step, in_sh, _ = make_superstep(
        mesh, "pagerank", w.num_vertices, rows_per_dev
    )
    t0 = time.time()
    lowered = step.lower(
        specs["src_vals"], specs["ell_idx"], specs["ell_valid"],
        specs["seg"], specs["out_deg"],
    )
    compiled = lowered.compile()
    dt = time.time() - t0
    mem = compiled.memory_analysis()
    cost = _cost_dict(compiled.cost_analysis())
    col = RA.parse_collectives(compiled.as_text(), loop_trips=1)
    terms = RA.RooflineTerms(
        flops_per_dev=float(cost.get("flops", 0.0) or 0.0),
        bytes_per_dev=float(cost.get("bytes accessed", 0.0) or 0.0),
        collective_bytes_per_dev=float(col.total_bytes),
        n_chips=n_dev,
    )
    if verbose:
        print(f"    memory_analysis: {mem}")
        print(f"    cost_analysis: flops={terms.flops_per_dev:.4g}")
        print(f"    collective bytes/dev: {terms.collective_bytes_per_dev:.4g}")
    return {
        "compile_s": dt,
        "memory": _mem_dict(mem),
        "terms": terms.as_dict(),
        "n_chips": n_dev,
        "workload": workload,
    }


# ----------------------------------------------------------------------- CLI
def run(arch: str, shape_names, mesh_kinds, out: Optional[str] = None,
        fail_fast: bool = False) -> list:
    results = []
    arch_list = configs.list_archs() if arch == "all" else [arch]

    for mesh_kind in mesh_kinds:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
        print(f"=== mesh {mesh_kind}: {dict(zip(mesh.axis_names, mesh.devices.shape))} ===")
        for a in arch_list:
            if a == "graphmp":
                continue
            cfg = configs.get_config(a)
            shapes = shape_names or configs.applicable_shapes(a)
            for sname in shapes:
                if sname not in configs.applicable_shapes(a):
                    print(f"  {a} x {sname}: SKIPPED (inapplicable, DESIGN.md §4)")
                    continue
                shape = SHAPES[sname]
                print(f"  {a} x {sname} [{shape.mode}] ...", flush=True)
                try:
                    _, info = lower_cell(cfg, shape, mesh)
                    results.append(dataclasses.asdict(CellResult(
                        arch=a, shape=sname, mesh=mesh_kind, ok=True,
                        compile_s=info["compile_s"], memory=info["memory"],
                        terms=info.get("terms"),
                        model_flops=info.get("model_flops_global", 0.0),
                        hlo_flops_ratio=info.get("model_vs_hlo_flops", 0.0),
                    )))
                    print(f"    OK compile={info['compile_s']:.1f}s "
                          f"peak_mem/dev={info['memory']['peak_bytes_estimate']/2**30:.2f}GiB")
                except Exception as e:
                    traceback.print_exc()
                    results.append(dataclasses.asdict(CellResult(
                        arch=a, shape=sname, mesh=mesh_kind, ok=False,
                        error=f"{type(e).__name__}: {e}"[:500],
                    )))
                    if fail_fast:
                        raise
        if arch in ("all", "graphmp"):
            print(f"  graphmp x eu-2015 [superstep] ...", flush=True)
            try:
                info = lower_graphmp(mesh)
                results.append(dataclasses.asdict(CellResult(
                    arch="graphmp", shape="eu-2015", mesh=mesh_kind, ok=True,
                    compile_s=info["compile_s"], memory=info["memory"],
                    terms=info["terms"],
                )))
            except Exception as e:
                traceback.print_exc()
                results.append(dataclasses.asdict(CellResult(
                    arch="graphmp", shape="eu-2015", mesh=mesh_kind,
                    ok=False, error=str(e)[:500],
                )))
                if fail_fast:
                    raise

    n_ok = sum(r["ok"] for r in results)
    print(f"\n==== dry-run: {n_ok}/{len(results)} cells compiled ====")
    if out:
        os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
        with open(out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {out}")
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default=None,
                    help="comma-separated shape names (default: all applicable)")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default=None)
    ap.add_argument("--fail-fast", action="store_true")
    args = ap.parse_args()
    shapes = args.shape.split(",") if args.shape else None
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    results = run(args.arch, shapes, meshes, out=args.out,
                  fail_fast=args.fail_fast)
    if not all(r["ok"] for r in results):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
