"""State-space / recurrent mixers: SSD (Mamba-2 style) and xLSTM blocks.

Hardware adaptation (DESIGN.md §2, §7): Jamba ships a Mamba-1 selective
scan (CUDA kernel, per-channel A, sequential in time).  The TPU-native
formulation is the **chunked SSD form** (Dao & Gu 2024): within a chunk the
recurrence is evaluated as causal-masked matmuls (MXU work, fully visible
to cost analysis); across chunks a tiny associative scan carries the
[N, P] state.  Same asymptotic class, matmul-dominated — this is what a
production TPU Mamba runs, so we implement SSD and note the substitution.

xLSTM's mLSTM is the same algebra (matrix memory + scalar gates), so it
reuses the chunked core with sigmoid forget/input gates and a normalizer
row obtained by appending a ones-column to V.  sLSTM is a genuinely
sequential scalar recurrence; it is implemented as a time-step scan (its
FLOPs are elementwise and negligible next to the matmul blocks; noted for
roofline accounting).

One shared primitive:

    y_t = q_t . h_t        h_t = a_t * h_{t-1} + s_t * (k_t v_t^T)

with per-head scalar decay ``a_t`` and input scale ``s_t``.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.distributed.sharding import ShardingCtx

from . import common as C


# ----------------------------------------------------------- chunked core
def chunked_linear_rnn(
    q: jax.Array,  # [B, S, H, N]
    k: jax.Array,  # [B, S, H, N]
    v: jax.Array,  # [B, S, H, P]
    log_decay: jax.Array,  # [B, S, H]  (log a_t, <= 0)
    in_scale: jax.Array,  # [B, S, H]  (s_t)
    chunk: int,
    h0: Optional[jax.Array] = None,  # [B, H, N, P]
    ac=None,  # sharding-constraint callback: ac(x, *logical_axes)
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y [B,S,H,P], h_final [B,H,N,P])."""
    if ac is None:
        ac = lambda x, *axes: x
    B, S, H, N = q.shape
    P = v.shape[-1]
    if S % chunk:
        # Pad to a chunk multiple with inert steps: decay=1 (log 0) and
        # in_scale=0 leave the state untouched; padded outputs are dropped.
        pad = chunk - S % chunk
        padf = lambda a, val=0.0: jnp.pad(
            a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2),
            constant_values=val,
        )
        y, h = chunked_linear_rnn(
            padf(q), padf(k), padf(v), padf(log_decay), padf(in_scale),
            chunk, h0, ac,
        )
        return y[:, :S], h
    nc, Q = S // chunk, chunk
    f32 = jnp.float32

    # All big intermediates carry an explicit heads->TP constraint: without
    # it GSPMD can leave the [B,nc,H,Q,Q] / [B,nc,H,N,P] tensors replicated
    # (measured: 23.5 GiB/dev forward on jamba train_4k, 1.5 GiB with).
    qc = ac(q.reshape(B, nc, Q, H, N).astype(f32), "batch", None, None, "heads", None)
    kc = ac(k.reshape(B, nc, Q, H, N).astype(f32), "batch", None, None, "heads", None)
    vc = ac(v.reshape(B, nc, Q, H, P).astype(f32), "batch", None, None, "heads", None)
    ld = log_decay.reshape(B, nc, Q, H).astype(f32)
    sc = in_scale.reshape(B, nc, Q, H).astype(f32)

    L = jnp.cumsum(ld, axis=2)  # [B,nc,Q,H] inclusive within-chunk log decay

    # ---- intra-chunk: causal masked matmuls (the MXU-dominant part)
    smat = jnp.einsum("bcqhn,bcjhn->bchqj", qc, kc)  # [B,nc,H,Q,Q]
    smat = ac(smat, "batch", None, "heads", None, None)
    dl = L[:, :, :, None, :] - L[:, :, None, :, :]  # [B,nc,Q(i),Q(j),H]
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    gamma = jnp.where(causal[None, None, :, :, None], jnp.exp(dl), 0.0)
    gamma = ac(gamma, "batch", None, None, None, "heads")
    w = (
        smat
        * gamma.transpose(0, 1, 4, 2, 3)  # [B,nc,H,Q,Q]
        * sc.transpose(0, 1, 3, 2)[:, :, :, None, :]  # s_j on the j axis
    )
    y_intra = jnp.einsum("bchqj,bcjhp->bcqhp", w, vc)

    # ---- per-chunk input state + decay to the chunk end
    to_end = jnp.exp(L[:, :, -1:, :] - L)  # [B,nc,Q,H]
    u = jnp.einsum("bcjhn,bcjhp->bchnp", kc * (sc * to_end)[..., None], vc)
    u = ac(u, "batch", None, "heads", None, None)
    alpha = jnp.exp(L[:, :, -1, :])  # [B,nc,H]

    # ---- inter-chunk associative scan (state carry, small)
    def comb(x, y):
        a1, u1 = x
        a2, u2 = y
        return a2 * a1, a2[..., None, None] * u1 + u2

    a_in, u_in = alpha, u
    if h0 is not None:
        u_in = u_in.at[:, 0].add(alpha[:, 0, :, None, None] * h0.astype(f32))
    a_sc, h_after = jax.lax.associative_scan(comb, (a_in, u_in), axis=1)
    h_after = ac(h_after, "batch", None, "heads", None, None)
    h_start = jnp.concatenate(
        [jnp.zeros_like(h_after[:, :1]), h_after[:, :-1]], axis=1
    )
    if h0 is not None:
        h_start = h_start.at[:, 0].set(h0.astype(f32))

    y_inter = jnp.einsum(
        "bcqhn,bchnp->bcqhp", qc * jnp.exp(L)[..., None], h_start
    )
    y = (y_intra + y_inter).reshape(B, S, H, P)
    return y.astype(v.dtype), h_after[:, -1].astype(f32)


def linear_rnn_step(
    q, k, v, log_decay, in_scale, h,  # q/k [B,H,N], v [B,H,P], scalars [B,H]
):
    """Single decode step of the same recurrence."""
    f32 = jnp.float32
    a = jnp.exp(log_decay.astype(f32))[..., None, None]
    h = a * h + (in_scale.astype(f32))[..., None, None] * jnp.einsum(
        "bhn,bhp->bhnp", k.astype(f32), v.astype(f32)
    )
    y = jnp.einsum("bhn,bhnp->bhp", q.astype(f32), h)
    return y.astype(v.dtype), h


# ------------------------------------------------------------- SSD block
def ssd_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 8)
    d, di, N = cfg.d_model, cfg.d_inner, cfg.ssm_state
    H = di // cfg.ssm_head_dim
    return {
        "in_proj": C.linear_init(ks[0], d, 2 * di),  # -> (x, z gate)
        "conv_w": C.he_init(ks[1], (4, di), 4),  # causal depthwise conv
        "bc_proj": C.linear_init(ks[2], d, 2 * N),  # shared B, C (1 group)
        "dt_proj": C.linear_init(ks[3], d, H),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "a_log": jnp.zeros((H,), jnp.float32),  # A = -exp(a_log)
        "d_skip": jnp.ones((H,), jnp.float32),
        "out_proj": C.linear_init(ks[4], di, d),
    }


def ssd_specs(cfg: ModelConfig):
    return {
        "in_proj": C.linear_specs("embed", "inner"),
        "conv_w": (None, "inner"),
        "bc_proj": C.linear_specs("embed", None),
        "dt_proj": C.linear_specs("embed", None),
        "dt_bias": (None,),
        "a_log": (None,),
        "d_skip": (None,),
        "out_proj": C.linear_specs("inner", "embed"),
    }


def _causal_conv(x: jax.Array, w: jax.Array, state: Optional[jax.Array] = None):
    """Depthwise causal conv, kernel 4.  x: [B,S,di]; state: [B,3,di]."""
    if state is None:
        pad = jnp.zeros((x.shape[0], w.shape[0] - 1, x.shape[-1]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(
        xp[:, i : i + x.shape[1]] * w[i].astype(x.dtype)
        for i in range(w.shape[0])
    )
    new_state = xp[:, -(w.shape[0] - 1) :]
    return out, new_state


def ssd_block(
    params,
    x: jax.Array,  # [B,S,d]
    cfg: ModelConfig,
    ctx: ShardingCtx,
    state: Optional[dict] = None,  # decode: {"h": [B,H,N,P], "conv": [B,3,di]}
):
    B, S, d = x.shape
    di, N, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_head_dim
    H = di // P
    xz = C.linear(params["in_proj"], x)  # [B,S,2di]
    xz = ctx.ac(xz, "batch", None, "inner")
    xin, z = jnp.split(xz, 2, axis=-1)
    conv_state = state["conv"] if state is not None else None
    xin, new_conv = _causal_conv(xin, params["conv_w"], conv_state)
    xin = jax.nn.silu(xin)

    bc = C.linear(params["bc_proj"], x).astype(jnp.float32)  # [B,S,2N]
    b_t, c_t = jnp.split(bc, 2, axis=-1)
    dt = jax.nn.softplus(
        C.linear(params["dt_proj"], x).astype(jnp.float32) + params["dt_bias"]
    )  # [B,S,H]
    a = -jnp.exp(params["a_log"])  # [H]
    log_decay = dt * a  # [B,S,H]

    xh = xin.reshape(B, S, H, P)
    v = xh * dt[..., None].astype(xh.dtype)  # fold dt into input
    qN = jnp.broadcast_to(c_t[:, :, None, :], (B, S, H, N))
    kN = jnp.broadcast_to(b_t[:, :, None, :], (B, S, H, N))

    if state is None:
        y, h_final = chunked_linear_rnn(
            qN, kN, v, log_decay, jnp.ones_like(log_decay), cfg.ssm_chunk,
            ac=ctx.ac,
        )
        new_state = {"h": h_final, "conv": new_conv}
    else:
        yv, h = linear_rnn_step(
            qN[:, 0], kN[:, 0], v[:, 0], log_decay[:, 0],
            jnp.ones_like(log_decay[:, 0]), state["h"],
        )
        y = yv[:, None]
        new_state = {"h": h, "conv": new_conv}

    y = y + xh * params["d_skip"][None, None, :, None].astype(y.dtype)
    y = y.reshape(B, S, di) * jax.nn.silu(z)
    out = C.linear(params["out_proj"], y)
    return out, new_state


def ssd_state_init(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    di, N, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_head_dim
    H = di // P
    return {
        "h": jnp.zeros((batch, H, N, P), jnp.float32),
        "conv": jnp.zeros((batch, 3, di), dtype),
    }


# ------------------------------------------------------------ mLSTM block
def mlstm_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 8)
    d, di = cfg.d_model, cfg.d_inner
    H = cfg.num_heads
    P = di // H
    return {
        "in_proj": C.linear_init(ks[0], d, 2 * di),  # -> (x, z gate)
        "conv_w": C.he_init(ks[1], (4, di), 4),
        "wq": C.linear_init(ks[2], di, di),
        "wk": C.linear_init(ks[3], di, di),
        "wv": C.linear_init(ks[4], di, di),
        "w_if": C.linear_init(ks[5], di, 2 * H, bias=True),  # input/forget gates
        "gn_scale": jnp.ones((di,), jnp.float32),
        "out_proj": C.linear_init(ks[6], di, d),
    }


def mlstm_specs(cfg: ModelConfig):
    return {
        "in_proj": C.linear_specs("embed", "inner"),
        "conv_w": (None, "inner"),
        # [di, di] square projections: shard the OUTPUT dim only (mapping
        # both dims to the TP axis would be a duplicate-axis spec)
        "wq": C.linear_specs(None, "inner"),
        "wk": C.linear_specs(None, "inner"),
        "wv": C.linear_specs(None, "inner"),
        "w_if": C.linear_specs("inner", None, bias=True),
        "gn_scale": ("inner",),
        "out_proj": C.linear_specs("inner", "embed"),
    }


def _headwise_rms(x: jax.Array, scale: jax.Array, H: int) -> jax.Array:
    """Group norm over each head's channels (xLSTM uses GN post-cell)."""
    B, S, di = x.shape
    xh = x.reshape(B, S, H, di // H).astype(jnp.float32)
    var = jnp.mean(xh * xh, axis=-1, keepdims=True)
    xh = xh * jax.lax.rsqrt(var + 1e-6)
    return (xh.reshape(B, S, di) * scale).astype(x.dtype)


def mlstm_block(
    params, x: jax.Array, cfg: ModelConfig, ctx: ShardingCtx,
    state: Optional[dict] = None,
):
    B, S, d = x.shape
    di = cfg.d_inner
    H = cfg.num_heads
    P = di // H
    xz = C.linear(params["in_proj"], x)
    xz = ctx.ac(xz, "batch", None, "inner")
    xin, z = jnp.split(xz, 2, axis=-1)
    conv_state = state["conv"] if state is not None else None
    xc, new_conv = _causal_conv(xin, params["conv_w"], conv_state)
    xc = jax.nn.silu(xc)

    q = C.linear(params["wq"], xc).reshape(B, S, H, P) * (P ** -0.5)
    k = C.linear(params["wk"], xc).reshape(B, S, H, P)
    v = C.linear(params["wv"], xin).reshape(B, S, H, P)
    gates = C.linear(params["w_if"], xc).astype(jnp.float32)  # [B,S,2H]
    i_g = jax.nn.sigmoid(gates[..., :H])
    f_g = jax.nn.sigmoid(gates[..., H:] + 3.0)  # forget bias -> long memory
    log_decay = jnp.log(f_g + 1e-9)

    # normalizer: append a ones column to v -> last channel accumulates i*k.q
    v_ext = jnp.concatenate([v, jnp.ones_like(v[..., :1])], axis=-1)
    if state is None:
        y_ext, h_final = chunked_linear_rnn(
            q, k, v_ext, log_decay, i_g, cfg.ssm_chunk, ac=ctx.ac,
        )
        new_state = {"h": h_final, "conv": new_conv}
    else:
        y1, h = linear_rnn_step(
            q[:, 0], k[:, 0], v_ext[:, 0], log_decay[:, 0], i_g[:, 0],
            state["h"],
        )
        y_ext = y1[:, None]
        new_state = {"h": h, "conv": new_conv}
    num, den = y_ext[..., :P], y_ext[..., P:]
    y = num / jnp.maximum(jnp.abs(den), 1.0)
    y = _headwise_rms(y.reshape(B, S, di), params["gn_scale"], H)
    y = y * jax.nn.silu(z)
    return C.linear(params["out_proj"], y), new_state


def mlstm_state_init(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    di = cfg.d_inner
    H = cfg.num_heads
    P = di // H
    return {
        "h": jnp.zeros((batch, H, P, P + 1), jnp.float32),
        "conv": jnp.zeros((batch, 3, di), dtype),
    }


# ------------------------------------------------------------ sLSTM block
def slstm_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    d, di = cfg.d_model, cfg.d_inner
    H = cfg.num_heads
    P = di // H
    return {
        "in_proj": C.linear_init(ks[0], d, di),
        "w_gates": C.linear_init(ks[1], di, 4 * di, bias=True),
        "r_gates": C.he_init(ks[2], (H, P, 4 * P), P),  # block-diag recurrent
        "out_proj": C.linear_init(ks[3], di, d),
    }


def slstm_specs(cfg: ModelConfig):
    return {
        "in_proj": C.linear_specs("embed", "inner"),
        # square gate projection: shard output dim only (see mlstm_specs)
        "w_gates": C.linear_specs(None, "inner", bias=True),
        "r_gates": (None, None, None),
        "out_proj": C.linear_specs("inner", "embed"),
    }


def slstm_block(
    params, x: jax.Array, cfg: ModelConfig, ctx: ShardingCtx,
    state: Optional[dict] = None,
):
    """Sequential scalar LSTM with per-head recurrence (scan over time).

    FLOPs here are O(S * di * 4P) — small next to the matmul blocks — and
    the scan body is counted once by cost analysis; noted in EXPERIMENTS.md
    §Roofline methodology.
    """
    B, S, d = x.shape
    di = cfg.d_inner
    H = cfg.num_heads
    P = di // H
    xin = C.linear(params["in_proj"], x)
    gates_x = C.linear(params["w_gates"], xin).astype(jnp.float32)  # [B,S,4di]

    def step(carry, gx):
        h, c = carry  # [B,H,P] each
        rec = jnp.einsum("bhp,hpq->bhq", h, params["r_gates"])  # [B,H,4P]
        g = gx.reshape(B, H, 4 * P) + rec
        i_g, f_g, z_g, o_g = jnp.split(g, 4, axis=-1)
        c = jax.nn.sigmoid(f_g + 1.0) * c + jax.nn.sigmoid(i_g) * jnp.tanh(z_g)
        h = jax.nn.sigmoid(o_g) * jnp.tanh(c)
        return (h, c), h

    if state is None:
        h0 = jnp.zeros((B, H, P), jnp.float32)
        c0 = jnp.zeros((B, H, P), jnp.float32)
    else:
        h0, c0 = state["h"], state["c"]
    (hT, cT), ys = jax.lax.scan(step, (h0, c0), gates_x.transpose(1, 0, 2))
    y = ys.transpose(1, 0, 2, 3).reshape(B, S, di).astype(x.dtype)
    new_state = {"h": hT, "c": cT}
    return C.linear(params["out_proj"], y), new_state


def slstm_state_init(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    di = cfg.d_inner
    H = cfg.num_heads
    P = di // H
    return {
        "h": jnp.zeros((batch, H, P), jnp.float32),
        "c": jnp.zeros((batch, H, P), jnp.float32),
    }
