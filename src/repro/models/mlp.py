"""Feed-forward blocks: SwiGLU (llama/qwen/yi), GeGLU (gemma), GELU (whisper)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig

from . import common as C


def mlp_init(key, d: int, ff: int, mlp_type: str):
    ks = jax.random.split(key, 3)
    if mlp_type in ("swiglu", "geglu"):
        return {
            "wg": C.linear_init(ks[0], d, ff),
            "wu": C.linear_init(ks[1], d, ff),
            "wd": C.linear_init(ks[2], ff, d),
        }
    return {  # plain gelu
        "wu": C.linear_init(ks[0], d, ff, bias=True),
        "wd": C.linear_init(ks[1], ff, d, bias=True),
    }


def mlp_specs(mlp_type: str):
    if mlp_type in ("swiglu", "geglu"):
        return {
            "wg": C.linear_specs("embed", "mlp"),
            "wu": C.linear_specs("embed", "mlp"),
            "wd": C.linear_specs("mlp", "embed"),
        }
    return {
        "wu": C.linear_specs("embed", "mlp", bias=True),
        "wd": C.linear_specs("mlp", "embed", bias=True),
    }


def mlp(params, x: jax.Array, mlp_type: str) -> jax.Array:
    if mlp_type == "swiglu":
        return C.linear(
            params["wd"],
            jax.nn.silu(C.linear(params["wg"], x)) * C.linear(params["wu"], x),
        )
    if mlp_type == "geglu":
        return C.linear(
            params["wd"],
            jax.nn.gelu(C.linear(params["wg"], x), approximate=True)
            * C.linear(params["wu"], x),
        )
    return C.linear(params["wd"], jax.nn.gelu(C.linear(params["wu"], x)))
