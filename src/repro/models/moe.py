"""Mixture-of-Experts FFN with sort-based capacity dispatch.

TPU-native design choices (DESIGN.md §2/§4):

- Dispatch is computed **per batch row** (vmap over B): the argsort /
  rank-in-expert math stays local to a device under GSPMD because the batch
  dim is sharded and the sorted dim (S*k) is not — no accidental global
  sorts.
- The dispatched buffer ``[B, E, C, d]`` carries an explicit sharding
  constraint putting E on the TP/EP mesh axis; GSPMD materialises the
  token->expert exchange as all-to-all style collectives — the expert-
  parallel boundary.
- Capacity follows GShard: ``C = ceil(S * top_k / E * capacity_factor)``;
  overflow tokens are dropped (their combine weight is zero), underflow
  slots compute on zeros.  This is the *selective-scheduling analogue* for
  MoE noted in DESIGN.md: experts whose capacity slots are empty do only
  padded work, and the router histogram plays the role of the paper's
  Bloom-filter activity bits.

Returns the load-balancing auxiliary loss alongside outputs.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.distributed.sharding import ShardingCtx

from . import common as C


def moe_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    def eh(k, shape, fan_in):
        return C.he_init(k, shape, fan_in)

    p = {
        "router": {"w": eh(ks[0], (d, E), d)},
        "wg": eh(ks[1], (E, d, ff), d),
        "wu": eh(ks[2], (E, d, ff), d),
        "wd": eh(ks[3], (E, ff, d), ff),
    }
    if cfg.mlp_type == "gelu":
        p.pop("wg")
    return p


def moe_specs(cfg: ModelConfig):
    p = {
        "router": {"w": ("embed", None)},
        "wg": ("expert", "embed_expert", "mlp_expert"),
        "wu": ("expert", "embed_expert", "mlp_expert"),
        "wd": ("expert", "mlp_expert", "embed_expert"),
    }
    if cfg.mlp_type == "gelu":
        p.pop("wg")
    return p


def _capacity(seq: int, cfg: ModelConfig) -> int:
    c = int(seq * cfg.top_k / cfg.num_experts * cfg.capacity_factor)
    return max(8, -(-c // 8) * 8)  # >=8 and sublane-aligned


def _dispatch_row(xr: jax.Array, router_w: jax.Array, cfg: ModelConfig, cap: int):
    """One batch row: route, sort by expert, rank within capacity."""
    S, d = xr.shape
    E, k = cfg.num_experts, cfg.top_k
    logits = (xr.astype(jnp.float32) @ router_w.astype(jnp.float32))  # [S, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, k)  # [S, k]
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    flat_e = eidx.reshape(-1)  # [S*k]
    order = jnp.argsort(flat_e, stable=True)
    se = flat_e[order]
    tok = order // k
    starts = jnp.searchsorted(se, jnp.arange(E), side="left")
    rank = jnp.arange(S * k) - starts[se]
    keep = rank < cap
    slot = jnp.where(keep, se * cap + rank, E * cap)  # E*cap = drop bin

    # load-balance aux (Switch): E * sum_e f_e * P_e
    f = jnp.zeros((E,), jnp.float32).at[flat_e].add(1.0) / (S * k)
    P_mean = probs.mean(axis=0)
    aux = E * jnp.sum(f * P_mean)
    gate_sorted = gates.reshape(-1)[order]
    return slot, tok, keep, gate_sorted, aux


def moe_ffn(
    params,
    x: jax.Array,  # [B, S, d]
    cfg: ModelConfig,
    ctx: ShardingCtx,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y [B,S,d], aux_loss scalar)."""
    B, S, d = x.shape
    E, cap = cfg.num_experts, _capacity(S, cfg)

    slot, tok, keep, gate_sorted, aux = jax.vmap(
        lambda xr: _dispatch_row(xr, params["router"]["w"], cfg, cap)
    )(x)

    def scatter_row(xr, sl, tk):
        buf = jnp.zeros((E * cap, d), x.dtype)
        return buf.at[sl].set(xr[tk], mode="drop")

    buf = jax.vmap(scatter_row)(x, slot, tok).reshape(B, E, cap, d)
    # ---- expert-parallel boundary: E onto the TP axis (all-to-all in HLO)
    buf = ctx.ac(buf, "batch", "expert", None, None)

    wd = params["wd"].astype(x.dtype)
    if cfg.mlp_type == "gelu":
        h = jnp.einsum("becd,edf->becf", buf, params["wu"].astype(x.dtype))
        h = jax.nn.gelu(h)
    else:
        g = jnp.einsum("becd,edf->becf", buf, params["wg"].astype(x.dtype))
        u = jnp.einsum("becd,edf->becf", buf, params["wu"].astype(x.dtype))
        act = jax.nn.silu(g) if cfg.mlp_type == "swiglu" else jax.nn.gelu(g, approximate=True)
        h = act * u
    out = jnp.einsum("becf,efd->becd", h, wd)  # [B, E, cap, d]
    out = ctx.ac(out, "batch", "expert", None, None)
    out_flat = out.reshape(B, E * cap, d)

    def combine_row(of, sl, tk, kp, gs):
        contrib = of[jnp.minimum(sl, E * cap - 1)]  # [S*k, d]
        w = (gs * kp).astype(x.dtype)[:, None]
        return jnp.zeros((S, d), x.dtype).at[tk].add(contrib * w)

    y = jax.vmap(combine_row)(out_flat, slot, tok, keep, gate_sorted)
    return y, aux.mean()
