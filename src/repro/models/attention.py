"""GQA attention block: projections, RoPE, KV cache, cross-attention.

The score/softmax/value computation is delegated to
``repro.kernels.flash_attention.ops.attention`` (impl selectable: "xla" for
dry-run/CPU, "pallas" on TPU).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.kernels.flash_attention.ops import attention

from . import common as C


def attn_init(key, cfg: ModelConfig, *, cross: bool = False):
    ks = jax.random.split(key, 4)
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    return {
        "wq": C.linear_init(ks[0], d, qd, bias=cfg.qkv_bias),
        "wk": C.linear_init(ks[1], d, kvd, bias=cfg.qkv_bias),
        "wv": C.linear_init(ks[2], d, kvd, bias=cfg.qkv_bias),
        "wo": C.linear_init(ks[3], qd, d),
    }


def attn_specs(cfg: ModelConfig):
    return {
        "wq": C.linear_specs("embed", "qkv", bias=cfg.qkv_bias),
        "wk": C.linear_specs("embed", "qkv", bias=cfg.qkv_bias),
        "wv": C.linear_specs("embed", "qkv", bias=cfg.qkv_bias),
        "wo": C.linear_specs("qkv", "embed"),
    }


def _split_heads(x: jax.Array, n: int, hd: int) -> jax.Array:
    b, s, _ = x.shape
    return x.reshape(b, s, n, hd)


def blocked_attention(
    q: jax.Array,  # [B, Sq, H, hd]
    k: jax.Array,  # [B, Skv, Hkv, hd]
    v: jax.Array,  # [B, Skv, Hkv, hd]
    *,
    block_k: int,
    causal: bool = True,
) -> jax.Array:
    """Flash-style online-softmax attention, kv-blocked via lax.scan.

    Memory is O(Sq * block_k) instead of O(Sq * Skv); the scan body is
    rematerialised in backward, so training memory stays bounded too.
    NOTE for cost accounting: the kv loop hides (nk-1)/nk of the attention
    FLOPs from cost_analysis; the roofline pipeline adds them back
    analytically (roofline.analysis.attention_analytic).
    """
    B, Sq, H, hd = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    group = H // Hkv
    nk = -(-Skv // block_k)
    pad = nk * block_k - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    scale = hd ** -0.5
    qf = (q.astype(jnp.float32) * scale).reshape(B, Sq, Hkv, group, hd)
    ks = k.reshape(B, nk, block_k, Hkv, hd).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, nk, block_k, Hkv, hd).transpose(1, 0, 2, 3, 4)
    qpos = (Skv - Sq) + jnp.arange(Sq)  # suffix-aligned (decode convention)

    def body(carry, xs):
        m, l, acc = carry
        idx, kb, vb = xs
        s = jnp.einsum(
            "bqngd,bknd->bqngk", qf, kb.astype(jnp.float32)
        )  # [B,Sq,Hkv,group,bk]
        kpos = idx * block_k + jnp.arange(block_k)
        valid = kpos[None, :] < Skv
        if causal:
            valid = valid & (qpos[:, None] >= kpos[None, :])
        s = jnp.where(valid[None, :, None, None, :], s, -jnp.inf)
        m_new = jnp.maximum(m, s.max(axis=-1))
        # guard fully-masked rows (m_new = -inf): exp(-inf - -inf) -> nan
        m_safe = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(valid[None, :, None, None, :], p, 0.0)
        alpha = jnp.where(jnp.isneginf(m), 0.0, jnp.exp(m - m_safe))
        l = l * alpha + p.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bqngk,bknd->bqngd", p, vb.astype(jnp.float32)
        )
        return (m_new, l, acc), None

    m0 = jnp.full((B, Sq, Hkv, group), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, Sq, Hkv, group), jnp.float32)
    a0 = jnp.zeros((B, Sq, Hkv, group, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        jax.checkpoint(body), (m0, l0, a0),
        (jnp.arange(nk), ks, vs),
    )
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def self_attention(
    params,
    x: jax.Array,  # [B, S, d]
    positions: jax.Array,  # [B, S]
    cfg: ModelConfig,
    *,
    causal: bool = True,
    use_rope: bool = True,
    kv_cache: Optional[Tuple[jax.Array, jax.Array]] = None,  # ([B,Smax,Hkv,hd] x2)
    cache_index: Optional[jax.Array] = None,  # scalar: write offset
    impl: str = "xla",
    block_k: int = 0,
    ac=None,  # sharding-constraint callback (seq-parallel scores)
    bf16_probs: bool = False,
):
    """Returns (out [B,S,d], new_kv_cache)."""
    B, S, _ = x.shape
    H, Hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = _split_heads(C.linear(params["wq"], x), H, hd)
    k = _split_heads(C.linear(params["wk"], x), Hkv, hd)
    v = _split_heads(C.linear(params["wv"], x), Hkv, hd)
    if use_rope:
        q = C.apply_rope(q, positions, cfg.rope_theta)
        k = C.apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    if kv_cache is not None:
        ck, cv = kv_cache
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, cache_index, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, cache_index, 0, 0))
        new_cache = (ck, cv)
        # Static cache shape; validity expressed via absolute-position mask.
        out = _attend_with_cache(q, ck, cv, cache_index + S, impl=impl, cfg=cfg)
        return C.linear(params["wo"], out.reshape(B, S, H * hd)), new_cache

    if block_k and S > block_k and impl == "xla":
        out = blocked_attention(q, k, v, block_k=block_k, causal=causal)
    else:
        out = attention(
            q.transpose(0, 2, 1, 3),
            k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3),
            causal=causal,
            impl=impl,
            ac=ac,
            bf16_probs=bf16_probs,
        ).transpose(0, 2, 1, 3)
    return C.linear(params["wo"], out.reshape(B, S, H * hd)), new_cache


def _attend_with_cache(q, ck, cv, valid_len, *, impl, cfg):
    """Decode-style attention over a static-size cache with masking.

    q: [B, S, H, hd] (S = tokens being appended, usually 1)
    ck/cv: [B, Smax, Hkv, hd]; positions < valid_len are valid.
    """
    B, S, H, hd = q.shape
    Smax, Hkv = ck.shape[1], ck.shape[2]
    group = H // Hkv
    qf = q.astype(jnp.float32).reshape(B, S, Hkv, group, hd)
    kf = ck.astype(jnp.float32)
    s = jnp.einsum("bsngd,bknd->bsngk", qf, kf) * (hd ** -0.5)
    kpos = jnp.arange(Smax)
    qpos = valid_len - S + jnp.arange(S)
    mask = kpos[None, :] <= qpos[:, None]  # [S, Smax]
    s = jnp.where(mask[None, :, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bsngk,bknd->bsngd", p, cv.astype(jnp.float32))
    return o.reshape(B, S, H, hd).astype(q.dtype)


def cross_attention(
    params,
    x: jax.Array,  # [B, S, d] decoder states
    memory: jax.Array,  # [B, T, d] encoder output
    cfg: ModelConfig,
    *,
    impl: str = "xla",
    ac=None,
    bf16_probs: bool = False,
):
    B, S, _ = x.shape
    H, Hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = _split_heads(C.linear(params["wq"], x), H, hd)
    k = _split_heads(C.linear(params["wk"], memory), Hkv, hd)
    v = _split_heads(C.linear(params["wv"], memory), Hkv, hd)
    out = attention(
        q.transpose(0, 2, 1, 3),
        k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3),
        causal=False,
        impl=impl,
        ac=ac,
        bf16_probs=bf16_probs,
    ).transpose(0, 2, 1, 3)
    return C.linear(params["wo"], out.reshape(B, S, H * hd))
