"""Decoder assembly: heterogeneous blocks, scan-over-groups, KV/SSM caches.

All ten architectures are assembled from the same machinery:

- ``cfg.layer_kind(i)`` decides each layer's mixer (attn / ssd / mlstm /
  slstm) and MLP (dense / moe / none).  Layer kinds repeat with period
  ``cfg.group_period`` (1 for homogeneous stacks, 8 for Jamba, 4 for
  xLSTM), so parameters stack as [num_groups, ...] pytrees and the layer
  stack runs as ONE ``lax.scan`` over groups — O(1) HLO size regardless of
  depth, which keeps the 80-cell dry-run compile matrix fast.  Roofline
  accounting multiplies scan-body costs back up (EXPERIMENTS.md §Roofline
  methodology).
- Three modes: ``train`` (no caches, remat per group), ``prefill``
  (returns per-layer caches), ``decode`` (consumes + updates caches,
  static cache shapes, position-masked attention).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.distributed.sharding import ShardingCtx

from . import common as C
from . import moe as MOE
from . import ssm as SSM
from .attention import attn_init, attn_specs, cross_attention, self_attention
from .mlp import mlp, mlp_init, mlp_specs


# ------------------------------------------------------------- one block
def block_init(key, cfg: ModelConfig, layer_in_group: int):
    mixer, mlp_kind = cfg.layer_kind(layer_in_group)
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"ln1": C.rmsnorm_init(cfg.d_model)}
    if mixer == "attn":
        p["attn"] = attn_init(ks[0], cfg)
        if cfg.encdec:
            p["ln_x"] = C.rmsnorm_init(cfg.d_model)
            p["xattn"] = attn_init(ks[2], cfg, cross=True)
    elif mixer == "ssd":
        p["ssd"] = SSM.ssd_init(ks[0], cfg)
    elif mixer == "mlstm":
        p["mlstm"] = SSM.mlstm_init(ks[0], cfg)
    elif mixer == "slstm":
        p["slstm"] = SSM.slstm_init(ks[0], cfg)
    if mlp_kind == "dense":
        ff = cfg.dense_d_ff or cfg.d_ff
        p["ln2"] = C.rmsnorm_init(cfg.d_model)
        p["mlp"] = mlp_init(ks[1], cfg.d_model, ff, cfg.mlp_type)
    elif mlp_kind == "moe":
        p["ln2"] = C.rmsnorm_init(cfg.d_model)
        p["moe"] = MOE.moe_init(ks[1], cfg)
    return p


def block_specs(cfg: ModelConfig, layer_in_group: int):
    mixer, mlp_kind = cfg.layer_kind(layer_in_group)
    p: Dict[str, Any] = {"ln1": C.rmsnorm_specs()}
    if mixer == "attn":
        p["attn"] = attn_specs(cfg)
        if cfg.encdec:
            p["ln_x"] = C.rmsnorm_specs()
            p["xattn"] = attn_specs(cfg)
    elif mixer == "ssd":
        p["ssd"] = SSM.ssd_specs(cfg)
    elif mixer == "mlstm":
        p["mlstm"] = SSM.mlstm_specs(cfg)
    elif mixer == "slstm":
        p["slstm"] = SSM.slstm_specs(cfg)
    if mlp_kind == "dense":
        p["ln2"] = C.rmsnorm_specs()
        p["mlp"] = mlp_specs(cfg.mlp_type)
    elif mlp_kind == "moe":
        p["ln2"] = C.rmsnorm_specs()
        p["moe"] = MOE.moe_specs(cfg)
    return p


def block_cache_init(
    cfg: ModelConfig, layer_in_group: int, batch: int, max_seq: int,
    dtype=jnp.bfloat16,
):
    """Static-shape cache for one block (decode mode)."""
    mixer, _ = cfg.layer_kind(layer_in_group)
    if mixer == "attn":
        kv = lambda: jnp.zeros(
            (batch, max_seq, cfg.num_kv_heads, cfg.head_dim), dtype
        )
        return {"k": kv(), "v": kv()}
    if mixer == "ssd":
        return SSM.ssd_state_init(cfg, batch, dtype)
    if mixer == "mlstm":
        return SSM.mlstm_state_init(cfg, batch, dtype)
    if mixer == "slstm":
        return SSM.slstm_state_init(cfg, batch, dtype)
    return {}


def block_apply(
    params,
    x: jax.Array,
    positions: jax.Array,
    cfg: ModelConfig,
    ctx: ShardingCtx,
    layer_in_group: int,
    *,
    mode: str,  # train | prefill | decode
    cache=None,
    cache_index=None,
    memory: Optional[jax.Array] = None,  # enc-dec cross-attention memory
):
    """Returns (x, new_cache, aux_loss)."""
    mixer, mlp_kind = cfg.layer_kind(layer_in_group)
    aux = jnp.zeros((), jnp.float32)
    new_cache: Dict[str, Any] = {}
    h = C.rmsnorm(params["ln1"], x, cfg.norm_eps)

    if mixer == "attn":
        if mode == "decode":
            out, kvc = self_attention(
                params["attn"], h, positions, cfg,
                kv_cache=(cache["k"], cache["v"]), cache_index=cache_index,
                impl=ctx.attn_impl,
            )
            new_cache = {"k": kvc[0], "v": kvc[1]}
        else:
            out, _ = self_attention(
                params["attn"], h, positions, cfg, impl=ctx.attn_impl,
                block_k=ctx.attn_block_k,
                ac=ctx.ac if ctx.attn_seq_shard else None,
                bf16_probs=ctx.attn_bf16_probs,
            )
            if mode == "prefill":
                # cache = computed K/V, written densely at positions 0..S
                kc = C.linear(params["attn"]["wk"], h)
                vc = C.linear(params["attn"]["wv"], h)
                B, S, _ = h.shape
                kh = kc.reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
                kh = C.apply_rope(kh, positions, cfg.rope_theta)
                vh = vc.reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
                new_cache = {"k": kh, "v": vh}
        x = x + out
        if cfg.encdec and memory is not None:
            hx = C.rmsnorm(params["ln_x"], x, cfg.norm_eps)
            x = x + cross_attention(params["xattn"], hx, memory, cfg,
                                    impl=ctx.attn_impl,
                                    ac=ctx.ac if ctx.attn_seq_shard else None,
                                    bf16_probs=ctx.attn_bf16_probs)
    elif mixer == "ssd":
        out, st = SSM.ssd_block(
            params["ssd"], h, cfg, ctx,
            state=cache if mode == "decode" else None,
        )
        if mode != "train":
            new_cache = st
        x = x + out
    elif mixer == "mlstm":
        out, st = SSM.mlstm_block(
            params["mlstm"], h, cfg, ctx,
            state=cache if mode == "decode" else None,
        )
        if mode != "train":
            new_cache = st
        x = x + out
    elif mixer == "slstm":
        out, st = SSM.slstm_block(
            params["slstm"], h, cfg, ctx,
            state=cache if mode == "decode" else None,
        )
        if mode != "train":
            new_cache = st
        x = x + out

    if mlp_kind == "dense":
        h2 = C.rmsnorm(params["ln2"], x, cfg.norm_eps)
        x = x + mlp(params["mlp"], h2, cfg.mlp_type)
    elif mlp_kind == "moe":
        h2 = C.rmsnorm(params["ln2"], x, cfg.norm_eps)
        y, aux = MOE.moe_ffn(params["moe"], h2, cfg, ctx)
        x = x + y
    x = ctx.ac(x, "batch", None, None)
    return x, new_cache, aux


# ------------------------------------------------------------ group stack
def group_init(key, cfg: ModelConfig):
    period = cfg.group_period
    ks = jax.random.split(key, period)
    return {f"layer_{j}": block_init(ks[j], cfg, j) for j in range(period)}


def group_specs(cfg: ModelConfig):
    period = cfg.group_period
    return {f"layer_{j}": block_specs(cfg, j) for j in range(period)}


def stacked_group_init(key, cfg: ModelConfig):
    """Params for all groups, stacked on axis 0: leaves [num_groups, ...]."""
    ks = jax.random.split(key, cfg.num_groups)
    return jax.vmap(lambda k: group_init(k, cfg))(ks)


def stacked_group_specs(cfg: ModelConfig):
    g = group_specs(cfg)
    return jax.tree_util.tree_map(
        lambda spec: ("layers",) + spec, g,
        is_leaf=lambda s: isinstance(s, tuple),
    )


def group_cache_init(cfg: ModelConfig, batch: int, max_seq: int, dtype=jnp.bfloat16):
    period = cfg.group_period
    return {
        f"layer_{j}": block_cache_init(cfg, j, batch, max_seq, dtype)
        for j in range(period)
    }


def stacked_cache_init(cfg: ModelConfig, batch: int, max_seq: int, dtype=jnp.bfloat16):
    one = group_cache_init(cfg, batch, max_seq, dtype)
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (cfg.num_groups,) + x.shape), one
    )


def _block_cache_specs(cfg: ModelConfig, layer_in_group: int):
    """Logical axes for one block's decode cache (mirrors block_cache_init)."""
    mixer, _ = cfg.layer_kind(layer_in_group)
    if mixer == "attn":
        kv = ("layers", "batch", "kvseq", "heads_kv", None)
        return {"k": kv, "v": kv}
    if mixer == "ssd":
        return {
            "h": ("layers", "batch", "heads", None, None),
            "conv": ("layers", "batch", None, "inner"),
        }
    if mixer == "mlstm":
        return {
            "h": ("layers", "batch", "heads", None, None),
            "conv": ("layers", "batch", None, "inner"),
        }
    if mixer == "slstm":
        return {
            "h": ("layers", "batch", "heads", None),
            "c": ("layers", "batch", "heads", None),
        }
    return {}


def stacked_cache_specs(cfg: ModelConfig):
    return {
        f"layer_{j}": _block_cache_specs(cfg, j)
        for j in range(cfg.group_period)
    }


def run_stack(
    stacked_params,
    x: jax.Array,
    positions: jax.Array,
    cfg: ModelConfig,
    ctx: ShardingCtx,
    *,
    mode: str,
    caches=None,  # stacked [G, ...] pytree (prefill: None in, built out)
    cache_index=None,
    memory: Optional[jax.Array] = None,
    remat: bool = True,
):
    """Scan the group stack.  Returns (x, new_caches, aux_total)."""
    period = cfg.group_period

    use_remat = remat and mode == "train"

    def one_layer(j, gparams_j, xc, gcache_j):
        return block_apply(
            gparams_j, xc, positions, cfg, ctx, j,
            mode=mode, cache=gcache_j, cache_index=cache_index,
            memory=memory,
        )

    def group_body(carry, xs):
        xc, aux_acc = carry
        gparams, gcache = xs
        new_gcache = {}
        for j in range(period):
            name = f"layer_{j}"
            layer_fn = functools.partial(one_layer, j)
            if use_remat and period > 1:
                # nested remat: backward recomputes ONE layer at a time, not
                # the whole group (a group of 8 jamba layers held ~50 GiB of
                # recomputed activations live without this)
                layer_fn = jax.checkpoint(layer_fn)
            xc, nc, aux = layer_fn(
                gparams[name], xc,
                None if gcache is None else gcache[name],
            )
            new_gcache[name] = nc
        return (xc, aux_acc + aux), new_gcache

    body = group_body
    if use_remat:
        body = jax.checkpoint(group_body)

    xs = (stacked_params, caches)
    (x, aux), new_caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), xs)
    return x, new_caches, aux
