"""Top-level model API: init / specs / train_loss / prefill / decode_step.

The single entry point the launcher, dry-run, trainer and server all use.
Batch layouts (built by ``launch.dryrun.input_specs`` / ``data.tokens``):

    train:   {"tokens": [B,S] int32, "labels": [B,S] int32,
              +"patch_embeds": [B,prefix,d] (vlm) | "frames": [B,T,d] (audio)}
    prefill: {"tokens": [B,S]}  (+ frontend extras)
    decode:  {"tokens": [B,1], "cache_index": scalar int32, caches pytree}
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.distributed.sharding import ShardingCtx

from . import common as C
from . import transformer as T

Params = Dict[str, Any]


# ------------------------------------------------------------------- init
def init_params(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    ks = jax.random.split(key, 8)
    p: Params = {
        "embed": C.embedding_init(ks[0], cfg.vocab_size, cfg.d_model),
        "groups": T.stacked_group_init(ks[1], cfg),
        "final_norm": C.rmsnorm_init(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = C.linear_init(ks[2], cfg.d_model, cfg.vocab_size)
    if cfg.encdec:
        enc_cfg = _encoder_cfg(cfg)
        p["encoder"] = {
            "groups": T.stacked_group_init(ks[3], enc_cfg),
            "final_norm": C.rmsnorm_init(cfg.d_model),
        }
    return C.cast_tree(p, dtype)


def param_specs(cfg: ModelConfig) -> Params:
    p: Params = {
        "embed": C.embedding_specs(),
        "groups": T.stacked_group_specs(cfg),
        "final_norm": C.rmsnorm_specs(),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = C.linear_specs("embed", "vocab")
    if cfg.encdec:
        enc_cfg = _encoder_cfg(cfg)
        p["encoder"] = {
            "groups": T.stacked_group_specs(enc_cfg),
            "final_norm": C.rmsnorm_specs(),
        }
    return p


def _encoder_cfg(cfg: ModelConfig) -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        cfg,
        num_layers=cfg.num_encoder_layers,
        encdec=False,
        num_experts=0,
        attn_every=0,
        mlp_type="gelu",
    )


# --------------------------------------------------------------- backbone
def _embed_inputs(params, batch, cfg: ModelConfig, ctx: ShardingCtx):
    """Token embeddings (+ modality prefix), positions, label mask."""
    tokens = batch["tokens"]
    x = C.embed(params["embed"], tokens)
    if cfg.frontend == "vision_stub" and "patch_embeds" in batch:
        # STUB frontend per spec: precomputed patch embeddings prefix the
        # token sequence (PaliGemma-style prefix-LM, causal mask retained).
        x = jnp.concatenate([batch["patch_embeds"].astype(x.dtype), x], axis=1)
    scale = jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if cfg.family in ("vlm",) or cfg.name.startswith("gemma"):
        x = x * scale  # gemma-family embedding scaling
    B, S = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = ctx.ac(x, "batch", None, None)
    return x, positions


def _encode(params, batch, cfg: ModelConfig, ctx: ShardingCtx):
    """Whisper-style encoder over (stub) audio frame embeddings."""
    frames = batch["frames"]  # [B, T, d] precomputed conv-frontend output
    enc_cfg = _encoder_cfg(cfg)
    x = frames.astype(jnp.bfloat16)
    x = x + C.sinusoidal_positions(x.shape[1], cfg.d_model).astype(x.dtype)
    # Encoder is bidirectional: reuse the stack with causal disabled by
    # calling attention directly in non-causal mode via cfg flag hack-free
    # path: encoder blocks are plain attn+mlp, mode="train", causal=False.
    x, _, _ = _run_encoder_stack(params["encoder"]["groups"], x, enc_cfg, ctx)
    return C.rmsnorm(params["encoder"]["final_norm"], x, cfg.norm_eps)


def _run_encoder_stack(stacked, x, enc_cfg, ctx):
    from .attention import self_attention
    from .mlp import mlp as mlp_apply

    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    def body(carry, gparams):
        xc = carry
        blk = gparams["layer_0"]
        h = C.rmsnorm(blk["ln1"], xc, enc_cfg.norm_eps)
        out, _ = self_attention(
            blk["attn"], h, positions, enc_cfg, causal=False,
            impl=ctx.attn_impl,
            ac=ctx.ac if ctx.attn_seq_shard else None,
            bf16_probs=ctx.attn_bf16_probs,
        )
        xc = xc + out
        h2 = C.rmsnorm(blk["ln2"], xc, enc_cfg.norm_eps)
        xc = xc + mlp_apply(blk["mlp"], h2, enc_cfg.mlp_type)
        return xc, None

    x, _ = jax.lax.scan(body, x, stacked)
    return x, None, None


def _head(params, x, cfg: ModelConfig, ctx: ShardingCtx):
    x = C.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = x @ params["embed"]["table"].T.astype(x.dtype)
    else:
        logits = C.linear(params["lm_head"], x)
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    return ctx.ac(logits, "batch", None, "vocab")


def forward(
    params, batch, cfg: ModelConfig, ctx: ShardingCtx, *, mode: str,
    caches=None, cache_index=None, remat: bool = True, memory=None,
):
    """Shared backbone.  Returns (logits, new_caches, aux)."""
    if cfg.encdec and memory is None and mode != "decode":
        memory = _encode(params, batch, cfg, ctx)
    x, positions = _embed_inputs(params, batch, cfg, ctx)
    if mode == "decode" and cache_index is not None:
        B, S = batch["tokens"].shape
        positions = cache_index + jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32), (B, S)
        )
    x, new_caches, aux = T.run_stack(
        params["groups"], x, positions, cfg, ctx,
        mode=mode, caches=caches, cache_index=cache_index, memory=memory,
        remat=remat,
    )
    logits = _head(params, x, cfg, ctx)
    return logits, new_caches, aux


# ------------------------------------------------------------------ losses
def train_loss(
    params, batch, cfg: ModelConfig, ctx: ShardingCtx, *,
    aux_coef: float = 0.01, remat: bool = True,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    logits, _, aux = forward(params, batch, cfg, ctx, mode="train", remat=remat)
    labels = batch["labels"]
    if cfg.frontend == "vision_stub" and "patch_embeds" in batch:
        # prefix positions carry no next-token loss
        prefix = batch["patch_embeds"].shape[1]
        logits = logits[:, prefix:]
    lf = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(lf, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    loss = (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    total = loss + aux_coef * aux
    return total, {"loss": loss, "aux": aux, "tokens": mask.sum()}


# ---------------------------------------------------------------- serving
#
# Cache layout: {"stack": <[G,...] per-layer caches>, "memory": enc_out|None}
# — the encoder output (whisper) is computed once at prefill and carried in
# the cache pytree so decode steps never re-run the encoder.
def prefill(params, batch, cfg: ModelConfig, ctx: ShardingCtx):
    """Full-sequence forward; returns (last_logits, caches)."""
    memory = _encode(params, batch, cfg, ctx) if cfg.encdec else None
    logits, stack, _ = forward(
        params, batch, cfg, ctx, mode="prefill", remat=False, memory=memory,
    )
    return logits[:, -1], {"stack": stack, "memory": memory}


def decode_step(
    params, tokens, caches, cache_index, cfg: ModelConfig, ctx: ShardingCtx,
):
    """One token step.  tokens: [B,1]; returns (logits [B,V], new_caches)."""
    logits, new_stack, _ = forward(
        params, {"tokens": tokens}, cfg, ctx,
        mode="decode", caches=caches["stack"], cache_index=cache_index,
        remat=False, memory=caches.get("memory"),
    )
    return logits[:, -1], {"stack": new_stack, "memory": caches.get("memory")}


def init_decode_caches(cfg: ModelConfig, batch: int, max_seq: int,
                       dtype=jnp.bfloat16):
    memory = None
    if cfg.encdec:
        memory = jnp.zeros((batch, cfg.encoder_seq, cfg.d_model), dtype)
    return {
        "stack": T.stacked_cache_init(cfg, batch, max_seq, dtype),
        "memory": memory,
    }


def pad_caches(caches, cfg: ModelConfig, *, max_seq: int):
    """Grow prefill KV caches ([G,B,S,...]) to a decode budget of max_seq.

    Only attention K/V leaves have a sequence axis (axis 2 under the group
    stacking); SSM/conv states are O(1) and pass through unchanged.
    """

    def one(path, leaf):
        key = path[-1]
        name = getattr(key, "key", None)
        if name in ("k", "v") and leaf.ndim == 5:
            pad = max_seq - leaf.shape[2]
            if pad <= 0:
                return leaf
            widths = [(0, 0)] * leaf.ndim
            widths[2] = (0, pad)
            return jnp.pad(leaf, widths)
        return leaf

    return jax.tree_util.tree_map_with_path(one, caches)
