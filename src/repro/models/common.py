"""Shared model components: norms, RoPE, embeddings, init, logical sharding.

Parameters are plain pytrees of jnp arrays.  Every init function has a
mirror ``*_specs`` producing the same tree structure with *logical axis*
tuples instead of arrays; ``repro.distributed.sharding`` maps logical axes
to mesh axes.  Tests assert the two trees are always congruent.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, Any]

# Logical axis names used across the model zoo:
#   "embed"  : d_model dims            -> FSDP axis (data, pod)
#   "qkv"    : flattened head dims     -> TP axis (model)
#   "mlp"    : d_ff dims               -> TP axis (model)
#   "vocab"  : vocabulary dim          -> TP axis (model)
#   "expert" : MoE expert dim          -> TP axis (model) (expert parallel)
#   "inner"  : SSM inner dims          -> TP axis (model)
#   "layers" : stacked scan groups     -> replicated
#   None     : replicated


def he_init(key, shape, fan_in, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype) * np.sqrt(1.0 / max(fan_in, 1))


# --------------------------------------------------------------------- norms
def rmsnorm_init(d: int) -> Params:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm_specs() -> Params:
    return {"scale": ("embed",)}


def rmsnorm(params: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * params["scale"]
    return out.astype(x.dtype)


def layernorm_init(d: int) -> Params:
    return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}


def layernorm_specs() -> Params:
    return {"scale": ("embed",), "bias": ("embed",)}


def layernorm(params: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * params["scale"] + params["bias"]
    return out.astype(x.dtype)


# ---------------------------------------------------------------------- rope
def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, D]; positions: [..., S] int32."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)  # [D/2]
    ang = positions[..., :, None, None].astype(jnp.float32) * freqs  # [..., S, 1, D/2]
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, d: int) -> jax.Array:
    pos = np.arange(seq)[:, None]
    i = np.arange(d // 2)[None, :]
    ang = pos / np.power(10_000.0, 2 * i / d)
    out = np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)
    return jnp.asarray(out, jnp.float32)


# ------------------------------------------------------------------- linear
def linear_init(key, d_in: int, d_out: int, *, bias: bool = False) -> Params:
    p = {"w": he_init(key, (d_in, d_out), d_in)}
    if bias:
        p["b"] = jnp.zeros((d_out,), jnp.float32)
    return p


def linear_specs(ax_in: Optional[str], ax_out: Optional[str], *, bias: bool = False):
    p = {"w": (ax_in, ax_out)}
    if bias:
        p["b"] = (ax_out,)
    return p


def linear(params: Params, x: jax.Array) -> jax.Array:
    y = x @ params["w"].astype(x.dtype)
    if "b" in params:
        y = y + params["b"].astype(x.dtype)
    return y


# ---------------------------------------------------------------- embedding
def embedding_init(key, vocab: int, d: int) -> Params:
    return {"table": jax.random.normal(key, (vocab, d), jnp.float32) * 0.02}


def embedding_specs() -> Params:
    return {"table": ("vocab", "embed")}


def embed(params: Params, tokens: jax.Array, dtype=jnp.bfloat16) -> jax.Array:
    return jnp.take(params["table"], tokens, axis=0).astype(dtype)


# ------------------------------------------------------------ tree utilities
def tree_congruent(params, specs) -> bool:
    """Same structure: params tree (array leaves) vs specs tree (tuple leaves)."""
    tp = jax.tree_util.tree_structure(params)
    ts = jax.tree_util.tree_structure(
        specs, is_leaf=lambda x: isinstance(x, tuple)
    )
    return tp == ts


def cast_tree(tree, dtype):
    return jax.tree_util.tree_map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree,
    )


def count_params(tree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))
