"""Recompactor: fold pending delta runs back into base shards.

The overlay keeps sweeps correct while deltas are pending, but every decode
of a dirty shard pays the fold (and ELL shards decode via CSR + a fresh
``csr_to_ell``).  Recompaction restores the fast path: for each dirty shard
it k-way-merges base keys + pending runs (tombstones applied in publish
order, inserts merged — the same :func:`~repro.delta.overlay.apply_run`
fold the overlay uses, so the result is bitwise the overlay's view) and
rewrites the base CSR + ELL containers through ``ShardStore.write_shard``,
which fires the PR 3 invalidation hooks — live engines drop stale cached
bytes and device-resident decodes automatically.  Fresh unique-source
arrays are re-deposited as warm state so engines rebuild that shard's Bloom
filter without another read.

Safety against live sweeps: absorbing runs ``<= S`` changes which state the
BASE bytes represent, so compaction (a) waits until no sweep is pinned
below ``S`` (:meth:`DeltaOverlay.wait_pins_below`) and (b) performs the
swap — staged base write + manifest flip + renames + run removal — under
the same per-shard lock the overlay decode takes.  A concurrent reader
pinned at ``v >= S`` therefore sees either (old base, runs ``<= S``
pending) or (new base, runs ``(S, v]`` pending); both decode to the same
logical shard.

Safety against crashes (DESIGN.md §12): the new base containers are staged
under ``delta_stage/`` and ONE atomic manifest write flips the shard —
floor advance and stage record land together — before any base file is
replaced.  A crash before the flip discards the stage (old base + runs
intact); a crash after it has recovery finish the renames and delete the
absorbed runs.  The old two-file overwrite could crash between the base
rewrite and the floor advance, double-applying the runs on reopen.

Triggers (``should_compact``): pending run count >= ``min_runs`` OR pending
delta bytes >= ``min_delta_frac`` of the base container.  ``compact()``
runs synchronously; ``start()`` runs the same policy on a background
thread, the LSM-style maintenance loop a serving deployment wants.
"""

from __future__ import annotations

import dataclasses
import os
import threading
from typing import List, Optional, Sequence

import numpy as np

from repro.core.ingest import csr_from_keys, keys_of_csr
from repro.core.storage import DELTA_STAGE_DIR
from repro.delta.overlay import apply_run
from repro.delta.recovery import crashpoint, stage_rel_name
from repro.obs import trace

__all__ = ["CompactionStats", "Recompactor"]


@dataclasses.dataclass
class CompactionStats:
    shards_compacted: int = 0
    runs_absorbed: int = 0
    inserts_applied: int = 0
    tombstones_applied: int = 0
    shard_bytes_written: int = 0

    def merge(self, other: "CompactionStats") -> None:
        self.shards_compacted += other.shards_compacted
        self.runs_absorbed += other.runs_absorbed
        self.inserts_applied += other.inserts_applied
        self.tombstones_applied += other.tombstones_applied
        self.shard_bytes_written += other.shard_bytes_written


class Recompactor:
    """Merge pending delta runs into new base shards (sync or background)."""

    def __init__(
        self,
        store,
        *,
        min_runs: int = 1,
        min_delta_frac: float = 0.0,
        interval_s: float = 0.05,
    ):
        if min_runs < 1:
            raise ValueError("min_runs must be >= 1")
        self.store = store
        self.overlay = store.ensure_delta()
        self.min_runs = min_runs
        self.min_delta_frac = min_delta_frac
        self.interval_s = interval_s
        self.total = CompactionStats()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._lock = threading.Lock()  # guards ``total`` merges
        # Lifecycle lock: start/stop may race (e.g. concurrent
        # GraphService.close calls); the maintenance thread itself never
        # takes it, so joining under it cannot deadlock.
        self._lifecycle_lock = threading.Lock()

    # ------------------------------------------------------------- policy
    def should_compact(self, p: int) -> bool:
        """Either trigger fires: pending run count reached ``min_runs``, or
        (when ``min_delta_frac > 0``) pending delta bytes reached that
        fraction of the base container.  A zero fraction disables the byte
        trigger rather than making it always-on — otherwise ``min_runs``
        could never batch runs up."""
        n_runs, _, _, pend_bytes = self.overlay.pending_stats(p)
        if n_runs == 0:
            return False
        if n_runs >= self.min_runs:
            return True
        if self.min_delta_frac <= 0.0:
            return False
        base = self.store.file_size(self.store.shard_name(p, "csr"))
        return pend_bytes >= self.min_delta_frac * max(base, 1)

    def dirty_shards(self) -> List[int]:
        return self.overlay.dirty_shards()

    # -------------------------------------------------------------- action
    def compact_shard(self, p: int) -> Optional[CompactionStats]:
        """Absorb shard ``p``'s runs up to the current version; returns the
        per-shard stats, or None if there was nothing to absorb (or a stop
        was requested while waiting for older sweep pins to drain)."""
        store, overlay = self.store, self.overlay
        s = overlay.version
        if not overlay.has_pending(p, s):
            return None
        if not overlay.wait_pins_below(s, stop=self._stop):
            return None
        with trace.span("compact.shard", shard=p, version=s) as sp:
            out = self._compact_locked(p, s, sp)
        return out

    def _compact_locked(self, p: int, s: int, sp) -> Optional[CompactionStats]:
        store, overlay = self.store, self.overlay
        meta = store.read_meta()
        ep = store.ell_params()
        with overlay.shard_lock(p):
            runs = overlay.pending_runs(p, s)
            if not runs:
                return None
            # fold base + runs <= s exactly as the overlay decodes them
            raw = store.shard_bytes(p, "csr")
            keys = keys_of_csr(store.decode_csr(p, raw))
            n_ins = n_tombs = 0
            for r in runs:
                tombs, ins = r.tombs(store), r.ins(store)
                keys = apply_run(keys, tombs, ins)
                n_ins += len(ins)
                n_tombs += len(tombs)
            v0, v1 = meta.interval_of(p)
            shard = csr_from_keys(p, v0, v1, keys)
            del keys
            # the swap (staged-rename protocol, DESIGN.md §12): encode the
            # new base into the staging dir, flip the manifest — floor
            # advance + stage record in ONE atomic write — then rename each
            # container into place and clean up; all under this shard's
            # overlay lock, so readers see old-base+runs or new-base, never
            # half of each, and a crash at any point recovers cleanly.
            csr_raw, ell_raw, _ = store.encode_shard(
                shard,
                num_vertices=meta.num_vertices,
                window=ep["window"], k=ep["k"], tr=ep["tr"],
            )
            csr_name = store.shard_name(p, "csr")
            ell_name = store.shard_name(p, "ell")
            os.makedirs(store._path(DELTA_STAGE_DIR), exist_ok=True)
            store.write_bytes(stage_rel_name(csr_name), csr_raw)
            store.write_bytes(stage_rel_name(ell_name), ell_raw)
            crashpoint("compact.staged")
            overlay.commit_compaction(p, s)  # COMMIT: the manifest flip
            crashpoint("compact.flipped")
            os.replace(store._path(stage_rel_name(csr_name)), store._path(csr_name))
            crashpoint("compact.csr_renamed")
            os.replace(store._path(stage_rel_name(ell_name)), store._path(ell_name))
            crashpoint("compact.renamed")
            store.invalidate_shard(p)  # hooks fire; warm state re-deposited
            store.set_warm_sources(p, np.unique(shard.col).astype(np.int64))
            overlay.clear_stage(p, s, runs)
        written = len(csr_raw) + len(ell_raw)
        st = CompactionStats(
            shards_compacted=1,
            runs_absorbed=len(runs),
            inserts_applied=n_ins,
            tombstones_applied=n_tombs,
            shard_bytes_written=written,
        )
        sp.set(runs=len(runs), inserts=n_ins, tombstones=n_tombs, bytes=written)
        with self._lock:
            self.total.merge(st)
        return st

    def compact(self, shards: Optional[Sequence[int]] = None) -> CompactionStats:
        """Synchronously compact ``shards`` (default: every dirty shard
        passing the trigger policy; pass an explicit list to force)."""
        agg = CompactionStats()
        if shards is None:
            shards = [p for p in self.dirty_shards() if self.should_compact(p)]
        for p in shards:
            st = self.compact_shard(p)
            if st is not None:
                agg.merge(st)
        return agg

    # ---------------------------------------------------------- background
    def start(self) -> None:
        """Run the trigger policy on a background maintenance thread."""
        with self._lifecycle_lock:
            if self._thread is not None:
                return
            self._stop.clear()

            def loop() -> None:
                while not self._stop.wait(self.interval_s):
                    try:
                        self.compact()
                    except Exception:  # maintenance must not kill the host
                        if self._stop.is_set():
                            return
                        raise

            self._thread = threading.Thread(
                target=loop, name="graphdelta-recompact", daemon=True
            )
            self._thread.start()

    def stop(self) -> None:
        """Signal the maintenance thread and JOIN it — including any
        compaction it is mid-way through.  Idempotent and thread-safe:
        every concurrent caller blocks until the thread has fully exited
        (the old unguarded ``self._thread = None`` let a second closer
        return while a compaction still held shard locks)."""
        self._stop.set()
        with self._lifecycle_lock:
            if self._thread is not None:
                self._thread.join()
                self._thread = None

    def __enter__(self) -> "Recompactor":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.stop()
