"""Crash recovery for GraphDelta: journal replay + staged-rename completion.

DESIGN.md §12.  The delta layer's durable state is a set of per-shard run
files, the metadata pair (``property.json`` / ``vertexinfo.npz``), the base
shard containers, and ONE commit record — ``delta_manifest.json``, always
written via the store's atomic tmp+rename channel.  Every multi-file
protocol (publish, compaction) is arranged so that a crash at ANY point
leaves the store in a state this module can roll forward or back from,
using only the manifest:

Publish (``EdgeLog.publish`` / ``DeltaOverlay.commit_publish``)::

    run files            delta_run_<shard>_<seq>.npz, one per touched shard
    metadata journal     delta_journal_<seq>.npz — ABSOLUTE post-publish
                         degree rows for the touched vertices + edge count
    COMMIT               manifest gains {"version": seq, "journal": seq}
    metadata             property.json + vertexinfo.npz rewritten
    clear                manifest rewritten without "journal"; journal file
                         removed

    crash before COMMIT  -> run files / journal at seq > version: deleted
    crash after  COMMIT  -> journal replayed onto the metadata (idempotent:
                            absolute values, not deltas), then cleared

Compaction (``Recompactor._compact_locked``)::

    staged containers    delta_stage/shard_<p>.{csr,ell}.npz
    COMMIT               manifest gains {"floor": {p: s}, "stage": {p: s}}
                         in ONE atomic write — the floor advance and the
                         stage record land together, so pending runs can
                         never be applied onto a base that already absorbed
                         them (the double-apply window)
    rename               each staged file os.replace'd into place
    clear                absorbed run files removed; manifest rewritten
                         without the stage record

    crash before COMMIT  -> staged files without a record: deleted (base +
                            runs intact — nothing happened)
    crash after  COMMIT  -> recovery finishes the renames for staged files
                            still present, deletes runs <= floor, clears
                            the record

The module also owns the named **crash injection points** the recovery test
matrix SIGKILLs a subprocess at (``tests/test_crash_recovery.py``); the
hook is a no-op unless a test installs one.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Callable, Dict, Optional

import numpy as np

from repro.core.storage import (
    DELTA_JOURNAL_PREFIX,
    DELTA_MANIFEST,
    DELTA_RUN_PREFIX,
    DELTA_STAGE_DIR,
    _load_npz_bytes,
    _save_npz_bytes,
)

__all__ = [
    "CRASH_POINTS",
    "RecoveryReport",
    "crashpoint",
    "encode_journal",
    "journal_name",
    "recover",
    "set_crash_hook",
    "stage_rel_name",
]

#: Every named injection point, in protocol order.  The matrix test kills a
#: subprocess at each one and asserts the reopened store is bitwise either
#: the pre-operation or the post-operation oracle — never a mix.
CRASH_POINTS = (
    "publish.first_run",       # first run file durable, rest missing
    "publish.runs_written",    # all run files durable, no journal yet
    "publish.journal_written", # journal durable, manifest not flipped
    "publish.committed",       # manifest flipped, metadata not yet written
    "publish.meta_written",    # metadata durable, journal not yet cleared
    "compact.staged",          # staged containers durable, manifest not flipped
    "compact.flipped",         # manifest flipped, renames pending
    "compact.csr_renamed",     # csr renamed into place, ell rename pending
    "compact.renamed",         # both renamed, run files / record not cleared
)

_crash_hook: Optional[Callable[[str], None]] = None


def set_crash_hook(hook: Optional[Callable[[str], None]]) -> None:
    """Install (or clear, with ``None``) the crash-injection hook.
    Test-only; production code never sets it."""
    global _crash_hook
    _crash_hook = hook


def crashpoint(name: str) -> None:
    """Invoke the injection hook, if any.  The matrix driver's hook
    SIGKILLs the process here — simulating a crash with the files exactly
    as the protocol left them at this point."""
    if _crash_hook is not None:
        _crash_hook(name)


def journal_name(seq: int) -> str:
    return f"{DELTA_JOURNAL_PREFIX}{seq:07d}.npz"


def stage_rel_name(base_name: str) -> str:
    """Store-relative path of ``base_name`` inside the staging dir."""
    return f"{DELTA_STAGE_DIR}/{base_name}"


def encode_journal(meta, vids: np.ndarray, num_edges: int) -> bytes:
    """Metadata journal payload: ABSOLUTE post-publish degree rows for the
    touched vertex ids plus the new edge count.  Absolute (not deltas) so
    replay is idempotent — recovery may run after the metadata already
    landed, or itself crash mid-replay and run again."""
    vids = np.asarray(vids, dtype=np.int64)
    return _save_npz_bytes(
        vids=vids,
        in_deg=np.asarray(meta.in_deg)[vids],
        out_deg=np.asarray(meta.out_deg)[vids],
        num_edges=np.array([int(num_edges)], dtype=np.int64),
    )


@dataclasses.dataclass
class RecoveryReport:
    """What one recovery pass did (informational; tests assert on it)."""

    journal_replayed: bool = False
    stage_renames_finished: int = 0
    stage_files_discarded: int = 0
    orphan_runs_removed: int = 0
    orphan_journals_removed: int = 0

    @property
    def acted(self) -> bool:
        return bool(
            self.journal_replayed
            or self.stage_renames_finished
            or self.stage_files_discarded
            or self.orphan_runs_removed
            or self.orphan_journals_removed
        )


def recover(overlay) -> RecoveryReport:
    """Run the recovery state machine for ``overlay``'s store and populate
    the overlay's in-memory state (version, floors, registered runs).

    Called from ``DeltaOverlay.__init__`` — i.e. once per store open, before
    any engine can read.  Idempotent: recovering an already-clean store is
    a no-op, and recovery itself crashing at any point leaves a state a
    second recovery completes.
    """
    store = overlay.store
    rep = RecoveryReport()

    man: Dict = {}
    if store.exists(DELTA_MANIFEST):
        man = json.loads(store.read_bytes(DELTA_MANIFEST))
    overlay.version = int(man.get("version", 0))
    overlay._floor = {int(p): int(s) for p, s in man.get("floor", {}).items()}
    journal_seq = man.get("journal")
    stage = {int(p): int(s) for p, s in man.get("stage", {}).items()}

    # -- 1. committed compaction flips: finish the renames ----------------
    # The stage record in the manifest IS the commit; the base files on
    # disk may be any prefix of {csr renamed, ell renamed}.  Finish what
    # remains; a staged file already renamed is simply absent here.
    stage_dir = store._path(DELTA_STAGE_DIR)
    staged_files = set(os.listdir(stage_dir)) if os.path.isdir(stage_dir) else set()
    for p in sorted(stage):
        for fmt in ("csr", "ell"):
            base = store.shard_name(p, fmt)
            if base in staged_files:
                os.replace(os.path.join(stage_dir, base), store._path(base))
                staged_files.discard(base)
                rep.stage_renames_finished += 1

    # -- 2. uncommitted stage leftovers: discard ---------------------------
    # No record in the manifest -> the compaction never committed; the old
    # base + its pending runs are the truth.  (Includes .tmp scraps from a
    # write that died mid-flight.)
    for f in staged_files:
        try:
            os.remove(os.path.join(stage_dir, f))
            rep.stage_files_discarded += 1
        except OSError:
            pass

    # -- 3. committed publish with unapplied metadata: replay the journal --
    if journal_seq is not None:
        jn = journal_name(int(journal_seq))
        if store.exists(jn):
            z = _load_npz_bytes(store.read_bytes(jn))
            meta = store.read_meta()
            vids = z["vids"]
            meta.in_deg[vids] = z["in_deg"]
            meta.out_deg[vids] = z["out_deg"]
            meta.num_edges = int(z["num_edges"][0])
            store.write_meta(meta)
            rep.journal_replayed = True
        # a referenced-but-missing journal means the clear itself was
        # interrupted after the file removal: metadata already durable

    # -- 4. run files: register published ones, delete orphans -------------
    # seq > version: the publish never committed.  seq <= floor: absorbed
    # by a committed compaction whose cleanup was interrupted.
    for f in sorted(os.listdir(store.root)):
        if not (f.startswith(DELTA_RUN_PREFIX) and f.endswith(".npz")):
            continue
        stem = f[len(DELTA_RUN_PREFIX):-4]
        try:
            p_s, seq_s = stem.split("_")
            p, seq = int(p_s), int(seq_s)
        except ValueError:
            continue
        if seq > overlay.version or seq <= overlay._floor.get(p, 0):
            os.remove(store._path(f))
            rep.orphan_runs_removed += 1
            continue
        from .overlay import DeltaRun  # local: avoid import cycle

        run = DeltaRun(p, seq, f, nbytes=store.file_size(f))
        overlay._runs.setdefault(p, []).append(run)
        overlay._last_publish[p] = max(overlay._last_publish.get(p, 0), seq)
    for runs in overlay._runs.values():
        runs.sort(key=lambda r: r.seq)

    # -- 5. clear recovered protocol state from the manifest ---------------
    # Rewrite BEFORE deleting journal files: if we crash in between, the
    # next recovery finds unreferenced journals and deletes them (step 6);
    # the reverse order would leave a manifest referencing a missing file
    # (tolerated above, but needlessly).
    if journal_seq is not None or stage:
        overlay._stage = {}
        overlay._write_manifest()

    # -- 6. unreferenced journal files: delete ------------------------------
    # After step 5 no journal is referenced; any file left is either an
    # uncommitted publish's (its runs were deleted in step 4) or a cleared
    # one whose removal was interrupted.
    for f in sorted(os.listdir(store.root)):
        if f.startswith(DELTA_JOURNAL_PREFIX) and f.endswith(".npz"):
            os.remove(store._path(f))
            rep.orphan_journals_removed += 1

    return rep
