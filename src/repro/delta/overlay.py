"""DeltaOverlay: versioned merge of base shards + pending delta runs.

The overlay is the read side of GraphDelta (DESIGN.md §8).  A store's base
shards stay immutable between recompactions; every published update batch
adds one *delta run* per affected shard — a file of destination-sorted
``(dst << 32) | src`` insert keys plus unique tombstone keys (deletes).
``load_logical`` reconstructs the CURRENT logical shard by folding the
pending runs over the base CSR in publish order:

    keys := base_keys
    for run in runs(floor < seq <= pin):      # publish order
        keys := merge(keys \\ run.tombs, run.ins)

Because the fold operates on exactly the sort keys the external build uses
(``repro.core.ingest``), the result is bitwise what a from-scratch build of
the mutated edge list (same intervals) would produce — tombstones remove
ALL copies of an edge, inserts add one copy, and a later batch's insert
survives an earlier batch's tombstone by construction of the publish fold
(``repro.delta.edgelog``).

Version/snapshot semantics
--------------------------
``version`` is the publish sequence number (0 = base only).  A sweep PINS
the version it starts at (:meth:`acquire_pin`); every decode during that
sweep applies runs up to the pin only, so one sweep never mixes two graph
versions.  Publishes happen strictly *between* sweeps in the serving layer;
pins exist so background recompaction can also run safely: absorbing runs
``<= S`` into the base waits until no active pin is below ``S``
(:meth:`wait_pins_below`), and the per-shard swap (base rewrite + floor
advance) happens under the same per-shard lock every overlay decode takes —
a concurrent reader sees either (old base, runs ``<= S`` pending) or
(new base, runs ``<= S`` absorbed), never half of each.

Durability: run files live in the store (accounted channel) and
``delta_manifest.json`` is the ONLY commit record — one atomic write flips
a publish (version + metadata journal ref) or a compaction (floor + stage
record) in its entirety.  On open, :func:`repro.delta.recovery.recover`
rolls every interrupted protocol forward or back from the manifest alone:
uncommitted runs/journals/staged files are deleted, a committed publish's
metadata journal is replayed, a committed compaction's staged renames are
finished.  See DESIGN.md §12 for the full state machine.
"""

from __future__ import annotations

import contextlib
import json
import os
import threading
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.csr import csr_to_ell
from repro.core.ingest import csr_from_keys, keys_of_csr, kway_merge
from repro.obs import trace
from repro.core.storage import DELTA_MANIFEST, DELTA_RUN_PREFIX, _load_npz_bytes, _save_npz_bytes

from . import recovery as _recovery
from .recovery import crashpoint

__all__ = ["DeltaRun", "DeltaOverlay", "apply_run", "tombstoned_mask",
           "run_name"]

_KEY_DTYPE = np.dtype("<i8")


def run_name(shard_id: int, seq: int) -> str:
    return f"{DELTA_RUN_PREFIX}{shard_id:05d}_{seq:07d}.npz"


def tombstoned_mask(keys: np.ndarray, tombs: np.ndarray) -> np.ndarray:
    """Bool mask over ``keys`` marking entries present in the sorted-unique
    tombstone array — the one membership primitive every delta fold uses
    (drop = ``keys[~mask]``, removed-multiplicity = ``keys[mask]``)."""
    if len(tombs) == 0 or len(keys) == 0:
        return np.zeros(len(keys), dtype=bool)
    pos = np.minimum(np.searchsorted(tombs, keys), len(tombs) - 1)
    return tombs[pos] == keys


def apply_run(
    keys: np.ndarray, tombs: np.ndarray, ins: np.ndarray
) -> np.ndarray:
    """One fold step: drop ALL copies of tombstoned keys, merge inserts.

    ``keys`` and ``ins`` are sorted (possibly with duplicates); ``tombs`` is
    sorted unique.  Output is sorted — merging two sorted arrays preserves
    the (dst, src) lexicographic order the shard format requires.
    """
    if len(tombs) and len(keys):
        keys = keys[~tombstoned_mask(keys, tombs)]
    if len(ins):
        keys = kway_merge([keys, ins])
    return keys


class DeltaRun:
    """One published delta run for one shard (lazy-loaded, then cached)."""

    __slots__ = ("shard_id", "seq", "name", "n_ins", "n_tombs", "nbytes",
                 "_ins", "_tombs")

    def __init__(self, shard_id: int, seq: int, name: str,
                 n_ins: int = -1, n_tombs: int = -1, nbytes: int = 0):
        self.shard_id = shard_id
        self.seq = seq
        self.name = name
        self.n_ins = n_ins
        self.n_tombs = n_tombs
        self.nbytes = nbytes
        self._ins: Optional[np.ndarray] = None
        self._tombs: Optional[np.ndarray] = None

    @staticmethod
    def encode(ins: np.ndarray, tombs: np.ndarray) -> bytes:
        return _save_npz_bytes(
            ins=ins.astype(_KEY_DTYPE), tombs=tombs.astype(_KEY_DTYPE)
        )

    def set_arrays(self, ins: np.ndarray, tombs: np.ndarray) -> None:
        self._ins, self._tombs = ins, tombs
        self.n_ins, self.n_tombs = len(ins), len(tombs)

    def _load(self, store) -> None:
        if self._ins is None:
            z = _load_npz_bytes(store.read_bytes(self.name))
            self.set_arrays(z["ins"], z["tombs"])

    def ins(self, store) -> np.ndarray:
        self._load(store)
        return self._ins

    def tombs(self, store) -> np.ndarray:
        self._load(store)
        return self._tombs

    def insert_sources(self, store) -> np.ndarray:
        """Unique source vertex ids this run inserts (Bloom refresh input)."""
        return np.unique(self.ins(store) & 0xFFFFFFFF).astype(np.int64)


class DeltaOverlay:
    """Pending-mutation state of one :class:`~repro.core.storage.ShardStore`."""

    def __init__(self, store):
        self.store = store
        self._lock = threading.Lock()
        self._shard_locks: Dict[int, threading.Lock] = {}
        self._runs: Dict[int, List[DeltaRun]] = {}
        self._floor: Dict[int, int] = {}  # runs <= floor[p] absorbed in base
        self._last_publish: Dict[int, int] = {}  # p -> newest publish seq
        self.version = 0
        self._num_vertices: Optional[int] = None
        # active sweep pins: version -> refcount
        self._pins: Dict[int, int] = {}
        self._pin_cond = threading.Condition(self._lock)
        # shards whose committed compaction is mid-swap: p -> absorbed seq.
        # Recorded in the manifest so recovery can finish the staged
        # renames; empty except inside commit_compaction..clear_stage.
        self._stage: Dict[int, int] = {}
        # Serializes the manifest PROTOCOL sections (publish commit,
        # compaction flip) against each other — a background compaction's
        # manifest write must never clobber a publish's journal-bearing
        # manifest mid-protocol.  Ordering: shard_lock -> _commit_lock ->
        # _lock; _lock is never held while taking either of the others.
        self._commit_lock = threading.Lock()
        self._recover()

    # ------------------------------------------------------------ recovery
    def _recover(self) -> None:
        """Delegate to the recovery state machine (repro.delta.recovery):
        replays a committed publish's metadata journal, finishes a
        committed compaction's staged renames, deletes uncommitted
        runs/journals/staged files, and registers the surviving runs.
        The report is kept on ``self.last_recovery`` — a clean open has
        ``last_recovery.acted == False``."""
        self.last_recovery = _recovery.recover(self)

    def _write_manifest(
        self, *, version: Optional[int] = None, journal: Optional[int] = None
    ) -> None:
        """Write the commit record (atomic tmp+rename).  ``version``
        overrides ``self.version`` (publish commits the new version on disk
        BEFORE making it visible in memory); ``journal`` records a pending
        metadata journal; any active stage records ride along — and the
        written floor folds them in (a stage record MEANS "floor advanced
        to s, renames pending"), while the in-memory floor stays behind
        until :meth:`clear_stage` so live readers keep folding the pending
        runs over the OLD base until the new one is actually in place."""
        floor = dict(self._floor)
        for p, s in self._stage.items():
            floor[p] = max(floor.get(p, 0), s)
        man = {
            "version": self.version if version is None else version,
            "floor": {str(p): s for p, s in floor.items()},
        }
        if self._stage:
            man["stage"] = {str(p): s for p, s in self._stage.items()}
        if journal is not None:
            man["journal"] = journal
        self.store.write_bytes(DELTA_MANIFEST, json.dumps(man).encode())

    # ------------------------------------------------------------- queries
    def shard_lock(self, p: int) -> threading.Lock:
        with self._lock:
            lock = self._shard_locks.get(p)
            if lock is None:
                lock = self._shard_locks[p] = threading.Lock()
            return lock

    def _pending(self, p: int, pin: Optional[int]) -> List[DeltaRun]:
        v = self.version if pin is None else pin
        lo = self._floor.get(p, 0)
        return [r for r in self._runs.get(p, ()) if lo < r.seq <= v]

    def has_pending(self, p: int, pin: Optional[int] = None) -> bool:
        with self._lock:
            return bool(self._pending(p, pin))

    def pending_runs(self, p: int, pin: Optional[int] = None) -> List[DeltaRun]:
        with self._lock:
            return list(self._pending(p, pin))

    def dirty_shards(self) -> List[int]:
        with self._lock:
            return sorted(p for p in self._runs if self._pending(p, None))

    def pending_stats(self, p: int) -> Tuple[int, int, int, int]:
        """(runs, inserts, tombstones, bytes) pending for shard ``p``."""
        runs = self.pending_runs(p)
        for r in runs:
            if r.n_ins < 0:
                r._load(self.store)
        return (
            len(runs),
            sum(r.n_ins for r in runs),
            sum(r.n_tombs for r in runs),
            sum(r.nbytes for r in runs),
        )

    def floors(self) -> Dict[int, int]:
        """Snapshot of the per-shard absorbed-watermark map (shard ->
        highest publish seq folded into its base)."""
        with self._lock:
            return dict(self._floor)

    def last_publish_seq(self, p: int) -> int:
        """Newest publish seq known to have touched shard ``p`` (0 = never;
        absorbed runs forget this after a restart — combine with
        :meth:`floors` for publish evidence across restarts)."""
        with self._lock:
            return self._last_publish.get(p, 0)

    def publishes_since(self, seen_version: int) -> List[int]:
        """Shards touched by any publish after ``seen_version`` (still
        reported after recompaction absorbs the runs — consumers patching
        Bloom/source filters must not miss absorbed inserts)."""
        with self._lock:
            return sorted(
                p for p, s in self._last_publish.items() if s > seen_version
            )

    def pending_insert_sources(self, p: int, pin: Optional[int] = None) -> np.ndarray:
        runs = self.pending_runs(p, pin)
        if not runs:
            return np.empty(0, dtype=np.int64)
        srcs = [r.insert_sources(self.store) for r in runs]
        return np.unique(np.concatenate(srcs))

    # ---------------------------------------------------------------- pins
    def acquire_pin(self) -> int:
        with self._lock:
            v = self.version
            self._pins[v] = self._pins.get(v, 0) + 1
            return v

    def release_pin(self, v: int) -> None:
        with self._lock:
            n = self._pins.get(v, 0) - 1
            if n <= 0:
                self._pins.pop(v, None)
            else:
                self._pins[v] = n
            self._pin_cond.notify_all()

    @contextlib.contextmanager
    def pinned(self):
        v = self.acquire_pin()
        try:
            yield v
        finally:
            self.release_pin(v)

    def wait_pins_below(self, s: int, *, stop: Optional[threading.Event] = None,
                        timeout: float = 0.1) -> bool:
        """Block until no active pin is below ``s`` (so absorbing runs
        ``<= s`` into the base cannot change what a live sweep decodes).
        Returns False if ``stop`` was set while waiting."""
        with self._lock:
            while any(v < s for v in self._pins):
                if stop is not None and stop.is_set():
                    return False
                self._pin_cond.wait(timeout)
        return True

    # ------------------------------------------------------------- decode
    def _num_v(self) -> int:
        if self._num_vertices is None:
            self._num_vertices = self.store.read_meta().num_vertices
        return self._num_vertices

    def logical_keys(self, p: int, pin: Optional[int] = None,
                     *, raw: Optional[bytes] = None) -> np.ndarray:
        """Sorted packed keys of the logical shard at ``pin`` (no locking —
        callers hold :meth:`shard_lock` when racing a compaction swap)."""
        store = self.store
        if raw is None:
            raw = store.shard_bytes(p, "csr")
        keys = keys_of_csr(store.decode_csr(p, raw))
        for r in self.pending_runs(p, pin):
            keys = apply_run(keys, r.tombs(store), r.ins(store))
        return keys

    def load_logical(self, p: int, fmt: str = "csr", *,
                     pin: Optional[int] = None, cache=None):
        """Decode the LOGICAL shard (base + pending runs at ``pin``).

        Returns the ShardCSR / EllShard the consumer would have seen from a
        store whose base already contained the mutations.  The per-shard
        lock makes the (base bytes, applicable runs) pair atomic against a
        concurrent recompaction swap.  When ``cache`` is given it is
        consulted/filled with the base **CSR** container bytes — a shard
        with pending deltas always caches CSR bytes (the only format the
        overlay can merge); the publish/compact invalidation hooks drop the
        entry whenever the shard flips between pending and clean, so one
        cache slot never holds ambiguous bytes.
        """
        store = self.store
        with trace.span("overlay.merge", shard=p) as sp:
            with self.shard_lock(p):
                gen0 = store.shard_generation(p)
                from_cache = False
                raw = cache.get(p) if cache is not None else None
                if raw is not None:
                    from_cache = True
                else:
                    raw = store.shard_bytes(p, "csr")
                    if cache is not None:
                        cache.put(p, raw)
                        if store.shard_generation(p) != gen0:
                            cache.invalidate(p)  # raced with a swap/overwrite
                base = store.decode_csr(p, raw)
                sp.set(runs=len(self.pending_runs(p, pin)), from_cache=from_cache)
                keys = self.logical_keys(p, pin, raw=raw)
            csr = csr_from_keys(p, base.v0, base.v1, keys)
            if fmt == "csr":
                return csr, from_cache
            ep = store.ell_params()
            ell = csr_to_ell(
                csr, self._num_v(),
                window=ep["window"], k=ep["k"], tr=ep["tr"],
            )
            return ell, from_cache

    # --------------------------------------------------------- publication
    def commit_publish(
        self,
        seq: int,
        runs: List[DeltaRun],
        touched: List[int],
        *,
        meta=None,
        journal: Optional[str] = None,
    ) -> None:
        """Commit a published batch (crash-atomic, DESIGN.md §12).

        The caller (``EdgeLog.publish``) has already written the run files
        and the metadata journal ``journal`` (absolute post-publish degree
        rows).  Protocol, under the commit lock:

        1. manifest gains ``{"version": seq, "journal": seq}`` — THE commit
           point.  A crash before this write loses the publish entirely
           (recovery deletes the orphan files); a crash after it keeps the
           publish entirely (recovery replays the journal).
        2. updated metadata ``meta`` is written.  Only now — never before
           the commit — so a crash can no longer leave degree arrays ahead
           of discarded runs (the stale-degree window).
        3. in-memory registration: runs + version become visible.  Deferred
           to here so concurrent readers never see the new version while
           the on-disk metadata still lags it; guaranteed (``finally``)
           even if step 2 raised, because the commit already happened.
        4. the journal ref is cleared from the manifest and the journal
           file removed.

        After the commit the method invalidates decoded/cached copies of
        the touched shards.  Base bytes are unchanged by a publish, so warm
        base-source arrays survive (``drop_warm=False``).

        Raises only for pre-commit failures (the manifest write itself);
        the caller distinguishes via ``overlay.version``: still below
        ``seq`` means nothing committed and the files must be scrubbed.
        """
        with self._commit_lock:
            self._write_manifest(
                version=seq, journal=seq if journal is not None else None
            )
            # committed: everything below must leave a recoverable state
            try:
                crashpoint("publish.committed")
                if meta is not None:
                    self.store.write_meta(meta)
                crashpoint("publish.meta_written")
            finally:
                with self._lock:
                    for r in runs:
                        self._runs.setdefault(r.shard_id, []).append(r)
                        self._last_publish[r.shard_id] = seq
                    self.version = seq
            with self._lock:
                self._write_manifest()
            if journal is not None:
                try:
                    os.remove(self.store._path(journal))
                except OSError:
                    pass
        for p in touched:
            self.store.invalidate_shard(p, drop_warm=False)

    # --------------------------------------------------------- compaction
    def commit_compaction(self, p: int, upto_seq: int) -> None:
        """Atomically flip shard ``p`` to its staged base (DESIGN.md §12):
        ONE manifest write advances the on-disk floor to ``upto_seq`` AND
        records the stage, so recovery either sees neither (old base +
        runs — the compaction never happened) or both (it finishes the
        renames and drops the absorbed runs) — never a floor that advanced
        without its new base, nor pending runs re-applied onto a base that
        already absorbed them.

        In-memory floor/run state is deliberately NOT touched here: until
        the renames land (:meth:`clear_stage`), live readers must keep
        seeing the shard as dirty, so their decodes take the overlay path
        and serialize on the shard lock the compactor holds — a clean-path
        reader checks ``has_pending`` WITHOUT that lock and would otherwise
        read the old base with the runs already dropped.  Caller holds the
        shard lock and has written the staged containers."""
        with self._commit_lock:
            with self._lock:
                self._stage[p] = upto_seq
                self._write_manifest()  # folds the stage into the floor

    def clear_stage(self, p: int, upto_seq: int, runs: List[DeltaRun]) -> None:
        """Staged containers are renamed into place: make the absorption
        visible in memory (floor advance + run pruning), drop the stage
        record, then remove the absorbed run files.  Run-file deletion is
        safe last — recovery deletes runs at or below the manifest floor
        itself."""
        with self._commit_lock:
            with self._lock:
                self._floor[p] = max(self._floor.get(p, 0), upto_seq)
                keep = [r for r in self._runs.get(p, ()) if r.seq > upto_seq]
                if keep:
                    self._runs[p] = keep
                else:
                    self._runs.pop(p, None)
                self._stage.pop(p, None)
                self._write_manifest()
        for r in runs:
            try:
                os.remove(self.store._path(r.name))
            except OSError:
                pass
