"""GraphDelta: live edge mutations over the semi-external-memory store.

The base GraphMP design (paper §II-B) writes immutable destination-interval
shards once; this package makes the shard store *updatable* without ever
breaking the bitwise contract the rest of the system is tested against:

========================  ==================================================
:class:`EdgeLog`          stages insert/delete batches and publishes them as
                          per-shard destination-sorted *delta runs*
                          (``(dst << 32) | src`` keys, deletes as
                          tombstones) through the store's accounted channel.
:class:`DeltaOverlay`     merges base shard + pending runs at decode time,
                          behind ``ShardStore.load_shard`` and the shard
                          pipeline — engines, lane sweeps and executors see
                          one logical shard.  Versioned: sweeps pin the
                          publish sequence they start at and never observe a
                          mixed graph version.
:class:`Recompactor`      background (or synchronous) LSM-style maintenance:
                          k-way-merges pending runs into new base shards,
                          firing the shard-invalidation hooks and refreshing
                          warm Bloom-filter sources.
========================  ==================================================

See DESIGN.md §8 for the delta format, overlay decode, recompaction
triggers and version/snapshot semantics.
"""

from .edgelog import EdgeLog, PublishResult
from .overlay import DeltaOverlay, DeltaRun, apply_run
from .recompact import CompactionStats, Recompactor
from .recovery import CRASH_POINTS, RecoveryReport, recover, set_crash_hook

__all__ = [
    "EdgeLog",
    "PublishResult",
    "DeltaOverlay",
    "DeltaRun",
    "apply_run",
    "CompactionStats",
    "Recompactor",
    "CRASH_POINTS",
    "RecoveryReport",
    "recover",
    "set_crash_hook",
]
