"""EdgeLog: staged edge mutations flushed into per-shard delta runs.

Write side of GraphDelta (DESIGN.md §8).  Callers :meth:`append` batches of
edge inserts/deletes; :meth:`publish` folds every staged batch into AT MOST
one delta run per affected shard and commits them CRASH-atomically
(DESIGN.md §12: run files → metadata journal → one-write manifest commit →
metadata), advancing the overlay version by one.  A crash anywhere leaves
either no trace of the publish or all of it — recovery replays the
journaled metadata of a committed publish and scrubs the files of an
uncommitted one.

Batch semantics (the contract the bitwise tests enforce):

- the logical graph is an edge *multiset* over a FIXED vertex set
  (``0 .. num_vertices``); inserts add one copy (duplicates allowed, as in
  ``preprocess``), deletes remove ALL copies of the named edge (a delete of
  an absent edge is a no-op),
- within one batch deletes apply before inserts,
- batches apply in append order.

The publish fold turns that sequential semantics into a single
``(tombstones, inserts)`` pair per shard: a later batch's delete also
cancels earlier staged inserts of the same edge, and a later batch's insert
survives earlier tombstones because tombstones only ever apply to state
*below* the run's sequence number.  Routing/packing reuses the streamed
ingest machinery (``route_edges`` — destination shard by interval,
``(dst << 32) | src`` keys), so a delta run is "just another sorted run"
for the recompactor's k-way merge.

Degree accounting: deletes must know how many copies they removed, so a
publish with tombstones reads the affected shards' CURRENT logical keys
(base + earlier pending runs) once — O(affected shards), never O(|E|) —
and the updated in/out-degree arrays + edge count are persisted with the
publish, keeping ``GraphMeta`` bitwise-equal to a from-scratch build of
the mutated edge list.
"""

from __future__ import annotations

import dataclasses
import os
import threading
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.ingest import kway_merge, route_edges

from .overlay import DeltaRun, run_name, tombstoned_mask
from .recovery import crashpoint, encode_journal, journal_name

__all__ = ["EdgeLog", "PublishResult"]


@dataclasses.dataclass
class PublishResult:
    """What one publish did: the version it created and its extent."""

    version: int
    batches: int = 0
    edges_inserted: int = 0
    edges_removed: int = 0  # copies actually removed (not tombstones named)
    shards_touched: Tuple[int, ...] = ()
    run_bytes_written: int = 0


def _norm_edges(edges, num_vertices: int, what: str):
    """Accept ``(src, dst)`` array pair or an ``[N, 2]`` array; validate."""
    if edges is None:
        return None
    if isinstance(edges, tuple) and len(edges) == 2:
        src = np.asarray(edges[0], dtype=np.int64).ravel()
        dst = np.asarray(edges[1], dtype=np.int64).ravel()
    else:
        arr = np.asarray(edges, dtype=np.int64)
        if arr.size == 0:
            return None
        if arr.ndim != 2 or arr.shape[1] != 2:
            raise ValueError(f"{what}: expected (src, dst) arrays or [N, 2]")
        src, dst = arr[:, 0], arr[:, 1]
    if src.shape != dst.shape:
        raise ValueError(f"{what}: src/dst length mismatch")
    if len(src) == 0:
        return None
    lo = min(int(src.min()), int(dst.min()))
    hi = max(int(src.max()), int(dst.max()))
    if lo < 0 or hi >= num_vertices:
        raise ValueError(
            f"{what}: vertex id out of range [0, {num_vertices}): "
            f"min={lo} max={hi}"
        )
    return src.astype(np.int32), dst.astype(np.int32)


class EdgeLog:
    """Stage insert/delete batches against a live store and publish them."""

    def __init__(self, store, *, chunk_edges: int = 1 << 20):
        self.store = store
        self.overlay = store.ensure_delta()
        self.chunk_edges = max(1, int(chunk_edges))
        self._staged: List[Tuple] = []  # (ins or None, dels or None)
        self._lock = threading.Lock()
        self._num_vertices = store.read_meta().num_vertices

    # -------------------------------------------------------------- staging
    def append(self, inserts=None, deletes=None) -> int:
        """Stage one mutation batch; returns the staged-batch count.

        ``inserts`` / ``deletes`` are ``(src, dst)`` array pairs (or
        ``[N, 2]`` arrays).  Nothing is visible until :meth:`publish`.
        """
        ins = _norm_edges(inserts, self._num_vertices, "inserts")
        dels = _norm_edges(deletes, self._num_vertices, "deletes")
        with self._lock:
            if ins is not None or dels is not None:
                self._staged.append((ins, dels))
            return len(self._staged)

    @property
    def staged_batches(self) -> int:
        with self._lock:
            return len(self._staged)

    def _route(self, src: np.ndarray, dst: np.ndarray, intervals):
        """Chunked scatter (bounds the argsort working set for big batches)."""
        for lo in range(0, len(src), self.chunk_edges):
            yield from route_edges(
                intervals, src[lo: lo + self.chunk_edges],
                dst[lo: lo + self.chunk_edges],
            )

    # ------------------------------------------------------------- publish
    def publish(self) -> PublishResult:
        """Fold all staged batches into one delta run per affected shard,
        write + commit them, and return the new version."""
        with self._lock:
            staged, self._staged = self._staged, []
        overlay, store = self.overlay, self.store
        if not staged:
            return PublishResult(version=overlay.version)

        meta = store.read_meta()
        intervals = meta.intervals
        tomb_acc = {}  # p -> sorted unique tombstone keys
        ins_acc = {}  # p -> sorted insert keys (multiset)
        for ins, dels in staged:
            if dels is not None:
                for p, keys in self._route(dels[0], dels[1], intervals):
                    t = np.unique(keys)
                    pend = ins_acc.get(p)
                    if pend is not None and len(pend):
                        # this batch's delete removes earlier staged copies
                        ins_acc[p] = pend[~tombstoned_mask(pend, t)]
                    prev = tomb_acc.get(p)
                    tomb_acc[p] = t if prev is None else np.union1d(prev, t)
            if ins is not None:
                for p, keys in self._route(ins[0], ins[1], intervals):
                    ins_acc[p] = kway_merge(
                        [ins_acc.get(p, keys[:0]), np.sort(keys)]
                    )

        touched = sorted(
            p for p in set(tomb_acc) | set(ins_acc)
            if len(tomb_acc.get(p, ())) or len(ins_acc.get(p, ()))
        )
        if not touched:
            # every staged batch cancelled out — nothing becomes visible
            return PublishResult(version=overlay.version, batches=len(staged))

        seq = overlay.version + 1
        runs: List[DeltaRun] = []
        added_total = removed_total = run_bytes = 0
        empty = np.empty(0, dtype=np.int64)
        vid_parts: List[np.ndarray] = []  # endpoints whose degrees change
        try:
            first_run = True
            for p in touched:
                tombs = tomb_acc.get(p, empty)
                ins = ins_acc.get(p, empty)
                removed = empty
                if len(tombs):
                    # exact removed multiplicities need current logical keys
                    with overlay.shard_lock(p):
                        cur = overlay.logical_keys(p)
                    removed = cur[tombstoned_mask(cur, tombs)]
                for arr, sign in ((ins, 1), (removed, -1)):
                    if len(arr):
                        np.add.at(meta.out_deg, arr & 0xFFFFFFFF, sign)
                        np.add.at(meta.in_deg, arr >> 32, sign)
                        vid_parts.append(arr & 0xFFFFFFFF)
                        vid_parts.append(arr >> 32)
                added_total += len(ins)
                removed_total += len(removed)
                raw = DeltaRun.encode(ins, tombs)
                name = run_name(p, seq)
                store.write_bytes(name, raw)
                if first_run:
                    crashpoint("publish.first_run")
                    first_run = False
                run_bytes += len(raw)
                run = DeltaRun(p, seq, name, nbytes=len(raw))
                run.set_arrays(ins, tombs)
                runs.append(run)
            crashpoint("publish.runs_written")

            # Metadata journal (DESIGN.md §12): ABSOLUTE post-publish degree
            # rows for every touched vertex + the new edge count, durable
            # BEFORE the manifest commit.  Replay at recovery is idempotent,
            # so a crash anywhere after the commit still converges to the
            # published metadata.
            meta.num_edges += added_total - removed_total
            vids = (
                np.unique(np.concatenate(vid_parts)).astype(np.int64)
                if vid_parts else empty
            )
            journal = journal_name(seq)
            store.write_bytes(journal, encode_journal(meta, vids, meta.num_edges))
            crashpoint("publish.journal_written")

            # One atomic manifest write commits the publish; metadata is
            # applied AFTER it (stale-degree window closed), and only a
            # committed publish bumps overlay.version.
            overlay.commit_publish(seq, runs, touched, meta=meta, journal=journal)
        except BaseException:
            if overlay.version < seq:
                # Not committed: nothing became visible, but files written
                # at ``seq`` must not linger — a LATER successful publish
                # commits the same seq, and recovery would then legitimize
                # these orphans as published runs.  Scrub by NAME for every
                # touched shard (not just registered DeltaRuns — a write
                # that raised after landing its file never registered one)
                # plus the journal.
                for name in [run_name(p, seq) for p in touched] + [journal_name(seq)]:
                    try:
                        os.remove(store._path(name))
                    except OSError:
                        pass
            raise
        return PublishResult(
            version=seq,
            batches=len(staged),
            edges_inserted=added_total,
            edges_removed=removed_total,
            shards_touched=tuple(touched),
            run_bytes_written=run_bytes,
        )
