"""GraphPulse exporters: Prometheus text exposition + JSONL time series.

Two formats, two audiences:

- :func:`prometheus_text` renders a :class:`MetricsRegistry` (or a
  pre-taken ``snapshot()`` dict) in the Prometheus text exposition format
  (version 0.0.4): counters and gauges as single samples, histograms as
  summaries (``{quantile="0.5|0.95|0.99"}`` plus ``_sum`` / ``_count``).
  Instrument names are namespaced and sanitized (``query.latency_s`` ->
  ``graphmp_query_latency_s``), so the output drops straight into a
  Prometheus scrape or ``promtool check metrics``.
- :func:`jsonl_lines` / :func:`write_jsonl` flatten a
  :class:`~repro.obs.timeseries.TimeSeriesRegistry` ring into one JSON
  object per line, one line per closed window — the consolidated-bench
  and offline-analysis format (every line parses independently, files
  append across runs).

Both have round-trip parsers (:func:`parse_prometheus`,
:func:`read_jsonl`) used by the test suite and the ``fig_qps`` benchmark
to prove the exports are machine-readable, not just printable.
"""

from __future__ import annotations

import json
import re
from typing import Any, Dict, Iterable, Iterator, List, Union

from .metrics import Histogram, MetricsRegistry
from .timeseries import TimeSeriesRegistry, WindowSample

__all__ = [
    "prometheus_text",
    "parse_prometheus",
    "jsonl_lines",
    "write_jsonl",
    "read_jsonl",
]

_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")
_QUANTS = ((0.5, "0.5"), (0.95, "0.95"), (0.99, "0.99"))


def _metric_name(namespace: str, name: str) -> str:
    out = _NAME_RE.sub("_", f"{namespace}_{name}" if namespace else name)
    if out and out[0].isdigit():
        out = "_" + out
    return out


def _fmt(v: float) -> str:
    """Prometheus sample value: plain float, inf/nan spelled out."""
    if v != v:
        return "NaN"
    if v == float("inf"):
        return "+Inf"
    if v == float("-inf"):
        return "-Inf"
    return repr(float(v))


def prometheus_text(
    source: Union[MetricsRegistry, Dict[str, Any]],
    *,
    namespace: str = "graphmp",
) -> str:
    """Render instruments in the Prometheus text exposition format."""
    lines: List[str] = []
    if isinstance(source, MetricsRegistry):
        items = sorted(source.instruments().items())
        for name, inst in items:
            mname = _metric_name(namespace, name)
            if isinstance(inst, Histogram):
                lines.append(f"# TYPE {mname} summary")
                for q, label in _QUANTS:
                    lines.append(
                        f'{mname}{{quantile="{label}"}} {_fmt(inst.quantile(q))}'
                    )
                lines.append(f"{mname}_sum {_fmt(inst.total)}")
                lines.append(f"{mname}_count {_fmt(inst.count)}")
            else:
                kind = "gauge" if type(inst).__name__ == "Gauge" else "counter"
                lines.append(f"# TYPE {mname} {kind}")
                lines.append(f"{mname} {_fmt(inst.value)}")
        return "\n".join(lines) + "\n"
    # a snapshot() dict: histograms appear as percentile blocks
    pct_key = {"0.5": "p50", "0.95": "p95", "0.99": "p99"}
    for name, val in sorted(source.items()):
        mname = _metric_name(namespace, name)
        if isinstance(val, dict):
            lines.append(f"# TYPE {mname} summary")
            for q, label in _QUANTS:
                lines.append(
                    f'{mname}{{quantile="{label}"}} '
                    f"{_fmt(val.get(pct_key[label], 0.0))}"
                )
            mean = float(val.get("mean", 0.0))
            count = float(val.get("count", 0))
            lines.append(f"{mname}_sum {_fmt(mean * count)}")
            lines.append(f"{mname}_count {_fmt(count)}")
        else:
            lines.append(f"# TYPE {mname} untyped")
            lines.append(f"{mname} {_fmt(float(val))}")
    return "\n".join(lines) + "\n"


_SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>[^}]*)\})?\s+(?P<value>\S+)$"
)


def parse_prometheus(text: str) -> Dict[str, float]:
    """Parse exposition text back to ``{name{labels}: value}`` samples.

    A validating round-trip for tests/benchmarks: raises ``ValueError`` on
    any line that is neither a comment nor a well-formed sample.
    """
    out: Dict[str, float] = {}
    for lineno, line in enumerate(text.splitlines(), 1):
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        m = _SAMPLE_RE.match(line)
        if m is None:
            raise ValueError(f"line {lineno}: not a prometheus sample: {line!r}")
        key = m.group("name")
        if m.group("labels"):
            key += "{" + m.group("labels") + "}"
        v = m.group("value")
        out[key] = float("inf") if v == "+Inf" else (
            float("-inf") if v == "-Inf" else float(v)
        )
    return out


# --------------------------------------------------------------- JSONL side


def _sample_doc(s: WindowSample) -> Dict[str, Any]:
    return {
        "index": s.index,
        "wall_ts": s.wall_ts,
        "duration_s": s.duration_s,
        "counters": dict(s.counters),
        "gauges": dict(s.gauges),
        "histograms": {k: w.percentiles() for k, w in s.histograms.items()},
    }


def jsonl_lines(
    ts: Union[TimeSeriesRegistry, Iterable[WindowSample]]
) -> Iterator[str]:
    """One compact JSON object per closed window, oldest first."""
    samples = ts.samples() if isinstance(ts, TimeSeriesRegistry) else ts
    for s in samples:
        yield json.dumps(_sample_doc(s), separators=(",", ":"))


def write_jsonl(
    path: str,
    ts: Union[TimeSeriesRegistry, Iterable[WindowSample]],
    *,
    append: bool = False,
) -> int:
    """Write the ring as JSONL; returns the number of lines written."""
    n = 0
    with open(path, "a" if append else "w") as f:
        for line in jsonl_lines(ts):
            f.write(line + "\n")
            n += 1
    return n


def read_jsonl(path: str) -> List[Dict[str, Any]]:
    """Parse a JSONL export back to window dicts (validates every line)."""
    out: List[Dict[str, Any]] = []
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            doc = json.loads(line)
            for req in ("index", "wall_ts", "duration_s", "counters",
                        "gauges", "histograms"):
                if req not in doc:
                    raise ValueError(
                        f"{path}:{lineno}: window missing {req!r}"
                    )
            out.append(doc)
    return out
