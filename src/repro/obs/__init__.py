"""GraphScope: unified tracing + metrics for the VSW stack (DESIGN.md §11).

Two pieces:

- :mod:`repro.obs.trace` — structured tracer with nestable spans on
  lock-free per-thread ring buffers, exporting Chrome-trace/Perfetto JSON.
  Disabled (the default) it is a guard-flag no-op.
- :mod:`repro.obs.metrics` — typed Counter/Gauge/Histogram instruments,
  a :class:`MetricsRegistry` that absorbs the stack's nine stats
  dataclasses, and one shared ``verify_conservation()``.
"""

from .metrics import (
    ConservationError,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
)
from .trace import (
    NULL_SPAN,
    Span,
    Tracer,
    active,
    counter,
    install,
    instant,
    span,
    tracing,
    uninstall,
)

__all__ = [
    "ConservationError",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "NULL_SPAN",
    "Span",
    "Tracer",
    "active",
    "counter",
    "install",
    "instant",
    "span",
    "tracing",
    "uninstall",
]
