"""GraphScope + GraphPulse: tracing, metrics, and time-series telemetry.

Four pieces (DESIGN.md §11, §13):

- :mod:`repro.obs.trace` — structured tracer with nestable spans on
  lock-free per-thread ring buffers, exporting Chrome-trace/Perfetto JSON.
  Disabled (the default) it is a guard-flag no-op.
- :mod:`repro.obs.metrics` — typed Counter/Gauge/Histogram instruments,
  a :class:`MetricsRegistry` that absorbs the stack's nine stats
  dataclasses, one shared ``verify_conservation()``, and windowed
  histogram snapshots (:class:`HistogramWindow`).
- :mod:`repro.obs.timeseries` — :class:`TimeSeriesRegistry`: cadenced
  windowed snapshots of a registry into a bounded ring (counters diffed,
  histograms logically reset-on-window).
- :mod:`repro.obs.slo` — declared objectives evaluated as multi-window
  burn rates over the ring, emitting typed :class:`SLOViolation` records;
  :mod:`repro.obs.export` renders Prometheus text exposition and JSONL
  time series.
"""

from .export import (
    jsonl_lines,
    parse_prometheus,
    prometheus_text,
    read_jsonl,
    write_jsonl,
)
from .metrics import (
    ConservationError,
    Counter,
    Gauge,
    Histogram,
    HistogramState,
    HistogramWindow,
    MetricsRegistry,
)
from .slo import (
    SLO,
    SLOMonitor,
    SLOViolation,
    error_rate_slo,
    latency_slo,
    share_slo,
)
from .timeseries import MergedWindow, TimeSeriesRegistry, WindowSample
from .trace import (
    NULL_SPAN,
    Span,
    Tracer,
    active,
    counter,
    dropped_events,
    install,
    instant,
    publish_drops,
    span,
    tracing,
    uninstall,
)

__all__ = [
    "ConservationError",
    "Counter",
    "Gauge",
    "Histogram",
    "HistogramState",
    "HistogramWindow",
    "MetricsRegistry",
    "MergedWindow",
    "TimeSeriesRegistry",
    "WindowSample",
    "SLO",
    "SLOMonitor",
    "SLOViolation",
    "latency_slo",
    "error_rate_slo",
    "share_slo",
    "prometheus_text",
    "parse_prometheus",
    "jsonl_lines",
    "write_jsonl",
    "read_jsonl",
    "NULL_SPAN",
    "Span",
    "Tracer",
    "active",
    "counter",
    "dropped_events",
    "install",
    "instant",
    "publish_drops",
    "span",
    "tracing",
    "uninstall",
]
