"""GraphPulse time series: cadenced *windowed* views of a MetricsRegistry.

:class:`~repro.obs.metrics.MetricsRegistry` instruments accumulate for the
lifetime of the service — exactly right for conservation identities, wrong
for operating a service, where "p99 latency" must mean *p99 over the last
few seconds*, not since boot.  :class:`TimeSeriesRegistry` closes that gap:
``tick()`` snapshots the registry into a :class:`WindowSample` —

- **counters** are diffed against the previous tick's marks, so each
  sample carries the per-window increment (and ``rate()`` divides by the
  window duration);
- **histograms** are logically reset-on-window via
  :meth:`~repro.obs.metrics.Histogram.window_since` bucket diffs — the
  live histogram keeps its lifetime data, the sample sees only the
  window's records;
- **gauges** are sampled as-is (they are already point-in-time).

Samples land in a bounded ring (``capacity`` windows), so a long-lived
service holds O(capacity) telemetry regardless of uptime.  ``start()``
runs the tick loop on a daemon thread at ``interval_s`` cadence;
``tick()`` may equally be driven by an external clock (tests drive it
manually, :meth:`repro.serve.service.GraphService.start_telemetry` owns
the thread in production).

Window-delta conservation: for every counter, the sum of all window
deltas ever emitted plus the current mark equals the live counter value —
``test_pulse.py`` asserts this while a fused workload is mid-sweep, which
is the torn-read guard for concurrent ticks.

:class:`~repro.obs.slo.SLOMonitor` consumes the ring via :meth:`merged`,
which folds the last-``T``-seconds of samples into one
:class:`~repro.obs.metrics.HistogramWindow` per histogram (plus summed
counter deltas) — the long/short windows of multi-window burn-rate
evaluation are re-aggregations of the same ring, not separate collectors.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Dict, List, Mapping, Optional, Tuple

from .metrics import Gauge, Histogram, HistogramState, HistogramWindow, MetricsRegistry

__all__ = ["TimeSeriesRegistry", "WindowSample", "MergedWindow"]


@dataclasses.dataclass(frozen=True)
class WindowSample:
    """One closed telemetry window: deltas since the previous tick."""

    index: int
    t_start: float  # perf_counter seconds (monotonic, same clock as t_end)
    t_end: float
    wall_ts: float  # time.time() at window close, for export timestamps
    counters: Mapping[str, float]  # per-window increments
    gauges: Mapping[str, float]  # point-in-time values at window close
    histograms: Mapping[str, HistogramWindow]  # per-window sample sets

    @property
    def duration_s(self) -> float:
        return max(self.t_end - self.t_start, 0.0)

    def rate(self, name: str) -> float:
        """Per-second rate of one counter over this window (0 if absent)."""
        dur = self.duration_s
        return self.counters.get(name, 0.0) / dur if dur > 0 else 0.0


@dataclasses.dataclass(frozen=True)
class MergedWindow:
    """Several consecutive samples folded into one evaluation window."""

    t_start: float
    t_end: float
    samples: int
    counters: Dict[str, float]
    histograms: Dict[str, HistogramWindow]

    @property
    def duration_s(self) -> float:
        return max(self.t_end - self.t_start, 0.0)


class TimeSeriesRegistry:
    """Bounded ring of windowed MetricsRegistry snapshots.

    Thread-safety: ``tick()`` is serialized by an internal lock and safe to
    call while worker threads are recording into the registry — counter
    float reads are atomic under the GIL and histogram state copies take
    the histogram's own lock, so a window can straddle a recording but
    never tear one.
    """

    def __init__(self, registry: MetricsRegistry, *, capacity: int = 1024,
                 interval_s: float = 0.5):
        if capacity <= 0:
            raise ValueError("time-series capacity must be positive")
        if interval_s <= 0:
            raise ValueError("tick interval must be positive")
        self.registry = registry
        self.capacity = int(capacity)
        self.interval_s = float(interval_s)
        self._samples: "deque[WindowSample]" = deque(maxlen=self.capacity)
        self._counter_marks: Dict[str, float] = {}
        self._hist_marks: Dict[str, HistogramState] = {}
        self._lock = threading.Lock()
        self._t_mark = time.perf_counter()
        self._index = 0
        self._dropped = 0  # samples evicted from the ring
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # ------------------------------------------------------------- ticking
    def tick(self) -> WindowSample:
        """Close the current window: diff counters, window histograms."""
        with self._lock:
            t_end = time.perf_counter()
            counters: Dict[str, float] = {}
            gauges: Dict[str, float] = {}
            hists: Dict[str, HistogramWindow] = {}
            for name, inst in self.registry.instruments().items():
                if isinstance(inst, Histogram):
                    win = inst.window_since(self._hist_marks.get(name))
                    hists[name] = win
                    self._hist_marks[name] = inst.state()
                elif isinstance(inst, Gauge):
                    gauges[name] = inst.value
                else:  # Counter
                    v = float(inst.value)
                    prev = self._counter_marks.get(name, 0.0)
                    # Monotonic by construction; clamp defensively so a
                    # replaced instrument can never emit a negative window.
                    counters[name] = max(v - prev, 0.0)
                    self._counter_marks[name] = v
            sample = WindowSample(
                index=self._index,
                t_start=self._t_mark,
                t_end=t_end,
                wall_ts=time.time(),
                counters=counters,
                gauges=gauges,
                histograms=hists,
            )
            self._index += 1
            self._t_mark = t_end
            if len(self._samples) == self.capacity:
                self._dropped += 1
            self._samples.append(sample)
            return sample

    # ------------------------------------------------------------ querying
    def samples(self) -> List[WindowSample]:
        with self._lock:
            return list(self._samples)

    def latest(self) -> Optional[WindowSample]:
        with self._lock:
            return self._samples[-1] if self._samples else None

    @property
    def num_windows(self) -> int:
        """Windows ever closed (>= len(samples()) once the ring wraps)."""
        with self._lock:
            return self._index

    @property
    def dropped_samples(self) -> int:
        with self._lock:
            return self._dropped

    def series(self, name: str) -> List[Tuple[float, float]]:
        """(wall_ts, value) pairs for one counter (window deltas) or gauge."""
        out = []
        for s in self.samples():
            if name in s.counters:
                out.append((s.wall_ts, s.counters[name]))
            elif name in s.gauges:
                out.append((s.wall_ts, s.gauges[name]))
        return out

    def merged(self, last_s: float) -> MergedWindow:
        """Fold the samples whose windows END within the last ``last_s``
        seconds into one evaluation window (SLO burn-rate input).  Returns
        an empty window when no sample qualifies."""
        now = time.perf_counter()
        picked = [s for s in self.samples() if now - s.t_end <= last_s]
        if not picked:
            return MergedWindow(t_start=now, t_end=now, samples=0,
                                counters={}, histograms={})
        counters: Dict[str, float] = {}
        hists: Dict[str, HistogramWindow] = {}
        for s in picked:
            for k, v in s.counters.items():
                counters[k] = counters.get(k, 0.0) + v
            for k, w in s.histograms.items():
                hists[k] = hists[k].merge(w) if k in hists else w
        return MergedWindow(
            t_start=picked[0].t_start,
            t_end=picked[-1].t_end,
            samples=len(picked),
            counters=counters,
            histograms=hists,
        )

    # ----------------------------------------------------- background loop
    def start(self) -> "TimeSeriesRegistry":
        """Tick on a daemon thread every ``interval_s`` until ``stop()``."""
        if self._thread is not None:
            raise RuntimeError("time-series ticker already running")
        self._stop.clear()

        def loop() -> None:
            while not self._stop.wait(self.interval_s):
                self.tick()

        self._thread = threading.Thread(
            target=loop, name="graphpulse-ticker", daemon=True
        )
        self._thread.start()
        return self

    def stop(self, *, final_tick: bool = True) -> None:
        """Stop the ticker (idempotent); optionally close a last window so
        the tail of the run is never lost to cadence truncation."""
        th, self._thread = self._thread, None
        if th is not None:
            self._stop.set()
            th.join()
            if final_tick:
                self.tick()

    def __enter__(self) -> "TimeSeriesRegistry":
        return self

    def __exit__(self, *exc) -> None:
        self.stop()
