"""SLO objectives evaluated as multi-window burn rates over GraphPulse.

An SLO here is "at most a ``budget`` fraction of the service's traffic may
be *bad*", with three notions of bad (matching what the serving stack can
actually measure from :class:`~repro.obs.metrics.MetricsRegistry`):

``latency``
    A query is bad when its latency exceeds ``threshold_s``.  The bad
    fraction comes from :meth:`HistogramWindow.fraction_above` on the
    windowed latency histogram — e.g. budget 0.01 + threshold 50 ms reads
    "p99 latency <= 50 ms".
``error_rate``
    Bad = the window's increments of ``bad_counters`` (rejections, shard
    load failures); total = increments of ``total_counters``.
``share``
    A *time* share instead of an event share: windowed
    ``sum(num_hist) / sum(den_hist)`` must stay under ``budget`` — e.g.
    queue-wait seconds as a share of total latency seconds.

Burn rate = measured bad fraction / budget: 1.0 means the error budget is
being consumed exactly at the sustainable pace, ``k`` means ``k``-times
too fast.  Following the multi-window SRE discipline, a violation fires
only when BOTH a long window and its paired short window burn at >=
``factor`` — the long window filters blips, the short window proves the
problem is still live (so old incidents cannot page forever).  Windows
are re-aggregations of the :class:`~repro.obs.timeseries.TimeSeriesRegistry`
ring via :meth:`~repro.obs.timeseries.TimeSeriesRegistry.merged`.

Violations are typed :class:`SLOViolation` records: kept on the monitor
(bounded), counted in the registry (``slo.violations``), and surfaced by
``GraphService.metrics_snapshot()``.  Evaluation is edge-triggered per
(objective, window pair): a condition that stays bad emits ONE record
until it recovers and trips again.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Dict, List, Optional, Sequence, Tuple

from .timeseries import MergedWindow, TimeSeriesRegistry

__all__ = [
    "SLO",
    "SLOMonitor",
    "SLOViolation",
    "latency_slo",
    "error_rate_slo",
    "share_slo",
    "DEFAULT_WINDOWS",
]

#: (long_s, short_s, burn factor) pairs.  The classic SRE 1h/5m + 6h/30m
#: alerts scaled to single-process bench runs: a sustained burn over tens
#: of seconds, confirmed live over the last few.
DEFAULT_WINDOWS: Tuple[Tuple[float, float, float], ...] = (
    (30.0, 5.0, 2.0),
    (120.0, 10.0, 1.0),
)


@dataclasses.dataclass(frozen=True)
class SLO:
    """One declared objective (see module docstring for the kinds)."""

    name: str
    kind: str  # "latency" | "error_rate" | "share"
    budget: float  # allowed bad fraction, in (0, 1]
    threshold_s: float = 0.0  # latency kind: the per-query latency bound
    hist: str = "query.latency_s"  # latency kind: windowed histogram name
    bad_counters: Tuple[str, ...] = ()  # error_rate kind
    total_counters: Tuple[str, ...] = ()  # error_rate kind
    num_hist: str = ""  # share kind: numerator time histogram
    den_hist: str = ""  # share kind: denominator time histogram
    min_events: int = 10  # below this many window events: not evaluated

    def __post_init__(self) -> None:
        if not 0.0 < self.budget <= 1.0:
            raise ValueError(f"SLO {self.name}: budget must be in (0, 1]")
        if self.kind not in ("latency", "error_rate", "share"):
            raise ValueError(f"SLO {self.name}: unknown kind {self.kind!r}")

    # -- measurement -------------------------------------------------------

    def bad_fraction(self, w: MergedWindow) -> Optional[float]:
        """Measured bad fraction over one merged window; None = not enough
        data to evaluate (too few events — never a violation)."""
        if self.kind == "latency":
            h = w.histograms.get(self.hist)
            if h is None or h.count < self.min_events:
                return None
            return h.fraction_above(self.threshold_s)
        if self.kind == "error_rate":
            bad = sum(w.counters.get(c, 0.0) for c in self.bad_counters)
            total = sum(w.counters.get(c, 0.0) for c in self.total_counters)
            if total < self.min_events:
                return None
            return bad / total
        num = w.histograms.get(self.num_hist)
        den = w.histograms.get(self.den_hist)
        if den is None or den.count < self.min_events or den.total <= 0.0:
            return None
        return (num.total if num is not None else 0.0) / den.total

    def burn_rate(self, w: MergedWindow) -> Optional[float]:
        frac = self.bad_fraction(w)
        return None if frac is None else frac / self.budget


def latency_slo(name: str, *, threshold_s: float, budget: float = 0.01,
                hist: str = "query.latency_s", min_events: int = 10) -> SLO:
    """"All but ``budget`` of queries answer within ``threshold_s``"."""
    return SLO(name=name, kind="latency", budget=budget,
               threshold_s=threshold_s, hist=hist, min_events=min_events)


def error_rate_slo(
    name: str, *, budget: float = 0.01,
    bad: Sequence[str] = ("query.rejected", "shard.load_error"),
    total: Sequence[str] = ("query.completed", "query.rejected"),
    min_events: int = 10,
) -> SLO:
    """"At most ``budget`` of admissions end in rejection or error"."""
    return SLO(name=name, kind="error_rate", budget=budget,
               bad_counters=tuple(bad), total_counters=tuple(total),
               min_events=min_events)


def share_slo(name: str, *, budget: float,
              num_hist: str = "query.queue_wait_s",
              den_hist: str = "query.latency_s", min_events: int = 10) -> SLO:
    """"``num_hist`` time stays under a ``budget`` share of ``den_hist``"."""
    return SLO(name=name, kind="share", budget=budget, num_hist=num_hist,
               den_hist=den_hist, min_events=min_events)


@dataclasses.dataclass(frozen=True)
class SLOViolation:
    """One edge-triggered burn-rate trip (typed, export-friendly)."""

    slo: str
    kind: str
    wall_ts: float
    long_s: float
    short_s: float
    factor: float  # the burn factor this window pair alerts at
    burn_long: float
    burn_short: float
    bad_fraction: float  # measured over the long window
    budget: float
    threshold_s: float

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


class SLOMonitor:
    """Evaluates declared objectives over a time-series ring.

    ``evaluate()`` is meant to be called once per telemetry tick (the
    service's cadence thread does); each call re-derives every
    (objective, window-pair) burn rate from the ring and emits new
    :class:`SLOViolation` records on rising edges.  All mutation happens
    on the calling thread; readers get copies.
    """

    def __init__(
        self,
        timeseries: TimeSeriesRegistry,
        slos: Sequence[SLO],
        *,
        windows: Sequence[Tuple[float, float, float]] = DEFAULT_WINDOWS,
        max_records: int = 1024,
    ):
        names = [s.name for s in slos]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate SLO names: {names}")
        self.timeseries = timeseries
        self.slos: Tuple[SLO, ...] = tuple(slos)
        self.windows: Tuple[Tuple[float, float, float], ...] = tuple(
            (float(l), float(s), float(f)) for l, s, f in windows
        )
        for long_s, short_s, _ in self.windows:
            if short_s > long_s:
                raise ValueError(
                    f"short window {short_s}s exceeds long window {long_s}s"
                )
        self._records: "deque[SLOViolation]" = deque(maxlen=max_records)
        self._active: set = set()  # (slo.name, long_s) currently tripped
        self._evaluations = 0
        # last-computed burn rates, keyed (slo.name, long_s) -> (long, short)
        self._burns: Dict[Tuple[str, float], Tuple[Optional[float], Optional[float]]] = {}

    def evaluate(self, *, wall_ts: Optional[float] = None) -> List[SLOViolation]:
        """One evaluation pass; returns only the NEW violations."""
        wall_ts = time.time() if wall_ts is None else wall_ts
        self._evaluations += 1
        merged: Dict[float, MergedWindow] = {}
        for long_s, short_s, _ in self.windows:
            for w in (long_s, short_s):
                if w not in merged:
                    merged[w] = self.timeseries.merged(w)
        new: List[SLOViolation] = []
        for slo in self.slos:
            for long_s, short_s, factor in self.windows:
                burn_long = slo.burn_rate(merged[long_s])
                burn_short = slo.burn_rate(merged[short_s])
                self._burns[(slo.name, long_s)] = (burn_long, burn_short)
                tripped = (
                    burn_long is not None
                    and burn_short is not None
                    and burn_long >= factor
                    and burn_short >= factor
                )
                key = (slo.name, long_s)
                if tripped and key not in self._active:
                    self._active.add(key)
                    v = SLOViolation(
                        slo=slo.name,
                        kind=slo.kind,
                        wall_ts=wall_ts,
                        long_s=long_s,
                        short_s=short_s,
                        factor=factor,
                        burn_long=burn_long,
                        burn_short=burn_short,
                        bad_fraction=burn_long * slo.budget,
                        budget=slo.budget,
                        threshold_s=slo.threshold_s,
                    )
                    self._records.append(v)
                    new.append(v)
                    self.timeseries.registry.counter("slo.violations").add(1)
                elif not tripped and key in self._active:
                    self._active.discard(key)
        return new

    # -- introspection -----------------------------------------------------

    @property
    def violations(self) -> List[SLOViolation]:
        return list(self._records)

    def snapshot(self) -> Dict[str, Any]:
        """The block ``GraphService.metrics_snapshot()`` embeds."""
        objectives = []
        for slo in self.slos:
            burns = {}
            for long_s, short_s, factor in self.windows:
                bl, bs = self._burns.get((slo.name, long_s), (None, None))
                burns[f"{long_s:g}s/{short_s:g}s"] = {
                    "factor": factor,
                    "burn_long": bl,
                    "burn_short": bs,
                }
            objectives.append({
                "name": slo.name,
                "kind": slo.kind,
                "budget": slo.budget,
                "threshold_s": slo.threshold_s,
                "burn_rates": burns,
            })
        return {
            "objectives": objectives,
            "evaluations": self._evaluations,
            "violations": [v.to_dict() for v in self._records],
            "active": sorted(f"{n}@{w:g}s" for n, w in self._active),
        }
