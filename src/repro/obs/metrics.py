"""GraphScope metrics: typed instruments + conservation checking.

The engine historically grew nine disconnected stats dataclasses
(``IOStats``, ``CacheStats``, ``PipelineStats``, ``ExecStats``,
``IterStats``, ``SweepIterStats``, ``IngestStats``, ``CompactionStats``,
``CollectiveStats``), each with its own ad-hoc conservation sums scattered
across tests and benchmarks. :class:`MetricsRegistry` absorbs any of them
via :meth:`MetricsRegistry.ingest` into namespaced typed instruments
(:class:`Counter` / :class:`Gauge` / :class:`Histogram`) and — crucially —
*declares the class's conservation invariants at ingest time* so one shared
:meth:`MetricsRegistry.verify_conservation` replaces the per-test sums:

=================  ======================================================
class              invariants declared on ingest
=================  ======================================================
IOStats            reads==0 -> bytes_read==0 (and same for writes)
CacheStats         counters non-negative
PipelineStats      cache_hits + resident_hits <= shards_loaded
ExecStats          sum(device_shards.values()) == shards_executed,
                   sum(device_dispatches.values()) == dispatches
IterStats          shards_processed + shards_skipped == shards_total,
                   sum(device_shards) == shards_processed,
                   sum(device_bytes) == bytes_read,
                   sum(device_dispatches) == dispatches
SweepIterStats     same device conservation as IterStats
IngestStats        spill + shard + meta == bytes_written_total,
                   spill bytes read back exactly once
CompactionStats    counters non-negative
CollectiveStats    total_bytes == sum(bytes_by_kind.values())
=================  ======================================================

Adapters dispatch on ``type(obj).__name__`` so this module never imports
the core/serve/delta packages (which import *us* for tracing).

Histograms are fixed log-bucket streaming estimators: ~7% bucket growth
gives ≲3.5% relative quantile error at O(1) memory, enough for the
p50/p95/p99 tail-latency numbers ``GraphService.metrics_snapshot()``
surfaces into ``BENCH_graphmp.json``.
"""

from __future__ import annotations

import math
import threading
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Tuple

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "HistogramState",
    "HistogramWindow",
    "MetricsRegistry",
    "ConservationError",
]

#: log-bucket growth factor; quantile relative error ~ sqrt(growth) - 1.
_GROWTH = 1.07
_LOG_GROWTH = math.log(_GROWTH)


class ConservationError(AssertionError):
    """Raised by verify_conservation(strict=True) with all violations."""


class Counter:
    """Monotonic accumulator."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def add(self, v: float) -> None:
        if v < 0:
            raise ValueError(f"counter {self.name}: negative increment {v}")
        self.value += v


class Gauge:
    """Last-value instrument."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Streaming log-bucket histogram with quantile extraction.

    Values are bucketed at ``floor(log(x) / log(1.07))`` into a sparse dict;
    exact min/max/sum are kept so extreme quantiles clamp to observed
    bounds. Thread-safe (one small lock per record — this sits on serving
    control paths, never per-edge paths).
    """

    __slots__ = ("name", "_buckets", "count", "total", "min", "max", "zeros", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._buckets: Dict[int, int] = {}
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf
        self.zeros = 0  # values <= 0 (clock jitter can yield 0.0 durations)
        self._lock = threading.Lock()

    def record(self, x: float) -> None:
        with self._lock:
            self.count += 1
            self.total += x
            if x < self.min:
                self.min = x
            if x > self.max:
                self.max = x
            if x <= 0.0:
                self.zeros += 1
                return
            idx = int(math.floor(math.log(x) / _LOG_GROWTH))
            self._buckets[idx] = self._buckets.get(idx, 0) + 1

    def merge(self, other: "Histogram") -> None:
        with self._lock:
            self.count += other.count
            self.total += other.total
            self.min = min(self.min, other.min)
            self.max = max(self.max, other.max)
            self.zeros += other.zeros
            for idx, n in other._buckets.items():
                self._buckets[idx] = self._buckets.get(idx, 0) + n

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def quantile(self, q: float) -> float:
        """Estimated q-quantile (q in [0, 1]); 0.0 when empty."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile {q} outside [0, 1]")
        with self._lock:
            if self.count == 0:
                return 0.0
            rank = q * self.count
            if rank <= self.zeros:
                return max(0.0, min(self.min, 0.0))
            cum = self.zeros
            for idx in sorted(self._buckets):
                cum += self._buckets[idx]
                if cum >= rank:
                    mid = math.exp((idx + 0.5) * _LOG_GROWTH)
                    return min(max(mid, self.min), self.max)
            return self.max

    def percentiles(self) -> Dict[str, float]:
        """The standard snapshot block: count/mean/p50/p95/p99/max."""
        return {
            "count": self.count,
            "mean": self.mean,
            "p50": self.quantile(0.50),
            "p95": self.quantile(0.95),
            "p99": self.quantile(0.99),
            "min": self.min if self.count else 0.0,
            "max": self.max if self.count else 0.0,
        }

    # -- windowing (GraphPulse, DESIGN.md §13) -----------------------------

    def reset(self) -> None:
        """Clear all recorded samples (hard reset-on-window semantics)."""
        with self._lock:
            self._buckets.clear()
            self.count = 0
            self.total = 0.0
            self.min = math.inf
            self.max = -math.inf
            self.zeros = 0

    def state(self) -> "HistogramState":
        """Immutable cumulative snapshot, cheap to keep as a window mark."""
        with self._lock:
            return HistogramState(
                buckets=dict(self._buckets),
                count=self.count,
                total=self.total,
                zeros=self.zeros,
                min=self.min,
                max=self.max,
            )

    def window_since(self, prev: Optional["HistogramState"]) -> "HistogramWindow":
        """The histogram of samples recorded AFTER ``prev`` was taken.

        Implemented as a bucket-count diff against the cumulative state, so
        the live histogram keeps its lifetime data (``metrics_snapshot()``
        stays all-time) while callers get logical reset-on-window
        percentiles.  With ``prev=None`` the window is the full lifetime
        (exact min/max); otherwise window min/max are bucket-edge estimates.
        """
        cur = self.state()
        return cur.diff(prev)


class HistogramState:
    """Frozen cumulative histogram snapshot (a window mark).

    Two states taken from the same histogram diff into a
    :class:`HistogramWindow` — the samples recorded between the marks.
    """

    __slots__ = ("buckets", "count", "total", "zeros", "min", "max")

    def __init__(self, *, buckets: Dict[int, int], count: int, total: float,
                 zeros: int, min: float, max: float):
        self.buckets = buckets
        self.count = count
        self.total = total
        self.zeros = zeros
        self.min = min
        self.max = max

    def diff(self, prev: Optional["HistogramState"]) -> "HistogramWindow":
        """Samples recorded after ``prev`` (cumulative-count subtraction)."""
        if prev is None or prev.count == 0:
            return HistogramWindow(
                buckets=dict(self.buckets),
                count=self.count,
                total=self.total,
                zeros=self.zeros,
                lo=self.min if self.count else 0.0,
                hi=self.max if self.count else 0.0,
            )
        buckets = {
            idx: n - prev.buckets.get(idx, 0)
            for idx, n in self.buckets.items()
            if n - prev.buckets.get(idx, 0) > 0
        }
        count = self.count - prev.count
        zeros = self.zeros - prev.zeros
        if count <= 0:
            return HistogramWindow(buckets={}, count=0, total=0.0, zeros=0,
                                   lo=0.0, hi=0.0)
        # Window min/max cannot be recovered exactly from cumulative state;
        # clamp to the occupied window buckets (0 when only zeros landed).
        if buckets:
            idxs = sorted(buckets)
            lo = 0.0 if zeros > 0 else math.exp(idxs[0] * _LOG_GROWTH)
            hi = min(math.exp((idxs[-1] + 1) * _LOG_GROWTH), self.max)
        else:
            lo = hi = 0.0
        return HistogramWindow(
            buckets=buckets,
            count=count,
            total=self.total - prev.total,
            zeros=max(0, zeros),
            lo=lo,
            hi=hi,
        )


class HistogramWindow:
    """Samples recorded within one window, with the same quantile engine.

    Unlike :class:`Histogram` this is an immutable value object — safe to
    stash in a time-series ring and merge across windows (multi-window SLO
    burn rates merge the short windows that make up a long one).
    """

    __slots__ = ("buckets", "count", "total", "zeros", "lo", "hi")

    def __init__(self, *, buckets: Dict[int, int], count: int, total: float,
                 zeros: int, lo: float, hi: float):
        self.buckets = buckets
        self.count = count
        self.total = total
        self.zeros = zeros
        self.lo = lo
        self.hi = hi

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def quantile(self, q: float) -> float:
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile {q} outside [0, 1]")
        if self.count == 0:
            return 0.0
        rank = q * self.count
        if rank <= self.zeros:
            return 0.0
        cum = self.zeros
        for idx in sorted(self.buckets):
            cum += self.buckets[idx]
            if cum >= rank:
                mid = math.exp((idx + 0.5) * _LOG_GROWTH)
                return min(max(mid, self.lo), self.hi)
        return self.hi

    def fraction_above(self, x: float) -> float:
        """Fraction of window samples whose value exceeds ``x`` (bucket
        resolution: a bucket counts as above iff its midpoint is)."""
        if self.count == 0:
            return 0.0
        above = sum(
            n for idx, n in self.buckets.items()
            if math.exp((idx + 0.5) * _LOG_GROWTH) > x
        )
        return above / self.count

    def merge(self, other: "HistogramWindow") -> "HistogramWindow":
        buckets = dict(self.buckets)
        for idx, n in other.buckets.items():
            buckets[idx] = buckets.get(idx, 0) + n
        if self.count and other.count:
            lo, hi = min(self.lo, other.lo), max(self.hi, other.hi)
        else:
            nz = self if self.count else other
            lo, hi = nz.lo, nz.hi
        return HistogramWindow(
            buckets=buckets,
            count=self.count + other.count,
            total=self.total + other.total,
            zeros=self.zeros + other.zeros,
            lo=lo,
            hi=hi,
        )

    def percentiles(self) -> Dict[str, float]:
        """Same block shape as :meth:`Histogram.percentiles`."""
        return {
            "count": self.count,
            "mean": self.mean,
            "p50": self.quantile(0.50),
            "p95": self.quantile(0.95),
            "p99": self.quantile(0.99),
            "min": self.lo if self.count else 0.0,
            "max": self.hi if self.count else 0.0,
        }


class MetricsRegistry:
    """Named typed instruments + declared conservation invariants.

    ``ingest(stats_obj)`` absorbs any of the nine stats classes (adapters
    keyed by class name), accumulating counters under a namespaced prefix
    (``io.bytes_read``, ``exec.dispatches``, ...) and appending the class's
    conservation checks — evaluated against *that object's* values — to the
    registry. ``verify_conservation()`` then replays every declared check.
    """

    def __init__(self, max_checks: int = 8192):
        self._instruments: Dict[str, Any] = {}
        # Bounded: a long-running service ingests stats forever; verification
        # covers the most recent `max_checks` declared identities.
        self._checks: "deque[Tuple[str, float, float, float]]" = deque(
            maxlen=max_checks
        )
        self._lock = threading.Lock()

    # -- instruments -------------------------------------------------------

    def _get(self, name: str, cls: type) -> Any:
        with self._lock:
            inst = self._instruments.get(name)
            if inst is None:
                inst = cls(name)
                self._instruments[name] = inst
            elif not isinstance(inst, cls):
                raise TypeError(
                    f"instrument {name!r} already exists as "
                    f"{type(inst).__name__}, requested {cls.__name__}"
                )
            return inst

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def value(self, name: str) -> float:
        inst = self._instruments[name]
        return inst.value if not isinstance(inst, Histogram) else inst.mean

    def instruments(self) -> Dict[str, Any]:
        """Point-in-time copy of the name -> instrument map (the objects
        themselves are shared; used by the time-series snapshotter)."""
        with self._lock:
            return dict(self._instruments)

    # -- conservation ------------------------------------------------------

    def check(self, label: str, lhs: float, rhs: float, tol: float = 0.0) -> None:
        """Declare one conservation identity ``lhs == rhs`` (within tol)."""
        with self._lock:
            self._checks.append((label, float(lhs), float(rhs), float(tol)))

    def verify_conservation(self, strict: bool = True) -> List[str]:
        """Replay every declared invariant; return (or raise) violations."""
        violations: List[str] = []
        with self._lock:
            checks = list(self._checks)
        for label, lhs, rhs, tol in checks:
            bound = tol * max(1.0, abs(lhs), abs(rhs)) if tol else 0.0
            if abs(lhs - rhs) > bound:
                violations.append(f"{label}: {lhs} != {rhs} (tol={tol})")
        if violations and strict:
            raise ConservationError(
                "conservation violated:\n  " + "\n  ".join(violations)
            )
        return violations

    @property
    def num_checks(self) -> int:
        with self._lock:
            return len(self._checks)

    # -- ingestion of the nine stats classes -------------------------------

    def ingest(self, stats: Any, prefix: Optional[str] = None) -> None:
        """Absorb one stats object (dispatch on its class name)."""
        adapter = _ADAPTERS.get(type(stats).__name__)
        if adapter is None:
            raise TypeError(
                f"no metrics adapter for {type(stats).__name__}; "
                f"known: {sorted(_ADAPTERS)}"
            )
        adapter(self, stats, prefix)

    def snapshot(self) -> Dict[str, Any]:
        """All instrument values; histograms render as percentile blocks."""
        with self._lock:
            items = list(self._instruments.items())
        out: Dict[str, Any] = {}
        for name, inst in items:
            out[name] = inst.percentiles() if isinstance(inst, Histogram) else inst.value
        return out

    # adapter helpers ------------------------------------------------------

    def _bump(self, prefix: str, stats: Any, fields: Tuple[str, ...]) -> None:
        for f in fields:
            v = getattr(stats, f)
            self.counter(f"{prefix}.{f}").add(max(0.0, float(v)))
            if v < 0:
                self.check(f"{prefix}.{f} >= 0", float(v), 0.0)


# -- the nine adapters -----------------------------------------------------


def _ingest_io(reg: MetricsRegistry, s: Any, prefix: Optional[str]) -> None:
    p = prefix or "io"
    reg._bump(p, s, ("bytes_read", "bytes_written", "reads", "writes"))
    if s.reads == 0:
        reg.check(f"{p}: no reads -> no bytes_read", s.bytes_read, 0)
    if s.writes == 0:
        reg.check(f"{p}: no writes -> no bytes_written", s.bytes_written, 0)


def _ingest_cache(reg: MetricsRegistry, s: Any, prefix: Optional[str]) -> None:
    p = prefix or "cache"
    reg._bump(
        p,
        s,
        (
            "hits",
            "misses",
            "evictions",
            "inserted_bytes_raw",
            "inserted_bytes_stored",
            "compress_time_s",
            "decompress_time_s",
        ),
    )


def _ingest_pipeline(reg: MetricsRegistry, s: Any, prefix: Optional[str]) -> None:
    p = prefix or "pipeline"
    reg._bump(
        p, s, ("shards_loaded", "load_total_s", "wait_s", "cache_hits", "resident_hits")
    )
    reg.check(
        f"{p}: cache+resident hits <= loads",
        min(s.cache_hits + s.resident_hits, s.shards_loaded),
        s.cache_hits + s.resident_hits,
    )


def _ingest_exec(reg: MetricsRegistry, s: Any, prefix: Optional[str]) -> None:
    p = prefix or "exec"
    reg._bump(
        p,
        s,
        (
            "dispatches",
            "batches",
            "ragged_dispatches",
            "ragged_lanes",
            "overlap_s",
            "shards_executed",
            "exec_s",
        ),
    )
    # RaggedFuse conservation (DESIGN.md §14): a ragged flush is exactly one
    # dispatch per batch, and the ragged lane axis is the disjoint union of
    # the per-group lane blocks.
    reg.check(
        f"{p}: ragged_dispatches <= batches",
        min(s.ragged_dispatches, s.batches),
        s.ragged_dispatches,
    )
    reg.check(
        f"{p}: batches <= dispatches",
        min(s.batches, s.dispatches),
        s.batches,
    )
    if s.group_lanes:
        reg.check(
            f"{p}: sum(group_lanes) == ragged_lanes",
            sum(s.group_lanes.values()),
            s.ragged_lanes,
        )
    if s.device_shards:
        reg.check(
            f"{p}: sum(device_shards) == shards_executed",
            sum(s.device_shards.values()),
            s.shards_executed,
        )
    if s.device_dispatches:
        reg.check(
            f"{p}: sum(device_dispatches) == dispatches",
            sum(s.device_dispatches.values()),
            s.dispatches,
        )


def _device_conservation(
    reg: MetricsRegistry, s: Any, p: str, dispatches: Optional[int]
) -> None:
    """Shared IterStats/SweepIterStats mesh identities (DESIGN.md §10)."""
    if s.device_shards:
        reg.check(
            f"{p}[{s.iteration}]: sum(device_shards) == shards_processed",
            sum(s.device_shards),
            s.shards_processed,
        )
    if s.device_bytes:
        reg.check(
            f"{p}[{s.iteration}]: sum(device_bytes) == bytes_read",
            sum(s.device_bytes),
            s.bytes_read,
            tol=1e-9,
        )
    if s.device_dispatches and dispatches is not None:
        reg.check(
            f"{p}[{s.iteration}]: sum(device_dispatches) == dispatches",
            sum(s.device_dispatches),
            dispatches,
        )


def _ingest_iter(reg: MetricsRegistry, s: Any, prefix: Optional[str]) -> None:
    p = prefix or "iter"
    reg._bump(
        p,
        s,
        (
            "shards_processed",
            "shards_skipped",
            "bytes_read",
            "cache_hits",
            "cache_misses",
            "load_total_s",
            "load_wait_s",
            "exec_s",
            "dispatches",
        ),
    )
    reg.histogram(f"{p}.time_s").record(s.time_s)
    _device_conservation(reg, s, p, s.dispatches)


def _ingest_sweep_iter(reg: MetricsRegistry, s: Any, prefix: Optional[str]) -> None:
    p = prefix or "sweep"
    reg._bump(
        p,
        s,
        (
            "shards_processed",
            "shards_skipped",
            "bytes_read",
            "retired",
            "backfilled",
            "lane_rows_skipped",
            "load_total_s",
            "load_wait_s",
            "exec_s",
            "dispatches",
            "batches",
            "overlap_s",
        ),
    )
    reg.histogram(f"{p}.time_s").record(s.time_s)
    reg.gauge(f"{p}.live_lanes").set(s.live_lanes)
    reg.gauge(f"{p}.groups").set(s.groups)
    # RaggedFuse (DESIGN.md §14): every flushed batch costs at least one
    # dispatch; the ragged path makes it exactly one.
    reg.check(
        f"{p}[{s.iteration}]: batches <= dispatches",
        min(s.batches, s.dispatches),
        s.batches,
    )
    _device_conservation(reg, s, p, None)


def _ingest_ingest(reg: MetricsRegistry, s: Any, prefix: Optional[str]) -> None:
    p = prefix or "ingest"
    reg._bump(
        p,
        s,
        (
            "num_edges",
            "spills",
            "runs",
            "spill_bytes_written",
            "spill_bytes_read",
            "shard_bytes_written",
            "meta_bytes_written",
        ),
    )
    reg.check(
        f"{p}: spill+shard+meta == bytes_written_total",
        s.spill_bytes_written + s.shard_bytes_written + s.meta_bytes_written,
        s.bytes_written_total,
    )
    reg.check(
        f"{p}: spill bytes read back exactly once",
        s.spill_bytes_read,
        s.spill_bytes_written,
    )


def _ingest_compaction(reg: MetricsRegistry, s: Any, prefix: Optional[str]) -> None:
    p = prefix or "compact"
    reg._bump(
        p,
        s,
        (
            "shards_compacted",
            "runs_absorbed",
            "inserts_applied",
            "tombstones_applied",
            "shard_bytes_written",
        ),
    )


def _ingest_collective(reg: MetricsRegistry, s: Any, prefix: Optional[str]) -> None:
    p = prefix or "collective"
    for kind, b in s.bytes_by_kind.items():
        reg.counter(f"{p}.bytes.{kind}").add(max(0.0, float(b)))
    for kind, c in s.count_by_kind.items():
        reg.counter(f"{p}.count.{kind}").add(max(0.0, float(c)))
    reg.check(
        f"{p}: total_bytes == sum(bytes_by_kind)",
        s.total_bytes,
        sum(s.bytes_by_kind.values()),
    )


_ADAPTERS: Dict[str, Callable[[MetricsRegistry, Any, Optional[str]], None]] = {
    "IOStats": _ingest_io,
    "CacheStats": _ingest_cache,
    "PipelineStats": _ingest_pipeline,
    "ExecStats": _ingest_exec,
    "IterStats": _ingest_iter,
    "SweepIterStats": _ingest_sweep_iter,
    "IngestStats": _ingest_ingest,
    "CompactionStats": _ingest_compaction,
    "CollectiveStats": _ingest_collective,
}
