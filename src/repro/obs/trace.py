"""GraphScope structured tracer: nestable spans on per-thread ring buffers.

The tracer answers one question the nine ad-hoc stats dataclasses cannot:
*where did this sweep spend its wall-clock, on which thread, in what order?*
Every hot path in the stack wraps its work in ``span("shard.load", shard=i)``
calls; when a :class:`Tracer` is installed the spans land in a per-thread
ring buffer (no locks on the record path — each ring has exactly one writer
thread), and :meth:`Tracer.export_chrome` emits Chrome-trace / Perfetto JSON
in which the pipeline prefetchers (``shard-prefetch_*``), the recompactor
(``graphdelta-recompact``), the service worker (``graphserve-worker``) and
the submitting thread each get their own lane.

Disabled-by-default discipline
------------------------------
``span()`` / ``counter()`` / ``instant()`` are module-level functions that
read one module global. When no tracer is installed they return a shared
no-op context manager / return immediately — the cost at every call site is
a global load, a ``None`` check, and (for spans) entering a ``__slots__``
singleton. Tier-1 timings therefore do not change when tracing is off; the
``fig_obs`` benchmark section measures this cost per call site and asserts
the aggregate stays under the 5% overhead budget (DESIGN.md §11).

Span taxonomy (DESIGN.md §11 has the full table)::

    service.admit / service.fusion_set / service.retire / service.publish
    sweep.plan / sweep.iter / batch.form
    shard.load / shard.wait / store.read / store.write
    cache.get / cache.put / overlay.merge / compact.shard
    exec.dispatch / vsw.run / vsw.iter / mesh.build_device_graph

Events are recorded as ``perf_counter_ns`` intervals and exported with
microsecond timestamps relative to the tracer's epoch, so traces from one
process line up across threads.
"""

from __future__ import annotations

import json
import os
import threading
import time
from contextlib import contextmanager
from typing import Any, Dict, Iterator, List, Optional, Tuple


class _NullSpan:
    """Shared no-op span returned while tracing is disabled."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc: Any) -> bool:
        return False

    def set(self, **attrs: Any) -> "_NullSpan":
        return self


NULL_SPAN = _NullSpan()

_ACTIVE: Optional["Tracer"] = None


def active() -> Optional["Tracer"]:
    """The currently installed tracer, or None when tracing is disabled."""
    return _ACTIVE


def span(name: str, **attrs: Any) -> Any:
    """Open a span if tracing is enabled; otherwise return the no-op span.

    Usage at call sites is always ``with trace.span("shard.load", shard=i):``
    — the disabled path costs one global read and a None check.
    """
    t = _ACTIVE
    if t is None:
        return NULL_SPAN
    return t.span(name, **attrs)


def counter(name: str, value: float, **attrs: Any) -> None:
    """Record a counter sample ("C" event) if tracing is enabled."""
    t = _ACTIVE
    if t is not None:
        t.counter(name, value, **attrs)


def instant(name: str, **attrs: Any) -> None:
    """Record an instant event ("i") if tracing is enabled."""
    t = _ACTIVE
    if t is not None:
        t.instant(name, **attrs)


def dropped_events() -> int:
    """Events dropped so far by the active tracer's rings (0 when tracing
    is disabled).  Monotonic while one tracer stays installed, so callers
    can mirror it into a registry counter (``trace.dropped_events``)."""
    t = _ACTIVE
    return t.dropped_events() if t is not None else 0


def publish_drops(registry: Any) -> int:
    """Mirror the active tracer's drop count into ``registry`` as the
    ``trace.dropped_events`` counter (created on first drop only, so a
    healthy run's snapshot stays free of zero-noise).  Returns the total.
    """
    d = dropped_events()
    if d > 0:
        c = registry.counter("trace.dropped_events")
        if d > c.value:
            c.add(d - c.value)
    return d


def install(tracer: "Tracer") -> "Tracer":
    """Install `tracer` as the process-wide active tracer."""
    global _ACTIVE
    _ACTIVE = tracer
    return tracer


def uninstall() -> None:
    """Disable tracing (span() reverts to the no-op path)."""
    global _ACTIVE
    _ACTIVE = None


@contextmanager
def tracing(tracer: Optional["Tracer"] = None) -> Iterator["Tracer"]:
    """Context manager: install a tracer for the block, restore on exit."""
    t = tracer if tracer is not None else Tracer()
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = t
    try:
        yield t
    finally:
        _ACTIVE = prev


class _ThreadRing:
    """Fixed-capacity event ring with exactly one writer thread.

    The writer appends without taking any lock; the exporter snapshots by
    copying the backing list, which is safe under the GIL because slots are
    assigned whole tuples. ``n`` counts all events ever written, so
    ``n - capacity`` (when positive) is the number of dropped-oldest events.
    """

    __slots__ = ("tid", "name", "capacity", "buf", "n", "depth")

    def __init__(self, tid: int, name: str, capacity: int):
        self.tid = tid
        self.name = name
        self.capacity = capacity
        self.buf: List[Optional[tuple]] = [None] * capacity
        self.n = 0
        self.depth = 0  # currently-open spans on this thread

    def push(self, ev: tuple) -> None:
        self.buf[self.n % self.capacity] = ev
        self.n += 1

    def snapshot(self) -> Tuple[List[tuple], int]:
        n = self.n
        if n <= self.capacity:
            return [e for e in self.buf[:n] if e is not None], 0
        cut = n % self.capacity
        out = self.buf[cut:] + self.buf[:cut]
        return [e for e in out if e is not None], n - self.capacity


class Span:
    """A single open span; records a completed "X" event on exit.

    Exceptions propagating through the span mark it with an ``error`` attr
    (and re-raise), so failed shard loads render red in the timeline with
    the failing shard id attached.
    """

    __slots__ = ("_ring", "_name", "_attrs", "_t0")

    def __init__(self, ring: _ThreadRing, name: str, attrs: Optional[Dict[str, Any]]):
        self._ring = ring
        self._name = name
        self._attrs = attrs
        self._t0 = 0

    def set(self, **attrs: Any) -> "Span":
        """Attach/overwrite attributes on an open span."""
        if self._attrs is None:
            self._attrs = attrs
        else:
            self._attrs.update(attrs)
        return self

    def __enter__(self) -> "Span":
        self._ring.depth += 1
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, exc_type: Any, exc: Any, tb: Any) -> bool:
        dur = time.perf_counter_ns() - self._t0
        ring = self._ring
        ring.depth -= 1
        if exc is not None:
            self.set(error=repr(exc))
        ring.push(("X", self._name, self._t0, dur, self._attrs))
        return False


class Tracer:
    """Collects spans/counters/instants into per-thread rings.

    Parameters
    ----------
    capacity:
        Events retained per thread; oldest are dropped beyond this (the
        drop count is reported in the export's ``otherData``).
    """

    def __init__(self, capacity: int = 1 << 16):
        if capacity <= 0:
            raise ValueError("tracer capacity must be positive")
        self.capacity = int(capacity)
        self.epoch_ns = time.perf_counter_ns()
        self._local = threading.local()
        self._rings: List[_ThreadRing] = []
        self._reg_lock = threading.Lock()

    # -- recording ---------------------------------------------------------

    def _ring(self) -> _ThreadRing:
        ring = getattr(self._local, "ring", None)
        if ring is None:
            th = threading.current_thread()
            ring = _ThreadRing(th.ident or 0, th.name, self.capacity)
            with self._reg_lock:
                self._rings.append(ring)
            self._local.ring = ring
        return ring

    def span(self, name: str, **attrs: Any) -> Span:
        return Span(self._ring(), name, attrs or None)

    def counter(self, name: str, value: float, **attrs: Any) -> None:
        self._ring().push(("C", name, time.perf_counter_ns(), value, attrs or None))

    def instant(self, name: str, **attrs: Any) -> None:
        self._ring().push(("i", name, time.perf_counter_ns(), 0, attrs or None))

    # -- introspection (used by well-formedness tests) ---------------------

    def open_span_count(self) -> int:
        """Number of spans currently entered but not yet exited."""
        with self._reg_lock:
            return sum(r.depth for r in self._rings)

    def event_count(self) -> int:
        with self._reg_lock:
            return sum(min(r.n, r.capacity) for r in self._rings)

    def thread_names(self) -> List[str]:
        with self._reg_lock:
            return [r.name for r in self._rings]

    def dropped_events(self) -> int:
        """Oldest-event drops across all rings (ring overflow evidence)."""
        with self._reg_lock:
            return sum(max(0, r.n - r.capacity) for r in self._rings)

    # -- export ------------------------------------------------------------

    def export_chrome(self, path: Optional[str] = None) -> Dict[str, Any]:
        """Render all recorded events as a Chrome-trace JSON object.

        Loadable by Perfetto / ``chrome://tracing``. Returns the dict; when
        `path` is given, also writes it as JSON.
        """
        pid = os.getpid()
        with self._reg_lock:
            rings = list(self._rings)
        events: List[Dict[str, Any]] = []
        dropped_total = 0
        for ring in rings:
            events.append(
                {
                    "ph": "M",
                    "pid": pid,
                    "tid": ring.tid,
                    "name": "thread_name",
                    "args": {"name": ring.name},
                }
            )
            evs, dropped = ring.snapshot()
            dropped_total += dropped
            for ev in evs:
                ph, name, t_ns, dur_or_val, attrs = ev
                rec: Dict[str, Any] = {
                    "ph": ph,
                    "pid": pid,
                    "tid": ring.tid,
                    "name": name,
                    "ts": (t_ns - self.epoch_ns) / 1000.0,
                }
                if ph == "X":
                    rec["dur"] = dur_or_val / 1000.0
                    if attrs:
                        rec["args"] = _jsonable(attrs)
                elif ph == "C":
                    args = {"value": dur_or_val}
                    if attrs:
                        args.update(_jsonable(attrs))
                    rec["args"] = args
                else:  # instant
                    rec["s"] = "t"
                    if attrs:
                        rec["args"] = _jsonable(attrs)
                events.append(rec)
        out = {
            "traceEvents": events,
            "displayTimeUnit": "ms",
            "otherData": {
                "tracer": "graphscope",
                "dropped_events": dropped_total,
                "ring_capacity": self.capacity,
            },
        }
        if dropped_total > 0:
            # Loud, not silent: a truncated timeline is misleading evidence.
            out["otherData"]["warning"] = (
                f"ring overflow: {dropped_total} oldest events dropped "
                f"(per-thread capacity {self.capacity}); the timeline is "
                f"truncated at its start — raise Tracer(capacity=...) to "
                f"capture the full run"
            )
        if path is not None:
            with open(path, "w") as f:
                json.dump(out, f)
        return out


def _jsonable(attrs: Dict[str, Any]) -> Dict[str, Any]:
    """Coerce span attrs to JSON-safe scalars (numpy ints etc. appear)."""
    out: Dict[str, Any] = {}
    for k, v in attrs.items():
        if isinstance(v, (str, bool)) or v is None:
            out[k] = v
        elif isinstance(v, (int, float)):
            out[k] = v
        else:
            try:
                out[k] = int(v)
            except (TypeError, ValueError):
                try:
                    out[k] = float(v)
                except (TypeError, ValueError):
                    out[k] = str(v)
    return out
