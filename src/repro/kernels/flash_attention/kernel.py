"""Pallas TPU kernel: flash attention (online-softmax, causal, GQA-ready).

The same HBM->VMEM sliding-window schedule as the graph kernel, applied to
the LM hot spot: the KV sequence is streamed block-by-block past a resident
Q block while softmax statistics (m, l) and the output accumulator live in
VMEM scratch.

- grid = (BH, n_q_blocks, n_kv_blocks); the kv dim iterates fastest, so the
  scratch accumulator carries across kv steps of one (bh, q) cell; it is
  initialised at ik == 0 and divided by l at the last kv step.
- causal blocks strictly above the diagonal are skipped with ``pl.when``
  (their DMA still happens in this baseline — see EXPERIMENTS.md §Perf for
  the index-remap variant that avoids it).
- all softmax math in f32; inputs may be bf16.

GQA: callers pass K/V already expanded to Hq heads (XLA broadcasts lazily);
the kernel itself is head-agnostic.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    causal: bool, scale: float, seq_off: int,
    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
):
    ik = pl.program_id(2)
    nk = pl.num_programs(2)
    iq = pl.program_id(1)
    bq, d = q_ref.shape[-2], q_ref.shape[-1]
    bk = k_ref.shape[-2]

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = iq * bq + seq_off  # query positions in KV coordinates
    k_start = ik * bk

    def _compute():
        q = q_ref[0].astype(jnp.float32)  # [bq, d]
        k = k_ref[0].astype(jnp.float32)  # [bk, d]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale  # [bq, bk]
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_cur = s.max(axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_prev * alpha + p.sum(axis=1)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )

    if causal:
        # Skip blocks strictly above the diagonal: kv block start beyond the
        # last query position of this q block.
        pl.when(k_start <= q_start + bq - 1)(_compute)
    else:
        _compute()

    @pl.when(ik == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)  # fully-masked rows stay 0
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def _decode_kernel(scale: float, q_ref, k_ref, v_ref, valid_ref,
                   o_ref, m_ref, l_ref, acc_ref):
    """One (bh, kv-block) step: q is a resident [G, d] tile (the GQA query
    group for one kv head); stats carried in VMEM scratch across kv blocks."""
    ik = pl.program_id(1)
    nk = pl.num_programs(1)
    bk = k_ref.shape[-2]

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)  # [G, d]
    k = k_ref[0].astype(jnp.float32)  # [bk, d]
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # [G, bk]
    mask = valid_ref[0]  # [bk] bool: cache slot holds a live token
    s = jnp.where(mask[None, :], s, NEG_INF)
    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask[None, :], p, 0.0)
    l_ref[...] = l_prev * alpha + p.sum(axis=1)
    m_ref[...] = m_new
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(ik == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("scale", "block_k", "interpret")
)
def flash_decode(
    q: jax.Array,  # [BHkv, G, D]   (G = query heads per kv head)
    k: jax.Array,  # [BHkv, S, D]   (local KV shard)
    v: jax.Array,  # [BHkv, S, D]
    valid: jax.Array,  # [BHkv, S] bool — live cache slots
    *,
    scale: float | None = None,
    block_k: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """Single-token decode attention over a (possibly sharded) KV cache.

    This is the kernel-native layout identified in EXPERIMENTS.md §Perf
    (whisper it1): each device holds a SLICE of the cache sequence; the
    kernel emits the un-normalised accumulator plus softmax stats, and the
    cross-device combine is a cheap psum of (m, l, acc) — no score
    re-gathering.  ``flash_decode_combine`` performs that merge.

    Returns (o [BHkv, G, D], m [BHkv, G], l [BHkv, G]) with o UN-normalised?
    — no: o is locally normalised; use flash_decode_combine for multi-shard.
    """
    bh, G, d = q.shape
    S = k.shape[1]
    if S % block_k:
        pad = block_k - S % block_k
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0)))
        valid = jnp.pad(valid, ((0, 0), (0, pad)))
        S += pad
    scale = (d ** -0.5) if scale is None else scale
    grid = (bh, S // block_k)
    return pl.pallas_call(
        functools.partial(_decode_kernel, scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, G, d), lambda b, ik: (b, 0, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, ik: (b, ik, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, ik: (b, ik, 0)),
            pl.BlockSpec((1, block_k), lambda b, ik: (b, ik)),
        ],
        out_specs=pl.BlockSpec((1, G, d), lambda b, ik: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, valid)


def decode_partials_ref(q, k, v, valid, *, scale=None):
    """jnp oracle emitting (o_unnormalised, m, l) for the shard-combine."""
    bh, G, d = q.shape
    scale = (d ** -0.5) if scale is None else scale
    s = jnp.einsum("bgd,bkd->bgk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    s = jnp.where(valid[:, None, :], s, NEG_INF)
    m = s.max(axis=-1)
    p = jnp.where(valid[:, None, :], jnp.exp(s - m[..., None]), 0.0)
    l = p.sum(axis=-1)
    o = jnp.einsum("bgk,bkd->bgd", p, v.astype(jnp.float32))
    return o, m, l


def flash_decode_combine(os, ms, ls):
    """Merge per-shard partials: os [N,bh,G,D] un-norm, ms/ls [N,bh,G].

    The multi-device form is the same algebra under psum: each device
    contributes exp(m_i - m*) re-weighted sums.  Used by the seq-sharded
    decode path instead of re-gathering scores (EXPERIMENTS.md §Perf).
    """
    m_star = ms.max(axis=0)  # [bh, G]
    w = jnp.exp(ms - m_star[None])  # [N, bh, G]
    l_tot = (ls * w).sum(axis=0)
    o_tot = (os * w[..., None]).sum(axis=0)
    return (o_tot / jnp.maximum(l_tot, 1e-30)[..., None])


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jax.Array,  # [BH, Sq, D]
    k: jax.Array,  # [BH, Skv, D]
    v: jax.Array,  # [BH, Skv, D]
    *,
    causal: bool = True,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    bh, sq, d = q.shape
    skv = k.shape[1]
    if sq % block_q or skv % block_k:
        raise ValueError(f"seq lens ({sq},{skv}) need blocks ({block_q},{block_k})")
    scale = (d ** -0.5) if scale is None else scale
    seq_off = skv - sq  # decode convention (queries align to the suffix)
    grid = (bh, sq // block_q, skv // block_k)

    return pl.pallas_call(
        functools.partial(_flash_kernel, causal, scale, seq_off),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, iq, ik: (b, iq, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, iq, ik: (b, ik, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, iq, ik: (b, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, iq, ik: (b, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
